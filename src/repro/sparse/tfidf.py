"""TF-IDF weighting and the paper's rank-based term culling (§1).

The paper: "TF-IDF culling is performed by ranking terms. A rank is calculated
by summing all weights for each term. The 8000 terms with the highest rank are
selected." Host-side (numpy) — this is corpus preprocessing.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.sparse.csr import Csr, csr_select_columns


def tfidf_weight(counts: Csr, smooth: bool = True) -> Csr:
    """Turn a term-count CSR into TF-IDF weights. tf = raw count,
    idf = log(N / df) (smoothed: log((1+N)/(1+df)) + 1)."""
    data = np.asarray(counts.data, dtype=np.float64)
    indices = np.asarray(counts.indices)
    n_docs = counts.n_rows
    df = np.bincount(indices, minlength=counts.n_cols).astype(np.float64)
    if smooth:
        idf = np.log((1.0 + n_docs) / (1.0 + df)) + 1.0
    else:
        idf = np.log(np.maximum(n_docs / np.maximum(df, 1.0), 1.0))
    return Csr(
        data=jnp.asarray((data * idf[indices]).astype(np.float32)),
        indices=counts.indices,
        indptr=counts.indptr,
        n_cols=counts.n_cols,
    )


def term_ranks(weighted: Csr) -> np.ndarray:
    """Rank of each term = sum of its weights over the corpus (paper §1)."""
    data = np.asarray(weighted.data, dtype=np.float64)
    indices = np.asarray(weighted.indices)
    return np.bincount(indices, weights=data, minlength=weighted.n_cols)


def cull_terms(weighted: Csr, n_keep: int = 8000) -> tuple[Csr, np.ndarray]:
    """Keep the ``n_keep`` highest-ranked terms; re-index columns.

    Returns (culled matrix, kept original term ids).
    """
    ranks = term_ranks(weighted)
    n_keep = min(n_keep, weighted.n_cols)
    keep = np.sort(np.argpartition(-ranks, n_keep - 1)[:n_keep])
    return csr_select_columns(weighted, keep), keep


def unit_normalize_rows(m: Csr) -> Csr:
    """L2-normalise document vectors (cosine ≡ euclidean on the unit sphere —
    standard for document clustering; CLUTO does the same)."""
    from repro.sparse.csr import csr_row_norms

    norms = np.sqrt(np.maximum(np.asarray(csr_row_norms(m)), 1e-12))
    rows = np.asarray(m.row_ids())
    data = np.asarray(m.data) / norms[rows]
    return Csr(jnp.asarray(data), m.indices, m.indptr, m.n_cols)
