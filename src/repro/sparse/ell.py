"""Padded (ELL) sparse layout — the TPU-friendly form.

Every row is padded to ``nnz_max`` (column id 0, value 0). Static shapes, so the
``ell_spmm`` Pallas kernel can tile it into VMEM. The paper's medoid K-tree keeps
documents sparse; ELL is how those documents feed the MXU.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import Csr


class Ell(NamedTuple):
    values: jax.Array   # f[n_rows, nnz_max]   (0 on padding)
    cols: jax.Array     # i32[n_rows, nnz_max] (0 on padding — value 0 nullifies)
    n_cols: int         # static

    @property
    def n_rows(self) -> int:
        return self.values.shape[0]

    @property
    def nnz_max(self) -> int:
        return self.values.shape[1]

    @property
    def shape(self):
        return (self.n_rows, self.n_cols)


def ell_from_csr(m: Csr, nnz_max: int | None = None, pad_to: int = 8) -> Ell:
    """Host-side CSR → ELL. ``nnz_max`` defaults to the longest row, rounded up
    to a multiple of ``pad_to`` (lane-friendly)."""
    indptr = np.asarray(m.indptr)
    lengths = np.diff(indptr)
    if nnz_max is None:
        nnz_max = int(lengths.max()) if lengths.size else 1
    nnz_max = max(pad_to, int(-(-nnz_max // pad_to) * pad_to))
    vals = np.zeros((m.n_rows, nnz_max), dtype=np.asarray(m.data).dtype)
    cols = np.zeros((m.n_rows, nnz_max), dtype=np.int32)
    data = np.asarray(m.data)
    indices = np.asarray(m.indices)
    for i in range(m.n_rows):
        k = min(int(lengths[i]), nnz_max)
        vals[i, :k] = data[indptr[i] : indptr[i] + k]
        cols[i, :k] = indices[indptr[i] : indptr[i] + k]
    return Ell(values=jnp.asarray(vals), cols=jnp.asarray(cols), n_cols=m.n_cols)


def ell_to_dense(e: Ell) -> jax.Array:
    out = jnp.zeros(e.shape, e.values.dtype)
    r = jnp.broadcast_to(jnp.arange(e.n_rows)[:, None], e.cols.shape)
    return out.at[r, e.cols].add(e.values)


def ell_dot_dense(e: Ell, dense_t: jax.Array) -> jax.Array:
    """Scores S[i,k] = Σ_j values[i,j] · dense_t[cols[i,j], k].

    ``dense_t``: f[n_cols, K] (centres transposed). This is the pure-XLA
    reference path; the Pallas kernel (repro.kernels.ell_spmm) is the TPU
    version. Memory: n_rows × nnz_max × K intermediate — callers tile rows.
    """
    gathered = jnp.take(dense_t, e.cols, axis=0)           # [n, nnz, K]
    return jnp.einsum("nj,njk->nk", e.values, gathered)


def ell_row_norms(e: Ell) -> jax.Array:
    return (e.values * e.values).sum(axis=1)
