"""CSR sparse matrix built on ``jnp.take`` + ``jax.ops.segment_sum``.

The container is a NamedTuple of plain arrays so it is a pytree and crosses
jit/pjit boundaries. Row counts are static (shape metadata), nnz is static per
instance — standard for JAX sparse work.

Semantics follow scipy.sparse.csr_matrix: ``indptr[i]:indptr[i+1]`` delimits the
column indices / values of row ``i``.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Csr(NamedTuple):
    """Compressed sparse row matrix of logical shape ``(n_rows, n_cols)``."""

    data: jax.Array     # f[nnz]
    indices: jax.Array  # i32[nnz] column ids
    indptr: jax.Array   # i32[n_rows + 1]
    n_cols: int         # static

    @property
    def n_rows(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_rows, self.n_cols)

    @property
    def dtype(self):
        return self.data.dtype

    def row_ids(self) -> jax.Array:
        """i32[nnz] — the row id of every stored element."""
        return row_ids_from_indptr(self.indptr, self.nnz)


def row_ids_from_indptr(indptr: jax.Array, nnz: int) -> jax.Array:
    """Expand an indptr into per-element row ids (the CSR→COO row expansion)."""
    n_rows = indptr.shape[0] - 1
    # searchsorted('right') maps element position -> row; O(nnz log n_rows).
    return (
        jnp.searchsorted(indptr, jnp.arange(nnz, dtype=indptr.dtype), side="right")
        .astype(jnp.int32)
        - 1
    ).clip(0, n_rows - 1)


def csr_from_dense(x, threshold: float = 0.0) -> Csr:
    """Host-side constructor (numpy) — used by data pipelines and tests."""
    x = np.asarray(x)
    mask = np.abs(x) > threshold
    counts = mask.sum(axis=1)
    indptr = np.zeros(x.shape[0] + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    rows, cols = np.nonzero(mask)
    return Csr(
        data=jnp.asarray(x[rows, cols]),
        indices=jnp.asarray(cols.astype(np.int32)),
        indptr=jnp.asarray(indptr),
        n_cols=x.shape[1],
    )


def csr_to_dense(m: Csr) -> jax.Array:
    out = jnp.zeros(m.shape, m.dtype)
    return out.at[m.row_ids(), m.indices].add(m.data)


def csr_matmat(m: Csr, dense: jax.Array) -> jax.Array:
    """``m @ dense`` — gather rhs rows by column id, scale, segment-sum by row.

    dense: f[n_cols, d] -> f[n_rows, d]. This is the message-passing primitive
    (gather → scale → segment reduce) the kernel taxonomy calls out.
    """
    gathered = jnp.take(dense, m.indices, axis=0)          # [nnz, d]
    scaled = gathered * m.data[:, None]
    return jax.ops.segment_sum(scaled, m.row_ids(), num_segments=m.n_rows)


def csr_row_norms(m: Csr) -> jax.Array:
    """Squared L2 norm of every row — needed for ‖x−c‖² expansion."""
    return jax.ops.segment_sum(m.data * m.data, m.row_ids(), num_segments=m.n_rows)


def csr_row_gather_dense(m: Csr, rows: jax.Array, max_nnz_row: int) -> jax.Array:
    """Gather a set of rows as *dense* vectors: f[len(rows), n_cols].

    Used by the medoid K-tree: internal nodes store document ids; NN search
    against medoid centres gathers those documents. ``max_nnz_row`` bounds the
    per-row scatter (static shape); rows with more nnz are truncated (callers
    pass the corpus-wide max).
    """
    rows = jnp.asarray(rows, jnp.int32)
    starts = m.indptr[rows]                                 # [R]
    lengths = m.indptr[rows + 1] - starts                   # [R]
    offs = jnp.arange(max_nnz_row, dtype=jnp.int32)         # [L]
    gidx = starts[:, None] + offs[None, :]                  # [R, L]
    valid = offs[None, :] < lengths[:, None]
    gidx = jnp.where(valid, gidx, 0)
    cols = jnp.where(valid, jnp.take(m.indices, gidx), 0)
    vals = jnp.where(valid, jnp.take(m.data, gidx), 0.0)
    out = jnp.zeros((rows.shape[0], m.n_cols), m.dtype)
    r = jnp.broadcast_to(jnp.arange(rows.shape[0])[:, None], cols.shape)
    return out.at[r, cols].add(vals)


def csr_slice_rows(m: Csr, lo: int, hi: int) -> Csr:
    """Host-side contiguous row slice ``m[lo:hi]`` (corpus sharding: each
    shard's documents feed ``build``/``insert`` as their own matrix)."""
    indptr = np.asarray(m.indptr)
    start, stop = int(indptr[lo]), int(indptr[hi])
    return Csr(
        data=m.data[start:stop],
        indices=m.indices[start:stop],
        indptr=jnp.asarray(indptr[lo : hi + 1] - indptr[lo]),
        n_cols=m.n_cols,
    )


def csr_select_columns(m: Csr, keep: np.ndarray) -> Csr:
    """Host-side column filter + re-index (term culling). ``keep``: sorted ids."""
    keep = np.asarray(keep)
    data = np.asarray(m.data)
    indices = np.asarray(m.indices)
    indptr = np.asarray(m.indptr)
    remap = -np.ones(m.n_cols, dtype=np.int32)
    remap[keep] = np.arange(keep.shape[0], dtype=np.int32)
    new_cols = remap[indices]
    mask = new_cols >= 0
    # per-row surviving counts -> new indptr
    rows = np.repeat(np.arange(m.n_rows), np.diff(indptr))
    surv = np.bincount(rows[mask], minlength=m.n_rows)
    new_indptr = np.zeros(m.n_rows + 1, dtype=np.int32)
    np.cumsum(surv, out=new_indptr[1:])
    return Csr(
        data=jnp.asarray(data[mask]),
        indices=jnp.asarray(new_cols[mask]),
        indptr=jnp.asarray(new_indptr),
        n_cols=int(keep.shape[0]),
    )
