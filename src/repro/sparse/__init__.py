"""Sparse document-matrix substrate.

JAX has no native CSR/CSC (only experimental BCOO), so this package builds the
sparse layer the paper needs from first principles:

- :mod:`repro.sparse.csr`  — CSR container + take/segment_sum products.
- :mod:`repro.sparse.ell`  — padded (ELL) layout, the TPU-friendly form used by
  the ``ell_spmm`` Pallas kernel.
- :mod:`repro.sparse.tfidf` — TF-IDF weighting and the paper's rank-based term
  culling (top-8000 terms).
"""
from repro.sparse.csr import (
    Csr,
    csr_from_dense,
    csr_to_dense,
    csr_matmat,
    csr_row_norms,
    csr_row_gather_dense,
    csr_select_columns,
    csr_slice_rows,
)
from repro.sparse.ell import Ell, ell_from_csr, ell_to_dense, ell_dot_dense
from repro.sparse.tfidf import tfidf_weight, cull_terms

__all__ = [
    "Csr",
    "csr_from_dense",
    "csr_to_dense",
    "csr_matmat",
    "csr_row_norms",
    "csr_row_gather_dense",
    "csr_select_columns",
    "csr_slice_rows",
    "Ell",
    "ell_from_csr",
    "ell_to_dense",
    "ell_dot_dense",
    "tfidf_weight",
    "cull_terms",
]
