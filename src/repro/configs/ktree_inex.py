"""The paper's own INEX 2008 experiment config: 114,366 docs, 15 labels,
TF-IDF culled to 8,000 terms (3.4 GB dense / 58.5 MB sparse — paper §1).
K-tree order sweeps produce the cluster-count axis of Figure 1."""
from repro.configs.registry import ArchSpec, register
from repro.data.synth_corpus import INEX_LIKE

CFG = {
    "corpus": INEX_LIKE,
    "orders": (20, 35, 50, 80, 120),   # order m sweep → leaf-cluster counts
    "sample_fraction": 0.1,            # paper §3 sampled variant
    "cluto_iters": 10,                 # CLUTO-style fixed-iteration baseline
    # document representation fed to the K-tree (repro.core.backend): the
    # paper's §4 experiments keep the culled matrix dense on this collection
    "representation": "dense",
}

register(ArchSpec(
    name="ktree-inex", family="paper", cfg=CFG,
    shapes={
        # distributed corpus assignment step on the production mesh:
        # 114,366 × 8,000 dense fp32 (paper's dense representation), k=1000
        # n_docs padded 114366 -> 114688 (512-divisible; zero-weight pad docs)
        "cluster_assign": {"kind": "cluster", "n_docs": 114688, "n_terms": 8000, "k": 1024},
    },
    notes="paper-reproduction config (benchmarks/paper_quality.py)",
))
