"""dlrm-mlperf [recsys] — n_dense=13 n_sparse=26 embed_dim=128
bot_mlp=13-512-256-128 top_mlp=1024-1024-512-256-1 interaction=dot.
MLPerf DLRM benchmark config (Criteo 1TB), arXiv:1906.00091.
Embedding tables: the authentic 26 MLPerf row counts (Σ≈188M rows ⇒ 96 GB
fp32) — sharded row-wise over the whole mesh."""
from repro.configs.registry import ArchSpec, register
from repro.models.recsys import RecsysConfig, MLPERF_TABLE_ROWS

CFG = RecsysConfig(
    name="dlrm-mlperf", kind="dlrm", embed_dim=128,
    table_rows=MLPERF_TABLE_ROWS, n_dense=13,
    bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
)

SHAPES = {
    "train_batch":    {"kind": "train",     "batch": 65536},
    "serve_p99":      {"kind": "serve",     "batch": 512},
    "serve_bulk":     {"kind": "serve",     "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1, "n_candidates": 1_000_448}  # 1M padded to 512-divisible,
}

register(ArchSpec(
    name="dlrm-mlperf", family="recsys", cfg=CFG, shapes=SHAPES,
    optimizer="adamw",
    rules_overrides={"serve_p99": {"table_rows": "model"}},
    notes="retrieval_cand scores candidates from table t0 (39.9M rows) — "
          "also served by the K-tree ANN path (paper §5 collection selection).",
))
