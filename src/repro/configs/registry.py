"""Architecture registry: every assigned arch is a selectable config
(``--arch <id>``) exposing, per input shape, (abstract inputs, abstract state,
logical axes, step fn) — everything the dry-run, smoke tests and launchers
need. See DESIGN §4 for the applicability map.
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import optim as optim_lib
from repro.train.loop import TrainState, make_train_step

SDS = jax.ShapeDtypeStruct
f32, i32 = jnp.float32, jnp.int32


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                     # lm | gnn | recsys
    cfg: Any
    shapes: Mapping[str, Mapping[str, Any]]
    rules_overrides: Mapping[str, Mapping[str, Any]] = dataclasses.field(default_factory=dict)
    optimizer: str = "adamw"
    notes: str = ""


_REGISTRY: Dict[str, ArchSpec] = {}

_CONFIG_MODULES = [
    "qwen2_5_14b", "granite_20b", "phi3_mini", "grok1_314b", "dbrx_132b",
    "dimenet", "dlrm_mlperf", "wide_deep", "bst", "dien",
    "ktree_inex", "ktree_rcv1", "ktree_rcv1_rp",
]


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.name] = spec
    return spec


def _ensure_loaded():
    for m in _CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get(name: str) -> ArchSpec:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs(family: Optional[str] = None):
    _ensure_loaded()
    return sorted(
        n for n, s in _REGISTRY.items() if family is None or s.family == family
    )


def make_optimizer(spec: ArchSpec):
    return optim_lib.adafactor() if spec.optimizer == "adafactor" else optim_lib.adamw()


def cfg_for_shape(spec: ArchSpec, shape_name: str):
    """Shape-specific config view (e.g. DimeNet's d_feat / n_classes vary per
    dataset; molecule switches to atom-type embedding + energy head)."""
    cfg = spec.cfg
    sh = spec.shapes[shape_name]
    if spec.family == "gnn":
        if sh.get("molecular"):
            cfg = dataclasses.replace(cfg, d_feat=0, n_classes=0)
        else:
            cfg = dataclasses.replace(
                cfg, d_feat=sh["d_feat"], n_classes=sh["n_classes"]
            )
    return cfg


# ---------------------------------------------------------------------------
# per-family abstract input builders — (inputs, input logical axes)
# ---------------------------------------------------------------------------

def abstract_inputs(spec: ArchSpec, shape_name: str) -> Tuple[Any, Any]:
    sh = dict(spec.shapes[shape_name])
    if spec.family == "lm":
        return _lm_inputs(spec, sh)
    if spec.family == "gnn":
        return _gnn_inputs(spec, sh, cfg_for_shape(spec, shape_name))
    if spec.family == "recsys":
        return _recsys_inputs(spec, sh)
    if spec.family == "paper":
        return _paper_inputs(spec, sh)
    raise ValueError(spec.family)


def _lm_inputs(spec, sh):
    from repro.models import transformer as T

    cfg = spec.cfg
    b = sh["batch"]
    kind = sh["kind"]
    bax = None if b == 1 else "batch"
    if kind == "train":
        s = sh["seq"]
        specs = {"tokens": SDS((b, s), i32), "labels": SDS((b, s), i32)}
        axes = {"tokens": (bax, "seq"), "labels": (bax, "seq")}
        return specs, axes
    if kind == "prefill":
        s = sh["seq"]
        return {"tokens": SDS((b, s), i32)}, {"tokens": (bax, "seq")}
    if kind == "decode":
        s = sh["seq"]
        cax = T.cache_logical_axes(b)
        cache_shape = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.hd)
        specs = {
            "cache": {"k": SDS(cache_shape, cfg.dtype), "v": SDS(cache_shape, cfg.dtype)},
            "tokens": SDS((b, 1), i32),
            "pos": SDS((), i32),
        }
        axes = {
            "cache": {"k": cax, "v": cax},
            "tokens": (bax, None),
            "pos": (),
        }
        return specs, axes
    raise ValueError(kind)


def _gnn_inputs(spec, sh, cfg):
    n, e, t = sh["n_nodes"], sh["n_edges"], sh["n_triplets"]
    specs = {
        "pos": SDS((n, 3), f32),
        "edge_index": SDS((2, e), i32),
        "triplets": SDS((2, t), i32),
    }
    axes = {
        "pos": ("nodes", None),
        "edge_index": (None, "edges"),
        "triplets": (None, "edges"),
    }
    if cfg.d_feat > 0:
        specs["feats"] = SDS((n, cfg.d_feat), f32)
        axes["feats"] = ("nodes", None)
    else:
        specs["z"] = SDS((n,), i32)
        axes["z"] = ("nodes",)
    if cfg.n_classes:
        specs["labels"] = SDS((n,), i32)
        axes["labels"] = ("nodes",)
    else:
        g = sh.get("n_graphs", 1)
        # n_graphs itself is static — threaded through the loss closure
        specs.update({"graph_id": SDS((n,), i32), "labels": SDS((g,), f32)})
        axes.update({"graph_id": ("nodes",), "labels": (None,)})
    return specs, axes


def _recsys_inputs(spec, sh):
    cfg = spec.cfg
    kind = sh["kind"]
    if kind == "retrieval":
        n_cand = sh["n_candidates"]
        specs: Dict[str, Any] = {"cand_ids": SDS((n_cand,), i32)}
        axes: Dict[str, Any] = {"cand_ids": ("cand",)}
        b, bax = sh.get("batch", 1), None
    else:
        b = sh["batch"]
        bax = "batch"
        specs, axes = {}, {}
    k = cfg.kind
    if k == "dlrm":
        specs.update({"dense": SDS((b, cfg.n_dense), f32), "sparse_ids": SDS((b, cfg.n_sparse), i32)})
        axes.update({"dense": (bax, None), "sparse_ids": (bax, None)})
    elif k == "wide_deep":
        specs["sparse_ids"] = SDS((b, cfg.n_sparse), i32)
        axes["sparse_ids"] = (bax, None)
    elif k == "bst":
        specs.update({
            "hist_ids": SDS((b, cfg.seq_len), i32),
            "target_id": SDS((b,), i32),
            "context_ids": SDS((b, cfg.n_context), i32),
        })
        axes.update({"hist_ids": (bax, None), "target_id": (bax,), "context_ids": (bax, None)})
    elif k == "dien":
        specs.update({
            "hist_ids": SDS((b, cfg.seq_len), i32),
            "hist_cat_ids": SDS((b, cfg.seq_len), i32),
            "target_id": SDS((b,), i32),
            "target_cat_id": SDS((b,), i32),
        })
        axes.update({
            "hist_ids": (bax, None), "hist_cat_ids": (bax, None),
            "target_id": (bax,), "target_cat_id": (bax,),
        })
        if cfg.n_context:
            specs["context_ids"] = SDS((b, cfg.n_context), i32)
            axes["context_ids"] = (bax, None)
    if kind == "train":
        specs["labels"] = SDS((b,), f32)
        axes["labels"] = (bax,)
    return specs, axes


# ---------------------------------------------------------------------------
# abstract state (params / TrainState) + logical axes
# ---------------------------------------------------------------------------

def _model_api(spec: ArchSpec):
    if spec.family == "lm":
        from repro.models import transformer as M
    elif spec.family == "gnn":
        from repro.models import gnn as M
    else:
        from repro.models import recsys as M
    return M


def abstract_params(spec: ArchSpec, shape_name: Optional[str] = None) -> Tuple[Any, Any]:
    if spec.family == "paper":
        return {}, {}
    M = _model_api(spec)
    cfg = cfg_for_shape(spec, shape_name) if shape_name else spec.cfg
    params = jax.eval_shape(lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0))
    axes = M.param_logical_axes(cfg)
    return params, axes


def abstract_state(spec: ArchSpec, shape_name: str) -> Tuple[Any, Any]:
    """Abstract (state, logical axes) for the cell: TrainState for train cells,
    bare params for serving cells."""
    sh = spec.shapes[shape_name]
    params, paxes = abstract_params(spec, shape_name)
    if sh["kind"] != "train":
        return params, paxes
    opt = make_optimizer(spec)
    opt_state = jax.eval_shape(opt.init, params)
    opt_axes = opt.state_logical_axes(paxes, params)
    state = TrainState(params, opt_state, SDS((), i32))
    axes = TrainState(paxes, opt_axes, ())
    return state, axes


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def step_fn(spec: ArchSpec, shape_name: str) -> Callable:
    """The jittable callable for this cell: (state, inputs) → outputs."""
    sh = dict(spec.shapes[shape_name])
    kind = sh["kind"]
    if kind == "cluster":
        if _paper_representation(spec) == "sparse_medoid":
            return _cluster_step_sparse
        return _cluster_step
    M = _model_api(spec)
    cfg = cfg_for_shape(spec, shape_name)

    if kind == "train":
        from repro.models.sharding import current_rules, _MESH

        loss = functools.partial(_static_loss, M=M, cfg=cfg, static=_static_fields(sh))
        rules, mesh = current_rules(), _MESH.get()
        param_specs = None
        if rules is not None and mesh is not None:
            _, paxes = abstract_params(spec, shape_name)
            param_specs = jax.tree.map(
                lambda ax: rules.spec(*tuple(ax)), paxes,
                is_leaf=lambda x: isinstance(x, tuple),
            )
        step = make_train_step(loss, make_optimizer(spec),
                               n_microbatches=sh.get("n_microbatches", 1),
                               param_specs=param_specs, mesh=mesh)
        return lambda state, inputs: step(state, inputs)

    if kind == "prefill":
        from repro.models import transformer as T

        return lambda params, inputs: T.prefill(
            params, inputs["tokens"], cfg, max_seq=sh["seq"]
        )
    if kind == "decode":
        from repro.models import transformer as T

        return lambda params, inputs: T.decode_step(
            params, inputs["cache"], inputs["tokens"], inputs["pos"], cfg
        )
    if kind == "serve":
        return lambda params, inputs: M.forward(params, inputs, cfg)
    if kind == "retrieval":
        from repro.models import recsys as R

        def retrieve(params, inputs):
            feats = {k: v for k, v in inputs.items() if k != "cand_ids"}
            u = R.user_embedding(params, feats, cfg)
            cand = R.embedding_lookup(params["tables"]["t0"], inputs["cand_ids"])
            return R.retrieval_score(params, u, cand, topk=sh.get("topk", 100))

        return retrieve
    raise ValueError(kind)


def _paper_representation(spec) -> str:
    """Document representation for the K-tree families ("dense" — the seed
    behaviour — or "sparse_medoid", paper §2's ELL layout). Configs carry it
    in their cfg dict; absent means dense."""
    cfg = spec.cfg
    if isinstance(cfg, Mapping):
        return cfg.get("representation", "dense")
    return "dense"


def _paper_inputs(spec, sh):
    """The paper's own workload on the production mesh: one distributed
    k-means/K-tree assignment step over the culled corpus matrix — documents
    sharded over data axes, centres over model (§Perf iteration: the
    replicated-centre baseline left the model axis idle; sharding the centre
    set 16-ways shards both N×K×D matmuls).

    Representation (cfg["representation"]):
    - dense: corpus stored bf16 on device (§Perf: casting f32→bf16 in-step
      *added* a copy; storing bf16 halves the dominant X-read bytes; centres
      and all accumulations stay f32);
    - sparse_medoid: the corpus arrives in ELL layout (values/cols padded to
      nnz_max) — HBM traffic ∝ sparse bytes, the paper's §1 point.
    """
    n, d, k = sh["n_docs"], sh["n_terms"], sh["k"]
    if _paper_representation(spec) == "sparse_medoid":
        nnz = sh.get("nnz_max", 128)
        specs = {
            "x_vals": SDS((n, nnz), f32),
            "x_cols": SDS((n, nnz), i32),
            "centers": SDS((k, d), f32),
        }
        axes = {
            "x_vals": ("batch", None),
            "x_cols": ("batch", None),
            "centers": ("centers_k", None),
        }
        return specs, axes
    specs = {"x": SDS((n, d), jnp.bfloat16), "centers": SDS((k, d), f32)}
    axes = {"x": ("batch", None), "centers": ("centers_k", None)}
    return specs, axes


def _cluster_step(_state, inputs):
    """One Lloyd step in the global view (GSPMD inserts the psum-equivalent
    all-reduce of the (sum, count) partials). bf16 distance/update matmuls
    with f32 accumulation (§Perf: halves the X bytes on the MXU path; centre
    updates stay f32)."""
    from repro.models.sharding import constrain

    x, c = inputs["x"], inputs["centers"]
    x16 = x if x.dtype == jnp.bfloat16 else x.astype(jnp.bfloat16)
    c16 = c.astype(jnp.bfloat16)
    cross = jax.lax.dot_general(
        x16, c16, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                       # [N, K] f32
    c_sq = jnp.einsum("kd,kd->k", c, c)
    dist = c_sq[None, :] - 2.0 * cross                      # ‖x‖² constant-dropped
    dist = constrain(dist, "batch", "centers_k")
    idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
    onehot = jax.nn.one_hot(idx, c.shape[0], dtype=jnp.bfloat16)
    sums = jax.lax.dot_general(
        onehot, x16, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                       # [K, D] f32
    counts = onehot.astype(jnp.float32).sum(axis=0)
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1e-12), c)
    new_c = constrain(new_c, "centers_k", None)
    # min-distance (for SSE) needs the dropped ‖x‖² back
    x_sq = jnp.einsum("nd,nd->n", x.astype(jnp.float32), x.astype(jnp.float32))
    sse = (jnp.take_along_axis(dist, idx[:, None], 1)[:, 0] + x_sq).sum()
    return new_c, sse


def _cluster_step_sparse(_state, inputs):
    """One Lloyd step over an ELL-laid-out corpus (sparse_medoid
    representation). Row blocks are densified into a bounded scratch and hit
    the MXU as plain matmuls — the ``ell_spmm`` kernel's densify-then-matmul
    pattern (DESIGN.md §3.4) expressed in XLA so GSPMD can shard it; the HBM
    resident corpus stays sparse."""
    vals, colids, c = inputs["x_vals"], inputs["x_cols"], inputs["centers"]
    n, nnz = vals.shape
    k, d = c.shape
    block = next((b for b in (4096, 2048, 1024, 512, 256, 128) if n % b == 0), n)
    c_sq = jnp.einsum("kd,kd->k", c, c)
    rows = jnp.arange(block, dtype=jnp.int32)[:, None]

    def body(carry, xb):
        sums, counts, sse = carry
        vb, cb = xb                                          # [block, nnz]
        xd = jnp.zeros((block, d), jnp.float32).at[
            jnp.broadcast_to(rows, cb.shape), cb
        ].add(vb)
        cross = jax.lax.dot_general(
            xd, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                    # [block, k]
        dist = c_sq[None, :] - 2.0 * cross                   # ‖x‖² constant-dropped
        idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
        onehot = jax.nn.one_hot(idx, k, dtype=jnp.float32)
        sums = sums + jax.lax.dot_general(
            onehot, xd, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        counts = counts + onehot.sum(axis=0)
        x_sq = jnp.einsum("bn,bn->b", vb, vb)                # exact on ELL padding
        sse = sse + (jnp.take_along_axis(dist, idx[:, None], 1)[:, 0] + x_sq).sum()
        return (sums, counts, sse), None

    init = (jnp.zeros((k, d), jnp.float32), jnp.zeros((k,), jnp.float32), jnp.float32(0.0))
    xs = (
        vals.reshape(n // block, block, nnz),
        colids.reshape(n // block, block, nnz),
    )
    (sums, counts, sse), _ = jax.lax.scan(body, init, xs)
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1e-12), c)
    return new_c, sse


def _static_fields(sh):
    return {k: v for k, v in sh.items() if k in ("n_graphs",)}


def _static_loss(params, batch, M, cfg, static):
    batch = dict(batch)
    batch.update(static)
    return M.loss_fn(params, batch, cfg)


def rules_for(spec: ArchSpec, shape_name: str, multi_pod: bool):
    from repro.models.sharding import make_rules

    over = dict(spec.rules_overrides.get("*", {}))
    over.update(spec.rules_overrides.get(shape_name, {}))
    return make_rules(multi_pod, **over)
