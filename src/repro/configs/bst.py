"""bst [recsys] — Behavior Sequence Transformer (Alibaba, arXiv:1905.06874):
embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256,
interaction=transformer over the user behaviour sequence.
Item vocab 10M (Taobao-scale) + 8 context fields of 100k."""
from repro.configs.registry import ArchSpec, register
from repro.models.recsys import RecsysConfig

CFG = RecsysConfig(
    name="bst", kind="bst", embed_dim=32,
    table_rows=(10_000_000,) + (100_000,) * 8,
    seq_len=20, n_heads=8, n_blocks=1, n_context=8,
    top_mlp=(1024, 512, 256),
)

SHAPES = {
    "train_batch":    {"kind": "train",     "batch": 65536},
    "serve_p99":      {"kind": "serve",     "batch": 512},
    "serve_bulk":     {"kind": "serve",     "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1, "n_candidates": 1_000_448}  # 1M padded to 512-divisible,
}

register(ArchSpec(name="bst", family="recsys", cfg=CFG, shapes=SHAPES))
