"""The paper's RCV1-subset experiment config: 193,844 docs, 103 industry
labels, top-8,000 terms (Figure 2)."""
from repro.configs.registry import ArchSpec, register
from repro.data.synth_corpus import RCV1_LIKE

CFG = {
    "corpus": RCV1_LIKE,
    "orders": (20, 35, 50, 80, 120),
    "sample_fraction": 0.1,
    "cluto_iters": 10,
    # document representation fed to the K-tree (repro.core.backend): RCV1
    # exercises the paper's §2 sparse/medoid extension — documents stay in
    # ELL layout and score via the ell_spmm path
    "representation": "sparse_medoid",
}

register(ArchSpec(
    name="ktree-rcv1", family="paper", cfg=CFG,
    shapes={
        # n_docs padded 193844 -> 194048 (512-divisible)
        # sparse_medoid representation: documents arrive as ELL (values, cols)
        # padded to nnz_max; ~80 tokens/doc ⇒ 128 covers the tail post-culling
        "cluster_assign": {"kind": "cluster", "n_docs": 194048, "n_terms": 8000,
                           "k": 1024, "nnz_max": 128},
    },
    notes="paper-reproduction config (benchmarks/paper_quality.py)",
))
