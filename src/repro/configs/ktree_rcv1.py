"""The paper's RCV1-subset experiment config: 193,844 docs, 103 industry
labels, top-8,000 terms (Figure 2)."""
from repro.configs.registry import ArchSpec, register
from repro.data.synth_corpus import RCV1_LIKE

CFG = {
    "corpus": RCV1_LIKE,
    "orders": (20, 35, 50, 80, 120),
    "sample_fraction": 0.1,
    "cluto_iters": 10,
}

register(ArchSpec(
    name="ktree-rcv1", family="paper", cfg=CFG,
    shapes={
        # n_docs padded 193844 -> 194048 (512-divisible)
        "cluster_assign": {"kind": "cluster", "n_docs": 194048, "n_terms": 8000, "k": 1024},
    },
    notes="paper-reproduction config (benchmarks/paper_quality.py)",
))
