"""Architecture configs (``--arch <id>``): the 10 assigned architectures with
their exact published dimensions + the paper's own K-tree experiment configs.
All access goes through repro.configs.registry."""
from repro.configs.registry import get, list_archs, ArchSpec

__all__ = ["get", "list_archs", "ArchSpec"]
