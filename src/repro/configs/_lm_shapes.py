"""The four LM input-shape cells shared by every LM architecture.

``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a KV cache),
NOT ``train_step``. ``long_500k`` is a *decode* shape: decode attention is
O(L) per token, so full-attention archs run it (DESIGN §4 — the quadratic
cost these archs would pay only affects prefill/train at 500k, which is not
lowered here).
"""
def lm_shapes(n_microbatches: int = 1):
    """n_microbatches = gradient-accumulation depth for train_4k — the
    standard activation-memory lever at these model sizes (one microbatch's
    activations live at a time; grads accumulate in fp32)."""
    return {
        "train_4k":    {"kind": "train",   "batch": 256, "seq": 4096,
                        "n_microbatches": n_microbatches},
        "prefill_32k": {"kind": "prefill", "batch": 32,  "seq": 32768},
        "decode_32k":  {"kind": "decode",  "batch": 128, "seq": 32768},
        "long_500k":   {"kind": "decode",  "batch": 1,   "seq": 524288},
    }


LM_SHAPES = lm_shapes()
