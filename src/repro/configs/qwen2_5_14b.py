"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA with QKV bias [hf:Qwen/Qwen2.5-14B; hf]."""
import jax.numpy as jnp
from repro.configs.registry import ArchSpec, register
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, qkv_bias=True, dtype=jnp.bfloat16,
)

register(ArchSpec(
    name="qwen2.5-14b", family="lm", cfg=CFG, shapes=lm_shapes(n_microbatches=4),
    optimizer="adamw",
    rules_overrides={
        # §Perf iteration 3: decode must not FSDP-shard weights — the
        # per-layer all-gather dominated the decode roofline (measured
        # 976 MiB/layer on qwen). Weights fit model-sharded for dense archs.
        # seq→None: the length-1 decode dim must not claim the model axis
        # (it starves act_ff/act_vocab and forces weight gathers — §Perf it.4)
        "decode_32k": {"fsdp": None, "seq": None},
        "long_500k": {"fsdp": None, "seq": None},
    },
    notes="GQA 40q/8kv heads, qkv bias; heads don't divide the 16-way model "
          "axis, so attention is context-parallel (seq over model).",
))
