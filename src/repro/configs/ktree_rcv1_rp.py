"""Random Indexing K-tree config (PAPERS.md, arxiv 1001.0833) on the RCV1
subset: documents stay sparse, the tree is built and routed in a 128-dim
seeded random projection, and answers are exact-rescored from the original
rows (DESIGN.md §5.1)."""
from repro.configs.registry import ArchSpec, register
from repro.data.synth_corpus import RCV1_LIKE

CFG = {
    "corpus": RCV1_LIKE,
    "orders": (20, 35, 50, 80, 120),
    "sample_fraction": 0.1,
    "cluto_iters": 10,
    # Random Indexing representation (repro.data.pipeline.corpus_backend):
    # ELL base corpus wrapped in a RandomProjBackend — build/descent run in
    # the rp_dim-dim projection, queries exact-rescore from the base rows
    "representation": "rp",
    "rp_dim": 128,
    "rp_seed": 0,
    "rp_kind": "gaussian",
}

register(ArchSpec(
    name="ktree-rcv1-rp", family="paper", cfg=CFG,
    shapes={
        # the cluster step runs entirely in the projected space, so the
        # abstract workload is the *dense* step at d = rp_dim (the whole
        # point of RI: descent FLOPs scale with 128, not 8000 terms);
        # n_docs padded 193844 -> 194048 (512-divisible) as in ktree-rcv1
        "cluster_assign": {"kind": "cluster", "n_docs": 194048,
                           "n_terms": 128, "k": 1024},
    },
    notes="Random Indexing K-tree (benchmarks/ri_recall.py)",
))
