"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1]."""
import jax.numpy as jnp
from repro.configs.registry import ArchSpec, register
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, moe=True, n_experts=8, top_k=2,
    dtype=jnp.bfloat16,
)

register(ArchSpec(
    name="grok-1-314b", family="lm", cfg=CFG, shapes=lm_shapes(n_microbatches=4),
    optimizer="adafactor",   # factored states: the 314B memory enabler
    rules_overrides={"*": {"expert": None},
                     "decode_32k": {"expert": None, "seq": None},
                     "long_500k": {"expert": None, "seq": None}},  # E=8 ∤ 16 → TP inside experts
    notes="8 experts don't divide the 16-way model axis: experts replicated "
          "across model, d_ff tensor-parallel instead (Mixtral-style TP). "
          "Adafactor (factored 2nd moment) for optimizer memory.",
))
