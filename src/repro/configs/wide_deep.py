"""wide-deep [recsys] — n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat [arXiv:1606.07792]. Table rows follow a realistic
power-law spread (2×10M, 4×1M, 14×100k, 20×10k ≈ 25.7M rows)."""
from repro.configs.registry import ArchSpec, register
from repro.models.recsys import RecsysConfig

ROWS = tuple(
    10_000_000 if i < 2 else
    1_000_000 if i < 6 else
    100_000 if i < 20 else
    10_000
    for i in range(40)
)

CFG = RecsysConfig(
    name="wide-deep", kind="wide_deep", embed_dim=32, table_rows=ROWS,
    top_mlp=(1024, 512, 256),
)

SHAPES = {
    "train_batch":    {"kind": "train",     "batch": 65536},
    "serve_p99":      {"kind": "serve",     "batch": 512},
    "serve_bulk":     {"kind": "serve",     "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1, "n_candidates": 1_000_448}  # 1M padded to 512-divisible,
}

register(ArchSpec(
    name="wide-deep", family="recsys", cfg=CFG, shapes=SHAPES, optimizer="adamw",
))
