"""dimenet [gnn] — n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6 [arXiv:2003.03123].

Four graph regimes (kernel_taxonomy §GNN — triplet-gather family):
- full_graph_sm: cora-scale full-batch (2,708 nodes / 10,556 edges / 1,433 feats)
- minibatch_lg:  reddit-scale neighbour-sampled batches (fanout 15-10 from
  1,024 seeds → padded 180k-node subgraph); the real sampler is
  repro.models.gnn.neighbour_sample
- ogb_products:  full-batch large (2.45M nodes / 61.9M edges / 100 feats)
- molecule:      128 batched small graphs (30 nodes / 64 edges each)

Non-molecular datasets have no 3-D coordinates: positions are a stub frontend
input, and triplets are capped per edge (sampled angular neighbours) — the
large-graph adaptation recorded in DESIGN §4.
"""
from repro.configs.registry import ArchSpec, register
from repro.models.gnn import DimeNetConfig

# feature-mode config used by the 3 graph datasets (d_feat varies per shape —
# we register 3 sub-variants internally but one public arch id)
CFG = DimeNetConfig(
    name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8,
    n_spherical=7, n_radial=6, d_feat=1433, n_classes=7,
)

SHAPES = {
    # kind=train for all: GNN cells exercise train_step (full-batch or sampled)
    # edge/triplet counts padded to 512 multiples (self-loop padding on a
    # dummy node) so the (data, model)-sharded edge arrays divide the mesh
    "full_graph_sm": {
        "kind": "train", "n_nodes": 2708, "n_edges": 10752,      # 10,556 real
        "n_triplets": 42496, "d_feat": 1433, "n_classes": 7,     # 42,224 real
    },
    "minibatch_lg": {
        "kind": "train", "n_nodes": 180224, "n_edges": 172032,
        "n_triplets": 3 * 172032, "d_feat": 602, "n_classes": 41,
        "fanout": (15, 10), "batch_nodes": 1024,
    },
    "ogb_products": {
        "kind": "train", "n_nodes": 2449408, "n_edges": 61859840,  # 2,449,029 / 61,859,140 real
        "n_triplets": 2 * 61859840, "d_feat": 100, "n_classes": 47,
    },
    "molecule": {
        "kind": "train", "n_nodes": 128 * 30, "n_edges": 128 * 64,
        "n_triplets": 6 * 128 * 64, "n_graphs": 128, "molecular": True,
    },
}

register(ArchSpec(
    name="dimenet", family="gnn", cfg=CFG, shapes=SHAPES,
    optimizer="adamw",
    rules_overrides={
        # large graphs shard node arrays over data too (activations dominate)
        "ogb_products": {"nodes": "data"},
        "minibatch_lg": {"nodes": "data"},
    },
    notes="K-tree technique inapplicable at model level (DESIGN §4); "
          "per-shape cfg overrides d_feat/n_classes (see registry.cfg_for_shape).",
))
