"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152; llama-arch code model [arXiv:2405.04324]."""
import jax.numpy as jnp
from repro.configs.registry import ArchSpec, register
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="granite-20b", n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, dtype=jnp.bfloat16,
)

register(ArchSpec(
    name="granite-20b", family="lm", cfg=CFG, shapes=lm_shapes(n_microbatches=4),
    optimizer="adamw",
    rules_overrides={
        # §Perf iteration 3: decode must not FSDP-shard weights — the
        # per-layer all-gather dominated the decode roofline (measured
        # 976 MiB/layer on qwen). Weights fit model-sharded for dense archs.
        # seq→None: the length-1 decode dim must not claim the model axis
        # (it starves act_ff/act_vocab and forces weight gathers — §Perf it.4)
        "decode_32k": {"fsdp": None, "seq": None},
        "long_500k": {"fsdp": None, "seq": None},
    },
    notes="MQA (kv=1): KV cache tiny; decode cache seq-shards over model.",
))
