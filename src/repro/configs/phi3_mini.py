"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064; RoPE SwiGLU [arXiv:2404.14219]."""
import jax.numpy as jnp
from repro.configs.registry import ArchSpec, register
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, dtype=jnp.bfloat16,
)

register(ArchSpec(
    name="phi3-mini-3.8b", family="lm", cfg=CFG, shapes=lm_shapes(n_microbatches=2),
    optimizer="adamw",
    rules_overrides={
        # §Perf iteration 3: decode must not FSDP-shard weights — the
        # per-layer all-gather dominated the decode roofline (measured
        # 976 MiB/layer on qwen). Weights fit model-sharded for dense archs.
        # seq→None: the length-1 decode dim must not claim the model axis
        # (it starves act_ff/act_vocab and forces weight gathers — §Perf it.4)
        "decode_32k": {"fsdp": None, "seq": None},
        "long_500k": {"fsdp": None, "seq": None},
    },
    notes="full MHA (kv=32): largest per-param KV cache of the dense trio.",
))
