"""dien [recsys] — Deep Interest Evolution Network (arXiv:1809.03672):
embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80 interaction=AUGRU.
Item vocab 1M, category vocab 10k, 2 user-profile context fields."""
from repro.configs.registry import ArchSpec, register
from repro.models.recsys import RecsysConfig

CFG = RecsysConfig(
    name="dien", kind="dien", embed_dim=18,
    table_rows=(1_000_000, 10_000, 50_000, 50_000),  # item, cat, 2×profile
    seq_len=100, gru_dim=108, n_context=2, top_mlp=(200, 80),
    # NOTE: GRU stays a scan (full unroll at batch 262k stalls XLA:CPU);
    # the roofline applies an analytic 100-step trip-count correction
    # (benchmarks/roofline.py::_dien_correction)
)

SHAPES = {
    "train_batch":    {"kind": "train",     "batch": 65536},
    "serve_p99":      {"kind": "serve",     "batch": 512},
    "serve_bulk":     {"kind": "serve",     "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1, "n_candidates": 1_000_448}  # 1M padded to 512-divisible,
}

register(ArchSpec(name="dien", family="recsys", cfg=CFG, shapes=SHAPES))
