"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 (fine-grained) [hf:databricks/dbrx-base]."""
import jax.numpy as jnp
from repro.configs.registry import ArchSpec, register
from repro.configs._lm_shapes import lm_shapes
from repro.models.transformer import LMConfig

CFG = LMConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, moe=True, n_experts=16, top_k=4,
    dtype=jnp.bfloat16,
)

register(ArchSpec(
    name="dbrx-132b", family="lm", cfg=CFG, shapes=lm_shapes(n_microbatches=4),
    optimizer="adafactor",
    rules_overrides={"decode_32k": {"seq": None}, "long_500k": {"seq": None}},
    notes="16 experts = 16-way expert parallelism over the model axis; "
          "dispatch all-to-alls priced in §Roofline.",
))
