"""Launch layer: production mesh builders, the multi-pod dry-run driver, and
train/serve entry points."""
