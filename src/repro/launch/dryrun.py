import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: prove every (architecture × input shape × mesh) cell
lowers, SPMD-partitions and compiles, and extract the roofline terms.

For each cell:
  1. FULL compile — jit(step).lower(state, inputs).compile() with the real
     shardings; memory_analysis() proves per-device fit, cost_analysis() gives
     HLO flops/bytes, and the post-SPMD HLO text gives collective bytes.
  2. LAYER PROBE (LM cells) — XLA's cost analysis counts a while-loop body
     once, so scanned-layer models are costed as
         total = full + (n_layers − 1) × probe(single layer)
     where the probe compiles exactly one block (fwd for serving, fwd+bwd with
     the production remat policy for training) under the same mesh/sharding
     rules, with the flash-attention KV scan unrolled. Verified against the
     analytic 6·N·D model-FLOPs in §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --out experiments/dryrun
Results are written incrementally as JSON, one file per cell.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_production_mesh
from repro.models.sharding import use_rules, named_sharding
from repro.train.loop import TrainState

# ---------------------------------------------------------------------------
# collective-bytes extraction from post-SPMD HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_DONE_RE = re.compile(r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)-done")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (result sizes of every
    collective op in the partitioned module; -start/-done pairs counted once)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if _DONE_RE.search(line):
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        out[m.group(2)] = out.get(m.group(2), 0) + _shape_bytes(m.group(1))
    return out


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns a per-program list
        ca = ca[0] if ca else {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = [
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ]
    return {k: float(getattr(ma, k)) for k in keys if hasattr(ma, k)}


# ---------------------------------------------------------------------------
# cell compilation
# ---------------------------------------------------------------------------

def _attach_shardings(abstract, axes, mesh, rules):
    def attach(a, ax):
        if not hasattr(a, "shape"):
            return a
        ns = named_sharding(mesh, rules, *tuple(ax))
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=ns)

    return jax.tree.map(
        attach, abstract, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def compile_cell(
    arch: str, shape: str, multi_pod: bool, mesh=None
) -> Tuple[Any, Dict[str, Any]]:
    """Lower + compile the full step for one cell. Returns (compiled, record)."""
    spec = registry.get(arch)
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    rules = registry.rules_for(spec, shape, multi_pod)
    t0 = time.perf_counter()
    with use_rules(rules, mesh):
        state, s_axes = registry.abstract_state(spec, shape)
        inputs, i_axes = registry.abstract_inputs(spec, shape)
        state = _attach_shardings(state, s_axes, mesh, rules)
        inputs = _attach_shardings(inputs, i_axes, mesh, rules)
        fn = registry.step_fn(spec, shape)
        kind = spec.shapes[shape]["kind"]
        # donate the train state: params/opt update in place (production setting;
        # without it the updated state doubles the resident param memory)
        donate = (0,) if kind == "train" else ()
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(state, inputs)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
    hlo = compiled.as_text()
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "kind": spec.shapes[shape]["kind"],
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost": cost_summary(compiled),
        "memory": memory_summary(compiled),
        "collectives_per_device_bytes": collective_bytes(hlo),
        "hlo_len": len(hlo),
    }
    return compiled, rec


def compile_lm_probe(
    arch: str, shape: str, multi_pod: bool, mesh=None
) -> Dict[str, Any]:
    """Single-layer cost probe for scanned LM cells (see module docstring)."""
    from repro.models import transformer as T

    spec = registry.get(arch)
    sh = spec.shapes[shape]
    kind = sh["kind"]
    cfg = dataclasses.replace(spec.cfg, flash_unroll=True)
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    rules = registry.rules_for(spec, shape, multi_pod)
    b = sh["batch"]
    if kind == "train":  # probes see one microbatch; total scales by n_mb
        b = b // sh.get("n_microbatches", 1)
    s = sh["seq"]
    d = cfg.d_model

    params_abs, p_axes = registry.abstract_params(spec)
    layer_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), params_abs["layers"]
    )
    layer_axes = jax.tree.map(
        lambda ax: tuple(ax)[1:], p_axes["layers"],
        is_leaf=lambda x: isinstance(x, tuple),
    )

    with use_rules(rules, mesh):
        layer_in = _attach_shardings(layer_abs, layer_axes, mesh, rules)
        if kind == "train":
            x_abs = jax.ShapeDtypeStruct(
                (b, s, d), cfg.dtype, sharding=named_sharding(mesh, rules, "batch", "seq", "act_embed")
            )
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))

            block = T._remat_wrap(lambda x, lp: T._block(x, lp, cfg, positions)[0], cfg)

            def probe(x, lp):
                # fwd+bwd of one layer, incl. remat recompute — grads wrt x
                # (dgrad) and lp (wgrad)
                return jax.grad(lambda x, lp: block(x, lp).astype(jnp.float32).sum(), argnums=(0, 1))(x, lp)

        elif kind == "prefill":
            x_abs = jax.ShapeDtypeStruct(
                (b, s, d), cfg.dtype, sharding=named_sharding(mesh, rules, "batch", "seq", "act_embed")
            )
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))

            def probe(x, lp):
                return T.block_prefill(x, lp, cfg, positions, max_seq=s)

        else:  # decode
            cax = T.cache_logical_axes(b)
            cache_shape = (b, s, cfg.n_kv_heads, cfg.hd)
            kc_abs = jax.ShapeDtypeStruct(
                cache_shape, cfg.dtype, sharding=named_sharding(mesh, rules, *cax[1:])
            )
            x_abs = jax.ShapeDtypeStruct(
                (b, 1, d), cfg.dtype, sharding=named_sharding(mesh, rules, None if b == 1 else "batch", None, None)
            )
            positions = jnp.zeros((b, 1), jnp.int32)

            def probe(x, lp, kc, vc):
                return T.block_decode(x, lp, kc, vc, jnp.int32(0), positions, cfg, cax)

        with mesh:
            if kind == "decode":
                compiled = jax.jit(probe).lower(x_abs, layer_in, kc_abs, kc_abs).compile()
            else:
                compiled = jax.jit(probe).lower(x_abs, layer_in).compile()

    rec = {
        "cost": cost_summary(compiled),
        "collectives_per_device_bytes": collective_bytes(compiled.as_text()),
        "probe_batch": b,
    }

    # boundary probe: embed gather (+its scatter-grad), final norm, LM head,
    # loss — everything outside the layer stack, at microbatch size
    with use_rules(rules, mesh):
        params_b = {
            "embed": jax.ShapeDtypeStruct(
                (cfg.vocab, d), cfg.dtype, sharding=named_sharding(mesh, rules, "vocab", "fsdp")
            ),
            "lm_head": jax.ShapeDtypeStruct(
                (d, cfg.vocab), cfg.dtype, sharding=named_sharding(mesh, rules, "fsdp", "vocab")
            ),
            "final_norm": jax.ShapeDtypeStruct(
                (d,), cfg.dtype, sharding=named_sharding(mesh, rules, None)
            ),
        }
        if kind == "train":
            from repro.models import layers as Lx
            from repro.models.sharding import constrain as _con

            tok_abs = jax.ShapeDtypeStruct(
                (b, s), jnp.int32,
                sharding=named_sharding(mesh, rules, None if b == 1 else "batch", "seq"),
            )
            x_mid = jax.ShapeDtypeStruct(
                (b, s, d), cfg.dtype,
                sharding=named_sharding(mesh, rules, "batch", "seq", "act_embed"),
            )

            def boundary(pb, x_mid, tokens, labels):
                x0 = jnp.take(pb["embed"], tokens, axis=0)
                x = Lx.rmsnorm(x_mid + x0, pb["final_norm"])
                logits = jnp.einsum("bsd,dv->bsv", x, pb["lm_head"])
                logits = _con(logits, "batch", "seq", "act_vocab")
                return Lx.softmax_xent(logits, labels)

            bfn = jax.grad(boundary, argnums=(0, 1))
            with mesh:
                compiled_b = jax.jit(bfn).lower(params_b, x_mid, tok_abs, tok_abs).compile()
            rec["boundary"] = {
                "cost": cost_summary(compiled_b),
                "collectives_per_device_bytes": collective_bytes(compiled_b.as_text()),
            }
    return rec


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Optional[str], mesh=None) -> Dict:
    spec = registry.get(arch)
    name = f"{arch}__{shape}__{'2x16x16' if multi_pod else '16x16'}"
    try:
        compiled, rec = compile_cell(arch, shape, multi_pod, mesh=mesh)
        print(f"[dryrun] {name}: compile ok "
              f"({rec['compile_s']}s, flops={rec['cost']['flops']:.3e})", flush=True)
        try:
            ma = compiled.memory_analysis()
            print(f"[dryrun]   memory_analysis: {rec['memory']}", flush=True)
        except Exception:
            pass
        del compiled
        if spec.family == "lm":
            probe = compile_lm_probe(arch, shape, multi_pod, mesh=mesh)
            rec["layer_probe"] = probe
            rec["n_layers"] = spec.cfg.n_layers
            print(f"[dryrun]   probe flops={probe['cost']['flops']:.3e}", flush=True)
        rec["status"] = "ok"
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec = {
            "arch": arch, "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "FAIL", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"[dryrun] {name}: FAIL {rec['error']}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def all_cells():
    for arch in registry.list_archs():
        spec = registry.get(arch)
        for shape in spec.shapes:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--families", default="lm,gnn,recsys,paper")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    fams = set(args.families.split(","))
    cells = (
        [(args.arch, args.shape)]
        if args.arch and args.shape
        else [
            (a, s) for a, s in all_cells()
            if registry.get(a).family in fams
        ]
    )
    results = []
    mesh_cache = {}
    for multi_pod in meshes:
        if multi_pod not in mesh_cache:
            mesh_cache[multi_pod] = make_production_mesh(multi_pod=multi_pod)
        for arch, shape in cells:
            name = f"{arch}__{shape}__{'2x16x16' if multi_pod else '16x16'}"
            path = os.path.join(args.out, name + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] {name}: cached, skipping", flush=True)
                continue
            results.append(run_cell(arch, shape, multi_pod, args.out, mesh=mesh_cache[multi_pod]))
    fails = [r for r in results if r.get("status") != "ok"]
    print(f"[dryrun] done: {len(results) - len(fails)} ok, {len(fails)} failed", flush=True)
    if fails:
        for r in fails:
            print("  FAIL:", r["arch"], r["shape"], r["mesh"], r["error"], flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
