"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Demonstrates the two serving paths end-to-end at reduced scale:
- LM: prefill a batch of prompts, then batched greedy decode with the KV cache.
- recsys retrieval: score a query against candidates brute-force and through
  the K-tree ANN index (the paper's NN-search-tree application) and report
  agreement + speed.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.train import reduced_cfg


def serve_lm(args):
    from repro.models import transformer as T

    spec = registry.get(args.arch)
    cfg = reduced_cfg(spec, args.scale)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    max_seq = args.prompt_len + args.gen_len

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg, max_seq=max_seq))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t0 = time.time()
    for i in range(args.gen_len - 1):
        logits, cache = decode(params, cache, toks, jnp.int32(args.prompt_len + i))
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen_len} tokens in {t_decode:.2f}s "
          f"({args.batch * args.gen_len / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample output ids:", np.asarray(gen[0, :16]))


def serve_retrieval(args):
    from repro.models import recsys as R
    from repro.core import ktree as kt

    spec = registry.get(args.arch)
    cfg = reduced_cfg(spec, args.scale)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    items = params["tables"]["t0"]                      # candidate embeddings
    n = items.shape[0]
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 0.3, (1, cfg.embed_dim)).astype(np.float32))

    t0 = time.time()
    scores, idx = R.retrieval_score(params, q, items, topk=10)
    jax.block_until_ready(scores)
    t_brute = time.time() - t0

    # K-tree ANN (paper's search tree): maximum inner product ≈ NN on the
    # unit sphere — normalise items for the index
    norm = items / jnp.maximum(jnp.linalg.norm(items, axis=1, keepdims=True), 1e-9)
    t0 = time.time()
    tree = kt.build(norm, order=32, batch_size=512)
    t_build = time.time() - t0
    qn = q / jnp.maximum(jnp.linalg.norm(q), 1e-9)
    t0 = time.time()
    doc, dist = kt.nn_search(tree, qn)
    t_ann = time.time() - t0
    in_topk = int(doc[0]) in set(np.asarray(idx[0]).tolist())
    print(f"brute-force top-10 in {t_brute*1e3:.1f}ms over {n} candidates; "
          f"K-tree build {t_build:.2f}s, ANN query {t_ann*1e3:.1f}ms, "
          f"ANN hit in brute top-10: {in_topk}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    spec = registry.get(args.arch)
    if spec.family == "lm":
        serve_lm(args)
    elif spec.family == "recsys":
        serve_retrieval(args)
    else:
        raise SystemExit("serving demo supports lm + recsys archs")


if __name__ == "__main__":
    main()
