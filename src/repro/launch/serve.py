"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Demonstrates the serving paths end-to-end at reduced scale:
- LM: prefill a batch of prompts, then batched greedy decode with the KV cache.
- recsys retrieval: score a query against candidates brute-force and through
  the K-tree ANN index (the paper's NN-search-tree application) and report
  agreement + speed.
- paper (``--arch ktree-inex`` / ``ktree-rcv1``): the K-tree itself as the
  serving system — build **or restore** the index from a checkpoint
  (``--ckpt``, via ``ckpt.save_ktree``/``restore_ktree``), then answer
  batched top-k queries with the beam-search engine (DESIGN.md §7) and
  report QPS + recall@k vs brute force.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.train import reduced_cfg


def serve_lm(args):
    from repro.models import transformer as T

    spec = registry.get(args.arch)
    cfg = reduced_cfg(spec, args.scale)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    max_seq = args.prompt_len + args.gen_len

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg, max_seq=max_seq))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t0 = time.perf_counter()
    for i in range(args.gen_len - 1):
        logits, cache = decode(params, cache, toks, jnp.int32(args.prompt_len + i))
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen_len} tokens in {t_decode:.2f}s "
          f"({args.batch * args.gen_len / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample output ids:", np.asarray(gen[0, :16]))


def serve_retrieval(args):
    from repro.models import recsys as R
    from repro.core import ktree as kt

    spec = registry.get(args.arch)
    cfg = reduced_cfg(spec, args.scale)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    items = params["tables"]["t0"]                      # candidate embeddings
    n = items.shape[0]
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 0.3, (1, cfg.embed_dim)).astype(np.float32))

    t0 = time.perf_counter()
    scores, idx = R.retrieval_score(params, q, items, topk=10)
    jax.block_until_ready(scores)
    t_brute = time.perf_counter() - t0

    # K-tree ANN (paper's search tree): maximum inner product ≈ NN on the
    # unit sphere — normalise items for the index
    norm = items / jnp.maximum(jnp.linalg.norm(items, axis=1, keepdims=True), 1e-9)
    t0 = time.perf_counter()
    tree = kt.build(norm, order=32, batch_size=512)
    t_build = time.perf_counter() - t0
    qn = q / jnp.maximum(jnp.linalg.norm(q), 1e-9)
    t0 = time.perf_counter()
    doc, dist = kt.nn_search(tree, qn)
    t_ann = time.perf_counter() - t0
    in_topk = int(doc[0]) in set(np.asarray(idx[0]).tolist())
    print(f"brute-force top-10 in {t_brute*1e3:.1f}ms over {n} candidates; "
          f"K-tree build {t_build:.2f}s, ANN query {t_ann*1e3:.1f}ms, "
          f"ANN hit in brute top-10: {in_topk}")


def serve_paper_store(args):
    """Out-of-core K-tree serving (DESIGN.md §9): corpus in an on-disk block
    store (``--store DIR``) with ``--budget-mb`` of block-cache residency;
    the index streams in block-by-block (``build_from_store``) or restores by
    manifest reference (``--ckpt`` → ``save_index``/``restore_index``), and
    queries are answered straight from the store — the full corpus is never
    resident. ``--mesh N`` serves shard-parallel with per-shard block caches
    (``--budget-mb`` split evenly across the shards); ``--prefetch D`` moves
    the sequential disk scans (streaming build, single-device queries, the
    ground-truth block sweep) onto an async reader thread of that depth —
    sharded queries fetch candidates on demand and are unaffected.
    ``--engine`` composes with all of it: the store-backed (and sharded)
    search fn is handed to the continuous-batching ``ServingEngine``
    (DESIGN.md §8.1), whose per-batch report includes peak store residency
    from the shards' block caches.

    ``--autotune`` (DESIGN.md §11) measures-and-picks the three overlap
    knobs — query ``pipeline``, store ``prefetch``, ``chunk`` — for this
    (store layout, budget, backend) tuple, caching the decision in the
    store's ``TUNE.json`` sidecar; without the flag a valid sidecar entry is
    still consumed. Explicit ``--prefetch`` always wins, and tuned answers
    are bit-identical to the untuned ones (checked against the depth-1
    synchronous baseline every ``--autotune`` run)."""
    from repro.core import ktree as kt
    from repro.core.autotune import (
        autotune_store_search, load_tuned, sidecar_path,
    )
    from repro.core.engine import make_search_fn
    from repro.core.query import (
        AnswerCache, brute_force_topk_stream, recall_at_k, topk_search_cached,
    )
    from repro.ckpt import restore_index, save_index
    from repro.core.store import open_store
    from repro.data.pipeline import corpus_store
    from repro.data.synth_corpus import scaled

    spec = registry.get(args.arch)
    rep = spec.cfg.get("representation", "dense")
    rp_dim = _rp_dim_for(args, spec)
    corpus_spec = scaled(spec.cfg["corpus"], n_docs=args.n_docs, culled=args.culled)
    budget = max(int(args.budget_mb * 1024 * 1024), 1)
    projection = None

    if args.ckpt and os.path.isdir(args.ckpt):
        # restore by manifest reference: the checkpoint names the store it
        # was built over (and its content hash) — serve that one, don't
        # touch/describe the --store path it may or may not equal. An RP
        # index also records its projection spec; restore_index replays the
        # matrix bit-exactly from the stored seed (3-tuple return)
        t0 = time.perf_counter()
        out = restore_index(args.ckpt, budget_bytes=budget)
        tree, store = out[0], out[1]
        projection = out[2] if len(out) == 3 else None
        if rp_dim and (projection is None or projection.out_dim != rp_dim
                       or projection.seed != args.rp_seed):
            rec = projection.spec() if projection is not None else None
            raise SystemExit(
                f"index {args.ckpt} records projection {rec} but this serve "
                f"run expects rp_dim={rp_dim} seed={args.rp_seed}; match "
                "--rp-dim/--rp-seed to the checkpoint or rebuild"
            )
        print(f"restored store-backed index from {args.ckpt} in "
              f"{time.perf_counter()-t0:.2f}s (depth={int(tree.depth)}, "
              f"nodes={int(tree.n_nodes)}, store {store.path}: "
              f"{store.n_docs} docs, {store.n_blocks} blocks × "
              f"{store.block_docs}, budget {budget/1e6:.1f}MB"
              + (f", projection seed={projection.seed} "
                 f"{projection.in_dim}→{projection.out_dim}"
                 if projection is not None else "") + ")")
    else:
        t0 = time.perf_counter()
        corpus_store(corpus_spec, args.store, representation=rep,
                     block_docs=args.block_docs)
        store = open_store(args.store, budget_bytes=budget)
        print(f"store {args.store}: {store.n_docs} docs, {store.n_blocks} "
              f"blocks × {store.block_docs} docs ({store.nbytes/1e6:.1f}MB "
              f"on disk, budget {budget/1e6:.1f}MB) in {time.perf_counter()-t0:.2f}s")
        if rp_dim:
            from repro.core.backend import make_projection

            projection = make_projection(store.dim, rp_dim, seed=args.rp_seed)
        t0 = time.perf_counter()
        tree = kt.build_from_store(
            store, order=args.order,
            medoid=rep == "sparse_medoid" and projection is None,
            batch_size=256, prefetch=args.prefetch, projection=projection,
            # a prior sidecar decision feeds the build's prefetch when
            # --prefetch isn't explicit (build reads sequentially too)
            tuned=load_tuned(store, budget_bytes=budget,
                             backend=_backend_tag(projection)),
        )
        print(f"streaming-built K-tree over {store.n_docs} docs in "
              f"{time.perf_counter()-t0:.2f}s (depth={int(tree.depth)}, "
              f"nodes={int(tree.n_nodes)}, "
              f"cache: {store.cache.stats['evictions']} evictions, "
              f"resident {store.cache.resident_bytes/1e6:.1f}MB)")
        if args.ckpt:
            print(f"saved index by manifest reference to "
                  f"{save_index(args.ckpt, tree, store, projection=projection)}")

    nq = min(args.queries, store.n_docs)
    q_view = store.view(0, nq)
    on_fault = None if args.on_fault == "raise" else args.on_fault
    # cache keys + ground truth share these query rows; degrade mode
    # zero-fills rows whose block is quarantined instead of failing
    x_q = make_dense_rows(store, nq, on_fault=on_fault or "raise")
    if on_fault and args.cache:
        raise SystemExit(
            "--on-fault degrade does not compose with --cache (degraded "
            "answers must not be cached); drop one of the two"
        )
    if on_fault and projection is not None:
        raise SystemExit(
            "--on-fault degrade does not compose with random-projection "
            "routing (--rp-dim): the exact-rescore stage needs every "
            "candidate row readable; drop one of the two"
        )
    backend_tag = _backend_tag(projection)
    rp_kw = dict(rp=projection, rp_corpus=store if projection is not None
                 else None)
    if args.autotune:
        t0 = time.perf_counter()
        tuned = autotune_store_search(
            tree, store, k=args.k, beam=args.beam, budget_bytes=budget,
            backend=backend_tag, n_queries=nq, **rp_kw,
        )
        src = f"measured in {time.perf_counter() - t0:.2f}s"
    else:
        tuned = load_tuned(store, budget_bytes=budget, backend=backend_tag)
        src = "from sidecar"
    if tuned is not None:
        print(f"autotune: pipeline={tuned.pipeline} "
              f"prefetch={tuned.prefetch} chunk={tuned.chunk} "
              f"({tuned.qps:.0f} QPS vs depth-1 baseline "
              f"{tuned.baseline_qps:.0f}, read∩compute "
              f"{tuned.overlap_frac:.0%}; {src}, "
              f"sidecar {sidecar_path(store)})")
    if args.mesh > 1:
        # store-backed sharded serving: the corpus stays on disk — each mesh
        # shard fetches only the candidates it owns through its own block
        # cache (--budget-mb split evenly across the shards)
        from repro.core.backend import shard_from_store
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh)
        sshards = shard_from_store(
            mesh, store, budget_bytes=max(budget // args.mesh, 1)
        )
        mode = f"sharded×{args.mesh}"
        search_fn = make_search_fn(
            tree, mesh=mesh, corpus=sshards, on_fault=on_fault, rp=projection,
            prefetch=args.prefetch, tuned=tuned,
        )
        block_caches = [p.store.cache for p in sshards.parts]
    else:
        sshards = None
        mode = "single-device"
        search_fn = make_search_fn(
            tree, prefetch=args.prefetch, on_fault=on_fault,
            rp=projection, rp_corpus=store, tuned=tuned,
        )
        block_caches = [store.cache]
    if projection is not None:
        mode += f", rp{projection.out_dim}"
    run = lambda src: search_fn(src, args.k, args.beam)
    run(q_view)  # warm the jit cache
    if args.engine:
        return serve_engine_mode(
            args, search_fn, x_q, tree, mode=f"{mode}, out-of-core",
            corpus_token=store.manifest_hash, block_caches=block_caches,
        )
    if args.cache:
        # miss batches are dense rows (content hashing addresses raw bytes),
        # so the miss engine is the dense-row engine — warm it *outside* the
        # timed loop, or its first-compile cost lands in the QPS report
        run(x_q)
        cache = AnswerCache(args.cache)
        t0 = time.perf_counter()
        for _ in range(2):  # pass 1 cold-fills, pass 2 replays (hit path)
            docs, _ = topk_search_cached(
                tree, x_q, cache, k=args.k, beam=args.beam,
                search_fn=run, corpus_token=store.manifest_hash,
            )
        qps = 2 * nq / max(time.perf_counter() - t0, 1e-9)
        s = cache.stats
        print(f"cache: hits={s['hits']} misses={s['misses']} "
              f"hit_rate={s['hit_rate']:.2f} size={s['size']}/{s['capacity']}")
    else:
        t0 = time.perf_counter()
        out = run(q_view)
        qps = nq / max(time.perf_counter() - t0, 1e-9)
        docs = out[0]
        if len(out) == 3 and out[2].degraded:
            rep = out[2]
            print(f"DEGRADED answers: quarantined blocks "
                  f"{list(rep.quarantined_blocks)}, "
                  f"{len(rep.dropped_query_rows)} query rows dropped")
        if tuned is not None and on_fault is None:
            # knobs only reschedule work — pin it by re-answering with the
            # depth-1 synchronous schedule and explicit default chunking
            from repro.core.query import topk_search

            b_docs, b_dist = topk_search(
                tree, q_view, k=args.k, beam=args.beam,
                chunk=512, pipeline=1, prefetch=0, **rp_kw,
            )
            ok = bool(np.array_equal(np.asarray(docs), b_docs)
                      and np.array_equal(np.asarray(out[1]), b_dist))
            print("tuned answers vs depth-1 sync baseline: "
                  + ("bit-identical" if ok else "MISMATCH"))
            if not ok:
                raise SystemExit(
                    "tuned knobs changed answers — depths must never "
                    "change numerics"
                )

    cs = store.cache.stats
    print(f"store cache: hit_rate={cs['hit_rate']:.2f} "
          f"evictions={cs['evictions']} resident={cs['resident_bytes']/1e6:.1f}"
          f"/{cs['budget_bytes']/1e6:.1f}MB")
    if cs["read_retries"] or cs["verify_failures"] or cs["quarantined"]:
        print(f"store robustness: read_retries={cs['read_retries']} "
              f"read_errors={cs['read_errors']} "
              f"verify_failures={cs['verify_failures']} "
              f"quarantined={cs['quarantined']}")
    if sshards is not None:
        for s, st in enumerate(sshards.cache_stats):
            print(f"shard {s} cache: hit_rate={st['hit_rate']:.2f} "
                  f"misses={st['misses']} evictions={st['evictions']} "
                  f"resident={st['resident_bytes']/1e6:.2f}"
                  f"/{st['budget_bytes']/1e6:.2f}MB")
        print(f"peak store residency across shards: "
              f"{sshards.peak_resident_bytes/1e6:.2f}MB "
              f"(bound {args.mesh}×{max(budget // args.mesh, 1)/1e6:.2f}MB "
              f"+ one-block floors)")
    # ground truth streams block-by-block off the store (never fully
    # resident); degrade mode skips quarantined/excised blocks, so the
    # reference covers exactly the corpus the degraded index can answer from
    gt_prefetch = (
        args.prefetch if args.prefetch is not None
        else (tuned.prefetch if tuned is not None else 0)
    )
    true = brute_force_topk_stream(
        x_q,
        _dense_store_blocks(store, prefetch=gt_prefetch,
                            on_fault=on_fault or "raise"),
        args.k,
    )
    recall = recall_at_k(docs, true)
    print(f"{nq} queries: beam={args.beam} k={args.k} "
          f"recall@{args.k}={recall:.3f} {qps:.0f} QPS "
          f"({store.kind} store, out-of-core, {mode})")


def serve_engine_mode(args, search_fn, x_q, tree, mode,
                      corpus_token=None, block_caches=()):
    """``--engine``: continuous-batching service mode (DESIGN.md §8).

    Instead of replaying the query file as one closed batch, requests are
    generated **open-loop** at ``--rate`` arrivals/s (Poisson gaps, seeded)
    and admitted into a ``core.engine.ServingEngine`` — bounded queue
    (``--max-queue``, overload sheds instead of queueing unboundedly),
    dynamic batches up to ``--row-budget`` rows dispatched on fill or the
    oldest request's deadline forcing point (``--max-wait-ms`` /
    ``--deadline-ms``), optional ``--cache`` answer-cache pre-stage. The
    report is p50/p95/p99 latency + QPS + shed/occupancy/queue-depth — and a
    bit-identity check of one served request against the offline engine."""
    from repro.core.engine import ServingEngine
    from repro.core.query import AnswerCache
    from repro.launch.engine import report_lines, request_pool, run_load

    cache = AnswerCache(args.cache) if args.cache else None
    xw = np.asarray(x_q)
    pool = request_pool(
        xw, n_requests=args.requests,
        rows_per_request=args.rows_per_req, k=args.k, beam=args.beam,
    )
    # warm the chunk-aligned shapes dynamic batches hit: the engine pads each
    # request to its pow2 bucket and chunks fragments at the bucket, with the
    # fragment's chunk count also pow2-padded — so the compile ladder is
    # (bucket × pow2 chunk counts). First compiles land here, not in the
    # latency percentiles
    from repro.core.engine import pow2_bucket

    bucket = pow2_bucket(args.rows_per_req)
    cap = pow2_bucket(args.row_budget)

    def _warm(s, chunk_rows):
        reps = -(-s // xw.shape[0])  # ceil
        search_fn(np.tile(xw, (reps, 1))[:s], args.k, args.beam,
                  chunk_rows=chunk_rows)

    s = bucket
    while True:
        _warm(s, bucket)
        if s >= 2 * cap:
            break
        s *= 2
    if cache is not None:
        # cache miss batches run at single-row chunking (per-row-stable
        # answers); warm its pow2 miss-count ladder too
        m = 1
        while m <= cap:
            _warm(m, 1)
            m *= 2
    deadline = args.deadline_ms / 1e3 if args.deadline_ms else None
    print(f"engine serving ({mode}): rate={args.rate:.0f}/s "
          f"requests={args.requests} rows/req={args.rows_per_req} "
          f"row_budget={args.row_budget} max_queue={args.max_queue} "
          f"max_wait={args.max_wait_ms}ms"
          + (f" deadline={args.deadline_ms}ms" if deadline else ""))
    timeout = (args.request_timeout_ms / 1e3
               if args.request_timeout_ms else None)
    with ServingEngine(
        search_fn, row_budget=args.row_budget, max_queue=args.max_queue,
        max_wait_s=args.max_wait_ms / 1e3, request_timeout_s=timeout,
        cache=cache, tree=tree,
        corpus_token=corpus_token, block_caches=block_caches,
    ) as eng:
        stats = run_load(eng, pool, rate_qps=args.rate, deadline_s=deadline)
        rows, k, beam = pool[0]
        d_eng, s_eng = eng.submit(rows, k=k, beam=beam).result(timeout=120)
    if cache is None:
        out_off = search_fn(rows, k, beam)
        d_off, s_off = out_off[0], out_off[1]
    else:
        # cache entries are per-row answers (computed at single-row
        # chunking), so the offline reference is the per-row standalone calls
        parts = [search_fn(rows[i:i + 1], k, beam)
                 for i in range(rows.shape[0])]
        d_off = np.concatenate([np.asarray(p[0]) for p in parts])
        s_off = np.concatenate([np.asarray(p[1]) for p in parts])
    ok = bool((np.asarray(d_eng) == np.asarray(d_off)).all()
              and (np.asarray(s_eng) == np.asarray(s_off)).all())
    for line in report_lines(stats):
        print(line)
    print("engine answers vs offline engine: "
          + ("bit-identical" if ok else "MISMATCH"))
    if not ok:
        raise SystemExit("engine answers diverged from the offline engine")


def _backend_tag(projection) -> str:
    """The backend half of a ``core.autotune.tune_key``: ``"exact"`` for
    direct routing, ``"rp<out_dim>"`` for random-projection routing (the RP
    route's extra rescore stage can want different depths)."""
    return "exact" if projection is None else f"rp{projection.out_dim}"


def _rp_dim_for(args, spec) -> int:
    """Effective random-projection dim for this serve run: ``--rp-dim`` wins,
    else an arch whose representation is ``"rp"`` supplies its cfg default
    (``ktree-rcv1-rp``); 0 = exact routing (no projection)."""
    if args.rp_dim:
        return int(args.rp_dim)
    if spec.cfg.get("representation") == "rp":
        return int(spec.cfg.get("rp_dim", 128))
    return 0


def make_dense_rows(store, nq: int, on_fault: str = "raise") -> np.ndarray:
    """Densify the first ``nq`` store rows host-side (cache keys hash dense
    row bytes; ground truth needs dense queries). ``on_fault="degrade"``
    gathers through ``take_rows_masked`` — rows whose block is
    quarantined/excised come back as zero vectors instead of failing the
    whole serve run (DESIGN.md §10)."""
    from repro.core.backend import backend_from_store

    if on_fault != "degrade":
        be = backend_from_store(store, np.arange(nq))
        return np.asarray(be.take(jnp.arange(nq, dtype=jnp.int32)))
    got, _ = store.take_rows_masked(np.arange(nq))
    if store.kind == "dense":
        return np.asarray(got["x"], np.float32)
    v, c = got["values"], got["cols"]
    x = np.zeros((nq, store.dim), np.float32)
    # masked rows are zero-filled (values 0 → the scatter adds nothing)
    np.add.at(x, (np.arange(nq)[:, None], c), v)
    return x


def _dense_store_blocks(store, prefetch: int = 0, on_fault: str = "raise"):
    """Yield ``(row_offset, dense rows)`` per store block for
    ``brute_force_topk_stream`` — dense blocks as-is, ELL blocks densified by
    a host-side numpy scatter-add (padding slots are value 0, so they add
    nothing). One block resident at a time; ``prefetch ≥ 1`` reads the next
    block on an async reader thread while the current one is scored.
    ``on_fault="degrade"`` skips quarantined/excised blocks (the degraded
    ground-truth scan)."""
    for lo, hi, arrays in store.iter_blocks(prefetch=prefetch,
                                            on_fault=on_fault):
        if store.kind == "dense":
            yield lo, arrays["x"][: hi - lo].astype(np.float32)
        else:
            v, c = arrays["values"][: hi - lo], arrays["cols"][: hi - lo]
            xb = np.zeros((hi - lo, store.dim), np.float32)
            np.add.at(xb, (np.arange(hi - lo)[:, None], c), v)
            yield lo, xb


def _dense_backend_blocks(backend, n_docs: int, block: int = 16384):
    """Yield ``(row_offset, dense rows)`` per backend row block for
    ``brute_force_topk_stream`` — the in-memory counterpart of
    :func:`_dense_store_blocks`. Block size matches ``brute_force_topk``'s
    ``doc_block`` default, so the streamed ground truth merges at the same
    boundaries (shared ``_merge_topk`` step → identical ids); only one
    densified block is host-resident at a time instead of the whole corpus."""
    import jax.numpy as jnp

    for lo in range(0, n_docs, block):
        rows = jnp.arange(lo, min(lo + block, n_docs), dtype=jnp.int32)
        yield lo, np.asarray(backend.take(rows).astype(jnp.float32))


def serve_paper(args):
    """K-tree retrieval serving: build-or-restore the index, answer batched
    top-k beam-search queries (single-device, or shard-parallel with
    ``--mesh N``, optionally through an LRU answer cache with ``--cache C``),
    report recall@k vs brute force and QPS. ``--store DIR`` switches to the
    out-of-core path (:func:`serve_paper_store`)."""
    from repro.core import ktree as kt
    from repro.core.engine import make_search_fn
    from repro.core.query import (
        AnswerCache, brute_force_topk_stream, recall_at_k, topk_search_cached,
    )
    from repro.ckpt import restore_ktree, save_ktree
    from repro.data.pipeline import corpus_backend
    from repro.data.synth_corpus import scaled

    if args.store:
        return serve_paper_store(args)

    spec = registry.get(args.arch)
    rep = spec.cfg.get("representation", "dense")
    rp_dim = _rp_dim_for(args, spec)
    corpus_spec = scaled(spec.cfg["corpus"], n_docs=args.n_docs, culled=args.culled)
    base_rep = "sparse_medoid" if rep == "rp" else rep
    backend, _ = corpus_backend(corpus_spec, representation=base_rep)
    projection = None
    if rp_dim:
        # Random Indexing routing (DESIGN.md §5.1): build/route in the
        # projection, exact-rescore answers from the original backend rows
        from repro.core.backend import make_projection

        projection = make_projection(backend.dim, rp_dim, seed=args.rp_seed)
        rep = f"rp{rp_dim}/{base_rep}"
    medoid = base_rep == "sparse_medoid" and projection is None

    ckpt_file = (
        args.ckpt if not args.ckpt or args.ckpt.endswith(".npz")
        else args.ckpt + ".npz"
    )
    if ckpt_file and os.path.exists(ckpt_file):
        from repro.ckpt import load_ktree_projection

        t0 = time.perf_counter()
        tree = restore_ktree(args.ckpt)
        recorded = load_ktree_projection(args.ckpt)
        if not args.rp_dim and projection is None and recorded is not None:
            projection = recorded  # serve with the checkpointed projection
        if projection is not None or recorded is not None:
            # projection is part of the index identity: routing a tree built
            # under one projection with a different matrix (or none) silently
            # degrades every query — refuse, like a rewritten corpus
            exp = projection.spec() if projection is not None else None
            rec = recorded.spec() if recorded is not None else None
            if exp != rec:
                raise SystemExit(
                    f"checkpoint {ckpt_file} records projection {rec} but "
                    f"this serve run expects {exp}; match --rp-dim/--rp-seed "
                    "to the checkpoint or rebuild with a fresh --ckpt path"
                )
            projection = recorded  # replayed bit-exactly from the stored seed
        # guard against serving an index built over a different corpus: doc
        # ids in the tree must address rows of *this* corpus
        max_doc = max(
            (int(np.asarray(tree.child[leaf, : int(tree.n_entries[leaf])]).max())
             for leaf in kt.leaf_nodes(tree)), default=-1,
        )
        want_dim = projection.out_dim if projection is not None else backend.dim
        if tree.dim != want_dim or max_doc >= corpus_spec.n_docs:
            raise SystemExit(
                f"checkpoint {ckpt_file} does not match this corpus "
                f"(tree dim={tree.dim} max doc id={max_doc} vs expected "
                f"dim={want_dim} n_docs={corpus_spec.n_docs}); "
                "rebuild with a fresh --ckpt path or matching --n-docs/--culled"
            )
        print(f"restored K-tree from {ckpt_file} in {time.perf_counter()-t0:.2f}s "
              f"(depth={int(tree.depth)}, nodes={int(tree.n_nodes)}"
              + (f", projection seed={projection.seed} "
                 f"{projection.in_dim}→{projection.out_dim}"
                 if projection is not None else "") + ")")
    else:
        from repro.core.backend import RandomProjBackend

        build_be = (
            backend if projection is None
            else RandomProjBackend.wrap(backend, projection)
        )
        t0 = time.perf_counter()
        tree = kt.build(build_be, order=args.order, medoid=medoid, batch_size=256)
        print(f"built K-tree over {args.n_docs} docs in {time.perf_counter()-t0:.2f}s "
              f"(depth={int(tree.depth)}, nodes={int(tree.n_nodes)})")
        if args.ckpt:
            print(f"saved index to "
                  f"{save_ktree(args.ckpt, tree, projection=projection)}")

    # batched queries: corpus documents queried back against the index
    nq = min(args.queries, corpus_spec.n_docs)
    rows = jnp.arange(nq, dtype=jnp.int32)
    x_q = np.asarray(backend.take(rows))

    if args.mesh > 1:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh)
        shards = backend.shard(mesh)  # rows placed across shards once
        mode = f"sharded×{args.mesh}"
        search_fn = make_search_fn(tree, mesh=mesh, corpus=shards,
                                   rp=projection)
    else:
        mode = "single-device"
        search_fn = make_search_fn(tree, rp=projection, rp_corpus=backend)

    def run(xq):
        return search_fn(xq, args.k, args.beam)

    run(x_q)  # warm the jit cache
    if args.engine:
        return serve_engine_mode(args, search_fn, x_q, tree, mode=mode)
    if args.cache:
        # timed section answers the stream twice: pass 1 cold-fills the LRU,
        # pass 2 replays it — the hit path the report's hit_rate measures
        cache = AnswerCache(args.cache)
        t0 = time.perf_counter()
        docs, _ = topk_search_cached(
            tree, x_q, cache, k=args.k, beam=args.beam, search_fn=run
        )
        docs, _ = topk_search_cached(
            tree, x_q, cache, k=args.k, beam=args.beam, search_fn=run
        )
        qps = 2 * nq / max(time.perf_counter() - t0, 1e-9)
        s = cache.stats
        print(f"cache: hits={s['hits']} misses={s['misses']} "
              f"hit_rate={s['hit_rate']:.2f} size={s['size']}/{s['capacity']}")
    else:
        t0 = time.perf_counter()
        docs, _ = run(x_q)
        qps = nq / max(time.perf_counter() - t0, 1e-9)

    # brute-force ground truth on the query slice (exact squared distances),
    # streamed block-wise off the backend — densifying the whole corpus in one
    # take() defeated the blocked brute force for sparse/large corpora
    true = brute_force_topk_stream(
        x_q, _dense_backend_blocks(backend, corpus_spec.n_docs), args.k
    )
    recall = recall_at_k(docs, true)
    print(f"{nq} queries: beam={args.beam} k={args.k} "
          f"recall@{args.k}={recall:.3f} {qps:.0f} QPS ({rep} backend, {mode})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    # --- paper (K-tree) serving mode ---
    ap.add_argument("--ckpt", default="", help="K-tree index checkpoint path: "
                    "restore if present, else build and save here")
    ap.add_argument("--n-docs", type=int, default=2000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--rp-dim", type=int, default=0,
                    help="Random Indexing routing (DESIGN.md §5.1): build and "
                    "descend the K-tree in an N-dim seeded random projection, "
                    "exact-rescoring answers from the original rows; 0 = "
                    "exact routing (default; archs with representation='rp' "
                    "fall back to their cfg rp_dim). Composes with --mesh/"
                    "--store/--cache/--engine; not with --on-fault degrade")
    ap.add_argument("--rp-seed", type=int, default=0,
                    help="projection seed for --rp-dim — the whole index "
                    "replays from it (checkpoints persist spec, not matrix)")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--mesh", type=int, default=0, help="shard-parallel query "
                    "serving over N devices (topk_search_sharded); 0/1 = "
                    "single device. Composes with --store: the corpus stays "
                    "on disk behind per-shard block caches")
    ap.add_argument("--cache", type=int, default=0, help="LRU answer-cache "
                    "capacity (0 = off); the timed stream runs twice so the "
                    "report shows the hit path")
    ap.add_argument("--store", default="", help="out-of-core mode: corpus "
                    "block-store directory (written on first run, reused "
                    "after); builds stream from disk and queries fetch "
                    "blocks on demand (DESIGN.md §9). With --ckpt the index "
                    "checkpoints by manifest reference (save_index)")
    ap.add_argument("--budget-mb", type=float, default=64.0,
                    help="block-cache residency budget for --store, in MB "
                    "(with --mesh N: split evenly into N per-shard caches)")
    ap.add_argument("--block-docs", type=int, default=1024,
                    help="rows per store block (the disk I/O granule)")
    ap.add_argument("--prefetch", type=int, default=None,
                    help="async block-prefetch depth for --store (reader "
                    "thread ahead of the sequential disk scans: streaming "
                    "build, single-device + store-sourced sharded queries, "
                    "ground truth). Default: the store's TUNE.json decision "
                    "if present, else 0 (synchronous); an explicit value "
                    "always wins over --autotune")
    ap.add_argument("--autotune", action="store_true",
                    help="measure-and-pick (pipeline, prefetch, chunk) for "
                    "this (store layout, --budget-mb, backend) before "
                    "serving (DESIGN.md §11); the decision is cached in the "
                    "store's TUNE.json sidecar (invalidated when the "
                    "manifest hash rotates) and answers are checked "
                    "bit-identical to the depth-1 synchronous baseline")
    # --- continuous-batching engine mode (DESIGN.md §8) ---
    ap.add_argument("--engine", action="store_true",
                    help="serve through the continuous-batching engine: "
                    "open-loop request arrivals at --rate, bounded admission "
                    "queue, dynamic batches, p50/p95/p99 latency report. "
                    "Composes with --mesh/--cache/--store")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="open-loop arrival rate, requests/s (--engine)")
    ap.add_argument("--requests", type=int, default=512,
                    help="number of generated requests (--engine)")
    ap.add_argument("--rows-per-req", type=int, default=1,
                    help="query rows per generated request (--engine)")
    ap.add_argument("--row-budget", type=int, default=256,
                    help="max query rows per dispatched batch (--engine)")
    ap.add_argument("--max-queue", type=int, default=128,
                    help="admission-queue bound in requests; a full queue "
                    "sheds instead of growing (--engine)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="batch-formation wait cap, ms (--engine)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request completion deadline, ms; 0 = none. "
                    "The batcher dispatches no later than the oldest "
                    "request's deadline forcing point (--engine)")
    # --- robustness (DESIGN.md §10) ---
    ap.add_argument("--fsck", action="store_true",
                    help="verify the --store directory offline (digest-check "
                    "every block against the manifest) and exit: status 0 "
                    "clean, 1 damaged. With --fsck-repair, excise damaged "
                    "blocks and rewrite the manifest first")
    ap.add_argument("--fsck-repair", action="store_true",
                    help="with --fsck: excise damaged blocks (tombstone the "
                    "manifest entries, move files aside as <name>.damaged) "
                    "so the surviving rows serve degraded")
    ap.add_argument("--on-fault", choices=("raise", "degrade"),
                    default="raise",
                    help="store-read fault policy for --store serving: "
                    "'raise' fails the batch with a typed store error; "
                    "'degrade' drops only the quarantined blocks' rows and "
                    "flags the answers (DESIGN.md §10)")
    ap.add_argument("--request-timeout-ms", type=float, default=0.0,
                    help="engine-wide per-request time budget, ms; 0 = none. "
                    "The engine watchdog fails overdue requests with "
                    "EngineTimeout so no caller can hang (--engine)")
    args = ap.parse_args()
    if args.fsck:
        from repro.core.fsck import fsck_store, repair_store

        if not args.store:
            raise SystemExit("--fsck needs --store DIR")
        report = (repair_store(args.store) if args.fsck_repair
                  else fsck_store(args.store))
        for line in report.lines():
            print(line)
        raise SystemExit(0 if (report.clean or report.repaired) else 1)
    spec = registry.get(args.arch)
    if spec.family == "lm":
        serve_lm(args)
    elif spec.family == "recsys":
        serve_retrieval(args)
    elif spec.family == "paper":
        serve_paper(args)
    else:
        raise SystemExit("serving demo supports lm + recsys + paper archs")


if __name__ == "__main__":
    main()
