"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Demonstrates the serving paths end-to-end at reduced scale:
- LM: prefill a batch of prompts, then batched greedy decode with the KV cache.
- recsys retrieval: score a query against candidates brute-force and through
  the K-tree ANN index (the paper's NN-search-tree application) and report
  agreement + speed.
- paper (``--arch ktree-inex`` / ``ktree-rcv1``): the K-tree itself as the
  serving system — build **or restore** the index from a checkpoint
  (``--ckpt``, via ``ckpt.save_ktree``/``restore_ktree``), then answer
  batched top-k queries with the beam-search engine (DESIGN.md §7) and
  report QPS + recall@k vs brute force.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch.train import reduced_cfg


def serve_lm(args):
    from repro.models import transformer as T

    spec = registry.get(args.arch)
    cfg = reduced_cfg(spec, args.scale)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)))
    max_seq = args.prompt_len + args.gen_len

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg, max_seq=max_seq))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t0 = time.time()
    for i in range(args.gen_len - 1):
        logits, cache = decode(params, cache, toks, jnp.int32(args.prompt_len + i))
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen_len} tokens in {t_decode:.2f}s "
          f"({args.batch * args.gen_len / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample output ids:", np.asarray(gen[0, :16]))


def serve_retrieval(args):
    from repro.models import recsys as R
    from repro.core import ktree as kt

    spec = registry.get(args.arch)
    cfg = reduced_cfg(spec, args.scale)
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    items = params["tables"]["t0"]                      # candidate embeddings
    n = items.shape[0]
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 0.3, (1, cfg.embed_dim)).astype(np.float32))

    t0 = time.time()
    scores, idx = R.retrieval_score(params, q, items, topk=10)
    jax.block_until_ready(scores)
    t_brute = time.time() - t0

    # K-tree ANN (paper's search tree): maximum inner product ≈ NN on the
    # unit sphere — normalise items for the index
    norm = items / jnp.maximum(jnp.linalg.norm(items, axis=1, keepdims=True), 1e-9)
    t0 = time.time()
    tree = kt.build(norm, order=32, batch_size=512)
    t_build = time.time() - t0
    qn = q / jnp.maximum(jnp.linalg.norm(q), 1e-9)
    t0 = time.time()
    doc, dist = kt.nn_search(tree, qn)
    t_ann = time.time() - t0
    in_topk = int(doc[0]) in set(np.asarray(idx[0]).tolist())
    print(f"brute-force top-10 in {t_brute*1e3:.1f}ms over {n} candidates; "
          f"K-tree build {t_build:.2f}s, ANN query {t_ann*1e3:.1f}ms, "
          f"ANN hit in brute top-10: {in_topk}")


def serve_paper_store(args):
    """Out-of-core K-tree serving (DESIGN.md §9): corpus in an on-disk block
    store (``--store DIR``) with ``--budget-mb`` of block-cache residency;
    the index streams in block-by-block (``build_from_store``) or restores by
    manifest reference (``--ckpt`` → ``save_index``/``restore_index``), and
    queries are answered straight from the store — the full corpus is never
    resident. ``--mesh N`` serves shard-parallel with per-shard block caches
    (``--budget-mb`` split evenly across the shards); ``--prefetch D`` moves
    the sequential disk scans (streaming build, single-device queries, the
    ground-truth block sweep) onto an async reader thread of that depth —
    sharded queries fetch candidates on demand and are unaffected."""
    from repro.core import ktree as kt
    from repro.core.query import (
        AnswerCache, brute_force_topk_stream, recall_at_k, topk_search,
        topk_search_cached, topk_search_sharded,
    )
    from repro.ckpt import restore_index, save_index
    from repro.core.store import open_store
    from repro.data.pipeline import corpus_store
    from repro.data.synth_corpus import scaled

    spec = registry.get(args.arch)
    rep = spec.cfg.get("representation", "dense")
    corpus_spec = scaled(spec.cfg["corpus"], n_docs=args.n_docs, culled=args.culled)
    budget = max(int(args.budget_mb * 1024 * 1024), 1)

    if args.ckpt and os.path.isdir(args.ckpt):
        # restore by manifest reference: the checkpoint names the store it
        # was built over (and its content hash) — serve that one, don't
        # touch/describe the --store path it may or may not equal
        t0 = time.time()
        tree, store = restore_index(args.ckpt, budget_bytes=budget)
        print(f"restored store-backed index from {args.ckpt} in "
              f"{time.time()-t0:.2f}s (depth={int(tree.depth)}, "
              f"nodes={int(tree.n_nodes)}, store {store.path}: "
              f"{store.n_docs} docs, {store.n_blocks} blocks × "
              f"{store.block_docs}, budget {budget/1e6:.1f}MB)")
    else:
        t0 = time.time()
        corpus_store(corpus_spec, args.store, representation=rep,
                     block_docs=args.block_docs)
        store = open_store(args.store, budget_bytes=budget)
        print(f"store {args.store}: {store.n_docs} docs, {store.n_blocks} "
              f"blocks × {store.block_docs} docs ({store.nbytes/1e6:.1f}MB "
              f"on disk, budget {budget/1e6:.1f}MB) in {time.time()-t0:.2f}s")
        t0 = time.time()
        tree = kt.build_from_store(
            store, order=args.order, medoid=rep == "sparse_medoid",
            batch_size=256, prefetch=args.prefetch,
        )
        print(f"streaming-built K-tree over {store.n_docs} docs in "
              f"{time.time()-t0:.2f}s (depth={int(tree.depth)}, "
              f"nodes={int(tree.n_nodes)}, "
              f"cache: {store.cache.stats['evictions']} evictions, "
              f"resident {store.cache.resident_bytes/1e6:.1f}MB)")
        if args.ckpt:
            print(f"saved index by manifest reference to "
                  f"{save_index(args.ckpt, tree, store)}")

    nq = min(args.queries, store.n_docs)
    q_view = store.view(0, nq)
    x_q = make_dense_rows(store, nq)  # cache keys + ground truth share these
    if args.mesh > 1:
        # store-backed sharded serving: the corpus stays on disk — each mesh
        # shard fetches only the candidates it owns through its own block
        # cache (--budget-mb split evenly across the shards)
        from repro.core.backend import shard_from_store
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh)
        sshards = shard_from_store(
            mesh, store, budget_bytes=max(budget // args.mesh, 1)
        )
        mode = f"sharded×{args.mesh}"
        run = lambda src: topk_search_sharded(
            mesh, tree, src, corpus=sshards, k=args.k, beam=args.beam
        )
    else:
        sshards = None
        mode = "single-device"
        run = lambda src: topk_search(
            tree, src, k=args.k, beam=args.beam, prefetch=args.prefetch
        )
    run(q_view)  # warm the jit cache
    if args.cache:
        # miss batches are dense rows (content hashing addresses raw bytes),
        # so the miss engine is the dense-row engine — warm it *outside* the
        # timed loop, or its first-compile cost lands in the QPS report
        run(x_q)
        cache = AnswerCache(args.cache)
        t0 = time.time()
        for _ in range(2):  # pass 1 cold-fills, pass 2 replays (hit path)
            docs, _ = topk_search_cached(
                tree, x_q, cache, k=args.k, beam=args.beam,
                search_fn=run, corpus_token=store.manifest_hash,
            )
        qps = 2 * nq / max(time.time() - t0, 1e-9)
        s = cache.stats
        print(f"cache: hits={s['hits']} misses={s['misses']} "
              f"hit_rate={s['hit_rate']:.2f} size={s['size']}/{s['capacity']}")
    else:
        t0 = time.time()
        docs, _ = run(q_view)
        qps = nq / max(time.time() - t0, 1e-9)

    cs = store.cache.stats
    print(f"store cache: hit_rate={cs['hit_rate']:.2f} "
          f"evictions={cs['evictions']} resident={cs['resident_bytes']/1e6:.1f}"
          f"/{cs['budget_bytes']/1e6:.1f}MB")
    if sshards is not None:
        for s, st in enumerate(sshards.cache_stats):
            print(f"shard {s} cache: hit_rate={st['hit_rate']:.2f} "
                  f"misses={st['misses']} evictions={st['evictions']} "
                  f"resident={st['resident_bytes']/1e6:.2f}"
                  f"/{st['budget_bytes']/1e6:.2f}MB")
        print(f"peak store residency across shards: "
              f"{sshards.peak_resident_bytes/1e6:.2f}MB "
              f"(bound {args.mesh}×{max(budget // args.mesh, 1)/1e6:.2f}MB "
              f"+ one-block floors)")
    # ground truth streams block-by-block off the store (never fully resident)
    true = brute_force_topk_stream(
        x_q, _dense_store_blocks(store, prefetch=args.prefetch), args.k
    )
    recall = recall_at_k(docs, true)
    print(f"{nq} queries: beam={args.beam} k={args.k} "
          f"recall@{args.k}={recall:.3f} {qps:.0f} QPS "
          f"({store.kind} store, out-of-core, {mode})")


def make_dense_rows(store, nq: int) -> np.ndarray:
    """Densify the first ``nq`` store rows host-side (cache keys hash dense
    row bytes; ground truth needs dense queries)."""
    from repro.core.backend import backend_from_store

    be = backend_from_store(store, np.arange(nq))
    return np.asarray(be.take(jnp.arange(nq, dtype=jnp.int32)))


def _dense_store_blocks(store, prefetch: int = 0):
    """Yield ``(row_offset, dense rows)`` per store block for
    ``brute_force_topk_stream`` — dense blocks as-is, ELL blocks densified by
    a host-side numpy scatter-add (padding slots are value 0, so they add
    nothing). One block resident at a time; ``prefetch ≥ 1`` reads the next
    block on an async reader thread while the current one is scored."""
    for lo, hi, arrays in store.iter_blocks(prefetch=prefetch):
        if store.kind == "dense":
            yield lo, arrays["x"][: hi - lo].astype(np.float32)
        else:
            v, c = arrays["values"][: hi - lo], arrays["cols"][: hi - lo]
            xb = np.zeros((hi - lo, store.dim), np.float32)
            np.add.at(xb, (np.arange(hi - lo)[:, None], c), v)
            yield lo, xb


def serve_paper(args):
    """K-tree retrieval serving: build-or-restore the index, answer batched
    top-k beam-search queries (single-device, or shard-parallel with
    ``--mesh N``, optionally through an LRU answer cache with ``--cache C``),
    report recall@k vs brute force and QPS. ``--store DIR`` switches to the
    out-of-core path (:func:`serve_paper_store`)."""
    from repro.core import ktree as kt
    from repro.core.query import (
        AnswerCache, brute_force_topk, recall_at_k, topk_search,
        topk_search_cached, topk_search_sharded,
    )
    from repro.ckpt import restore_ktree, save_ktree
    from repro.data.pipeline import corpus_backend
    from repro.data.synth_corpus import scaled

    if args.store:
        return serve_paper_store(args)

    spec = registry.get(args.arch)
    rep = spec.cfg.get("representation", "dense")
    corpus_spec = scaled(spec.cfg["corpus"], n_docs=args.n_docs, culled=args.culled)
    backend, _ = corpus_backend(corpus_spec, representation=rep)
    medoid = rep == "sparse_medoid"

    ckpt_file = (
        args.ckpt if not args.ckpt or args.ckpt.endswith(".npz")
        else args.ckpt + ".npz"
    )
    if ckpt_file and os.path.exists(ckpt_file):
        t0 = time.time()
        tree = restore_ktree(args.ckpt)
        # guard against serving an index built over a different corpus: doc
        # ids in the tree must address rows of *this* corpus
        max_doc = max(
            (int(np.asarray(tree.child[leaf, : int(tree.n_entries[leaf])]).max())
             for leaf in kt.leaf_nodes(tree)), default=-1,
        )
        if tree.dim != backend.dim or max_doc >= corpus_spec.n_docs:
            raise SystemExit(
                f"checkpoint {ckpt_file} does not match this corpus "
                f"(tree dim={tree.dim} max doc id={max_doc} vs corpus "
                f"dim={backend.dim} n_docs={corpus_spec.n_docs}); "
                "rebuild with a fresh --ckpt path or matching --n-docs/--culled"
            )
        print(f"restored K-tree from {ckpt_file} in {time.time()-t0:.2f}s "
              f"(depth={int(tree.depth)}, nodes={int(tree.n_nodes)})")
    else:
        t0 = time.time()
        tree = kt.build(backend, order=args.order, medoid=medoid, batch_size=256)
        print(f"built K-tree over {args.n_docs} docs in {time.time()-t0:.2f}s "
              f"(depth={int(tree.depth)}, nodes={int(tree.n_nodes)})")
        if args.ckpt:
            print(f"saved index to {save_ktree(args.ckpt, tree)}")

    # batched queries: corpus documents queried back against the index
    nq = min(args.queries, corpus_spec.n_docs)
    rows = jnp.arange(nq, dtype=jnp.int32)
    x_q = np.asarray(backend.take(rows))

    if args.mesh > 1:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.mesh)
        shards = backend.shard(mesh)  # rows placed across shards once
        mode = f"sharded×{args.mesh}"

        def run(xq):
            return topk_search_sharded(
                mesh, tree, xq, corpus=shards, k=args.k, beam=args.beam
            )
    else:
        mode = "single-device"

        def run(xq):
            return topk_search(tree, xq, k=args.k, beam=args.beam)

    run(x_q)  # warm the jit cache
    if args.cache:
        # timed section answers the stream twice: pass 1 cold-fills the LRU,
        # pass 2 replays it — the hit path the report's hit_rate measures
        cache = AnswerCache(args.cache)
        t0 = time.time()
        docs, _ = topk_search_cached(
            tree, x_q, cache, k=args.k, beam=args.beam, search_fn=run
        )
        docs, _ = topk_search_cached(
            tree, x_q, cache, k=args.k, beam=args.beam, search_fn=run
        )
        qps = 2 * nq / max(time.time() - t0, 1e-9)
        s = cache.stats
        print(f"cache: hits={s['hits']} misses={s['misses']} "
              f"hit_rate={s['hit_rate']:.2f} size={s['size']}/{s['capacity']}")
    else:
        t0 = time.time()
        docs, _ = run(x_q)
        qps = nq / max(time.time() - t0, 1e-9)

    # brute-force ground truth on the query slice (exact squared distances)
    x_all = np.asarray(backend.take(jnp.arange(corpus_spec.n_docs, dtype=jnp.int32)))
    recall = recall_at_k(docs, brute_force_topk(x_q, x_all, args.k))
    print(f"{nq} queries: beam={args.beam} k={args.k} "
          f"recall@{args.k}={recall:.3f} {qps:.0f} QPS ({rep} backend, {mode})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    # --- paper (K-tree) serving mode ---
    ap.add_argument("--ckpt", default="", help="K-tree index checkpoint path: "
                    "restore if present, else build and save here")
    ap.add_argument("--n-docs", type=int, default=2000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--mesh", type=int, default=0, help="shard-parallel query "
                    "serving over N devices (topk_search_sharded); 0/1 = "
                    "single device. Composes with --store: the corpus stays "
                    "on disk behind per-shard block caches")
    ap.add_argument("--cache", type=int, default=0, help="LRU answer-cache "
                    "capacity (0 = off); the timed stream runs twice so the "
                    "report shows the hit path")
    ap.add_argument("--store", default="", help="out-of-core mode: corpus "
                    "block-store directory (written on first run, reused "
                    "after); builds stream from disk and queries fetch "
                    "blocks on demand (DESIGN.md §9). With --ckpt the index "
                    "checkpoints by manifest reference (save_index)")
    ap.add_argument("--budget-mb", type=float, default=64.0,
                    help="block-cache residency budget for --store, in MB "
                    "(with --mesh N: split evenly into N per-shard caches)")
    ap.add_argument("--block-docs", type=int, default=1024,
                    help="rows per store block (the disk I/O granule)")
    ap.add_argument("--prefetch", type=int, default=0,
                    help="async block-prefetch depth for --store (reader "
                    "thread ahead of the sequential disk scans: streaming "
                    "build, single-device queries, ground truth; 0 = "
                    "synchronous). Sharded queries (--mesh) fetch candidates "
                    "on demand per chunk and are unaffected")
    args = ap.parse_args()
    spec = registry.get(args.arch)
    if spec.family == "lm":
        serve_lm(args)
    elif spec.family == "recsys":
        serve_retrieval(args)
    elif spec.family == "paper":
        serve_paper(args)
    else:
        raise SystemExit("serving demo supports lm + recsys + paper archs")


if __name__ == "__main__":
    main()
