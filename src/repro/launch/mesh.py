"""Mesh builders. Functions (not module constants) so importing never touches
jax device state — the dry-run must set XLA_FLAGS before first jax init."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (data, model); multi-pod adds a leading pod
    axis: 2×16×16 = 512 chips. The pod axis composes with data for batch/FSDP
    sharding; crossing it prices DCI, staying inside prices ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (CPU) devices the test process has."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_serving_mesh(n_shards: int):
    """1-D data mesh for shard-parallel query serving (`--mesh N` in
    launch/serve.py): the corpus rows shard over ``data``, the tree
    replicates. Needs ≥ n_shards visible devices — on CPU force them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax init."""
    n_dev = len(jax.devices())
    if n_dev < n_shards:
        raise SystemExit(
            f"serving mesh wants {n_shards} shards but only {n_dev} device(s) "
            "are visible; set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n_shards} (CPU) or run on a {n_shards}-chip slice"
        )
    return jax.make_mesh((n_shards,), ("data",))
