"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (CPU-feasible) training run for any registered architecture at a
--scale-reduced size, with the full production machinery: sharded data
pipeline, microbatching, checkpoints every N steps, resume-from-latest, and
the K-tree corpus-clustering hook (paper §5 collection selection) for LM runs.

On a real fleet the same entry point runs under `jax.distributed.initialize`
with the production mesh; here the mesh defaults to all local devices.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.train.loop import init_state, make_train_step, train_loop, TrainState
from repro import ckpt as ckpt_lib


def reduced_cfg(spec, scale: float):
    """Shrink a config for local runs (layers/width/tables divided)."""
    cfg = spec.cfg
    if spec.family == "lm":
        return dataclasses.replace(
            cfg,
            n_layers=max(2, int(cfg.n_layers * scale)),
            d_model=max(64, int(cfg.d_model * scale) // 16 * 16),
            n_heads=max(4, int(cfg.n_heads * scale)),
            n_kv_heads=max(1, min(cfg.n_kv_heads, max(4, int(cfg.n_heads * scale)))),
            d_ff=max(128, int(cfg.d_ff * scale) // 16 * 16),
            vocab=max(256, int(cfg.vocab * scale) // 128 * 128),
            dtype=jnp.float32,
        )
    if spec.family == "gnn":
        return dataclasses.replace(cfg, d_hidden=max(16, int(cfg.d_hidden * scale)),
                                   n_blocks=max(1, int(cfg.n_blocks * scale * 3)))
    # recsys
    return dataclasses.replace(
        cfg, table_rows=tuple(min(r, 5000) for r in cfg.table_rows)
    )


def synth_lm_batch(step, cfg, batch=8, seq=128, seed=0):
    rng = np.random.default_rng((seed, step))
    toks = rng.integers(0, cfg.vocab, (batch, seq + 1))
    return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    spec = registry.get(args.arch)
    if spec.family != "lm":
        raise SystemExit("local training demo currently targets the LM family; "
                         "GNN/recsys train via their smoke tests + dry-run")
    cfg = reduced_cfg(spec, args.scale)
    from repro.models import transformer as T

    opt = registry.make_optimizer(spec)
    loss = lambda p, b: T.loss_fn(p, b, cfg)
    step_fn = jax.jit(make_train_step(loss, opt))
    state = init_state(jax.random.PRNGKey(0), lambda k: T.init_params(k, cfg), opt)
    if args.resume and args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir):
        restored = ckpt_lib.restore(args.ckpt_dir, state.as_dict())
        state = TrainState(restored["params"], restored["opt"], jnp.asarray(restored["step"]))
        print(f"resumed from step {int(state.step)}")

    def on_metrics(step, m):
        print(f"step {step:5d} loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f}", flush=True)

    state, dt = train_loop(
        state, step_fn, lambda s: synth_lm_batch(s, cfg, args.batch, args.seq),
        n_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        log_every=5, on_metrics=on_metrics,
    )
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
