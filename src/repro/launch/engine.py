"""Open-loop load generation + reporting for the continuous-batching engine
(DESIGN.md §8; served by ``launch/serve.py --engine``).

Closed-loop replay (send a batch, wait, send the next) can never show
overload — the client self-throttles to whatever the server sustains. An
**open-loop** generator schedules arrivals on its own clock (Poisson
inter-arrival gaps at a target rate, seeded → reproducible) and submits each
request at its scheduled instant regardless of how the previous ones are
doing; when the engine saturates, the bounded queue sheds and the report
shows it, instead of the latency silently absorbing the backlog. This is the
standard serving-benchmark arrival model (sglang-style benchmark pipelines)
and what ``benchmarks/serving.py`` sweeps across rates.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import EngineSaturated, ResultHandle, ServingEngine


def open_loop_arrivals(
    rate_qps: float, n_requests: int, seed: int = 0,
) -> np.ndarray:
    """Relative arrival offsets (seconds, ascending, len ``n_requests``) for a
    Poisson process at ``rate_qps`` — exponential inter-arrival gaps from a
    seeded rng, so a sweep is reproducible request-for-request."""
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be > 0, got {rate_qps}")
    if n_requests < 1:
        raise ValueError(f"n_requests must be ≥ 1, got {n_requests}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_qps, n_requests)
    gaps[0] = 0.0  # first request fires at t0
    return np.cumsum(gaps)


def run_load(
    engine: ServingEngine,
    requests: Sequence[Tuple[np.ndarray, int, int]],
    rate_qps: float,
    deadline_s: Optional[float] = None,
    seed: int = 0,
    clock: Callable[[], float] = time.perf_counter,
    sleep: Callable[[float], None] = time.sleep,
) -> dict:
    """Drive ``engine`` with an open-loop arrival process and return the
    merged report.

    ``requests``: the request pool as ``(rows, k, beam)`` tuples, submitted
    in order at :func:`open_loop_arrivals` instants (monotonic ``clock``;
    ``sleep`` is a seam for tests). Sheds (:class:`EngineSaturated`) are
    counted and skipped — open loop means the next arrival stays on
    schedule. Failed handles (``EngineTimeout``/``EngineFault``/typed store
    errors) are drained, not re-raised — the engine's ``failed``/``timeouts``
    counters already report them, and a chaos run's load report must survive
    its injected faults. Returns the engine's :meth:`ServingEngine.stats`
    snapshot plus load-side fields: ``offered_qps`` (requests / offered
    span), ``target_qps``; ``completed`` handles' answers are *not* retained
    — use :func:`submit_all` when the caller needs them."""
    handles, stats = submit_all(
        engine, requests, rate_qps, deadline_s=deadline_s, seed=seed,
        clock=clock, sleep=sleep,
    )
    for h in handles:
        if h is not None:
            try:
                h.result()
            except Exception:
                pass  # resolved-with-error: counted in the engine's stats
    out = engine.stats()
    out.update(stats)
    return out


def submit_all(
    engine: ServingEngine,
    requests: Sequence[Tuple[np.ndarray, int, int]],
    rate_qps: float,
    deadline_s: Optional[float] = None,
    seed: int = 0,
    clock: Callable[[], float] = time.perf_counter,
    sleep: Callable[[float], None] = time.sleep,
) -> Tuple[List[Optional[ResultHandle]], dict]:
    """Open-loop submission pass: returns ``(handles, load_stats)`` where
    ``handles[i]`` is request *i*'s :class:`ResultHandle` or ``None`` if it
    was shed at admission. ``load_stats`` carries ``target_qps`` and the
    achieved ``offered_qps`` (arrival schedule pressure, not completion
    throughput — the engine's own stats report that)."""
    offsets = open_loop_arrivals(rate_qps, len(requests), seed=seed)
    handles: List[Optional[ResultHandle]] = []
    t0 = clock()
    for (rows, k, beam), dt in zip(requests, offsets):
        lag = (t0 + dt) - clock()
        if lag > 0:
            sleep(lag)
        try:
            handles.append(
                engine.submit(rows, k=k, beam=beam, deadline_s=deadline_s)
            )
        except EngineSaturated:
            handles.append(None)
    span = max(clock() - t0, 1e-9)
    return handles, dict(
        target_qps=float(rate_qps),
        offered_qps=len(requests) / span,
    )


def request_pool(
    x: np.ndarray, n_requests: int, rows_per_request: int = 1,
    k: int = 10, beam: int = 4, seed: int = 0,
) -> List[Tuple[np.ndarray, int, int]]:
    """Build a request pool by sampling row groups from a query matrix:
    ``n_requests`` tuples of (``rows_per_request`` rows drawn with a seeded
    rng, k, beam). Repeated draws are likely on small pools — which is the
    point when an :class:`repro.core.query.AnswerCache` is staged."""
    if rows_per_request < 1:
        raise ValueError(
            f"rows_per_request must be ≥ 1, got {rows_per_request}"
        )
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, max(x.shape[0] - rows_per_request + 1, 1),
                          n_requests)
    return [
        (np.ascontiguousarray(x[s:s + rows_per_request]), k, beam)
        for s in starts
    ]


def report_lines(stats: dict, label: str = "engine") -> List[str]:
    """Human-readable serving report (one string per line) from a
    :func:`run_load` / :meth:`ServingEngine.stats` dict — the lines
    ``serve.py --engine`` prints and CI greps."""
    lat = stats.get("latency_ms", {})
    lines = [
        f"{label}: {stats['completed']} completed / {stats['admitted']} "
        f"admitted, shed={stats['shed']} "
        f"deadline_misses={stats['deadline_misses']}",
        f"{label} latency: p50={lat.get('p50', 0.0):.2f}ms "
        f"p95={lat.get('p95', 0.0):.2f}ms p99={lat.get('p99', 0.0):.2f}ms "
        f"qps={stats.get('qps', 0.0):.0f}"
        + (f" (offered {stats['offered_qps']:.0f}/s"
           f" target {stats['target_qps']:.0f}/s)"
           if "offered_qps" in stats else ""),
        f"{label} batching: {stats['n_batches']} batches "
        f"({stats['n_fragments']} fragments), "
        f"occupancy={stats['batch_occupancy']:.2f}, "
        f"max_queue_depth={stats['max_queue_depth']}",
    ]
    if (stats.get("failed") or stats.get("timeouts")
            or stats.get("watchdog_restarts") or stats.get("degraded")):
        lines.append(
            f"{label} robustness: failed={stats.get('failed', 0)} "
            f"timeouts={stats.get('timeouts', 0)} "
            f"watchdog_restarts={stats.get('watchdog_restarts', 0)} "
            f"degraded={stats.get('degraded', 0)}"
        )
    if stats.get("peak_batch_store_bytes"):
        lines.append(
            f"{label} store: peak per-batch residency "
            f"{stats['peak_batch_store_bytes'] / 1e6:.2f}MB"
        )
    if "cache" in stats:
        c = stats["cache"]
        lines.append(
            f"{label} cache: hits={c['hits']} misses={c['misses']} "
            f"hit_rate={c['hit_rate']:.2f} size={c['size']}/{c['capacity']}"
        )
    return lines
