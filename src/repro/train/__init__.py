"""Training runtime: optimizers (AdamW, factored Adafactor for the ≥100B
configs), the train-step factory (microbatch accumulation, remat, optional
int8 error-feedback gradient compression), and the training loop with
checkpoint/restart fault tolerance."""
from repro.train.optim import adamw, adafactor, Optimizer
from repro.train.loop import make_train_step, TrainState

__all__ = ["adamw", "adafactor", "Optimizer", "make_train_step", "TrainState"]
