"""Train-step factory + fault-tolerant training loop.

Distributed-optimization features (DESIGN §5):
- microbatch gradient accumulation (scan) for activation memory,
- optional int8 error-feedback gradient compression on the DP all-reduce,
- donated state (params update in place),
- deterministic, restartable stepping (checkpoint/resume handled by
  repro.ckpt; the data pipeline is a pure function of the step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.train.optim import Optimizer


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array

    def as_dict(self):
        return {"params": self.params, "opt": self.opt, "step": self.step}


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda _, c: TrainState(*c),
)


def _compress_int8(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """int8 quantise with error feedback: returns (q, scale, new_err).
    The all-reduce then moves 1 byte/grad instead of 2–4 (beyond-paper trick;
    ablated in EXPERIMENTS §Perf)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale, g32 - q.astype(jnp.float32) * scale


def make_train_step(
    loss_fn: Callable[[Any, Dict], jax.Array],
    optimizer: Optimizer,
    n_microbatches: int = 1,
    compress_grads: bool = False,
    param_specs: Any = None,
    mesh: Any = None,
):
    """Returns step(state, batch) -> (state, metrics). ``batch`` leading dim is
    split into ``n_microbatches`` chunks and gradients are accumulated in fp32.

    ``param_specs``/``mesh``: PartitionSpecs matching params — the fp32
    accumulator is constrained to them (otherwise the scan carry defaults to
    replicated and a vocab×d_model f32 grad materialises on every device;
    measured 2×3 GiB/device on grok-1 before this constraint).
    """

    def _constrain_like(g):
        if param_specs is None or mesh is None:
            return g
        from jax.sharding import NamedSharding

        return jax.tree.map(
            lambda gg, sp: jax.lax.with_sharding_constraint(gg, NamedSharding(mesh, sp)),
            g, param_specs,
        )

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state.params
        if n_microbatches == 1:
            loss, grads = grads_of(params, batch)
            grads = _constrain_like(grads)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_microbatches, b // n_microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mb_i):
                loss_acc, g_acc = carry
                loss_i, g_i = grads_of(params, mb_i)
                # constrain BEFORE accumulating: the data-reduction of dW can
                # then lower to reduce-scatter onto the param shards (ZeRO-2)
                # instead of all-reduce + slice — halves DP grad traffic
                g_i = _constrain_like(g_i)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, g_i
                )
                return (loss_acc + loss_i, g_acc), None

            g0 = _constrain_like(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.float32(0.0), g0), mb)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)

        if compress_grads:
            # error-feedback state rides in opt state under "_ef"
            ef = state.opt.get("_ef") if isinstance(state.opt, dict) else None
            if ef is None:
                ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
            qse = jax.tree.map(
                _compress_int8, grads, ef,
                is_leaf=lambda x: isinstance(x, jax.Array) or hasattr(x, "shape"),
            )
            grads = jax.tree.map(
                lambda t: t[0].astype(jnp.float32) * t[1],
                qse, is_leaf=lambda x: isinstance(x, tuple),
            )
            new_ef = jax.tree.map(lambda t: t[2], qse, is_leaf=lambda x: isinstance(x, tuple))
        opt_state = {k: v for k, v in state.opt.items() if k != "_ef"} if isinstance(state.opt, dict) else state.opt
        new_params, new_opt = optimizer.update(grads, opt_state, params, state.step)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        if compress_grads and isinstance(new_opt, dict):
            new_opt = dict(new_opt)
            new_opt["_ef"] = new_ef
        return (
            TrainState(new_params, new_opt, state.step + 1),
            {"loss": loss, "grad_norm": gnorm},
        )

    return step


def init_state(key, init_params_fn, optimizer: Optimizer) -> TrainState:
    params = init_params_fn(key)
    return TrainState(params, optimizer.init(params), jnp.int32(0))


def train_loop(
    state: TrainState,
    step_fn,
    batch_fn: Callable[[int], Dict],
    n_steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 100,
    log_every: int = 10,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
):
    """Fault-tolerant loop: resumable by construction — the batch is a pure
    function of the step and the checkpoint stores the step. A crashed or
    preempted worker restarts, restores the latest atomic checkpoint and
    continues bit-identically."""
    from repro import ckpt as ckpt_lib

    start = int(state.step)
    t0 = time.perf_counter()
    for step in range(start, n_steps):
        batch = batch_fn(step)
        state, metrics = step_fn(state, batch)
        if on_metrics and (step % log_every == 0):
            on_metrics(step, jax.tree.map(float, metrics))
        if ckpt_dir and ((step + 1) % ckpt_every == 0 or step + 1 == n_steps):
            ckpt_lib.save(ckpt_dir, state.as_dict(), step + 1)
    return state, time.perf_counter() - t0
