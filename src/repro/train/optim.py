"""Optimizers in pure JAX (no optax): AdamW and factored Adafactor.

Optimizer states follow the param sharding (ZeRO: the state pytree reuses the
param PartitionSpecs), so memory scales with the mesh. Adafactor's factored
second moment makes the 314B/132B MoE configs feasible (DESIGN §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    # update(grads, state, params, step) -> (new_params, new_state)
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # state_logical_axes(param_axes, abstract_params) -> state axes pytree
    state_logical_axes: Callable[[Any, Any], Any]


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, (str, tuple)) for a in x)


def adamw(
    lr: float = 1e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        m_leaves = treedef.flatten_up_to(state["m"])
        v_leaves = treedef.flatten_up_to(state["v"])
        new_p, new_m, new_v = [], [], []
        for g, m, v, p in zip(g_leaves, m_leaves, v_leaves, p_leaves):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
            new_m.append(m)
            new_v.append(v)
        return treedef.unflatten(new_p), {
            "m": treedef.unflatten(new_m),
            "v": treedef.unflatten(new_v),
        }

    def state_axes(param_axes, _abstract_params):
        return {"m": param_axes, "v": param_axes}

    return Optimizer(init, update, state_axes)


def adafactor(
    lr: float = 1e-3,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    min_dim_size_to_factor: int = 128,
) -> Optimizer:
    """Factored second moment (Shazeer & Stern, arXiv:1804.04235); no first
    moment. State for an [.., a, b] matrix is [.., a] + [.., b] — the memory
    trick that makes grok-1-314b trainable on 512 chips."""

    def _factored(shape) -> bool:
        return (
            len(shape) >= 2
            and shape[-1] >= min_dim_size_to_factor
            and shape[-2] >= min_dim_size_to_factor
        )

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(one, params)

    _is_state = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                denom = r[..., :, None] * vc[..., None, :]
                u = g32 * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        s_leaves = jax.tree.flatten(state, is_leaf=_is_state)[0]
        results = [upd(g, s, p) for g, s, p in zip(g_leaves, s_leaves, p_leaves)]
        return (
            treedef.unflatten([r[0] for r in results]),
            treedef.unflatten([r[1] for r in results]),
        )

    def state_axes(param_axes, abstract_params):
        ax_leaves, treedef = jax.tree.flatten(param_axes, is_leaf=_is_axes_leaf)
        p_leaves = treedef.flatten_up_to(abstract_params)
        out = []
        for ax, p in zip(ax_leaves, p_leaves):
            ax = tuple(ax)
            if _factored(p.shape):
                out.append({"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]})
            else:
                out.append({"v": ax})
        return treedef.unflatten(out)

    return Optimizer(init, update, state_axes)
