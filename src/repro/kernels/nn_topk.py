"""Pallas TPU kernel: fused masked top-k nearest-centre search.

Generalises ``nn_assign`` (hard-min online accumulator, DESIGN.md §3.3) to the
k smallest distances per query — the beam-search / top-k retrieval hot spot
(DESIGN.md §7). dist[b,k] = ‖x_b‖² − 2·x_b·c_k + ‖c_k‖² as before; the running
state per query row is now a sorted length-``kq`` buffer of (dist, centre id)
pairs instead of a scalar (min, argmin).

Grid: (B/bm, K/bk) with the k axis inner/sequential so the output buffers
(indexed by b only) stay resident in VMEM across centre tiles. Each tile is
merged into the running buffer by ``kq`` select-min-and-mask passes over the
concatenated [bm, kq + bk] candidates — O(kq·(kq+bk)) VPU work per tile,
negligible next to the [bm,D]×[D,bk] MXU matmul for the beam widths the query
engine uses (kq ≤ 64).

Tie-breaking matches ``jax.lax.top_k``: ascending distance, ties by lower
centre index (the running buffer holds earlier tiles' entries and
concatenates before the current tile, and argmin takes the first occurrence).
Masked / padded centres carry +inf distance; exhausted buffer slots report
centre id −1.

VMEM per step (bm=bk=128, kq≤64, D≤8192 fp32): x 4 MiB + c 4 MiB + merge
buffers ~0.4 MiB < 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nn_topk_kernel(
    x_ref, c_ref, bias_ref, dist_ref, arg_ref, *, bk: int, kq: int
):
    k = pl.program_id(1)
    x = x_ref[...]
    c = c_ref[...]
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                        # [bm, bk]
    x32 = x.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    x_sq = jnp.sum(x32 * x32, axis=1)                        # [bm]
    c_sq = jnp.sum(c32 * c32, axis=1)                        # [bk]
    dist = jnp.maximum(x_sq[:, None] - 2.0 * cross + c_sq[None, :], 0.0)
    # masked AND padded centres both carry +inf bias (built in ops.nn_topk)
    dist = dist + bias_ref[...][None, :]
    col = k * bk + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)

    @pl.when(k == 0)
    def _init():
        dist_ref[...] = jnp.full(dist_ref.shape, jnp.inf, jnp.float32)
        arg_ref[...] = jnp.full(arg_ref.shape, -1, jnp.int32)

    # merge the tile into the running sorted buffer: kq select-min passes over
    # the [bm, kq + bk] candidate set (buffer first → earlier tiles win ties)
    comb_d = jnp.concatenate([dist_ref[...], dist], axis=1)
    comb_i = jnp.concatenate([arg_ref[...], col], axis=1)
    pos = jax.lax.broadcasted_iota(jnp.int32, comb_d.shape, 1)
    out_d = jnp.zeros(dist_ref.shape, jnp.float32)
    out_i = jnp.zeros(arg_ref.shape, jnp.int32)
    slot = jax.lax.broadcasted_iota(jnp.int32, out_d.shape, 1)
    for t in range(kq):
        m = jnp.min(comb_d, axis=1)                          # [bm]
        a = jnp.argmin(comb_d, axis=1).astype(jnp.int32)     # first occurrence
        sel = pos == a[:, None]
        win = jnp.sum(jnp.where(sel, comb_i, 0), axis=1)     # gather winner id
        win = jnp.where(jnp.isinf(m), -1, win)               # exhausted → −1
        out_d = jnp.where(slot == t, m[:, None], out_d)
        out_i = jnp.where(slot == t, win[:, None], out_i)
        comb_d = jnp.where(sel, jnp.inf, comb_d)             # consume the winner
    dist_ref[...] = out_d
    arg_ref[...] = out_i


@functools.partial(jax.jit, static_argnames=("kq", "bm", "bk", "interpret"))
def nn_topk_pallas(
    x: jax.Array,
    centers: jax.Array,
    bias: jax.Array,
    *,
    kq: int,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """Padded entry point — callers use repro.kernels.ops.nn_topk, which pads
    B/K/D and builds the centre-mask bias. x: [B,D], centers: [K,D], bias: [K].
    Returns (dist f32[B,kq] ascending, idx i32[B,kq]; −1 id on padding)."""
    b, d = x.shape
    k, _ = centers.shape
    assert b % bm == 0 and k % bk == 0, "pad B and K first"
    grid = (b // bm, k // bk)
    kernel = functools.partial(_nn_topk_kernel, bk=bk, kq=kq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, kq), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, kq), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kq), jnp.float32),
            jax.ShapeDtypeStruct((b, kq), jnp.int32),
        ],
        interpret=interpret,
    )(x, centers, bias)
