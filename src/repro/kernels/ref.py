"""Pure-jnp oracles for every Pallas kernel (the correctness references the
per-kernel tests sweep against)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _full_sqdist(
    x: jax.Array, centers: jax.Array, valid: Optional[jax.Array] = None
) -> jax.Array:
    """Clamped squared distances f32[B, K] (masked centres → +inf) — the shared
    distance matrix behind ``nn_assign_ref`` and ``nn_topk_ref`` (so their
    argmin / top-1 agree bit-for-bit)."""
    x32 = x.astype(jnp.float32)
    c32 = centers.astype(jnp.float32)
    d = (
        jnp.einsum("bd,bd->b", x32, x32)[:, None]
        - 2.0 * x32 @ c32.T
        + jnp.einsum("kd,kd->k", c32, c32)[None, :]
    )
    d = jnp.maximum(d, 0.0)
    if valid is not None:
        d = jnp.where(valid[None, :], d, jnp.inf)
    return d


def nn_assign_ref(
    x: jax.Array, centers: jax.Array, valid: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """(argmin idx i32[B], sqdist f32[B]) against every centre row."""
    d = _full_sqdist(x, centers, valid)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]


def topk_from_dist(dist: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """(idx i32[B,k], dist f32[B,k]) — k smallest per row, ascending, ties by
    lower column (``lax.top_k`` stability). Rows with fewer than k finite
    entries pad with (−1, +inf); ``k`` may exceed the column count."""
    b, n = dist.shape
    if k > n:
        dist = jnp.pad(dist, ((0, 0), (0, k - n)), constant_values=jnp.inf)
    neg, idx = jax.lax.top_k(-dist, k)
    d = -neg
    idx = jnp.where(jnp.isfinite(d), idx.astype(jnp.int32), -1)
    return idx, d


def nn_topk_ref(
    x: jax.Array, centers: jax.Array, k: int, valid: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """(idx i32[B,k], sqdist f32[B,k]) — the k nearest centres per query,
    ascending; oracle for the ``nn_topk`` Pallas kernel."""
    return topk_from_dist(_full_sqdist(x, centers, valid), k)


def topk_merge_ref(
    ids: jax.Array, dist: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Merge per-shard top-k candidate lists into one exact global top-k.

    ``ids`` i32[B, S, k_s], ``dist`` f32[B, S, k_s] — each shard's ascending
    (id, dist) list over a *disjoint* id subset, padded with (−1, +inf).
    Returns (ids i32[B, k], dist f32[B, k]) ascending — the top-k of the union,
    by composing :func:`topk_from_dist` over the flattened S·k_s candidates.
    Exact ties across shards resolve in shard-major order (the serving merge's
    documented tie rule)."""
    b = ids.shape[0]
    ids_f = ids.reshape(b, -1)
    dist_f = dist.reshape(b, -1)
    pos, d = topk_from_dist(dist_f, k)
    out = jnp.take_along_axis(ids_f, jnp.maximum(pos, 0), axis=1)
    return jnp.where(pos >= 0, out, -1).astype(jnp.int32), d


def ell_spmm_ref(values: jax.Array, cols: jax.Array, centers: jax.Array) -> jax.Array:
    """S[b,k] = Σ_j values[b,j] · centers[k, cols[b,j]] — densify + matmul."""
    b, nz = values.shape
    d = centers.shape[1]
    x_dense = jnp.zeros((b, d), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], cols.shape)
    x_dense = x_dense.at[rows, cols].add(values.astype(jnp.float32))
    return x_dense @ centers.astype(jnp.float32).T
