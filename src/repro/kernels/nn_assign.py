"""Pallas TPU kernel: fused nearest-centre search (the K-tree hot spot).

dist[b,k] = ‖x_b‖² − 2·x_b·c_k + ‖c_k‖², reduced to (min, argmin) over k with an
*online* accumulator across centre tiles — flash-attention's online-softmax
pattern specialised to hard-min (DESIGN.md §3.3). The cross term is a
[bm,D]×[D,bk] MXU matmul per tile; block dims are multiples of 128.

Grid: (B/bm, K/bk) — the k axis is the inner (sequential, "arbitrary") axis so
the output block (indexed by b only) stays resident in VMEM and is revisited.

VMEM budget per step (defaults bm=bk=128, D≤8192, fp32):
x 128·8192·4 = 4 MiB, c 4 MiB, dist 64 KiB, outputs ~1 KiB → ~8.2 MiB < 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nn_assign_kernel(x_ref, c_ref, bias_ref, min_ref, arg_ref, *, bk: int, k_actual: int):
    k = pl.program_id(1)
    x = x_ref[...]
    c = c_ref[...]
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                        # [bm, bk]
    x32 = x.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    x_sq = jnp.sum(x32 * x32, axis=1)                        # [bm]
    c_sq = jnp.sum(c32 * c32, axis=1)                        # [bk]
    dist = jnp.maximum(x_sq[:, None] - 2.0 * cross + c_sq[None, :], 0.0)
    dist = dist + bias_ref[...][None, :]                     # +inf on masked centres
    col = k * bk + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    dist = jnp.where(col < k_actual, dist, jnp.inf)          # padded-K guard

    local_min = jnp.min(dist, axis=1)
    local_arg = (k * bk + jnp.argmin(dist, axis=1)).astype(jnp.int32)

    @pl.when(k == 0)
    def _init():
        min_ref[...] = local_min
        arg_ref[...] = local_arg

    @pl.when(k > 0)
    def _accum():
        prev = min_ref[...]
        better = local_min < prev                            # strict: keeps first occurrence
        min_ref[...] = jnp.where(better, local_min, prev)
        arg_ref[...] = jnp.where(better, local_arg, arg_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def nn_assign_pallas(
    x: jax.Array,
    centers: jax.Array,
    bias: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """Padded entry point — callers use repro.kernels.ops.nn_assign, which pads
    B/K/D and builds the centre-mask bias. x: [B,D], centers: [K,D], bias: [K]."""
    b, d = x.shape
    k, _ = centers.shape
    assert b % bm == 0 and k % bk == 0, "pad B and K first"
    grid = (b // bm, k // bk)
    kernel = functools.partial(_nn_assign_kernel, bk=bk, k_actual=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(x, centers, bias)
