"""Pallas TPU kernel: padded-sparse (ELL) documents × dense centres scores.

S[b,k] = Σ_j vals[b,j] · C[k, cols[b,j]] — the medoid/sparse K-tree scoring path
(paper §2: documents stay sparse; only centres are dense).

TPU adaptation (DESIGN.md §3.4): instead of per-element gathers (GPU-style),
each row tile is **densified once into a VMEM scratch buffer** (nnz_max
column-scatter steps) and then hits the MXU as a plain [bm,D]×[D,bk] matmul
against every centre tile. The densify cost is amortised over all K tiles
because the k grid axis is inner/sequential and the scratch persists across it.
HBM traffic stays proportional to the *sparse* bytes — the paper's point.

VMEM per step (bm=128, bk=128, D≤8192 fp32): scratch 4 MiB + c 4 MiB + vals/cols
128·nnz_max·8 ≤ 0.25 MiB (nnz_max 256) + out 64 KiB ≈ 8.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific VMEM scratch spec; interpret mode accepts it too
    from jax.experimental.pallas import tpu as pltpu

    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)  # noqa: E731
except Exception:  # pragma: no cover
    _SCRATCH = None


def _ell_spmm_kernel(vals_ref, cols_ref, c_ref, out_ref, x_scratch, *, nnz_max: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _densify():
        x_scratch[...] = jnp.zeros_like(x_scratch)
        bm = vals_ref.shape[0]
        rows = jnp.arange(bm, dtype=jnp.int32)

        def body(j, acc):
            # one column-scatter per nnz slot; padding (col 0, val 0) is harmless
            acc = acc.at[rows, cols_ref[:, j]].add(vals_ref[:, j].astype(jnp.float32))
            return acc

        x_scratch[...] = jax.lax.fori_loop(0, nnz_max, body, x_scratch[...])

    out_ref[...] = jax.lax.dot_general(
        x_scratch[...],
        c_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def ell_spmm_pallas(
    values: jax.Array,   # f[B, nnz_max]
    cols: jax.Array,     # i32[B, nnz_max]
    centers: jax.Array,  # f[K, D]
    *,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Padded entry point (see repro.kernels.ops.ell_spmm). Returns S f32[B,K]."""
    b, nnz_max = values.shape
    k, d = centers.shape
    assert b % bm == 0 and k % bk == 0, "pad B and K first"
    grid = (b // bm, k // bk)
    kernel = functools.partial(_ell_spmm_kernel, nnz_max=nnz_max)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nnz_max), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, nnz_max), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        scratch_shapes=[_SCRATCH((bm, d))] if _SCRATCH else [],
        interpret=interpret,
    )(values, cols, centers)
