"""Jitted public wrappers around the Pallas kernels: padding to hardware-aligned
block shapes, centre-mask bias construction, backend dispatch (interpret mode on
CPU so the TPU kernel bodies are validated everywhere)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.nn_assign import nn_assign_pallas
from repro.kernels.nn_topk import nn_topk_pallas
from repro.kernels.ell_spmm import ell_spmm_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def nn_assign(
    x: jax.Array,
    centers: jax.Array,
    valid: Optional[jax.Array] = None,
    bm: int = 128,
    bk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """(idx i32[B], sqdist f32[B]) — drop-in for repro.core.kmeans.assign.

    Pads B→bm·⌈⌉, K→bk·⌈⌉, D→128·⌈⌉ (zero padding leaves distances unchanged;
    padded centres are masked +inf inside the kernel)."""
    b, d = x.shape
    k = centers.shape[0]
    bp, kp, dp = _pad_to(b, bm), _pad_to(k, bk), _pad_to(d, 128)
    xq = jnp.pad(x, ((0, bp - b), (0, dp - d)))
    cq = jnp.pad(centers, ((0, kp - k), (0, dp - d)))
    bias = jnp.zeros((k,), jnp.float32)
    if valid is not None:
        bias = jnp.where(valid, 0.0, jnp.inf)
    # padded centre rows must never win: +inf bias
    bias = jnp.pad(bias, (0, kp - k), constant_values=jnp.inf)
    dist, idx = nn_assign_pallas(xq, cq, bias, bm=bm, bk=bk, interpret=_interpret())
    return idx[:b], dist[:b]


def nn_topk(
    x: jax.Array,
    centers: jax.Array,
    k: int,
    valid: Optional[jax.Array] = None,
    bm: int = 128,
    bk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """(idx i32[B,k], sqdist f32[B,k]) — k nearest centres per query, ascending
    (ties by lower centre id, matching ``lax.top_k``). Generalises
    :func:`nn_assign`; padding follows the same scheme, and queries with fewer
    than k reachable centres pad with (−1, +inf) — ``k`` may exceed K."""
    b, d = x.shape
    kc = centers.shape[0]
    bp, kp, dp = _pad_to(b, bm), _pad_to(kc, bk), _pad_to(d, 128)
    xq = jnp.pad(x, ((0, bp - b), (0, dp - d)))
    cq = jnp.pad(centers, ((0, kp - kc), (0, dp - d)))
    bias = jnp.zeros((kc,), jnp.float32)
    if valid is not None:
        bias = jnp.where(valid, 0.0, jnp.inf)
    # padded centre rows must never win: +inf bias
    bias = jnp.pad(bias, (0, kp - kc), constant_values=jnp.inf)
    dist, idx = nn_topk_pallas(
        xq, cq, bias, kq=k, bm=bm, bk=bk, interpret=_interpret()
    )
    return idx[:b], dist[:b]


def ell_spmm(
    values: jax.Array,
    cols: jax.Array,
    centers: jax.Array,
    bm: int = 128,
    bk: int = 128,
) -> jax.Array:
    """Sparse-doc × dense-centre scores S f32[B,K] (see ell_spmm kernel)."""
    b, nnz = values.shape
    k, d = centers.shape
    bp, kp, dp = _pad_to(b, bm), _pad_to(k, bk), _pad_to(d, 128)
    vq = jnp.pad(values, ((0, bp - b), (0, 0)))
    cq = jnp.pad(cols, ((0, bp - b), (0, 0)))
    ctq = jnp.pad(centers, ((0, kp - k), (0, dp - d)))
    s = ell_spmm_pallas(vq, cq, ctq, bm=bm, bk=bk, interpret=_interpret())
    return s[:b, :k]


def medoid_assign_sparse(
    values: jax.Array,
    cols: jax.Array,
    row_sq: jax.Array,
    centers: jax.Array,
    valid: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """NN assignment where *documents are sparse* (ELL) and centres dense —
    the medoid K-tree scoring path: ‖x‖² − 2·S + ‖c‖² with S from ell_spmm."""
    s = ell_spmm(values, cols, centers)
    c32 = centers.astype(jnp.float32)
    c_sq = jnp.einsum("kd,kd->k", c32, c32)
    dist = jnp.maximum(row_sq[:, None] - 2.0 * s + c_sq[None, :], 0.0)
    if valid is not None:
        dist = jnp.where(valid[None, :], dist, jnp.inf)
    idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
    return idx, jnp.take_along_axis(dist, idx[:, None], axis=1)[:, 0]
