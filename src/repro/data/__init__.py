"""Data substrate: synthetic labelled corpora (the INEX-2008-like and RCV1-like
collections used by the paper's evaluation), sharded batch pipelines, and the
GNN neighbour sampler."""
from repro.data.synth_corpus import make_corpus, CorpusSpec, INEX_LIKE, RCV1_LIKE
from repro.data.pipeline import ShardedBatcher

__all__ = ["make_corpus", "CorpusSpec", "INEX_LIKE", "RCV1_LIKE", "ShardedBatcher"]
