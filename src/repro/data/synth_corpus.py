"""Synthetic labelled document corpora.

The paper evaluates on INEX 2008 XML Mining (114,366 docs, 15 labels) and an
RCV1 subset (193,844 docs, 103 industry labels), both culled to the 8000
highest-ranked terms (INEX: 10,229,913 nnz after culling → ~89 nnz/doc).

Those corpora are not redistributable and the container is offline, so we
generate corpora with matching *statistics* via a planted-topic model:

- vocabulary with a Zipfian background distribution (natural-language-like),
- each label owns a topic: a sparse multinomial concentrated on a label-specific
  term subset, mixed with the background,
- per-document length ~ lognormal, terms drawn from mix(topic, background),
- label sizes follow a power law (real collections are imbalanced).

Ground-truth labels make purity/entropy well-defined — the same protocol as the
paper, with a knowable generative truth.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.sparse.csr import Csr


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    name: str
    n_docs: int
    n_labels: int
    vocab: int            # raw vocabulary before culling
    culled_vocab: int     # paper: 8000
    mean_doc_len: float   # tokens per doc (pre-dedup)
    topic_terms: int      # terms owned by each label topic
    topic_weight: float   # P(token from topic) vs background
    label_zipf: float     # power-law exponent for label sizes


# Full-size specs (used in dry-runs / docs); benches scale these down.
INEX_LIKE = CorpusSpec(
    name="inex2008-like", n_docs=114_366, n_labels=15, vocab=206_868,
    culled_vocab=8000, mean_doc_len=120.0, topic_terms=600, topic_weight=0.55,
    label_zipf=1.1,
)
RCV1_LIKE = CorpusSpec(
    name="rcv1-like", n_docs=193_844, n_labels=103, vocab=47_236,
    culled_vocab=8000, mean_doc_len=80.0, topic_terms=200, topic_weight=0.6,
    label_zipf=1.3,
)


def scaled(spec: CorpusSpec, n_docs: int, vocab: int | None = None,
           culled: int | None = None) -> CorpusSpec:
    """Shrink a spec for CPU-budget benches, keeping its character."""
    return dataclasses.replace(
        spec,
        n_docs=n_docs,
        vocab=vocab or min(spec.vocab, max(4 * (culled or spec.culled_vocab), 2000)),
        culled_vocab=culled or spec.culled_vocab,
    )


def _zipf_probs(v: int, s: float = 1.05) -> np.ndarray:
    p = 1.0 / np.power(np.arange(1, v + 1, dtype=np.float64), s)
    return p / p.sum()


def make_corpus(spec: CorpusSpec, seed: int = 0) -> Tuple[Csr, np.ndarray]:
    """Returns (term-count CSR [n_docs, vocab], labels i32[n_docs]).

    Vectorised sampling: we draw per-document token counts against a mixed
    multinomial by sampling token→term ids in one big array pass per label
    group (documents of one label share a topic distribution).
    """
    rng = np.random.default_rng(seed)
    # label sizes ~ power law, normalised to n_docs
    raw = 1.0 / np.power(np.arange(1, spec.n_labels + 1, dtype=np.float64), spec.label_zipf)
    sizes = np.maximum((raw / raw.sum() * spec.n_docs).astype(np.int64), 1)
    sizes[0] += spec.n_docs - sizes.sum()  # fix rounding on the largest label
    labels = np.repeat(np.arange(spec.n_labels, dtype=np.int32), sizes)
    rng.shuffle(labels)

    background = _zipf_probs(spec.vocab)
    # per-label topic term subsets (disjoint-ish: drawn without replacement from
    # the mid-frequency band so topics are informative but not trivially split)
    band = np.arange(spec.vocab // 50, spec.vocab)
    doc_lens = np.maximum(
        rng.lognormal(np.log(spec.mean_doc_len), 0.4, spec.n_docs).astype(np.int64), 8
    )

    rows_parts, cols_parts, vals_parts = [], [], []
    for lbl in range(spec.n_labels):
        docs = np.nonzero(labels == lbl)[0]
        if docs.size == 0:
            continue
        topic_ids = rng.choice(band, size=spec.topic_terms, replace=False)
        topic_p = rng.dirichlet(np.full(spec.topic_terms, 0.5))
        lens = doc_lens[docs]
        total = int(lens.sum())
        # choose source: topic vs background per token
        from_topic = rng.random(total) < spec.topic_weight
        n_topic = int(from_topic.sum())
        toks = np.empty(total, dtype=np.int64)
        toks[from_topic] = topic_ids[rng.choice(spec.topic_terms, size=n_topic, p=topic_p)]
        toks[~from_topic] = rng.choice(spec.vocab, size=total - n_topic, p=background)
        doc_of_tok = np.repeat(docs, lens)
        # count (doc, term) pairs
        key = doc_of_tok.astype(np.int64) * spec.vocab + toks
        uniq, counts = np.unique(key, return_counts=True)
        rows_parts.append((uniq // spec.vocab).astype(np.int64))
        cols_parts.append((uniq % spec.vocab).astype(np.int32))
        vals_parts.append(counts.astype(np.float32))

    rows = np.concatenate(rows_parts)
    cols = np.concatenate(cols_parts)
    vals = np.concatenate(vals_parts)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    indptr = np.zeros(spec.n_docs + 1, dtype=np.int32)
    np.cumsum(np.bincount(rows, minlength=spec.n_docs), out=indptr[1:])
    counts_csr = Csr(
        data=jnp.asarray(vals),
        indices=jnp.asarray(cols),
        indptr=jnp.asarray(indptr),
        n_cols=spec.vocab,
    )
    return counts_csr, labels


def prepared_corpus(spec: CorpusSpec, seed: int = 0):
    """Full paper preprocessing: counts → TF-IDF → cull top terms → unit rows.

    Returns (culled tf-idf Csr, labels).
    """
    from repro.sparse.tfidf import tfidf_weight, cull_terms, unit_normalize_rows

    counts, labels = make_corpus(spec, seed)
    weighted = tfidf_weight(counts)
    culled, _ = cull_terms(weighted, spec.culled_vocab)
    return unit_normalize_rows(culled), labels
