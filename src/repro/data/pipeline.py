"""Sharded, deterministic, restartable batch pipeline.

Design points for 1000+-node runs:
- determinism: batch contents are a pure function of (seed, step, shard) — any
  worker can recompute any batch, so a restarted/replaced node needs no state
  hand-off beyond the step counter in the checkpoint.
- sharding: each data-parallel group reads only its slice (disjoint strided
  partition), so input bandwidth scales with the fleet.
- straggler/fault semantics: batches are addressed by step; a worker that
  skips a damaged record logs it and substitutes the next index (skip-and-log),
  keeping the global batch shape static.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np
import jax


@dataclasses.dataclass
class ShardedBatcher:
    n_examples: int
    global_batch: int
    shard_id: int = 0
    n_shards: int = 1
    seed: int = 0
    drop_remainder: bool = True

    def __post_init__(self):
        if self.global_batch % self.n_shards:
            raise ValueError("global_batch must divide evenly across shards")
        self.per_shard = self.global_batch // self.n_shards

    def batch_indices(self, step: int) -> np.ndarray:
        """Indices for this shard at `step` — pure function of (seed, step)."""
        epoch = (step * self.global_batch) // self.n_examples
        rng = np.random.default_rng((self.seed, epoch))
        perm = rng.permutation(self.n_examples)
        start = (step * self.global_batch) % self.n_examples
        idx = perm[(start + np.arange(self.global_batch)) % self.n_examples]
        return idx[self.shard_id :: self.n_shards]

    def __call__(self, start_step: int = 0) -> Iterator[np.ndarray]:
        step = start_step
        while True:
            yield self.batch_indices(step)
            step += 1


def shard_bounds(n: int, shard_id: int, n_shards: int) -> tuple[int, int]:
    """Contiguous [lo, hi) partition of n items over n_shards (for corpus
    sharding in distributed K-tree / k-means)."""
    base, rem = divmod(n, n_shards)
    lo = shard_id * base + min(shard_id, rem)
    return lo, lo + base + (1 if shard_id < rem else 0)


# ---------------------------------------------------------------------------
# corpus → K-tree backend path (paper preprocessing, both representations)
# ---------------------------------------------------------------------------

def corpus_backend(
    spec, representation: str = "sparse_medoid", seed: int = 0,
    rp_dim: int = 128, rp_seed: int = 0, rp_kind: str = "gaussian",
):
    """Full paper corpus path in one call: term counts → TF-IDF → cull top
    terms → unit rows, then lay the culled matrix out for the requested
    K-tree representation.

    ``representation``:
    - ``"dense"``         — densify (the seed/paper-§4 dense K-tree path);
    - ``"sparse_medoid"`` — keep documents sparse in ELL(+CSR) layout (paper
      §2's medoid K-tree; the ``ell_spmm`` scoring path);
    - ``"rp"``            — Random Indexing K-tree (DESIGN.md §5.1): documents
      stay sparse (ELL base), tree build/descent runs in an ``rp_dim``-dim
      seeded random projection (``rp_seed``/``rp_kind`` → ``make_projection``).
      Query with ``topk_search(..., rp=backend)`` for exact rescore.

    Returns (backend, labels i32[n_docs]). The backend plugs straight into
    ``repro.core.ktree.build(backend, ...)``.
    """
    from repro.core.backend import (
        RandomProjBackend, make_backend, make_projection,
    )
    from repro.data.synth_corpus import prepared_corpus

    if representation not in ("dense", "sparse_medoid", "rp"):
        raise ValueError(f"unknown representation {representation!r}")
    culled, labels = prepared_corpus(spec, seed=seed)
    if representation == "rp":
        base = make_backend(culled, "sparse")
        proj = make_projection(base.dim, rp_dim, seed=rp_seed, kind=rp_kind)
        return RandomProjBackend.wrap(base, proj), labels
    kind = "dense" if representation == "dense" else "sparse"
    return make_backend(culled, kind), labels


def corpus_store(
    spec, path: str, representation: str = "sparse_medoid", seed: int = 0,
    block_docs: int = 4096, reuse: bool = True,
):
    """Prepared corpus → on-disk block store (DESIGN.md §9), returns ``path``.

    Runs :func:`corpus_backend` (term counts → TF-IDF → cull → unit rows →
    backend layout) and writes the result with
    ``repro.core.store.save_store`` — dense representation lands as dense
    blocks, ``sparse_medoid`` *and* ``rp`` as ELL blocks (the store always
    holds the **original** rows; an RP projection is never materialised on
    disk, it replays from its seed — build with
    ``build_from_store(..., projection=...)``). A sidecar ``PIPELINE.json``
    records the full generation request (every spec field, representation,
    seed, block_docs) plus the written store's ``manifest_hash``. With
    ``reuse=True`` (default) an existing store at ``path`` is kept as-is
    *only if* that sidecar matches the current request exactly **and** the
    store's content hash still matches the recorded one; any difference — a
    different spec (even one with the same shape), seed, representation,
    blocking, or a store grown/regenerated in place since generation
    (``CorpusStore.append``) — raises rather than silently serving a stale
    corpus. The preparation pipeline is deterministic in (spec, seed), so a
    reused matching store is byte-identical to a rewrite."""
    import dataclasses
    import json
    import os

    from repro.core.store import (
        MANIFEST_NAME, load_manifest, open_store, save_store,
    )

    request = {
        "spec": dataclasses.asdict(spec), "representation": representation,
        "seed": seed, "block_docs": block_docs,
    }
    sidecar = os.path.join(path, "PIPELINE.json")
    if reuse and os.path.exists(os.path.join(path, MANIFEST_NAME)):
        recorded = None
        if os.path.exists(sidecar):
            # a corrupt/truncated sidecar raises a typed ManifestError
            # naming the file, not a bare JSONDecodeError
            recorded = load_manifest(sidecar)
        recorded_req = {
            k: v for k, v in (recorded or {}).items() if k != "manifest_hash"
        } or None
        if recorded_req != request:
            raise ValueError(
                f"existing store at {path} was generated from a different "
                f"request: recorded {recorded_req}, current {request} — point "
                "--store at a fresh directory or delete the old one"
            )
        # content check: a store grown in place (CorpusStore.append /
        # insert_into_store) or otherwise mutated since generation is NOT the
        # prepared corpus this request describes, even though the generation
        # request still matches. Exception: a store *repaired* by store_fsck
        # records its pre-repair hash in the manifest's fsck_lineage chain —
        # that is this corpus minus its damaged blocks (doc ids unchanged),
        # so serving it degraded is exactly the point of the repair
        rec_hash = (recorded or {}).get("manifest_hash")
        cur = open_store(path)
        if (rec_hash is not None and rec_hash != cur.manifest_hash
                and rec_hash not in cur.manifest.get("fsck_lineage", ())):
            raise ValueError(
                f"existing store at {path} matches this generation request "
                "but its content changed since it was written (appended to "
                "or regenerated — manifest hash "
                f"{cur.manifest_hash} != recorded {rec_hash}); point --store "
                "at a fresh directory or delete the old one"
            )
        return path
    backend, _ = corpus_backend(spec, representation=representation, seed=seed)
    if representation == "rp":
        backend = backend.base  # original rows; the projection replays from seed
    save_store(path, backend, block_docs=block_docs)
    request["manifest_hash"] = open_store(path).manifest_hash
    with open(sidecar, "w") as f:
        json.dump(request, f, indent=1, sort_keys=True)
    return path
