"""Sharded pytree checkpointing with atomic renames and elastic restore."""
from __future__ import annotations

import os
import re
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _leaf_paths(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, tree: Any, step: int) -> str:
    """Atomic save: write to step_xxx.tmp, fsync, rename."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _leaf_paths(tree)
    manifest = {"treedef": str(treedef), "n_leaves": len(leaves), "step": step, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append({"i": i, "dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(tmp, "MANIFEST.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep=3)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        (int(m.group(1)), name)
        for name in os.listdir(ckpt_dir)
        if (m := _STEP_RE.match(name))
    )
    for _, name in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Highest step number with a completed ``step_xxx`` directory in
    ``ckpt_dir`` (None when none exist)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for n in os.listdir(ckpt_dir) if (m := _STEP_RE.match(n))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``. ``shardings`` (optional pytree of
    NamedShardings) re-places leaves against the *current* mesh — elastic
    restore across fleet-size changes."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    like_leaves, treedef = jax.tree.flatten(like)
    mpath = os.path.join(path, "MANIFEST.msgpack")
    try:
        with open(mpath, "rb") as f:
            manifest = msgpack.unpackb(f.read())
    except (ValueError, msgpack.exceptions.ExtraData,
            msgpack.exceptions.UnpackException) as e:
        from repro.core.store import ManifestError

        raise ManifestError(
            mpath, f"corrupt or truncated checkpoint manifest ({e})"
        ) from e
    assert manifest["n_leaves"] == len(like_leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, expected {len(like_leaves)}"
    )
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(like_leaves)
    )
    out = []
    for i, (ref, shard) in enumerate(zip(like_leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr, dtype=getattr(ref, "dtype", None)))
    return treedef.unflatten(out)


# --- K-tree persistence (paper: "efficient disk based implementations") -----

def save_ktree(path: str, tree, projection=None) -> str:
    """Atomic single-file K-tree snapshot (tmp + rename, like :func:`save`).

    Extended dtypes (bfloat16 & friends) are not understood by the .npy
    format's descr — ``np.save`` silently writes them as opaque void bytes
    that ``jnp.asarray`` then rejects on load. Each field's true dtype is
    recorded in the meta blob and non-native float dtypes are stored upcast
    to float32 (lossless); :func:`restore_ktree` casts back.

    ``projection`` (a ``repro.core.backend.RandomProjection``) records the
    random-projection *spec* — seed, dims, kind, dtype — in the meta blob.
    The matrix itself is never written: jax's threefry PRNG is deterministic,
    so the spec replays it bit-exactly (DESIGN.md §5.1). Read it back with
    :func:`load_ktree_projection`."""
    import dataclasses

    final = path if path.endswith(".npz") else path + ".npz"
    os.makedirs(os.path.dirname(final) or ".", exist_ok=True)
    arrays, dtypes = {}, {}
    for f in dataclasses.fields(tree):
        if f.metadata.get("static"):
            continue
        arr = np.asarray(jax.device_get(getattr(tree, f.name)))
        dtypes[f.name] = str(arr.dtype)
        if arr.dtype.kind == "V":  # extended float (e.g. bfloat16)
            arr = arr.astype(np.float32)
        arrays[f.name] = arr
    meta = {"order": tree.order, "medoid": tree.medoid, "dtypes": dtypes}
    if projection is not None:
        meta["projection"] = projection.spec()
    tmp = final + ".tmp.npz"
    np.savez(tmp, **arrays, _meta=np.frombuffer(msgpack.packb(meta), dtype=np.uint8))
    os.replace(tmp, final)
    return final


def restore_ktree(path: str):
    """Load a :func:`save_ktree` snapshot back into a live ``KTree`` (accepts
    the path with or without its ``.npz`` suffix; per-field dtypes restored
    from the meta blob)."""
    from repro.core.ktree import KTree

    data = np.load(path if path.endswith(".npz") else path + ".npz")
    meta = msgpack.unpackb(data["_meta"].tobytes())
    dtypes = meta.get("dtypes", {})  # absent in pre-fix checkpoints
    kwargs = {
        k: jnp.asarray(v, dtype=dtypes.get(k))
        for k, v in data.items()
        if k != "_meta"
    }
    return KTree(order=int(meta["order"]), medoid=bool(meta["medoid"]), **kwargs)


def load_ktree_projection(path: str):
    """Replay the ``RandomProjection`` recorded by
    ``save_ktree(..., projection=...)`` (None when the snapshot was saved
    without one). The matrix is rebuilt from the stored spec via
    ``projection_from_spec`` — bit-identical to the one used at save time."""
    from repro.core.backend import projection_from_spec

    data = np.load(path if path.endswith(".npz") else path + ".npz")
    meta = msgpack.unpackb(data["_meta"].tobytes())
    spec = meta.get("projection")
    return None if spec is None else projection_from_spec(spec)


# --- store-backed index persistence (DESIGN.md §9) ---------------------------

INDEX_META_NAME = "INDEX.json"


def save_index(path: str, tree, store, projection=None) -> str:
    """Checkpoint a store-backed index **by manifest reference**: the tree's
    array pages are snapshotted (``tree.npz``, via :func:`save_ktree`) next to
    a small JSON that records the corpus store's path and
    ``manifest_hash`` — the corpus itself (the large side of the index) is
    never copied or materialised.

    ``path`` becomes a directory ``{tree.npz, INDEX.json}``; the write lands
    in a tmp dir and installs by rename (an existing checkpoint is moved
    aside and removed only after the replacement is in place, so a crash
    never destroys the previous restore point). Restore with
    :func:`restore_index`, which re-opens the store and refuses to pair the
    tree with a corpus whose manifest content changed (regenerated in place →
    stale doc ids). A store grown by ``ktree.insert_into_store`` rotates its
    ``manifest_hash`` the same way: re-checkpoint the grown (tree, store)
    pair afterwards — the pre-insert checkpoint correctly refuses to restore
    against the extended corpus.

    ``projection`` (a ``RandomProjection``) records the random-projection
    spec in both the tree snapshot and ``INDEX.json`` for an RP-routed index
    (tree built over ``RandomProjBackend.from_store``). Restore rebuilds the
    matrix bit-exactly from the spec and refuses a caller-expected projection
    that differs (``ProjectionMismatch``), the same contract as a rewritten
    store."""
    import json

    from repro.core.store import _install_dir

    tmp = path.rstrip("/") + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    save_ktree(os.path.join(tmp, "tree"), tree, projection=projection)
    ref = {
        "store_path": os.path.abspath(store.path),
        "manifest_hash": store.manifest_hash,
        "kind": store.kind,
        "n_docs": store.n_docs,
    }
    if projection is not None:
        ref["projection"] = projection.spec()
    with open(os.path.join(tmp, INDEX_META_NAME), "w") as f:
        json.dump(ref, f, indent=1, sort_keys=True)
    _install_dir(tmp, path)
    return path


def restore_index(
    path: str,
    budget_bytes: Optional[int] = None,
    check: bool = True,
    projection=None,
):
    """Restore a :func:`save_index` checkpoint → ``(tree, store)``, or
    ``(tree, store, projection)`` when the checkpoint recorded a
    random-projection spec (``save_index(..., projection=...)``).

    The store is re-opened from the recorded path with ``budget_bytes`` of
    block-cache residency (default: the store module's default budget).
    ``check=True`` (default) verifies the store's current ``manifest_hash``
    against the one recorded at save time and raises ``ValueError`` on
    mismatch — the corpus was regenerated in place (or grown by
    ``insert_into_store`` after the save), so the tree's doc ids would
    silently address different (or fewer) documents than the tree that was
    checkpointed alongside them. One mismatch is allowed: a store *repaired*
    by ``store_fsck`` records its pre-repair hashes in the manifest's
    ``fsck_lineage`` chain — excision keeps blocks positional, so the tree's
    doc ids still address the same rows and the pair restores (reads of the
    excised blocks fail typed / degrade, DESIGN.md §10). A corrupt or
    truncated ``INDEX.json`` raises a typed
    ``repro.core.store.ManifestError`` naming the file.

    ``projection`` states the projection the caller *expects* (a
    ``RandomProjection`` or a spec dict). A recorded projection that differs
    from the expectation in any field — seed, dims, kind, dtype — raises
    ``repro.core.backend.ProjectionMismatch``: routing a tree built under one
    projection with a different matrix silently degrades every query, the
    exact analogue of pairing a tree with a rewritten corpus. Expecting a
    projection when none was recorded (or vice versa when the checkpoint
    carries one and dims disagree with the tree/store) is refused the same
    way. The returned projection's matrix is replayed bit-exactly from the
    stored seed."""
    from repro.core.backend import ProjectionMismatch, projection_from_spec
    from repro.core.store import (
        DEFAULT_BUDGET_BYTES, ManifestError, load_manifest, open_store,
    )

    ipath = os.path.join(path, INDEX_META_NAME)
    if not os.path.exists(ipath):
        raise FileNotFoundError(
            f"no store-backed index checkpoint at {path} "
            f"(missing {INDEX_META_NAME})"
        )
    ref = load_manifest(ipath)
    for key in ("store_path", "manifest_hash"):
        if key not in ref:
            raise ManifestError(
                ipath, f"index reference is missing the {key!r} field "
                       "(corrupt or not a save_index checkpoint)"
            )
    tree = restore_ktree(os.path.join(path, "tree"))
    store = open_store(
        ref["store_path"],
        budget_bytes=DEFAULT_BUDGET_BYTES if budget_bytes is None else budget_bytes,
    )
    if check and store.manifest_hash != ref["manifest_hash"]:
        if ref["manifest_hash"] not in store.manifest.get("fsck_lineage", ()):
            raise ValueError(
                f"index {path} references corpus store {ref['store_path']} "
                f"with manifest hash {ref['manifest_hash']}, but the store on "
                f"disk now hashes to {store.manifest_hash} — the corpus was "
                "rewritten in place; rebuild the index (or pass check=False "
                "to pair anyway)"
            )
    expected = projection.spec() if hasattr(projection, "spec") else projection
    recorded = ref.get("projection")
    if recorded is None:
        if expected is not None:
            raise ProjectionMismatch(
                f"index {path} records no random projection but the caller "
                f"expects one ({expected}) — this checkpoint was built on the "
                "exact (unprojected) path"
            )
        return tree, store
    if expected is not None and dict(expected) != dict(recorded):
        raise ProjectionMismatch(
            f"index {path} records projection {recorded} but the caller "
            f"expects {expected} — routing this tree under a different "
            "projection silently degrades every query; rebuild the index"
        )
    proj = projection_from_spec(recorded)
    if proj.out_dim != tree.dim or proj.in_dim != store.dim:
        raise ProjectionMismatch(
            f"index {path} records projection "
            f"{proj.in_dim}→{proj.out_dim} but the restored tree has dim "
            f"{tree.dim} and the store has dim {store.dim} — checkpoint and "
            "corpus disagree; rebuild the index"
        )
    return tree, store, proj
