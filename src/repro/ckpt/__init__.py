"""Checkpointing + fault tolerance (DESIGN §5).

- Atomic step directories (`step_000123.tmp` → rename) — a crash mid-write
  never corrupts the restore point.
- Pytree leaves stored as raw .npy files + a msgpack manifest with the tree
  structure, dtypes and shapes.
- Elastic restore: arrays are re-placed against whatever mesh/sharding the
  restoring job provides — the fleet size may change between runs.
- K-tree persistence: the tree's array pages serialise the same way (the
  paper's disk-based K-tree, §1).
- Store-backed indexes checkpoint **by manifest reference**
  (`save_index`/`restore_index`, DESIGN.md §9): the tree snapshot plus the
  corpus store's path + content hash — the corpus is never rematerialised,
  and a store rewritten in place is refused at restore.
"""
from repro.ckpt.checkpoint import (
    save, restore, latest_step, save_ktree, restore_ktree,
    load_ktree_projection, save_index, restore_index,
)

__all__ = [
    "save", "restore", "latest_step", "save_ktree", "restore_ktree",
    "load_ktree_projection", "save_index", "restore_index",
]
