"""Sampled K-tree construction (paper §3).

"The medoid K-tree was also used to select 10% of the corpus for sampling.
This sample was used to construct a K-tree. The resulting K-tree was used to
perform a nearest neighbour search and produce a clustering solution."
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ktree as kt


def select_sample_medoid(
    x: jax.Array, fraction: float = 0.1, key: Optional[jax.Array] = None,
    batch_size: int = 256,
) -> np.ndarray:
    """Select ~fraction·N exemplar documents with a medoid K-tree: build with
    order ≈ 1/(0.7·fraction) (average leaf fill ≈ 0.7·m) and return the
    above-leaf exemplar doc ids (one per leaf)."""
    if key is None:
        key = jax.random.PRNGKey(7)
    order = max(4, int(round(1.0 / (0.7 * fraction))))
    tree = kt.build(x, order=order, key=key, batch_size=batch_size, medoid=True)
    leaves = kt.leaf_nodes(tree)
    parent = np.asarray(tree.parent)
    parent_slot = np.asarray(tree.parent_slot)
    child = np.asarray(tree.child)
    ne = np.asarray(tree.n_entries)
    ids = []
    if int(tree.depth) == 1:  # root is the only leaf — sample its docs
        root = int(tree.root)
        ids = child[root, : ne[root]].tolist()
    else:
        for leaf in leaves:
            p, s = int(parent[leaf]), int(parent_slot[leaf])
            # medoid internal entries store exemplar *vectors*; recover the doc id
            # as the leaf entry nearest the exemplar — by construction the
            # exemplar is one of the subtree's documents.
            ids.append(_leaf_doc_nearest(tree, leaf, p, s))
    return np.unique(np.asarray(ids, dtype=np.int64))


def _leaf_doc_nearest(tree: kt.KTree, leaf: int, p: int, s: int) -> int:
    c = np.asarray(tree.centers[p, s])
    ne = int(tree.n_entries[leaf])
    vecs = np.asarray(tree.centers[leaf, :ne])
    d = ((vecs - c) ** 2).sum(axis=1)
    return int(np.asarray(tree.child[leaf, : ne])[int(np.argmin(d))])


def select_sample_random(n: int, fraction: float, key: jax.Array) -> np.ndarray:
    k = max(1, int(round(n * fraction)))
    return np.asarray(jax.random.choice(key, n, (k,), replace=False))


def sampled_ktree_clustering(
    x: jax.Array,
    order: int,
    fraction: float = 0.1,
    key: Optional[jax.Array] = None,
    sample_mode: str = "medoid",
    batch_size: int = 256,
) -> Tuple[np.ndarray, int, kt.KTree]:
    """Full paper §3 pipeline: sample → build K-tree on sample → NN-assign the
    whole corpus. Returns (cluster i32[N], n_clusters, tree)."""
    if key is None:
        key = jax.random.PRNGKey(11)
    k1, k2 = jax.random.split(key)
    if sample_mode == "medoid":
        sample = select_sample_medoid(x, fraction, k1, batch_size=batch_size)
    else:
        sample = select_sample_random(x.shape[0], fraction, k1)
    tree = kt.build(x[jnp.asarray(sample)], order=order, key=k2, batch_size=batch_size)
    assign = kt.assign_via_tree(tree, x)
    n_clusters = len(kt.leaf_nodes(tree))
    return assign, n_clusters, tree
