"""Continuous-batching serving engine (DESIGN.md §8).

``topk_search`` and friends are *offline* engines: hand them a fixed query
array and they answer it as one closed batch. A service sees something else —
requests arriving one at a time, each with its own query rows, ``k``,
``beam``, and latency deadline — and the paper's operational claim ("suitable
for large document collections" at scale) is about that regime. This module
is the front end that turns the offline engines into a service:

    submit() ──► bounded admission queue ──► batcher ──► engine call ──► demux
                   │ full → shed               │ dispatch when the row budget
                   │ (reject now, never        │ fills OR the oldest request's
                   │  queue unboundedly)       │ deadline forcing-point arrives
                                               │ fragments bucketed per (k, beam)

- **Admission** — :meth:`ServingEngine.submit` enqueues a request and returns
  a :class:`ResultHandle` future. The queue is bounded: when it is full the
  request is *rejected immediately* (:class:`EngineSaturated`, counted in
  ``shed``) instead of absorbed into an ever-growing backlog — under overload
  latency stays bounded and the caller learns to back off.
- **Dynamic batching** — the dispatcher thread drains the queue FIFO into a
  batch of up to ``row_budget`` query rows, waiting for more arrivals only
  until the oldest pending request's *forcing point*: ``admit + max_wait``,
  tightened to ``deadline − dispatch_margin`` for requests that carry one. A
  full batch dispatches immediately; a lone request on an idle engine waits
  at most ``max_wait``.
- **Bucketed, chunk-aligned execution** — the drained batch is fragmented by
  ``(k, beam, pow2 request-size bucket)``: one offline-engine call per
  distinct setting and size class, each request's rows padded to the bucket
  (:func:`pow2_pad_rows`) and the call chunked *at* the bucket, so every
  query chunk gathers exactly one request's rows — the same tensor its
  standalone offline call gathers, which is what makes every request's
  answer **bit-identical** to the offline engines (XLA numerics depend on
  the gathered chunk shape, so naive concatenation would drift by ulps).
  Compiles stay bounded by (settings × pow2 buckets) actually served, not by
  batch composition — the same bucketing discipline as descent depths and
  chunk sizes (DESIGN.md §6).
- **Cache staging** — an optional :class:`repro.core.query.AnswerCache` runs
  as a pre-batch stage (:func:`repro.core.query.cache_stage`): hit rows are
  answered without occupying engine rows, misses are deduplicated, and every
  computed answer is inserted — exactly :func:`topk_search_cached`'s
  accounting, applied per fragment.
- **Observability** — per-request latency lands in a
  :class:`LatencyRecorder` (injectable monotonic clock — the fake-clock seam
  the timing tests pin); :meth:`ServingEngine.stats` reports p50/p95/p99
  latency, QPS, queue depth, shed/deadline-miss counters, batch occupancy,
  and (when ``block_caches`` are wired, e.g. a store-backed corpus) the
  per-batch peak disk residency via ``BlockCache.reset_peak``.
- **Robustness** (DESIGN.md §10) — a **watchdog** thread guarantees that
  every admitted request resolves — an answer, a typed error, or a timeout —
  so a caller blocked in :meth:`ResultHandle.result` can never hang forever.
  It enforces the engine-wide ``request_timeout_s`` (overdue requests, queued
  *or* in flight behind a wedged ``search_fn``, fail with
  :class:`EngineTimeout`) and restarts the dispatcher thread if it ever dies
  (the orphaned in-flight batch fails with :class:`EngineFault`; later
  requests are served by the replacement). ``close(drain=False)`` fails
  queued and in-flight requests with :class:`EngineClosed` instead of
  waiting on them. Degraded answers from the offline engines'
  ``on_fault="degrade"`` mode (see :func:`make_search_fn`) surface on the
  handle as ``ResultHandle.degraded`` plus the
  :class:`repro.core.faults.FaultReport` in ``ResultHandle.report``.

The engine owns one dispatcher thread; ``submit`` is safe from any number of
threads. All timing uses a monotonic clock (``time.perf_counter`` by
default) — wall-clock ``time.time`` can step under NTP and corrupt latency
percentiles.
"""
from __future__ import annotations

import dataclasses
import inspect
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.autotune import resolve_knobs
from repro.core.profile import NULL_PROFILER
from repro.core.query import (
    AnswerCache,
    cache_fill,
    cache_stage,
    concat_request_rows,
    split_batch_answers,
    topk_search,
    topk_search_sharded,
)


class EngineSaturated(RuntimeError):
    """Admission rejected: the bounded request queue is full (the request was
    counted in ``shed``). Back off and retry — the alternative, unbounded
    queueing, converts overload into unbounded latency for everyone."""


class EngineClosed(RuntimeError):
    """The engine has been closed; no further requests are admitted. Also the
    failure attached to queued/in-flight handles abandoned by
    ``close(drain=False)``."""


class EngineTimeout(TimeoutError):
    """A request exceeded its time budget: either the caller's
    ``result(timeout=...)`` wait elapsed, or the engine watchdog expired the
    request against the engine-wide ``request_timeout_s`` (in which case the
    handle is *failed* with this error — the request will never deliver an
    answer). Subclasses :class:`TimeoutError`."""


class EngineFault(RuntimeError):
    """The dispatcher thread died while this request was in flight; the
    watchdog failed the orphaned handle with this error and restarted the
    dispatcher. The request was *not* answered — resubmit if desired."""


class ResultHandle:
    """Future for one admitted request: ``result()`` blocks until the batch
    containing the request completes and returns ``(doc_ids i32[r, k],
    sqdist f32[r, k])`` — bit-identical to the offline engine on the same
    rows. ``deadline_missed`` is set (post-completion) when the answer landed
    after the request's deadline; the answer is still delivered.

    Resolution is **set-once**: the first of {answer, engine error, watchdog
    timeout, close} to land wins and every later attempt is a no-op, so the
    dispatcher completing a request the watchdog already expired cannot
    overwrite the timeout (and vice versa). ``degraded`` is True when the
    answer came from a degraded engine call (``on_fault="degrade"`` with
    quarantined blocks — DESIGN.md §10); ``report`` then carries the
    :class:`repro.core.faults.FaultReport`."""

    def __init__(self):
        self._done = threading.Event()
        self._lock = threading.Lock()
        self._value: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._error: Optional[BaseException] = None
        self.deadline_missed = False
        self.degraded = False
        self.report = None

    def _resolve(self, value) -> bool:
        """Attach the answer unless already resolved; True if this call won."""
        with self._lock:
            if self._done.is_set():
                return False
            self._value = value
            self._done.set()
            return True

    def _resolve_error(self, err: BaseException) -> bool:
        """Attach a failure unless already resolved; True if this call won."""
        with self._lock:
            if self._done.is_set():
                return False
            self._error = err
            self._done.set()
            return True

    # older internal spellings (kept for any external caller)
    _set = _resolve
    _set_error = _resolve_error

    def done(self) -> bool:
        """True once the request has an answer (or a failure) attached."""
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block (up to ``timeout`` seconds) for the answer; re-raises the
        engine-call exception if the dispatching batch failed. A ``timeout``
        elapsing raises :class:`EngineTimeout` (a :class:`TimeoutError`) —
        the request itself is still pending and may resolve later."""
        if not self._done.wait(timeout):
            raise EngineTimeout("request not completed within timeout")
        if self._error is not None:
            raise self._error
        return self._value


@dataclasses.dataclass
class _Pending:
    """One queued request (internal): rows + per-request engine settings,
    admit timestamp, absolute deadline / forcing point (engine clock), and
    the caller's handle."""

    rows: np.ndarray
    k: int
    beam: int
    t_admit: float
    deadline: Optional[float]
    force_t: float
    handle: ResultHandle


class LatencyRecorder:
    """Thread-safe per-request latency sink with percentile reporting.

    ``clock`` is the one timing seam: every duration is the difference of two
    ``clock()`` readings, monotonic by default (``time.perf_counter``) so an
    NTP step or a coarse wall clock can never corrupt the percentiles — the
    regression tests drive a fake clock through here and pin the arithmetic.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._lock = threading.Lock()
        self._samples: List[float] = []
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    def now(self) -> float:
        """One clock reading (the engine stamps admits/completions here so
        every timestamp shares the recorder's clock)."""
        return self.clock()

    def record(self, t_start: float, t_done: Optional[float] = None) -> float:
        """Append one latency sample ``t_done − t_start`` (``t_done`` defaults
        to now); returns the sample seconds."""
        if t_done is None:
            t_done = self.clock()
        lat = t_done - t_start
        with self._lock:
            self._samples.append(lat)
            if self._t_first is None:
                self._t_first = t_start
            self._t_last = t_done if self._t_last is None else max(self._t_last, t_done)
        return lat

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    def percentiles(self, qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
        """``{"p50": ms, ...}`` over all recorded samples (empty → zeros)."""
        with self._lock:
            samples = np.asarray(self._samples, np.float64)
        if samples.size == 0:
            return {f"p{int(q)}": 0.0 for q in qs}
        return {
            f"p{int(q)}": float(np.percentile(samples, q) * 1e3) for q in qs
        }

    def throughput(self) -> float:
        """Completed requests per second over the span from the first admit
        to the last completion (0.0 until two timestamps exist)."""
        with self._lock:
            n = len(self._samples)
            if n == 0 or self._t_first is None or self._t_last is None:
                return 0.0
            span = self._t_last - self._t_first
        return n / span if span > 0 else 0.0


def pow2_bucket(n: int) -> int:
    """Smallest power of two ≥ ``n`` (n ≥ 1) — the row-count bucket a request
    or batch lands in, mirroring ``_levels_bucket``'s pow2 discipline."""
    return 1 << max(int(n) - 1, 0).bit_length()


def pow2_pad_rows(x: np.ndarray, to: Optional[int] = None) -> Tuple[np.ndarray, int]:
    """Pad a row batch to ``to`` rows (default: the next power of two) by
    repeating the last row; returns ``(x_padded, n_real)``.

    Two jobs at once. (1) Compile bounding: the offline engines' jit
    signature includes the query batch's ``[n, d]`` shape, so without padding
    every distinct dynamic-batch size would compile afresh — the
    serving-batch application of the ``padded_chunk_rows`` bucketing
    discipline (DESIGN.md §6). (2) Bit-identity: an offline call on ``r``
    rows pads its chunk row *ids* to ``pow2_bucket(r)`` by repeating the last
    id — padding the row *content* the same way feeds the gathered scoring
    kernel the identical tensor, so a request executed inside a chunk-aligned
    batch answers bit-identically to its standalone call. Per-row
    independence makes the padded rows' answers discards: the dispatcher
    slices back to ``n_real`` before demuxing."""
    n = x.shape[0]
    m = pow2_bucket(n) if to is None else int(to)
    if m == n:
        return x, n
    return np.concatenate([x, np.repeat(x[-1:], m - n, axis=0)]), n


def make_search_fn(
    tree, *, mesh=None, corpus=None, chunk: Optional[int] = None,
    pipeline: Optional[int] = None, prefetch: Optional[int] = None,
    on_fault: Optional[str] = None, rp=None, rp_corpus=None, tuned=None,
    profiler=None,
) -> Callable[..., Tuple[np.ndarray, np.ndarray]]:
    """Adapt the offline engines to the ``search_fn(x, k, beam,
    chunk_rows=None)`` signature :class:`ServingEngine` dispatches through.

    ``mesh=None`` → :func:`topk_search` (single device; ``corpus`` unused).
    With a mesh → :func:`topk_search_sharded` over ``corpus`` — pass a
    pre-sharded handle (``backend.shard(mesh)`` or
    ``backend.shard_from_store``) so rows/partitions are placed once, not per
    batch. ``chunk_rows`` overrides the query chunk size for one call — the
    engine passes each fragment's request bucket here so every chunk gathers
    exactly one request's (padded) rows, which is what makes batched answers
    bit-identical to standalone calls (see :func:`pow2_pad_rows`). The
    returned callable carries the default chunk as ``fn.chunk`` so the engine
    knows when a request is too large to chunk-align.

    ``on_fault`` (DESIGN.md §10): ``None`` keeps the offline engines'
    default (``"raise"`` — unreadable corpus blocks fail the batch with a
    typed store error). ``"degrade"`` serves past quarantined blocks: calls
    return a third :class:`repro.core.faults.FaultReport` element, which the
    engine strips off the answer and surfaces as ``ResultHandle.degraded`` /
    ``.report``.

    ``rp``/``rp_corpus`` (DESIGN.md §5.1): a random-projection routing spec
    forwarded verbatim to the offline engines — the tree descends in the
    projected space, answers are exact-rescored from ``rp_corpus`` (or the
    RP backend's base). Incompatible with ``on_fault="degrade"``.

    Knob resolution (DESIGN.md §11): ``chunk``/``pipeline``/``prefetch``
    left ``None`` resolve through ``tuned=`` (a ``core.autotune.TunedKnobs``,
    e.g. loaded from the store's ``TUNE.json`` sidecar) then the repo
    defaults — resolved eagerly so ``fn.chunk`` is always a concrete int.
    ``profiler=`` (a ``core.profile.Profiler``) is forwarded to every
    offline-engine call; answers are unaffected."""
    chunk, pipeline, prefetch = resolve_knobs(
        tuned, chunk=chunk, pipeline=pipeline, prefetch=prefetch,
    )
    kw = {} if on_fault is None else {"on_fault": on_fault}
    if rp is not None:
        kw["rp"] = rp
        kw["rp_corpus"] = rp_corpus
    if profiler is not None:
        kw["profiler"] = profiler
    if mesh is None:
        def fn(x, k, beam, chunk_rows=None):
            return topk_search(
                tree, x, k=k, beam=beam, chunk=chunk_rows or chunk,
                pipeline=pipeline, prefetch=prefetch, **kw,
            )
    else:
        def fn(x, k, beam, chunk_rows=None):
            return topk_search_sharded(
                mesh, tree, x, corpus=corpus, k=k, beam=beam,
                chunk=chunk_rows or chunk, pipeline=pipeline,
                prefetch=prefetch, **kw,
            )
    fn.chunk = chunk
    fn.pipeline = pipeline
    fn.prefetch = prefetch
    fn.on_fault = on_fault
    return fn


class ServingEngine:
    """Continuous-batching front end over an offline search engine.

    ``search_fn(x f32[R, d], k, beam) -> (docs i32[R, k], dist f32[R, k])``
    is the execution seam — :func:`make_search_fn` builds it for the
    single-device, sharded, and store-backed paths; any callable with the
    same contract (per-row-independent answers) slots in.

    Parameters:

    - ``row_budget`` — max query rows per dispatched batch (the batch fills
      to this, then dispatches; one oversized request still dispatches alone
      — the offline engines chunk internally).
    - ``max_queue`` — admission bound in *requests*; a full queue sheds.
    - ``max_wait_s`` — idle dispatch latency cap: a batch never waits longer
      than this for more arrivals.
    - ``dispatch_margin_s`` — headroom subtracted from a request's deadline
      to get its forcing point (estimated service time, so dispatch happens
      early enough to matter).
    - ``cache``/``corpus_token`` — optional :class:`AnswerCache` pre-batch
      stage; the cache is bound to ``tree`` (required then) and
      ``corpus_token`` exactly like :func:`topk_search_cached`.
    - ``block_caches`` — ``BlockCache`` handles of a store-backed corpus;
      the engine resets their peak residency per batch and reports the
      largest per-batch disk working set.
    - ``clock`` — monotonic time source shared with the
      :class:`LatencyRecorder` (fake-clock seam for tests).
    - ``profiler`` — optional ``repro.core.profile.Profiler`` (DESIGN.md
      §11): records one ``"engine_batch"`` span per dispatched batch and one
      ``"engine_call"`` span per offline-engine call inside it; the default
      ``NULL_PROFILER`` is free.
    - ``request_timeout_s`` — engine-wide per-request time budget (admit →
      answer), enforced by the watchdog thread: an overdue request — still
      queued *or* in flight behind a wedged ``search_fn`` — is failed with
      :class:`EngineTimeout` so its caller unblocks. ``None`` (default)
      disables expiry; the watchdog still runs for dispatcher restarts.

    Use as a context manager; :meth:`close` drains admitted requests before
    stopping, so no accepted request is ever dropped. ``close(drain=False)``
    abandons queued/in-flight requests with :class:`EngineClosed` instead —
    the escape hatch when the search fn itself is wedged.
    """

    def __init__(
        self,
        search_fn: Callable[[np.ndarray, int, int], Tuple[np.ndarray, np.ndarray]],
        *,
        row_budget: int = 256,
        max_queue: int = 128,
        max_wait_s: float = 2e-3,
        dispatch_margin_s: float = 0.0,
        request_timeout_s: Optional[float] = None,
        cache: Optional[AnswerCache] = None,
        tree=None,
        corpus_token: Optional[str] = None,
        block_caches: Sequence = (),
        clock: Callable[[], float] = time.perf_counter,
        profiler=NULL_PROFILER,
    ):
        if row_budget < 1 or max_queue < 1:
            raise ValueError(
                f"row_budget and max_queue must be ≥ 1, got "
                f"{row_budget}/{max_queue}"
            )
        if max_wait_s < 0 or dispatch_margin_s < 0:
            raise ValueError("max_wait_s and dispatch_margin_s must be ≥ 0")
        if request_timeout_s is not None and request_timeout_s <= 0:
            raise ValueError(
                f"request_timeout_s must be > 0 when set, got "
                f"{request_timeout_s}"
            )
        if cache is not None and tree is None:
            raise ValueError("cache staging needs the tree to bind to")
        self.search_fn = search_fn
        try:
            self._accepts_chunk = (
                "chunk_rows" in inspect.signature(search_fn).parameters
            )
        except (TypeError, ValueError):
            self._accepts_chunk = False
        self._chunk_cap = int(getattr(search_fn, "chunk", 512))
        self.row_budget = int(row_budget)
        self.max_queue = int(max_queue)
        self.max_wait_s = float(max_wait_s)
        self.dispatch_margin_s = float(dispatch_margin_s)
        self.request_timeout_s = (
            None if request_timeout_s is None else float(request_timeout_s)
        )
        self.cache = cache
        self.profiler = profiler
        self.block_caches = tuple(block_caches)
        if cache is not None:
            cache.bind(tree, corpus_token)
        self.recorder = LatencyRecorder(clock)
        self._cv = threading.Condition()
        self._queue: "deque[_Pending]" = deque()
        self._closing = False
        self._abort = False
        self._inflight: Optional[List[_Pending]] = None
        # counters (under _cv's lock: the dispatcher and submit already hold it)
        self._admitted = 0
        self._shed = 0
        self._completed = 0
        self._failed = 0
        self._deadline_misses = 0
        self._timeouts = 0
        self._watchdog_restarts = 0
        self._degraded = 0
        self._n_batches = 0
        self._n_fragments = 0
        self._occupancy_sum = 0.0
        self._max_queue_depth = 0
        self._peak_batch_store_bytes = 0
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        self._watchdog_stop = threading.Event()
        self._watchdog_tick = (
            0.02 if self.request_timeout_s is None
            else min(0.02, self.request_timeout_s / 4.0)
        )
        self._watchdog_thread = threading.Thread(
            target=self._watchdog_loop, daemon=True
        )
        self._watchdog_thread.start()

    # ---------------------------------------------------------------- admit
    def submit(
        self, rows: np.ndarray, k: int = 10, beam: int = 4,
        deadline_s: Optional[float] = None,
    ) -> ResultHandle:
        """Admit one request (``rows`` f32[r, d] query vectors, per-request
        ``k``/``beam``, optional relative latency ``deadline_s``) and return
        its :class:`ResultHandle`.

        Raises :class:`EngineSaturated` (and counts a shed) when the bounded
        queue is full — admission control is immediate rejection, never
        unbounded queueing — and :class:`EngineClosed` after :meth:`close`."""
        rows = np.asarray(rows)
        if rows.ndim != 2 or rows.shape[0] < 1:
            raise ValueError(
                f"request rows must be [r ≥ 1, d], got shape {rows.shape}"
            )
        if k < 1 or beam < 1:
            raise ValueError(f"k and beam must be ≥ 1, got k={k} beam={beam}")
        t = self.recorder.now()
        force_t = t + self.max_wait_s
        deadline = None
        if deadline_s is not None:
            deadline = t + float(deadline_s)
            force_t = min(force_t, deadline - self.dispatch_margin_s)
        handle = ResultHandle()
        with self._cv:
            if self._closing:
                raise EngineClosed("engine is closed")
            if len(self._queue) >= self.max_queue:
                self._shed += 1
                raise EngineSaturated(
                    f"queue full ({self.max_queue} requests) — shed"
                )
            self._queue.append(_Pending(
                rows=rows, k=int(k), beam=int(beam), t_admit=t,
                deadline=deadline, force_t=force_t, handle=handle,
            ))
            self._admitted += 1
            self._max_queue_depth = max(self._max_queue_depth, len(self._queue))
            self._cv.notify()
        return handle

    @property
    def queue_depth(self) -> int:
        """Requests currently waiting for dispatch."""
        with self._cv:
            return len(self._queue)

    # ------------------------------------------------------------- dispatch
    def _take_batch(self) -> List[_Pending]:
        """Pop FIFO requests up to ``row_budget`` rows (caller holds the
        lock; always pops at least one)."""
        batch: List[_Pending] = [self._queue.popleft()]
        rows = batch[0].rows.shape[0]
        while self._queue and rows + self._queue[0].rows.shape[0] <= self.row_budget:
            nxt = self._queue.popleft()
            rows += nxt.rows.shape[0]
            batch.append(nxt)
        return batch

    def _loop(self) -> None:
        """Dispatcher thread: wait for fill-or-forcing-point, then execute.

        The in-flight batch is published as ``_inflight`` (set under the lock
        in the same critical section that pops it, cleared only after every
        handle is resolved) so the watchdog can expire or orphan-fail it —
        if this thread dies mid-batch, ``_inflight`` still names exactly the
        handles that would otherwise hang."""
        while True:
            with self._cv:
                while not self._queue:
                    if self._closing or self._abort:
                        return
                    self._cv.wait(0.05)
                # wait for the batch to fill — but never past the oldest
                # pending request's forcing point (the watchdog may expire
                # queued requests concurrently, so re-check for emptiness)
                while self._queue:
                    total = sum(p.rows.shape[0] for p in self._queue)
                    force_t = min(p.force_t for p in self._queue)
                    now = self.recorder.now()
                    if (total >= self.row_budget or now >= force_t
                            or self._closing or self._abort):
                        break
                    self._cv.wait(min(max(force_t - now, 0.0), 0.05))
                if self._abort:
                    return
                if not self._queue:
                    continue
                batch = self._take_batch()
                self._inflight = batch
            self._execute(batch)
            with self._cv:
                self._inflight = None

    # ------------------------------------------------------------- watchdog
    def _watchdog_loop(self) -> None:
        """Watchdog thread: one :meth:`_watchdog_pass` per tick until
        :meth:`close` stops it."""
        while not self._watchdog_stop.wait(self._watchdog_tick):
            self._watchdog_pass()

    def _watchdog_pass(self) -> None:
        """One watchdog sweep — the no-hang guarantee (DESIGN.md §10).

        (a) Dispatcher liveness: if the dispatcher thread died (a bug or
        BaseException below :meth:`_execute`'s own handler), fail its
        orphaned in-flight handles with :class:`EngineFault` and start a
        replacement dispatcher, so the engine keeps serving.
        (b) Request expiry (when ``request_timeout_s`` is set): fail every
        queued or in-flight request older than the budget with
        :class:`EngineTimeout` — resolution is set-once, so a later engine
        answer for an expired request is discarded, never double-counted."""
        with self._cv:
            stopped = self._closing or self._abort
            dead = not self._thread.is_alive()
        if dead and not stopped:
            with self._cv:
                orphans = list(self._inflight or [])
                self._inflight = None
                self._watchdog_restarts += 1
                replacement = threading.Thread(target=self._loop, daemon=True)
                self._thread = replacement
            err = EngineFault(
                "dispatcher thread died mid-batch; request abandoned "
                "(dispatcher restarted — resubmit if desired)"
            )
            n_orphaned = sum(
                1 for p in orphans if p.handle._resolve_error(err)
            )
            with self._cv:
                self._failed += n_orphaned
            replacement.start()
        budget = self.request_timeout_s
        if budget is None:
            return
        now = self.recorder.now()
        expired: List[_Pending] = []
        with self._cv:
            if any(now - p.t_admit > budget for p in self._queue):
                keep: "deque[_Pending]" = deque()
                for p in self._queue:
                    (expired if now - p.t_admit > budget else keep).append(p)
                self._queue = keep
            expired.extend(
                p for p in (self._inflight or [])
                if now - p.t_admit > budget
            )
        if not expired:
            return
        n_timed_out = 0
        for p in expired:
            err = EngineTimeout(
                f"request exceeded request_timeout_s={budget:g}s "
                f"(admitted {now - p.t_admit:.3f}s ago) — expired by the "
                f"engine watchdog"
            )
            if p.handle._resolve_error(err):
                n_timed_out += 1
        with self._cv:
            self._timeouts += n_timed_out
            self._failed += n_timed_out

    def _fragments(self, batch: List[_Pending]):
        """Group a drained batch by (k, beam, request row bucket), preserving
        FIFO order within each group — one engine call per distinct setting
        and pow2 size class, so the chunk-aligned dispatch (see
        :meth:`_execute`) keeps every request's answer bit-identical to its
        standalone offline call. Requests too large to chunk-align (rows >
        the search fn's default chunk) get ``bucket None`` and dispatch solo
        with offline semantics."""
        groups: "Dict[Tuple[int, int, Optional[int]], List[_Pending]]" = {}
        for p in batch:
            r = p.rows.shape[0]
            bucket = None if r > self._chunk_cap else pow2_bucket(r)
            groups.setdefault((p.k, p.beam, bucket), []).append(p)
        return groups

    def _call(self, x, k, beam, chunk_rows=None):
        """One offline-engine call, forwarding ``chunk_rows`` only when the
        search fn takes it (custom callables without the seam still work —
        they just don't get the chunk-alignment bit-identity guarantee).

        Normalizes the return to ``(docs, dist, report)``: degrade-mode
        engines (``on_fault="degrade"``) return a third
        :class:`repro.core.faults.FaultReport` element; plain engines get
        ``report=None``."""
        with self.profiler.span("engine_call"):
            if chunk_rows is not None and self._accepts_chunk:
                out = self.search_fn(x, k, beam, chunk_rows=chunk_rows)
            else:
                out = self.search_fn(x, k, beam)
        if len(out) == 3:
            docs, dist, report = out
        else:
            docs, dist = out
            report = None
        return np.asarray(docs), np.asarray(dist), report

    def _run_fragment(self, group: List[_Pending], k: int, beam: int,
                      bucket: Optional[int]):
        """Execute one (k, beam, bucket) fragment and return per-request
        ``(docs, dist)`` answers in group order.

        Chunk-aligned dispatch (``bucket`` set): each request's rows are
        padded to the bucket, concatenated, and run with ``chunk_rows =
        bucket`` — every query chunk then gathers exactly one request's
        (padded) rows, the same tensor its standalone offline call gathers,
        so answers are bit-identical per request. The fragment's chunk count
        is padded to a power of two as well (whole dummy chunks of the last
        row) so compiles stay bounded per (bucket, pow2 chunk count), not per
        batch composition. ``bucket None`` (oversized requests) dispatches
        each request in the group alone with the search fn's own default
        chunking — the literal offline call per request.

        With a cache staged, hit rows are answered without engine rows and
        the deduplicated miss batch runs at ``chunk_rows = 1`` — each cache
        entry is then the bit-exact answer of a standalone single-row call,
        so repeat single-row requests stay bit-identical however they
        batch. A *degraded* miss batch (on_fault="degrade" with quarantined
        blocks) is scattered to its requests but **not** inserted into the
        cache — a degraded answer must never outlive the fault that produced
        it.

        Answers come back as ``(docs, dist, report)`` triples; in a
        chunk-aligned fragment every request shares the fragment's report
        (corpus-side quarantine affects the whole call)."""
        if bucket is None:
            return [self._call(p.rows, k, beam) for p in group]
        x, bounds = concat_request_rows([p.rows for p in group])
        if self.cache is not None:
            report = None
            docs, dist, miss = cache_stage(self.cache, x, k, beam)
            if miss:
                rep = np.asarray([rows[0] for rows in miss.values()])
                xm, n_miss = pow2_pad_rows(x[rep])
                d_new, s_new, report = self._call(xm, k, beam, chunk_rows=1)
                if report is not None and report.degraded:
                    # scatter only — degraded answers stay out of the cache
                    for j, (_, rows) in enumerate(miss.items()):
                        for i in rows:
                            docs[i], dist[i] = d_new[j], s_new[j]
                else:
                    cache_fill(self.cache, miss, d_new[:n_miss],
                               s_new[:n_miss], docs, dist)
            return [
                (d, s, report)
                for d, s in split_batch_answers(docs, dist, bounds)
            ]
        parts = [pow2_pad_rows(p.rows, to=bucket)[0] for p in group]
        n_pad = pow2_bucket(len(parts)) - len(parts)
        parts.extend(np.repeat(parts[-1][-1:], bucket, axis=0)
                     for _ in range(n_pad))
        xb, _ = concat_request_rows(parts)
        d_all, s_all, report = self._call(xb, k, beam, chunk_rows=bucket)
        return [
            (d_all[i * bucket:i * bucket + p.rows.shape[0]].copy(),
             s_all[i * bucket:i * bucket + p.rows.shape[0]].copy(),
             report)
            for i, p in enumerate(group)
        ]

    def _execute(self, batch: List[_Pending]) -> None:
        """Run one dispatched batch: per-(k, beam, bucket) fragment through
        :meth:`_run_fragment`, then answer demux, latency + occupancy +
        per-batch store-residency accounting."""
        for c in self.block_caches:
            c.reset_peak()
        n_frags = 0
        batch_span = self.profiler.span("engine_batch")
        batch_span.__enter__()
        try:
            for (k, beam, bucket), group in self._fragments(batch).items():
                n_frags += 1
                answers = self._run_fragment(group, k, beam, bucket)
                if len(answers) != len(group):
                    raise RuntimeError(
                        f"fragment (k={k}, beam={beam}, bucket={bucket}) "
                        f"returned {len(answers)} answers for "
                        f"{len(group)} requests"
                    )
                for p, (d, s, report) in zip(group, answers):
                    t_done = self.recorder.now()
                    missed = p.deadline is not None and t_done > p.deadline
                    degraded = report is not None and report.degraded
                    p.handle.deadline_missed = missed
                    p.handle.degraded = degraded
                    p.handle.report = report
                    if p.handle._resolve((d, s)):
                        # a watchdog-expired handle keeps its timeout;
                        # only a winning resolve counts as completed
                        self.recorder.record(p.t_admit, t_done)
                        with self._cv:
                            self._completed += 1
                            if missed:
                                self._deadline_misses += 1
                            if degraded:
                                self._degraded += 1
        except BaseException as e:
            for p in batch:
                if p.handle._resolve_error(e):
                    with self._cv:
                        self._failed += 1
        finally:
            batch_span.__exit__(None, None, None)
            store_peak = sum(
                int(c.peak_resident_bytes) for c in self.block_caches
            )
            with self._cv:
                self._n_batches += 1
                self._n_fragments += n_frags
                self._occupancy_sum += (
                    sum(p.rows.shape[0] for p in batch) / self.row_budget
                )
                self._peak_batch_store_bytes = max(
                    self._peak_batch_store_bytes, store_peak
                )

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Serving report snapshot: latency percentiles (ms), QPS, admission
        counters (admitted/completed/shed/failed/deadline_misses), queue
        depth (current + high-water), batch counts + mean row occupancy,
        per-batch peak store residency, and the answer-cache stats when one
        is staged."""
        with self._cv:
            snap = dict(
                admitted=self._admitted,
                completed=self._completed,
                shed=self._shed,
                failed=self._failed,
                deadline_misses=self._deadline_misses,
                timeouts=self._timeouts,
                watchdog_restarts=self._watchdog_restarts,
                degraded=self._degraded,
                queue_depth=len(self._queue),
                max_queue_depth=self._max_queue_depth,
                n_batches=self._n_batches,
                n_fragments=self._n_fragments,
                batch_occupancy=(
                    self._occupancy_sum / self._n_batches
                    if self._n_batches else 0.0
                ),
                peak_batch_store_bytes=self._peak_batch_store_bytes,
            )
        snap["latency_ms"] = self.recorder.percentiles()
        snap["qps"] = self.recorder.throughput()
        if self.cache is not None:
            snap["cache"] = self.cache.stats
        return snap

    # ---------------------------------------------------------------- close
    def close(self, drain: bool = True) -> None:
        """Stop admitting and shut down (idempotent).

        ``drain=True`` (default): every already-admitted request completes
        before the dispatcher joins — no accepted request is ever dropped.
        ``drain=False``: queued and in-flight requests are *failed* with
        :class:`EngineClosed` immediately, so their callers unblock even if
        the search fn is wedged; the dispatcher thread is abandoned (daemon)
        if it does not exit within a grace period and any late answer it
        produces is discarded by set-once resolution."""
        with self._cv:
            self._closing = True
            dropped: List[_Pending] = []
            if not drain:
                self._abort = True
                dropped = list(self._queue)
                self._queue.clear()
                dropped.extend(self._inflight or [])
            self._cv.notify_all()
        if drain:
            self._thread.join()
        else:
            err = EngineClosed(
                "engine closed with drain=False; request abandoned"
            )
            n_dropped = sum(
                1 for p in dropped if p.handle._resolve_error(err)
            )
            with self._cv:
                self._failed += n_dropped
            self._thread.join(timeout=1.0)
        self._watchdog_stop.set()
        self._watchdog_thread.join(timeout=1.0)

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
