"""Out-of-core corpus store — the paper's "efficient disk based
implementations where space requirements exceed that of main memory"
(DESIGN.md §9).

The corpus lives on disk as **fixed-size, chunk-aligned blocks** plus a small
JSON manifest; only a bounded set of blocks is ever resident. Two block
layouts mirror the two vector backends (DESIGN.md §5):

- ``kind="dense"`` — each block is one ``.npy`` file holding
  ``f[block_docs, d]`` rows;
- ``kind="ell"``   — each block is a pair of ``.npy`` files,
  ``values f[block_docs, nnz_max]`` + ``cols i32[block_docs, nnz_max]``
  (the ELL layout the ``ell_spmm`` kernel scores; padding slots are
  value 0 / col 0).

The last block is zero-padded to ``block_docs`` so every file has the same
shape (mmap-friendly); the manifest records the true ``n_docs`` and readers
never address the padding.

Residency is governed by :class:`BlockCache` — an LRU over decoded blocks
with a byte budget. Sequential consumers (streaming build, store-backed
queries) touch blocks in row order, so a budget of even one block streams the
whole corpus through bounded memory; random access degrades gracefully to
re-reads. Each block file's blake2b digest is recorded in the manifest at
write time, and :func:`CorpusStore.manifest_hash` hashes the canonical
manifest — a content token that changes whenever the corpus is regenerated in
place **or grown by** :meth:`CorpusStore.append` (the answer-cache and
checkpoint staleness guards key on it, DESIGN.md §8/§9).

Serving-plane seams (DESIGN.md §8/§9): :class:`Prefetcher` is the async
reader thread that moves disk decodes off the dispatch path (build, query,
and streamed ground truth share it; answers are bit-identical to the
synchronous scans), and :meth:`CorpusStore.partition` splits the corpus into
per-shard row ranges with independent block caches — the disk side of
store-backed ``topk_search_sharded``. :meth:`CorpusStore.append` closes the
loop for growing corpora: ``ktree.insert_into_store`` spills newly inserted
leaf vectors into the padding tail of the last block plus freshly appended
block files, atomically extending the manifest.

This module is deliberately numpy/host-only (no jax imports): stores cross no
jit boundary. The device-side seam is ``repro.core.backend.from_store`` —
chunk-sized in-memory backends materialised from store rows on demand.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

MANIFEST_NAME = "manifest.json"
FORMAT_TAG = "ktree-store-v1"
DEFAULT_BLOCK_DOCS = 4096
DEFAULT_BUDGET_BYTES = 256 * 1024 * 1024


class BlockCache:
    """LRU cache of decoded corpus blocks under a byte budget.

    ``loader(block_id) -> dict[str, np.ndarray]`` decodes one block from disk;
    the cache accounts ``nbytes`` of every array it holds and evicts
    least-recently-used blocks once the budget is exceeded. A single block
    larger than the whole budget is still admitted (the floor of residency is
    one block — nothing works below that), evicting everything else.

    ``hits``/``misses``/``evictions`` feed the out-of-core bench and the
    serving report (benchmarks/oocore.py, ``launch/serve.py --store``).

    Thread safety: a :class:`Prefetcher` reader thread may race the consumer
    loop on the same cache, so ``get`` runs under a lock — every call
    increments exactly one of hits/misses and the byte accounting (incl. the
    one-block residency floor) stays exact under concurrency. Disk decode
    happens inside the lock: concurrent readers of one store serialise on I/O
    rather than double-loading a block and double-counting its bytes.
    """

    def __init__(self, budget_bytes: int, loader):
        if budget_bytes < 1:
            raise ValueError(f"budget_bytes must be ≥ 1, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self._loader = loader
        self._blocks: "Dict[int, Dict[str, np.ndarray]]" = {}
        self._lru: List[int] = []  # least-recent first
        self._bytes = 0
        self._peak = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def _block_bytes(arrays: Dict[str, np.ndarray]) -> int:
        """Total decoded size of one block's arrays."""
        return sum(int(a.nbytes) for a in arrays.values())

    def get(self, block_id: int) -> Dict[str, np.ndarray]:
        """The decoded arrays of ``block_id``, loading + evicting as needed."""
        with self._lock:
            if block_id in self._blocks:
                self.hits += 1
                self._lru.remove(block_id)
                self._lru.append(block_id)
                return self._blocks[block_id]
            self.misses += 1
            arrays = self._loader(block_id)
            self._bytes += self._block_bytes(arrays)
            self._peak = max(self._peak, self._bytes)
            self._blocks[block_id] = arrays
            self._lru.append(block_id)
            while self._bytes > self.budget_bytes and len(self._lru) > 1:
                old = self._lru.pop(0)
                self._bytes -= self._block_bytes(self._blocks.pop(old))
                self.evictions += 1
            return arrays

    def drop(self, block_id: int) -> None:
        """Forget a resident block without counting an eviction — staleness
        invalidation (a block file rewritten by :meth:`CorpusStore.append`),
        not budget pressure."""
        with self._lock:
            if block_id in self._blocks:
                self._bytes -= self._block_bytes(self._blocks.pop(block_id))
                self._lru.remove(block_id)

    @property
    def resident_bytes(self) -> int:
        """Bytes currently held across all resident blocks."""
        return self._bytes

    @property
    def peak_resident_bytes(self) -> int:
        """High-water residency since construction or the last
        :meth:`reset_peak` — the per-batch accounting seam the serving engine
        (``core/engine.py``) reads: reset before a batch dispatch, read after,
        and the difference window is exactly that batch's disk working set."""
        return self._peak

    def reset_peak(self) -> int:
        """Restart peak tracking at the current residency; returns the peak
        of the window just closed (so per-batch accounting is one call)."""
        with self._lock:
            prev = self._peak
            self._peak = self._bytes
            return prev

    @property
    def stats(self) -> dict:
        """hit/miss/eviction counters + residency for reports."""
        total = self.hits + self.misses
        return dict(
            hits=self.hits, misses=self.misses, evictions=self.evictions,
            hit_rate=self.hits / total if total else 0.0,
            resident_bytes=self._bytes, resident_blocks=len(self._lru),
            peak_resident_bytes=self._peak, budget_bytes=self.budget_bytes,
        )


class Prefetcher:
    """Bounded async reader: applies ``fetch`` to each request from
    ``requests`` on a daemon worker thread, keeping up to ``depth`` finished
    results buffered ahead of the consumer (the worker may additionally have
    one fetch in flight while the buffer is full).

    Iterating yields ``(request, result)`` pairs in request order — results
    are the same objects a synchronous ``fetch`` loop would produce, so
    consumers are bit-identical to the unprefetched path; only the disk read
    moves off the dispatch path (DESIGN.md §9: the next block's read overlaps
    device compute *and* the current chunk's D2H copy-out, where the
    ``pipeline`` dispatch-ahead alone still serialised read → dispatch).
    A ``fetch`` exception is re-raised at the consumer's next step. Use as a
    context manager (or call :meth:`close`) to stop the worker early;
    exhausting the iterator joins it automatically.
    """

    _DONE = object()
    _ERR = object()

    def __init__(self, requests: Iterable, fetch: Callable, depth: int = 1):
        if depth < 1:
            raise ValueError(f"prefetch depth must be ≥ 1, got {depth}")
        self.depth = int(depth)
        self._results: "queue.Queue" = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        self._requests = iter(requests)
        self._fetch = fetch
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        """Worker loop: fetch ahead until the requests run dry or close()."""
        try:
            for req in self._requests:
                if self._stop.is_set():
                    return
                item = (req, self._fetch(req))
                while not self._stop.is_set():
                    try:
                        self._results.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
            self._put_final((Prefetcher._DONE, None))
        except BaseException as e:  # surfaced at the consumer's next step
            self._put_final((Prefetcher._ERR, e))

    def _put_final(self, item):
        """Enqueue the terminal marker without deadlocking against close()."""
        while not self._stop.is_set():
            try:
                self._results.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Tuple[object, object]]:
        while not self._stop.is_set():
            try:
                tag, payload = self._results.get(timeout=0.1)
            except queue.Empty:
                continue
            if tag is Prefetcher._DONE:
                self._thread.join()
                return
            if tag is Prefetcher._ERR:
                self._thread.join()
                raise payload
            yield tag, payload

    def close(self) -> None:
        """Stop the worker and discard buffered results (idempotent)."""
        self._stop.set()
        while True:
            try:
                self._results.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _digest(path: str) -> str:
    """blake2b-128 hex digest of one block file's raw bytes."""
    h = hashlib.blake2b(digest_size=16)
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _save_block(dir_path: str, name: str, arr: np.ndarray) -> Tuple[str, str]:
    """Write one block array; returns (file name, content digest)."""
    fname = name + ".npy"
    np.save(os.path.join(dir_path, fname), arr)
    return fname, _digest(os.path.join(dir_path, fname))


def _replace_block(dir_path: str, fname: str, arr: np.ndarray) -> str:
    """Atomically (re)write one block file in a *live* store directory (tmp +
    ``os.replace``, so readers never observe a half-written block); returns
    the new content digest. The append path's per-file counterpart of
    :func:`_save_block` (which writes into a not-yet-installed tmp dir)."""
    tmp = os.path.join(dir_path, fname + ".tmp")
    with open(tmp, "wb") as f:
        np.save(f, arr)
    os.replace(tmp, os.path.join(dir_path, fname))
    return _digest(os.path.join(dir_path, fname))


def _install_dir(tmp: str, path: str) -> None:
    """Install a fully-written ``tmp`` directory at ``path`` without ever
    destroying existing data before its replacement is in place: the old
    directory is moved aside, the new one renamed in, and only then is the
    old one removed. A crash mid-replace leaves the previous data at
    ``path + ".old"`` instead of gone."""
    old = path.rstrip("/") + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    had_old = os.path.exists(path)
    if had_old:
        os.rename(path, old)
    os.rename(tmp, path)
    if had_old:
        shutil.rmtree(old)


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad ``arr`` along axis 0 up to ``rows`` (fixed-size blocks)."""
    if arr.shape[0] == rows:
        return np.ascontiguousarray(arr)
    pad = np.zeros((rows - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([np.ascontiguousarray(arr), pad], axis=0)


def save_store(path: str, corpus, block_docs: int = DEFAULT_BLOCK_DOCS) -> str:
    """Write a corpus to an on-disk block store; returns ``path``.

    ``corpus``: a dense ``f[N, d]`` array (→ ``kind="dense"``), a
    :class:`repro.sparse.Csr`, or an existing
    :class:`repro.core.backend.EllSparseBackend` / ``DenseBackend``
    (→ layout follows the backend). ``block_docs`` is the fixed rows-per-block
    granularity (the unit of disk I/O and cache residency).

    The write lands in ``path.tmp`` and is installed by rename, so a crash
    mid-write never leaves a half-readable store at ``path``. Replacing an
    existing store moves the old directory aside (``path.old``) before the
    rename and removes it only after the new store is in place — a crash in
    the replace window leaves the previous corpus intact at ``path.old``
    (plus possibly the finished rewrite at ``path.tmp``), never destroyed.
    Readers opened before the rewrite keep their (now stale) manifest, which
    is exactly what :func:`CorpusStore.manifest_hash` exists to detect.
    """
    from repro.core.backend import DenseBackend, EllSparseBackend, make_backend
    from repro.sparse.csr import Csr

    if block_docs < 1:
        raise ValueError(f"block_docs must be ≥ 1, got {block_docs}")
    if isinstance(corpus, Csr):
        corpus = make_backend(corpus, "sparse")
    if isinstance(corpus, (DenseBackend, EllSparseBackend)) is False:
        corpus = make_backend(np.asarray(corpus), "dense")

    tmp = path.rstrip("/") + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    n_docs = corpus.n_docs
    n_blocks = max(-(-n_docs // block_docs), 1)
    blocks = []
    if isinstance(corpus, DenseBackend):
        x = np.asarray(corpus.x)
        kind, dim, nnz_max = "dense", int(x.shape[1]), None
        dtype = str(x.dtype)
        for i in range(n_blocks):
            blk = _pad_rows(x[i * block_docs:(i + 1) * block_docs], block_docs)
            fname, dig = _save_block(tmp, f"dense_{i:05d}", blk)
            blocks.append({"i": i, "files": {"x": fname}, "digest": dig})
    else:
        values = np.asarray(corpus.values)
        cols = np.asarray(corpus.cols, dtype=np.int32)
        kind, dim, nnz_max = "ell", int(corpus.n_cols), int(values.shape[1])
        dtype = str(values.dtype)
        for i in range(n_blocks):
            sl = slice(i * block_docs, (i + 1) * block_docs)
            fv, dv = _save_block(tmp, f"ell_values_{i:05d}",
                                 _pad_rows(values[sl], block_docs))
            fc, dc = _save_block(tmp, f"ell_cols_{i:05d}",
                                 _pad_rows(cols[sl], block_docs))
            # digest concatenation follows sorted field-name order ("cols"
            # then "values") — the same order open_store's verify recomputes
            blocks.append({"i": i, "files": {"values": fv, "cols": fc},
                           "digest": dc + dv})

    manifest = {
        "format": FORMAT_TAG, "kind": kind, "n_docs": int(n_docs),
        "dim": dim, "dtype": dtype, "block_docs": int(block_docs),
        "n_blocks": int(n_blocks), "nnz_max": nnz_max, "blocks": blocks,
    }
    with open(os.path.join(tmp, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    _install_dir(tmp, path)
    return path


@dataclasses.dataclass
class CorpusStore:
    """A memory-mapped, block-cached view of an on-disk corpus.

    Open with :func:`open_store`. Exposes the corpus *shape* (``n_docs``,
    ``dim``, ``kind``, ``nnz_max``) and row access (:meth:`take_rows`) through
    the :class:`BlockCache`; device-side consumers go through
    ``repro.core.backend.from_store`` (chunk backends) or
    ``repro.core.ktree.build_from_store`` (streaming build). A store is a
    host-side handle — it is **not** a pytree and never crosses jit.
    """

    path: str
    manifest: dict
    cache: BlockCache

    # -- shape / identity ---------------------------------------------------
    @property
    def kind(self) -> str:
        """Block layout: ``"dense"`` or ``"ell"``."""
        return self.manifest["kind"]

    @property
    def n_docs(self) -> int:
        """True corpus row count (excludes last-block padding)."""
        return self.manifest["n_docs"]

    @property
    def dim(self) -> int:
        """Vector dimensionality (``n_cols`` for ELL stores)."""
        return self.manifest["dim"]

    @property
    def block_docs(self) -> int:
        """Rows per fixed-size block (the I/O + residency granule)."""
        return self.manifest["block_docs"]

    @property
    def n_blocks(self) -> int:
        """Number of block files."""
        return self.manifest["n_blocks"]

    @property
    def nnz_max(self) -> Optional[int]:
        """ELL padding width (None for dense stores)."""
        return self.manifest["nnz_max"]

    @property
    def dtype(self) -> np.dtype:
        """Element dtype of the stored vectors (``cols`` is always i32)."""
        return np.dtype(self.manifest["dtype"])

    @property
    def nbytes(self) -> int:
        """Total decoded corpus bytes across all blocks (dense rows or
        ELL values+cols) — what "corpus exceeds the residency budget" is
        measured against."""
        itemsize = np.dtype(self.manifest["dtype"]).itemsize
        rows = self.n_blocks * self.block_docs
        if self.kind == "dense":
            return rows * self.dim * itemsize
        return rows * self.nnz_max * (itemsize + 4)

    @property
    def manifest_hash(self) -> str:
        """Content token: blake2b-128 of the canonical manifest JSON.

        The manifest embeds every block file's digest, so regenerating the
        corpus in place (same path, different data) yields a different hash —
        the staleness key for answer caches and manifest-reference
        checkpoints. Memoised per handle (the manifest is immutable once
        opened; serving passes this token on every batch)."""
        h = self.__dict__.get("_manifest_hash")
        if h is None:
            blob = json.dumps(self.manifest, sort_keys=True).encode()
            h = hashlib.blake2b(blob, digest_size=16).hexdigest()
            self.__dict__["_manifest_hash"] = h
        return h

    # -- block access -------------------------------------------------------
    def _load_block(self, i: int) -> Dict[str, np.ndarray]:
        """Decode block ``i`` from disk (mmap → private in-memory copy, so the
        cache's byte accounting matches actual residency)."""
        entry = self.manifest["blocks"][i]
        out = {}
        for name, fname in entry["files"].items():
            arr = np.load(os.path.join(self.path, fname), mmap_mode="r")
            out[name] = np.array(arr)  # materialise: residency is the point
        return out

    def read_block(self, i: int) -> Dict[str, np.ndarray]:
        """Block ``i``'s arrays through the LRU cache (padded to
        ``block_docs`` rows — use :meth:`block_rows` for the valid range)."""
        if not 0 <= i < self.n_blocks:
            raise IndexError(f"block {i} out of range [0, {self.n_blocks})")
        return self.cache.get(i)

    def block_rows(self, i: int) -> Tuple[int, int]:
        """Global row range ``[lo, hi)`` of valid docs in block ``i``."""
        lo = i * self.block_docs
        return lo, min(lo + self.block_docs, self.n_docs)

    def iter_blocks(
        self, prefetch: int = 0
    ) -> Iterator[Tuple[int, int, Dict[str, np.ndarray]]]:
        """Yield ``(lo, hi, arrays)`` per block in row order — the streaming
        scan pattern (arrays still padded; slice ``[:hi-lo]``).

        ``prefetch ≥ 1`` moves the block reads onto a :class:`Prefetcher`
        reader thread of that depth, so the next block's disk decode overlaps
        the consumer's work on the current one; the yielded arrays are the
        same cache entries the synchronous scan returns."""
        if prefetch:
            with Prefetcher(range(self.n_blocks), self.read_block,
                            depth=prefetch) as pf:
                for i, arrays in pf:
                    lo, hi = self.block_rows(i)
                    yield lo, hi, arrays
            return
        for i in range(self.n_blocks):
            lo, hi = self.block_rows(i)
            yield lo, hi, self.read_block(i)

    def take_rows(self, rows: np.ndarray) -> Dict[str, np.ndarray]:
        """Gather arbitrary global rows as host arrays.

        Returns ``{"x": f[B, d]}`` (dense) or
        ``{"values": f[B, nnz_max], "cols": i32[B, nnz_max]}`` (ELL). Rows are
        fetched block-by-block through the cache, so a contiguous chunk costs
        one or two block reads; out-of-range ids raise."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_docs):
            raise IndexError(
                f"row ids outside [0, {self.n_docs}): "
                f"[{rows.min()}, {rows.max()}]"
            )
        names = ("x",) if self.kind == "dense" else ("values", "cols")
        out = {
            name: np.empty(
                (rows.size,) + self._field_shape(name),
                self._field_dtype(name),
            )
            for name in names
        }
        blk = rows // self.block_docs
        for b in np.unique(blk):
            arrays = self.read_block(int(b))
            sel = np.nonzero(blk == b)[0]
            local = rows[sel] - int(b) * self.block_docs
            for name in names:
                out[name][sel] = arrays[name][local]
        return out

    def _field_shape(self, name: str) -> Tuple[int, ...]:
        """Per-row trailing shape of a stored field."""
        return (self.dim,) if name == "x" else (self.nnz_max,)

    def _field_dtype(self, name: str):
        """Dtype of a stored field."""
        return np.int32 if name == "cols" else np.dtype(self.manifest["dtype"])

    def view(self, lo: int = 0, hi: Optional[int] = None) -> "StoreSlice":
        """A row-range view ``[lo, hi)`` of this store — same cache, same
        disk; lets callers query a subset (e.g. the first ``nq`` docs) without
        materialising it."""
        return StoreSlice(self, lo, self.n_docs if hi is None else hi)

    def partition(
        self, n_shards: int, budget_bytes: Optional[int] = None
    ) -> List["StoreSlice"]:
        """Split the corpus into ``n_shards`` contiguous row ranges, each a
        :class:`StoreSlice` over its **own** fresh :class:`BlockCache` — the
        disk side of shard-parallel serving (DESIGN.md §8/§9).

        Shard ``s`` owns global rows ``[s·L, (s+1)·L) ∩ [0, n_docs)`` with
        ``L = ⌈n_docs / n_shards⌉`` — the same extent
        ``distributed.shard_rows`` gives a row-sharded in-memory corpus, so
        per-shard ownership agrees with ``*DocShards`` exactly. Each
        partition's cache holds ``budget_bytes`` (default: this handle's
        budget), so total store residency is bounded by
        ``n_shards × budget_bytes`` (plus the per-cache one-block floor);
        partitions share the disk files but no cache state with this handle
        or each other. A boundary block straddling two shards may be resident
        in both caches — that double-count is included in the bound."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be ≥ 1, got {n_shards}")
        budget = self.cache.budget_bytes if budget_bytes is None else int(budget_bytes)
        ext = -(-self.n_docs // n_shards)
        parts = []
        for s in range(n_shards):
            h = CorpusStore(path=self.path, manifest=self.manifest, cache=None)  # type: ignore[arg-type]
            h.cache = BlockCache(budget, h._load_block)
            parts.append(h.view(min(s * ext, self.n_docs),
                                min((s + 1) * ext, self.n_docs)))
        return parts

    # -- growth (insert-into-store, DESIGN.md §9) ---------------------------
    def append(self, corpus) -> str:
        """Append rows to the on-disk corpus; returns the **rotated**
        ``manifest_hash``.

        ``corpus`` (dense array / Csr / backend) is normalised to this
        store's exact block layout first
        (``backend.backend_for_store_layout`` — same ``dim``/``dtype``, and
        for ELL stores the same ``nnz_max`` width, truncating longer rows
        exactly like an explicit-``nnz_max`` backend). New rows take global
        ids ``[n_docs, n_docs + B)``: the last block's zero-padding tail is
        filled first (the merged block lands in a **fresh generation-named
        file** — the old tail file is left untouched), then whole new block
        files are appended, and finally the manifest is atomically replaced
        with the extended block list, new digests, and the new ``n_docs`` —
        a crash at any point leaves the *previous* manifest fully consistent
        *and verifiable* (``open_store(verify=True)`` still passes: every
        file the old manifest references is unmodified; files written by the
        interrupted append are unreferenced orphans, reclaimed when a later
        append reuses their names or the store is rewritten).

        This handle's manifest and content token move to the appended state
        (the memoised hash is recomputed — ``AnswerCache``/``restore_index``
        consumers holding the old token correctly treat the grown corpus as
        new content); the rewritten block is dropped from its cache. Handles
        and partitions opened *before* the append keep their old manifest —
        their ``[0, old n_docs)`` reads stay correct, they just don't see the
        new rows until reopened."""
        from repro.core.backend import backend_for_store_layout

        be = backend_for_store_layout(self, corpus)
        if self.kind == "dense":
            new_fields = {"x": np.asarray(be.x)}
        else:
            new_fields = {"values": np.asarray(be.values),
                          "cols": np.asarray(be.cols, np.int32)}
        b_new = next(iter(new_fields.values())).shape[0]
        if b_new == 0:
            return self.manifest_hash
        n0, bd = self.n_docs, self.block_docs
        last = self.n_blocks - 1
        valid_in_last = n0 - last * bd
        blocks = [dict(e) for e in self.manifest["blocks"]]

        def _write(i: int, rows: Dict[str, np.ndarray], gen: str = "") -> dict:
            # per-field digest layout must match save_store exactly; ``gen``
            # suffixes the rewritten tail block's file names so the file the
            # OLD manifest references is never touched (n_docs strictly
            # grows, so generation names are unique per append)
            if self.kind == "dense":
                fx = f"dense_{i:05d}{gen}.npy"
                return {"i": i, "files": {"x": fx},
                        "digest": _replace_block(self.path, fx,
                                                 _pad_rows(rows["x"], bd))}
            fv = f"ell_values_{i:05d}{gen}.npy"
            fc = f"ell_cols_{i:05d}{gen}.npy"
            dv = _replace_block(self.path, fv, _pad_rows(rows["values"], bd))
            dc = _replace_block(self.path, fc, _pad_rows(rows["cols"], bd))
            return {"i": i, "files": {"values": fv, "cols": fc},
                    "digest": dc + dv}

        def _slice(lo: int, hi: int) -> Dict[str, np.ndarray]:
            return {k: v[lo:hi] for k, v in new_fields.items()}

        # every file is written before the manifest replace, and none of them
        # is referenced by the old manifest (the merged tail block gets a
        # fresh generation name), so a crash anywhere leaves the old manifest
        # consistent and verifiable; the superseded tail file becomes an
        # unreferenced orphan once the new manifest lands
        consumed = min(bd - valid_in_last, b_new) if valid_in_last < bd else 0
        new_entries = []
        start = consumed
        i = last + 1
        while start < b_new:
            new_entries.append(_write(i, _slice(start, start + bd)))
            start += bd
            i += 1
        rewritten = None
        if consumed:
            old = self._load_block(last)  # direct read: no cache-stats noise
            merged = {
                k: np.concatenate(
                    [old[k][:valid_in_last], new_fields[k][:consumed]], axis=0
                )
                for k in new_fields
            }
            rewritten = _write(last, merged, gen=f"_g{n0 + b_new:09d}")
            blocks[last] = rewritten

        manifest = dict(self.manifest)
        manifest["blocks"] = blocks + new_entries
        manifest["n_docs"] = n0 + b_new
        manifest["n_blocks"] = len(manifest["blocks"])
        mtmp = os.path.join(self.path, MANIFEST_NAME + ".tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        os.replace(mtmp, os.path.join(self.path, MANIFEST_NAME))

        self.manifest = manifest  # rebind: stale handles keep the old dict
        self.__dict__.pop("_manifest_hash", None)  # rotate the content token
        if rewritten is not None:
            self.cache.drop(last)
        return self.manifest_hash


@dataclasses.dataclass
class StoreSlice:
    """A contiguous row-range view over a :class:`CorpusStore`.

    Duck-types the store's read surface (``kind``/``dim``/``nnz_max``/
    ``take_rows``) with local row ids ``[0, n_docs)`` mapped onto the parent's
    ``[lo, hi)`` — accepted anywhere a store is (store-backed
    ``topk_search``, ``from_store`` chunk backends)."""

    store: CorpusStore
    lo: int
    hi: int

    def __post_init__(self):
        if not 0 <= self.lo <= self.hi <= self.store.n_docs:
            raise ValueError(
                f"slice [{self.lo}, {self.hi}) outside "
                f"[0, {self.store.n_docs}]"
            )

    @property
    def kind(self) -> str:
        """Parent store's block layout."""
        return self.store.kind

    @property
    def n_docs(self) -> int:
        """Rows in this view."""
        return self.hi - self.lo

    @property
    def dim(self) -> int:
        """Parent store's vector dimensionality."""
        return self.store.dim

    @property
    def nnz_max(self) -> Optional[int]:
        """Parent store's ELL padding width (None for dense)."""
        return self.store.nnz_max

    @property
    def dtype(self) -> np.dtype:
        """Parent store's vector element dtype."""
        return self.store.dtype

    @property
    def manifest_hash(self) -> str:
        """Parent store's content token (slices share corpus identity)."""
        return self.store.manifest_hash

    def take_rows(self, rows: np.ndarray) -> Dict[str, np.ndarray]:
        """Gather view-local rows (offset into the parent's range);
        ids outside ``[0, n_docs)`` of the *view* raise — offsetting must not
        silently reinterpret them as other parent rows."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_docs):
            raise IndexError(
                f"row ids outside the view's [0, {self.n_docs}): "
                f"[{rows.min()}, {rows.max()}]"
            )
        return self.store.take_rows(rows + self.lo)


def open_store(
    path: str, budget_bytes: int = DEFAULT_BUDGET_BYTES, verify: bool = False
) -> CorpusStore:
    """Open an on-disk corpus store with an LRU residency budget.

    ``budget_bytes`` bounds decoded-block residency (the out-of-core dial —
    benchmarks/oocore.py sweeps it). ``verify=True`` re-hashes every block
    file against the manifest digests before returning (slow; integrity
    check after a copy)."""
    mpath = os.path.join(path, MANIFEST_NAME)
    if not os.path.exists(mpath):
        raise FileNotFoundError(f"no corpus store at {path} (missing {MANIFEST_NAME})")
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("format") != FORMAT_TAG:
        raise ValueError(
            f"{path}: unknown store format {manifest.get('format')!r} "
            f"(expected {FORMAT_TAG!r})"
        )
    if verify:
        for entry in manifest["blocks"]:
            # field-name-sorted order, matching save_store's concatenation
            # (manifest JSON round-trips with sort_keys, so .values() order
            # is already sorted — sorting explicitly keeps it load-order-proof)
            dig = "".join(
                _digest(os.path.join(path, entry["files"][name]))
                for name in sorted(entry["files"])
            )
            if dig != entry["digest"]:
                raise ValueError(
                    f"{path}: block {entry['i']} content does not match its "
                    "manifest digest (corrupt or partially rewritten store)"
                )
    store = CorpusStore(path=path, manifest=manifest, cache=None)  # type: ignore[arg-type]
    store.cache = BlockCache(budget_bytes, store._load_block)
    return store
