"""Out-of-core corpus store — the paper's "efficient disk based
implementations where space requirements exceed that of main memory"
(DESIGN.md §9).

The corpus lives on disk as **fixed-size, chunk-aligned blocks** plus a small
JSON manifest; only a bounded set of blocks is ever resident. Two block
layouts mirror the two vector backends (DESIGN.md §5):

- ``kind="dense"`` — each block is one ``.npy`` file holding
  ``f[block_docs, d]`` rows;
- ``kind="ell"``   — each block is a pair of ``.npy`` files,
  ``values f[block_docs, nnz_max]`` + ``cols i32[block_docs, nnz_max]``
  (the ELL layout the ``ell_spmm`` kernel scores; padding slots are
  value 0 / col 0).

The last block is zero-padded to ``block_docs`` so every file has the same
shape (mmap-friendly); the manifest records the true ``n_docs`` and readers
never address the padding.

Residency is governed by :class:`BlockCache` — an LRU over decoded blocks
with a byte budget. Sequential consumers (streaming build, store-backed
queries) touch blocks in row order, so a budget of even one block streams the
whole corpus through bounded memory; random access degrades gracefully to
re-reads. Each block file's blake2b digest is recorded in the manifest at
write time, and :func:`CorpusStore.manifest_hash` hashes the canonical
manifest — a content token that changes whenever the corpus is regenerated in
place (the answer-cache staleness guard keys on it, DESIGN.md §8/§9).

This module is deliberately numpy/host-only (no jax imports): stores cross no
jit boundary. The device-side seam is ``repro.core.backend.from_store`` —
chunk-sized in-memory backends materialised from store rows on demand.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

MANIFEST_NAME = "manifest.json"
FORMAT_TAG = "ktree-store-v1"
DEFAULT_BLOCK_DOCS = 4096
DEFAULT_BUDGET_BYTES = 256 * 1024 * 1024


class BlockCache:
    """LRU cache of decoded corpus blocks under a byte budget.

    ``loader(block_id) -> dict[str, np.ndarray]`` decodes one block from disk;
    the cache accounts ``nbytes`` of every array it holds and evicts
    least-recently-used blocks once the budget is exceeded. A single block
    larger than the whole budget is still admitted (the floor of residency is
    one block — nothing works below that), evicting everything else.

    ``hits``/``misses``/``evictions`` feed the out-of-core bench and the
    serving report (benchmarks/oocore.py, ``launch/serve.py --store``).
    """

    def __init__(self, budget_bytes: int, loader):
        if budget_bytes < 1:
            raise ValueError(f"budget_bytes must be ≥ 1, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self._loader = loader
        self._blocks: "Dict[int, Dict[str, np.ndarray]]" = {}
        self._lru: List[int] = []  # least-recent first
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def _block_bytes(arrays: Dict[str, np.ndarray]) -> int:
        """Total decoded size of one block's arrays."""
        return sum(int(a.nbytes) for a in arrays.values())

    def get(self, block_id: int) -> Dict[str, np.ndarray]:
        """The decoded arrays of ``block_id``, loading + evicting as needed."""
        if block_id in self._blocks:
            self.hits += 1
            self._lru.remove(block_id)
            self._lru.append(block_id)
            return self._blocks[block_id]
        self.misses += 1
        arrays = self._loader(block_id)
        self._bytes += self._block_bytes(arrays)
        self._blocks[block_id] = arrays
        self._lru.append(block_id)
        while self._bytes > self.budget_bytes and len(self._lru) > 1:
            old = self._lru.pop(0)
            self._bytes -= self._block_bytes(self._blocks.pop(old))
            self.evictions += 1
        return arrays

    @property
    def resident_bytes(self) -> int:
        """Bytes currently held across all resident blocks."""
        return self._bytes

    @property
    def stats(self) -> dict:
        """hit/miss/eviction counters + residency for reports."""
        total = self.hits + self.misses
        return dict(
            hits=self.hits, misses=self.misses, evictions=self.evictions,
            hit_rate=self.hits / total if total else 0.0,
            resident_bytes=self._bytes, resident_blocks=len(self._lru),
            budget_bytes=self.budget_bytes,
        )


def _digest(path: str) -> str:
    """blake2b-128 hex digest of one block file's raw bytes."""
    h = hashlib.blake2b(digest_size=16)
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _save_block(dir_path: str, name: str, arr: np.ndarray) -> Tuple[str, str]:
    """Write one block array; returns (file name, content digest)."""
    fname = name + ".npy"
    np.save(os.path.join(dir_path, fname), arr)
    return fname, _digest(os.path.join(dir_path, fname))


def _install_dir(tmp: str, path: str) -> None:
    """Install a fully-written ``tmp`` directory at ``path`` without ever
    destroying existing data before its replacement is in place: the old
    directory is moved aside, the new one renamed in, and only then is the
    old one removed. A crash mid-replace leaves the previous data at
    ``path + ".old"`` instead of gone."""
    old = path.rstrip("/") + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    had_old = os.path.exists(path)
    if had_old:
        os.rename(path, old)
    os.rename(tmp, path)
    if had_old:
        shutil.rmtree(old)


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad ``arr`` along axis 0 up to ``rows`` (fixed-size blocks)."""
    if arr.shape[0] == rows:
        return np.ascontiguousarray(arr)
    pad = np.zeros((rows - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([np.ascontiguousarray(arr), pad], axis=0)


def save_store(path: str, corpus, block_docs: int = DEFAULT_BLOCK_DOCS) -> str:
    """Write a corpus to an on-disk block store; returns ``path``.

    ``corpus``: a dense ``f[N, d]`` array (→ ``kind="dense"``), a
    :class:`repro.sparse.Csr`, or an existing
    :class:`repro.core.backend.EllSparseBackend` / ``DenseBackend``
    (→ layout follows the backend). ``block_docs`` is the fixed rows-per-block
    granularity (the unit of disk I/O and cache residency).

    The write lands in ``path.tmp`` and is installed by rename, so a crash
    mid-write never leaves a half-readable store at ``path``. Replacing an
    existing store moves the old directory aside (``path.old``) before the
    rename and removes it only after the new store is in place — a crash in
    the replace window leaves the previous corpus intact at ``path.old``
    (plus possibly the finished rewrite at ``path.tmp``), never destroyed.
    Readers opened before the rewrite keep their (now stale) manifest, which
    is exactly what :func:`CorpusStore.manifest_hash` exists to detect.
    """
    from repro.core.backend import DenseBackend, EllSparseBackend, make_backend
    from repro.sparse.csr import Csr

    if block_docs < 1:
        raise ValueError(f"block_docs must be ≥ 1, got {block_docs}")
    if isinstance(corpus, Csr):
        corpus = make_backend(corpus, "sparse")
    if isinstance(corpus, (DenseBackend, EllSparseBackend)) is False:
        corpus = make_backend(np.asarray(corpus), "dense")

    tmp = path.rstrip("/") + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    n_docs = corpus.n_docs
    n_blocks = max(-(-n_docs // block_docs), 1)
    blocks = []
    if isinstance(corpus, DenseBackend):
        x = np.asarray(corpus.x)
        kind, dim, nnz_max = "dense", int(x.shape[1]), None
        dtype = str(x.dtype)
        for i in range(n_blocks):
            blk = _pad_rows(x[i * block_docs:(i + 1) * block_docs], block_docs)
            fname, dig = _save_block(tmp, f"dense_{i:05d}", blk)
            blocks.append({"i": i, "files": {"x": fname}, "digest": dig})
    else:
        values = np.asarray(corpus.values)
        cols = np.asarray(corpus.cols, dtype=np.int32)
        kind, dim, nnz_max = "ell", int(corpus.n_cols), int(values.shape[1])
        dtype = str(values.dtype)
        for i in range(n_blocks):
            sl = slice(i * block_docs, (i + 1) * block_docs)
            fv, dv = _save_block(tmp, f"ell_values_{i:05d}",
                                 _pad_rows(values[sl], block_docs))
            fc, dc = _save_block(tmp, f"ell_cols_{i:05d}",
                                 _pad_rows(cols[sl], block_docs))
            # digest concatenation follows sorted field-name order ("cols"
            # then "values") — the same order open_store's verify recomputes
            blocks.append({"i": i, "files": {"values": fv, "cols": fc},
                           "digest": dc + dv})

    manifest = {
        "format": FORMAT_TAG, "kind": kind, "n_docs": int(n_docs),
        "dim": dim, "dtype": dtype, "block_docs": int(block_docs),
        "n_blocks": int(n_blocks), "nnz_max": nnz_max, "blocks": blocks,
    }
    with open(os.path.join(tmp, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    _install_dir(tmp, path)
    return path


@dataclasses.dataclass
class CorpusStore:
    """A memory-mapped, block-cached view of an on-disk corpus.

    Open with :func:`open_store`. Exposes the corpus *shape* (``n_docs``,
    ``dim``, ``kind``, ``nnz_max``) and row access (:meth:`take_rows`) through
    the :class:`BlockCache`; device-side consumers go through
    ``repro.core.backend.from_store`` (chunk backends) or
    ``repro.core.ktree.build_from_store`` (streaming build). A store is a
    host-side handle — it is **not** a pytree and never crosses jit.
    """

    path: str
    manifest: dict
    cache: BlockCache

    # -- shape / identity ---------------------------------------------------
    @property
    def kind(self) -> str:
        """Block layout: ``"dense"`` or ``"ell"``."""
        return self.manifest["kind"]

    @property
    def n_docs(self) -> int:
        """True corpus row count (excludes last-block padding)."""
        return self.manifest["n_docs"]

    @property
    def dim(self) -> int:
        """Vector dimensionality (``n_cols`` for ELL stores)."""
        return self.manifest["dim"]

    @property
    def block_docs(self) -> int:
        """Rows per fixed-size block (the I/O + residency granule)."""
        return self.manifest["block_docs"]

    @property
    def n_blocks(self) -> int:
        """Number of block files."""
        return self.manifest["n_blocks"]

    @property
    def nnz_max(self) -> Optional[int]:
        """ELL padding width (None for dense stores)."""
        return self.manifest["nnz_max"]

    @property
    def nbytes(self) -> int:
        """Total decoded corpus bytes across all blocks (dense rows or
        ELL values+cols) — what "corpus exceeds the residency budget" is
        measured against."""
        itemsize = np.dtype(self.manifest["dtype"]).itemsize
        rows = self.n_blocks * self.block_docs
        if self.kind == "dense":
            return rows * self.dim * itemsize
        return rows * self.nnz_max * (itemsize + 4)

    @property
    def manifest_hash(self) -> str:
        """Content token: blake2b-128 of the canonical manifest JSON.

        The manifest embeds every block file's digest, so regenerating the
        corpus in place (same path, different data) yields a different hash —
        the staleness key for answer caches and manifest-reference
        checkpoints. Memoised per handle (the manifest is immutable once
        opened; serving passes this token on every batch)."""
        h = self.__dict__.get("_manifest_hash")
        if h is None:
            blob = json.dumps(self.manifest, sort_keys=True).encode()
            h = hashlib.blake2b(blob, digest_size=16).hexdigest()
            self.__dict__["_manifest_hash"] = h
        return h

    # -- block access -------------------------------------------------------
    def _load_block(self, i: int) -> Dict[str, np.ndarray]:
        """Decode block ``i`` from disk (mmap → private in-memory copy, so the
        cache's byte accounting matches actual residency)."""
        entry = self.manifest["blocks"][i]
        out = {}
        for name, fname in entry["files"].items():
            arr = np.load(os.path.join(self.path, fname), mmap_mode="r")
            out[name] = np.array(arr)  # materialise: residency is the point
        return out

    def read_block(self, i: int) -> Dict[str, np.ndarray]:
        """Block ``i``'s arrays through the LRU cache (padded to
        ``block_docs`` rows — use :meth:`block_rows` for the valid range)."""
        if not 0 <= i < self.n_blocks:
            raise IndexError(f"block {i} out of range [0, {self.n_blocks})")
        return self.cache.get(i)

    def block_rows(self, i: int) -> Tuple[int, int]:
        """Global row range ``[lo, hi)`` of valid docs in block ``i``."""
        lo = i * self.block_docs
        return lo, min(lo + self.block_docs, self.n_docs)

    def iter_blocks(self) -> Iterator[Tuple[int, int, Dict[str, np.ndarray]]]:
        """Yield ``(lo, hi, arrays)`` per block in row order — the streaming
        scan pattern (arrays still padded; slice ``[:hi-lo]``)."""
        for i in range(self.n_blocks):
            lo, hi = self.block_rows(i)
            yield lo, hi, self.read_block(i)

    def take_rows(self, rows: np.ndarray) -> Dict[str, np.ndarray]:
        """Gather arbitrary global rows as host arrays.

        Returns ``{"x": f[B, d]}`` (dense) or
        ``{"values": f[B, nnz_max], "cols": i32[B, nnz_max]}`` (ELL). Rows are
        fetched block-by-block through the cache, so a contiguous chunk costs
        one or two block reads; out-of-range ids raise."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_docs):
            raise IndexError(
                f"row ids outside [0, {self.n_docs}): "
                f"[{rows.min()}, {rows.max()}]"
            )
        names = ("x",) if self.kind == "dense" else ("values", "cols")
        out = {
            name: np.empty(
                (rows.size,) + self._field_shape(name),
                self._field_dtype(name),
            )
            for name in names
        }
        blk = rows // self.block_docs
        for b in np.unique(blk):
            arrays = self.read_block(int(b))
            sel = np.nonzero(blk == b)[0]
            local = rows[sel] - int(b) * self.block_docs
            for name in names:
                out[name][sel] = arrays[name][local]
        return out

    def _field_shape(self, name: str) -> Tuple[int, ...]:
        """Per-row trailing shape of a stored field."""
        return (self.dim,) if name == "x" else (self.nnz_max,)

    def _field_dtype(self, name: str):
        """Dtype of a stored field."""
        return np.int32 if name == "cols" else np.dtype(self.manifest["dtype"])

    def view(self, lo: int = 0, hi: Optional[int] = None) -> "StoreSlice":
        """A row-range view ``[lo, hi)`` of this store — same cache, same
        disk; lets callers query a subset (e.g. the first ``nq`` docs) without
        materialising it."""
        return StoreSlice(self, lo, self.n_docs if hi is None else hi)


@dataclasses.dataclass
class StoreSlice:
    """A contiguous row-range view over a :class:`CorpusStore`.

    Duck-types the store's read surface (``kind``/``dim``/``nnz_max``/
    ``take_rows``) with local row ids ``[0, n_docs)`` mapped onto the parent's
    ``[lo, hi)`` — accepted anywhere a store is (store-backed
    ``topk_search``, ``from_store`` chunk backends)."""

    store: CorpusStore
    lo: int
    hi: int

    def __post_init__(self):
        if not 0 <= self.lo <= self.hi <= self.store.n_docs:
            raise ValueError(
                f"slice [{self.lo}, {self.hi}) outside "
                f"[0, {self.store.n_docs}]"
            )

    @property
    def kind(self) -> str:
        """Parent store's block layout."""
        return self.store.kind

    @property
    def n_docs(self) -> int:
        """Rows in this view."""
        return self.hi - self.lo

    @property
    def dim(self) -> int:
        """Parent store's vector dimensionality."""
        return self.store.dim

    @property
    def nnz_max(self) -> Optional[int]:
        """Parent store's ELL padding width (None for dense)."""
        return self.store.nnz_max

    @property
    def manifest_hash(self) -> str:
        """Parent store's content token (slices share corpus identity)."""
        return self.store.manifest_hash

    def take_rows(self, rows: np.ndarray) -> Dict[str, np.ndarray]:
        """Gather view-local rows (offset into the parent's range);
        ids outside ``[0, n_docs)`` of the *view* raise — offsetting must not
        silently reinterpret them as other parent rows."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_docs):
            raise IndexError(
                f"row ids outside the view's [0, {self.n_docs}): "
                f"[{rows.min()}, {rows.max()}]"
            )
        return self.store.take_rows(rows + self.lo)


def open_store(
    path: str, budget_bytes: int = DEFAULT_BUDGET_BYTES, verify: bool = False
) -> CorpusStore:
    """Open an on-disk corpus store with an LRU residency budget.

    ``budget_bytes`` bounds decoded-block residency (the out-of-core dial —
    benchmarks/oocore.py sweeps it). ``verify=True`` re-hashes every block
    file against the manifest digests before returning (slow; integrity
    check after a copy)."""
    mpath = os.path.join(path, MANIFEST_NAME)
    if not os.path.exists(mpath):
        raise FileNotFoundError(f"no corpus store at {path} (missing {MANIFEST_NAME})")
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("format") != FORMAT_TAG:
        raise ValueError(
            f"{path}: unknown store format {manifest.get('format')!r} "
            f"(expected {FORMAT_TAG!r})"
        )
    if verify:
        for entry in manifest["blocks"]:
            # field-name-sorted order, matching save_store's concatenation
            # (manifest JSON round-trips with sort_keys, so .values() order
            # is already sorted — sorting explicitly keeps it load-order-proof)
            dig = "".join(
                _digest(os.path.join(path, entry["files"][name]))
                for name in sorted(entry["files"])
            )
            if dig != entry["digest"]:
                raise ValueError(
                    f"{path}: block {entry['i']} content does not match its "
                    "manifest digest (corrupt or partially rewritten store)"
                )
    store = CorpusStore(path=path, manifest=manifest, cache=None)  # type: ignore[arg-type]
    store.cache = BlockCache(budget_bytes, store._load_block)
    return store
