"""Out-of-core corpus store — the paper's "efficient disk based
implementations where space requirements exceed that of main memory"
(DESIGN.md §9).

The corpus lives on disk as **fixed-size, chunk-aligned blocks** plus a small
JSON manifest; only a bounded set of blocks is ever resident. Two block
layouts mirror the two vector backends (DESIGN.md §5):

- ``kind="dense"`` — each block is one ``.npy`` file holding
  ``f[block_docs, d]`` rows;
- ``kind="ell"``   — each block is a pair of ``.npy`` files,
  ``values f[block_docs, nnz_max]`` + ``cols i32[block_docs, nnz_max]``
  (the ELL layout the ``ell_spmm`` kernel scores; padding slots are
  value 0 / col 0).

The last block is zero-padded to ``block_docs`` so every file has the same
shape (mmap-friendly); the manifest records the true ``n_docs`` and readers
never address the padding.

Residency is governed by :class:`BlockCache` — an LRU over decoded blocks
with a byte budget. Sequential consumers (streaming build, store-backed
queries) touch blocks in row order, so a budget of even one block streams the
whole corpus through bounded memory; random access degrades gracefully to
re-reads. Each block file's blake2b digest is recorded in the manifest at
write time, and :func:`CorpusStore.manifest_hash` hashes the canonical
manifest — a content token that changes whenever the corpus is regenerated in
place **or grown by** :meth:`CorpusStore.append` (the answer-cache and
checkpoint staleness guards key on it, DESIGN.md §8/§9).

Serving-plane seams (DESIGN.md §8/§9): :class:`Prefetcher` is the async
reader thread that moves disk decodes off the dispatch path (build, query,
and streamed ground truth share it; answers are bit-identical to the
synchronous scans), and :meth:`CorpusStore.partition` splits the corpus into
per-shard row ranges with independent block caches — the disk side of
store-backed ``topk_search_sharded``. :meth:`CorpusStore.append` closes the
loop for growing corpora: ``ktree.insert_into_store`` spills newly inserted
leaf vectors into the padding tail of the last block plus freshly appended
block files, atomically extending the manifest.

Fault model (DESIGN.md §10): every block read goes through a hardened path —
blake2b digest verification at read time (on by default, opt out via
:class:`ReadPolicy`), capped exponential backoff + jitter on transient
failures, and quarantine of blocks that exhaust their retries, surfacing
typed :class:`BlockCorrupt` / :class:`BlockUnavailable` errors with exact
counters in ``BlockCache.stats``. A :class:`repro.core.faults.FaultPlan`
passed to :func:`open_store` injects reproducible faults behind the same
seam.

This module is deliberately numpy/host-only (no jax imports): stores cross no
jit boundary. The device-side seam is ``repro.core.backend.from_store`` —
chunk-sized in-memory backends materialised from store rows on demand.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import queue
import shutil
import threading
import time
import weakref
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.faults import _coin
from repro.core.profile import NULL_PROFILER

MANIFEST_NAME = "manifest.json"
FORMAT_TAG = "ktree-store-v1"
DEFAULT_BLOCK_DOCS = 4096
DEFAULT_BUDGET_BYTES = 256 * 1024 * 1024


class BlockError(RuntimeError):
    """Base of typed block-read failures.

    ``retryable`` is False: these are post-retry *verdicts* — the hardened
    read path raises them only once the :class:`ReadPolicy` retries are
    exhausted (so :class:`Prefetcher` propagates them instead of restarting
    its reader thread).
    """

    retryable = False

    def __init__(self, path: str, block: int, detail: str):
        super().__init__(f"{path}: block {block}: {detail}")
        self.path = path
        self.block = block


class BlockCorrupt(BlockError, ValueError):
    """Block content failed blake2b digest verification after retries."""


class BlockUnavailable(BlockError, IOError):
    """Block cannot be read: I/O failure after retries, quarantined by an
    earlier exhausted read, or excised from the manifest by ``store_fsck``."""


class ManifestError(ValueError):
    """A manifest/sidecar file that cannot be parsed or fails its format
    guard — always names the offending path (instead of surfacing a raw
    ``json.JSONDecodeError`` with no context)."""

    def __init__(self, path: str, detail: str):
        super().__init__(f"{path}: {detail}")
        self.path = path


class _DigestMismatch(Exception):
    """Internal: one read attempt's content failed digest verification."""


@dataclasses.dataclass(frozen=True)
class ReadPolicy:
    """How hard a store read tries before giving up (DESIGN.md §10).

    ``verify`` checks each block's blake2b digest against the manifest on
    every decode (on by default; per-store opt-out for trusted media).
    Failed attempts — I/O errors, injected faults, digest mismatches — are
    retried up to ``max_retries`` times with capped exponential backoff
    (``backoff_s · 2^(attempt-1)``, capped at ``backoff_cap_s``) plus a
    deterministic jitter fraction of up to ``jitter`` drawn from ``seed``,
    so concurrent readers of a flaky block don't retry in lockstep and test
    runs stay reproducible.
    """

    verify: bool = True
    max_retries: int = 3
    backoff_s: float = 0.005
    backoff_cap_s: float = 0.1
    jitter: float = 0.5
    seed: int = 0


def check_on_fault(on_fault: str) -> None:
    """Validate an ``on_fault`` mode argument (``"raise"`` or ``"degrade"``)."""
    if on_fault not in ("raise", "degrade"):
        raise ValueError(
            f"on_fault must be 'raise' or 'degrade', got {on_fault!r}"
        )


class BlockCache:
    """LRU cache of decoded corpus blocks under a byte budget.

    ``loader(block_id) -> dict[str, np.ndarray]`` decodes one block from disk;
    the cache accounts ``nbytes`` of every array it holds and evicts
    least-recently-used blocks once the budget is exceeded. A single block
    larger than the whole budget is still admitted (the floor of residency is
    one block — nothing works below that), evicting everything else.

    ``hits``/``misses``/``evictions`` feed the out-of-core bench and the
    serving report (benchmarks/oocore.py, ``launch/serve.py --store``).

    Thread safety: a :class:`Prefetcher` reader thread may race the consumer
    loop on the same cache, so ``get`` runs under a lock — every call
    increments exactly one of hits/misses and the byte accounting (incl. the
    one-block residency floor) stays exact under concurrency. Disk decode
    happens inside the lock: concurrent readers of one store serialise on I/O
    rather than double-loading a block and double-counting its bytes.

    Profiling (DESIGN.md §11): set ``cache.profiler`` to a
    ``repro.core.profile.Profiler`` and every cache-miss decode records a
    ``"disk_read"`` span (on whichever thread missed). The default
    ``NULL_PROFILER`` costs one truthiness check per miss.
    """

    _instances: "weakref.WeakSet" = weakref.WeakSet()

    def __init__(self, budget_bytes: int, loader):
        if budget_bytes < 1:
            raise ValueError(f"budget_bytes must be ≥ 1, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self._loader = loader
        self._blocks: "Dict[int, Dict[str, np.ndarray]]" = {}
        self._lru: List[int] = []  # least-recent first
        self._bytes = 0
        self._peak = 0
        self._lock = threading.Lock()
        self.profiler = NULL_PROFILER
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # hardened-read counters (DESIGN.md §10), bumped by the store's
        # loader as faults are observed/retried/exhausted
        self.read_retries = 0
        self.read_errors = 0
        self.verify_failures = 0
        self.quarantined = 0
        BlockCache._instances.add(self)

    @staticmethod
    def _block_bytes(arrays: Dict[str, np.ndarray]) -> int:
        """Total decoded size of one block's arrays."""
        return sum(int(a.nbytes) for a in arrays.values())

    def get(self, block_id: int) -> Dict[str, np.ndarray]:
        """The decoded arrays of ``block_id``, loading + evicting as needed."""
        with self._lock:
            if block_id in self._blocks:
                self.hits += 1
                self._lru.remove(block_id)
                self._lru.append(block_id)
                return self._blocks[block_id]
            self.misses += 1
            if self.profiler.enabled:
                with self.profiler.span("disk_read"):
                    arrays = self._loader(block_id)
            else:
                arrays = self._loader(block_id)
            self._bytes += self._block_bytes(arrays)
            self._peak = max(self._peak, self._bytes)
            self._blocks[block_id] = arrays
            self._lru.append(block_id)
            while self._bytes > self.budget_bytes and len(self._lru) > 1:
                old = self._lru.pop(0)
                self._bytes -= self._block_bytes(self._blocks.pop(old))
                self.evictions += 1
            return arrays

    def drop(self, block_id: int) -> None:
        """Forget a resident block without counting an eviction — staleness
        invalidation (a block file rewritten by :meth:`CorpusStore.append`),
        not budget pressure."""
        with self._lock:
            if block_id in self._blocks:
                self._bytes -= self._block_bytes(self._blocks.pop(block_id))
                self._lru.remove(block_id)

    @property
    def resident_bytes(self) -> int:
        """Bytes currently held across all resident blocks."""
        return self._bytes

    @property
    def peak_resident_bytes(self) -> int:
        """High-water residency since construction or the last
        :meth:`reset_peak` — the per-batch accounting seam the serving engine
        (``core/engine.py``) reads: reset before a batch dispatch, read after,
        and the difference window is exactly that batch's disk working set."""
        return self._peak

    def reset_peak(self) -> int:
        """Restart peak tracking at the current residency; returns the peak
        of the window just closed (so per-batch accounting is one call)."""
        with self._lock:
            prev = self._peak
            self._peak = self._bytes
            return prev

    def reset_stats(self) -> None:
        """Zero every counter (hits/misses/evictions + hardened-read) and
        restart peak tracking at current residency — resident blocks stay.
        Benchmark legs call this between sweeps so hit-rate/residency
        numbers don't bleed across cells (benchmarks/run.py)."""
        with self._lock:
            self.hits = 0
            self.misses = 0
            self.evictions = 0
            self.read_retries = 0
            self.read_errors = 0
            self.verify_failures = 0
            self.quarantined = 0
            self._peak = self._bytes

    @classmethod
    def reset_all_stats(cls) -> int:
        """Call :meth:`reset_stats` on every live cache (a weakref registry
        tracks them); returns how many were reset. The between-legs seam for
        ``benchmarks/run.py`` — legs build their own stores, so the runner
        can't enumerate the caches itself."""
        caches = list(cls._instances)
        for c in caches:
            c.reset_stats()
        return len(caches)

    @property
    def stats(self) -> dict:
        """hit/miss/eviction counters + residency for reports."""
        total = self.hits + self.misses
        return dict(
            hits=self.hits, misses=self.misses, evictions=self.evictions,
            hit_rate=self.hits / total if total else 0.0,
            resident_bytes=self._bytes, resident_blocks=len(self._lru),
            peak_resident_bytes=self._peak, budget_bytes=self.budget_bytes,
            read_retries=self.read_retries, read_errors=self.read_errors,
            verify_failures=self.verify_failures, quarantined=self.quarantined,
        )


class Prefetcher:
    """Bounded async reader: applies ``fetch`` to each request from
    ``requests`` on a daemon worker thread, keeping up to ``depth`` finished
    results buffered ahead of the consumer (the worker may additionally have
    one fetch in flight while the buffer is full).

    Iterating yields ``(request, result)`` pairs in request order — results
    are the same objects a synchronous ``fetch`` loop would produce, so
    consumers are bit-identical to the unprefetched path; only the disk read
    moves off the dispatch path (DESIGN.md §9: the next block's read overlaps
    device compute *and* the current chunk's D2H copy-out, where the
    ``pipeline`` dispatch-ahead alone still serialised read → dispatch).
    Fault handling (DESIGN.md §10): a ``fetch`` exception whose type carries
    ``retryable = False`` (the store's :class:`BlockError` verdicts — the
    read policy already exhausted its retries) is re-raised at the consumer's
    next step. Any other exception is treated as a transient reader fault:
    the worker thread is restarted up to ``max_restarts`` times, re-issuing
    the failed request, and only an exhausted restart budget propagates —
    result order is preserved across restarts, so consumers stay
    bit-identical. Use as a context manager (or call :meth:`close`) to stop
    the worker early; exhausting the iterator joins it automatically.

    ``profiler=`` (DESIGN.md §11) records one ``"read"`` span per fetch on
    the reader thread — pass it when the ``fetch`` callable isn't already
    instrumented (``query._store_chunk_iter`` wraps its own fetch, so it
    leaves this at the free ``NULL_PROFILER`` default).
    """

    _DONE = object()
    _ERR = object()

    def __init__(self, requests: Iterable, fetch: Callable, depth: int = 1,
                 max_restarts: int = 2, profiler=NULL_PROFILER):
        if depth < 1:
            raise ValueError(f"prefetch depth must be ≥ 1, got {depth}")
        self.depth = int(depth)
        self.profiler = profiler
        self.max_restarts = int(max_restarts)
        self.restarts = 0
        self._results: "queue.Queue" = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        self._requests = iter(requests)
        self._fetch = fetch
        self._inflight_req = None
        self._have_inflight = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _emit(self, req):
        """Fetch one request and hand the result to the consumer queue."""
        self._inflight_req = req
        self._have_inflight = True
        with self.profiler.span("read"):
            got = self._fetch(req)
        item = (req, got)
        self._have_inflight = False
        while not self._stop.is_set():
            try:
                self._results.put(item, timeout=0.1)
                break
            except queue.Full:
                continue

    def _run(self, retry_req=None):
        """Worker loop: fetch ahead until the requests run dry or close().

        ``retry_req`` re-issues the request a previous (faulted) worker
        incarnation died on, so a restart loses no results."""
        try:
            if retry_req is not None:
                self._emit(retry_req)
            for req in self._requests:
                if self._stop.is_set():
                    return
                self._emit(req)
            self._put_final((Prefetcher._DONE, None))
        except BaseException as e:  # surfaced at the consumer's next step
            if (getattr(e, "retryable", True)
                    and self.restarts < self.max_restarts
                    and not self._stop.is_set()):
                # transient reader fault: restart the thread on the failed
                # request; only exhausted budgets reach the consumer
                self.restarts += 1
                failed = self._inflight_req if self._have_inflight else None
                self._have_inflight = False
                self._thread = threading.Thread(
                    target=self._run, args=(failed,), daemon=True
                )
                self._thread.start()
                return
            self._put_final((Prefetcher._ERR, e))

    def _put_final(self, item):
        """Enqueue the terminal marker without deadlocking against close()."""
        while not self._stop.is_set():
            try:
                self._results.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Tuple[object, object]]:
        while not self._stop.is_set():
            try:
                tag, payload = self._results.get(timeout=0.1)
            except queue.Empty:
                continue
            if tag is Prefetcher._DONE:
                self._thread.join()
                return
            if tag is Prefetcher._ERR:
                self._thread.join()
                raise payload
            yield tag, payload

    def close(self) -> None:
        """Stop the worker and discard buffered results (idempotent)."""
        self._stop.set()
        while True:
            try:
                self._results.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _digest(path: str) -> str:
    """blake2b-128 hex digest of one block file's raw bytes."""
    h = hashlib.blake2b(digest_size=16)
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _save_block(dir_path: str, name: str, arr: np.ndarray) -> Tuple[str, str]:
    """Write one block array; returns (file name, content digest)."""
    fname = name + ".npy"
    np.save(os.path.join(dir_path, fname), arr)
    return fname, _digest(os.path.join(dir_path, fname))


def _replace_block(dir_path: str, fname: str, arr: np.ndarray) -> str:
    """Atomically (re)write one block file in a *live* store directory (tmp +
    ``os.replace``, so readers never observe a half-written block); returns
    the new content digest. The append path's per-file counterpart of
    :func:`_save_block` (which writes into a not-yet-installed tmp dir)."""
    tmp = os.path.join(dir_path, fname + ".tmp")
    with open(tmp, "wb") as f:
        np.save(f, arr)
    os.replace(tmp, os.path.join(dir_path, fname))
    return _digest(os.path.join(dir_path, fname))


def _install_dir(tmp: str, path: str) -> None:
    """Install a fully-written ``tmp`` directory at ``path`` without ever
    destroying existing data before its replacement is in place: the old
    directory is moved aside, the new one renamed in, and only then is the
    old one removed. A crash mid-replace leaves the previous data at
    ``path + ".old"`` instead of gone."""
    old = path.rstrip("/") + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    had_old = os.path.exists(path)
    if had_old:
        os.rename(path, old)
    os.rename(tmp, path)
    if had_old:
        shutil.rmtree(old)


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad ``arr`` along axis 0 up to ``rows`` (fixed-size blocks)."""
    if arr.shape[0] == rows:
        return np.ascontiguousarray(arr)
    pad = np.zeros((rows - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([np.ascontiguousarray(arr), pad], axis=0)


def save_store(path: str, corpus, block_docs: int = DEFAULT_BLOCK_DOCS) -> str:
    """Write a corpus to an on-disk block store; returns ``path``.

    ``corpus``: a dense ``f[N, d]`` array (→ ``kind="dense"``), a
    :class:`repro.sparse.Csr`, or an existing
    :class:`repro.core.backend.EllSparseBackend` / ``DenseBackend``
    (→ layout follows the backend). ``block_docs`` is the fixed rows-per-block
    granularity (the unit of disk I/O and cache residency).

    The write lands in ``path.tmp`` and is installed by rename, so a crash
    mid-write never leaves a half-readable store at ``path``. Replacing an
    existing store moves the old directory aside (``path.old``) before the
    rename and removes it only after the new store is in place — a crash in
    the replace window leaves the previous corpus intact at ``path.old``
    (plus possibly the finished rewrite at ``path.tmp``), never destroyed.
    Readers opened before the rewrite keep their (now stale) manifest, which
    is exactly what :func:`CorpusStore.manifest_hash` exists to detect.
    """
    from repro.core.backend import DenseBackend, EllSparseBackend, make_backend
    from repro.sparse.csr import Csr

    if block_docs < 1:
        raise ValueError(f"block_docs must be ≥ 1, got {block_docs}")
    if isinstance(corpus, Csr):
        corpus = make_backend(corpus, "sparse")
    if isinstance(corpus, (DenseBackend, EllSparseBackend)) is False:
        corpus = make_backend(np.asarray(corpus), "dense")

    tmp = path.rstrip("/") + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    n_docs = corpus.n_docs
    n_blocks = max(-(-n_docs // block_docs), 1)
    blocks = []
    if isinstance(corpus, DenseBackend):
        x = np.asarray(corpus.x)
        kind, dim, nnz_max = "dense", int(x.shape[1]), None
        dtype = str(x.dtype)
        for i in range(n_blocks):
            blk = _pad_rows(x[i * block_docs:(i + 1) * block_docs], block_docs)
            fname, dig = _save_block(tmp, f"dense_{i:05d}", blk)
            blocks.append({"i": i, "files": {"x": fname}, "digest": dig})
    else:
        values = np.asarray(corpus.values)
        cols = np.asarray(corpus.cols, dtype=np.int32)
        kind, dim, nnz_max = "ell", int(corpus.n_cols), int(values.shape[1])
        dtype = str(values.dtype)
        for i in range(n_blocks):
            sl = slice(i * block_docs, (i + 1) * block_docs)
            fv, dv = _save_block(tmp, f"ell_values_{i:05d}",
                                 _pad_rows(values[sl], block_docs))
            fc, dc = _save_block(tmp, f"ell_cols_{i:05d}",
                                 _pad_rows(cols[sl], block_docs))
            # digest concatenation follows sorted field-name order ("cols"
            # then "values") — the same order open_store's verify recomputes
            blocks.append({"i": i, "files": {"values": fv, "cols": fc},
                           "digest": dc + dv})

    manifest = {
        "format": FORMAT_TAG, "kind": kind, "n_docs": int(n_docs),
        "dim": dim, "dtype": dtype, "block_docs": int(block_docs),
        "n_blocks": int(n_blocks), "nnz_max": nnz_max, "blocks": blocks,
    }
    with open(os.path.join(tmp, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    _install_dir(tmp, path)
    return path


@dataclasses.dataclass
class CorpusStore:
    """A memory-mapped, block-cached view of an on-disk corpus.

    Open with :func:`open_store`. Exposes the corpus *shape* (``n_docs``,
    ``dim``, ``kind``, ``nnz_max``) and row access (:meth:`take_rows`) through
    the :class:`BlockCache`; device-side consumers go through
    ``repro.core.backend.from_store`` (chunk backends) or
    ``repro.core.ktree.build_from_store`` (streaming build). A store is a
    host-side handle — it is **not** a pytree and never crosses jit.
    """

    path: str
    manifest: dict
    cache: BlockCache
    read_policy: ReadPolicy = dataclasses.field(default_factory=ReadPolicy)
    fault_plan: Optional[object] = None
    quarantined: Dict[int, str] = dataclasses.field(default_factory=dict)

    # -- shape / identity ---------------------------------------------------
    @property
    def kind(self) -> str:
        """Block layout: ``"dense"`` or ``"ell"``."""
        return self.manifest["kind"]

    @property
    def n_docs(self) -> int:
        """True corpus row count (excludes last-block padding)."""
        return self.manifest["n_docs"]

    @property
    def dim(self) -> int:
        """Vector dimensionality (``n_cols`` for ELL stores)."""
        return self.manifest["dim"]

    @property
    def block_docs(self) -> int:
        """Rows per fixed-size block (the I/O + residency granule)."""
        return self.manifest["block_docs"]

    @property
    def n_blocks(self) -> int:
        """Number of block files."""
        return self.manifest["n_blocks"]

    @property
    def nnz_max(self) -> Optional[int]:
        """ELL padding width (None for dense stores)."""
        return self.manifest["nnz_max"]

    @property
    def dtype(self) -> np.dtype:
        """Element dtype of the stored vectors (``cols`` is always i32)."""
        return np.dtype(self.manifest["dtype"])

    @property
    def nbytes(self) -> int:
        """Total decoded corpus bytes across all blocks (dense rows or
        ELL values+cols) — what "corpus exceeds the residency budget" is
        measured against."""
        itemsize = np.dtype(self.manifest["dtype"]).itemsize
        rows = self.n_blocks * self.block_docs
        if self.kind == "dense":
            return rows * self.dim * itemsize
        return rows * self.nnz_max * (itemsize + 4)

    @property
    def manifest_hash(self) -> str:
        """Content token: blake2b-128 of the canonical manifest JSON.

        The manifest embeds every block file's digest, so regenerating the
        corpus in place (same path, different data) yields a different hash —
        the staleness key for answer caches and manifest-reference
        checkpoints. Memoised per handle (the manifest is immutable once
        opened; serving passes this token on every batch)."""
        h = self.__dict__.get("_manifest_hash")
        if h is None:
            blob = json.dumps(self.manifest, sort_keys=True).encode()
            h = hashlib.blake2b(blob, digest_size=16).hexdigest()
            self.__dict__["_manifest_hash"] = h
        return h

    # -- block access -------------------------------------------------------
    def _read_block_attempt(
        self, i: int, entry: dict, attempt: int
    ) -> Dict[str, np.ndarray]:
        """One raw read + digest verify + decode attempt of block ``i``.

        The :class:`repro.core.faults.FaultPlan` seam sits on the raw bytes:
        injected stalls/read errors fire before the read, injected bit-flips
        mangle the payload in flight — so digest verification (not the
        parser) is what must catch corruption, exactly as with real media."""
        plan = self.fault_plan
        if plan is not None:
            plan.on_read(i, attempt)
        raws = {}
        for name in sorted(entry["files"]):
            with open(os.path.join(self.path, entry["files"][name]), "rb") as f:
                raw = f.read()
            if plan is not None:
                raw = plan.corrupt_bytes(i, name, raw)
            raws[name] = raw
        if self.read_policy.verify:
            # field-name-sorted concatenation, matching save_store's layout
            dig = "".join(
                hashlib.blake2b(raws[n], digest_size=16).hexdigest()
                for n in sorted(raws)
            )
            if dig != entry["digest"]:
                raise _DigestMismatch(
                    f"content digest mismatch (read {dig}, "
                    f"manifest {entry['digest']})"
                )
        return {
            name: np.load(io.BytesIO(raw), allow_pickle=False)
            for name, raw in raws.items()
        }

    def _load_block(self, i: int) -> Dict[str, np.ndarray]:
        """Decode block ``i`` from disk through the hardened read path.

        Verifies the block's blake2b digest (``read_policy.verify``, on by
        default), retries failed attempts — I/O errors, injected faults,
        digest mismatches — with capped exponential backoff + deterministic
        jitter, and **quarantines** a block that exhausts its retries so
        subsequent reads fail fast. Surfaces :class:`BlockCorrupt` (digest
        mismatch) or :class:`BlockUnavailable` (I/O / quarantined / excised),
        with exact counters on this handle's :class:`BlockCache`."""
        entry = self.manifest["blocks"][i]
        if entry.get("excised"):
            reason = "excised by store_fsck: " + str(entry.get("reason", ""))
        else:
            reason = self.quarantined.get(i)
        if reason is not None:
            raise BlockUnavailable(self.path, i, reason)
        pol, cache = self.read_policy, self.cache
        last: Optional[BaseException] = None
        for attempt in range(pol.max_retries + 1):
            if attempt:
                if cache is not None:
                    cache.read_retries += 1
                delay = min(pol.backoff_s * (2.0 ** (attempt - 1)),
                            pol.backoff_cap_s)
                if delay > 0.0:
                    time.sleep(delay * (1.0 + pol.jitter * _coin(
                        pol.seed, "backoff", i, attempt)))
            try:
                return self._read_block_attempt(i, entry, attempt)
            except _DigestMismatch as e:
                last = e
                if cache is not None:
                    cache.verify_failures += 1
            except (OSError, ValueError) as e:
                # OSError: real/injected I/O faults; ValueError: np.load on
                # mangled bytes when verification is opted out
                last = e
                if cache is not None:
                    cache.read_errors += 1
        self.quarantined[i] = f"{type(last).__name__}: {last}"
        if cache is not None:
            cache.quarantined += 1
        if isinstance(last, _DigestMismatch):
            raise BlockCorrupt(self.path, i, str(last)) from last
        raise BlockUnavailable(
            self.path, i,
            f"read failed after {pol.max_retries + 1} attempts: {last}",
        ) from last

    def read_block(self, i: int) -> Dict[str, np.ndarray]:
        """Block ``i``'s arrays through the LRU cache (padded to
        ``block_docs`` rows — use :meth:`block_rows` for the valid range)."""
        if not 0 <= i < self.n_blocks:
            raise IndexError(f"block {i} out of range [0, {self.n_blocks})")
        return self.cache.get(i)

    def block_rows(self, i: int) -> Tuple[int, int]:
        """Global row range ``[lo, hi)`` of valid docs in block ``i``."""
        lo = i * self.block_docs
        return lo, min(lo + self.block_docs, self.n_docs)

    def iter_blocks(
        self, prefetch: int = 0, on_fault: str = "raise"
    ) -> Iterator[Tuple[int, int, Dict[str, np.ndarray]]]:
        """Yield ``(lo, hi, arrays)`` per block in row order — the streaming
        scan pattern (arrays still padded; slice ``[:hi-lo]``).

        ``prefetch ≥ 1`` moves the block reads onto a :class:`Prefetcher`
        reader thread of that depth, so the next block's disk decode overlaps
        the consumer's work on the current one; the yielded arrays are the
        same cache entries the synchronous scan returns.

        ``on_fault="degrade"`` silently skips blocks whose hardened read
        raises a :class:`BlockError` (quarantined/excised/corrupt) instead of
        failing the whole scan — the degraded ground-truth/streaming mode."""
        check_on_fault(on_fault)

        def _read(i: int):
            if on_fault == "degrade":
                try:
                    return self.read_block(i)
                except BlockError:
                    return None
            return self.read_block(i)

        if prefetch:
            with Prefetcher(range(self.n_blocks), _read, depth=prefetch) as pf:
                for i, arrays in pf:
                    if arrays is None:
                        continue
                    lo, hi = self.block_rows(i)
                    yield lo, hi, arrays
            return
        for i in range(self.n_blocks):
            arrays = _read(i)
            if arrays is None:
                continue
            lo, hi = self.block_rows(i)
            yield lo, hi, arrays

    def take_rows(self, rows: np.ndarray) -> Dict[str, np.ndarray]:
        """Gather arbitrary global rows as host arrays.

        Returns ``{"x": f[B, d]}`` (dense) or
        ``{"values": f[B, nnz_max], "cols": i32[B, nnz_max]}`` (ELL). Rows are
        fetched block-by-block through the cache, so a contiguous chunk costs
        one or two block reads; out-of-range ids raise."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_docs):
            raise IndexError(
                f"row ids outside [0, {self.n_docs}): "
                f"[{rows.min()}, {rows.max()}]"
            )
        names = ("x",) if self.kind == "dense" else ("values", "cols")
        out = {
            name: np.empty(
                (rows.size,) + self._field_shape(name),
                self._field_dtype(name),
            )
            for name in names
        }
        blk = rows // self.block_docs
        for b in np.unique(blk):
            arrays = self.read_block(int(b))
            sel = np.nonzero(blk == b)[0]
            local = rows[sel] - int(b) * self.block_docs
            for name in names:
                out[name][sel] = arrays[name][local]
        return out

    def take_rows_masked(
        self, rows: np.ndarray
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Gather rows like :meth:`take_rows`, surviving unreadable blocks.

        Returns ``(arrays, ok)`` where ``ok[j]`` is False for rows whose
        block raised a :class:`BlockError` after the read policy's retries
        (those rows are zero-filled in ``arrays``). The degrade-mode fetch
        primitive (DESIGN.md §10): callers drop the masked rows instead of
        failing the whole gather. Out-of-range ids still raise — only
        *fault* outcomes are maskable."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_docs):
            raise IndexError(
                f"row ids outside [0, {self.n_docs}): "
                f"[{rows.min()}, {rows.max()}]"
            )
        names = ("x",) if self.kind == "dense" else ("values", "cols")
        out = {
            name: np.zeros(
                (rows.size,) + self._field_shape(name),
                self._field_dtype(name),
            )
            for name in names
        }
        ok = np.ones(rows.size, dtype=bool)
        blk = rows // self.block_docs
        for b in np.unique(blk):
            sel = np.nonzero(blk == b)[0]
            try:
                arrays = self.read_block(int(b))
            except BlockError:
                ok[sel] = False
                continue
            local = rows[sel] - int(b) * self.block_docs
            for name in names:
                out[name][sel] = arrays[name][local]
        return out, ok

    def _field_shape(self, name: str) -> Tuple[int, ...]:
        """Per-row trailing shape of a stored field."""
        return (self.dim,) if name == "x" else (self.nnz_max,)

    def _field_dtype(self, name: str):
        """Dtype of a stored field."""
        return np.int32 if name == "cols" else np.dtype(self.manifest["dtype"])

    def view(self, lo: int = 0, hi: Optional[int] = None) -> "StoreSlice":
        """A row-range view ``[lo, hi)`` of this store — same cache, same
        disk; lets callers query a subset (e.g. the first ``nq`` docs) without
        materialising it."""
        return StoreSlice(self, lo, self.n_docs if hi is None else hi)

    def partition(
        self, n_shards: int, budget_bytes: Optional[int] = None
    ) -> List["StoreSlice"]:
        """Split the corpus into ``n_shards`` contiguous row ranges, each a
        :class:`StoreSlice` over its **own** fresh :class:`BlockCache` — the
        disk side of shard-parallel serving (DESIGN.md §8/§9).

        Shard ``s`` owns global rows ``[s·L, (s+1)·L) ∩ [0, n_docs)`` with
        ``L = ⌈n_docs / n_shards⌉`` — the same extent
        ``distributed.shard_rows`` gives a row-sharded in-memory corpus, so
        per-shard ownership agrees with ``*DocShards`` exactly. Each
        partition's cache holds ``budget_bytes`` (default: this handle's
        budget), so total store residency is bounded by
        ``n_shards × budget_bytes`` (plus the per-cache one-block floor);
        partitions share the disk files but no cache state with this handle
        or each other. A boundary block straddling two shards may be resident
        in both caches — that double-count is included in the bound."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be ≥ 1, got {n_shards}")
        budget = self.cache.budget_bytes if budget_bytes is None else int(budget_bytes)
        ext = -(-self.n_docs // n_shards)
        parts = []
        for s in range(n_shards):
            # partitions share the read policy, fault plan, and the
            # *quarantine dict* (same underlying disk: a block one shard's
            # reads exhausted is bad for every shard), but not cache state
            h = CorpusStore(path=self.path, manifest=self.manifest, cache=None,  # type: ignore[arg-type]
                            read_policy=self.read_policy,
                            fault_plan=self.fault_plan,
                            quarantined=self.quarantined)
            h.cache = BlockCache(budget, h._load_block)
            parts.append(h.view(min(s * ext, self.n_docs),
                                min((s + 1) * ext, self.n_docs)))
        return parts

    # -- growth (insert-into-store, DESIGN.md §9) ---------------------------
    def append(self, corpus) -> str:
        """Append rows to the on-disk corpus; returns the **rotated**
        ``manifest_hash``.

        ``corpus`` (dense array / Csr / backend) is normalised to this
        store's exact block layout first
        (``backend.backend_for_store_layout`` — same ``dim``/``dtype``, and
        for ELL stores the same ``nnz_max`` width, truncating longer rows
        exactly like an explicit-``nnz_max`` backend). New rows take global
        ids ``[n_docs, n_docs + B)``: the last block's zero-padding tail is
        filled first (the merged block lands in a **fresh generation-named
        file** — the old tail file is left untouched), then whole new block
        files are appended, and finally the manifest is atomically replaced
        with the extended block list, new digests, and the new ``n_docs`` —
        a crash at any point leaves the *previous* manifest fully consistent
        *and verifiable* (``open_store(verify=True)`` still passes: every
        file the old manifest references is unmodified; files written by the
        interrupted append are unreferenced orphans, reclaimed when a later
        append reuses their names or the store is rewritten).

        This handle's manifest and content token move to the appended state
        (the memoised hash is recomputed — ``AnswerCache``/``restore_index``
        consumers holding the old token correctly treat the grown corpus as
        new content); the rewritten block is dropped from its cache. Handles
        and partitions opened *before* the append keep their old manifest —
        their ``[0, old n_docs)`` reads stay correct, they just don't see the
        new rows until reopened."""
        from repro.core.backend import backend_for_store_layout

        be = backend_for_store_layout(self, corpus)
        if self.kind == "dense":
            new_fields = {"x": np.asarray(be.x)}
        else:
            new_fields = {"values": np.asarray(be.values),
                          "cols": np.asarray(be.cols, np.int32)}
        b_new = next(iter(new_fields.values())).shape[0]
        if b_new == 0:
            return self.manifest_hash
        n0, bd = self.n_docs, self.block_docs
        last = self.n_blocks - 1
        valid_in_last = n0 - last * bd
        blocks = [dict(e) for e in self.manifest["blocks"]]

        def _step(label: str) -> None:
            # kill-point seam: a FaultPlan(kill_after_writes=k) "crashes" the
            # append before its (k+1)-th write step — the crash-safety sweep
            # in tests exercises every step boundary
            if self.fault_plan is not None:
                self.fault_plan.on_write(label)

        def _write(i: int, rows: Dict[str, np.ndarray], gen: str = "") -> dict:
            # per-field digest layout must match save_store exactly; ``gen``
            # suffixes the rewritten tail block's file names so the file the
            # OLD manifest references is never touched (n_docs strictly
            # grows, so generation names are unique per append)
            if self.kind == "dense":
                fx = f"dense_{i:05d}{gen}.npy"
                _step(f"block:{i}:x")
                return {"i": i, "files": {"x": fx},
                        "digest": _replace_block(self.path, fx,
                                                 _pad_rows(rows["x"], bd))}
            fv = f"ell_values_{i:05d}{gen}.npy"
            fc = f"ell_cols_{i:05d}{gen}.npy"
            _step(f"block:{i}:values")
            dv = _replace_block(self.path, fv, _pad_rows(rows["values"], bd))
            _step(f"block:{i}:cols")
            dc = _replace_block(self.path, fc, _pad_rows(rows["cols"], bd))
            return {"i": i, "files": {"values": fv, "cols": fc},
                    "digest": dc + dv}

        def _slice(lo: int, hi: int) -> Dict[str, np.ndarray]:
            return {k: v[lo:hi] for k, v in new_fields.items()}

        # every file is written before the manifest replace, and none of them
        # is referenced by the old manifest (the merged tail block gets a
        # fresh generation name), so a crash anywhere leaves the old manifest
        # consistent and verifiable; the superseded tail file becomes an
        # unreferenced orphan once the new manifest lands
        consumed = min(bd - valid_in_last, b_new) if valid_in_last < bd else 0
        new_entries = []
        start = consumed
        i = last + 1
        while start < b_new:
            new_entries.append(_write(i, _slice(start, start + bd)))
            start += bd
            i += 1
        rewritten = None
        if consumed:
            old = self._load_block(last)  # direct read: no cache-stats noise
            merged = {
                k: np.concatenate(
                    [old[k][:valid_in_last], new_fields[k][:consumed]], axis=0
                )
                for k in new_fields
            }
            rewritten = _write(last, merged, gen=f"_g{n0 + b_new:09d}")
            blocks[last] = rewritten

        manifest = dict(self.manifest)
        manifest["blocks"] = blocks + new_entries
        manifest["n_docs"] = n0 + b_new
        manifest["n_blocks"] = len(manifest["blocks"])
        mtmp = os.path.join(self.path, MANIFEST_NAME + ".tmp")
        _step("manifest:tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        _step("manifest:replace")
        os.replace(mtmp, os.path.join(self.path, MANIFEST_NAME))
        _step("post-commit")

        self.manifest = manifest  # rebind: stale handles keep the old dict
        self.__dict__.pop("_manifest_hash", None)  # rotate the content token
        if rewritten is not None:
            self.cache.drop(last)
        return self.manifest_hash


@dataclasses.dataclass
class StoreSlice:
    """A contiguous row-range view over a :class:`CorpusStore`.

    Duck-types the store's read surface (``kind``/``dim``/``nnz_max``/
    ``take_rows``) with local row ids ``[0, n_docs)`` mapped onto the parent's
    ``[lo, hi)`` — accepted anywhere a store is (store-backed
    ``topk_search``, ``from_store`` chunk backends)."""

    store: CorpusStore
    lo: int
    hi: int

    def __post_init__(self):
        if not 0 <= self.lo <= self.hi <= self.store.n_docs:
            raise ValueError(
                f"slice [{self.lo}, {self.hi}) outside "
                f"[0, {self.store.n_docs}]"
            )

    @property
    def kind(self) -> str:
        """Parent store's block layout."""
        return self.store.kind

    @property
    def n_docs(self) -> int:
        """Rows in this view."""
        return self.hi - self.lo

    @property
    def dim(self) -> int:
        """Parent store's vector dimensionality."""
        return self.store.dim

    @property
    def nnz_max(self) -> Optional[int]:
        """Parent store's ELL padding width (None for dense)."""
        return self.store.nnz_max

    @property
    def dtype(self) -> np.dtype:
        """Parent store's vector element dtype."""
        return self.store.dtype

    @property
    def manifest_hash(self) -> str:
        """Parent store's content token (slices share corpus identity)."""
        return self.store.manifest_hash

    def take_rows(self, rows: np.ndarray) -> Dict[str, np.ndarray]:
        """Gather view-local rows (offset into the parent's range);
        ids outside ``[0, n_docs)`` of the *view* raise — offsetting must not
        silently reinterpret them as other parent rows."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_docs):
            raise IndexError(
                f"row ids outside the view's [0, {self.n_docs}): "
                f"[{rows.min()}, {rows.max()}]"
            )
        return self.store.take_rows(rows + self.lo)

    def take_rows_masked(
        self, rows: np.ndarray
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Masked gather of view-local rows (see
        :meth:`CorpusStore.take_rows_masked`); the same view-bounds check as
        :meth:`take_rows` applies."""
        rows = np.asarray(rows)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n_docs):
            raise IndexError(
                f"row ids outside the view's [0, {self.n_docs}): "
                f"[{rows.min()}, {rows.max()}]"
            )
        return self.store.take_rows_masked(rows + self.lo)

    @property
    def quarantined(self) -> Dict[int, str]:
        """Parent store's quarantine map (block id → reason) — shared across
        every view/partition of the same disk."""
        return self.store.quarantined


def load_manifest(mpath: str) -> dict:
    """Parse a JSON manifest/sidecar, surfacing :class:`ManifestError` (which
    names the offending path) instead of a raw ``json.JSONDecodeError`` on
    corrupt or truncated files."""
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise ManifestError(
            mpath, f"corrupt or truncated manifest — not valid JSON ({e})"
        ) from e
    if not isinstance(manifest, dict):
        raise ManifestError(
            mpath,
            f"expected a JSON object, got {type(manifest).__name__}",
        )
    return manifest


def open_store(
    path: str,
    budget_bytes: int = DEFAULT_BUDGET_BYTES,
    verify: bool = False,
    fault_plan: Optional[object] = None,
    read_policy: Optional[ReadPolicy] = None,
) -> CorpusStore:
    """Open an on-disk corpus store with an LRU residency budget.

    ``budget_bytes`` bounds decoded-block residency (the out-of-core dial —
    benchmarks/oocore.py sweeps it). ``verify=True`` re-hashes every block
    file against the manifest digests before returning (slow; integrity
    check after a copy) — independent of the per-read verification that
    ``read_policy`` (default :class:`ReadPolicy`: verify on, 3 retries)
    applies to every block decode. ``fault_plan`` threads a
    :class:`repro.core.faults.FaultPlan` behind all reads/appends for
    reproducible fault injection. Blocks excised by ``store_fsck`` open
    pre-quarantined: reads raise :class:`BlockUnavailable`, degrade-mode
    searches drop their rows."""
    mpath = os.path.join(path, MANIFEST_NAME)
    if not os.path.exists(mpath):
        raise FileNotFoundError(f"no corpus store at {path} (missing {MANIFEST_NAME})")
    manifest = load_manifest(mpath)
    if manifest.get("format") != FORMAT_TAG:
        raise ManifestError(
            mpath,
            f"unknown store format {manifest.get('format')!r} "
            f"(expected {FORMAT_TAG!r})",
        )
    if verify:
        for entry in manifest["blocks"]:
            if entry.get("excised"):
                continue  # fsck tombstone: no files to verify
            # field-name-sorted order, matching save_store's concatenation
            # (manifest JSON round-trips with sort_keys, so .values() order
            # is already sorted — sorting explicitly keeps it load-order-proof)
            dig = "".join(
                _digest(os.path.join(path, entry["files"][name]))
                for name in sorted(entry["files"])
            )
            if dig != entry["digest"]:
                raise BlockCorrupt(
                    path, entry["i"],
                    "content does not match its manifest digest "
                    "(corrupt or partially rewritten store)",
                )
    store = CorpusStore(path=path, manifest=manifest, cache=None,  # type: ignore[arg-type]
                        read_policy=read_policy or ReadPolicy(),
                        fault_plan=fault_plan)
    for entry in manifest["blocks"]:
        if entry.get("excised"):
            store.quarantined[entry["i"]] = (
                "excised by store_fsck: " + str(entry.get("reason", ""))
            )
    store.cache = BlockCache(budget_bytes, store._load_block)
    return store
