"""Measured-overlap phase profiling (DESIGN.md §11).

The disk paths hide three hand-tuned overlap knobs — query ``pipeline=``,
store ``prefetch=``, chunk/fragment size — and until now nothing measured
whether the phases they are supposed to overlap (disk read, H2D staging,
device compute/D2H) actually do. This module is the measurement layer:
named **spans** on an injectable monotonic clock (the same seam as
``engine.LatencyRecorder``), recorded as plain ``(name, t0, t1, depth)``
tuples cheap enough to thread through the hot paths —
``store.BlockCache``/``store.Prefetcher``, ``query._pipeline_chunks`` /
``query._store_chunk_iter``, ``ktree.build_from_store``, and the
``engine.ServingEngine`` dispatch loop all take an optional profiler.

Span names used by the wired paths (callers may add their own):

- ``"read"`` — one chunk/batch's store row fetch (on the consumer thread
  when ``prefetch=0``, on the ``Prefetcher`` reader thread when ≥ 1 — the
  wall-clock intervals then genuinely interleave with compute, which is
  exactly what :meth:`Profiler.overlap_seconds` measures);
- ``"disk_read"`` — one block decode inside ``BlockCache.get`` (nested
  under ``"read"``);
- ``"dispatch"`` — H2D staging + jit dispatch of one query chunk;
- ``"compute"`` — the blocking ``device_get`` on one chunk's in-flight
  result (device compute + D2H copy-out);
- ``"insert"`` — one streaming-build batch's insert waves;
- ``"engine_batch"`` / ``"engine_call"`` — one serving-engine batch /
  one offline-engine call inside it.

Disabled mode: pass ``NULL_PROFILER`` (the default everywhere). Its
``span()`` returns one preallocated no-op context manager — no clock
reads, no record allocation, no per-call garbage — so instrumented code
pays a single attribute lookup and a branch-free ``with`` when profiling
is off (pinned by tests/test_profile.py's zero-allocation test).

Thread safety: records append to a plain list (atomic under the GIL) and
nesting depth is tracked per-thread, so a ``Prefetcher`` reader thread and
the consumer loop can share one profiler; interval queries merge across
threads, which is what makes cross-thread overlap measurable at all.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, NamedTuple, Sequence, Tuple


class SpanRecord(NamedTuple):
    """One closed span: ``name``, clock times ``t0 ≤ t1``, and ``depth``
    (0 = outermost on its thread; nested spans count up)."""

    name: str
    t0: float
    t1: float
    depth: int

    @property
    def seconds(self) -> float:
        """Span duration on the profiler's clock."""
        return self.t1 - self.t0


class _SpanCtx:
    """Context manager for one in-flight span (see :meth:`Profiler.span`)."""

    __slots__ = ("_prof", "_name", "_t0", "_depth")

    def __init__(self, prof: "Profiler", name: str):
        self._prof = prof
        self._name = name

    def __enter__(self) -> "_SpanCtx":
        tls = self._prof._tls
        self._depth = getattr(tls, "depth", 0)
        tls.depth = self._depth + 1
        self._t0 = self._prof.clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._prof.clock()
        self._prof._tls.depth = self._depth
        self._prof._records.append(
            SpanRecord(self._name, self._t0, t1, self._depth)
        )
        return False


class _NullSpan:
    """The do-nothing span ``NULL_PROFILER.span()`` hands out — one shared
    instance, so the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Profiler:
    """Span recorder on an injectable monotonic clock.

    ``clock`` defaults to ``time.perf_counter``; tests inject a fake ticking
    clock and assert span exactness (the ``LatencyRecorder`` pattern).
    ``enabled`` is ``True`` — hot paths guard optional extra work (e.g. the
    block-level ``"disk_read"`` spans) on it so the :data:`NULL_PROFILER`
    singleton stays free."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._records: List[SpanRecord] = []
        self._tls = threading.local()

    def span(self, name: str) -> _SpanCtx:
        """A context manager timing one named phase::

            with prof.span("read"):
                rows = store.take_rows(ids)

        Nesting is tracked per thread (the inner span's ``depth`` is the
        outer's + 1); the record lands when the block exits."""
        return _SpanCtx(self, name)

    def add(self, name: str, t0: float, t1: float, depth: int = 0) -> None:
        """Record a span measured externally (pre-timed phases, tests)."""
        self._records.append(SpanRecord(name, float(t0), float(t1), depth))

    @property
    def records(self) -> Tuple[SpanRecord, ...]:
        """All closed spans, in completion order (across threads)."""
        return tuple(self._records)

    def reset(self) -> None:
        """Drop all recorded spans (between sweep cells)."""
        self._records.clear()

    def totals(self) -> Dict[str, Dict[str, float]]:
        """Per-name aggregate: ``{name: {"seconds": Σ duration, "count": n}}``.

        Nested same-name spans both count — callers that need exclusive
        time should use distinct names per level (the wired paths do)."""
        out: Dict[str, Dict[str, float]] = {}
        for r in self._records:
            agg = out.setdefault(r.name, {"seconds": 0.0, "count": 0})
            agg["seconds"] += r.seconds
            agg["count"] += 1
        return out

    def intervals(self, name: str) -> List[Tuple[float, float]]:
        """The merged (disjoint, sorted) wall-clock intervals covered by any
        span named ``name`` — across threads and nesting levels."""
        spans = sorted(
            (r.t0, r.t1) for r in self._records if r.name == name
        )
        merged: List[Tuple[float, float]] = []
        for t0, t1 in spans:
            if merged and t0 <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], t1))
            else:
                merged.append((t0, t1))
        return merged

    def overlap_seconds(self, a: str, b: str) -> float:
        """Wall-clock seconds during which an ``a`` span and a ``b`` span
        were *simultaneously* open — the measured-overlap primitive the
        auto-tuner's report is built on (``core/autotune.py``): with
        ``prefetch ≥ 1`` the ``"read"`` spans run on the reader thread and
        genuinely intersect the consumer's ``"compute"`` spans; at depth 0
        they cannot, and this returns ~0."""
        ia, ib = self.intervals(a), self.intervals(b)
        total, i, j = 0.0, 0, 0
        while i < len(ia) and j < len(ib):
            lo = max(ia[i][0], ib[j][0])
            hi = min(ia[i][1], ib[j][1])
            if hi > lo:
                total += hi - lo
            if ia[i][1] <= ib[j][1]:
                i += 1
            else:
                j += 1
        return total

    def phase_report(self, names: Sequence[str] = ("read", "dispatch",
                                                   "compute")) -> str:
        """One-line human summary of the named phases + read/compute overlap
        (serving reports, benchmark rows)."""
        tot = self.totals()
        parts = [
            f"{n}={tot[n]['seconds'] * 1e3:.1f}ms×{tot[n]['count']}"
            for n in names if n in tot
        ]
        parts.append(
            f"read∩compute={self.overlap_seconds('read', 'compute') * 1e3:.1f}ms"
        )
        return " ".join(parts)


class NullProfiler(Profiler):
    """The disabled profiler: every ``span()`` returns the same no-op
    context manager and nothing is ever recorded. Hot paths take this as
    their default so instrumentation has near-zero cost when off."""

    enabled = False

    def __init__(self):
        super().__init__()

    def span(self, name: str) -> _NullSpan:  # type: ignore[override]
        """The shared no-op span — same object every call (no allocation)."""
        return _NULL_SPAN

    def add(self, name: str, t0: float, t1: float, depth: int = 0) -> None:
        """Dropped."""


NULL_PROFILER = NullProfiler()
