"""Deterministic fault injection for the out-of-core serving path.

The paper's disk-based K-tree (DESIGN.md §9) makes query answers depend on
block I/O, background reader threads, and a dispatcher thread.  This module
is the single seam through which every failure mode of that stack is
injected *reproducibly*: a :class:`FaultPlan` is handed to
``open_store(fault_plan=...)`` and consulted on every block read (and on
every write step of ``CorpusStore.append``), so tests and benchmarks can
replay the exact same fault schedule from a seed.

Fault taxonomy (DESIGN.md §10):

- **transient read errors** — an :class:`InjectedReadError` on a subset of
  read attempts; the hardened read path retries with capped exponential
  backoff and the answer stays bit-identical.
- **persistent read errors** — every attempt on a block fails; retries
  exhaust, the block is quarantined, and the read surfaces a typed
  ``BlockUnavailable``.
- **bit-flip corruption** — a byte of the on-disk payload is flipped past
  the ``.npy`` header; blake2b verification catches it and surfaces
  ``BlockCorrupt``.
- **read stalls** — a configurable sleep before a block's payload returns,
  exercising engine watchdog / ``EngineTimeout`` paths.
- **write kill-points** — :meth:`FaultPlan.on_write` raises
  :class:`InjectedCrash` after a configured number of write steps,
  simulating a crash at any point inside ``CorpusStore.append`` /
  ``insert_into_store`` for generation-safety tests.

All decisions are pure functions of ``(seed, block, attempt)`` — no global
RNG state — so a plan injects the same faults no matter how reads interleave
across threads.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Dict, FrozenSet, Iterable, Optional, Tuple


class InjectedReadError(IOError):
    """A :class:`FaultPlan`-injected block read failure.

    ``persistent`` distinguishes faults that will never clear (every attempt
    on the block fails) from transient ones that a retry can outlast.
    Transient faults are retryable; the hardened read path in
    ``core/store.py`` keys its retry decision off the ``retryable``
    attribute.
    """

    retryable = True

    def __init__(self, block: int, attempt: int, persistent: bool = False):
        kind = "persistent" if persistent else "transient"
        super().__init__(
            f"injected {kind} read fault: block {block}, attempt {attempt}"
        )
        self.block = block
        self.attempt = attempt
        self.persistent = persistent


class InjectedCrash(RuntimeError):
    """A :class:`FaultPlan`-injected process "crash" at a write step.

    Raised by :meth:`FaultPlan.on_write` once the configured number of write
    steps has completed — the kill-point seam for crash-safety sweeps over
    ``CorpusStore.append`` and ``insert_into_store``.
    """


@dataclasses.dataclass(frozen=True)
class FaultReport:
    """What a degraded (``on_fault="degrade"``) search dropped and why.

    Returned as the third element of a search's answer tuple.  When
    ``degraded`` is False the answer is bit-identical to a fault-free run;
    when True, only the listed quarantined blocks' candidates (or query
    rows) were dropped and the surviving answers are bit-identical to a
    reference search over the surviving subset.
    """

    degraded: bool = False
    quarantined_blocks: Tuple[int, ...] = ()
    dropped_query_rows: Tuple[int, ...] = ()
    dropped_docs: int = 0
    errors: Tuple[str, ...] = ()


def _coin(seed: int, *key) -> float:
    """Deterministic uniform [0, 1) draw keyed by ``(seed, *key)``."""
    h = hashlib.blake2b(repr((seed,) + key).encode(), digest_size=8).digest()
    return int.from_bytes(h, "big") / float(1 << 64)


class FaultPlan:
    """A seeded, per-block-addressable schedule of injected faults.

    Parameters
    ----------
    seed:
        Root of all randomized decisions; two plans with the same parameters
        inject identical fault schedules.
    transient_rate:
        Probability that any given ``(block, attempt)`` read attempt raises a
        transient :class:`InjectedReadError`.  The draw is a pure function of
        ``(seed, block, attempt)``, so a failing attempt fails on every
        replay and a retry (next attempt index) re-rolls the coin.
    transient_blocks / transient_attempts:
        Deterministic variant: the first ``transient_attempts`` read attempts
        of each listed block fail, later attempts succeed — the directed way
        to exercise the retry path.
    persistent_blocks:
        Blocks whose every read attempt fails (retries exhaust; the store
        quarantines them and raises ``BlockUnavailable``).
    corrupt_blocks:
        Blocks whose on-disk payload bytes are bit-flipped in flight (past
        the ``.npy`` header, so digest verification — not the parser — must
        catch it and raise ``BlockCorrupt``).
    stall_blocks / stall_s:
        Blocks whose reads sleep ``stall_s`` seconds before returning,
        for watchdog / timeout tests.
    kill_after_writes:
        If set, the ``kill_after_writes + 1``-th write step observed by
        :meth:`on_write` raises :class:`InjectedCrash` (the first
        ``kill_after_writes`` steps succeed).
    """

    def __init__(
        self,
        seed: int = 0,
        transient_rate: float = 0.0,
        transient_blocks: Iterable[int] = (),
        transient_attempts: int = 1,
        persistent_blocks: Iterable[int] = (),
        corrupt_blocks: Iterable[int] = (),
        stall_blocks: Iterable[int] = (),
        stall_s: float = 0.0,
        kill_after_writes: Optional[int] = None,
    ):
        self.seed = int(seed)
        self.transient_rate = float(transient_rate)
        self.transient_blocks: FrozenSet[int] = frozenset(transient_blocks)
        self.transient_attempts = int(transient_attempts)
        self.persistent_blocks: FrozenSet[int] = frozenset(persistent_blocks)
        self.corrupt_blocks: FrozenSet[int] = frozenset(corrupt_blocks)
        self.stall_blocks: FrozenSet[int] = frozenset(stall_blocks)
        self.stall_s = float(stall_s)
        self.kill_after_writes = kill_after_writes
        self._lock = threading.Lock()
        self._writes_seen = 0
        self._counts: Dict[str, int] = {
            "transient_injected": 0,
            "persistent_injected": 0,
            "corruptions_injected": 0,
            "stalls_injected": 0,
            "writes_seen": 0,
        }

    def _bump(self, key: str) -> None:
        with self._lock:
            self._counts[key] += 1

    def on_read(self, block: int, attempt: int) -> None:
        """Consulted before each read attempt; sleeps and/or raises.

        Called by the hardened read path in ``core/store.py`` with the
        0-based retry ``attempt`` index.  May sleep (stall), raise a
        persistent or transient :class:`InjectedReadError`, or return
        normally (no fault this attempt).
        """
        if self.stall_s > 0.0 and block in self.stall_blocks:
            self._bump("stalls_injected")
            time.sleep(self.stall_s)
        if block in self.persistent_blocks:
            self._bump("persistent_injected")
            raise InjectedReadError(block, attempt, persistent=True)
        if block in self.transient_blocks and attempt < self.transient_attempts:
            self._bump("transient_injected")
            raise InjectedReadError(block, attempt)
        if self.transient_rate > 0.0:
            if _coin(self.seed, "read", block, attempt) < self.transient_rate:
                self._bump("transient_injected")
                raise InjectedReadError(block, attempt)

    def corrupt_bytes(self, block: int, field: str, raw: bytes) -> bytes:
        """Bit-flip one payload byte of a corrupt block's field in flight.

        The flip lands past byte 128 (the ``.npy`` header) so the array still
        parses — only digest verification can detect the damage, which is
        exactly the failure mode the verify-at-read path must catch.
        """
        if block not in self.corrupt_blocks or len(raw) <= 129:
            return raw
        self._bump("corruptions_injected")
        span = len(raw) - 129
        pos = 129 + int(_coin(self.seed, "flip", block, field) * span)
        out = bytearray(raw)
        out[pos] ^= 0x40
        return bytes(out)

    def on_write(self, label: str) -> None:
        """Consulted before each write step; raises at the kill point.

        ``label`` names the step (e.g. ``"block:tail"``, ``"manifest"``) so
        crash sweeps can report where they died.
        """
        with self._lock:
            self._counts["writes_seen"] += 1
            n = self._counts["writes_seen"]
        if self.kill_after_writes is not None and n > self.kill_after_writes:
            raise InjectedCrash(
                f"injected crash before write step {n} ({label})"
            )

    @property
    def stats(self) -> Dict[str, int]:
        """Counters of injected faults so far (copied snapshot)."""
        with self._lock:
            return dict(self._counts)
