"""Clustering quality metrics — micro-averaged purity and entropy (paper §3),
plus NMI as an extra. All pure jnp (differentiability not needed, but jit-able
and shardable over documents).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def contingency(assign: jax.Array, labels: jax.Array, n_clusters: int, n_labels: int) -> jax.Array:
    """n[c, l] = #docs in cluster c with label l. assign/labels: i32[N]."""
    flat = assign.astype(jnp.int32) * n_labels + labels.astype(jnp.int32)
    counts = jnp.bincount(flat, length=n_clusters * n_labels)
    return counts.reshape(n_clusters, n_labels).astype(jnp.float32)


def micro_purity(assign, labels, n_clusters: int, n_labels: int) -> jax.Array:
    """Σ_c (n_c/N) · max_l n_cl / n_c = (1/N) Σ_c max_l n_cl — cluster scores
    weighted by cluster size (micro averaging, paper §3)."""
    n = contingency(assign, labels, n_clusters, n_labels)
    total = jnp.maximum(n.sum(), 1.0)
    return n.max(axis=1).sum() / total


def micro_entropy(assign, labels, n_clusters: int, n_labels: int) -> jax.Array:
    """Σ_c (n_c/N) · H(labels | c), H in bits normalised by log2(n_labels) so the
    score is in [0,1] (0 = pure). Lower is better."""
    n = contingency(assign, labels, n_clusters, n_labels)
    n_c = n.sum(axis=1, keepdims=True)
    p = n / jnp.maximum(n_c, 1.0)
    h = -jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0).sum(axis=1)
    h = h / jnp.log2(jnp.maximum(float(n_labels), 2.0))
    total = jnp.maximum(n.sum(), 1.0)
    return (n_c[:, 0] * h).sum() / total


def nmi(assign, labels, n_clusters: int, n_labels: int) -> jax.Array:
    """Normalised mutual information (arith-mean normalisation)."""
    n = contingency(assign, labels, n_clusters, n_labels)
    total = jnp.maximum(n.sum(), 1.0)
    p = n / total
    pc = p.sum(axis=1, keepdims=True)
    pl = p.sum(axis=0, keepdims=True)
    mi = jnp.where(p > 0, p * (jnp.log(jnp.maximum(p, 1e-30)) - jnp.log(jnp.maximum(pc * pl, 1e-30))), 0.0).sum()
    hc = -jnp.where(pc > 0, pc * jnp.log(jnp.maximum(pc, 1e-30)), 0.0).sum()
    hl = -jnp.where(pl > 0, pl * jnp.log(jnp.maximum(pl, 1e-30)), 0.0).sum()
    return 2.0 * mi / jnp.maximum(hc + hl, 1e-30)


def cluster_sizes(assign: jax.Array, n_clusters: int) -> jax.Array:
    return jnp.bincount(assign.astype(jnp.int32), length=n_clusters)
