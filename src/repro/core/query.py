"""Batched top-k beam-search query engine over a built K-tree (DESIGN.md §7).

The paper uses the K-tree as a nearest-neighbour search tree for retrieval;
the greedy root→leaf descent (``nn_search``) visits exactly one leaf, so a
query that routes into the "wrong" subtree near the root can never recover.
Beam search keeps the best ``beam`` branches per level instead of the argmin:

- **level 0** — the root's entries are one flat centre set, so the top-``beam``
  entries come from the backend's fused flat path (``topk_flat``: the
  ``nn_topk`` Pallas kernel for dense queries, the ``ell_spmm`` scoring path +
  ``top_k`` for sparse queries).
- **levels 1..depth−2** — each of the ``beam`` frontier nodes contributes its
  ≤ m+1 entries; all ``beam·(m+1)`` candidates are scored in one
  ``cross_nodes`` call (per-query gathered centres — MXU einsum for dense
  rows, nnz-bounded column gather for sparse rows) and the best ``beam``
  children become the next frontier.
- **leaf level** — the union of the ``beam`` candidate leaves' documents
  (their entries *are* the inserted vectors) is scored the same way and
  reduced to ``(doc_ids, dists)[B, k]``, ascending, exact squared distances.

Everything after backend construction is one jitted call per query chunk;
descent depth is bucketed to powers of two exactly like ``route``
(DESIGN.md §6), so a growing tree triggers O(log depth) compiles per
(beam, k) setting, not one per depth.

``beam=1, k=1`` reproduces the greedy ``nn_search`` bit-for-bit: every level
scores the same tensors with the same expressions and ``top_k``'s
tie-breaking (lowest index first) matches ``argmin``'s.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import VectorBackend, make_backend
from repro.core.ktree import KTree, _levels_bucket, chunked_query_rows


def _score_entries(
    tree: KTree, backend: VectorBackend, rows: jax.Array,
    frontier: jax.Array, active: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Score every entry of every frontier node against each query.

    Returns (diff_sq f32[B, beam·m1] = ‖c‖² − 2·x·c with invalid slots and
    inactive beams masked +inf, child i32[B, beam·m1]). The ‖x‖² constant is
    deliberately dropped — it cannot change any per-query ordering and keeping
    it out preserves bit-exact agreement with the greedy descent."""
    b, beam = frontier.shape
    m1 = tree.slots
    c = tree.centers[frontier].reshape(b, beam * m1, tree.dim)
    c_sq = jnp.einsum("bmd,bmd->bm", c, c)
    diff_sq = c_sq - 2.0 * backend.cross_nodes(rows, c)
    slot_ok = (
        jnp.arange(m1)[None, None, :] < tree.n_entries[frontier][:, :, None]
    )                                                        # [B, beam, m1]
    ok = jnp.logical_and(slot_ok, active[:, :, None]).reshape(b, beam * m1)
    diff_sq = jnp.where(ok, diff_sq, jnp.inf)
    child = tree.child[frontier].reshape(b, beam * m1)
    return diff_sq, child


@functools.partial(jax.jit, static_argnames=("max_levels", "beam", "k"))
def _beam_search(
    tree: KTree,
    backend: VectorBackend,
    rows: jax.Array,
    levels: jax.Array,
    max_levels: int,
    beam: int,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """One jitted beam-search descent + leaf scoring for a chunk of queries.

    Levels ≥ ``levels`` are masked no-ops (bucketed compiles, DESIGN.md §6).
    Returns (doc_ids i32[B, k], sqdist f32[B, k]) ascending; queries reaching
    fewer than k documents pad with (−1, +inf)."""
    b = rows.shape[0]
    frontier = jnp.full((b, beam), 1, jnp.int32) * tree.root
    active = jnp.broadcast_to(jnp.arange(beam) == 0, (b, beam))

    for l in range(max_levels):
        if l == 0:
            # root fast path: one flat centre set → fused top-beam
            valid = jnp.arange(tree.slots) < tree.n_entries[tree.root]
            idx, _ = backend.topk_flat(
                rows, tree.centers[tree.root], valid, beam
            )                                                # [B, beam]
            new_active = idx >= 0
            child_sel = tree.child[tree.root][jnp.maximum(idx, 0)]
        else:
            diff_sq, child = _score_entries(tree, backend, rows, frontier, active)
            negd, pos = jax.lax.top_k(-diff_sq, beam)
            new_active = jnp.isfinite(negd)
            child_sel = jnp.take_along_axis(child, pos, axis=1)
        child_sel = jnp.maximum(child_sel, 0)                # safe gather id
        act_l = jnp.asarray(l, jnp.int32) < levels
        frontier = jnp.where(act_l, child_sel, frontier)
        active = jnp.where(act_l, new_active, active)

    # leaf level: the frontier's entries are the candidate documents
    diff_sq, child = _score_entries(tree, backend, rows, frontier, active)
    negd, pos = jax.lax.top_k(-diff_sq, min(k, diff_sq.shape[1]))
    if k > negd.shape[1]:                                    # k > beam·(m+1)
        negd = jnp.pad(negd, ((0, 0), (0, k - negd.shape[1])),
                       constant_values=-jnp.inf)
        pos = jnp.pad(pos, ((0, 0), (0, k - pos.shape[1])))
    found = jnp.isfinite(negd)
    docs = jnp.where(found, jnp.take_along_axis(child, pos, axis=1), -1)
    # the dropped ‖x‖² goes back in after selection (greedy does the same)
    dist = jnp.where(
        found, jnp.maximum(-negd + backend.row_sq(rows)[:, None], 0.0), jnp.inf
    )
    return docs.astype(jnp.int32), dist


def topk_search(
    tree: KTree, q, k: int = 10, beam: int = 4, chunk: int = 512
) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k ANN document search with beam-width recall control.

    ``q``: dense vectors, a Csr matrix, or a backend. Returns
    (doc_ids i32[B, k], sqdist f32[B, k]) ascending per query; padded entries
    are (−1, +inf). ``beam=1`` is the greedy single-path descent; wider beams
    trade ~beam× more scored candidates for recall (benchmarks/query_recall.py
    sweeps the trade-off). Queries are processed in chunks of ``chunk`` to
    bound the [chunk, beam·(m+1), d] gathered-centre buffers."""
    if k < 1 or beam < 1:
        raise ValueError(f"k and beam must be ≥ 1, got k={k} beam={beam}")
    be = make_backend(q)
    if be.dim != tree.dim:
        raise ValueError(
            f"query dim {be.dim} != tree dim {tree.dim} "
            "(was the index built over a different corpus?)"
        )
    levels = int(tree.depth) - 1
    max_levels = _levels_bucket(levels)
    n = be.n_docs
    docs_out = np.full((n, k), -1, np.int32)
    dist_out = np.full((n, k), np.inf, np.float32)
    if n == 0:
        return docs_out, dist_out
    for rows_np, rows in chunked_query_rows(n, chunk):
        docs, dist = _beam_search(
            tree, be, rows, jnp.int32(levels),
            max_levels=max_levels, beam=beam, k=k,
        )
        docs_out[rows_np] = np.asarray(docs)[: rows_np.size]
        dist_out[rows_np] = np.asarray(dist)[: rows_np.size]
    return docs_out, dist_out


# ---------------------------------------------------------------------------
# evaluation helpers (shared by benchmarks/query_recall.py, launch/serve.py
# and the examples — one definition of ground truth and recall)
# ---------------------------------------------------------------------------

def brute_force_topk(x_q: np.ndarray, x_all: np.ndarray, k: int) -> np.ndarray:
    """Exact k-NN doc ids [nq, k] by squared distance (ties: lower id)."""
    d = (
        (x_q ** 2).sum(1)[:, None]
        - 2.0 * x_q @ x_all.T
        + (x_all ** 2).sum(1)[None, :]
    )
    return np.argsort(d, axis=1, kind="stable")[:, :k]


def recall_at_k(docs: np.ndarray, true_k: np.ndarray) -> float:
    """Mean |retrieved ∩ true| / k; −1 padding in ``docs`` never matches."""
    k = true_k.shape[1]
    return float(np.mean([
        len(set(docs[i].tolist()) & set(true_k[i].tolist())) / k
        for i in range(true_k.shape[0])
    ]))
