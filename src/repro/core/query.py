"""Batched top-k beam-search query engine over a built K-tree (DESIGN.md §7).

The paper uses the K-tree as a nearest-neighbour search tree for retrieval;
the greedy root→leaf descent (``nn_search``) visits exactly one leaf, so a
query that routes into the "wrong" subtree near the root can never recover.
Beam search keeps the best ``beam`` branches per level instead of the argmin:

- **level 0** — the root's entries are one flat centre set, so the top-``beam``
  entries come from the backend's fused flat path (``topk_flat``: the
  ``nn_topk`` Pallas kernel for dense queries, the ``ell_spmm`` scoring path +
  ``top_k`` for sparse queries).
- **levels 1..depth−2** — each of the ``beam`` frontier nodes contributes its
  ≤ m+1 entries; all ``beam·(m+1)`` candidates are scored in one
  ``cross_nodes`` call (per-query gathered centres — MXU einsum for dense
  rows, nnz-bounded column gather for sparse rows) and the best ``beam``
  children become the next frontier.
- **leaf level** — the union of the ``beam`` candidate leaves' documents
  (their entries *are* the inserted vectors) is scored the same way and
  reduced to ``(doc_ids, dists)[B, k]``, ascending, exact squared distances.

Everything after backend construction is one jitted call per query chunk;
descent depth is bucketed to powers of two exactly like ``route``
(DESIGN.md §6), so a growing tree triggers O(log depth) compiles per
(beam, k) setting, not one per depth.

``beam=1, k=1`` reproduces the greedy ``nn_search`` bit-for-bit: every level
scores the same tensors with the same expressions and ``top_k``'s
tie-breaking (lowest index first) matches ``argmin``'s.

Serving plane (DESIGN.md §8): ``topk_search`` streams chunks through a
dispatch-ahead pipeline (device compute overlaps D2H copy-out),
``topk_search_sharded`` runs the leaf scoring shard-parallel over a
row-sharded corpus with an exact O(B·k·n_shards) top-k merge, and
``AnswerCache``/``topk_search_cached`` put an LRU over repeated queries.

Out-of-core (DESIGN.md §9): ``topk_search`` also accepts a disk-backed
``CorpusStore``/``StoreSlice`` as the query source — each chunk's rows are
fetched from the store's block cache and materialised as a chunk-sized
backend, and the same dispatch-ahead pipeline overlaps the next chunk's disk
read with the previous chunk's device compute (``prefetch ≥ 1`` further moves
the read onto a ``store.Prefetcher`` reader thread, overlapping it with the
current chunk's D2H as well). ``topk_search_sharded`` accepts a store (or a
``backend.shard_from_store`` handle) as the *corpus*: the corpus stays on
disk behind per-shard block caches and each shard fetches only the beam
candidates it owns per chunk. Answers are bit-identical to the in-memory
paths throughout.

Random-projection routing (DESIGN.md §5.1): with ``rp=`` the tree was built
in a seeded low-dimensional projection (``backend.RandomProjBackend``) —
queries are projected per chunk, the beam descends in the projected space,
and the leaf candidate pool is **rescored from the original representation**
(in-memory base, ``CorpusStore.take_rows``, or per-shard partition caches)
at full precision. The rescore literally calls :func:`brute_force_topk_dist`
per query over its own candidate rows, so it is bit-identical to brute force
restricted to that pool by construction; the single-device, cached, and
sharded RP paths all extract pools through the same jitted
``_chunk_candidates`` and therefore bit-match each other.
"""
from __future__ import annotations

import collections
import functools
import hashlib
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.backend import (
    DenseBackend,
    DenseDocShards,
    DocShards,
    EllDocShards,
    ProjectionMismatch,
    RandomProjBackend,
    RandomProjection,
    StoreDocShards,
    VectorBackend,
    backend_from_rows,
    backend_from_store,
    is_store,
    make_backend,
    shard_from_store,
)
from repro.core.autotune import resolve_knobs
from repro.core.faults import FaultReport
from repro.core.ktree import (
    KTree, _levels_bucket, chunked_query_rows, leaf_nodes, padded_chunk_rows,
)
from repro.core.profile import NULL_PROFILER
from repro.core.store import check_on_fault
from repro.kernels.ref import topk_from_dist, topk_merge_ref


def _score_entries(
    tree: KTree, backend: VectorBackend, rows: jax.Array,
    frontier: jax.Array, active: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Score every entry of every frontier node against each query.

    Returns (diff_sq f32[B, beam·m1] = ‖c‖² − 2·x·c with invalid slots and
    inactive beams masked +inf, child i32[B, beam·m1]). The ‖x‖² constant is
    deliberately dropped — it cannot change any per-query ordering and keeping
    it out preserves bit-exact agreement with the greedy descent."""
    b, beam = frontier.shape
    m1 = tree.slots
    c = tree.centers[frontier].reshape(b, beam * m1, tree.dim)
    c_sq = jnp.einsum("bmd,bmd->bm", c, c)
    diff_sq = c_sq - 2.0 * backend.cross_nodes(rows, c)
    slot_ok = (
        jnp.arange(m1)[None, None, :] < tree.n_entries[frontier][:, :, None]
    )                                                        # [B, beam, m1]
    ok = jnp.logical_and(slot_ok, active[:, :, None]).reshape(b, beam * m1)
    diff_sq = jnp.where(ok, diff_sq, jnp.inf)
    child = tree.child[frontier].reshape(b, beam * m1)
    return diff_sq, child


def _beam_frontier(
    tree: KTree,
    backend: VectorBackend,
    rows: jax.Array,
    levels: jax.Array,
    max_levels: int,
    beam: int,
) -> Tuple[jax.Array, jax.Array]:
    """Beam descent to the leaf level: (frontier i32[B, beam] candidate leaf
    ids, active bool[B, beam]). Levels ≥ ``levels`` are masked no-ops (bucketed
    compiles, DESIGN.md §6). Shared by the single-device leaf scoring
    (:func:`_beam_search`) and the shard-parallel path, so both descend through
    bit-identical frontiers."""
    b = rows.shape[0]
    frontier = jnp.full((b, beam), 1, jnp.int32) * tree.root
    active = jnp.broadcast_to(jnp.arange(beam) == 0, (b, beam))

    for l in range(max_levels):
        if l == 0:
            # root fast path: one flat centre set → fused top-beam
            valid = jnp.arange(tree.slots) < tree.n_entries[tree.root]
            idx, _ = backend.topk_flat(
                rows, tree.centers[tree.root], valid, beam
            )                                                # [B, beam]
            new_active = idx >= 0
            child_sel = tree.child[tree.root][jnp.maximum(idx, 0)]
        else:
            diff_sq, child = _score_entries(tree, backend, rows, frontier, active)
            negd, pos = jax.lax.top_k(-diff_sq, beam)
            new_active = jnp.isfinite(negd)
            child_sel = jnp.take_along_axis(child, pos, axis=1)
        child_sel = jnp.maximum(child_sel, 0)                # safe gather id
        act_l = jnp.asarray(l, jnp.int32) < levels
        frontier = jnp.where(act_l, child_sel, frontier)
        active = jnp.where(act_l, new_active, active)
    return frontier, active


@functools.partial(jax.jit, static_argnames=("max_levels", "beam", "k"))
def _beam_search(
    tree: KTree,
    backend: VectorBackend,
    rows: jax.Array,
    levels: jax.Array,
    max_levels: int,
    beam: int,
    k: int,
) -> Tuple[jax.Array, jax.Array]:
    """One jitted beam-search descent + leaf scoring for a chunk of queries.

    Levels ≥ ``levels`` are masked no-ops (bucketed compiles, DESIGN.md §6).
    Returns (doc_ids i32[B, k], sqdist f32[B, k]) ascending; queries reaching
    fewer than k documents pad with (−1, +inf)."""
    frontier, active = _beam_frontier(tree, backend, rows, levels, max_levels, beam)

    # leaf level: the frontier's entries are the candidate documents
    diff_sq, child = _score_entries(tree, backend, rows, frontier, active)
    negd, pos = jax.lax.top_k(-diff_sq, min(k, diff_sq.shape[1]))
    if k > negd.shape[1]:                                    # k > beam·(m+1)
        negd = jnp.pad(negd, ((0, 0), (0, k - negd.shape[1])),
                       constant_values=-jnp.inf)
        pos = jnp.pad(pos, ((0, 0), (0, k - pos.shape[1])))
    found = jnp.isfinite(negd)
    docs = jnp.where(found, jnp.take_along_axis(child, pos, axis=1), -1)
    # the dropped ‖x‖² goes back in after selection (greedy does the same)
    dist = jnp.where(
        found, jnp.maximum(-negd + backend.row_sq(rows)[:, None], 0.0), jnp.inf
    )
    return docs.astype(jnp.int32), dist


def _pipeline_chunks(chunks, pipeline: int, dispatch, docs_out, dist_out,
                     prof=NULL_PROFILER):
    """Dispatch-ahead chunk loop (DESIGN.md §8): keep up to ``pipeline`` chunks
    in flight, copying out the oldest only once newer chunks are already
    dispatched — device compute overlaps the host-blocking D2H fetch instead of
    serialising behind it. ``pipeline=1`` reproduces the old synchronous loop
    (fetch immediately after each dispatch).

    ``chunks`` yields ``(rows_np, payload)`` pairs and ``dispatch(payload)``
    returns the chunk's in-flight device result. For store-backed queries the
    payload carries the chunk's global row ids and ``dispatch`` starts with a
    disk read — the same schedule then overlaps chunk i+1's block fetch with
    chunk i's device compute (DESIGN.md §9).

    ``prof`` (a ``repro.core.profile.Profiler``, DESIGN.md §11) records one
    ``"dispatch"`` span per chunk (H2D staging + jit dispatch) and one
    ``"compute"`` span per drain (the blocking device_get: device compute +
    D2H); the store iterator's ``"read"`` spans complete the picture."""
    depth = max(int(pipeline), 1)
    pending = collections.deque()

    def drain_one():
        rows_np, fut = pending.popleft()
        with prof.span("compute"):
            docs, dist = jax.device_get(fut)
        docs_out[rows_np] = docs[: rows_np.size]
        dist_out[rows_np] = dist[: rows_np.size]

    for rows_np, payload in chunks:
        with prof.span("dispatch"):
            fut = dispatch(payload)
        pending.append((rows_np, fut))
        while len(pending) >= depth:
            drain_one()
    while pending:
        drain_one()


def _store_chunk_iter(store, n: int, chunk: int, prefetch: int, dropped=None,
                      prof=NULL_PROFILER):
    """Yield ``(rows_np, fetched row arrays)`` per padded query chunk of a
    store source. ``prefetch=0``: the disk read happens inline, right before
    the chunk is dispatched (the §8 dispatch-ahead pipeline then overlaps it
    with the *previous* chunk's compute). ``prefetch ≥ 1``: the reads move to
    a ``store.Prefetcher`` reader thread of that depth, which additionally
    overlaps them with the current chunk's D2H copy-out — the yielded arrays
    (and hence the answers) are identical either way.

    ``dropped`` (degrade mode, DESIGN.md §10): a list that collects the
    global query-row ids whose store blocks were unreadable after retries —
    those rows are zero-filled in the yielded arrays and the caller must
    flag their answers (−1, +inf).

    ``prof`` records one ``"read"`` span per chunk fetch — on the consumer
    thread when ``prefetch=0``, on the reader thread when ≥ 1, so
    ``prof.overlap_seconds("read", "compute")`` measures whether the
    prefetch depth actually bought overlap (DESIGN.md §11)."""

    def fetch(req):
        rows_np, padded = req
        with prof.span("read"):
            if dropped is None:
                return store.take_rows(padded)
            got, ok = store.take_rows_masked(padded)
        if not ok.all():
            # padded[:rows_np.size] == rows_np (padding repeats the last row)
            dropped.extend(int(r) for r in rows_np[~ok[: rows_np.size]])
        return got

    if prefetch:
        from repro.core.store import Prefetcher

        with Prefetcher(
            padded_chunk_rows(n, chunk), fetch, depth=prefetch,
        ) as pf:
            for (rows_np, _), got in pf:
                yield rows_np, got
        return
    for req in padded_chunk_rows(n, chunk):
        yield req[0], fetch(req)


def topk_search(
    tree: KTree, q, k: int = 10, beam: int = 4, chunk: Optional[int] = None,
    pipeline: Optional[int] = None, prefetch: Optional[int] = None,
    on_fault: str = "raise", rp=None, rp_corpus=None, tuned=None,
    profiler=NULL_PROFILER,
) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k ANN document search with beam-width recall control.

    ``q``: dense vectors, a Csr matrix, a backend, or a disk-backed
    ``CorpusStore``/``StoreSlice`` (DESIGN.md §9 — rows are fetched
    block-by-block from disk, chunk backends replace the monolithic array,
    and answers stay bit-identical to the in-memory path). Returns
    (doc_ids i32[B, k], sqdist f32[B, k]) ascending per query; padded entries
    are (−1, +inf). ``beam=1`` is the greedy single-path descent; wider beams
    trade ~beam× more scored candidates for recall (benchmarks/query_recall.py
    sweeps the trade-off). Queries are processed in chunks of ``chunk`` to
    bound the [chunk, beam·(m+1), d] gathered-centre buffers; ``pipeline``
    chunks stay in flight at once (2 = double-buffered dispatch-ahead, 1 = the
    old synchronous loop — benchmarks/query_throughput.py measures the gap).
    ``prefetch ≥ 1`` (store sources only) moves the disk reads onto an async
    ``store.Prefetcher`` reader thread of that depth, overlapping the next
    chunk's read with compute *and* the current D2H — answers unchanged.

    Fault handling (DESIGN.md §10): with the default ``on_fault="raise"`` a
    store block that exhausts its read retries surfaces a typed
    ``BlockCorrupt``/``BlockUnavailable``. ``on_fault="degrade"`` instead
    drops only the unreadable blocks' query rows — their answers become
    (−1, +inf), surviving rows stay bit-identical to a fault-free run — and
    returns a third element, a :class:`repro.core.faults.FaultReport`
    flagging ``degraded=True`` whenever anything was dropped.

    Random projection (DESIGN.md §5.1): ``rp=`` (a ``RandomProjBackend`` or
    bare ``RandomProjection``) switches to approximate-route, exact-rescore:
    queries are projected per chunk, the descent runs in the projected space
    the tree was built in, and the leaf candidate pool is rescored from the
    original representation — ``rp_corpus=`` (defaulting to the rp backend's
    in-memory base; pass the ``CorpusStore`` for an out-of-core base). The
    rescore is bit-identical to :func:`brute_force_topk_dist` restricted to
    each query's pool (it *is* that call); only the pool membership is
    approximate. Not composable with ``on_fault="degrade"`` yet.

    Knob resolution (DESIGN.md §11): ``chunk``/``pipeline``/``prefetch``
    left as ``None`` fall back to ``tuned=`` (a ``TunedKnobs`` from
    ``core/autotune.py``, typically loaded from the store's ``TUNE.json``
    sidecar) and then to the repo defaults (512 / 2 / 0) — explicit values
    always win, and since the knobs only reschedule work the answers are
    bit-identical whichever way they resolve. ``profiler=`` (a
    ``core.profile.Profiler``) records per-chunk "read"/"dispatch"/"compute"
    spans; the default ``NULL_PROFILER`` is free."""
    if k < 1 or beam < 1:
        raise ValueError(f"k and beam must be ≥ 1, got k={k} beam={beam}")
    chunk, pipeline, prefetch = resolve_knobs(
        tuned, chunk=chunk, pipeline=pipeline, prefetch=prefetch,
    )
    check_on_fault(on_fault)
    if rp is not None:
        if on_fault != "raise":
            raise ValueError(
                "rp= does not compose with on_fault='degrade' yet"
            )
        projection, src = _resolve_rp(rp, rp_corpus)
        return _topk_search_rp(
            tree, q, projection, src, k=k, beam=beam, chunk=chunk,
            pipeline=pipeline, prefetch=prefetch, prof=profiler,
        )
    store = q if is_store(q) else None
    degrade = on_fault == "degrade"
    dropped: Optional[list] = [] if (degrade and store is not None) else None
    be = None if store is not None else make_backend(q)
    src = store if store is not None else be
    if src.dim != tree.dim:
        raise ValueError(
            f"query dim {src.dim} != tree dim {tree.dim} "
            "(was the index built over a different corpus?)"
        )
    levels = int(tree.depth) - 1
    max_levels = _levels_bucket(levels)
    n = src.n_docs
    docs_out = np.full((n, k), -1, np.int32)
    dist_out = np.full((n, k), np.inf, np.float32)
    if n == 0:
        if degrade:
            return docs_out, dist_out, FaultReport()
        return docs_out, dist_out

    if store is not None:
        # out-of-core: the chunk's rows are read from the store's block cache
        # (a host disk fetch — inline, or on a Prefetcher reader thread) and
        # dispatched as a chunk-sized backend; with pipeline ≥ 2 the next
        # chunk's read overlaps this chunk's compute
        def dispatch(got):
            be_c = backend_from_rows(store, got)
            rows = jnp.arange(be_c.n_docs, dtype=jnp.int32)
            return _beam_search(
                tree, be_c, rows, jnp.int32(levels),
                max_levels=max_levels, beam=beam, k=k,
            )

        chunks = _store_chunk_iter(
            store, n, chunk, prefetch, dropped, prof=profiler,
        )
    else:
        def dispatch(rows):
            return _beam_search(
                tree, be, rows, jnp.int32(levels),
                max_levels=max_levels, beam=beam, k=k,
            )

        chunks = chunked_query_rows(n, chunk)

    _pipeline_chunks(chunks, pipeline, dispatch, docs_out, dist_out,
                     prof=profiler)
    if degrade:
        rows_lost = tuple(sorted(set(dropped))) if dropped else ()
        if rows_lost:
            idx = np.asarray(rows_lost, np.int64)
            docs_out[idx] = -1
            dist_out[idx] = np.inf
        qset = tuple(sorted(store.quarantined)) if store is not None else ()
        return docs_out, dist_out, FaultReport(
            degraded=bool(rows_lost), quarantined_blocks=qset,
            dropped_query_rows=rows_lost,
        )
    return docs_out, dist_out


# ---------------------------------------------------------------------------
# shard-parallel serving path (DESIGN.md §8): replicated tree + descent,
# row-sharded corpus at the leaf level, exact O(B·k·n_shards) top-k merge
# ---------------------------------------------------------------------------

def _tree_max_doc(tree: KTree) -> int:
    """Largest doc id stored in any leaf (host-side scan)."""
    child = np.asarray(tree.child)
    ne = np.asarray(tree.n_entries)
    return max(
        (int(child[leaf, : ne[leaf]].max()) for leaf in leaf_nodes(tree)),
        default=-1,
    )


def corpus_from_tree(tree: KTree) -> np.ndarray:
    """Recover the dense doc-vector corpus [n_docs, d] from the tree's own
    leaves (leaf entries *are* the inserted vectors). Default corpus for
    :func:`topk_search_sharded` when the build-time corpus isn't at hand; doc
    ids never inserted stay zero rows (the tree never addresses them)."""
    leaves = leaf_nodes(tree)
    child = np.asarray(tree.child)
    ne = np.asarray(tree.n_entries)
    centers = np.asarray(tree.centers)
    n_docs = _tree_max_doc(tree) + 1
    x = np.zeros((n_docs, tree.dim), np.float32)
    for leaf in leaves:
        x[child[leaf, : ne[leaf]]] = centers[leaf, : ne[leaf]]
    return x


_SHARDED_FN_CACHE: dict = {}


def _get_sharded_chunk_fn(mesh, shards_treedef, shards_specs, max_levels, beam, k):
    """Build (and cache) the jitted shard-map chunk function for one
    (mesh, corpus layout, level bucket, beam, k) setting.

    Per shard: translate the replicated beam candidates' global doc ids to
    local rows, score the owned ones against the local corpus block, take a
    local top-k, then all-gather the (k-wide) per-shard winners and merge with
    :func:`topk_merge_ref` — collective volume O(B·k·n_shards), never O(B·n)."""
    from repro.core.distributed import data_axes, flat_shard_index, shard_map

    key = (mesh, shards_treedef, shards_specs, max_levels, beam, k)
    fn = _SHARDED_FN_CACHE.get(key)
    if fn is not None:
        return fn
    axes = data_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    spec_tree = jax.tree_util.tree_unflatten(shards_treedef, list(shards_specs))

    def leaf_merge(shards, xq, q_sq, cand, valid):
        # runs per shard: `shards` leaves are this shard's local corpus block
        del q_sq  # ordering is invariant to the per-query constant
        my_shard = flat_shard_index(mesh, axes)
        docs_per_shard = shards._rows0().shape[0]            # local block length
        local, owned = shards.to_local(cand, my_shard * docs_per_shard, docs_per_shard)
        owned = jnp.logical_and(owned, cand < shards.n_docs)  # pad rows own nothing
        part = shards.score_local(xq, local)                 # [B, C] ‖c‖²−2x·c
        part = jnp.where(jnp.logical_and(valid, owned), part, jnp.inf)
        pos, d_loc = topk_from_dist(part, k)                 # [B, k] local winners
        ids_loc = jnp.where(
            pos >= 0,
            jnp.take_along_axis(cand, jnp.clip(pos, 0, cand.shape[1] - 1), axis=1),
            -1,
        )
        # tiny collective: each shard contributes only its k-wide winner list
        g_d, g_i = d_loc, ids_loc
        for a in reversed(axes):
            g_d = jax.lax.all_gather(g_d, a)
            g_i = jax.lax.all_gather(g_i, a)
        b = xq.shape[0]
        g_d = g_d.reshape(n_shards, b, k).transpose(1, 0, 2)  # [B, S, k]
        g_i = g_i.reshape(n_shards, b, k).transpose(1, 0, 2)
        return topk_merge_ref(g_i, g_d, k)

    smap = shard_map(
        leaf_merge,
        mesh=mesh,
        in_specs=(spec_tree, P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def chunk_fn(tree, qbe, rows, levels, shards):
        cand, valid, xq, q_sq = _chunk_candidates(
            tree, qbe, rows, levels, max_levels, beam
        )
        ids, part_d = smap(shards, xq, q_sq, cand, valid)
        found = ids >= 0
        # the dropped ‖x‖² goes back in after the merge, exactly like _beam_search
        dist = jnp.where(
            found, jnp.maximum(part_d + q_sq[:, None], 0.0), jnp.inf
        )
        return ids, dist

    fn = jax.jit(chunk_fn)
    _SHARDED_FN_CACHE[key] = fn
    return fn


def _chunk_candidates(
    tree: KTree, qbe: VectorBackend, rows: jax.Array, levels: jax.Array,
    max_levels: int, beam: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Descend one query chunk and expose its leaf-level candidate set:
    (cand i32[B, beam·m1] global doc ids, valid bool[B, beam·m1],
    xq f32[B, d] densified queries, q_sq f32[B]). Shared by the in-memory
    sharded chunk fn and the store-backed sharded path, so both score the
    exact same candidates for the exact same queries."""
    frontier, active = _beam_frontier(tree, qbe, rows, levels, max_levels, beam)
    b = rows.shape[0]
    m1 = tree.slots
    cand = tree.child[frontier].reshape(b, beam * m1)
    slot_ok = (
        jnp.arange(m1)[None, None, :] < tree.n_entries[frontier][:, :, None]
    )
    valid = jnp.logical_and(slot_ok, active[:, :, None]).reshape(b, beam * m1)
    xq = qbe.take(rows).astype(jnp.float32)                  # chunk-sized densify
    q_sq = qbe.row_sq(rows)
    return cand, valid, xq, q_sq


@functools.partial(jax.jit, static_argnames=("max_levels", "beam"))
def _chunk_candidates_jit(tree, qbe, rows, levels, max_levels, beam):
    """Jitted :func:`_chunk_candidates` — the device half of the store-backed
    sharded path (the host half fetches the owned candidates from disk)."""
    return _chunk_candidates(tree, qbe, rows, levels, max_levels, beam)


_STORE_MERGE_FN_CACHE: dict = {}


def _get_store_merge_fn(mesh, kind: str, k: int):
    """Build (and cache) the jitted shard-map pool-scoring merge for one
    (mesh, store layout, k) setting — the out-of-core counterpart of
    :func:`_get_sharded_chunk_fn`'s leaf merge (DESIGN.md §9).

    Each shard scores its fetched candidate *pool* with the exact
    ``DenseDocShards``/``EllDocShards.score_local`` expressions (pool rows
    are bit-identical to the corpus rows they were read from, so per-shard
    distances — and the all-gathered ``topk_merge_ref`` result — match the
    in-memory sharded path bit for bit); the collective stays
    O(B·k·n_shards)."""
    from repro.core.distributed import data_axes, shard_map

    key = (mesh, kind, k)
    fn = _STORE_MERGE_FN_CACHE.get(key)
    if fn is not None:
        return fn
    axes = data_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    n_pools = 1 if kind == "dense" else 2
    pool_spec = tuple(P(axes, None, None) for _ in range(n_pools))

    def merge(pools, pool_idx, owned, xq, q_sq, cand, valid):
        # per shard: leading stacked axis is this shard's slot — squeeze it
        pool_idx, owned = pool_idx[0], owned[0]
        if kind == "dense":
            xd = pools[0][0][pool_idx].astype(jnp.float32)    # [B, C, d]
            c_sq = jnp.einsum("bcd,bcd->bc", xd, xd)
            part = c_sq - 2.0 * jnp.einsum(
                "bd,bcd->bc", xq.astype(jnp.float32), xd
            )
        else:
            pv, pc = pools[0][0], pools[1][0]                 # [U, nnz] each
            sq = jnp.sum(pv.astype(jnp.float32) ** 2, axis=1)
            v = pv[pool_idx].astype(jnp.float32)              # [B, C, nnz]
            c = pc[pool_idx]
            b_idx = jnp.arange(xq.shape[0])[:, None, None]
            g = xq.astype(jnp.float32)[b_idx, c]
            part = sq[pool_idx] - 2.0 * jnp.einsum("bcn,bcn->bc", v, g)
        part = jnp.where(jnp.logical_and(valid, owned), part, jnp.inf)
        pos, d_loc = topk_from_dist(part, k)
        ids_loc = jnp.where(
            pos >= 0,
            jnp.take_along_axis(cand, jnp.clip(pos, 0, cand.shape[1] - 1), axis=1),
            -1,
        )
        g_d, g_i = d_loc, ids_loc
        for a in reversed(axes):
            g_d = jax.lax.all_gather(g_d, a)
            g_i = jax.lax.all_gather(g_i, a)
        b = xq.shape[0]
        g_d = g_d.reshape(n_shards, b, k).transpose(1, 0, 2)  # [B, S, k]
        g_i = g_i.reshape(n_shards, b, k).transpose(1, 0, 2)
        ids, part_d = topk_merge_ref(g_i, g_d, k)
        # the dropped ‖x‖² goes back in after the merge, like _beam_search
        dist = jnp.where(
            ids >= 0, jnp.maximum(part_d + q_sq[:, None], 0.0), jnp.inf
        )
        return ids, dist

    smap = shard_map(
        merge,
        mesh=mesh,
        in_specs=(pool_spec, P(axes, None, None), P(axes, None, None),
                  P(), P(), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    fn = jax.jit(smap)
    _STORE_MERGE_FN_CACHE[key] = fn
    return fn


def _topk_search_sharded_store(
    mesh, tree: KTree, q, sshards: StoreDocShards, k: int, beam: int,
    chunk: int, on_fault: str = "raise", prefetch: int = 0,
    prof=NULL_PROFILER,
) -> Tuple[np.ndarray, np.ndarray]:
    """Shard-parallel top-k over a disk-backed corpus (DESIGN.md §9): per
    chunk, the jitted descent yields the beam candidate set, each shard's
    partition fetches only the candidates it owns through its own block
    cache (:meth:`StoreDocShards.chunk_pools`), and the shard-map pool merge
    returns the exact global top-k. The full corpus is never resident — peak
    store bytes stay within n_shards × per-shard budget.

    ``on_fault="degrade"`` (DESIGN.md §10) drops only the unreadable blocks'
    candidates (their docs score +inf, exactly as if no shard owned them) and
    unreadable *query* rows (flagged (−1, +inf)); surviving answers are
    bit-identical to a reference search over the surviving corpus subset.
    Returns a third :class:`repro.core.faults.FaultReport` element.

    ``prefetch ≥ 1`` moves store *query*-row reads onto a Prefetcher reader
    thread (the corpus-candidate fetches stay demand-driven — they depend on
    each chunk's descent); ``prof`` records "read" spans per query-chunk
    fetch, "dispatch" around the jitted descent, and "compute" around the
    host-sync pool fetch + shard-map merge (DESIGN.md §11)."""
    degrade = on_fault == "degrade"
    store_q = q if is_store(q) else None
    qbe = None if store_q is not None else make_backend(q)
    n = (store_q if store_q is not None else qbe).n_docs
    levels = int(tree.depth) - 1
    max_levels = _levels_bucket(levels)
    merge_fn = _get_store_merge_fn(mesh, sshards.kind, k)
    docs_out = np.full((n, k), -1, np.int32)
    dist_out = np.full((n, k), np.inf, np.float32)
    rows_lost: set = set()
    docs_lost: set = set()

    def _report() -> FaultReport:
        qset = set(sshards.parts[0].quarantined)
        if store_q is not None:
            qset |= set(store_q.quarantined)
        return FaultReport(
            degraded=bool(rows_lost or docs_lost),
            quarantined_blocks=tuple(sorted(qset)),
            dropped_query_rows=tuple(sorted(rows_lost)),
            dropped_docs=len(docs_lost),
        )

    if n == 0:
        return (docs_out, dist_out, _report()) if degrade \
            else (docs_out, dist_out)
    if store_q is not None:
        dropped_q: list = []

        def chunk_backends():
            for rows_np, got in _store_chunk_iter(
                store_q, n, chunk, prefetch,
                dropped_q if degrade else None, prof=prof,
            ):
                qbe_c = backend_from_rows(store_q, got)
                rows = jnp.arange(qbe_c.n_docs, dtype=jnp.int32)
                yield rows_np, qbe_c, rows
    else:
        def chunk_backends():
            for rows_np, padded in padded_chunk_rows(n, chunk):
                yield rows_np, qbe, jnp.asarray(padded.astype(np.int32))

    for rows_np, qbe_c, rows in chunk_backends():
        with prof.span("dispatch"):
            cand, valid, xq, q_sq = _chunk_candidates_jit(
                tree, qbe_c, rows, jnp.int32(levels),
                max_levels=max_levels, beam=beam,
            )
        with prof.span("compute"):
            # host sync: the candidate ids drive this chunk's disk fetches
            pools, pool_idx, owned, dropped_ids = sshards.chunk_pools(
                np.asarray(cand), np.asarray(valid), on_fault=on_fault
            )
            if dropped_ids.size:
                docs_lost.update(int(i) for i in dropped_ids)
            ids, dist = merge_fn(
                pools, pool_idx, owned, xq, q_sq, cand, valid
            )
            docs_out[rows_np] = np.asarray(ids)[: rows_np.size]
            dist_out[rows_np] = np.asarray(dist)[: rows_np.size]
    if store_q is not None and dropped_q:
        rows_lost.update(dropped_q)
    if degrade:
        if rows_lost:
            idx = np.asarray(sorted(rows_lost), np.int64)
            docs_out[idx] = -1
            dist_out[idx] = np.inf
        return docs_out, dist_out, _report()
    return docs_out, dist_out


def shard_corpus(mesh, corpus, axes=None) -> DocShards:
    """Normalise (corpus, mesh) into a row-sharded corpus view: accepts a dense
    array, Csr, backend, or an already-sharded ``*DocShards`` (passed through)."""
    if isinstance(corpus, (DenseDocShards, EllDocShards)):
        return corpus
    return make_backend(corpus).shard(mesh, axes)


def topk_search_sharded(
    mesh, tree: KTree, q, corpus=None, k: int = 10, beam: int = 4,
    chunk: Optional[int] = None, pipeline: Optional[int] = None,
    prefetch: Optional[int] = None, on_fault: str = "raise",
    rp=None, rp_corpus=None, tuned=None, profiler=NULL_PROFILER,
) -> Tuple[np.ndarray, np.ndarray]:
    """Shard-parallel top-k search: same answers as :func:`topk_search`, with
    the corpus row-sharded over ``mesh``'s data axes (DESIGN.md §8).

    The (small) tree is replicated and every shard descends the full query
    chunk (descent touches only internal-node centres); at the leaf level each
    shard scores just the beam candidates *it owns* against its local corpus
    block, reduces them to a k-wide winner list, and an all-gather +
    :func:`topk_merge_ref` merge produces the exact global (doc_ids, dists)
    [B, k] — the collective moves O(B·k·n_shards) scalars, never O(B·n).

    ``corpus``: the document corpus the tree was built over (array, Csr,
    backend, or a pre-sharded ``backend.shard(mesh)`` result — pass the latter
    when serving many batches so rows are placed once). Defaults to the dense
    vectors recovered from the tree's own leaves. Exact distance ties across
    shards resolve in shard-major (= doc-id-range) order, which can differ
    from the single-device candidate order; real-valued corpora are unaffected.

    Out-of-core (DESIGN.md §9): a ``CorpusStore`` corpus (or a pre-built
    ``backend.shard_from_store`` handle — pass that when serving many batches
    so the per-shard block caches persist) keeps the corpus on disk: each
    shard fetches only the beam candidates it owns through its own block
    cache, and answers stay bit-identical to the in-memory sharded path.
    ``q`` may itself be a store/slice (chunk rows fetched on demand), with
    either corpus kind. The store-corpus path runs one chunk at a time
    (``pipeline`` does not apply): the descent's candidate ids must return to
    the host to drive that chunk's disk fetches.

    Fault handling (DESIGN.md §10): ``on_fault="degrade"`` applies to store
    query sources and store corpora — unreadable query rows answer (−1, +inf)
    and quarantined corpus blocks' candidates are dropped (scored +inf, as
    if no shard owned them); surviving answers stay bit-identical to a
    reference search over the surviving subset. Degrade mode returns a third
    :class:`repro.core.faults.FaultReport` element; the default ``"raise"``
    keeps the two-tuple API and surfaces typed block errors.

    Random projection (DESIGN.md §5.1): with ``rp=``, descent is replicated
    work anyway (it touches only the small projected tree) and the exact
    rescore runs host-side against the original corpus — ``rp_corpus=``,
    the ``corpus`` argument, or the rp backend's base, in that order. A
    ``StoreDocShards`` corpus keeps the rescore fetches behind the
    per-shard partition caches (residency stays bounded). The candidate
    pools come from the same jitted descent as the single-device RP path,
    and the rescore is the same per-query ``brute_force_topk_dist`` call —
    so sharded RP answers are bit-identical to single-device RP answers by
    construction. Not composable with ``on_fault="degrade"`` yet.

    ``prefetch ≥ 1`` (store query sources and the RP route) moves the disk
    reads onto a ``store.Prefetcher`` reader thread, exactly as in
    :func:`topk_search` — answers unchanged. ``chunk``/``pipeline``/
    ``prefetch`` left ``None`` resolve through ``tuned=`` then the repo
    defaults (DESIGN.md §11); ``profiler=`` records the same
    "read"/"dispatch"/"compute" spans as the single-device path.
    """
    if k < 1 or beam < 1:
        raise ValueError(f"k and beam must be ≥ 1, got k={k} beam={beam}")
    chunk, pipeline, prefetch = resolve_knobs(
        tuned, chunk=chunk, pipeline=pipeline, prefetch=prefetch,
    )
    check_on_fault(on_fault)
    if rp is not None:
        if on_fault != "raise":
            raise ValueError(
                "rp= does not compose with on_fault='degrade' yet"
            )
        projection, src = _resolve_rp(
            rp, rp_corpus if rp_corpus is not None else corpus
        )
        return _topk_search_rp(
            tree, q, projection, src, k=k, beam=beam, chunk=chunk,
            pipeline=pipeline, prefetch=prefetch, prof=profiler,
        )
    degrade = on_fault == "degrade"
    store_q = q if is_store(q) else None
    qbe = None if store_q is not None else make_backend(q)
    q_src = store_q if store_q is not None else qbe
    if q_src.dim != tree.dim:
        raise ValueError(
            f"query dim {q_src.dim} != tree dim {tree.dim} "
            "(was the index built over a different corpus?)"
        )
    if isinstance(corpus, StoreDocShards) or is_store(corpus):
        sshards = (
            corpus if isinstance(corpus, StoreDocShards)
            else shard_from_store(mesh, corpus)
        )
        if sshards.dim != tree.dim:
            raise ValueError(
                f"corpus dim {sshards.dim} != tree dim {tree.dim}"
            )
        return _topk_search_sharded_store(
            mesh, tree, q, sshards, k=k, beam=beam, chunk=chunk,
            on_fault=on_fault, prefetch=prefetch, prof=profiler,
        )
    fresh = not isinstance(corpus, (DenseDocShards, EllDocShards))
    shards = shard_corpus(mesh, corpus_from_tree(tree) if corpus is None else corpus)
    if shards.dim != tree.dim:
        raise ValueError(f"corpus dim {shards.dim} != tree dim {tree.dim}")
    if fresh and corpus is not None:
        # sharding a raw corpus already walks the host arrays once — spend a
        # cheap extra scan making a wrong-corpus pairing loud instead of
        # silently dropping the doc ids the corpus can't address. Pre-sharded
        # corpora (the serving hot path) skip this; callers own the pairing.
        max_doc = _tree_max_doc(tree)
        if max_doc >= shards.n_docs:
            raise ValueError(
                f"tree addresses doc id {max_doc} but the corpus has only "
                f"{shards.n_docs} rows (was the index built over a different "
                "corpus?)"
            )
    from repro.core.distributed import data_axes

    axes = data_axes(mesh)
    leaves, treedef = jax.tree_util.tree_flatten(shards)
    specs = tuple(P(axes, *([None] * (l.ndim - 1))) for l in leaves)
    levels = int(tree.depth) - 1
    fn = _get_sharded_chunk_fn(
        mesh, treedef, specs, _levels_bucket(levels), beam, k
    )
    n = q_src.n_docs
    docs_out = np.full((n, k), -1, np.int32)
    dist_out = np.full((n, k), np.inf, np.float32)
    rows_lost: set = set()
    if n == 0:
        return (docs_out, dist_out, FaultReport()) if degrade \
            else (docs_out, dist_out)

    if store_q is not None:
        # store-sourced queries: fetch each chunk's rows from the block cache
        # (inline, or on a Prefetcher reader thread when prefetch ≥ 1) and
        # descend a chunk-sized backend, exactly like topk_search's §9 path
        dropped_q: Optional[list] = [] if degrade else None

        def dispatch(got):
            qbe_c = backend_from_rows(store_q, got)
            rows = jnp.arange(qbe_c.n_docs, dtype=jnp.int32)
            return fn(tree, qbe_c, rows, jnp.int32(levels), shards)

        chunks = _store_chunk_iter(
            store_q, n, chunk, prefetch, dropped_q, prof=profiler,
        )
    else:
        def dispatch(rows):
            return fn(tree, qbe, rows, jnp.int32(levels), shards)

        chunks = chunked_query_rows(n, chunk)

    _pipeline_chunks(chunks, pipeline, dispatch, docs_out, dist_out,
                     prof=profiler)
    if degrade:
        if store_q is not None and dropped_q:
            rows_lost.update(dropped_q)
        if rows_lost:
            idx = np.asarray(sorted(rows_lost), np.int64)
            docs_out[idx] = -1
            dist_out[idx] = np.inf
        qset = tuple(sorted(store_q.quarantined)) if store_q is not None else ()
        return docs_out, dist_out, FaultReport(
            degraded=bool(rows_lost), quarantined_blocks=qset,
            dropped_query_rows=tuple(sorted(rows_lost)),
        )
    return docs_out, dist_out


# ---------------------------------------------------------------------------
# random-projection routing (DESIGN.md §5.1): beam descent in the projected
# space, exact rescore of the leaf candidate pool from the original
# representation. Approximate-route, exact-rescore — the Random Indexing
# K-tree's serving path.
# ---------------------------------------------------------------------------


def _resolve_rp(rp, src):
    """Normalise the ``rp=``/``rp_corpus=`` pair into (projection, rescore
    source) with typed validation. ``rp``: a ``RandomProjection`` or a
    ``RandomProjBackend`` (whose in-memory ``base``, if any, is the default
    source); ``src``: an explicit original-representation corpus — array,
    Csr, backend, ``CorpusStore``/``StoreSlice``, ``*DocShards``, or a
    ``StoreDocShards`` handle (rescore rows then fetch through the per-shard
    partition caches)."""
    if isinstance(rp, RandomProjBackend):
        projection = rp.projection
        if src is None:
            src = rp.base
    elif isinstance(rp, RandomProjection):
        projection = rp
    else:
        raise TypeError(
            f"rp must be a RandomProjection or RandomProjBackend, "
            f"got {type(rp).__name__}"
        )
    if isinstance(src, RandomProjBackend):
        src = src.base
    if src is None:
        raise ValueError(
            "RP rescore needs the original corpus: pass rp_corpus= "
            "(array/backend/CorpusStore/shards) or an RandomProjBackend "
            "with an in-memory base"
        )
    return projection, src


def _ell_densify_rows(values, cols, dim: int) -> np.ndarray:
    """Densify fetched ELL rows host-side → f32[B, dim]. Value-0 slots are
    padding (the repo-wide ELL convention), so the scatter-add contributes
    exactly +0.0 for them — bit-identical to the device ``take`` densify."""
    values = np.asarray(values)
    cols = np.asarray(cols)
    out = np.zeros((values.shape[0], dim), np.float32)
    rows = np.repeat(np.arange(values.shape[0]), values.shape[1])
    np.add.at(
        out, (rows, cols.ravel().astype(np.intp)),
        values.astype(np.float32, copy=False).ravel(),
    )
    return out


def _rp_row_fetcher(src, in_dim: int):
    """Build ``fetch(sorted unique global ids) → f32[U, in_dim]`` over the
    original representation — the rescore stage's row source. The fetched
    bytes are pinned bit-identical across source kinds (store round-trips
    are exact; ELL densifies reproduce ``take``), which is what lets the
    single-device, store-backed, and sharded rescores agree exactly."""
    if isinstance(src, StoreDocShards):
        if src.dim != in_dim:
            raise ProjectionMismatch(
                f"rescore corpus dim {src.dim} != projection in_dim {in_dim}"
            )

        def fetch(ids):
            out = np.zeros((ids.size, in_dim), np.float32)
            dps = src.docs_per_shard
            for s, part in enumerate(src.parts):
                lo = s * dps
                m = np.logical_and(ids >= lo, ids < lo + part.n_docs)
                if not m.any():
                    continue
                got = part.take_rows(ids[m] - lo)
                if src.kind == "dense":
                    out[m] = np.asarray(got["x"]).astype(np.float32, copy=False)
                else:
                    out[m] = _ell_densify_rows(got["values"], got["cols"], in_dim)
            src.peak_resident_bytes = max(
                src.peak_resident_bytes,
                sum(p.store.cache.resident_bytes for p in src.parts),
            )
            return out

        return fetch
    if is_store(src):
        if src.dim != in_dim:
            raise ProjectionMismatch(
                f"rescore corpus dim {src.dim} != projection in_dim {in_dim}"
            )

        def fetch(ids):
            got = src.take_rows(ids)
            if src.kind == "dense":
                return np.asarray(got["x"]).astype(np.float32, copy=False)
            return _ell_densify_rows(got["values"], got["cols"], in_dim)

        return fetch
    if isinstance(src, (DenseDocShards, EllDocShards)):
        if src.dim != in_dim:
            raise ProjectionMismatch(
                f"rescore corpus dim {src.dim} != projection in_dim {in_dim}"
            )
        if isinstance(src, DenseDocShards):
            x_np = np.asarray(src.x)
            return lambda ids: x_np[ids].astype(np.float32, copy=False)
        v_np, c_np = np.asarray(src.values), np.asarray(src.cols)
        return lambda ids: _ell_densify_rows(v_np[ids], c_np[ids], in_dim)
    be = make_backend(src)
    if be.dim != in_dim:
        raise ProjectionMismatch(
            f"rescore corpus dim {be.dim} != projection in_dim {in_dim}"
        )

    def fetch(ids):
        rows = be.take(jnp.asarray(ids, dtype=jnp.int32))
        return np.asarray(rows).astype(np.float32, copy=False)

    return fetch


def _rescore_pool_chunk(
    x_q: np.ndarray, cand: np.ndarray, valid: np.ndarray, fetch_rows, k: int,
    prefetched=None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact rescore of one chunk's leaf candidate pools.

    Per query: its valid candidates, deduplicated and sorted ascending, are
    gathered from the original representation and ranked by literally
    calling :func:`brute_force_topk_dist` over them — so the result *is*
    brute force restricted to the pool, bit for bit (the golden-equivalence
    tests make the same call). The union of the chunk's candidates is
    fetched once (one store round-trip per chunk); per-query rows are host
    gathers from that union. Distances clamp at 0 like every exact-path
    leaf distance.

    ``prefetched=(union, rows_u)`` hands in that union fetch done ahead of
    time (the ``prefetch ≥ 1`` rescore read-ahead in :func:`_topk_search_rp`)
    — the caller computed ``union`` by the exact expression below, so the
    ranking is bit-identical either way."""
    b = x_q.shape[0]
    docs = np.full((b, k), -1, np.int32)
    dist = np.full((b, k), np.inf, np.float32)
    if not valid.any():
        return docs, dist
    if prefetched is not None:
        union, rows_u = prefetched
    else:
        union = np.unique(cand[valid]).astype(np.int64)
        rows_u = fetch_rows(union)
    for i in range(b):
        ids_i = np.unique(cand[i][valid[i]]).astype(np.int64)
        if not ids_i.size:
            continue
        rows_i = rows_u[np.searchsorted(union, ids_i)]
        sel, d = brute_force_topk_dist(x_q[i : i + 1], rows_i, k)
        kk = sel.shape[1]
        docs[i, :kk] = ids_i[sel[0]]
        dist[i, :kk] = np.maximum(d[0], 0.0).astype(np.float32)
    return docs, dist


def rp_candidate_pools(
    tree: KTree, q, rp, beam: int = 4, chunk: int = 512,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The RP descent's leaf candidate pools, host-side: (cand i32[n,
    beam·m1] global doc ids, valid bool[n, beam·m1], x_q f32[n, in_dim]
    original query rows).

    These are *exactly* the pools ``topk_search(..., rp=...)`` rescores for
    the same ``(q, beam, chunk)`` — chunking affects which rows share a
    projection call, so pass the same ``chunk`` — produced by the same
    jitted ``_chunk_candidates`` descent. Exposed for the
    golden-equivalence tests (restrict ``brute_force_topk_dist`` to a pool
    and compare bit-for-bit) and for recall diagnostics."""
    projection = rp.projection if isinstance(rp, RandomProjBackend) else rp
    if not isinstance(projection, RandomProjection):
        raise TypeError(
            f"rp must be a RandomProjection or RandomProjBackend, "
            f"got {type(rp).__name__}"
        )
    store_q = q if is_store(q) else None
    qbe = None if store_q is not None else make_backend(q)
    q_src = store_q if store_q is not None else qbe
    if q_src.dim != projection.in_dim:
        raise ProjectionMismatch(
            f"query dim {q_src.dim} != projection in_dim {projection.in_dim}"
        )
    if tree.dim != projection.out_dim:
        raise ProjectionMismatch(
            f"tree dim {tree.dim} != projection out_dim {projection.out_dim} "
            "(was the tree built under a different projection?)"
        )
    levels = int(tree.depth) - 1
    max_levels = _levels_bucket(levels)
    n = q_src.n_docs
    cands, valids, xqs = [], [], []
    for rows_np, padded in padded_chunk_rows(n, chunk):
        if store_q is not None:
            qbe_c = backend_from_store(store_q, padded)
            rows = jnp.arange(padded.size, dtype=jnp.int32)
        else:
            qbe_c = qbe
            rows = jnp.asarray(padded.astype(np.int32))
        xq, cand, valid = _rp_chunk_candidates(
            tree, projection, qbe_c, rows, levels, max_levels, beam
        )
        b = rows_np.size
        cands.append(np.asarray(cand)[:b])
        valids.append(np.asarray(valid)[:b])
        xqs.append(np.asarray(xq)[:b].astype(np.float32, copy=False))
    if not cands:
        m1 = tree.slots
        return (np.zeros((0, beam * m1), np.int32),
                np.zeros((0, beam * m1), bool),
                np.zeros((0, projection.in_dim), np.float32))
    return (np.concatenate(cands), np.concatenate(valids), np.concatenate(xqs))


def _rp_chunk_candidates(
    tree: KTree, projection: RandomProjection, qbe_c, rows, levels: int,
    max_levels: int, beam: int,
):
    """One chunk of the RP descent: densify the original query rows, project
    them (one jitted matmul per row-bucket shape — replay-stable for equal
    chunking), and run the shared jitted candidate extraction over a dense
    backend of the projected rows. Returns device (x_q original, cand,
    valid). Single source of the RP pools: the single-device search, the
    sharded search, and :func:`rp_candidate_pools` all come through here."""
    xq = qbe_c.take(rows)                                     # original rows
    zq = projection.apply(xq)                                 # projected rows
    qbe_p = DenseBackend(zq)
    rows_p = jnp.arange(zq.shape[0], dtype=jnp.int32)
    cand, valid, _, _ = _chunk_candidates_jit(
        tree, qbe_p, rows_p, jnp.int32(levels),
        max_levels=max_levels, beam=beam,
    )
    return xq, cand, valid


def _topk_search_rp(
    tree: KTree, q, projection: RandomProjection, src, k: int, beam: int,
    chunk: int, pipeline: int, prefetch: int, prof=NULL_PROFILER,
) -> Tuple[np.ndarray, np.ndarray]:
    """The RP serving path: projected beam descent + exact host rescore.

    Same dispatch-ahead chunk schedule as :func:`topk_search` — the drain
    side runs the host rescore (a disk fetch + numpy ranking) instead of a
    plain D2H copy-out, so device descent of chunk i+1 overlaps chunk i's
    rescore. Every answer row depends only on its own query row and pool,
    so engine batching/caching compose exactly as for the exact path.

    ``prefetch ≥ 1`` applies at *both* disk seams: the store query-source
    reads move onto a ``store.Prefetcher`` reader thread (descent source),
    and the rescore's per-chunk candidate-union fetch moves onto a
    single-worker read-ahead executor so chunk i+1's rescore rows load
    while chunk i is still ranking. The union is computed by the same
    expression :func:`_rescore_pool_chunk` would use, so answers stay
    bit-identical (pinned in tests/test_rp.py)."""
    store_q = q if is_store(q) else None
    qbe = None if store_q is not None else make_backend(q)
    q_src = store_q if store_q is not None else qbe
    if q_src.dim != projection.in_dim:
        raise ProjectionMismatch(
            f"query dim {q_src.dim} != projection in_dim {projection.in_dim}"
        )
    if tree.dim != projection.out_dim:
        raise ProjectionMismatch(
            f"tree dim {tree.dim} != projection out_dim {projection.out_dim} "
            "(was the tree built under a different projection?)"
        )
    fetch_raw = _rp_row_fetcher(src, projection.in_dim)
    if prof.enabled:
        def fetch_rows(ids):
            with prof.span("read"):
                return fetch_raw(ids)
    else:
        fetch_rows = fetch_raw
    levels = int(tree.depth) - 1
    max_levels = _levels_bucket(levels)
    n = q_src.n_docs
    docs_out = np.full((n, k), -1, np.int32)
    dist_out = np.full((n, k), np.inf, np.float32)
    if n == 0:
        return docs_out, dist_out

    if store_q is not None:
        def dispatch(got):
            qbe_c = backend_from_rows(store_q, got)
            rows = jnp.arange(qbe_c.n_docs, dtype=jnp.int32)
            return _rp_chunk_candidates(
                tree, projection, qbe_c, rows, levels, max_levels, beam
            )

        chunks = _store_chunk_iter(store_q, n, chunk, prefetch, prof=prof)
    else:
        def dispatch(rows):
            return _rp_chunk_candidates(
                tree, projection, qbe, rows, levels, max_levels, beam
            )

        chunks = chunked_query_rows(n, chunk)

    depth = max(int(pipeline), 1)
    pending = collections.deque()
    ready = collections.deque()
    # rescore read-ahead (prefetch ≥ 1): a single-worker executor fetches
    # chunk i+1's candidate-union rows while chunk i's rescore is ranking
    executor = (
        ThreadPoolExecutor(max_workers=1) if int(prefetch or 0) >= 1
        else None
    )

    def harvest_one():
        # device→host sync of the oldest in-flight descent; with the
        # executor, also kick off its rescore union fetch in the background
        rows_np, (xq, cand, valid) = pending.popleft()
        b = rows_np.size
        with prof.span("compute"):
            xq_np = np.asarray(xq)[:b].astype(np.float32, copy=False)
            cand_np = np.asarray(cand)[:b]
            valid_np = np.asarray(valid)[:b]
        pre = None
        if executor is not None and valid_np.any():
            # exact expression _rescore_pool_chunk would use → bit-identical
            union = np.unique(cand_np[valid_np]).astype(np.int64)
            pre = (union, executor.submit(fetch_rows, union))
        ready.append((rows_np, xq_np, cand_np, valid_np, pre))

    def rank_one():
        rows_np, xq_np, cand_np, valid_np, pre = ready.popleft()
        prefetched = None
        if pre is not None:
            union, fut = pre
            prefetched = (union, fut.result())
        with prof.span("compute"):
            d, s = _rescore_pool_chunk(
                xq_np, cand_np, valid_np, fetch_rows, k,
                prefetched=prefetched,
            )
        docs_out[rows_np] = d
        dist_out[rows_np] = s

    try:
        for rows_np, payload in chunks:
            with prof.span("dispatch"):
                fut = dispatch(payload)
            pending.append((rows_np, fut))
            while len(pending) >= depth:
                harvest_one()
            while len(ready) >= 2:
                rank_one()
        while pending:
            harvest_one()
        while ready:
            rank_one()
    finally:
        if executor is not None:
            executor.shutdown(wait=True)
    return docs_out, dist_out


# ---------------------------------------------------------------------------
# answer cache (serving plane): LRU over content-hashed (query, k, beam)
# ---------------------------------------------------------------------------

class AnswerCache:
    """LRU top-k answer cache keyed by a content hash of (query row bytes,
    dtype, k, beam), with hit/miss counters for the serving QPS report.

    Exactness caveat: keys hash the raw float encoding, so only bit-identical
    queries hit (0.0 vs −0.0, or the same vector at a different dtype, miss);
    a blake2b-128 collision would alias two distinct queries — negligible
    (~2⁻⁶⁴ at any realistic cache size) but nonzero, hence "answer cache", not
    a correctness layer.

    Staleness: answers are valid for exactly one index. ``bind(index)`` clears
    the cache whenever a different index object shows up — KTree is an
    immutable pytree (``insert`` returns a *new* tree), so object identity is
    a sound invalidation token; :func:`topk_search_cached` binds on every
    call, making post-insert and cross-tree staleness impossible.

    Store-backed corpora add a second identity axis: the tree object can stay
    the same while the on-disk corpus it addresses is regenerated in place
    (same path, new blocks) — object identity alone would then serve answers
    whose doc ids point at different documents. ``bind(index, corpus_token)``
    closes that hole: pass the store's ``manifest_hash`` (a content hash over
    the per-block digests, DESIGN.md §9) and any token change flushes the
    cache.

    Thread safety: the serving engine (``core/engine.py``) consults the cache
    from its dispatcher thread while other threads admit requests, so
    ``get``/``put``/``bind`` (and the stats snapshot) run under a lock —
    matching the :class:`repro.core.store.BlockCache` treatment. Every call
    increments exactly one of hits/misses and LRU order stays consistent
    under concurrency."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"cache capacity must be ≥ 1, got {capacity}")
        self.capacity = int(capacity)
        self._entries: "collections.OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]]" = (
            collections.OrderedDict()
        )
        self._index = None
        self._corpus_token = None
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def bind(self, index, corpus_token: Optional[str] = None) -> None:
        """Tie cached answers to one (index object, corpus content) pair.

        A different index object (a new tree after insert, another tree
        entirely) or a changed ``corpus_token`` (a store regenerated in place
        — pass the store's ``manifest_hash``) flushes all entries. The bound
        index is held strongly, so its id can never be recycled while
        bound."""
        with self._lock:
            if index is not self._index or corpus_token != self._corpus_token:
                self._entries.clear()
                self._index = index
                self._corpus_token = corpus_token

    @staticmethod
    def make_key(row: np.ndarray, k: int, beam: int) -> bytes:
        """Content key: blake2b-128 over (raw row bytes, dtype, k, beam)."""
        h = hashlib.blake2b(digest_size=16)
        row = np.ascontiguousarray(row)
        h.update(row.tobytes())
        h.update(f"|{row.dtype}|{k}|{beam}".encode())
        return h.digest()

    def get(self, key: bytes):
        """(docs, dists) for a key, refreshing its LRU position; None on miss."""
        with self._lock:
            val = self._entries.get(key)
            if val is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return val

    def put(self, key: bytes, value: Tuple[np.ndarray, np.ndarray]) -> None:
        """Insert (docs, dists) at ``key``, evicting LRU entries over
        capacity."""
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def stats(self) -> dict:
        """hits/misses/hit_rate/size/capacity for the serving report."""
        with self._lock:
            total = self.hits + self.misses
            return dict(
                hits=self.hits, misses=self.misses,
                hit_rate=self.hits / total if total else 0.0,
                size=len(self._entries), capacity=self.capacity,
            )


def cache_stage(
    cache: AnswerCache, x_q: np.ndarray, k: int, beam: int,
) -> Tuple[np.ndarray, np.ndarray, "collections.OrderedDict[bytes, list]"]:
    """Pre-batch cache stage: probe every row of ``x_q`` against ``cache``.

    Returns ``(docs, dist, miss_rows)`` where hit rows of the [n, k] answer
    arrays are already filled (misses stay (−1, +inf) until
    :func:`cache_fill`) and ``miss_rows`` maps each missing content key to the
    row indices sharing it, in first-appearance order — the in-batch dedup: one
    engine row per distinct missing query. The caller must have ``bind``-ed
    the cache; :func:`topk_search_cached` and the serving engine
    (``core/engine.py``) both stage through here so their hit/miss accounting
    and LRU traffic are identical."""
    n = x_q.shape[0]
    docs = np.full((n, k), -1, np.int32)
    dist = np.full((n, k), np.inf, np.float32)
    miss_rows: "collections.OrderedDict[bytes, list]" = collections.OrderedDict()
    for i in range(n):
        key = AnswerCache.make_key(x_q[i], k, beam)
        val = cache.get(key)
        if val is not None:
            docs[i], dist[i] = val
        else:
            miss_rows.setdefault(key, []).append(i)
    return docs, dist, miss_rows


def cache_fill(
    cache: AnswerCache,
    miss_rows: "collections.OrderedDict[bytes, list]",
    d_new: np.ndarray, s_new: np.ndarray,
    docs: np.ndarray, dist: np.ndarray,
) -> None:
    """Complete a :func:`cache_stage`: scatter the miss batch's answers
    (``d_new``/``s_new`` [n_miss, k], one row per ``miss_rows`` entry in
    order) back into the staged [n, k] arrays and insert each into the
    cache."""
    for j, (key, rows) in enumerate(miss_rows.items()):
        val = (d_new[j].copy(), s_new[j].copy())
        cache.put(key, val)
        for i in rows:
            docs[i], dist[i] = val


def concat_request_rows(
    rows_list: Sequence[np.ndarray],
) -> Tuple[np.ndarray, List[int]]:
    """Stack per-request query-row fragments into one engine batch.

    Returns ``(x [R_total, d], bounds)`` where ``bounds`` are the cumulative
    row offsets (len = n_requests + 1) that :func:`split_batch_answers` uses
    to demux the batched answers. The engine scores each row independently
    (descent and leaf top-k are per-row), so batching fragments this way
    changes no request's answer — the serving engine's scatter side."""
    bounds = [0]
    for r in rows_list:
        bounds.append(bounds[-1] + int(r.shape[0]))
    if not rows_list:
        raise ValueError("concat_request_rows needs at least one fragment")
    return np.concatenate([np.asarray(r) for r in rows_list], axis=0), bounds


def split_batch_answers(
    docs: np.ndarray, dist: np.ndarray, bounds: List[int],
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Demux one batched answer pair back into per-request fragments along
    the ``bounds`` offsets produced by :func:`concat_request_rows` (copies, so
    a request's result never aliases the batch buffer)."""
    return [
        (docs[lo:hi].copy(), dist[lo:hi].copy())
        for lo, hi in zip(bounds[:-1], bounds[1:])
    ]


def topk_search_cached(
    tree: KTree, q, cache: AnswerCache, k: int = 10, beam: int = 4,
    chunk: int = 512,
    search_fn: Optional[Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]]] = None,
    corpus_token: Optional[str] = None,
    rp=None, rp_corpus=None,
) -> Tuple[np.ndarray, np.ndarray]:
    """:func:`topk_search` through an :class:`AnswerCache`: hit rows are served
    from the cache, miss rows (deduplicated within the batch) go through one
    engine call, and every computed answer is inserted. ``q`` must be dense
    rows (content hashing addresses raw bytes). ``search_fn`` overrides the
    engine for the miss batch — e.g. a :func:`topk_search_sharded` closure
    (it must answer over the *same* ``tree``: the cache binds to it).
    ``corpus_token``: pass the corpus store's ``manifest_hash`` when the
    served corpus lives on disk — answers then invalidate if the store is
    regenerated in place under an unchanged tree object (DESIGN.md §9).
    ``rp``/``rp_corpus`` route the miss batch through the RP
    approximate-route, exact-rescore path (DESIGN.md §5.1) — hashing still
    addresses the *original* query bytes, so cache keys are unchanged."""
    cache.bind(tree, corpus_token)
    x_q = np.asarray(q)
    docs, dist, miss_rows = cache_stage(cache, x_q, k, beam)
    if miss_rows:
        rep = np.asarray([rows[0] for rows in miss_rows.values()])
        if search_fn is None:
            d_new, s_new = topk_search(
                tree, x_q[rep], k=k, beam=beam, chunk=chunk,
                rp=rp, rp_corpus=rp_corpus,
            )
        else:
            d_new, s_new = search_fn(x_q[rep])
        cache_fill(cache, miss_rows, d_new, s_new, docs, dist)
    return docs, dist


# ---------------------------------------------------------------------------
# evaluation helpers (shared by benchmarks/query_recall.py, launch/serve.py
# and the examples — one definition of ground truth and recall)
# ---------------------------------------------------------------------------

def brute_force_topk(
    x_q: np.ndarray, x_all: np.ndarray, k: int,
    doc_block: int = 16384, q_block: int = 1024,
) -> np.ndarray:
    """Exact k-NN doc ids [nq, min(k, n_docs)] by squared distance (ties:
    lower id).

    Computed in ``q_block × doc_block`` tiles with a running top-k merge, so
    the full [n_q, n_docs] distance matrix never materialises — RCV1-scale
    ground truth fits in O(q_block·doc_block) memory. Stable tie order is
    preserved: per-tile stable argsorts keep equal-distance candidates in
    ascending doc-id order, and the running merge (stable argsort over
    [running | new-tile], where running ids always precede the tile's) keeps
    it — bit-identical to a stable argsort of the full matrix."""
    ids, _ = brute_force_topk_dist(
        x_q, x_all, k, doc_block=doc_block, q_block=q_block
    )
    return ids


def brute_force_topk_dist(
    x_q: np.ndarray, x_all: np.ndarray, k: int,
    doc_block: int = 16384, q_block: int = 1024,
) -> Tuple[np.ndarray, np.ndarray]:
    """:func:`brute_force_topk` with the squared distances alongside —
    (ids [nq, min(k, n)], sqdist [nq, min(k, n)], same tiles, same running
    merge, so the two can never diverge). This is also the RP rescore
    primitive: ``topk_search(rp=...)`` calls it per query over the candidate
    pool's original-representation rows, which is what makes the rescore
    stage bit-identical to brute force restricted to that pool *by
    construction*."""
    x_q = np.asarray(x_q)
    x_all = np.asarray(x_all)
    nq, n = x_q.shape[0], x_all.shape[0]
    out = np.empty((nq, min(k, n)), dtype=np.intp)
    out_d = np.empty((nq, min(k, n)), dtype=x_q.dtype)
    q_sq = (x_q ** 2).sum(1)
    for qs in range(0, nq, q_block):
        qe = min(qs + q_block, nq)
        qb = x_q[qs:qe]
        run_ids = np.empty((qe - qs, 0), dtype=np.intp)
        run_d = np.empty((qe - qs, 0), dtype=x_q.dtype)
        for ds in range(0, n, doc_block):
            de = min(ds + doc_block, n)
            xb = x_all[ds:de]
            d = q_sq[qs:qe, None] - 2.0 * qb @ xb.T + (xb ** 2).sum(1)[None, :]
            run_ids, run_d = _merge_topk(run_ids, run_d, d, ds, k)
        out[qs:qe] = run_ids
        out_d[qs:qe] = run_d
    return out, out_d


def _merge_topk(run_ids, run_d, d, offset, k):
    """One running stable top-k merge step: fold a tile's distance matrix
    ``d`` [nq, C] (candidate ids ``offset + column``) into the running
    (ids, dists) [nq, ≤k]. Stable tie order is preserved — running entries
    precede the tile's, and per-tile stable argsorts keep equal-distance
    candidates in ascending id order. Shared by :func:`brute_force_topk` and
    :func:`brute_force_topk_stream` so the two ground truths cannot
    diverge."""
    sel = np.argsort(d, axis=1, kind="stable")[:, :k]
    run_ids = np.concatenate([run_ids, sel + offset], axis=1)
    run_d = np.concatenate([run_d, np.take_along_axis(d, sel, 1)], axis=1)
    keep = np.argsort(run_d, axis=1, kind="stable")[:, :k]
    return (np.take_along_axis(run_ids, keep, 1),
            np.take_along_axis(run_d, keep, 1))


def brute_force_topk_stream(x_q: np.ndarray, blocks, k: int) -> np.ndarray:
    """Exact k-NN doc ids [nq, ≤k] against a corpus streamed as
    ``(row_offset, dense block rows)`` pairs — the out-of-core ground truth
    (DESIGN.md §9): only one block is resident at a time.

    Same distances, ties, and running merge as :func:`brute_force_topk`
    (shared :func:`_merge_topk` step); block boundaries are invisible to the
    result. ``launch/serve.py --store`` feeds it store blocks (ELL blocks
    densified host-side)."""
    x_q = np.asarray(x_q)
    nq = x_q.shape[0]
    q_sq = (x_q ** 2).sum(1)
    run_ids = np.empty((nq, 0), dtype=np.intp)
    run_d = np.empty((nq, 0), dtype=np.float32)
    for lo, xb in blocks:
        xb = np.asarray(xb)
        d = (q_sq[:, None] - 2.0 * x_q @ xb.T + (xb ** 2).sum(1)[None, :]
             ).astype(np.float32)
        run_ids, run_d = _merge_topk(run_ids, run_d, d, lo, k)
    return run_ids


def recall_at_k(docs: np.ndarray, true_k: np.ndarray) -> float:
    """Mean |retrieved ∩ true| / k; −1 padding in ``docs`` never matches.

    One broadcast equality reduction (no per-query Python sets — O(n_q·k²)
    numpy instead of interpreter time; the old loop is pinned by a test)."""
    docs = np.asarray(docs)
    true_k = np.asarray(true_k)
    k = true_k.shape[1]
    hit = (true_k[:, :, None] == docs[:, None, :]).any(axis=2)   # [nq, k]
    return float((hit.sum(axis=1) / k).mean())
