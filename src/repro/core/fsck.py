"""Offline store verify/repair — ``store_fsck`` (DESIGN.md §10).

The hardened read path (``core/store.py``) discovers damage *lazily*: a block
fails verification when something finally reads it, gets quarantined, and
degrade-mode serving drops its rows. This module is the *eager* counterpart —
an offline pass over a store directory that:

- **verifies** every live block file against the manifest digests (and that
  the files exist at all) without needing to open a serving handle, and
- **repairs** a damaged store by *excising* the broken blocks: each damaged
  entry is replaced by a tombstone (``{"i": i, "excised": true, "reason":
  ...}``), the offending files are moved aside (``<name>.damaged`` — kept for
  forensics, never silently deleted), and a consistent manifest is atomically
  rewritten (tmp + ``os.replace``), rotating ``manifest_hash`` so answer
  caches treat the excised store as new content. The pre-repair hash is
  appended to a ``fsck_lineage`` chain in the manifest, which lets
  manifest-reference consumers (``ckpt.restore_index``, the pipeline
  sidecar's reuse check) distinguish a *repaired* store — same corpus, same
  doc ids, minus the damaged blocks — from a store regenerated in place.

Blocks are *positional* (block ``i`` owns global rows ``[i·block_docs,
(i+1)·block_docs)``), so repair never renumbers anything: surviving blocks
keep their ids and row ranges, and a store opened after repair answers
bit-identically to an undamaged store over the surviving rows (the excised
blocks' rows are pre-quarantined — reads raise
:class:`repro.core.store.BlockUnavailable`, degrade-mode searches drop them).

``tools/store_fsck.py`` is the CLI wrapper; ``launch/serve.py --fsck`` runs
the same pass before serving.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Tuple

from repro.core.store import (
    FORMAT_TAG,
    MANIFEST_NAME,
    ManifestError,
    _digest,
    load_manifest,
)


@dataclasses.dataclass(frozen=True)
class FsckReport:
    """Result of one fsck pass over a store directory.

    ``damaged`` lists ``(block_id, reason)`` for every live block that failed
    this pass (missing file or digest mismatch); ``excised_prior`` are blocks
    already tombstoned by an earlier repair. ``repaired`` names the blocks
    this pass excised (empty for scan-only). ``manifest_hash_before/after``
    are the store's content tokens around the pass — they differ exactly when
    a repair rewrote the manifest."""

    path: str
    n_blocks: int
    n_docs: int
    checked: int
    damaged: Tuple[Tuple[int, str], ...]
    excised_prior: Tuple[int, ...]
    repaired: Tuple[int, ...]
    manifest_hash_before: str
    manifest_hash_after: str

    @property
    def clean(self) -> bool:
        """True when every live block verified (prior tombstones are not
        damage — they were already dealt with)."""
        return not self.damaged

    def lines(self) -> Tuple[str, ...]:
        """Human/grep-friendly report lines (the CLI and ``serve.py --fsck``
        print exactly these)."""
        out = [
            f"fsck: {self.path}: checked {self.checked}/{self.n_blocks} "
            f"blocks ({self.n_docs} docs"
            + (f", {len(self.excised_prior)} previously excised"
               if self.excised_prior else "")
            + ")"
        ]
        for i, reason in self.damaged:
            out.append(f"fsck: block {i} DAMAGED: {reason}")
        if self.repaired:
            out.append(
                f"fsck: repaired — excised {len(self.repaired)} block(s) "
                f"{list(self.repaired)}, manifest rewritten "
                f"({self.manifest_hash_before} -> {self.manifest_hash_after})"
            )
        elif self.damaged:
            out.append(
                f"fsck: {len(self.damaged)} damaged block(s) — run with "
                f"repair to excise"
            )
        else:
            out.append("fsck: clean")
        return tuple(out)


def _manifest_hash(manifest: dict) -> str:
    """Content token of a manifest dict — the same blake2b-128 of the
    canonical JSON that :meth:`repro.core.store.CorpusStore.manifest_hash`
    memoises."""
    blob = json.dumps(manifest, sort_keys=True).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


def _load_checked(path: str) -> dict:
    """Load + format-guard a store manifest (shared by scan and repair)."""
    mpath = os.path.join(path, MANIFEST_NAME)
    if not os.path.exists(mpath):
        raise FileNotFoundError(
            f"no corpus store at {path} (missing {MANIFEST_NAME})"
        )
    manifest = load_manifest(mpath)
    if manifest.get("format") != FORMAT_TAG:
        raise ManifestError(
            mpath,
            f"unknown store format {manifest.get('format')!r} "
            f"(expected {FORMAT_TAG!r})",
        )
    return manifest


def _scan(path: str, manifest: dict):
    """Verify every live block; returns ``(damaged, excised_prior,
    checked)``."""
    damaged = []
    excised_prior = []
    checked = 0
    for entry in manifest["blocks"]:
        i = int(entry["i"])
        if entry.get("excised"):
            excised_prior.append(i)
            continue
        checked += 1
        missing = [
            fname for fname in entry["files"].values()
            if not os.path.exists(os.path.join(path, fname))
        ]
        if missing:
            damaged.append((i, f"missing file(s): {', '.join(missing)}"))
            continue
        # field-name-sorted digest concatenation, matching save_store
        dig = "".join(
            _digest(os.path.join(path, entry["files"][name]))
            for name in sorted(entry["files"])
        )
        if dig != entry["digest"]:
            damaged.append((
                i,
                f"content digest mismatch (read {dig}, "
                f"manifest {entry['digest']})",
            ))
    return damaged, excised_prior, checked


def fsck_store(path: str) -> FsckReport:
    """Scan-only fsck: verify every live block file of the store at ``path``
    against the manifest digests. Touches nothing on disk; ``report.clean``
    says whether the store verifies."""
    manifest = _load_checked(path)
    damaged, excised_prior, checked = _scan(path, manifest)
    h = _manifest_hash(manifest)
    return FsckReport(
        path=path,
        n_blocks=int(manifest["n_blocks"]),
        n_docs=int(manifest["n_docs"]),
        checked=checked,
        damaged=tuple(damaged),
        excised_prior=tuple(excised_prior),
        repaired=(),
        manifest_hash_before=h,
        manifest_hash_after=h,
    )


def repair_store(path: str) -> FsckReport:
    """Fsck + repair: excise every damaged block of the store at ``path``.

    Damaged blocks' manifest entries become tombstones, their surviving files
    are moved aside as ``<name>.damaged``, and the manifest is atomically
    rewritten — see the module docstring for the exact guarantees. A clean
    store is left byte-identical (no manifest rewrite, same
    ``manifest_hash``). Idempotent: a second pass finds the tombstones
    already in place and nothing to do."""
    manifest = _load_checked(path)
    damaged, excised_prior, checked = _scan(path, manifest)
    h_before = _manifest_hash(manifest)
    if not damaged:
        return FsckReport(
            path=path,
            n_blocks=int(manifest["n_blocks"]),
            n_docs=int(manifest["n_docs"]),
            checked=checked,
            damaged=(),
            excised_prior=tuple(excised_prior),
            repaired=(),
            manifest_hash_before=h_before,
            manifest_hash_after=h_before,
        )
    bad = {i: reason for i, reason in damaged}
    blocks = []
    # lineage: excision keeps blocks positional (doc ids unchanged), so
    # consumers holding the pre-repair content token (index checkpoints,
    # pipeline sidecars) may safely pair with the repaired store — the chain
    # of pre-repair manifest hashes lets them tell "repaired" from
    # "regenerated"
    lineage = list(manifest.get("fsck_lineage", ())) + [h_before]
    for entry in manifest["blocks"]:
        i = int(entry["i"])
        if i not in bad:
            blocks.append(entry)
            continue
        for fname in entry["files"].values():
            full = os.path.join(path, fname)
            if os.path.exists(full):
                # keep the evidence, but out of the manifest's namespace so
                # a later append can never collide with it
                os.replace(full, full + ".damaged")
        blocks.append({"i": i, "excised": True, "reason": bad[i]})
    new_manifest = dict(manifest)
    new_manifest["blocks"] = blocks
    new_manifest["fsck_lineage"] = lineage
    mtmp = os.path.join(path, MANIFEST_NAME + ".tmp")
    with open(mtmp, "w") as f:
        json.dump(new_manifest, f, indent=1, sort_keys=True)
    os.replace(mtmp, os.path.join(path, MANIFEST_NAME))
    return FsckReport(
        path=path,
        n_blocks=int(new_manifest["n_blocks"]),
        n_docs=int(new_manifest["n_docs"]),
        checked=checked,
        damaged=tuple(damaged),
        excised_prior=tuple(excised_prior),
        repaired=tuple(sorted(bad)),
        manifest_hash_before=h_before,
        manifest_hash_after=_manifest_hash(new_manifest),
    )
