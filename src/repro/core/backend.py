"""Vector backends — how the K-tree touches document vectors (DESIGN.md §5).

The tree itself is representation-agnostic: node centres are always dense
rows of ``tree.centers`` (means in dense mode, document exemplars in medoid
mode). What varies is the *corpus side* — how a batch of documents, addressed
by row id, is scored against centres, norm'ed, and densified for leaf
appends / node splits. That seam is a backend:

- :class:`DenseBackend` — the seed behaviour: documents are rows of a dense
  ``f32[N, d]`` matrix. Scoring is an MXU matmul; the flat-centre path goes
  through the ``nn_assign`` Pallas kernel (interpret mode on CPU).
- :class:`EllSparseBackend` — the paper's sparse extension (§2): documents
  stay in ELL layout (values/cols padded to ``nnz_max``) with CSR alongside
  for exact-length dense row gathers. Scoring against a flat centre set goes
  through the ``ell_spmm`` Pallas kernel; scoring against per-query gathered
  node centres uses an ``nnz``-sized column gather (compute ∝ nnz, not d).
- :class:`RandomProjBackend` — the Random Indexing K-tree (PAPERS.md,
  arxiv 1001.0833): a base corpus (dense or ELL) plus a seeded random
  projection. Build, descent, and insert run entirely in the projected
  space (``dim == rp_dim`` — small dense centres, ~order-of-magnitude fewer
  descent FLOPs); the query engine rescores final candidates from the
  *original* representation at full precision (``query.topk_search(rp=...)``).

All are registered dataclass pytrees, so they cross jit boundaries and the
jitted tree ops (`route`, `_insert_wave`) specialise per backend type.

Distances everywhere drop the ‖x‖² constant: ``‖c‖² − 2·x·c`` has the same
argmin. ``row_sq`` supplies the constant back when a true distance is needed
(leaf NN search).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.csr import Csr, csr_from_dense, csr_row_gather_dense
from repro.sparse.ell import Ell, ell_from_csr


def _ell_csr_arrays(vals: np.ndarray, cols: np.ndarray, pad_to: int | None = None):
    """Rebuild host-side CSR arrays (data, indices, indptr) from padded ELL
    rows — the one place the load-bearing convention lives: value-0 slots are
    padding. ``pad_to`` zero-pads data/indices to a static capacity (chunk
    backends need fixed leaf shapes for compile-cache stability; ``indptr``
    bounds every read, so the padding is inert)."""
    mask = vals != 0
    indptr = np.zeros(vals.shape[0] + 1, dtype=np.int32)
    np.cumsum(mask.sum(axis=1), out=indptr[1:])
    data, indices = vals[mask], cols[mask].astype(np.int32)
    if pad_to is not None:
        nnz = int(indptr[-1])
        data_p = np.zeros(pad_to, vals.dtype)
        data_p[:nnz] = data
        idx_p = np.zeros(pad_to, np.int32)
        idx_p[:nnz] = indices
        data, indices = data_p, idx_p
    return data, indices, indptr


def _use_pallas() -> bool:
    """Kernel dispatch: the Pallas kernels are compiled on TPU; elsewhere the
    pure-jnp oracles in :mod:`repro.kernels.ref` serve as the fallback (the
    kernels themselves stay testable off-TPU through interpret mode in
    :mod:`repro.kernels.ops`)."""
    return jax.default_backend() == "tpu"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseBackend:
    """Documents are rows of a dense matrix — the seed K-tree's representation."""

    x: jax.Array  # f[N, d]

    @classmethod
    def from_store(cls, source, rows=None) -> "DenseBackend":
        """Chunk backend over rows of an on-disk dense corpus store
        (DESIGN.md §9).

        ``source``: a ``repro.core.store.CorpusStore`` (``kind="dense"``) or a
        ``StoreSlice``; ``rows``: global row ids to materialise (default: all
        — only sensible for small stores; out-of-core consumers pass
        chunk-sized row sets). The materialised rows are bit-identical to the
        corresponding rows of an in-memory backend over the same corpus, so
        every per-row op (``cross_nodes``/``topk_flat``/``row_sq``) agrees
        exactly with the monolithic path."""
        if rows is None:
            rows = np.arange(source.n_docs)
        return cls.from_rows(source.take_rows(rows))

    @classmethod
    def from_rows(cls, got) -> "DenseBackend":
        """Chunk backend over already-fetched dense store rows
        (``{"x": f[B, d]}`` — the :meth:`from_store` construction with the
        disk read factored out, so a ``store.Prefetcher`` can run it on a
        reader thread and hand the arrays over bit-identically)."""
        return cls(x=jnp.asarray(got["x"]))

    @property
    def n_docs(self) -> int:
        """Corpus row count N."""
        return self.x.shape[0]

    @property
    def dim(self) -> int:
        """Vector dimensionality d."""
        return self.x.shape[1]

    @property
    def dtype(self):
        """Document element dtype."""
        return self.x.dtype

    def take(self, rows: jax.Array) -> jax.Array:
        """Dense vectors for a batch of row ids — f[B, d]."""
        return self.x[rows]

    def row_sq(self, rows: jax.Array) -> jax.Array:
        """‖x‖² per row — f32[B] (the constant term of squared distances)."""
        xb = self.x[rows].astype(jnp.float32)
        return jnp.einsum("bd,bd->b", xb, xb)

    def cross_nodes(self, rows: jax.Array, centers: jax.Array) -> jax.Array:
        """x_b · c_bm for per-query gathered node centres ``centers`` [B, m1, d]."""
        return jnp.einsum("bd,bmd->bm", self.x[rows], centers)

    def cross_flat(self, rows: jax.Array, centers: jax.Array) -> jax.Array:
        """x_b · c_k against a flat centre set [K, d] → [B, K]."""
        return self.x[rows] @ centers.T

    def nn_flat(
        self, rows: jax.Array, centers: jax.Array, valid: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        """(idx i32[B], sqdist f32[B]) vs a flat centre set — Pallas ``nn_assign``
        kernel on TPU, ``ref.nn_assign_ref`` oracle elsewhere."""
        if _use_pallas():
            from repro.kernels.ops import nn_assign

            return nn_assign(self.x[rows], centers, valid=valid)
        from repro.kernels.ref import nn_assign_ref

        return nn_assign_ref(self.x[rows], centers, valid=valid)

    def topk_flat(
        self, rows: jax.Array, centers: jax.Array, valid: jax.Array, k: int
    ) -> Tuple[jax.Array, jax.Array]:
        """(idx i32[B,k], sqdist f32[B,k]) — k nearest flat centres per query,
        ascending (DESIGN.md §7). Pallas ``nn_topk`` kernel on TPU, the
        ``ref.nn_topk_ref`` oracle elsewhere; rows with fewer than k valid
        centres pad with (−1, +inf)."""
        if _use_pallas():
            from repro.kernels.ops import nn_topk

            return nn_topk(self.x[rows], centers, k, valid=valid)
        from repro.kernels.ref import nn_topk_ref

        return nn_topk_ref(self.x[rows], centers, k, valid=valid)

    def shard(self, mesh, axes=None) -> "DenseDocShards":
        """Row-shard this corpus over the mesh's data axes (DESIGN.md §8)."""
        from repro.core.distributed import shard_rows

        (x,), n_shards, _ = shard_rows(mesh, [self.x], axes)
        return DenseDocShards(x=x, n_docs=self.n_docs, n_shards=n_shards)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllSparseBackend:
    """Documents stay sparse (paper §2): ELL for kernel scoring, CSR for exact
    dense row gathers (leaf appends densify only the routed batch, never the
    corpus)."""

    values: jax.Array      # f[N, nnz_max] (0 on padding)
    cols: jax.Array        # i32[N, nnz_max] (0 on padding)
    sq: jax.Array          # f32[N] row squared norms
    csr_data: jax.Array    # f[nnz]
    csr_indices: jax.Array # i32[nnz]
    csr_indptr: jax.Array  # i32[N+1]
    n_cols: int = dataclasses.field(metadata=dict(static=True))

    @classmethod
    def from_store(cls, source, rows=None) -> "EllSparseBackend":
        """Chunk backend over rows of an on-disk ELL corpus store
        (DESIGN.md §9).

        ``source``: a ``repro.core.store.CorpusStore`` (``kind="ell"``) or a
        ``StoreSlice``; ``rows``: global row ids (default: all). The CSR side
        is rebuilt host-side from the fetched ELL rows (value-0 slots are
        padding, same convention as ``make_backend(Ell)``), and ``sq`` is
        computed from the ELL values exactly like
        :func:`sparse_backend_from_csr` — so chunk backends score, densify,
        and norm bit-identically to an in-memory backend over the same
        corpus.

        The CSR arrays are zero-padded to the static ``B·nnz_max`` capacity:
        a chunk's true nnz varies chunk-to-chunk, and a varying leaf shape
        would retrace the jitted consumers (``_beam_search``/``_insert_wave``)
        on *every* chunk — padded, all chunks of one bucket size share one
        compile, like the in-memory path. ``indptr`` bounds every CSR read,
        so the padding is never addressed."""
        if rows is None:
            rows = np.arange(source.n_docs)
        return cls.from_rows(source.take_rows(rows), source.dim)

    @classmethod
    def from_rows(cls, got, n_cols: int) -> "EllSparseBackend":
        """Chunk backend over already-fetched ELL store rows
        (``{"values", "cols"}`` — the :meth:`from_store` construction with
        the disk read factored out for ``store.Prefetcher`` consumers; same
        CSR rebuild, same static padding, bit-identical scoring)."""
        vals, cols = got["values"], got["cols"]
        data, indices, indptr = _ell_csr_arrays(vals, cols, pad_to=vals.size)
        return cls(
            values=jnp.asarray(vals),
            cols=jnp.asarray(cols),
            sq=jnp.sum(jnp.asarray(vals).astype(jnp.float32) ** 2, axis=1),
            csr_data=jnp.asarray(data),
            csr_indices=jnp.asarray(indices),
            csr_indptr=jnp.asarray(indptr),
            n_cols=n_cols,
        )

    @property
    def n_docs(self) -> int:
        """Corpus row count N."""
        return self.values.shape[0]

    @property
    def dim(self) -> int:
        """Logical vector dimensionality (the culled vocabulary size)."""
        return self.n_cols

    @property
    def nnz_max(self) -> int:
        """ELL padding width — max stored nonzeros per row."""
        return self.values.shape[1]

    @property
    def dtype(self):
        """Document element dtype."""
        return self.values.dtype

    def _csr(self) -> Csr:
        return Csr(self.csr_data, self.csr_indices, self.csr_indptr, self.n_cols)

    def take(self, rows: jax.Array) -> jax.Array:
        """Densify a batch of rows — f[B, d]. O(B·nnz_max) scatter; this is the
        only densification point in the sparse K-tree (wave-sized, not
        corpus-sized)."""
        return csr_row_gather_dense(self._csr(), rows, self.nnz_max)

    def row_sq(self, rows: jax.Array) -> jax.Array:
        """‖x‖² per row — f32[B], from the precomputed ELL norms."""
        return self.sq[rows]

    def cross_nodes(self, rows: jax.Array, centers: jax.Array) -> jax.Array:
        """Per-query gathered node centres [B, m1, d]: gather only the nnz
        touched columns of each query's own centre block — compute is
        B·m1·nnz, not B·m1·d."""
        v = self.values[rows]                                  # [B, nnz]
        c = self.cols[rows]                                    # [B, nnz]
        gathered = jnp.take_along_axis(centers, c[:, None, :], axis=2)  # [B, m1, nnz]
        return jnp.einsum("bn,bmn->bm", v, gathered)

    def cross_flat(self, rows: jax.Array, centers: jax.Array) -> jax.Array:
        """Flat centre set [K, d] → scores via the ``ell_spmm`` Pallas kernel
        on TPU, the ``ref.ell_spmm_ref`` oracle elsewhere."""
        if _use_pallas():
            from repro.kernels.ops import ell_spmm

            return ell_spmm(self.values[rows], self.cols[rows], centers)
        from repro.kernels.ref import ell_spmm_ref

        return ell_spmm_ref(self.values[rows], self.cols[rows], centers)

    def nn_flat(
        self, rows: jax.Array, centers: jax.Array, valid: jax.Array
    ) -> Tuple[jax.Array, jax.Array]:
        """(idx, sqdist) vs a flat centre set: ‖x‖² − 2·S + ‖c‖² with S from
        the ``ell_spmm`` scoring path (``medoid_assign_sparse`` on TPU)."""
        if _use_pallas():
            from repro.kernels.ops import medoid_assign_sparse

            return medoid_assign_sparse(
                self.values[rows], self.cols[rows], self.sq[rows], centers,
                valid=valid,
            )
        s = self.cross_flat(rows, centers)
        dist = self._flat_sqdist(rows, s, centers, valid)
        idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
        return idx, jnp.take_along_axis(dist, idx[:, None], axis=1)[:, 0]

    def _flat_sqdist(
        self, rows: jax.Array, scores: jax.Array, centers: jax.Array, valid: jax.Array
    ) -> jax.Array:
        """‖x‖² − 2·S + ‖c‖², clamped, masked → f32[B, K] (shared by the nn/topk
        flat paths so their top-1 agree bit-for-bit)."""
        c32 = centers.astype(jnp.float32)
        c_sq = jnp.einsum("kd,kd->k", c32, c32)
        dist = jnp.maximum(self.sq[rows][:, None] - 2.0 * scores + c_sq[None, :], 0.0)
        return jnp.where(valid[None, :], dist, jnp.inf)

    def topk_flat(
        self, rows: jax.Array, centers: jax.Array, valid: jax.Array, k: int
    ) -> Tuple[jax.Array, jax.Array]:
        """(idx i32[B,k], sqdist f32[B,k]) — k nearest flat centres per query,
        ascending. The cross term reuses the ``ell_spmm`` scoring path (Pallas
        on TPU via ``cross_flat``); the k-selection is a dense ``top_k`` over
        the K scores, which are already materialised."""
        from repro.kernels.ref import topk_from_dist

        s = self.cross_flat(rows, centers)
        return topk_from_dist(self._flat_sqdist(rows, s, centers, valid), k)

    def shard(self, mesh, axes=None) -> "EllDocShards":
        """Row-shard this corpus over the mesh's data axes (DESIGN.md §8).

        Only the ELL arrays + norms travel (the kernel-scoring layout); the CSR
        side stays host-global — the sharded serving path never densifies."""
        from repro.core.distributed import shard_rows

        (values, cols, sq), n_shards, _ = shard_rows(
            mesh, [self.values, self.cols, self.sq], axes
        )
        return EllDocShards(
            values=values, cols=cols, sq=sq,
            n_cols=self.n_cols, n_docs=self.n_docs, n_shards=n_shards,
        )


# ---------------------------------------------------------------------------
# random-projection backend (DESIGN.md §5.1): the Random Indexing K-tree.
# The tree is built and routed in a low-dimensional dense projection of the
# corpus while documents keep their original (possibly sparse, possibly
# on-disk) representation; the query engine's final rescore stage goes back
# to the original rows at full precision.
# ---------------------------------------------------------------------------


class ProjectionMismatch(ValueError):
    """A restored index's recorded random projection does not match what the
    caller (or the paired tree/store) expects — seed, dims, kind, or dtype
    differ, or one side has a projection and the other does not. Raised
    instead of silently serving answers routed through the wrong projection,
    the same refusal discipline as a rewritten store's ``manifest_hash``."""


PROJECT_CHUNK = 1024  # fixed projection granularity — see project_corpus


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RandomProjection:
    """A seeded random projection ``f32[in_dim] → f32[out_dim]`` — the part of
    an RP index that must replay exactly.

    The matrix is a pure function of ``(seed, in_dim, out_dim, kind)`` via
    jax's counter-based PRNG (:func:`make_projection`), so checkpoints persist
    only the spec (plus the dtype, verified on restore) and rebuild the
    matrix bit-identically; :meth:`spec` / ``checkpoint.restore_index`` carry
    it. Kinds: ``"gaussian"`` (dense N(0, 1/out_dim) — the JL default),
    ``"ternary"`` (sparse ±1 index vectors, the Random Indexing construction
    of arxiv 1001.0833, density 1/8, variance-normalised), ``"identity"``
    (requires ``out_dim == in_dim``; makes the RP pipeline reproduce the
    dense exact path — the equivalence anchor the tests pin)."""

    matrix: jax.Array  # f32[in_dim, out_dim]
    seed: int = dataclasses.field(metadata=dict(static=True))
    kind: str = dataclasses.field(metadata=dict(static=True))

    @property
    def in_dim(self) -> int:
        """Original (document) dimensionality."""
        return self.matrix.shape[0]

    @property
    def out_dim(self) -> int:
        """Projected (routing) dimensionality — the tree's ``dim``."""
        return self.matrix.shape[1]

    @property
    def dtype(self):
        """Projection matrix dtype (always float32 today; recorded in
        checkpoints so a future widening can't silently alias)."""
        return self.matrix.dtype

    def spec(self) -> dict:
        """The replayable description ``{seed, in_dim, out_dim, kind, dtype}``
        — everything :func:`projection_from_spec` needs to rebuild
        ``matrix`` bit-identically."""
        return dict(
            seed=int(self.seed), in_dim=int(self.in_dim),
            out_dim=int(self.out_dim), kind=str(self.kind),
            dtype=str(np.dtype(self.matrix.dtype)),
        )

    def apply(self, x: jax.Array) -> jax.Array:
        """Project dense rows ``f[B, in_dim] → f32[B, out_dim]`` (jitted; one
        compile per row-bucket shape, so equal-shaped calls are bit-stable)."""
        return _apply_projection(self.matrix, jnp.asarray(x))


@jax.jit
def _apply_projection(matrix: jax.Array, x: jax.Array) -> jax.Array:
    return x.astype(jnp.float32) @ matrix


def make_projection(
    in_dim: int, out_dim: int, seed: int = 0, kind: str = "gaussian"
) -> RandomProjection:
    """Deterministically generate a :class:`RandomProjection` from its spec.

    Same (seed, dims, kind) → bit-identical matrix on every call and every
    process (jax threefry PRNG), which is what makes a checkpointed RP index
    replayable from the stored seed alone."""
    if in_dim < 1 or out_dim < 1:
        raise ValueError(f"projection dims must be ≥ 1, got {in_dim}→{out_dim}")
    key = jax.random.PRNGKey(seed)
    if kind == "gaussian":
        matrix = jax.random.normal(
            key, (in_dim, out_dim), jnp.float32
        ) * jnp.float32(1.0 / math.sqrt(out_dim))
    elif kind == "ternary":
        # Random Indexing index vectors (arxiv 1001.0833): sparse ±1 at
        # density 1/8, scaled so E‖Px‖² ≈ ‖x‖²
        density = 1.0 / 8.0
        u = jax.random.uniform(key, (in_dim, out_dim), jnp.float32)
        scale = jnp.float32(1.0 / math.sqrt(density * out_dim))
        matrix = jnp.where(
            u < density / 2, scale, jnp.where(u > 1.0 - density / 2, -scale, 0.0)
        )
    elif kind == "identity":
        if out_dim != in_dim:
            raise ValueError(
                f"identity projection needs out_dim == in_dim, got "
                f"{in_dim}→{out_dim}"
            )
        matrix = jnp.eye(in_dim, dtype=jnp.float32)
    else:
        raise ValueError(
            f"unknown projection kind {kind!r}; use gaussian|ternary|identity"
        )
    return RandomProjection(matrix=matrix, seed=int(seed), kind=kind)


def projection_from_spec(spec: dict) -> RandomProjection:
    """Rebuild a projection from a :meth:`RandomProjection.spec` record,
    verifying the recorded dtype still matches what :func:`make_projection`
    produces (a silent dtype drift would un-replay every checkpoint)."""
    try:
        proj = make_projection(
            int(spec["in_dim"]), int(spec["out_dim"]),
            seed=int(spec["seed"]), kind=str(spec["kind"]),
        )
    except KeyError as e:
        raise ProjectionMismatch(f"projection spec missing field {e}") from e
    want = str(spec.get("dtype", "float32"))
    if str(np.dtype(proj.matrix.dtype)) != want:
        raise ProjectionMismatch(
            f"projection dtype {np.dtype(proj.matrix.dtype)} != recorded {want}"
        )
    return proj


def project_corpus(projection: RandomProjection, source, prefetch: int = 0):
    """Project a whole corpus → ``f32[N, out_dim]`` (host array), in fixed
    :data:`PROJECT_CHUNK`-row chunks.

    ``source``: an in-memory corpus/backend or a ``CorpusStore``/``StoreSlice``
    (rows stream through the block cache — only one densified chunk is ever
    resident, so the sparse corpus is never materialised; ``prefetch ≥ 1``
    moves store reads onto a ``store.Prefetcher`` thread). The chunk
    granularity is deliberately *fixed* — independent of the caller's batch
    size — so the in-memory and streaming constructions project every row at
    the same jitted shape and the two resulting backends (and every tree
    built over them) are bit-identical by construction."""
    from repro.core.ktree import padded_chunk_rows

    n = source.n_docs
    out_dim = projection.out_dim
    if n == 0:
        return np.zeros((0, out_dim), np.float32)
    if source.dim != projection.in_dim:
        raise ProjectionMismatch(
            f"corpus dim {source.dim} != projection in_dim {projection.in_dim}"
        )
    outs = []
    if is_store(source):
        def fetch(req):
            _, padded = req
            return source.take_rows(padded)

        import contextlib

        with contextlib.ExitStack() as stack:
            if prefetch:
                from repro.core.store import Prefetcher

                fetched = stack.enter_context(Prefetcher(
                    padded_chunk_rows(n, PROJECT_CHUNK), fetch, depth=prefetch,
                ))
            else:
                fetched = (
                    (req, fetch(req)) for req in padded_chunk_rows(n, PROJECT_CHUNK)
                )
            for (rows_np, padded), got in fetched:
                be_c = backend_from_rows(source, got)
                x = be_c.take(jnp.arange(padded.size, dtype=jnp.int32))
                outs.append(np.asarray(projection.apply(x))[: rows_np.size])
    else:
        be = make_backend(source)
        for rows_np, padded in padded_chunk_rows(n, PROJECT_CHUNK):
            x = be.take(jnp.asarray(padded.astype(np.int32)))
            outs.append(np.asarray(projection.apply(x))[: rows_np.size])
    return np.concatenate(outs, axis=0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RandomProjBackend:
    """The Random Indexing K-tree's corpus side (arxiv 1001.0833): a base
    corpus (dense or ELL — possibly left on disk) routed through a seeded
    random projection.

    Every tree-facing op (``take``/``cross_nodes``/``nn_flat``/…) delegates to
    ``proj`` — a :class:`DenseBackend` over the projected rows — so build,
    descent, and insert run entirely in the ``out_dim``-dimensional space
    (``dim == projection.out_dim``; the tree's centres are small and dense and
    bit-match a plain dense tree built over the same projected rows). What the
    projection *costs* is exactness: projected distances only approximate
    original-space distances, so the query engine treats the tree as a
    candidate generator and rescores the leaf pool from ``base`` (or the
    store) at full precision — ``query.topk_search(..., rp=...)``.

    ``base`` keeps the original in-memory representation for that rescore;
    it is ``None`` when the original rows live in a ``CorpusStore`` (the
    out-of-core construction — pass the store as ``rp_corpus=`` at query
    time)."""

    proj: DenseBackend
    projection: RandomProjection
    base: Optional[Union[DenseBackend, EllSparseBackend]]

    @classmethod
    def wrap(cls, corpus, projection: RandomProjection) -> "RandomProjBackend":
        """Wrap an in-memory corpus (dense array, Csr, Ell, or backend):
        normalises it via :func:`make_backend`, projects it with
        :func:`project_corpus`'s fixed chunking, and keeps the base for the
        exact rescore."""
        base = make_backend(corpus)
        z = project_corpus(projection, base)
        return cls(
            proj=DenseBackend(jnp.asarray(z)), projection=projection, base=base
        )

    @classmethod
    def from_store(
        cls, source, projection: RandomProjection, prefetch: int = 0
    ) -> "RandomProjBackend":
        """Project an on-disk corpus without ever materialising it
        (DESIGN.md §9): rows stream through the store's block cache in
        :data:`PROJECT_CHUNK` chunks, and only the projected ``f32[N,
        out_dim]`` matrix — the Random Indexing premise's *small*
        representation — stays resident. ``base`` is ``None``; rescore
        fetches original rows back through the store
        (``query.topk_search(..., rp_corpus=store)``). Bit-identical to
        :meth:`wrap` of the same corpus, by the shared fixed-chunk
        projection."""
        z = project_corpus(projection, source, prefetch=prefetch)
        return cls(
            proj=DenseBackend(jnp.asarray(z)), projection=projection, base=None
        )

    @property
    def n_docs(self) -> int:
        """Corpus row count N."""
        return self.proj.n_docs

    @property
    def dim(self) -> int:
        """Routing dimensionality — the *projected* dim (the tree's dim)."""
        return self.proj.dim

    @property
    def base_dim(self) -> int:
        """Original document dimensionality (the rescore space)."""
        return self.projection.in_dim

    @property
    def dtype(self):
        """Projected element dtype (f32)."""
        return self.proj.dtype

    def take(self, rows: jax.Array) -> jax.Array:
        """Projected vectors for a batch of row ids — f32[B, out_dim] (what
        leaf appends store: the tree holds projected rows)."""
        return self.proj.take(rows)

    def row_sq(self, rows: jax.Array) -> jax.Array:
        """‖Px‖² per row — norms in the projected space."""
        return self.proj.row_sq(rows)

    def cross_nodes(self, rows: jax.Array, centers: jax.Array) -> jax.Array:
        """Projected-space ``x·c`` against per-query gathered centres."""
        return self.proj.cross_nodes(rows, centers)

    def cross_flat(self, rows: jax.Array, centers: jax.Array) -> jax.Array:
        """Projected-space ``x·c`` against a flat centre set."""
        return self.proj.cross_flat(rows, centers)

    def nn_flat(self, rows, centers, valid):
        """Nearest flat centre per row, in the projected space."""
        return self.proj.nn_flat(rows, centers, valid)

    def topk_flat(self, rows, centers, valid, k):
        """Top-k flat centres per row, in the projected space."""
        return self.proj.topk_flat(rows, centers, valid, k)


VectorBackend = Union[DenseBackend, EllSparseBackend, RandomProjBackend]


# ---------------------------------------------------------------------------
# sharded corpus views — the serving plane's document side (DESIGN.md §8).
# A `*DocShards` is a backend row-sharded over a mesh's data axes: shard s owns
# the contiguous global doc ids [s·L, (s+1)·L) where L = n_pad / n_shards
# (rows zero-padded to the shard multiple). `score_local` and `to_local` are
# shard_map-body views: inside shard_map the array leaves ARE the local block.
# ---------------------------------------------------------------------------


class _DocShardsBase:
    n_docs: int
    n_shards: int

    @staticmethod
    def to_local(global_ids: jax.Array, lo, docs_per_shard: int):
        """Global→local doc-id translation: (local row ids clipped safe for
        gathering, owned mask). ``lo`` = flat_shard_index · docs_per_shard."""
        local = global_ids - lo
        owned = jnp.logical_and(local >= 0, local < docs_per_shard)
        return jnp.clip(local, 0, docs_per_shard - 1), owned


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseDocShards(_DocShardsBase):
    """Dense corpus rows sharded P(data_axes, None) for shard-parallel query
    serving."""

    x: jax.Array  # f[n_pad, d] (local block [L, d] inside shard_map)
    n_docs: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))

    def _rows0(self) -> jax.Array:
        return self.x

    @property
    def dim(self) -> int:
        """Vector dimensionality d."""
        return self.x.shape[1]

    def score_local(self, xq: jax.Array, ids: jax.Array) -> jax.Array:
        """‖c‖² − 2·x·c for local doc row ids ``ids`` [B, C] against dense
        queries ``xq`` [B, d] — shard_map-body view (same expressions as the
        single-device `_score_entries`, so distances agree)."""
        xd = self.x[ids].astype(jnp.float32)                   # [B, C, d]
        c_sq = jnp.einsum("bcd,bcd->bc", xd, xd)
        return c_sq - 2.0 * jnp.einsum("bd,bcd->bc", xq.astype(jnp.float32), xd)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllDocShards(_DocShardsBase):
    """ELL-sparse corpus rows sharded P(data_axes, None): the sharded scorer
    stays sparse-first — per-candidate compute is O(nnz), never a densify."""

    values: jax.Array  # f[n_pad, nnz_max]
    cols: jax.Array    # i32[n_pad, nnz_max]
    sq: jax.Array      # f32[n_pad]
    n_cols: int = dataclasses.field(metadata=dict(static=True))
    n_docs: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))

    def _rows0(self) -> jax.Array:
        return self.values

    @property
    def dim(self) -> int:
        """Logical vector dimensionality (the culled vocabulary size)."""
        return self.n_cols

    def score_local(self, xq: jax.Array, ids: jax.Array) -> jax.Array:
        """‖c‖² − 2·x·c for local doc row ids [B, C] against dense queries
        [B, d]: nnz-bounded column gather from the query rows (compute is
        B·C·nnz, not B·C·d) — shard_map-body view."""
        v = self.values[ids].astype(jnp.float32)               # [B, C, nnz]
        c = self.cols[ids]                                     # [B, C, nnz]
        b_idx = jnp.arange(xq.shape[0])[:, None, None]
        g = xq.astype(jnp.float32)[b_idx, c]                   # [B, C, nnz]
        return self.sq[ids] - 2.0 * jnp.einsum("bcn,bcn->bc", v, g)


DocShards = Union[DenseDocShards, EllDocShards]


class StoreDocShards:
    """Row-sharded view of an **on-disk** corpus for shard-parallel serving
    (DESIGN.md §8/§9) — the out-of-core sibling of ``*DocShards``.

    Shard ``s`` owns the same contiguous global rows the in-memory layout
    gives it (``distributed.shard_row_extent``), but instead of holding its
    corpus block on device it holds a ``CorpusStore.partition`` slice — its
    own ``BlockCache`` under a per-shard residency budget. Per query chunk,
    :meth:`chunk_pools` fetches only the beam candidates each shard owns
    (deduplicated, via that shard's cache) into a padded per-shard *pool*;
    the sharded engine scores pools with the exact ``score_local``
    expressions, so answers stay bit-identical to the in-memory sharded path
    while peak store residency stays ≤ n_shards × per-shard budget (plus the
    per-cache one-block floor). A host-side handle — never crosses jit.
    """

    def __init__(self, mesh, store, budget_bytes=None, axes=None):
        from repro.core.distributed import data_axes, n_row_shards, shard_row_extent

        self.mesh = mesh
        self.axes = data_axes(mesh) if axes is None else tuple(axes)
        self.n_shards = n_row_shards(mesh, self.axes)
        self.kind = store.kind
        self.dim = store.dim
        self.nnz_max = store.nnz_max
        self.n_docs = store.n_docs
        self.manifest_hash = store.manifest_hash
        self.docs_per_shard = shard_row_extent(store.n_docs, self.n_shards)
        self._dtype = np.dtype(store.dtype)
        self.parts = store.partition(self.n_shards, budget_bytes=budget_bytes)
        self.peak_resident_bytes = 0

    def _pool_fields(self):
        """(name, per-row trailing shape, dtype) of the pool arrays."""
        if self.kind == "dense":
            return (("x", (self.dim,), self._dtype),)
        return (("values", (self.nnz_max,), self._dtype),
                ("cols", (self.nnz_max,), np.int32))

    def chunk_pools(self, cand: np.ndarray, valid: np.ndarray,
                    on_fault: str = "raise"):
        """Fetch one chunk's owned candidate rows into per-shard pools.

        ``cand`` i32[B, C] global candidate doc ids, ``valid`` bool[B, C].
        Returns ``(pools, pool_idx, owned, dropped_ids)``: ``pools`` is a
        tuple of stacked arrays ``[S, U, …]`` (each shard's deduplicated
        owned candidate rows, zero-padded to a shared power-of-two ``U``),
        ``pool_idx`` i32[S, B, C] maps each candidate slot to its pool row
        (0 where unowned — masked), ``owned`` bool[S, B, C] marks the slots
        shard ``s`` must score — the same ownership predicate the in-memory
        ``to_local`` computes — and ``dropped_ids`` is the global doc ids
        this chunk could not fetch (always empty with ``on_fault="raise"``,
        where an unreadable block raises its typed ``BlockError`` instead).

        ``on_fault="degrade"`` (DESIGN.md §10): candidates whose store block
        exhausted its read retries are removed from ``owned`` — they score
        +inf exactly as if no shard owned them, so answers are bit-identical
        to a search over the surviving corpus subset. Updates
        :attr:`peak_resident_bytes` from the partition caches."""
        from repro.core.store import check_on_fault

        check_on_fault(on_fault)
        s_count, (b, c) = self.n_shards, cand.shape
        per_shard = []
        u_max = 1
        for s, part in enumerate(self.parts):
            lo = s * self.docs_per_shard
            own = np.logical_and(
                valid, np.logical_and(cand >= lo, cand < lo + part.n_docs)
            )
            ids = np.unique(cand[own])
            per_shard.append((lo, own, ids))
            u_max = max(u_max, ids.size)
        u = 1
        while u < u_max:
            u *= 2
        pools = tuple(
            np.zeros((s_count, u) + shape, dtype)
            for _, shape, dtype in self._pool_fields()
        )
        pool_idx = np.zeros((s_count, b, c), np.int32)
        owned = np.zeros((s_count, b, c), bool)
        dropped: list = []
        for s, (lo, own, ids) in enumerate(per_shard):
            owned[s] = own
            if ids.size:
                if on_fault == "degrade":
                    got, ok = self.parts[s].take_rows_masked(ids - lo)
                    if not ok.all():
                        bad = ids[~ok]
                        dropped.append(bad)
                        # drop only the unreadable blocks' candidates: they
                        # score +inf, exactly as if no shard owned them
                        owned[s] &= ~np.isin(cand, bad)
                else:
                    got = self.parts[s].take_rows(ids - lo)
                for pool, (name, _, _) in zip(pools, self._pool_fields()):
                    pool[s, : ids.size] = got[name]
                pool_idx[s][own] = np.searchsorted(ids, cand[own]).astype(np.int32)
        self.peak_resident_bytes = max(
            self.peak_resident_bytes,
            sum(p.store.cache.resident_bytes for p in self.parts),
        )
        dropped_ids = (
            np.concatenate(dropped) if dropped else np.empty(0, cand.dtype)
        )
        return pools, pool_idx, owned, dropped_ids

    @property
    def cache_stats(self) -> list:
        """Per-shard block-cache stats dicts (serve report + tests)."""
        return [p.store.cache.stats for p in self.parts]


def shard_from_store(mesh, store, budget_bytes=None, axes=None) -> StoreDocShards:
    """Row-shard an on-disk corpus over ``mesh``'s data axes **without
    materialising it** (DESIGN.md §8/§9).

    ``store``: an open ``CorpusStore`` (not a slice — shard ownership is
    defined over the full global row range the tree addresses);
    ``budget_bytes``: per-shard block-cache budget (default: the store
    handle's own budget), so total residency is bounded by
    n_shards × budget. The result plugs into
    ``query.topk_search_sharded(..., corpus=...)`` — pass it when serving
    many batches so the partitions (and their caches) are created once. A
    full-range ``StoreSlice`` is unwrapped to its parent; a partial slice is
    rejected (the tree addresses global doc ids, so a sharded corpus must
    cover the whole store)."""
    from repro.core.store import CorpusStore, StoreSlice

    if isinstance(store, StoreSlice):
        if store.lo == 0 and store.hi == store.store.n_docs:
            store = store.store
        else:
            raise ValueError(
                f"sharded corpus slice [{store.lo}, {store.hi}) must cover "
                f"the full store row range [0, {store.store.n_docs}) — the "
                "tree addresses global doc ids"
            )
    if not isinstance(store, CorpusStore):
        raise TypeError(
            f"shard_from_store wants an open CorpusStore, got {type(store).__name__}"
        )
    return StoreDocShards(mesh, store, budget_bytes=budget_bytes, axes=axes)


def sparse_backend_from_csr(
    m: Csr, nnz_max: int | None = None, pad_to: int = 8
) -> EllSparseBackend:
    """Build the ELL+CSR backend from a CSR corpus (host-side layout pass).

    ``sq`` is computed from the ELL values so that when an explicit ``nnz_max``
    truncates long rows, norms stay consistent with what ``cross_*``/``take``
    actually see (``take`` also clips at ``nnz_max``). ``pad_to`` is
    ``ell_from_csr``'s lane rounding — pass 1 to honour an explicit
    ``nnz_max`` exactly (the store-append path must match a store's recorded
    width, DESIGN.md §9)."""
    e = ell_from_csr(m, nnz_max=nnz_max, pad_to=pad_to)
    return EllSparseBackend(
        values=e.values,
        cols=e.cols,
        sq=jnp.sum(e.values.astype(jnp.float32) ** 2, axis=1),
        csr_data=m.data,
        csr_indices=m.indices,
        csr_indptr=m.indptr,
        n_cols=m.n_cols,
    )


def backend_from_store(source, rows=None) -> VectorBackend:
    """Materialise store rows as the matching in-memory backend
    (DESIGN.md §9).

    ``source``: a ``repro.core.store.CorpusStore`` or ``StoreSlice`` —
    ``kind="dense"`` → :class:`DenseBackend`, ``kind="ell"`` →
    :class:`EllSparseBackend`. ``rows`` (global ids, default all) is the
    residency knob: out-of-core consumers (store-backed ``topk_search``,
    ``build_from_store``) pass one chunk's rows at a time, so only
    chunk-sized backends ever exist on device."""
    if source.kind == "dense":
        return DenseBackend.from_store(source, rows)
    return EllSparseBackend.from_store(source, rows)


def backend_from_rows(source, got) -> VectorBackend:
    """Materialise **already-fetched** store rows as the matching backend.

    ``got`` is a ``take_rows`` result (``{"x"}`` dense / ``{"values",
    "cols"}`` ELL) for ``source``'s layout — the seam that lets a
    ``store.Prefetcher`` move the disk read onto a reader thread
    (DESIGN.md §9) while the backend construction (and hence every answer)
    stays bit-identical to :func:`backend_from_store`."""
    if source.kind == "dense":
        return DenseBackend.from_rows(got)
    return EllSparseBackend.from_rows(got, source.dim)


def backend_for_store_layout(source, corpus) -> VectorBackend:
    """Normalise new corpus rows into ``source``'s exact block layout.

    ``source``: a ``CorpusStore``/``StoreSlice``; ``corpus``: a dense array,
    Csr, or backend. Returns a backend whose rows can be appended to the
    store verbatim (``CorpusStore.append``) *and* inserted into the tree
    (``ktree.insert_into_store``) — one normalisation, so the vectors the
    tree holds and the vectors the store serves are bit-identical. Dense
    stores: densify + cast to the store dtype. ELL stores: re-lay the rows at
    the store's recorded ``nnz_max`` width (longer rows truncate exactly like
    an explicit-``nnz_max`` backend). Dimension mismatches raise.

    Idempotent: a backend already in the store's exact layout (same kind,
    dim, dtype — and ``nnz_max`` width for ELL) passes through untouched, so
    ``insert_into_store`` normalising once and ``append`` normalising its
    argument again costs one layout pass, not two."""
    if is_store(corpus):
        raise TypeError("append source must be in-memory rows, not a store")
    dtype = np.dtype(source.dtype)
    if source.kind == "dense":
        be = make_backend(corpus, "dense")
        if be.dim != source.dim:
            raise ValueError(
                f"appended rows have dim {be.dim} != store dim {source.dim}"
            )
        x = be.x if be.dtype == dtype else be.x.astype(dtype)
        return DenseBackend(x=x)
    if (
        isinstance(corpus, EllSparseBackend)
        and corpus.dim == source.dim
        and corpus.nnz_max == source.nnz_max
        and np.dtype(corpus.dtype) == dtype
    ):
        return corpus
    if isinstance(corpus, Csr):
        m = corpus
    elif isinstance(corpus, EllSparseBackend):
        m = corpus._csr()
    elif isinstance(corpus, DenseBackend):
        m = csr_from_dense(np.asarray(corpus.x))
    elif isinstance(corpus, Ell):
        data, indices, indptr = _ell_csr_arrays(
            np.asarray(corpus.values), np.asarray(corpus.cols)
        )
        m = Csr(data=jnp.asarray(data), indices=jnp.asarray(indices),
                indptr=jnp.asarray(indptr), n_cols=corpus.n_cols)
    else:
        m = csr_from_dense(np.asarray(corpus))
    if m.n_cols != source.dim:
        raise ValueError(
            f"appended rows have dim {m.n_cols} != store dim {source.dim}"
        )
    if np.asarray(m.data).dtype != dtype:
        m = Csr(data=jnp.asarray(np.asarray(m.data).astype(dtype)),
                indices=m.indices, indptr=m.indptr, n_cols=m.n_cols)
    return sparse_backend_from_csr(m, nnz_max=source.nnz_max, pad_to=1)


def is_store(x) -> bool:
    """True when ``x`` is an out-of-core corpus handle (a ``CorpusStore`` or
    ``StoreSlice``) rather than an in-memory corpus/backend."""
    from repro.core.store import CorpusStore, StoreSlice

    return isinstance(x, (CorpusStore, StoreSlice))


def make_backend(x, backend: str = "auto") -> VectorBackend:
    """Normalise (corpus, backend-name) into a backend instance.

    ``x``: dense array, :class:`Csr`, :class:`Ell`-producing Csr, an
    existing backend, or an out-of-core store handle (``CorpusStore`` /
    ``StoreSlice`` — materialised **whole**; out-of-core paths check
    :func:`is_store` before calling this). ``backend``: "auto" (follow the
    input layout), "dense", or "sparse".
    """
    if backend not in ("auto", "dense", "sparse"):
        raise ValueError(f"unknown backend {backend!r}; use auto|dense|sparse")
    if is_store(x):
        x = backend_from_store(x)
        if backend == "dense" and isinstance(x, EllSparseBackend):
            x = DenseBackend(x.take(jnp.arange(x.n_docs)))
        elif backend == "sparse" and isinstance(x, DenseBackend):
            x = sparse_backend_from_csr(csr_from_dense(np.asarray(x.x)))
    if isinstance(x, (DenseBackend, EllSparseBackend, RandomProjBackend)):
        return x
    if isinstance(x, Csr):
        if backend == "dense":
            from repro.sparse.csr import csr_to_dense

            return DenseBackend(csr_to_dense(x))
        return sparse_backend_from_csr(x)
    if isinstance(x, Ell):
        if backend == "dense":
            from repro.sparse.ell import ell_to_dense

            return DenseBackend(ell_to_dense(x))
        # rebuild CSR host-side straight from the padded layout (O(nnz);
        # never materialises the dense corpus) via the shared ELL→CSR helper
        data, indices, indptr = _ell_csr_arrays(
            np.asarray(x.values), np.asarray(x.cols)
        )
        m = Csr(
            data=jnp.asarray(data),
            indices=jnp.asarray(indices),
            indptr=jnp.asarray(indptr),
            n_cols=x.n_cols,
        )
        return sparse_backend_from_csr(m, nnz_max=x.nnz_max)
    # array-like
    xa = jnp.asarray(x)
    if backend == "sparse":
        return sparse_backend_from_csr(csr_from_dense(np.asarray(xa)))
    return DenseBackend(xa)
