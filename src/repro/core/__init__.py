"""The paper's primary contribution: K-tree (and its medoid/sampled variants),
the k-means family it builds on, the top-k beam-search query engine,
clustering metrics, and the distributed (shard_map) layer. See DESIGN.md §1–3
and §7."""
from repro.core import kmeans, ktree, metrics, query, sampling, store
from repro.core.kmeans import (
    kmeans as run_kmeans,
    kmeans_fixed_iters,
    bisecting_kmeans,
    minibatch_kmeans,
    assign,
    pairwise_sqdist,
)
from repro.core.ktree import (
    KTree,
    ktree_init,
    build,
    build_from_store,
    insert,
    extract_assignment,
    assign_via_tree,
    nn_search,
    nn_search_greedy,
    check_invariants,
)
from repro.core.store import open_store, save_store
from repro.core.metrics import micro_purity, micro_entropy, nmi
from repro.core.query import topk_search
from repro.core.sampling import sampled_ktree_clustering

__all__ = [
    "kmeans", "ktree", "metrics", "query", "sampling", "store",
    "run_kmeans", "kmeans_fixed_iters", "bisecting_kmeans", "minibatch_kmeans",
    "assign", "pairwise_sqdist",
    "KTree", "ktree_init", "build", "build_from_store", "insert",
    "extract_assignment",
    "assign_via_tree", "nn_search", "nn_search_greedy", "check_invariants",
    "open_store", "save_store",
    "topk_search",
    "micro_purity", "micro_entropy", "nmi", "sampled_ktree_clustering",
]
