"""Distributed clustering primitives (beyond the single-threaded paper).

Documents are sharded over the ``data`` (and ``pod``) mesh axes; centres are
replicated (small) or sharded over ``model`` (huge leaf-level K). Centroid
updates are (sum, count) psums — a hierarchical all-reduce: ICI within a pod,
DCI across pods, exactly the collective the roofline analysis prices.

These functions are written with ``shard_map`` so the collective schedule is
explicit (not left to GSPMD), which is what we tune in §Perf.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax>=0.6 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore


def shard_map(f, **kwargs):
    """Version shim: jax<0.6 spells ``check_vma`` as ``check_rep``."""
    try:
        return _shard_map(f, **kwargs)
    except TypeError:
        if "check_vma" in kwargs:
            kwargs = dict(kwargs)
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, **kwargs)

from repro.core.kmeans import assign as _assign


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that shard documents: ('pod','data') when multi-pod."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_row_shards(mesh: Mesh, axes: Optional[Tuple[str, ...]] = None) -> int:
    """Number of row shards a corpus splits into over the data axes."""
    axes = data_axes(mesh) if axes is None else tuple(axes)
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n


def flat_shard_index(mesh: Mesh, axes: Tuple[str, ...]):
    """Flattened shard index of the executing device *inside a shard_map body*
    — row-major over ``axes``, matching how ``P(axes, ...)`` splits rows."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * int(mesh.shape[a]) + jax.lax.axis_index(a)
    return idx


def shard_row_extent(n: int, n_shards: int) -> int:
    """Rows per shard after zero-padding ``n`` rows to the shard multiple —
    shard ``s`` owns the contiguous global row range ``[s·L, (s+1)·L)``.
    Single source of truth for row ownership: :func:`shard_rows` (in-memory
    ``*DocShards``) and ``store.CorpusStore.partition`` (out-of-core, §9)
    both derive from it, which is what keeps disk-backed shard ownership
    aligned with the device layout."""
    return -(-n // n_shards)


def shard_rows(mesh: Mesh, arrays, axes: Optional[Tuple[str, ...]] = None):
    """Device-put arrays row-sharded over the mesh's data axes.

    Rows are zero-padded up to the shard multiple so every shard holds the same
    block length (shard_map needs even splits); callers mask the pad rows via
    the true row count. Returns (sharded arrays list, n_shards, n_pad)."""
    axes = data_axes(mesh) if axes is None else tuple(axes)
    n_shards = n_row_shards(mesh, axes)
    n = int(arrays[0].shape[0])
    n_pad = shard_row_extent(n, n_shards) * n_shards
    out = []
    for a in arrays:
        a_np = np.asarray(a)
        assert a_np.shape[0] == n, "row-sharded arrays must share the row count"
        if n_pad > n:
            pad = np.zeros((n_pad - n, *a_np.shape[1:]), a_np.dtype)
            a_np = np.concatenate([a_np, pad], axis=0)
        spec = P(axes, *([None] * (a_np.ndim - 1)))
        out.append(jax.device_put(a_np, NamedSharding(mesh, spec)))
    return out, n_shards, n_pad


def distributed_lloyd_step(mesh: Mesh, use_kernel: bool = False):
    """Returns a jitted step: (x_sharded [N,d], centers [k,d]) →
    (centers', assign, sse). Centres replicated; docs sharded over data axes."""
    axes = data_axes(mesh)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axes, None), P(None, None)),
        out_specs=(P(None, None), P(axes), P()),
        check_vma=False,
    )
    def step(xs, c):
        k = c.shape[0]
        idx, dist = _assign(xs, c, use_kernel=use_kernel)
        onehot = jax.nn.one_hot(idx, k, dtype=xs.dtype)
        sums = jnp.einsum("nk,nd->kd", onehot, xs)
        counts = onehot.sum(axis=0)
        for ax in axes:  # hierarchical all-reduce: ICI first, then DCI
            sums = jax.lax.psum(sums, ax)
            counts = jax.lax.psum(counts, ax)
        new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1e-12), c)
        sse = dist.sum()
        for ax in axes:
            sse = jax.lax.psum(sse, ax)
        return new_c, idx, sse

    return jax.jit(step)


def distributed_kmeans(
    mesh: Mesh,
    x: jax.Array,
    k: int,
    iters: int = 20,
    key: Optional[jax.Array] = None,
    use_kernel: bool = False,
):
    """Fixed-iteration distributed Lloyd. ``x`` may be host-global; it is placed
    with a data-sharded NamedSharding. Returns (centers, assign, sse)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    axes = data_axes(mesh)
    x = jax.device_put(x, NamedSharding(mesh, P(axes, None)))
    # k-means++ on a bounded subsample (cheap, replicated) — full-data ++ would
    # serialise k rounds of global argmax; the sample is the standard remedy.
    from repro.core.kmeans import kmeans_pp_init

    k1, k2 = jax.random.split(key)
    n_sample = min(x.shape[0], max(8 * k, 2048))
    sample = x[jax.random.choice(k1, x.shape[0], (n_sample,), replace=False)]
    centers = jax.device_put(
        kmeans_pp_init(k2, sample, k), NamedSharding(mesh, P(None, None))
    )
    step = distributed_lloyd_step(mesh, use_kernel=use_kernel)
    idx = sse = None
    for _ in range(iters):
        centers, idx, sse = step(x, centers)
    return centers, idx, sse


def distributed_assign_sharded_centers(mesh: Mesh, k_global: int, use_kernel: bool = False):
    """NN assignment when the centre set itself is sharded over ``model``
    (leaf-level K in the tens of thousands): each device scores its centre
    shard, then a tiny (min, argmin) all-gather+reduce combines — collective
    volume is O(B·n_model_shards), not O(B·K).

    Returns jitted fn: (x [B,d] sharded over data axes, centers [K,d] sharded
    over model) → (global idx i32[B], sqdist f32[B]), both data-sharded.
    """
    axes = data_axes(mesh)
    n_shards = mesh.shape["model"]
    k_local = k_global // n_shards

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axes, None), P("model", None)),
        out_specs=(P(axes), P(axes)),
        check_vma=False,
    )
    def assign_fn(xs, cs):
        my_shard = jax.lax.axis_index("model")
        idx_local, dist_local = _assign(xs, cs, use_kernel=use_kernel)
        idx_global = idx_local + my_shard * k_local
        # gather the per-shard winners: [n_shards, B] each — tiny collective
        all_dist = jax.lax.all_gather(dist_local, "model")
        all_idx = jax.lax.all_gather(idx_global, "model")
        w = jnp.argmin(all_dist, axis=0)
        best_idx = jnp.take_along_axis(all_idx, w[None, :], axis=0)[0]
        best_dist = jnp.take_along_axis(all_dist, w[None, :], axis=0)[0]
        return best_idx.astype(jnp.int32), best_dist

    return jax.jit(assign_fn)


def sampled_tree_assign_distributed(mesh: Mesh, tree, x, chunk: int = 4096):
    """Paper §3 at fleet scale: the (small) sample-built tree is replicated and
    every data shard routes its own documents — embarrassingly parallel; the
    only collective is the final result layout. Returns cluster ids [N]."""
    from repro.core import ktree as kt

    axes = data_axes(mesh)
    x = jax.device_put(x, NamedSharding(mesh, P(axes, None)))
    # tree arrays are small (m·#nodes); replicate
    tree = jax.device_put(tree, NamedSharding(mesh, P()))
    return kt.assign_via_tree(tree, x, chunk=chunk)
