"""k-means variants used by the K-tree system and the CLUTO-style baselines.

- :func:`kmeans`              — Lloyd to convergence (what K-tree runs on node
                                splits; paper §4: "K-tree runs k-means to
                                convergence using dense vectors").
- :func:`kmeans_fixed_iters`  — fixed-iteration variant ("CLUTO stops after a
                                specified number of iterations").
- :func:`bisecting_kmeans`    — CLUTO's repeated-bisecting baseline.
- :func:`minibatch_kmeans`    — web-scale variant used by the bulk tree builder.

Everything is weighted (weights = subtree sizes when clustering tree entries)
and mask-aware (invalid rows carry weight 0), so the same jitted code serves
full-corpus clustering and the K-tree's tiny node splits via vmap.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# distances + assignment (the hot path — Pallas kernel behind a flag)
# ---------------------------------------------------------------------------

def pairwise_sqdist(
    x: jax.Array,
    centers: jax.Array,
    x_sq: Optional[jax.Array] = None,
    c_sq: Optional[jax.Array] = None,
) -> jax.Array:
    """‖x−c‖² = ‖x‖² − 2·x·cᵀ + ‖c‖² — [B,K]. The matmul is the MXU hot spot."""
    if x_sq is None:
        x_sq = jnp.einsum("nd,nd->n", x, x)
    if c_sq is None:
        c_sq = jnp.einsum("kd,kd->k", centers, centers)
    cross = x @ centers.T
    return jnp.maximum(x_sq[:, None] - 2.0 * cross + c_sq[None, :], 0.0)


def assign(
    x: jax.Array,
    centers: jax.Array,
    valid: Optional[jax.Array] = None,
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Nearest-centre assignment: (idx i32[B], sqdist f32[B]).

    ``valid``: bool[K] — masked centres are never chosen. ``use_kernel``
    dispatches to the Pallas ``nn_assign`` kernel (TPU; interpret-mode on CPU).
    """
    if use_kernel:
        from repro.kernels.ops import nn_assign

        return nn_assign(x, centers, valid=valid)
    d = pairwise_sqdist(x, centers)
    if valid is not None:
        d = jnp.where(valid[None, :], d, jnp.inf)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]


# ---------------------------------------------------------------------------
# Lloyd iterations
# ---------------------------------------------------------------------------

class KMeansResult(NamedTuple):
    centers: jax.Array    # f32[k, d]
    assign: jax.Array     # i32[n]
    counts: jax.Array     # f32[k] (weighted)
    sse: jax.Array        # f32[] weighted sum of squared distances
    iters: jax.Array      # i32[]


def _centroid_update(
    x: jax.Array, idx: jax.Array, w: jax.Array, k: int, via: str = "matmul"
) -> Tuple[jax.Array, jax.Array]:
    """(sums f32[k,d], counts f32[k]). ``matmul`` = one-hot einsum (MXU-friendly,
    what the TPU path uses); ``segment`` = segment_sum scatter."""
    if via == "matmul":
        onehot = jax.nn.one_hot(idx, k, dtype=x.dtype) * w[:, None]   # [n,k]
        sums = jnp.einsum("nk,nd->kd", onehot, x)
        counts = onehot.sum(axis=0)
    else:
        sums = jax.ops.segment_sum(x * w[:, None], idx, num_segments=k)
        counts = jax.ops.segment_sum(w, idx, num_segments=k)
    return sums, counts


def lloyd_step(
    x: jax.Array,
    centers: jax.Array,
    w: Optional[jax.Array] = None,
    update_via: str = "matmul",
    use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One weighted Lloyd step → (new_centers, idx, counts, sse).
    Empty clusters keep their previous centre (standard)."""
    k = centers.shape[0]
    if w is None:
        w = jnp.ones(x.shape[0], x.dtype)
    idx, dist = assign(x, centers, use_kernel=use_kernel)
    sums, counts = _centroid_update(x, idx, w, k, via=update_via)
    new_centers = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1e-12), centers)
    sse = jnp.sum(w * dist)
    return new_centers, idx, counts, sse


def kmeans_pp_init(key: jax.Array, x: jax.Array, k: int, w: Optional[jax.Array] = None) -> jax.Array:
    """k-means++ seeding (weighted). O(k) sequential rounds, each a matvec."""
    n = x.shape[0]
    if w is None:
        w = jnp.ones(n, x.dtype)
    key0, key = jax.random.split(key)
    first = jax.random.categorical(key0, jnp.log(jnp.maximum(w, 1e-30)))
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])
    mind0 = jnp.sum((x - x[first]) ** 2, axis=1)

    def body(i, carry):
        centers, mind, key = carry
        key, sub = jax.random.split(key)
        logits = jnp.log(jnp.maximum(mind * w, 1e-30))
        nxt = jax.random.categorical(sub, logits)
        c = x[nxt]
        centers = centers.at[i].set(c)
        mind = jnp.minimum(mind, jnp.sum((x - c) ** 2, axis=1))
        return centers, mind, key

    centers, _, _ = jax.lax.fori_loop(1, k, body, (centers0, mind0, key))
    return centers


def _kmeans_single(
    key: jax.Array,
    x: jax.Array,
    k: int,
    w: jax.Array,
    max_iters: int,
    tol: float,
    init: str,
    init_centers: Optional[jax.Array],
    update_via: str,
    use_kernel: bool,
) -> KMeansResult:
    """One Lloyd-to-convergence run from one seeding (see :func:`kmeans`)."""
    n = x.shape[0]
    if init_centers is not None:
        centers = init_centers
    elif init == "kmeanspp":
        centers = kmeans_pp_init(key, x, k, w)
    else:  # random rows
        sel = jax.random.choice(key, n, (k,), replace=False, p=w / w.sum())
        centers = x[sel]

    def cond(state):
        _, _, _, _, i, done = state
        return jnp.logical_and(i < max_iters, jnp.logical_not(done))

    def body(state):
        centers, idx_old, counts, sse_old, i, _ = state
        centers_new, idx, counts, sse = lloyd_step(
            x, centers, w, update_via=update_via, use_kernel=use_kernel
        )
        done = jnp.all(idx == idx_old)
        if tol > 0.0:
            done = jnp.logical_or(done, jnp.abs(sse_old - sse) <= tol * jnp.maximum(sse_old, 1e-30))
        return centers_new, idx, counts, sse, i + 1, done

    idx0 = jnp.full((n,), -1, jnp.int32)
    state = (centers, idx0, jnp.zeros((k,), x.dtype), jnp.inf, jnp.int32(0), jnp.bool_(False))
    centers, idx, counts, sse, iters, _ = jax.lax.while_loop(cond, body, state)
    # final consistent assignment against the converged centres
    idx, dist = assign(x, centers, use_kernel=use_kernel)
    sums, counts = _centroid_update(x, idx, w, k, via=update_via)
    centers = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1e-12), centers)
    return KMeansResult(centers, idx, counts, jnp.sum(w * dist), iters)


@functools.partial(
    jax.jit,
    static_argnames=("k", "max_iters", "update_via", "use_kernel", "init", "n_init"),
)
def kmeans(
    key: jax.Array,
    x: jax.Array,
    k: int,
    w: Optional[jax.Array] = None,
    max_iters: int = 300,
    tol: float = 0.0,
    init: str = "kmeanspp",
    init_centers: Optional[jax.Array] = None,
    update_via: str = "matmul",
    use_kernel: bool = False,
    n_init: int = 4,
) -> KMeansResult:
    """Weighted Lloyd **to convergence** (assignments fixed-point) — the k-means
    the paper runs inside K-tree. ``tol=0`` means exact assignment convergence;
    ``max_iters`` is a safety cap.

    ``n_init`` independent seedings run and the lowest-SSE solution wins
    (standard Lloyd restarts — k-means++ alone still lands in local optima on
    a bad draw). Explicit ``init_centers`` forces a single run."""
    if w is None:
        w = jnp.ones(x.shape[0], x.dtype)
    if init_centers is not None or n_init <= 1:
        return _kmeans_single(
            key, x, k, w, max_iters, tol, init, init_centers, update_via, use_kernel
        )
    runs = [
        _kmeans_single(kk, x, k, w, max_iters, tol, init, None, update_via, use_kernel)
        for kk in jax.random.split(key, n_init)
    ]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *runs)
    best = jnp.argmin(stacked.sse)
    return jax.tree.map(lambda a: a[best], stacked)


@functools.partial(jax.jit, static_argnames=("k", "iters", "update_via", "use_kernel"))
def kmeans_fixed_iters(
    key: jax.Array,
    x: jax.Array,
    k: int,
    iters: int = 10,
    w: Optional[jax.Array] = None,
    update_via: str = "matmul",
    use_kernel: bool = False,
) -> KMeansResult:
    """CLUTO-style: stop after ``iters`` Lloyd iterations (paper §4)."""
    n = x.shape[0]
    if w is None:
        w = jnp.ones(n, x.dtype)
    centers = kmeans_pp_init(key, x, k, w)

    def body(_, centers):
        c, _, _, _ = lloyd_step(x, centers, w, update_via=update_via, use_kernel=use_kernel)
        return c

    centers = jax.lax.fori_loop(0, iters, body, centers)
    idx, dist = assign(x, centers, use_kernel=use_kernel)
    _, counts = _centroid_update(x, idx, w, k, via=update_via)
    return KMeansResult(centers, idx, counts, jnp.sum(w * dist), jnp.int32(iters))


def bisecting_kmeans(
    key: jax.Array,
    x: jax.Array,
    k: int,
    w: Optional[jax.Array] = None,
    inner_iters: int = 20,
    use_kernel: bool = False,
) -> KMeansResult:
    """Repeated bisecting k-means (CLUTO ``rbr``-style): repeatedly 2-means-split
    the cluster with the largest weighted SSE until k clusters exist.

    Host loop over k−1 splits; each split is a *masked* jitted 2-means over the
    full array (weights zeroed outside the target cluster) so shapes stay
    static — no dynamic gathers.
    """
    n = x.shape[0]
    if w is None:
        w = jnp.ones(n, x.dtype)
    assign_full = jnp.zeros(n, jnp.int32)
    centers = jnp.zeros((k, x.shape[1]), x.dtype)
    centers = centers.at[0].set((x * w[:, None]).sum(0) / jnp.maximum(w.sum(), 1e-12))

    @functools.partial(jax.jit, static_argnames=())
    def split(key, assign_full, centers, target, n_current):
        mask = (assign_full == target).astype(x.dtype) * w
        # n_init=1: this is the CLUTO-style baseline the paper benchmarks
        # against — keep its per-split cost at one Lloyd run, not best-of-N
        res = kmeans(key, x, 2, w=mask, max_iters=inner_iters, init="kmeanspp",
                     use_kernel=use_kernel, n_init=1)
        sel = jnp.logical_and(assign_full == target, res.assign == 1)
        assign_full = jnp.where(sel, n_current, assign_full)
        centers = centers.at[target].set(res.centers[0]).at[n_current].set(res.centers[1])
        return assign_full, centers

    @jax.jit
    def cluster_sse(assign_full, centers):
        d = pairwise_sqdist(x, centers)
        dist = jnp.take_along_axis(d, assign_full[:, None], axis=1)[:, 0]
        return jax.ops.segment_sum(dist * w, assign_full, num_segments=k)

    for n_current in range(1, k):
        sse = cluster_sse(assign_full, centers)
        target = int(jnp.argmax(sse[:n_current]))
        key, sub = jax.random.split(key)
        assign_full, centers = split(sub, assign_full, centers, target, n_current)

    idx, dist = assign(x, centers)  # final refit assignment (CLUTO refines too)
    counts = jax.ops.segment_sum(w, assign_full, num_segments=k)
    return KMeansResult(centers, assign_full, counts, jnp.sum(w * dist), jnp.int32(k - 1))


def minibatch_kmeans(
    key: jax.Array,
    x: jax.Array,
    k: int,
    batch: int = 4096,
    steps: int = 200,
    use_kernel: bool = False,
) -> KMeansResult:
    """Sculley-style mini-batch k-means — the bulk tree builder's workhorse at
    corpus scale (per-centre 1/count learning rates)."""
    n = x.shape[0]
    key, sub = jax.random.split(key)
    sel = jax.random.choice(sub, n, (k,), replace=False)
    centers0 = x[sel]

    @jax.jit
    def step(carry, key):
        centers, counts = carry
        bidx = jax.random.randint(key, (batch,), 0, n)
        xb = x[bidx]
        idx, _ = assign(xb, centers, use_kernel=use_kernel)
        sums, bc = _centroid_update(xb, idx, jnp.ones(batch, x.dtype), k)
        counts_new = counts + bc
        lr = bc / jnp.maximum(counts_new, 1.0)
        means_b = sums / jnp.maximum(bc, 1e-12)[:, None]
        centers = jnp.where(bc[:, None] > 0, centers + lr[:, None] * (means_b - centers), centers)
        return (centers, counts_new), None

    keys = jax.random.split(key, steps)
    (centers, counts), _ = jax.lax.scan(step, (centers0, jnp.zeros(k, x.dtype)), keys)
    idx, dist = assign(x, centers, use_kernel=use_kernel)
    return KMeansResult(centers, idx, counts, jnp.sum(dist), jnp.int32(steps))
