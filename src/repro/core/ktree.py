"""K-tree — height-balanced cluster tree of order m (the paper's contribution).

TPU-native array layout (DESIGN.md §3): the whole tree lives in preallocated
device arrays; node ids are row indices. Entry arrays have ``order+1`` slots so
a node can transiently hold m+1 entries (the paper's overflow state) before the
k-means split. The control plane (which node to split next, wave scheduling) is
thin host Python; every data-touching step is a jitted batched op.

Semantics (paper §1):
- leaves hold 1..m data vectors (``child`` = document id),
- internal nodes hold 1..m (cluster mean, child node) pairs,
- insertion = NN search root→leaf, updating weighted means along the path,
- a node that reaches m+1 entries is split with k-means (k=2), the two means
  are promoted to the parent; the root split grows the tree by one level,
- the tree is a nearest-neighbour search tree over the inserted vectors.

Medoid variant (paper §2): centres are document exemplars (nearest entry to
each 2-means mean), entries are *not* weighted and means are *not* updated on
insertion — ``medoid=True``.

Vector backends (DESIGN.md §5): documents reach the tree through a
:mod:`repro.core.backend` instance — dense rows (seed behaviour) or the
paper's sparse representation (ELL + CSR; distances via the ``ell_spmm`` /
``nn_assign`` Pallas kernels on TPU, ``kernels/ref.py`` oracles on CPU).
Node centres are always dense; the sparse corpus is densified only one
routed wave at a time (leaf appends and node splits), never wholesale.

Control plane (DESIGN.md §6): ``route`` compilations are bucketed by level
count (one compile per power-of-two descent depth, with inactive levels
masked), and all overflowing nodes of one height are split in a single
jitted ``split_nodes_batch`` call (vmapped 2-means) instead of one jit call
per node.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import resolve_knobs
from repro.core.backend import VectorBackend, make_backend
from repro.core.kmeans import kmeans
from repro.core.profile import NULL_PROFILER


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KTree:
    # --- data fields (device arrays) ---
    centers: jax.Array       # f32[N, m+1, d] entry vectors/means (zeros invalid)
    counts: jax.Array        # f32[N, m+1]    subtree weight per entry
    child: jax.Array         # i32[N, m+1]    doc id (leaf) / node id (internal)
    n_entries: jax.Array     # i32[N]
    is_leaf: jax.Array       # bool[N]
    parent: jax.Array        # i32[N]         -1 for root
    parent_slot: jax.Array   # i32[N]
    height: jax.Array        # i32[N]         0 at leaves (stable under root growth)
    root: jax.Array          # i32[]
    n_nodes: jax.Array       # i32[]
    depth: jax.Array         # i32[]          levels; 1 = root is a leaf
    # --- meta fields (static) ---
    order: int = dataclasses.field(metadata=dict(static=True))
    medoid: bool = dataclasses.field(metadata=dict(static=True))

    @property
    def max_nodes(self) -> int:
        return self.centers.shape[0]

    @property
    def dim(self) -> int:
        return self.centers.shape[2]

    @property
    def slots(self) -> int:  # order + 1
        return self.centers.shape[1]


def ktree_init(
    max_nodes: int, order: int, dim: int, medoid: bool = False, dtype=jnp.float32
) -> KTree:
    m1 = order + 1
    return KTree(
        centers=jnp.zeros((max_nodes, m1, dim), dtype),
        counts=jnp.zeros((max_nodes, m1), dtype),
        child=jnp.full((max_nodes, m1), -1, jnp.int32),
        n_entries=jnp.zeros((max_nodes,), jnp.int32),
        is_leaf=jnp.ones((max_nodes,), bool).at[0].set(True),
        parent=jnp.full((max_nodes,), -1, jnp.int32),
        parent_slot=jnp.full((max_nodes,), -1, jnp.int32),
        height=jnp.zeros((max_nodes,), jnp.int32),
        root=jnp.int32(0),
        n_nodes=jnp.int32(1),
        depth=jnp.int32(1),
        order=order,
        medoid=medoid,
    )


CAPACITY_HEADROOM = 1.8
"""Node-capacity multiplier over the worst-case leaf count in
:func:`suggested_max_nodes`. Internal nodes of an order-m tree add at most
~1/(⌈m/2⌉−1) ≈ 0.5× more nodes on top of the leaves, and the split cascade
transiently allocates the new sibling before the parent absorbs it — 1.8×
covers both with margin (pinned by the capacity property test)."""


def suggested_max_nodes(n_docs: int, order: int) -> int:
    """Preallocation capacity: worst-case ~2·N/(m/2) half-full leaves, times
    :data:`CAPACITY_HEADROOM` for internal nodes + split headroom, plus
    constant slack for tiny corpora."""
    leaves = max(2 * n_docs // max(order // 2, 1), 8)
    return int(leaves * CAPACITY_HEADROOM) + 32


def _levels_bucket(levels: int) -> int:
    """Round a descent depth up to a power of two — ``route``/``_insert_wave``
    compile once per bucket (inactive levels are masked), so a growing tree
    triggers O(log depth) compiles instead of one per depth."""
    if levels <= 0:
        return 0
    b = 1
    while b < levels:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# routing (NN search root→leaf) — the hot path
# ---------------------------------------------------------------------------

def _node_nearest_slot(
    tree: KTree, node_ids: jax.Array, backend: VectorBackend, rows: jax.Array
) -> jax.Array:
    """For each (node, query-row) pick the nearest *valid* entry slot → i32[B].

    Distances drop the ‖x‖² constant (same argmin). Per-query gathered node
    centres: the backend supplies the cross term — MXU einsum for dense rows,
    an nnz-bounded column gather for sparse rows."""
    c = tree.centers[node_ids]                                   # [B, m1, d]
    c_sq = jnp.einsum("bmd,bmd->bm", c, c)
    cross = backend.cross_nodes(rows, c)
    dist = c_sq - 2.0 * cross
    valid = jnp.arange(tree.slots)[None, :] < tree.n_entries[node_ids][:, None]
    dist = jnp.where(valid, dist, jnp.inf)
    return jnp.argmin(dist, axis=1).astype(jnp.int32)


def _root_nearest_slot(
    tree: KTree, backend: VectorBackend, rows: jax.Array
) -> jax.Array:
    """Level-0 descent: every query is at the root, so its entries form one
    flat centre set — the fused flat-NN path (``nn_assign`` / ``ell_spmm``
    Pallas kernels on TPU, ref oracles elsewhere)."""
    c = tree.centers[tree.root]                                  # [m1, d]
    valid = jnp.arange(tree.slots) < tree.n_entries[tree.root]
    idx, _ = backend.nn_flat(rows, c, valid)
    return idx


def _route_descend(
    tree: KTree,
    backend: VectorBackend,
    rows: jax.Array,
    levels: jax.Array,
    max_levels: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Descend up to ``max_levels`` internal levels; levels ≥ ``levels`` are
    masked no-ops (the node sticks once the true leaf level is reached).

    Returns (leaf_ids i32[B], path_nodes i32[max_levels, B],
    path_slots i32[max_levels, B]); path rows at l ≥ levels are stale and must
    be masked by the caller."""
    b = rows.shape[0]
    node = jnp.full((b,), 1, jnp.int32) * tree.root
    nodes_l, slots_l = [], []
    for l in range(max_levels):
        if l == 0:
            slot = _root_nearest_slot(tree, backend, rows)
        else:
            slot = _node_nearest_slot(tree, node, backend, rows)
        nodes_l.append(node)
        slots_l.append(slot)
        active = jnp.asarray(l, jnp.int32) < levels
        node = jnp.where(active, tree.child[node, slot], node)
    path_nodes = jnp.stack(nodes_l) if max_levels else jnp.zeros((0, b), jnp.int32)
    path_slots = jnp.stack(slots_l) if max_levels else jnp.zeros((0, b), jnp.int32)
    return node, path_nodes, path_slots


@functools.partial(jax.jit, static_argnames=("max_levels",))
def _route_jit(tree, backend, rows, levels, max_levels):
    return _route_descend(tree, backend, rows, levels, max_levels)


def route(
    tree: KTree, x, levels: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Descend ``levels`` internal levels from the root.

    ``x``: dense array, Csr, or a backend instance. Returns (leaf_ids i32[B],
    path_nodes i32[levels, B], path_slots i32[levels, B]). ``levels = depth-1``
    reaches the leaf level (the tree is height-balanced, so every query
    descends the same number of steps). Compilation is bucketed: one compile
    per power-of-two level count, not one per depth."""
    backend = make_backend(x)
    rows = jnp.arange(backend.n_docs, dtype=jnp.int32)
    leaf, pn, ps = _route_jit(
        tree, backend, rows, jnp.int32(levels), max_levels=_levels_bucket(levels)
    )
    return leaf, pn[:levels], ps[:levels]


@jax.jit
def _nearest_in_leaf_backend(
    tree: KTree, leaf_ids: jax.Array, backend: VectorBackend, rows: jax.Array
):
    """(doc_id i32[B], sqdist f32[B]) — exact NN among the reached leaf's
    vectors, for any backend."""
    c = tree.centers[leaf_ids]                                   # [B, m1, d]
    c_sq = jnp.einsum("bmd,bmd->bm", c, c)
    diff_sq = c_sq - 2.0 * backend.cross_nodes(rows, c)
    valid = jnp.arange(tree.slots)[None, :] < tree.n_entries[leaf_ids][:, None]
    diff_sq = jnp.where(valid, diff_sq, jnp.inf)
    slot = jnp.argmin(diff_sq, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(diff_sq, slot[:, None], 1)[:, 0] + backend.row_sq(rows)
    return tree.child[leaf_ids, slot], jnp.maximum(best, 0.0)


def nearest_in_leaf(tree: KTree, leaf_ids: jax.Array, x: jax.Array):
    """(doc_id i32[B], sqdist f32[B]) — dense-query convenience wrapper."""
    backend = make_backend(x)
    rows = jnp.arange(backend.n_docs, dtype=jnp.int32)
    return _nearest_in_leaf_backend(tree, leaf_ids, backend, rows)


# ---------------------------------------------------------------------------
# batched insertion wave
# ---------------------------------------------------------------------------

def _group_rank(leaf_ids: jax.Array) -> jax.Array:
    """rank of each element within its equal-leaf group (stable, 0-based)."""
    b = leaf_ids.shape[0]
    perm = jnp.argsort(leaf_ids, stable=True)
    sorted_leaf = leaf_ids[perm]
    first = jnp.searchsorted(sorted_leaf, sorted_leaf, side="left")
    rank_sorted = jnp.arange(b, dtype=jnp.int32) - first.astype(jnp.int32)
    return jnp.zeros((b,), jnp.int32).at[perm].set(rank_sorted)


@functools.partial(jax.jit, static_argnames=("max_levels",))
def _insert_wave(
    tree: KTree,
    backend: VectorBackend,
    rows: jax.Array,
    doc_ids: jax.Array,
    valid: jax.Array,
    levels: jax.Array,
    max_levels: int,
) -> Tuple[KTree, jax.Array]:
    """One insertion wave at the current tree shape.

    Routes every (valid) backend row to its leaf, accepts per-leaf up to the
    m+1 overflow capacity, applies the paper's weighted-mean updates along the
    accepted paths (dense mode), and appends accepted vectors to leaves —
    densifying *only this wave's rows* via the backend. Returns
    (tree, accepted bool[B]). Callers split overflowing nodes and loop until
    nothing is pending (see :func:`build`)."""
    m1 = tree.slots
    nmax = tree.max_nodes
    leaf_ids, path_nodes, path_slots = _route_descend(
        tree, backend, rows, levels, max_levels
    )

    # ---- acceptance: per leaf, up to (m+1 − n_entries) new vectors this wave.
    # Invalid (already-inserted / padding) vectors must not consume capacity:
    # park them in a sentinel group before ranking.
    rank = _group_rank(jnp.where(valid, leaf_ids, nmax))
    free = (m1 - tree.n_entries[leaf_ids]).astype(jnp.int32)
    accepted = jnp.logical_and(valid, rank < free)

    # the only densification point: one wave's worth of rows
    x = backend.take(rows).astype(tree.centers.dtype)

    # ---- path mean updates for accepted vectors (dense K-tree only)
    if not tree.medoid:
        centers, counts = tree.centers, tree.counts
        for l in range(max_levels):
            upd = jnp.logical_and(accepted, jnp.asarray(l, jnp.int32) < levels)
            wa = upd.astype(x.dtype)
            n_l, s_l = path_nodes[l], path_slots[l]
            n_safe = jnp.where(upd, n_l, nmax)  # OOB rows are dropped
            sum_x = jnp.zeros_like(centers).at[n_safe, s_l].add(x * wa[:, None])
            cnt = jnp.zeros_like(counts).at[n_safe, s_l].add(wa)
            new_counts = counts + cnt
            centers = jnp.where(
                (cnt > 0)[..., None],
                (centers * counts[..., None] + sum_x) / jnp.maximum(new_counts, 1e-12)[..., None],
                centers,
            )
            counts = new_counts
        tree = dataclasses.replace(tree, centers=centers, counts=counts)

    # ---- leaf append
    slot = tree.n_entries[leaf_ids] + rank
    leaf_safe = jnp.where(accepted, leaf_ids, nmax)
    centers = tree.centers.at[leaf_safe, slot].set(x)
    counts = tree.counts.at[leaf_safe, slot].set(1.0)
    child = tree.child.at[leaf_safe, slot].set(doc_ids.astype(jnp.int32))
    n_entries = tree.n_entries.at[leaf_safe].add(accepted.astype(jnp.int32))
    tree = dataclasses.replace(
        tree, centers=centers, counts=counts, child=child, n_entries=n_entries
    )
    return tree, accepted


# ---------------------------------------------------------------------------
# node split (k-means k=2) + promotion — the B+-tree machinery
# ---------------------------------------------------------------------------

class _SplitParts(NamedTuple):
    """Pure per-node split computation (no tree writes) — shared by the scalar
    root split and the batched same-height split."""
    left_centers: jax.Array   # [m1, d]
    left_counts: jax.Array    # [m1]
    left_child: jax.Array     # [m1]
    n_left: jax.Array         # i32[]
    right_centers: jax.Array  # [m1, d]
    right_counts: jax.Array   # [m1]
    right_child: jax.Array    # [m1]
    n_right: jax.Array        # i32[]
    mean_l: jax.Array         # [d] promoted centre (mean or exemplar)
    mean_r: jax.Array         # [d]
    w_l: jax.Array            # f32[] promoted weight
    w_r: jax.Array            # f32[]


def _split_parts(
    key: jax.Array,
    e_centers: jax.Array,
    e_counts: jax.Array,
    e_child: jax.Array,
    n_e: jax.Array,
    medoid: bool,
) -> _SplitParts:
    """2-means an overflowing node's entries and partition them into the
    (stay, move) halves plus the two promoted summaries."""
    m1 = e_centers.shape[0]
    validm = jnp.arange(m1) < n_e

    w = jnp.where(validm, jnp.ones_like(e_counts) if medoid else e_counts, 0.0)
    # n_init=2: one retry guards against a degenerate k-means++ draw without
    # doubling the split cascade's cost the way the standalone default would
    res = kmeans(key, e_centers, 2, w=w, max_iters=50, init="kmeanspp", n_init=2)
    grp = res.assign.astype(jnp.int32)

    # enforce two non-empty groups (degenerate data / identical vectors)
    n1 = jnp.sum(jnp.where(validm, grp, 0))
    n0 = n_e - n1
    d_to_c0 = jnp.sum((e_centers - res.centers[0]) ** 2, axis=1)
    far = jnp.argmax(jnp.where(validm, d_to_c0, -jnp.inf)).astype(jnp.int32)
    near = jnp.argmin(jnp.where(validm, d_to_c0, jnp.inf)).astype(jnp.int32)
    grp = jnp.where(n1 == 0, grp.at[far].set(1), grp)
    grp = jnp.where(n0 == 0, grp.at[near].set(0), grp)
    grp = jnp.where(validm, grp, 1)  # invalid slots sort to the right group tail

    # stable partition: group-0 entries first (stay), group-1 entries (move)
    perm = jnp.argsort(grp, stable=True)
    n_left = jnp.sum(jnp.where(validm, (grp == 0).astype(jnp.int32), 0))
    n_right = n_e - n_left
    p_centers, p_counts, p_child = e_centers[perm], e_counts[perm], e_child[perm]
    pos = jnp.arange(m1, dtype=jnp.int32)
    left_sel = pos < n_left
    right_sel = pos < n_right

    left_centers = jnp.where(left_sel[:, None], p_centers, 0.0)
    left_counts = jnp.where(left_sel, p_counts, 0.0)
    left_child = jnp.where(left_sel, p_child, -1)
    # right entries compacted to the front of the new node
    r_perm = jnp.where(pos + n_left < m1, pos + n_left, m1 - 1)
    right_centers = jnp.where(right_sel[:, None], p_centers[r_perm], 0.0)
    right_counts = jnp.where(right_sel, p_counts[r_perm], 0.0)
    right_child = jnp.where(right_sel, p_child[r_perm], -1)

    # subtree summaries to promote
    w_l = jnp.sum(left_counts)
    w_r = jnp.sum(right_counts)
    mean_l = jnp.sum(left_centers * left_counts[:, None], 0) / jnp.maximum(w_l, 1e-12)
    mean_r = jnp.sum(right_centers * right_counts[:, None], 0) / jnp.maximum(w_r, 1e-12)
    if medoid:
        # exemplar = nearest entry vector to each mean (k-medoids, paper §2)
        def exemplar(entry_c, sel, mean):
            d = jnp.sum((entry_c - mean) ** 2, axis=1)
            i = jnp.argmin(jnp.where(sel, d, jnp.inf))
            return entry_c[i]
        mean_l = exemplar(left_centers, left_sel, mean_l)
        mean_r = exemplar(right_centers, right_sel, mean_r)

    return _SplitParts(
        left_centers, left_counts, left_child, n_left,
        right_centers, right_counts, right_child, n_right,
        mean_l, mean_r, w_l, w_r,
    )


@jax.jit
def split_node(tree: KTree, node_id: jax.Array, key: jax.Array) -> KTree:
    """Split one overflowing node (n_entries == m+1) into two with 2-means and
    promote the two means (or exemplars, medoid mode) to the parent. The caller
    guarantees the parent has a free slot. This scalar path also handles the
    root split (the only split that grows the tree); same-height non-root
    splits go through :func:`split_nodes_batch`."""
    m1 = tree.slots
    nmax = tree.max_nodes
    node_id = jnp.asarray(node_id, jnp.int32)
    parts = _split_parts(
        key,
        tree.centers[node_id],
        tree.counts[node_id],
        tree.child[node_id],
        tree.n_entries[node_id],
        tree.medoid,
    )
    leaf = tree.is_leaf[node_id]
    new_id = tree.n_nodes
    pos = jnp.arange(m1, dtype=jnp.int32)

    centers = tree.centers.at[node_id].set(parts.left_centers).at[new_id].set(parts.right_centers)
    counts = tree.counts.at[node_id].set(parts.left_counts).at[new_id].set(parts.right_counts)
    child = tree.child.at[node_id].set(parts.left_child).at[new_id].set(parts.right_child)
    n_entries = tree.n_entries.at[node_id].set(parts.n_left).at[new_id].set(parts.n_right)
    is_leaf = tree.is_leaf.at[new_id].set(leaf)
    height = tree.height.at[new_id].set(tree.height[node_id])

    # children of an internal node follow their entries
    int_node = jnp.logical_not(leaf)
    lc_safe = jnp.where(
        jnp.logical_and(int_node, pos < parts.n_left), parts.left_child, nmax
    )
    rc_safe = jnp.where(
        jnp.logical_and(int_node, pos < parts.n_right), parts.right_child, nmax
    )
    parent = tree.parent.at[lc_safe].set(node_id).at[rc_safe].set(new_id)
    parent_slot = tree.parent_slot.at[lc_safe].set(pos).at[rc_safe].set(pos)

    is_root = tree.parent[node_id] < 0
    p_id = jnp.where(is_root, tree.n_nodes + 1, tree.parent[node_id])
    p_slot_l = jnp.where(is_root, 0, tree.parent_slot[node_id])
    p_slot_r = jnp.where(is_root, 1, tree.n_entries[p_id])

    centers = centers.at[p_id, p_slot_l].set(parts.mean_l).at[p_id, p_slot_r].set(parts.mean_r)
    counts = counts.at[p_id, p_slot_l].set(parts.w_l).at[p_id, p_slot_r].set(parts.w_r)
    child = child.at[p_id, p_slot_l].set(node_id).at[p_id, p_slot_r].set(new_id)
    n_entries = n_entries.at[p_id].set(jnp.where(is_root, 2, n_entries[p_id] + 1))
    is_leaf = is_leaf.at[p_id].set(jnp.where(is_root, False, is_leaf[p_id]))
    height = height.at[p_id].set(
        jnp.where(is_root, tree.height[node_id] + 1, height[p_id])
    )
    parent = parent.at[node_id].set(p_id).at[new_id].set(p_id)
    parent = parent.at[p_id].set(jnp.where(is_root, -1, parent[p_id]))
    parent_slot = parent_slot.at[node_id].set(p_slot_l).at[new_id].set(p_slot_r)

    return dataclasses.replace(
        tree,
        centers=centers,
        counts=counts,
        child=child,
        n_entries=n_entries,
        is_leaf=is_leaf,
        parent=parent,
        parent_slot=parent_slot,
        height=height,
        root=jnp.where(is_root, p_id, tree.root).astype(jnp.int32),
        n_nodes=tree.n_nodes + jnp.where(is_root, 2, 1).astype(jnp.int32),
        depth=jnp.where(is_root, tree.depth + 1, tree.depth).astype(jnp.int32),
    )


@jax.jit
def split_nodes_batch(
    tree: KTree, node_ids: jax.Array, valid: jax.Array, keys: jax.Array
) -> KTree:
    """Split a batch of overflowing *same-height, non-root* nodes in one jitted
    call: vmapped 2-means + one set of fused scatters.

    ``node_ids`` i32[S] (padding rows have ``valid=False``), ``keys`` [S]-batch
    of PRNG keys. Splits whose parent lacks free slots are deferred (their
    ``valid`` drops) — the driver loop picks them up after the parent itself
    splits. The caller must exclude the root (its split grows the tree; use
    :func:`split_node`)."""
    m1 = tree.slots
    nmax = tree.max_nodes
    node_ids = jnp.asarray(node_ids, jnp.int32)
    read = jnp.where(valid, node_ids, 0)                 # safe gather index
    p_id = tree.parent[read]                             # [S] ≥ 0 for valid rows
    p_read = jnp.maximum(p_id, 0)

    # per-parent capacity: rank splits sharing a parent; only the first
    # (m+1 − n_entries[parent]) proceed this round
    rank = _group_rank(jnp.where(valid, p_id, nmax))
    free = (m1 - tree.n_entries[p_read]).astype(jnp.int32)
    valid = jnp.logical_and(valid, rank < free)

    parts = jax.vmap(
        functools.partial(_split_parts, medoid=tree.medoid)
    )(
        keys,
        tree.centers[read],
        tree.counts[read],
        tree.child[read],
        tree.n_entries[read],
    )

    leaf = tree.is_leaf[read]                            # [S]
    new_id = (tree.n_nodes + jnp.cumsum(valid) - valid).astype(jnp.int32)
    node_safe = jnp.where(valid, node_ids, nmax)
    new_safe = jnp.where(valid, new_id, nmax)

    centers = tree.centers.at[node_safe].set(parts.left_centers).at[new_safe].set(parts.right_centers)
    counts = tree.counts.at[node_safe].set(parts.left_counts).at[new_safe].set(parts.right_counts)
    child = tree.child.at[node_safe].set(parts.left_child).at[new_safe].set(parts.right_child)
    n_entries = tree.n_entries.at[node_safe].set(parts.n_left).at[new_safe].set(parts.n_right)
    is_leaf = tree.is_leaf.at[new_safe].set(leaf)
    height = tree.height.at[new_safe].set(tree.height[read])

    # children of internal nodes follow their entries
    pos = jnp.arange(m1, dtype=jnp.int32)[None, :]       # [1, m1]
    ok = jnp.logical_and(valid, jnp.logical_not(leaf))[:, None]
    lc_safe = jnp.where(jnp.logical_and(ok, pos < parts.n_left[:, None]), parts.left_child, nmax)
    rc_safe = jnp.where(jnp.logical_and(ok, pos < parts.n_right[:, None]), parts.right_child, nmax)
    node_b = jnp.broadcast_to(node_ids[:, None], lc_safe.shape)
    new_b = jnp.broadcast_to(new_id[:, None], rc_safe.shape)
    pos_b = jnp.broadcast_to(pos, lc_safe.shape)
    parent = tree.parent.at[lc_safe].set(node_b).at[rc_safe].set(new_b)
    parent_slot = tree.parent_slot.at[lc_safe].set(pos_b).at[rc_safe].set(pos_b)

    # promotion: left keeps the node's (parent, slot); right appends after the
    # parent's current entries, ordered by the per-parent rank
    p_safe = jnp.where(valid, p_id, nmax)
    p_slot_l = tree.parent_slot[read]
    p_slot_r = tree.n_entries[p_read] + rank
    centers = centers.at[p_safe, p_slot_l].set(parts.mean_l).at[p_safe, p_slot_r].set(parts.mean_r)
    counts = counts.at[p_safe, p_slot_l].set(parts.w_l).at[p_safe, p_slot_r].set(parts.w_r)
    child = child.at[p_safe, p_slot_l].set(node_ids).at[p_safe, p_slot_r].set(new_id)
    n_entries = n_entries.at[p_safe].add(valid.astype(jnp.int32))
    parent = parent.at[node_safe].set(p_id).at[new_safe].set(p_id)
    parent_slot = parent_slot.at[node_safe].set(p_slot_l).at[new_safe].set(p_slot_r)

    return dataclasses.replace(
        tree,
        centers=centers,
        counts=counts,
        child=child,
        n_entries=n_entries,
        is_leaf=is_leaf,
        parent=parent,
        parent_slot=parent_slot,
        height=height,
        n_nodes=tree.n_nodes + jnp.sum(valid).astype(jnp.int32),
    )


_SPLIT_BATCH_CAP = 64  # bounds vmapped-kmeans memory (S · m1 · d fp32)


def _split_batch_size(n: int) -> int:
    """Pad split batches to powers of two so ``split_nodes_batch`` compiles
    once per bucket."""
    b = 1
    while b < n:
        b *= 2
    return min(b, _SPLIT_BATCH_CAP)


def _split_all_overflowing(tree: KTree, key: jax.Array) -> Tuple[KTree, jax.Array]:
    """Host control plane: split overflowing nodes shallowest (max height)
    first — all overflowing nodes of one height in a single jitted call — until
    the m-order invariant holds everywhere. Splitting top-down guarantees a
    parent has spare capacity before its children promote into it (splits that
    would overflow a full parent are deferred one round by the batch op)."""
    while True:
        n_nodes = int(tree.n_nodes)
        n_entries = np.asarray(tree.n_entries[:n_nodes])
        over = np.nonzero(n_entries > tree.order)[0]
        if over.size == 0:
            return tree, key
        root = int(tree.root)
        if n_entries[root] > tree.order:
            # the root split grows the tree — scalar path
            key, sub = jax.random.split(key)
            tree = split_node(tree, jnp.int32(root), sub)
            continue
        heights = np.asarray(tree.height[:n_nodes])[over]
        batch = over[heights == heights.max()][:_SPLIT_BATCH_CAP]
        size = _split_batch_size(batch.size)
        ids = np.zeros(size, np.int32)
        ids[: batch.size] = batch[:size]
        valid = np.arange(size) < batch.size
        key, sub = jax.random.split(key)
        tree = split_nodes_batch(
            tree, jnp.asarray(ids), jnp.asarray(valid), jax.random.split(sub, size)
        )


# ---------------------------------------------------------------------------
# build drivers
# ---------------------------------------------------------------------------

def build(
    x,
    order: int,
    key: Optional[jax.Array] = None,
    batch_size: int = 256,
    medoid: bool = False,
    max_nodes: Optional[int] = None,
    backend: str = "auto",
) -> KTree:
    """Online batched construction (paper §1 semantics; ``batch_size=1`` is the
    exact sequential algorithm). Host loop: waves of route→accept→insert, then
    the split cascade, until the batch is fully inserted.

    ``x``: dense f[N, d] array, a :class:`repro.sparse.Csr` corpus, or a
    prebuilt backend. ``backend``: "auto" follows the input layout; "sparse"
    builds the paper's sparse-document tree (§2 — typically with
    ``medoid=True``) even from a dense input; "dense" densifies a sparse
    input. A prebuilt ``backend.RandomProjBackend`` passes through and builds
    the Random Indexing tree (DESIGN.md §5.1): every wave routes, appends,
    and splits in the projected space, so ``tree.dim`` is the projection's
    ``out_dim``. The pending set between waves is derived from the fetched
    ``accepted`` mask — no extra device→host sync per wave."""
    be = make_backend(x, backend)
    n = be.n_docs
    if key is None:
        key = jax.random.PRNGKey(0)
    if max_nodes is None:
        max_nodes = suggested_max_nodes(n, order)
    tree = ktree_init(max_nodes, order, be.dim, medoid=medoid, dtype=jnp.float32)

    for start in range(0, n, batch_size):
        idx = np.arange(start, min(start + batch_size, n))
        pad = batch_size - idx.size
        ids_np = np.concatenate([idx, np.full(pad, -1)]).astype(np.int32)
        rows = jnp.asarray(np.where(ids_np >= 0, ids_np, 0))
        doc_ids = jnp.asarray(ids_np)
        valid_np = ids_np >= 0
        while valid_np.any():
            levels = int(tree.depth) - 1
            tree, accepted = _insert_wave(
                tree, be, rows, doc_ids, jnp.asarray(valid_np),
                jnp.int32(levels), max_levels=_levels_bucket(levels),
            )
            valid_np &= ~np.asarray(accepted)
            tree, key = _split_all_overflowing(tree, key)
    return tree


def build_from_store(
    store,
    order: int,
    key: Optional[jax.Array] = None,
    batch_size: int = 256,
    medoid: bool = False,
    max_nodes: Optional[int] = None,
    prefetch: Optional[int] = None,
    projection=None,
    tuned=None,
    profiler=NULL_PROFILER,
) -> KTree:
    """Streaming out-of-core build: insert an on-disk corpus batch-by-batch
    (paper §1: "this tree structure allows for efficient disk based
    implementations where space requirements exceed that of main memory";
    DESIGN.md §9).

    ``store``: a ``repro.core.store.CorpusStore`` (dense or ELL blocks) or a
    ``StoreSlice``. Each batch's rows are fetched from disk through the
    store's LRU block cache and materialised as a *batch-sized* backend — at
    any moment the resident state is the tree arrays (centroids + structure),
    one batch of document vectors, and the store's bounded block cache. The
    K-tree's incremental insert is what makes this possible: leaves absorb
    each batch and the split cascade runs on resident tree pages only.

    Runs the exact wave/split schedule of :func:`build` (same batching, same
    PRNG consumption), so the resulting tree is **bit-identical** to an
    in-memory ``build(corpus, ...)`` over the same corpus and arguments —
    tests pin this for both block layouts.

    ``prefetch ≥ 1`` moves each batch's disk read onto an async
    ``store.Prefetcher`` reader thread of that depth, so the next batch's
    block fetch overlaps the current batch's insert waves; the fetched rows
    (and hence the tree) are identical to the synchronous path.

    ``projection`` (a ``backend.RandomProjection``, DESIGN.md §5.1) builds
    the Random Indexing tree instead: store blocks stream once through the
    fixed-chunk ``project_corpus`` pass (the sparse corpus is never
    materialised — only the small ``f32[N, out_dim]`` projected matrix stays
    resident, which is the RI premise) and the build runs entirely in the
    projected space. Bit-identical to ``build(RandomProjBackend.wrap(corpus,
    projection), ...)`` over the same corpus, by the shared fixed projection
    granularity.

    ``prefetch=None`` resolves through ``tuned=`` (a ``TunedKnobs`` from the
    store's ``TUNE.json`` sidecar, DESIGN.md §11) and then the repo default
    0 — explicit values win, and the knob never changes the tree.
    ``profiler=`` records one ``"read"`` span per batch fetch and one
    ``"insert"`` span per batch's insert waves."""
    from repro.core.backend import RandomProjBackend, backend_from_rows

    _, _, prefetch = resolve_knobs(tuned, prefetch=prefetch)
    if projection is not None:
        be = RandomProjBackend.from_store(store, projection, prefetch=prefetch)
        return build(
            be, order=order, key=key, batch_size=batch_size, medoid=medoid,
            max_nodes=max_nodes,
        )
    n = store.n_docs
    if key is None:
        key = jax.random.PRNGKey(0)
    if max_nodes is None:
        max_nodes = suggested_max_nodes(n, order)
    tree = ktree_init(max_nodes, order, store.dim, medoid=medoid, dtype=jnp.float32)

    batches = []
    for start in range(0, n, batch_size):
        idx = np.arange(start, min(start + batch_size, n))
        pad = batch_size - idx.size
        batches.append(np.concatenate([idx, np.full(pad, -1)]).astype(np.int32))

    def fetch(ids_np):
        # padding rows fetch corpus row 0, exactly like build's safe gather
        with profiler.span("read"):
            return store.take_rows(np.where(ids_np >= 0, ids_np, 0))

    import contextlib

    with contextlib.ExitStack() as stack:
        if prefetch:
            from repro.core.store import Prefetcher

            # registered on the stack so a failing insert wave (or an
            # interrupt) stops the reader thread instead of leaking it
            fetched = stack.enter_context(
                Prefetcher(batches, fetch, depth=prefetch)
            )
        else:
            fetched = ((ids_np, fetch(ids_np)) for ids_np in batches)
        for ids_np, got in fetched:
            be = backend_from_rows(store, got)
            rows = jnp.arange(batch_size, dtype=jnp.int32)
            doc_ids = jnp.asarray(ids_np)
            valid_np = ids_np >= 0
            with profiler.span("insert"):
                while valid_np.any():
                    levels = int(tree.depth) - 1
                    tree, accepted = _insert_wave(
                        tree, be, rows, doc_ids, jnp.asarray(valid_np),
                        jnp.int32(levels), max_levels=_levels_bucket(levels),
                    )
                    valid_np &= ~np.asarray(accepted)
                    tree, key = _split_all_overflowing(tree, key)
    return tree


def insert(
    tree: KTree, x, doc_ids, key: Optional[jax.Array] = None
) -> KTree:
    """Incremental insertion into an existing tree (paper §5: "clusters can be
    produced incrementally ... easy updates as new documents arrive").

    ``x``: the new documents (dense array, Csr, or backend); ``doc_ids``: their
    global ids (−1 = padding)."""
    if key is None:
        key = jax.random.PRNGKey(1)
    be = make_backend(x)
    doc_ids = jnp.asarray(doc_ids, jnp.int32)
    rows = jnp.arange(be.n_docs, dtype=jnp.int32)
    valid_np = np.asarray(doc_ids) >= 0
    while valid_np.any():
        levels = int(tree.depth) - 1
        tree, accepted = _insert_wave(
            tree, be, rows, doc_ids, jnp.asarray(valid_np),
            jnp.int32(levels), max_levels=_levels_bucket(levels),
        )
        valid_np &= ~np.asarray(accepted)
        tree, key = _split_all_overflowing(tree, key)
    return tree


def insert_into_store(
    tree: KTree, store, x, key: Optional[jax.Array] = None, projection=None
) -> KTree:
    """Incremental insertion into a **store-backed** index (DESIGN.md §9):
    route the new documents into the tree *and* spill their vectors to the
    on-disk corpus, closing the out-of-core loop for ever-growing corpora
    (paper §5's incremental updates, without the corpus ever being resident).

    ``x`` (dense array / Csr / backend) is normalised once into the store's
    exact block layout (``backend.backend_for_store_layout`` — ELL rows re-laid
    at the store's ``nnz_max`` width), so the vectors the tree inserts and the
    vectors the store serves afterwards are bit-identical; the new documents
    take global ids ``[store.n_docs, store.n_docs + B)``. The tree insert runs
    first (a failure leaves the store untouched), then ``store.append`` fills
    the last block's padding tail, appends new block files, and atomically
    replaces the manifest — rotating ``manifest_hash``, so answer caches and
    ``save_index`` checkpoints keyed on the old token correctly invalidate.

    Returns the new tree; ``store`` (an open ``CorpusStore``) is mutated in
    place and immediately serves the grown corpus. Equivalence contract: the
    returned tree bit-matches ``insert`` of the same normalised rows into an
    in-memory shadow tree (property-tested for both layouts).

    ``projection`` (a ``backend.RandomProjection``, DESIGN.md §5.1): the
    store still appends the *original* normalised rows — the rescore
    representation — while the tree inserts their projection (the routing
    representation), keeping the RI index's two spaces in lockstep. The
    inserted projected rows bit-match
    ``RandomProjBackend.wrap(normalised_rows, projection)``'s, which is what
    the shadow-tree property test pins."""
    from repro.core.backend import RandomProjBackend, backend_for_store_layout

    be = backend_for_store_layout(store, x)
    n0 = store.n_docs
    doc_ids = np.arange(n0, n0 + be.n_docs, dtype=np.int32)
    ins = be if projection is None else RandomProjBackend.wrap(be, projection)
    tree = insert(tree, ins, doc_ids, key=key)
    store.append(be)
    return tree


# ---------------------------------------------------------------------------
# read APIs
# ---------------------------------------------------------------------------

def leaf_nodes(tree: KTree) -> np.ndarray:
    n = int(tree.n_nodes)
    is_leaf = np.asarray(tree.is_leaf[:n])
    ne = np.asarray(tree.n_entries[:n])
    return np.nonzero(np.logical_and(is_leaf, ne > 0))[0]


def extract_assignment(tree: KTree, n_docs: int) -> Tuple[np.ndarray, int]:
    """(cluster i32[n_docs], n_clusters) — cluster = compact id of the containing
    leaf (the paper's leaf-level clustering solution). Unseen docs get −1."""
    leaves = leaf_nodes(tree)
    child = np.asarray(tree.child)
    ne = np.asarray(tree.n_entries)
    out = np.full(n_docs, -1, np.int32)
    for ci, leaf in enumerate(leaves):
        docs = child[leaf, : ne[leaf]]
        out[docs] = ci
    return out, len(leaves)


def padded_chunk_rows(n: int, chunk: int):
    """Yield (rows_np, padded host row ids) slices covering [0, n): each
    chunk's ids padded (repeating the last row) to the next power-of-two
    bucket ≤ ``chunk`` — same bucketing trick as :func:`_levels_bucket`, so
    jitted consumers compile once per bucket instead of once per remainder
    size. Single source of truth for chunk slicing: the in-memory query path
    (:func:`chunked_query_rows`) and the store-backed path (DESIGN.md §9)
    both derive from it, which is what keeps their chunk shapes — and hence
    answers — bit-identical."""
    for s in range(0, n, chunk):
        rows_np = np.arange(s, min(s + chunk, n))
        pad = _levels_bucket(rows_np.size) - rows_np.size
        yield rows_np, np.concatenate([rows_np, np.full(pad, rows_np[-1])])


def chunked_query_rows(n: int, chunk: int):
    """Yield (rows_np, rows_dev i32) slices covering [0, n) for batched query
    consumers — :func:`padded_chunk_rows` with the padded ids placed on
    device."""
    for rows_np, padded in padded_chunk_rows(n, chunk):
        yield rows_np, jnp.asarray(padded.astype(np.int32))


def assign_via_tree(tree: KTree, x, chunk: int = 1024) -> np.ndarray:
    """Cluster new vectors by NN search to the leaf level (sampled K-tree path,
    paper §3: tree built on a sample classifies the full corpus). ``x`` may be
    dense, a Csr corpus, or a backend."""
    be = make_backend(x)
    leaves = leaf_nodes(tree)
    remap = np.full(tree.max_nodes, -1, np.int32)
    remap[leaves] = np.arange(leaves.size, dtype=np.int32)
    levels = int(tree.depth) - 1
    max_levels = _levels_bucket(levels)
    outs = []
    for rows_np, rows in chunked_query_rows(be.n_docs, chunk):
        leaf_ids, _, _ = _route_jit(
            tree, be, rows, jnp.int32(levels), max_levels=max_levels
        )
        outs.append(remap[np.asarray(leaf_ids)][: rows_np.size])
    return np.concatenate(outs)


def nn_search(tree: KTree, q) -> Tuple[np.ndarray, np.ndarray]:
    """Approximate NN doc ids for queries (the search-tree application).
    ``q`` may be dense vectors, a Csr matrix, or a backend.

    Thin ``beam=1, k=1`` wrapper over the query engine
    (:func:`repro.core.query.topk_search`) — use that directly for top-k
    results or wider beams. The pre-engine greedy descent is kept as
    :func:`nn_search_greedy` (golden baseline for the equivalence tests)."""
    from repro.core.query import topk_search

    doc, dist = topk_search(tree, q, k=1, beam=1)
    return doc[:, 0], dist[:, 0]


def nn_search_greedy(tree: KTree, q) -> Tuple[np.ndarray, np.ndarray]:
    """The original greedy single-path descent (1-NN): route to one leaf, then
    exact NN among that leaf's vectors. ``topk_search(beam=1, k=1)`` must
    reproduce this exactly; tests pin the equivalence."""
    be = make_backend(q)
    levels = int(tree.depth) - 1
    rows = jnp.arange(be.n_docs, dtype=jnp.int32)
    leaf_ids, _, _ = _route_jit(
        tree, be, rows, jnp.int32(levels), max_levels=_levels_bucket(levels)
    )
    doc, dist = _nearest_in_leaf_backend(tree, leaf_ids, be, rows)
    return np.asarray(doc), np.asarray(dist)


def level_centers(tree: KTree, level: int) -> np.ndarray:
    """Centres at a given level below the root (0 = root entries) — "a smaller
    number of clusters higher in the tree" (paper §4) and the §5 browsing API."""
    n = int(tree.n_nodes)
    nodes = [int(tree.root)]
    for _ in range(level):
        nxt = []
        child = np.asarray(tree.child[:n])
        ne = np.asarray(tree.n_entries[:n])
        leaf = np.asarray(tree.is_leaf[:n])
        for nd in nodes:
            if leaf[nd]:
                continue
            nxt.extend(child[nd, : ne[nd]].tolist())
        nodes = nxt
    cs, ne_all = np.asarray(tree.centers[:n]), np.asarray(tree.n_entries[:n])
    return np.concatenate([cs[nd, : ne_all[nd]] for nd in nodes], axis=0)


def check_invariants(tree: KTree, n_docs: Optional[int] = None, rtol: float = 1e-3):
    """Structural invariants (tests + post-build validation):
    1. every allocated node obeys 1 ≤ n_entries ≤ m (an internal root ≥ 2),
    2. leaves all sit at height 0 and the tree is height-balanced,
    3. parent/child pointers are mutually consistent (incl. root parent −1,
       root height == depth−1, is_leaf ⇔ height 0, child ids allocated),
    4. internal entry count == total weight of the child's entries,
    5. dense mode: internal entry centre ≈ weighted mean of child entries,
    6. every allocated node is reachable from the root and slots past
       n_entries are cleared (child −1, zero weight),
    7. every inserted doc appears in exactly one leaf slot, with in-range id.
    Raises AssertionError on violation."""
    n = int(tree.n_nodes)
    ne = np.asarray(tree.n_entries[:n])
    child = np.asarray(tree.child[:n])
    counts = np.asarray(tree.counts[:n])
    centers = np.asarray(tree.centers[:n])
    is_leaf = np.asarray(tree.is_leaf[:n])
    parent = np.asarray(tree.parent[:n])
    parent_slot = np.asarray(tree.parent_slot[:n])
    height = np.asarray(tree.height[:n])
    root = int(tree.root)

    assert parent[root] == -1 and parent_slot[root] == -1, "root has a parent"
    assert height[root] == int(tree.depth) - 1, (
        f"root height {height[root]} != depth-1 ({int(tree.depth) - 1})"
    )
    reachable = set()
    stack = [root]
    while stack:
        nd = stack.pop()
        reachable.add(nd)
        if not is_leaf[nd]:
            stack.extend(int(c) for c in child[nd, : ne[nd]])
    assert reachable == set(range(n)), (
        f"allocated nodes unreachable from root: {sorted(set(range(n)) - reachable)}"
    )
    for nd in sorted(reachable):
        assert 1 <= ne[nd] <= tree.order, f"node {nd}: {ne[nd]} entries (m={tree.order})"
        assert is_leaf[nd] == (height[nd] == 0), f"is_leaf/height mismatch at {nd}"
        assert (child[nd, ne[nd]:] == -1).all(), f"stale child ids past n_entries at {nd}"
        assert (counts[nd, ne[nd]:] == 0).all(), f"stale weights past n_entries at {nd}"
        if is_leaf[nd]:
            assert (counts[nd, : ne[nd]] == 1).all(), f"leaf {nd} entry weight != 1"
        if not is_leaf[nd]:
            if nd == root:
                assert ne[nd] >= 2, f"internal root has {ne[nd]} < 2 entries"
            for s in range(ne[nd]):
                c = int(child[nd, s])
                assert 0 <= c < n, f"child id {c} of {nd} not allocated"
                assert parent[c] == nd and parent_slot[c] == s, f"bad pointer {nd}->{c}"
                assert height[c] == height[nd] - 1, "height mismatch"
                if not tree.medoid:
                    # medoid centres/counts are frozen at split time (paper §2)
                    assert abs(counts[nd, s] - counts[c, : ne[c]].sum()) <= max(
                        rtol * counts[nd, s], 1e-2
                    ), f"count mismatch at {nd}:{s}"
                    w = counts[c, : ne[c]]
                    mean = (centers[c, : ne[c]] * w[:, None]).sum(0) / max(w.sum(), 1e-12)
                    err = np.abs(centers[nd, s] - mean).max()
                    scale = max(np.abs(mean).max(), 1e-3)
                    assert err <= max(rtol * scale, 1e-3), f"mean mismatch {nd}:{s} err={err}"
    leaf_heights = {height[nd] for nd in reachable if is_leaf[nd]}
    assert leaf_heights == {0}, f"unbalanced leaves: {leaf_heights}"
    if n_docs is not None:
        seen = np.zeros(n_docs, np.int32)
        for nd in reachable:
            if is_leaf[nd]:
                docs = child[nd, : ne[nd]]
                assert ((docs >= 0) & (docs < n_docs)).all(), (
                    f"leaf {nd} holds out-of-range doc ids {docs}"
                )
                np.add.at(seen, docs, 1)
        assert (seen == 1).all(), f"doc conservation broken: {np.unique(seen)}"
