"""Measured-overlap auto-tuner for the three pipeline knobs (DESIGN.md §11).

The store-backed serving paths expose three overlap knobs that were always
hand-tuned magic numbers: query ``pipeline=`` (dispatch-ahead depth),
store ``prefetch=`` (async reader-thread depth), and the query ``chunk``
size. This module replaces them with a measured decision, the way
sglang-jax's ``profile_dma_compute.py`` sweeps DMA buffer depths:

1. **sweep** — run a short probe workload (store-backed ``topk_search``
   over the first rows of the corpus) for every candidate
   ``(pipeline, prefetch, chunk)`` with a :class:`repro.core.profile.Profiler`
   attached, recording wall time and the *measured* read∩compute overlap;
2. **choose** — :func:`choose_knobs` picks the highest-QPS cell (ties
   break toward more measured overlap, then shallower depths), and keeps
   the depth-1 synchronous baseline when nothing beats it;
3. **cache** — the winner lands in a ``TUNE.json`` sidecar next to the
   store's blocks, keyed by the store's ``manifest_hash`` + layout +
   residency budget + backend — any manifest rotation (append, repair,
   regeneration) invalidates the whole sidecar.

Consumption: ``topk_search`` / ``topk_search_sharded`` /
``build_from_store`` / ``make_search_fn`` accept ``tuned=`` (a
:class:`TunedKnobs`) and resolve their knob defaults through
:func:`resolve_knobs` — **explicit knob values always win** over tuned
ones, and tuned values only ever change scheduling, never numerics, so
answers stay bit-identical (pinned in tests/test_autotune.py).
``serve.py --store --autotune`` wires the whole loop end to end.

Determinism: the sweep's measurement seam is injectable (``runner=``), so
the same store + the same synthetic timings produce byte-identical
``TUNE.json`` files — the sidecar carries no timestamps or host state.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core.profile import Profiler

TUNE_NAME = "TUNE.json"
TUNE_VERSION = 1

# repo-wide knob defaults (the values the un-tuned signatures used to
# hardcode) — resolve_knobs falls back here when neither an explicit value
# nor a tuned one is given
DEFAULT_CHUNK = 512
DEFAULT_PIPELINE = 2
DEFAULT_PREFETCH = 0

# default sweep grid: small on purpose — 12 cells of a short probe workload
DEFAULT_PIPELINES = (1, 2, 4)
DEFAULT_PREFETCHES = (0, 2)
DEFAULT_CHUNKS = (256, 512)


@dataclasses.dataclass(frozen=True)
class TunedKnobs:
    """One tuner decision: the three knob values plus the measurements that
    justified them. ``qps``/``baseline_qps`` are the probe workload's
    queries/s for the chosen cell and for the depth-1 synchronous baseline
    ``(pipeline=1, prefetch=0, chunk=DEFAULT_CHUNK)``; ``overlap_frac`` is
    measured read∩compute wall overlap as a fraction of the cell's total
    read time (0 = fully serialised, →1 = reads fully hidden)."""

    pipeline: int
    prefetch: int
    chunk: int
    qps: float = 0.0
    baseline_qps: float = 0.0
    overlap_frac: float = 0.0

    def to_dict(self) -> dict:
        """JSON-ready form (rounded so sidecars are replay-stable)."""
        return {
            "pipeline": int(self.pipeline),
            "prefetch": int(self.prefetch),
            "chunk": int(self.chunk),
            "qps": round(float(self.qps), 3),
            "baseline_qps": round(float(self.baseline_qps), 3),
            "overlap_frac": round(float(self.overlap_frac), 4),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TunedKnobs":
        """Inverse of :meth:`to_dict` (sidecar load)."""
        return cls(
            pipeline=int(d["pipeline"]), prefetch=int(d["prefetch"]),
            chunk=int(d["chunk"]), qps=float(d.get("qps", 0.0)),
            baseline_qps=float(d.get("baseline_qps", 0.0)),
            overlap_frac=float(d.get("overlap_frac", 0.0)),
        )


def resolve_knobs(
    tuned: Optional[TunedKnobs],
    chunk: Optional[int] = None,
    pipeline: Optional[int] = None,
    prefetch: Optional[int] = None,
) -> Tuple[int, int, int]:
    """Resolve the three knobs into concrete ints: an **explicitly passed
    value always wins**; ``None`` falls back to the tuned value, and with no
    tuner decision either, to the repo defaults (512 / 2 / 0) the untuned
    signatures always used. Returns ``(chunk, pipeline, prefetch)``."""
    if chunk is None:
        chunk = tuned.chunk if tuned is not None else DEFAULT_CHUNK
    if pipeline is None:
        pipeline = tuned.pipeline if tuned is not None else DEFAULT_PIPELINE
    if prefetch is None:
        prefetch = tuned.prefetch if tuned is not None else DEFAULT_PREFETCH
    return int(chunk), int(pipeline), int(prefetch)


def _store_of(store):
    """Unwrap a StoreSlice to its backing CorpusStore (sidecars live next
    to the blocks)."""
    return getattr(store, "store", store)


def layout_tag(store) -> str:
    """The store-layout half of a tune key: block kind + rows per block.

    The residency budget and backend complete the key (:func:`tune_key`);
    content identity rides the sidecar-level ``manifest_hash``, so a layout
    tag never needs to hash rows itself."""
    s = _store_of(store)
    return f"{s.kind}-blk{int(s.block_docs)}"


def tune_key(store, budget_bytes: Optional[int] = None,
             backend: str = "exact") -> str:
    """Sidecar entry key for one ``(store layout, budget, backend)`` tuple.

    ``budget_bytes`` defaults to the store's current cache budget;
    ``backend`` names the query route (``"exact"``, ``"rp<out_dim>"``, …) —
    the RP route's extra rescore stage can want different depths than the
    exact route over the same blocks."""
    s = _store_of(store)
    if budget_bytes is None:
        budget_bytes = s.cache.budget_bytes
    return f"{layout_tag(store)}:budget{int(budget_bytes)}:{backend}"


def sidecar_path(store) -> str:
    """Where the store's ``TUNE.json`` lives (inside the block directory)."""
    return os.path.join(_store_of(store).path, TUNE_NAME)


def save_tuned(store, knobs: TunedKnobs, budget_bytes: Optional[int] = None,
               backend: str = "exact") -> str:
    """Write (merge) one decision into the store's ``TUNE.json`` sidecar.

    The sidecar records the store's ``manifest_hash`` at write time; a
    sidecar whose recorded hash no longer matches is stale in its entirety
    (the blocks changed under it) and is overwritten, not merged. Returns
    the sidecar path. Output is byte-deterministic for identical inputs
    (sorted keys, no timestamps) — the determinism test relies on it."""
    s = _store_of(store)
    path = sidecar_path(store)
    blob = {"version": TUNE_VERSION, "manifest_hash": s.manifest_hash,
            "entries": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if (prev.get("version") == TUNE_VERSION
                    and prev.get("manifest_hash") == s.manifest_hash):
                blob["entries"] = dict(prev.get("entries", {}))
        except (OSError, ValueError):
            pass  # unreadable sidecar: rewrite from scratch
    blob["entries"][tune_key(store, budget_bytes, backend)] = knobs.to_dict()
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(blob, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return path


def load_tuned(store, budget_bytes: Optional[int] = None,
               backend: str = "exact") -> Optional[TunedKnobs]:
    """Read the cached decision for this ``(layout, budget, backend)`` key.

    Returns ``None`` when there is no sidecar, no matching entry, the file
    is unreadable, **or the store's ``manifest_hash`` has rotated** since
    the sidecar was written (append / fsck-repair / in-place regeneration)
    — a stale depth choice is harmless, but a stale *measurement* must
    never look authoritative."""
    path = sidecar_path(store)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            blob = json.load(f)
    except (OSError, ValueError):
        return None
    if blob.get("version") != TUNE_VERSION:
        return None
    if blob.get("manifest_hash") != _store_of(store).manifest_hash:
        return None
    entry = blob.get("entries", {}).get(
        tune_key(store, budget_bytes, backend)
    )
    if entry is None:
        return None
    try:
        return TunedKnobs.from_dict(entry)
    except (KeyError, TypeError, ValueError):
        return None


def choose_knobs(
    cells: Dict[Tuple[int, int, int], Tuple[float, float]],
    baseline: Tuple[int, int, int],
    n_queries: int,
) -> TunedKnobs:
    """The tuner's decision rule, as a pure function of measurements.

    ``cells`` maps ``(pipeline, prefetch, chunk)`` → ``(wall_s,
    overlap_frac)`` for every swept cell (the baseline must be one of
    them); ``n_queries`` converts wall time to QPS. Ranking: highest QPS
    wins; ties (exact, after float division) break toward **more measured
    overlap** — the knob setting that demonstrably hides its reads — then
    toward the shallowest ``(pipeline, prefetch, chunk)`` so we never pay
    queue depth that buys nothing. A cell that cannot beat the baseline's
    QPS loses to it (the baseline participates on equal terms), so the
    tuner degrades to the synchronous schedule rather than pessimising."""
    if baseline not in cells:
        raise ValueError(f"sweep must include the baseline cell {baseline}")

    def rank(item):
        (pipeline, prefetch, chunk), (wall_s, overlap) = item
        qps = n_queries / max(wall_s, 1e-12)
        return (-qps, -overlap, pipeline, prefetch, chunk)

    (pipeline, prefetch, chunk), (wall_s, overlap) = min(
        cells.items(), key=rank
    )
    base_wall, _ = cells[baseline]
    return TunedKnobs(
        pipeline=pipeline, prefetch=prefetch, chunk=chunk,
        qps=n_queries / max(wall_s, 1e-12),
        baseline_qps=n_queries / max(base_wall, 1e-12),
        overlap_frac=overlap,
    )


def measure_cell(
    tree, store, pipeline: int, prefetch: int, chunk: int,
    k: int = 10, beam: int = 4, n_queries: int = 128, repeats: int = 1,
    rp=None, rp_corpus=None,
    clock: Callable[[], float] = time.perf_counter,
) -> Tuple[float, float]:
    """Measure one sweep cell: run the probe workload (store-backed
    ``topk_search`` over the store's first ``n_queries`` rows) under a
    profiler and return ``(best wall_s over repeats, overlap_frac)``.

    ``overlap_frac`` is measured read∩compute wall overlap divided by total
    read time (see :meth:`repro.core.profile.Profiler.overlap_seconds`);
    best-of-``repeats`` wall time is the noise-robust choice for short
    probes (the same convention as benchmarks/query_throughput.py)."""
    from repro.core.query import topk_search

    s = _store_of(store)
    nq = min(int(n_queries), s.n_docs)
    q_view = s.view(0, nq)
    best_wall, overlap = float("inf"), 0.0
    for _ in range(max(int(repeats), 1)):
        prof = Profiler(clock=clock)
        t0 = clock()
        topk_search(
            tree, q_view, k=k, beam=beam, chunk=chunk, pipeline=pipeline,
            prefetch=prefetch, rp=rp, rp_corpus=rp_corpus, profiler=prof,
        )
        wall = clock() - t0
        if wall < best_wall:
            best_wall = wall
            read_s = prof.totals().get("read", {}).get("seconds", 0.0)
            overlap = (
                prof.overlap_seconds("read", "compute") / read_s
                if read_s > 0 else 0.0
            )
    return best_wall, overlap


def autotune_store_search(
    tree, store, *,
    k: int = 10, beam: int = 4,
    budget_bytes: Optional[int] = None, backend: str = "exact",
    pipelines: Sequence[int] = DEFAULT_PIPELINES,
    prefetches: Sequence[int] = DEFAULT_PREFETCHES,
    chunks: Sequence[int] = DEFAULT_CHUNKS,
    n_queries: int = 128, repeats: int = 2,
    rp=None, rp_corpus=None,
    runner: Optional[Callable[[int, int, int], Tuple[float, float]]] = None,
    sidecar: bool = True, force: bool = False,
    clock: Callable[[], float] = time.perf_counter,
) -> TunedKnobs:
    """Tune ``(pipeline, prefetch, chunk)`` for one (store layout, budget,
    backend) tuple, consulting/maintaining the ``TUNE.json`` sidecar.

    Flow: unless ``force``, a valid cached decision for :func:`tune_key` is
    returned straight from the sidecar. Otherwise every grid cell — plus
    the depth-1 synchronous baseline ``(1, 0, DEFAULT_CHUNK)`` — is
    measured with :func:`measure_cell` (or the injectable ``runner(pipeline,
    prefetch, chunk) → (wall_s, overlap_frac)``, the determinism-test /
    synthetic-timing seam), :func:`choose_knobs` picks, and the winner is
    written back (``sidecar=False`` skips persistence, e.g. for read-only
    store dirs). Depths never change numerics, so tuning is always
    answer-safe; only scheduling differs."""
    if not force:
        cached = load_tuned(store, budget_bytes, backend)
        if cached is not None:
            return cached
    if runner is None:
        def runner(pipeline, prefetch, chunk):
            return measure_cell(
                tree, store, pipeline, prefetch, chunk, k=k, beam=beam,
                n_queries=n_queries, repeats=repeats, rp=rp,
                rp_corpus=rp_corpus, clock=clock,
            )
    baseline = (1, 0, DEFAULT_CHUNK)
    grid = {baseline}
    for pipeline in pipelines:
        for prefetch in prefetches:
            for chunk in chunks:
                grid.add((int(pipeline), int(prefetch), int(chunk)))
    cells = {
        cell: runner(*cell) for cell in sorted(grid)
    }
    nq = min(int(n_queries), _store_of(store).n_docs)
    knobs = choose_knobs(cells, baseline, nq)
    if sidecar:
        save_tuned(store, knobs, budget_bytes, backend)
    return knobs
