"""Logical-axis sharding rules (MaxText-style).

Every tensor dim carries a logical name; a ``Rules`` table maps logical names to
mesh axes (or None = replicated). Models call :func:`constrain` at the
boundaries where the partitioning must change (e.g. Megatron-style sequence
parallelism: activations are seq-sharded between blocks, head/ff-sharded inside
them) so GSPMD emits exactly the collectives we price in the roofline.

The table is carried in a context var set by the launcher / dry-run so model
code never hard-codes mesh axis names.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis name → mesh axis (or tuple of axes, or None)."""

    table: Mapping[str, Axis]

    def spec(self, *logical: Optional[str]) -> P:
        axes = []
        used: set = set()
        for name in logical:
            ax = self.table.get(name) if name else None
            # one mesh axis may shard only one dim — later claims degrade to None
            if ax is None:
                axes.append(None)
                continue
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            free = tuple(a for a in flat if a not in used)
            used.update(free)
            axes.append(free if len(free) > 1 else (free[0] if free else None))
        return P(*axes)


# Default training rules for the production (pod, data, model) mesh. ``fsdp``
# shards big weights over the data axes (ZeRO-3); ``tensor`` is classic TP.
def make_rules(multi_pod: bool, **overrides: Axis) -> Rules:
    dp: Axis = ("pod", "data") if multi_pod else "data"
    table: dict = {
        "batch": dp,
        "seq": "model",          # sequence/context parallelism between blocks
        "act_embed": None,
        "act_heads": None,
        "act_ff": "model",       # inside-MLP activations
        "act_vocab": "model",
        "fsdp": "data",          # weight dim sharded ZeRO-style
        "tensor": "model",       # weight dim sharded Megatron-style
        "vocab": "model",
        "expert": "model",
        "kv_seq": "model",       # decode KV cache length
        "kv_seq_b1": ("data", "model") if not multi_pod else ("pod", "data", "model"),
        "edges": (dp, "model") if isinstance(dp, str) else (*dp, "model"),
        "nodes": None,
        "table_rows": ("data", "model") if not multi_pod else ("pod", "data", "model"),
        "cand": ("data", "model") if not multi_pod else ("pod", "data", "model"),
        "centers_k": "model",    # §Perf: cluster-centre set sharded over model
        "layers": None,
        "stage": None,
    }
    table.update(overrides)
    return Rules(table)


_RULES: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "sharding_rules", default=None
)
_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "sharding_mesh", default=None
)


@contextlib.contextmanager
def use_rules(rules: Rules, mesh: Optional[Mesh] = None):
    t1 = _RULES.set(rules)
    t2 = _MESH.set(mesh)
    try:
        yield
    finally:
        _RULES.reset(t1)
        _MESH.reset(t2)


def current_rules() -> Optional[Rules]:
    return _RULES.get()


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside a rules context
    (so models run unmodified in single-device tests)."""
    rules = _RULES.get()
    mesh = _MESH.get()
    if rules is None or mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules.spec(*logical))
    )


def spec_for(*logical: Optional[str]) -> P:
    rules = _RULES.get()
    if rules is None:
        return P()
    return rules.spec(*logical)


def named_sharding(mesh: Mesh, rules: Rules, *logical: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(*logical))
