"""Common NN layers in pure JAX (no flax): params are nested dicts of arrays;
every layer is (init, apply) pairs. Matmul-heavy ops take an optional
``dtype`` for bf16 compute with fp32 params/accumulation.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain


def dense_init(key, in_dim: int, out_dim: int, scale: Optional[float] = None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return jnp.ones((dim,), dtype)


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


def mlp_stack_init(key, dims: Sequence[int], dtype=jnp.float32):
    """[(w,b), ...] for a plain ReLU MLP with the given layer widths."""
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        params.append(
            {"w": dense_init(sub, a, b, dtype=dtype), "b": jnp.zeros((b,), dtype)}
        )
    return params


def mlp_stack_apply(params, x: jax.Array, act=jax.nn.relu, final_act: bool = False) -> jax.Array:
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    freqs = rope_freqs(x.shape[-1], theta)                      # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]                         # [..., seq, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention — chunked (flash-style) causal for train/prefill, cached for decode
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B,S,Hk,hd] → [B,S,Hk*n_rep,hd] (GQA head sharing)."""
    if n_rep == 1:
        return k
    b, s, hk, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hk, n_rep, hd)).reshape(
        b, s, hk * n_rep, hd
    )


def flash_attention(
    q: jax.Array,      # [B, Sq, H, hd]
    k: jax.Array,      # [B, Skv, Hk, hd]
    v: jax.Array,      # [B, Skv, Hk, hd]
    q_offset: jax.Array | int = 0,   # global position of q[0] (seq-sharded q)
    causal: bool = True,
    kv_chunk: int = 1024,
    unroll: bool = False,            # dry-run probes: unroll so cost_analysis
                                     # counts every KV chunk (scan bodies are
                                     # otherwise costed once)
) -> jax.Array:
    """Memory-O(chunk) causal attention: lax.scan over KV chunks with the
    online-softmax accumulator. Peak intermediate = [B,H,Sq,kv_chunk] instead of
    [B,H,Sq,Skv] — what makes the 32k-prefill cells fit (DESIGN §5)."""
    b, sq, h, hd = q.shape
    skv, hk = k.shape[1], k.shape[2]
    n_rep = h // hk
    kv_chunk = min(kv_chunk, skv)
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:  # internal padding; padded keys are masked below via k_pos >= skv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / np.sqrt(hd)

    kc = k.reshape(b, n_chunks, kv_chunk, hk, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, hk, hd).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(sq)                            # [Sq] global

    def step(carry, inp):
        m, l, o = carry                                          # [B,H,Sq],[B,H,Sq],[B,H,Sq,hd]
        kb, vb, c_idx = inp                                      # [B,ck,Hk,hd]
        kb = _repeat_kv(kb, n_rep)
        vb = _repeat_kv(vb, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        k_pos = c_idx * kv_chunk + jnp.arange(kv_chunk)          # [ck] global
        mask = k_pos[None, :] < skv                              # padded tail
        if causal:
            mask = jnp.logical_and(mask, q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (exp(-inf - -inf))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    o0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        step, (m0, l0, o0), (kc, vc, jnp.arange(n_chunks)),
        unroll=n_chunks if unroll else 1,
    )
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q.dtype)               # [B,Sq,H,hd]


def dense_attention(
    q: jax.Array,      # [B, Sq, H, hd]
    k: jax.Array,      # [B, Skv, Hk, hd]
    v: jax.Array,
    causal: bool = True,
) -> jax.Array:
    """Materialised-scores attention — the *training* path. Under full remat
    the [B,H,Sq,Skv] scores are transient in fwd and recomputed in bwd, which
    beats flash-scan's per-chunk VJP residuals at train seq lengths (the
    hypothesis→measure log for this choice is in EXPERIMENTS.md §Perf)."""
    b, sq, h, hd = q.shape
    n_rep = h // k.shape[2]
    kb = _repeat_kv(k, n_rep)
    vb = _repeat_kv(v, n_rep)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) / np.sqrt(hd)
    if causal:
        q_pos = jnp.arange(sq)
        k_pos = jnp.arange(kb.shape[1])
        s = jnp.where((q_pos[:, None] >= k_pos[None, :])[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), vb)
    return o


def flash_decode_attention(
    q: jax.Array,        # [B, 1, H, hd]
    k_cache: jax.Array,  # [B, S, Hk, hd]  (seq sharded over kv axes)
    v_cache: jax.Array,
    length: jax.Array,   # i32[] valid cache prefix
) -> jax.Array:
    """Flash-decoding (§Perf iteration 2): explicit shard_map over the cache's
    sharded seq axis — each shard computes a partial softmax over its KV slice
    and the combine is a tiny (pmax, psum) of per-query stats. Left to GSPMD,
    the einsum gets resharded onto kv-heads and the repeated-KV broadcast is
    *replicated* (measured 2.9 GiB/layer on qwen decode_32k).

    Requires an active sharding-rules context; callers fall back to
    :func:`decode_attention` otherwise."""
    from repro.models import sharding as sh
    from jax.sharding import PartitionSpec as P

    rules, mesh = sh.current_rules(), sh._MESH.get()
    b, s, hk, hd = k_cache.shape
    h = q.shape[2]
    kv_name = "kv_seq_b1" if b == 1 else "kv_seq"
    kv_ax = rules.table.get(kv_name)
    if kv_ax is None:
        return decode_attention(q, k_cache, v_cache, length)
    kv_axes = (kv_ax,) if isinstance(kv_ax, str) else tuple(kv_ax)
    b_ax = None if b == 1 else rules.table.get("batch")
    n_shards = 1
    for a in kv_axes:
        n_shards *= mesh.shape[a]
    s_loc = s // n_shards
    scale = 1.0 / np.sqrt(hd)

    def local(qb, kb, vb):
        # shard-local partial attention over [B_loc, S_loc]
        idx = jax.lax.axis_index(kv_axes)          # flattened shard id
        k_pos = idx * s_loc + jnp.arange(s_loc)
        kb_r = _repeat_kv(kb, h // hk)
        vb_r = _repeat_kv(vb, h // hk)
        sL = jnp.einsum("bqhd,bkhd->bhqk", qb, kb_r).astype(jnp.float32) * scale
        valid = (k_pos < length)[None, None, None, :]
        sL = jnp.where(valid, sL, -jnp.inf)
        m = sL.max(axis=-1)                         # [B,H,1]
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(jnp.isfinite(sL), jnp.exp(sL - m_safe[..., None]), 0.0)
        l = p.sum(axis=-1)
        o = jnp.einsum("bhqk,bkhd->bhqd", p, vb_r.astype(jnp.float32))
        # combine partial softmaxes across shards
        gm = jax.lax.pmax(m, kv_axes)
        gm_safe = jnp.where(jnp.isfinite(gm), gm, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - gm_safe), 0.0)
        L = jax.lax.psum(l * alpha, kv_axes)
        O = jax.lax.psum(o * alpha[..., None], kv_axes)
        out = O / jnp.maximum(L, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3).astype(qb.dtype)   # [B,1,H,hd]

    fn = shard_map_fn(
        local,
        mesh=mesh,
        in_specs=(
            P(b_ax, None, None, None),
            P(b_ax, kv_axes, None, None),
            P(b_ax, kv_axes, None, None),
        ),
        out_specs=P(b_ax, None, None, None),
    )
    return fn(q, k_cache, v_cache)


def shard_map_fn(f, mesh, in_specs, out_specs):
    try:
        sm = jax.shard_map
    except AttributeError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map as sm  # type: ignore
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)


def decode_attention(
    q: jax.Array,        # [B, 1, H, hd]
    k_cache: jax.Array,  # [B, S, Hk, hd]
    v_cache: jax.Array,  # [B, S, Hk, hd]
    length: jax.Array,   # i32[] or i32[B] — valid cache prefix
) -> jax.Array:
    """One-token attention against the cache. The cache seq dim may be sharded
    (flash-decoding): the max/sum reductions below become partial-reduce +
    tiny all-reduce under GSPMD."""
    b, s, hk, hd = k_cache.shape
    h = q.shape[2]
    kb = _repeat_kv(k_cache, h // hk)
    vb = _repeat_kv(v_cache, h // hk)
    scale = 1.0 / np.sqrt(hd)
    s_logits = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.broadcast_to(jnp.atleast_1d(length)[:, None], (b, s))
    s_logits = jnp.where(valid[:, None, None, :], s_logits, -jnp.inf)
    p = jax.nn.softmax(s_logits, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# cross-entropy with sharded vocab
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array, valid: Optional[jax.Array] = None):
    """Mean token cross-entropy, safe for a vocab-sharded last dim: the gold
    logit is selected with an iota-compare reduction (fusable, partial+psum
    under GSPMD) instead of take_along_axis (which would gather the shard)."""
    logits32 = logits.astype(jnp.float32)
    m = logits32.max(axis=-1, keepdims=True)
    z = jnp.log(jnp.sum(jnp.exp(logits32 - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(labels.dtype, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], logits32, 0.0), axis=-1)
    nll = z - gold
    if valid is None:
        return nll.mean()
    w = valid.astype(jnp.float32)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
