"""Decoder-only transformer LM (dense + MoE) in pure JAX.

Covers the five assigned LM architectures: GQA/MQA/MHA, optional QKV bias
(qwen), RoPE, SwiGLU, MoE with top-k routing (grok-1: 8e top-2, dbrx: 16e
top-4). Layers are scanned (stacked params) with configurable remat so the
48–64-layer configs lower to one compiled block × L — essential for the 512-way
dry-run compile.

Sharding is expressed through logical names (repro.models.sharding): batch→dp,
sequence→model between blocks (Megatron-SP / context parallelism), feed-forward
and vocab →model inside blocks, experts→model where E divides the axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.sharding import constrain


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # numerics / memory
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    remat: str = "full"            # "full" | "dots" | "none"
    kv_chunk: int = 1024
    flash_unroll: bool = False     # dry-run cost probes unroll the KV scan
    train_attn: str = "dense"      # "dense" (remat-friendly) | "flash"
    cache_update: str = "mask"     # "mask" | "dus" — §Perf iteration 1: a
                                   # dynamic_update_slice at a dynamic position
                                   # on the seq-sharded cache makes GSPMD
                                   # all-gather the cache every layer (measured
                                   # 2.9 GiB/layer on qwen decode_32k); the
                                   # iota-compare masked update is elementwise
                                   # and partitions cleanly.
    aux_loss_weight: float = 0.01

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (for 6·N·D roofline accounting)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab, self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.moe:
            ff = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            ff = 3 * d * f
        per_layer = attn + ff + 2 * d
        return self.n_layers * per_layer + 2 * v * d + d

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_ff = self.n_experts * 3 * d * f
        active_ff = self.top_k * 3 * d * f
        return self.n_params() - self.n_layers * (dense_ff - active_ff)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: LMConfig) -> Dict:
    ks = jax.random.split(key, 16)
    d, hd, lcount = cfg.d_model, cfg.hd, cfg.n_layers
    h, hk, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    dt = cfg.dtype

    def w(key, *shape, scale=None):
        scale = scale if scale is not None else 1.0 / np.sqrt(shape[-2])
        return (jax.random.normal(key, shape) * scale).astype(dt)

    layers: Dict[str, jax.Array] = {
        "attn_norm": jnp.ones((lcount, d), dt),
        "mlp_norm": jnp.ones((lcount, d), dt),
        "wq": w(ks[0], lcount, d, h * hd),
        "wk": w(ks[1], lcount, d, hk * hd),
        "wv": w(ks[2], lcount, d, hk * hd),
        "wo": w(ks[3], lcount, h * hd, d),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((lcount, h * hd), dt)
        layers["bk"] = jnp.zeros((lcount, hk * hd), dt)
        layers["bv"] = jnp.zeros((lcount, hk * hd), dt)
    if cfg.moe:
        e = cfg.n_experts
        layers["router"] = w(ks[4], lcount, d, e, scale=0.02)
        layers["w_gate"] = w(ks[5], lcount, e, d, f)
        layers["w_up"] = w(ks[6], lcount, e, d, f)
        layers["w_down"] = w(ks[7], lcount, e, f, d)
    else:
        layers["w_gate"] = w(ks[5], lcount, d, f)
        layers["w_up"] = w(ks[6], lcount, d, f)
        layers["w_down"] = w(ks[7], lcount, f, d)
    return {
        "embed": L.embed_init(ks[8], cfg.vocab, d, dtype=dt),
        "layers": layers,
        "final_norm": jnp.ones((d,), dt),
        "lm_head": w(ks[9], d, cfg.vocab, scale=1.0 / np.sqrt(d)),
    }


def param_logical_axes(cfg: LMConfig) -> Dict:
    lay = {
        "attn_norm": ("layers", None),
        "mlp_norm": ("layers", None),
        "wq": ("layers", "fsdp", "tensor"),
        "wk": ("layers", "fsdp", "tensor"),
        "wv": ("layers", "fsdp", "tensor"),
        "wo": ("layers", "tensor", "fsdp"),
    }
    if cfg.qkv_bias:
        lay.update({"bq": ("layers", "tensor"), "bk": ("layers", "tensor"), "bv": ("layers", "tensor")})
    if cfg.moe:
        lay.update({
            "router": ("layers", "fsdp", None),
            "w_gate": ("layers", "expert", "fsdp", "tensor"),
            "w_up": ("layers", "expert", "fsdp", "tensor"),
            "w_down": ("layers", "expert", "tensor", "fsdp"),
        })
    else:
        lay.update({
            "w_gate": ("layers", "fsdp", "tensor"),
            "w_up": ("layers", "fsdp", "tensor"),
            "w_down": ("layers", "tensor", "fsdp"),
        })
    return {
        # embed sharded on vocab ONLY: a 2-D-sharded operand defeats GSPMD's
        # gather partitioning and the whole table gets all-gathered (measured:
        # full bf16[V,D] + f32 grads replicated per device on grok-1)
        "embed": ("vocab", None),
        "layers": lay,
        "final_norm": (None,),
        "lm_head": (None, "vocab"),
    }


# ---------------------------------------------------------------------------
# MoE block — sort-based dispatch with static capacity (DESIGN §5)
# ---------------------------------------------------------------------------

def _moe_ffn(x: jax.Array, lp: Dict, cfg: LMConfig) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] → (out, aux_loss). Groups = batch rows (GShard groups);
    experts sharded over model when divisible (rules decide)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * s * k / e))

    logits = jnp.einsum("bsd,de->bse", x, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                    # [B,S,k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * Σ_e f_e · p_e
    me = probs.mean(axis=(0, 1))                            # [E]
    ce = jnp.zeros((e,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    # flatten choices: [B, S*k] token slots sorted by expert id per batch row
    flat_e = eidx.reshape(b, s * k)
    flat_gate = gate.reshape(b, s * k).astype(x.dtype)
    src = jnp.broadcast_to(jnp.arange(s)[:, None], (s, k)).reshape(s * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)        # [B, S*k]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    sorted_src = src[order]                                 # [B, S*k]
    # position within expert group (vectorised per row)
    first = jax.vmap(lambda r: jnp.searchsorted(r, r, side="left"))(sorted_e)
    pos = jnp.arange(s * k)[None, :] - first                # [B, S*k]
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, e * cap)   # OOB rows dropped

    # Per-row (vmapped) gathers/scatters keep index tensors at [S*k] — a
    # jnp.take_along_axis over the D axis would broadcast u32 indices to
    # [B, S*k, D] (measured 48–60 GiB unsharded in the 314B HLO; see
    # EXPERIMENTS.md §Perf hypothesis log). Batched single-dim gathers also
    # partition cleanly along the batch dim under GSPMD.
    def _row_dispatch(xr, dest_r, src_r):
        xb = xr[src_r]                                      # [S*k, D]
        return jnp.zeros((e * cap, d), x.dtype).at[dest_r].set(xb, mode="drop")

    buf = jax.vmap(_row_dispatch)(x, dest, sorted_src)      # [B, E*C, D]
    buf = buf.reshape(b, e, cap, d)
    buf = constrain(buf, "batch", "expert", None, None)

    hg = jnp.einsum("becd,edf->becf", buf, lp["w_gate"])
    hu = jnp.einsum("becd,edf->becf", buf, lp["w_up"])
    ho = jnp.einsum("becf,efd->becd", jax.nn.silu(hg) * hu, lp["w_down"])
    ho = constrain(ho, "batch", "expert", None, None)
    ho = ho.reshape(b, e * cap, d)

    gate_sorted = jnp.take_along_axis(flat_gate, order, axis=1)  # [B,S*k] (no D)

    def _row_combine(hor, dest_r, keep_r, gate_r, src_r):
        out_sorted = hor[jnp.minimum(dest_r, e * cap - 1)]  # [S*k, D]
        contrib = jnp.where(keep_r[:, None], out_sorted, 0.0) * gate_r[:, None]
        return jnp.zeros((s, d), x.dtype).at[src_r].add(contrib)

    out = jax.vmap(_row_combine)(ho, dest, keep, gate_sorted, sorted_src)
    return out, aux


def _dense_ffn(x: jax.Array, lp: Dict) -> jax.Array:
    hg = jnp.einsum("bsd,df->bsf", x, lp["w_gate"])
    hu = jnp.einsum("bsd,df->bsf", x, lp["w_up"])
    h = jax.nn.silu(hg) * hu
    h = constrain(h, "batch", "seq", "act_ff")
    return jnp.einsum("bsf,fd->bsd", h, lp["w_down"])


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block(x: jax.Array, lp: Dict, cfg: LMConfig, positions: jax.Array):
    b, s, d = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xa = L.rmsnorm(x, lp["attn_norm"])
    q = jnp.einsum("bsd,dh->bsh", xa, lp["wq"])
    k = jnp.einsum("bsd,dh->bsh", xa, lp["wk"])
    v = jnp.einsum("bsd,dh->bsh", xa, lp["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hk, hd)
    v = v.reshape(b, s, hk, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "act_heads", None)
    # context parallelism: K/V replicated over the seq axis for the local-Q ×
    # global-KV attention (all-gather of the small GQA KV)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    if cfg.train_attn == "dense":
        attn = L.dense_attention(q, k, v, causal=True)
    else:
        attn = L.flash_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk, unroll=cfg.flash_unroll)
    attn = attn.reshape(b, s, h * hd)
    x = x + jnp.einsum("bsh,hd->bsd", attn, lp["wo"])
    x = constrain(x, "batch", "seq", "act_embed")

    xm = L.rmsnorm(x, lp["mlp_norm"])
    if cfg.moe:
        ff, aux = _moe_ffn(xm, lp, cfg)
    else:
        ff, aux = _dense_ffn(xm, lp), jnp.float32(0.0)
    x = x + ff
    x = constrain(x, "batch", "seq", "act_embed")
    return x, aux


def _remat_wrap(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params: Dict, tokens: jax.Array, cfg: LMConfig) -> Tuple[jax.Array, jax.Array]:
    """tokens [B,S] → (logits [B,S,V], aux_loss). Scan over stacked layers."""
    b, s = tokens.shape
    from repro.models.vocab_parallel import embed_lookup

    tok_ax = (None if b == 1 else "batch", "seq")
    x = embed_lookup(params["embed"], tokens, tok_logical=tok_ax)
    x = constrain(x, "batch", "seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    block = _remat_wrap(
        lambda x, lp: _block(x, lp, cfg, positions), cfg
    )

    def scan_body(x, lp):
        x, aux = block(x, lp)
        return x, aux

    x, auxs = jax.lax.scan(scan_body, x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"])
    # Megatron vocab-parallel loss boundary: gather seq, shard vocab — the
    # head grad einsum then yields [D, V/shards] locally (a seq-sharded logits
    # layout makes the [D,V] head grad replicate; measured 2×3 GiB on grok-1)
    x = constrain(x, "batch", None, "act_embed")
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = constrain(logits, "batch", None, "act_vocab")
    return logits, auxs.sum()


def loss_fn(params: Dict, batch: Dict, cfg: LMConfig) -> jax.Array:
    logits, aux = forward(params, batch["tokens"], cfg)
    ce = L.softmax_xent(logits, batch["labels"])
    return ce + cfg.aux_loss_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int) -> Dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def cache_logical_axes(batch: int) -> Tuple:
    # batch=1 long-context cells shard the cache length over every axis
    kv = "kv_seq_b1" if batch == 1 else "kv_seq"
    b = None if batch == 1 else "batch"
    return (None, b, kv, None, None)


def block_prefill(x: jax.Array, lp: Dict, cfg: LMConfig, positions: jax.Array, max_seq: int):
    """One prefill layer: returns (x', padded per-layer KV). Public so the
    dry-run cost probe can price a single layer exactly (scan bodies are
    costed once by XLA's analysis)."""
    b, s, _ = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xa = L.rmsnorm(x, lp["attn_norm"])
    q = jnp.einsum("bsd,dh->bsh", xa, lp["wq"])
    k = jnp.einsum("bsd,dh->bsh", xa, lp["wk"])
    v = jnp.einsum("bsd,dh->bsh", xa, lp["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = L.apply_rope(q.reshape(b, s, h, hd), positions, cfg.rope_theta)
    k = L.apply_rope(k.reshape(b, s, hk, hd), positions, cfg.rope_theta)
    v = v.reshape(b, s, hk, hd)
    q = constrain(q, "batch", "seq", "act_heads", None)
    kg = constrain(k, "batch", None, None, None)
    vg = constrain(v, "batch", None, None, None)
    attn = L.flash_attention(q, kg, vg, causal=True, kv_chunk=cfg.kv_chunk, unroll=cfg.flash_unroll)
    x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, s, h * hd), lp["wo"])
    xm = L.rmsnorm(x, lp["mlp_norm"])
    ff = _moe_ffn(xm, lp, cfg)[0] if cfg.moe else _dense_ffn(xm, lp)
    x = constrain(x + ff, "batch", "seq", "act_embed")
    kv_pad = max_seq - s
    k_out = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    v_out = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    return x, {"k": k_out, "v": v_out}


def prefill(params: Dict, tokens: jax.Array, cfg: LMConfig, max_seq: int):
    """Full-sequence forward that also fills the KV cache. Returns
    (last-token logits [B,V], cache)."""
    b, s = tokens.shape
    from repro.models.vocab_parallel import embed_lookup

    x = embed_lookup(params["embed"], tokens, tok_logical=(None if b == 1 else "batch", "seq"))
    x = constrain(x, "batch", "seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def scan_body(x, lp):
        return block_prefill(x, lp, cfg, positions, max_seq)

    body = _remat_wrap(scan_body, cfg) if cfg.remat != "none" else scan_body
    x, cache = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x[:, -1], params["final_norm"])
    x = constrain(x, "batch", "act_embed")
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])
    cax = cache_logical_axes(b)
    cache = {
        "k": constrain(cache["k"], *cax),
        "v": constrain(cache["v"], *cax),
    }
    return constrain(logits, "batch", "act_vocab"), cache


def block_decode(x, lp, kc, vc, pos, positions, cfg: LMConfig, cax):
    """One decode layer (cache update + attention + FFN). Public for the
    dry-run cost probe."""
    b = x.shape[0]
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xa = L.rmsnorm(x, lp["attn_norm"])
    q = jnp.einsum("bsd,dh->bsh", xa, lp["wq"])
    k = jnp.einsum("bsd,dh->bsh", xa, lp["wk"])
    v = jnp.einsum("bsd,dh->bsh", xa, lp["wv"])
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = L.apply_rope(q.reshape(b, 1, h, hd), positions, cfg.rope_theta)
    k = L.apply_rope(k.reshape(b, 1, hk, hd), positions, cfg.rope_theta)
    v = v.reshape(b, 1, hk, hd)
    if cfg.cache_update == "mask":
        sel = (jnp.arange(kc.shape[1]) == pos)[None, :, None, None]
        kc = jnp.where(sel, k.astype(kc.dtype), kc)
        vc = jnp.where(sel, v.astype(vc.dtype), vc)
    else:  # "dus" — kept for the §Perf before/after measurement
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
    kc = constrain(kc, *cax[1:])
    vc = constrain(vc, *cax[1:])
    from repro.models.sharding import current_rules
    if current_rules() is not None:
        # §Perf iteration 2: explicit flash-decoding (partial softmax per
        # cache shard + tiny stat combine) instead of GSPMD's choice
        attn = L.flash_decode_attention(q, kc, vc, pos + 1)
    else:
        attn = L.decode_attention(q, kc, vc, pos + 1)
    x = x + jnp.einsum("bsh,hd->bsd", attn.reshape(b, 1, h * hd), lp["wo"])
    xm = L.rmsnorm(x, lp["mlp_norm"])
    ff = _moe_ffn(xm, lp, cfg)[0] if cfg.moe else _dense_ffn(xm, lp)
    return x + ff, kc, vc


def decode_step(params: Dict, cache: Dict, tokens: jax.Array, pos: jax.Array, cfg: LMConfig):
    """One decode step: tokens [B,1] at position ``pos`` (i32 scalar) against a
    cache of static max length. Returns (logits [B,V], new cache)."""
    b = tokens.shape[0]
    from repro.models.vocab_parallel import embed_lookup

    x = embed_lookup(params["embed"], tokens, tok_logical=(None if b == 1 else "batch", None))
    positions = jnp.full((b, 1), pos, jnp.int32)
    cax = cache_logical_axes(b)

    def scan_body(x, inp):
        lp, kc, vc = inp
        x, kc, vc = block_decode(x, lp, kc, vc, pos, positions, cfg, cax)
        return x, {"k": kc, "v": vc}

    x, new_cache = jax.lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"])
    )
    x = L.rmsnorm(x[:, 0], params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])
    new_cache = {
        "k": constrain(new_cache["k"], *cax),
        "v": constrain(new_cache["v"], *cax),
    }
    return constrain(logits, "batch", "act_vocab"), new_cache
