"""Vocab-parallel embedding (Megatron-LM style) via shard_map.

GSPMD's gather partitioner (CPU backend especially) falls back to replicating
a vocab-sharded embedding table for ``jnp.take`` — measured as a full
bf16[V,D] + fp32 grad copy per device on the 131k-vocab configs. The classic
fix is explicit: each shard masks ids outside its vocab range, looks up
locally, zero-fills, and psums over the vocab axis. The VJP is then a purely
local scatter-add into the local shard — no replicated [V,D] buffers anywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from repro.models import sharding as sh


def embed_lookup(
    embed: jax.Array, tokens: jax.Array, tok_logical=("batch", "seq")
) -> jax.Array:
    """tokens [B,S] → [B,S,D]. Uses the vocab-parallel path when a sharding
    rules context is active and the vocab axis is actually sharded; plain
    take() otherwise (single-device tests). ``tok_logical`` is the tokens'
    logical sharding (decode passes (batch, None) — a length-1 dim can't
    shard)."""
    rules = sh.current_rules()
    mesh = sh._MESH.get()
    if rules is None or mesh is None:
        return jnp.take(embed, tokens, axis=0)
    vocab_ax = rules.table.get("vocab")
    if vocab_ax is None:
        return jnp.take(embed, tokens, axis=0)
    vocab_ax = vocab_ax if isinstance(vocab_ax, str) else vocab_ax[0]
    n_shards = mesh.shape[vocab_ax]
    if embed.shape[0] % n_shards != 0:
        return jnp.take(embed, tokens, axis=0)
    vshard = embed.shape[0] // n_shards
    tok_spec = rules.spec(*tok_logical)

    fn = shard_map(
        lambda etab, toks: _local_lookup(etab, toks, vocab_ax, vshard),
        mesh=mesh,
        in_specs=(P(vocab_ax, None), tok_spec),
        out_specs=P(*(tuple(tok_spec) + (None,))),
        check_vma=False,
    )
    return fn(embed, tokens)


def _local_lookup(etab, toks, vocab_ax, vshard):
    idx = jax.lax.axis_index(vocab_ax)
    local = toks - idx * vshard
    ok = jnp.logical_and(local >= 0, local < vshard)
    x = jnp.take(etab, jnp.clip(local, 0, vshard - 1), axis=0)
    x = jnp.where(ok[..., None], x, jnp.zeros((), x.dtype))
    return jax.lax.psum(x, vocab_ax)
