"""RecSys architectures: DLRM (MLPerf config), Wide&Deep, BST, DIEN.

The hot path is the sparse embedding lookup over huge tables (10⁶–10⁸ rows).
JAX has no EmbeddingBag — it is built here from ``jnp.take`` +
``jax.ops.segment_sum`` (kernel_taxonomy §RecSys: "this IS part of the
system"). Tables are sharded row-wise over the whole mesh (logical axis
``table_rows``); GSPMD turns the gathers into partition-local lookups +
masked all-reduce.

``retrieval_score`` serves the ``retrieval_cand`` shape: one query against 10⁶
candidates as a sharded batched-dot (and the K-tree ANN path in
repro.core gives the paper-technique alternative — see examples/retrieval_ann).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.sharding import constrain


# MLPerf DLRM (Criteo 1TB) embedding table sizes — arXiv:1906.00091 / MLPerf.
MLPERF_TABLE_ROWS: Tuple[int, ...] = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                     # dlrm | wide_deep | bst | dien
    embed_dim: int
    table_rows: Tuple[int, ...]   # one entry per sparse field
    n_dense: int = 0
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    # sequence models
    seq_len: int = 0
    n_heads: int = 0
    n_blocks: int = 0
    gru_dim: int = 0
    n_context: int = 0            # non-sequence categorical fields
    unroll_gru: bool = False      # dry-run cost probes unroll the time scan
    dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.table_rows)

    def n_params(self) -> int:
        params = jax.eval_shape(lambda k: init_params(k, self), jax.random.PRNGKey(0))
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Single-hot lookup [..,] → [.., d]; table may be row-sharded."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(
    table: jax.Array,
    ids: jax.Array,            # i32[nnz] flat ids
    segments: jax.Array,       # i32[nnz] output row of each id
    n_out: int,
    weights: jax.Array | None = None,
    combiner: str = "sum",
) -> jax.Array:
    """EmbeddingBag(sum/mean) = gather + segment_sum (the manual construction)."""
    vecs = jnp.take(table, ids, axis=0)
    if weights is not None:
        vecs = vecs * weights[:, None]
    out = jax.ops.segment_sum(vecs, segments, num_segments=n_out)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(segments, jnp.float32), segments, num_segments=n_out)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _pad_rows(rows: int) -> int:
    """Row-sharded tables are padded to 512 (mesh-size) multiples so the
    NamedSharding divides evenly; ids never reference the padding."""
    if rows >= 100_000:
        return -(-rows // 512) * 512
    return rows


def _init_tables(key, cfg: RecsysConfig, dim: int) -> Dict[str, jax.Array]:
    tables = {}
    for t, rows in enumerate(cfg.table_rows):
        key, sub = jax.random.split(key)
        scale = 1.0 / np.sqrt(dim)
        tables[f"t{t}"] = (
            jax.random.uniform(sub, (_pad_rows(rows), dim), minval=-scale, maxval=scale)
        ).astype(cfg.dtype)
    return tables


def _tables_axes(cfg: RecsysConfig) -> Dict[str, Tuple]:
    return {f"t{t}": ("table_rows", None) for t in range(cfg.n_sparse)}


# ---------------------------------------------------------------------------
# DLRM (dot interaction)
# ---------------------------------------------------------------------------

def _init_dlrm(key, cfg: RecsysConfig) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_f = cfg.n_sparse + 1
    n_inter = n_f * (n_f - 1) // 2
    top_in = n_inter + cfg.bot_mlp[-1]
    return {
        "tables": _init_tables(k1, cfg, cfg.embed_dim),
        "bot": L.mlp_stack_init(k2, (cfg.n_dense,) + cfg.bot_mlp, cfg.dtype),
        "top": L.mlp_stack_init(k3, (top_in,) + cfg.top_mlp, cfg.dtype),
    }


def _dlrm_forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    dense = batch["dense"].astype(cfg.dtype)                  # [B,13]
    ids = batch["sparse_ids"]                                 # [B,26]
    b = dense.shape[0]
    bot = L.mlp_stack_apply(params["bot"], dense, final_act=True)   # [B,128]
    embs = [
        embedding_lookup(params["tables"][f"t{t}"], ids[:, t])
        for t in range(cfg.n_sparse)
    ]
    feats = jnp.stack([bot] + embs, axis=1)                   # [B,27,d]
    feats = constrain(feats, "batch", None, None)
    inter = jnp.einsum("bnd,bmd->bnm", feats, feats)          # [B,27,27]
    iu, ju = np.triu_indices(feats.shape[1], k=1)
    inter_flat = inter[:, iu, ju]                             # [B,351]
    top_in = jnp.concatenate([bot, inter_flat], axis=-1)
    return L.mlp_stack_apply(params["top"], top_in)[:, 0]     # logits [B]


# ---------------------------------------------------------------------------
# Wide & Deep
# ---------------------------------------------------------------------------

def _init_wide_deep(key, cfg: RecsysConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    deep_in = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    wide = {}
    for t, rows in enumerate(cfg.table_rows):
        k3, sub = jax.random.split(k3)
        wide[f"w{t}"] = (jax.random.normal(sub, (_pad_rows(rows), 1)) * 0.01).astype(cfg.dtype)
    return {
        "tables": _init_tables(k1, cfg, cfg.embed_dim),
        "deep": L.mlp_stack_init(k2, (deep_in,) + cfg.top_mlp + (1,), cfg.dtype),
        "wide": wide,
        "bias": jnp.zeros((), cfg.dtype),
    }


def _wide_deep_forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    ids = batch["sparse_ids"]                                 # [B,F]
    embs = [
        embedding_lookup(params["tables"][f"t{t}"], ids[:, t])
        for t in range(cfg.n_sparse)
    ]
    deep_in = jnp.concatenate(embs, axis=-1)
    if cfg.n_dense:
        deep_in = jnp.concatenate([deep_in, batch["dense"].astype(cfg.dtype)], -1)
    deep = L.mlp_stack_apply(params["deep"], deep_in)[:, 0]
    wide = sum(
        embedding_lookup(params["wide"][f"w{t}"], ids[:, t])[:, 0]
        for t in range(cfg.n_sparse)
    )
    return deep + wide + params["bias"]


# ---------------------------------------------------------------------------
# BST (Behavior Sequence Transformer)
# ---------------------------------------------------------------------------

def _init_bst(key, cfg: RecsysConfig) -> Dict:
    d = cfg.embed_dim
    ks = jax.random.split(key, 10)
    mlp_in = (cfg.seq_len + 1 + cfg.n_context) * d
    return {
        "tables": _init_tables(ks[0], cfg, d),               # t0=item, rest context
        "pos": L.embed_init(ks[1], cfg.seq_len + 1, d, dtype=cfg.dtype),
        "wq": L.dense_init(ks[2], d, d, dtype=cfg.dtype),
        "wk": L.dense_init(ks[3], d, d, dtype=cfg.dtype),
        "wv": L.dense_init(ks[4], d, d, dtype=cfg.dtype),
        "wo": L.dense_init(ks[5], d, d, dtype=cfg.dtype),
        "ln1": jnp.ones((d,), cfg.dtype),
        "ln2": jnp.ones((d,), cfg.dtype),
        "ffn": L.mlp_stack_init(ks[6], (d, 4 * d, d), cfg.dtype),
        "mlp": L.mlp_stack_init(ks[7], (mlp_in,) + cfg.top_mlp + (1,), cfg.dtype),
    }


def _bst_forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    d, h = cfg.embed_dim, cfg.n_heads
    hist = batch["hist_ids"]                                  # [B,S]
    target = batch["target_id"]                               # [B]
    b, s = hist.shape
    seq = jnp.concatenate([hist, target[:, None]], axis=1)    # [B,S+1]
    x = embedding_lookup(params["tables"]["t0"], seq) + params["pos"][None]
    x = constrain(x, "batch", None, None)
    # one post-LN transformer block (paper: n_blocks=1, heads=8)
    q = (x @ params["wq"]).reshape(b, s + 1, h, d // h)
    k = (x @ params["wk"]).reshape(b, s + 1, h, d // h)
    v = (x @ params["wv"]).reshape(b, s + 1, h, d // h)
    att = L.flash_attention(q, k, v, causal=False, kv_chunk=max(8, s + 1))
    x = L.rmsnorm(x + att.reshape(b, s + 1, d) @ params["wo"], params["ln1"])
    x = L.rmsnorm(x + L.mlp_stack_apply(params["ffn"], x), params["ln2"])
    feats = [x.reshape(b, (s + 1) * d)]
    if cfg.n_context:
        ctx = batch["context_ids"]                            # [B,n_context]
        feats += [
            embedding_lookup(params["tables"][f"t{t+1}"], ctx[:, t])
            for t in range(cfg.n_context)
        ]
    return L.mlp_stack_apply(params["mlp"], jnp.concatenate(feats, -1), act=lambda z: jax.nn.leaky_relu(z, 0.01))[:, 0]


# ---------------------------------------------------------------------------
# DIEN (GRU + AUGRU)
# ---------------------------------------------------------------------------

def _gru_init(key, in_dim, hid, dt):
    k1, k2 = jax.random.split(key)
    return {
        "wx": L.dense_init(k1, in_dim, 3 * hid, dtype=dt),
        "wh": L.dense_init(k2, hid, 3 * hid, dtype=dt),
        "b": jnp.zeros((3 * hid,), dt),
    }


def _gru_scan(p, x_seq: jax.Array, hid: int, att: jax.Array | None = None, unroll: bool = False):
    """x_seq [B,S,D] → (final state [B,hid], states [B,S,hid]). ``att`` [B,S]
    turns the update gate into AUGRU (DIEN): u ← a_t · u."""
    b = x_seq.shape[0]
    augru = att is not None

    def step(hprev, inp):
        xt, at = inp
        gx = xt @ p["wx"] + p["b"]                            # [B,3h]
        gh = hprev @ p["wh"]
        r = jax.nn.sigmoid(gx[:, :hid] + gh[:, :hid])
        u = jax.nn.sigmoid(gx[:, hid : 2 * hid] + gh[:, hid : 2 * hid])
        cand = jnp.tanh(gx[:, 2 * hid :] + (r * hprev) @ p["wh"][:, 2 * hid :])
        if augru:
            u = at[:, None] * u
        h = (1 - u) * hprev + u * cand
        return h, h

    xs = x_seq.transpose(1, 0, 2)                             # [S,B,D]
    ats = att.transpose(1, 0) if augru else jnp.zeros((xs.shape[0], b), x_seq.dtype)
    h0 = jnp.zeros((b, hid), x_seq.dtype)
    hT, hs = jax.lax.scan(step, h0, (xs, ats), unroll=xs.shape[0] if unroll else 1)
    return hT, hs.transpose(1, 0, 2)


def _init_dien(key, cfg: RecsysConfig) -> Dict:
    ks = jax.random.split(key, 6)
    d, g = cfg.embed_dim * 2, cfg.gru_dim                     # item+category pairs
    mlp_in = g + cfg.embed_dim * 2 + cfg.n_context * cfg.embed_dim
    return {
        "tables": _init_tables(ks[0], cfg, cfg.embed_dim),    # t0 item, t1 cat, rest ctx
        "gru1": _gru_init(ks[1], d, g, cfg.dtype),
        "gru2": _gru_init(ks[2], g, g, cfg.dtype),
        "att_w": L.dense_init(ks[3], g, d, dtype=cfg.dtype),
        "mlp": L.mlp_stack_init(ks[4], (mlp_in,) + cfg.top_mlp + (1,), cfg.dtype),
    }


def _dien_forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    hist_i = batch["hist_ids"]                                # [B,S]
    hist_c = batch["hist_cat_ids"]                            # [B,S]
    tgt_i, tgt_c = batch["target_id"], batch["target_cat_id"]
    emb_i = embedding_lookup(params["tables"]["t0"], hist_i)
    emb_c = embedding_lookup(params["tables"]["t1"], hist_c)
    x = jnp.concatenate([emb_i, emb_c], axis=-1)              # [B,S,2d]
    x = constrain(x, "batch", None, None)
    tgt = jnp.concatenate(
        [embedding_lookup(params["tables"]["t0"], tgt_i),
         embedding_lookup(params["tables"]["t1"], tgt_c)], axis=-1
    )                                                         # [B,2d]
    _, interest = _gru_scan(params["gru1"], x, cfg.gru_dim, unroll=cfg.unroll_gru)   # [B,S,g]
    att = jnp.einsum("bsg,gd,bd->bs", interest, params["att_w"], tgt)
    att = jax.nn.softmax(att, axis=-1)
    final, _ = _gru_scan(params["gru2"], interest, cfg.gru_dim, att=att, unroll=cfg.unroll_gru)
    feats = [final, tgt]
    if cfg.n_context:
        ctx = batch["context_ids"]
        feats += [
            embedding_lookup(params["tables"][f"t{t+2}"], ctx[:, t])
            for t in range(cfg.n_context)
        ]
    return L.mlp_stack_apply(params["mlp"], jnp.concatenate(feats, -1))[:, 0]


# ---------------------------------------------------------------------------
# dispatch + losses + retrieval
# ---------------------------------------------------------------------------

_FWD = {
    "dlrm": _dlrm_forward,
    "wide_deep": _wide_deep_forward,
    "bst": _bst_forward,
    "dien": _dien_forward,
}
_INIT = {
    "dlrm": _init_dlrm,
    "wide_deep": _init_wide_deep,
    "bst": _init_bst,
    "dien": _init_dien,
}


def init_params(key: jax.Array, cfg: RecsysConfig) -> Dict:
    return _INIT[cfg.kind](key, cfg)


def forward(params: Dict, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    return _FWD[cfg.kind](params, batch, cfg)


def loss_fn(params: Dict, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    """Binary cross-entropy on CTR labels."""
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def param_logical_axes(cfg: RecsysConfig) -> Dict:
    params = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))

    def leaf_axes(path, leaf) -> Tuple:
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "tables" in names or "wide" in names:
            # only genuinely large tables shard row-wise; tiny ones (some
            # MLPerf fields have 3 rows) replicate — sharding a 3-row table
            # over 512 devices is pure padding
            if leaf.ndim == 2 and leaf.shape[0] >= 100_000:
                return ("table_rows", None)
            return tuple(None for _ in leaf.shape)
        return tuple(None for _ in leaf.shape)

    return jax.tree_util.tree_map_with_path(leaf_axes, params)


def retrieval_score(params: Dict, query_vec: jax.Array, cand_table: jax.Array, topk: int = 100):
    """Score 1..B query vectors against n_cand candidate embeddings (sharded
    over `cand`) — batched dot + top-k, the `retrieval_cand` serving path."""
    cand_table = constrain(cand_table, "cand", None)
    scores = query_vec @ cand_table.T                          # [B, n_cand]
    return jax.lax.top_k(scores, topk)


def user_embedding(params: Dict, batch: Dict, cfg: RecsysConfig) -> jax.Array:
    """A user-tower vector for retrieval (two-tower style): model-specific
    pooling of its non-candidate features."""
    if cfg.kind == "dlrm":
        return L.mlp_stack_apply(params["bot"], batch["dense"].astype(cfg.dtype), final_act=True)
    if cfg.kind == "wide_deep":
        ids = batch["sparse_ids"]
        embs = [embedding_lookup(params["tables"][f"t{t}"], ids[:, t]) for t in range(min(4, cfg.n_sparse))]
        return sum(embs)
    # sequence models: mean of history item embeddings
    emb = embedding_lookup(params["tables"]["t0"], batch["hist_ids"])
    return emb.mean(axis=1)
