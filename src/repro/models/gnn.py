"""DimeNet (directional message passing GNN, arXiv:2003.03123) in pure JAX.

Message-passing regime: *triplet gather* (not SpMM) — messages live on directed
edges m_ji, and the interaction blocks aggregate over triplets (k→j→i) with a
radial (Bessel) × angular (Legendre) basis and an n_bilinear-factorised
bilinear layer. All aggregation is `jnp.take` + `jax.ops.segment_sum` (JAX has
no sparse message-passing — building it IS the substrate, kernel_taxonomy §GNN).

Graph layout: one flat (possibly batched) graph —
  feats/z [N], pos [N,3], edge_index i32[2,E] (row 0 = target i, row 1 = source j),
  triplets i32[2,T] (row 0 = edge id kj, row 1 = edge id ji, sharing node j),
  graph_id i32[N] for per-graph readout.
Non-molecular datasets (cora/reddit/products) carry d_feat node features and a
stub `pos` input (DESIGN §4); triplets are capped per edge by the sampler.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.sharding import constrain


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_atom_types: int = 95          # molecular mode
    d_feat: int = 0                 # >0 → feature mode (non-molecular graphs)
    n_classes: int = 0              # >0 → node classification readout
    dtype: Any = jnp.float32

    def n_params(self) -> int:
        params = init_params(jax.random.PRNGKey(0), self, _abstract=True)
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def _dense(key, i, o, dt):
    return {"w": L.dense_init(key, i, o, dtype=dt), "b": jnp.zeros((o,), dt)}


def init_params(key: jax.Array, cfg: DimeNetConfig, _abstract: bool = False) -> Dict:
    if _abstract:
        return jax.eval_shape(lambda k: init_params(k, cfg), key)
    dt = cfg.dtype
    h, nb = cfg.d_hidden, cfg.n_bilinear
    nsr = cfg.n_spherical * cfg.n_radial
    ks = iter(jax.random.split(key, 8 + 6 * cfg.n_blocks))

    if cfg.d_feat > 0:
        node_in = _dense(next(ks), cfg.d_feat, h, dt)
    else:
        node_in = {"emb": L.embed_init(next(ks), cfg.n_atom_types, h, dtype=dt)}

    params: Dict = {
        "node_in": node_in,
        "rbf_proj": _dense(next(ks), cfg.n_radial, h, dt),
        "embed_mlp": _dense(next(ks), 3 * h, h, dt),
        "blocks": [],
        "out_rbf": _dense(next(ks), cfg.n_radial, h, dt),
        "head": _dense(
            next(ks), h, cfg.n_classes if cfg.n_classes else 1, dt
        ),
    }
    for _ in range(cfg.n_blocks):
        params["blocks"].append(
            {
                "msg_mlp": _dense(next(ks), h, h, dt),
                "w_bil_m": L.dense_init(next(ks), h, nb, dtype=dt),
                "w_bil_s": L.dense_init(next(ks), nsr, nb, dtype=dt),
                "w_bil_o": L.dense_init(next(ks), nb, h, dtype=dt),
                "upd_mlp": _dense(next(ks), h, h, dt),
            }
        )
    return params


def param_logical_axes(cfg: DimeNetConfig) -> Dict:
    def dn(_):
        return {"w": (None, None), "b": (None,)}

    blocks = [
        {
            "msg_mlp": dn(0), "w_bil_m": (None, None), "w_bil_s": (None, None),
            "w_bil_o": (None, None), "upd_mlp": dn(0),
        }
        for _ in range(cfg.n_blocks)
    ]
    node_in = {"w": (None, None), "b": (None,)} if cfg.d_feat > 0 else {"emb": (None, None)}
    return {
        "node_in": node_in, "rbf_proj": dn(0), "embed_mlp": dn(0),
        "blocks": blocks, "out_rbf": dn(0), "head": dn(0),
    }


def _apply_dense(p, x, act=jax.nn.silu):
    return act(x @ p["w"] + p["b"])


def _bessel_rbf(d: jax.Array, n_radial: int, cutoff: float) -> jax.Array:
    """DimeNet radial basis: sin(nπ d/c)/d, smooth-enveloped."""
    d = jnp.maximum(d, 1e-6)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    env = 1.0 - (d / cutoff) ** 2  # polynomial envelope (p=2 simplification)
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d * jnp.maximum(env, 0.0)


def _legendre(cos_t: jax.Array, n: int) -> jax.Array:
    """P_0..P_{n-1}(cos θ) by recurrence — the angular basis."""
    p0 = jnp.ones_like(cos_t)
    if n == 1:
        return p0[:, None]
    polys = [p0, cos_t]
    for l in range(2, n):
        polys.append(((2 * l - 1) * cos_t * polys[-1] - (l - 1) * polys[-2]) / l)
    return jnp.stack(polys[:n], axis=1)


def forward(params: Dict, batch: Dict, cfg: DimeNetConfig) -> jax.Array:
    """Returns per-graph energy [G] (molecular) or node logits [N, n_classes]."""
    pos = batch["pos"].astype(jnp.float32)                    # [N,3]
    ei = batch["edge_index"]                                  # [2,E] (i ← j)
    tri = batch["triplets"]                                   # [2,T] (kj, ji)
    n_nodes = pos.shape[0]
    i, j = ei[0], ei[1]

    # node embeddings
    if cfg.d_feat > 0:
        hnode = _apply_dense(params["node_in"], batch["feats"].astype(cfg.dtype))
    else:
        hnode = jnp.take(params["node_in"]["emb"], batch["z"], axis=0)

    # edge geometry
    vec = pos[i] - pos[j]                                     # [E,3]
    dist = jnp.sqrt(jnp.maximum((vec * vec).sum(-1), 1e-12))  # [E]
    rbf = _bessel_rbf(dist, cfg.n_radial, cfg.cutoff).astype(cfg.dtype)
    rbf_h = _apply_dense(params["rbf_proj"], rbf)             # [E,H]

    # triplet geometry: angle between edge kj (k→j) and edge ji (j→i)
    kj, ji = tri[0], tri[1]
    v1 = -jnp.take(vec, kj, axis=0)                           # j→k reversed: k→j
    v2 = jnp.take(vec, ji, axis=0)
    cos_t = (v1 * v2).sum(-1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9
    )
    ang = _legendre(jnp.clip(cos_t, -1.0, 1.0), cfg.n_spherical)      # [T,S]
    rad_kj = jnp.take(rbf, kj, axis=0)                                # [T,R]
    sbf = (ang[:, :, None] * rad_kj[:, None, :]).reshape(ang.shape[0], -1)
    sbf = sbf.astype(cfg.dtype)                                       # [T,S·R]

    # embedding block: m_ji = MLP([h_j, h_i, rbf])
    m = _apply_dense(
        params["embed_mlp"], jnp.concatenate([hnode[j], hnode[i], rbf_h], axis=-1)
    )                                                                 # [E,H]
    m = constrain(m, "edges", None)

    node_acc = jnp.zeros((n_nodes, cfg.d_hidden), cfg.dtype)
    for blk in params["blocks"]:
        mt = _apply_dense(blk["msg_mlp"], m)
        # factorised bilinear: (m_kj W_m) ⊙ (sbf W_s) → W_o, summed over k
        t_m = jnp.take(mt, kj, axis=0) @ blk["w_bil_m"]               # [T,nb]
        t_s = sbf @ blk["w_bil_s"]                                    # [T,nb]
        t = (t_m * t_s) @ blk["w_bil_o"]                              # [T,H]
        agg = jax.ops.segment_sum(t, ji, num_segments=m.shape[0])     # [E,H]
        m = m + _apply_dense(blk["upd_mlp"], mt * rbf_h + agg)
        m = constrain(m, "edges", None)
        node_acc = node_acc + jax.ops.segment_sum(
            m * _apply_dense(params["out_rbf"], rbf), i, num_segments=n_nodes
        )

    node_acc = constrain(node_acc, "nodes", None)
    out = node_acc @ params["head"]["w"] + params["head"]["b"]
    if cfg.n_classes:
        return out                                                    # [N,classes]
    n_graphs = batch["n_graphs"]
    return jax.ops.segment_sum(out[:, 0], batch["graph_id"], num_segments=n_graphs)


def loss_fn(params: Dict, batch: Dict, cfg: DimeNetConfig) -> jax.Array:
    out = forward(params, batch, cfg)
    if cfg.n_classes:
        return L.softmax_xent(out, batch["labels"])
    return jnp.mean((out - batch["labels"].astype(jnp.float32)) ** 2)


# ---------------------------------------------------------------------------
# host-side graph utilities (triplet construction, neighbour sampling)
# ---------------------------------------------------------------------------

def build_triplets(edge_index: np.ndarray, max_per_edge: int = 4, seed: int = 0) -> np.ndarray:
    """Triplets (kj, ji): for each edge ji, sample ≤max_per_edge incoming edges
    kj at node j (k≠i). Capping is the large-graph adaptation (DESIGN §4)."""
    rng = np.random.default_rng(seed)
    i, j = edge_index
    e = i.shape[0]
    by_target: dict = {}
    for eid in range(e):
        by_target.setdefault(int(i[eid]), []).append(eid)
    kj_list, ji_list = [], []
    for eid in range(e):
        cands = [c for c in by_target.get(int(j[eid]), []) if int(j[c]) != int(i[eid])]
        if len(cands) > max_per_edge:
            cands = rng.choice(cands, max_per_edge, replace=False).tolist()
        for c in cands:
            kj_list.append(c)
            ji_list.append(eid)
    if not kj_list:
        return np.zeros((2, 1), np.int32)
    return np.stack([np.asarray(kj_list, np.int32), np.asarray(ji_list, np.int32)])


def neighbour_sample(
    csr_indptr: np.ndarray,
    csr_indices: np.ndarray,
    seeds: np.ndarray,
    fanouts: tuple,
    seed: int = 0,
):
    """Uniform fanout sampling (GraphSAGE-style) → (nodes, edge_index local).
    The real sampler for the ``minibatch_lg`` cell."""
    rng = np.random.default_rng(seed)
    nodes = list(seeds.tolist())
    known = {int(n): idx for idx, n in enumerate(nodes)}
    src_l, dst_l = [], []
    frontier = seeds
    for fo in fanouts:
        nxt = []
        for u in frontier:
            u = int(u)
            lo, hi = csr_indptr[u], csr_indptr[u + 1]
            if hi == lo:
                continue
            neigh = csr_indices[lo:hi]
            take = neigh if hi - lo <= fo else rng.choice(neigh, fo, replace=False)
            for v in take:
                v = int(v)
                if v not in known:
                    known[v] = len(nodes)
                    nodes.append(v)
                dst_l.append(known[u])
                src_l.append(known[v])
                nxt.append(v)
        frontier = np.asarray(nxt, np.int64) if nxt else np.zeros(0, np.int64)
    edge_index = np.stack(
        [np.asarray(dst_l, np.int32), np.asarray(src_l, np.int32)]
    ) if dst_l else np.zeros((2, 1), np.int32)
    return np.asarray(nodes, np.int64), edge_index
