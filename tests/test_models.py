"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned architecture runs one forward/train step on CPU; output shapes and
finiteness asserted. Full configs are exercised only via the dry-run."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer as T
from repro.models import gnn as G
from repro.models import recsys as R


def _finite(x):
    return bool(np.isfinite(np.asarray(x)).all())


# --- reduced LM configs mirroring each assigned arch's distinguishing traits
REDUCED_LM = {
    "qwen2.5-14b": dict(n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
                        d_ff=160, vocab=128, qkv_bias=True),
    "granite-20b": dict(n_layers=2, d_model=64, n_heads=8, n_kv_heads=1,
                        d_ff=256, vocab=96),
    "phi3-mini-3.8b": dict(n_layers=2, d_model=48, n_heads=8, n_kv_heads=8,
                           d_ff=128, vocab=64),
    "grok-1-314b": dict(n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
                        d_ff=128, vocab=128, moe=True, n_experts=4, top_k=2),
    "dbrx-132b": dict(n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
                      d_ff=96, vocab=128, moe=True, n_experts=8, top_k=4),
}


@pytest.mark.parametrize("arch", sorted(REDUCED_LM))
def test_lm_smoke(arch):
    cfg = T.LMConfig(name=arch, dtype=jnp.float32, kv_chunk=16, **REDUCED_LM[arch])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    logits, aux = T.forward(params, tokens, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert _finite(logits)
    loss = T.loss_fn(params, batch, cfg)
    assert _finite(loss) and float(loss) > 0
    grads = jax.grad(T.loss_fn)(params, batch, cfg)
    assert all(_finite(g) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", sorted(REDUCED_LM))
def test_lm_serve_smoke(arch):
    cfg = T.LMConfig(name=arch, dtype=jnp.float32, kv_chunk=16, **REDUCED_LM[arch])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, cache = T.prefill(params, tokens, cfg, max_seq=24)
    assert logits.shape == (2, cfg.vocab) and _finite(logits)
    assert cache["k"].shape == (cfg.n_layers, 2, 24, cfg.n_kv_heads, cfg.hd)
    l2, cache = T.decode_step(params, cache, tokens[:, :1], jnp.int32(16), cfg)
    assert l2.shape == (2, cfg.vocab) and _finite(l2)


def test_lm_train_step_reduces_loss():
    from repro.train import adamw, make_train_step
    from repro.train.loop import init_state

    cfg = T.LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                     d_ff=64, vocab=61, dtype=jnp.float32, kv_chunk=16)
    step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg), adamw(lr=3e-3))
    state = init_state(jax.random.PRNGKey(0), lambda k: T.init_params(k, cfg), adamw(lr=3e-3))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 61)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_dimenet_smoke_molecular():
    cfg = G.DimeNetConfig(name="dime-sm", n_blocks=2, d_hidden=32, n_bilinear=4,
                          n_spherical=4, n_radial=4)
    params = G.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n, e = 20, 50
    ei = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)]).astype(np.int32)
    tri = G.build_triplets(ei, max_per_edge=3)
    batch = {
        "z": jnp.asarray(rng.integers(0, 10, n)),
        "pos": jnp.asarray(rng.normal(0, 1, (n, 3)).astype(np.float32)),
        "edge_index": jnp.asarray(ei), "triplets": jnp.asarray(tri),
        "graph_id": jnp.asarray(np.repeat([0, 1], n // 2)), "n_graphs": 2,
        "labels": jnp.asarray([1.0, -1.0]),
    }
    out = G.forward(params, batch, cfg)
    assert out.shape == (2,) and _finite(out)
    g = jax.grad(G.loss_fn)(params, batch, cfg)
    assert all(_finite(x) for x in jax.tree.leaves(g))


def test_dimenet_smoke_features_classification():
    cfg = G.DimeNetConfig(name="dime-f", n_blocks=2, d_hidden=32, n_bilinear=4,
                          d_feat=16, n_classes=5)
    params = G.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    n, e = 30, 80
    ei = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)]).astype(np.int32)
    batch = {
        "feats": jnp.asarray(rng.normal(0, 1, (n, 16)).astype(np.float32)),
        "pos": jnp.asarray(rng.normal(0, 1, (n, 3)).astype(np.float32)),
        "edge_index": jnp.asarray(ei),
        "triplets": jnp.asarray(G.build_triplets(ei, max_per_edge=2)),
        "labels": jnp.asarray(rng.integers(0, 5, n)),
    }
    out = G.forward(params, batch, cfg)
    assert out.shape == (n, 5) and _finite(out)
    assert _finite(G.loss_fn(params, batch, cfg))


def test_neighbour_sampler():
    rng = np.random.default_rng(2)
    n = 200
    # random graph in CSR
    deg = rng.integers(1, 10, n)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    indices = rng.integers(0, n, indptr[-1])
    seeds = rng.choice(n, 8, replace=False)
    nodes, ei = G.neighbour_sample(indptr, indices, seeds, fanouts=(4, 3))
    assert nodes.size >= 8
    assert ei.shape[0] == 2 and ei.max() < nodes.size


REDUCED_RS = {
    "dlrm-mlperf": R.RecsysConfig(
        name="dlrm-sm", kind="dlrm", embed_dim=16,
        table_rows=(500, 60, 3, 200), n_dense=13,
        bot_mlp=(32, 16), top_mlp=(32, 16, 1)),
    "wide-deep": R.RecsysConfig(
        name="wd-sm", kind="wide_deep", embed_dim=8,
        table_rows=(100,) * 6, top_mlp=(32, 16)),
    "bst": R.RecsysConfig(
        name="bst-sm", kind="bst", embed_dim=16, table_rows=(300, 50, 50),
        seq_len=6, n_heads=4, n_blocks=1, n_context=2, top_mlp=(32, 16)),
    "dien": R.RecsysConfig(
        name="dien-sm", kind="dien", embed_dim=8, table_rows=(200, 20, 30, 30),
        seq_len=7, gru_dim=24, n_context=2, top_mlp=(24, 8)),
}


def _rs_batch(cfg, b=6):
    rng = np.random.default_rng(0)
    batch = {"labels": jnp.asarray(rng.integers(0, 2, b).astype(np.float32))}
    if cfg.kind == "dlrm":
        batch["dense"] = jnp.asarray(rng.normal(0, 1, (b, 13)).astype(np.float32))
        batch["sparse_ids"] = jnp.asarray(rng.integers(0, 3, (b, cfg.n_sparse)), dtype=jnp.int32)
    elif cfg.kind == "wide_deep":
        batch["sparse_ids"] = jnp.asarray(rng.integers(0, 90, (b, cfg.n_sparse)), dtype=jnp.int32)
    elif cfg.kind == "bst":
        batch.update({
            "hist_ids": jnp.asarray(rng.integers(0, 290, (b, cfg.seq_len)), dtype=jnp.int32),
            "target_id": jnp.asarray(rng.integers(0, 290, b), dtype=jnp.int32),
            "context_ids": jnp.asarray(rng.integers(0, 40, (b, 2)), dtype=jnp.int32),
        })
    else:
        batch.update({
            "hist_ids": jnp.asarray(rng.integers(0, 190, (b, cfg.seq_len)), dtype=jnp.int32),
            "hist_cat_ids": jnp.asarray(rng.integers(0, 19, (b, cfg.seq_len)), dtype=jnp.int32),
            "target_id": jnp.asarray(rng.integers(0, 190, b), dtype=jnp.int32),
            "target_cat_id": jnp.asarray(rng.integers(0, 19, b), dtype=jnp.int32),
            "context_ids": jnp.asarray(rng.integers(0, 29, (b, 2)), dtype=jnp.int32),
        })
    return batch


@pytest.mark.parametrize("arch", sorted(REDUCED_RS))
def test_recsys_smoke(arch):
    cfg = REDUCED_RS[arch]
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    batch = _rs_batch(cfg)
    logits = R.forward(params, batch, cfg)
    assert logits.shape == (6,) and _finite(logits)
    loss = R.loss_fn(params, batch, cfg)
    assert _finite(loss)
    g = jax.grad(R.loss_fn)(params, batch, cfg)
    assert all(_finite(x) for x in jax.tree.leaves(g))


def test_embedding_bag_matches_manual():
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(0, 1, (50, 8)).astype(np.float32))
    ids = jnp.asarray([0, 3, 3, 7, 9], dtype=jnp.int32)
    segs = jnp.asarray([0, 0, 1, 1, 1], dtype=jnp.int32)
    out = R.embedding_bag(table, ids, segs, n_out=2)
    ref0 = np.asarray(table)[0] + np.asarray(table)[3]
    ref1 = np.asarray(table)[3] + np.asarray(table)[7] + np.asarray(table)[9]
    np.testing.assert_allclose(np.asarray(out[0]), ref0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), ref1, rtol=1e-6)
    mean = R.embedding_bag(table, ids, segs, n_out=2, combiner="mean")
    np.testing.assert_allclose(np.asarray(mean[1]), ref1 / 3, rtol=1e-6)


def test_retrieval_score_topk():
    cfg = REDUCED_RS["wide-deep"]
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    q = jnp.asarray(np.random.default_rng(0).normal(0, 1, (1, cfg.embed_dim)).astype(np.float32))
    cand = params["tables"]["t0"]
    scores, idx = R.retrieval_score(params, q, cand, topk=10)
    assert scores.shape == (1, 10) and idx.shape == (1, 10)
    full = np.asarray(q @ cand.T)[0]
    np.testing.assert_allclose(np.asarray(scores[0]), np.sort(full)[::-1][:10], rtol=1e-5)
