"""Fault-injection suite (DESIGN.md §10): the hardened serving path.

Every failure mode of the out-of-core stack is driven through the seeded
:class:`repro.core.faults.FaultPlan` seam (plus real on-disk damage for the
fsck tests) and pinned against the acceptance contract: a faulted query
either returns a bit-identical answer (transient faults outlasted by
retries), a correctly-flagged degraded answer (persistent damage), or a
typed error — never a hang, never a silently wrong answer.

The kill-point sweep over ``CorpusStore.append`` / ``insert_into_store`` is
*exhaustive* (every write step of every layout), with an extra
randomised `hypothesis` pass when that package is installed — the sweep is
the stronger check, so the property test is gated, not required.
"""
import os
import shutil
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from fixtures import assert_trees_equal, random_corpus, store_case, corpus_data

from repro.core import ktree as kt
from repro.core.engine import (
    EngineClosed,
    EngineFault,
    EngineTimeout,
    ServingEngine,
)
from repro.core.faults import (
    FaultPlan,
    FaultReport,
    InjectedCrash,
    InjectedReadError,
)
from repro.core.fsck import fsck_store, repair_store
from repro.core.query import topk_search
from repro.core.store import (
    BlockCorrupt,
    BlockError,
    BlockUnavailable,
    MANIFEST_NAME,
    ManifestError,
    Prefetcher,
    ReadPolicy,
    open_store,
    save_store,
)

try:
    import hypothesis  # noqa: F401

    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

# fast backoff so retry-heavy tests don't sleep their way through CI
_FAST = ReadPolicy(backoff_s=1e-4, backoff_cap_s=1e-3)


def _damage_block_file(store, block, byte=200):
    """Flip one payload byte of ``block``'s first file on disk."""
    entry = store.manifest["blocks"][block]
    fname = sorted(entry["files"].values())[0]
    full = os.path.join(store.path, fname)
    raw = bytearray(open(full, "rb").read())
    raw[byte] ^= 0xFF
    with open(full, "wb") as f:
        f.write(bytes(raw))
    return full


# ---------------------------------------------------------------------------
# FaultPlan: seeded, deterministic, counted
# ---------------------------------------------------------------------------

def _schedule(plan, blocks=8, attempts=4):
    out = []
    for b in range(blocks):
        for a in range(attempts):
            try:
                plan.on_read(b, a)
            except InjectedReadError:
                out.append((b, a))
    return out


def test_fault_plan_deterministic_and_counted():
    s1 = _schedule(FaultPlan(seed=7, transient_rate=0.3))
    s2 = _schedule(FaultPlan(seed=7, transient_rate=0.3))
    assert s1 == s2 and s1, "same seed must replay the same fault schedule"
    assert s1 != _schedule(FaultPlan(seed=8, transient_rate=0.3))
    p = FaultPlan(seed=7, transient_rate=0.3)
    assert _schedule(p) == s1
    assert p.stats["transient_injected"] == len(s1)

    # directed transient faults: exactly the first N attempts of the block
    p = FaultPlan(transient_blocks=[2], transient_attempts=2)
    assert _schedule(p) == [(2, 0), (2, 1)]

    # persistent faults: every attempt, typed with persistent=True
    p = FaultPlan(persistent_blocks=[1])
    with pytest.raises(InjectedReadError) as ei:
        p.on_read(1, 5)
    assert ei.value.persistent and ei.value.retryable
    p.on_read(0, 0)  # other blocks untouched


def test_fault_plan_corrupt_bytes_deterministic():
    raw = bytes(range(256)) * 4
    p1 = FaultPlan(seed=3, corrupt_blocks=(0,))
    p2 = FaultPlan(seed=3, corrupt_blocks=(0,))
    out = p1.corrupt_bytes(0, "x", raw)
    assert out == p2.corrupt_bytes(0, "x", raw) and out != raw
    assert out[:129] == raw[:129], "flip must land past the .npy header"
    assert p1.corrupt_bytes(1, "x", raw) == raw  # non-corrupt block untouched
    assert p1.stats["corruptions_injected"] == 1


# ---------------------------------------------------------------------------
# hardened reads: retry / quarantine / verify
# ---------------------------------------------------------------------------

def test_transient_fault_retried_bit_identical(tmp_path):
    case = store_case(tmp_path)
    clean = open_store(case.path).read_block(1)
    plan = FaultPlan(transient_blocks=[1], transient_attempts=2)
    store = open_store(case.path, fault_plan=plan, read_policy=_FAST)
    got = store.read_block(1)
    for name in clean:
        np.testing.assert_array_equal(got[name], clean[name])
    cs = store.cache.stats
    assert cs["read_retries"] == 2 and cs["read_errors"] == 2
    assert cs["verify_failures"] == 0 and cs["quarantined"] == 0
    assert plan.stats["transient_injected"] == 2
    assert not store.quarantined


def test_persistent_fault_quarantines_and_fails_fast(tmp_path):
    case = store_case(tmp_path)
    plan = FaultPlan(persistent_blocks=[1])
    store = open_store(case.path, fault_plan=plan, read_policy=_FAST)
    with pytest.raises(BlockUnavailable) as ei:
        store.read_block(1)
    assert "after 4 attempts" in str(ei.value)
    cs = store.cache.stats
    assert cs["read_errors"] == 4 and cs["read_retries"] == 3
    assert cs["quarantined"] == 1
    assert "InjectedReadError" in store.quarantined[1]
    # second read fast-fails off the quarantine map: no new attempts
    with pytest.raises(BlockUnavailable):
        store.read_block(1)
    assert store.cache.stats["read_errors"] == 4
    assert plan.stats["persistent_injected"] == 4
    # healthy blocks still serve
    store.read_block(0)


def test_corrupt_block_caught_by_digest_not_parser(tmp_path):
    case = store_case(tmp_path)
    clean = open_store(case.path).read_block(0)
    plan = FaultPlan(corrupt_blocks=(0,))
    store = open_store(case.path, fault_plan=plan, read_policy=_FAST)
    with pytest.raises(BlockCorrupt):
        store.read_block(0)
    cs = store.cache.stats
    assert cs["verify_failures"] == 4 and cs["quarantined"] == 1
    # verify opt-out: the mangled payload still *parses* (flip is past the
    # header) and silently returns different bytes — exactly the failure
    # mode verification exists to catch
    noverify = open_store(
        case.path, fault_plan=FaultPlan(corrupt_blocks=(0,)),
        read_policy=ReadPolicy(verify=False, backoff_s=1e-4),
    )
    got = noverify.read_block(0)
    assert got["x"].shape == clean["x"].shape
    assert got["x"].tobytes() != clean["x"].tobytes()


def test_take_rows_masked_survives_unreadable_blocks(tmp_path):
    case = store_case(tmp_path)
    store = open_store(
        case.path, fault_plan=FaultPlan(persistent_blocks=[1]),
        read_policy=_FAST,
    )
    rows = np.arange(store.n_docs)
    got, ok = store.take_rows_masked(rows)
    lo, hi = store.block_rows(1)
    expect_ok = np.ones(store.n_docs, bool)
    expect_ok[lo:hi] = False
    np.testing.assert_array_equal(ok, expect_ok)
    assert not got["x"][lo:hi].any(), "masked rows are zero-filled"
    clean = open_store(case.path).take_rows(rows)
    np.testing.assert_array_equal(got["x"][ok], clean["x"][ok])
    # out-of-range ids still raise — only fault outcomes are maskable
    with pytest.raises(IndexError):
        store.take_rows_masked(np.array([store.n_docs]))


def test_iter_blocks_degrade_skips_unreadable(tmp_path):
    case = store_case(tmp_path)
    clean = {
        lo: arrays["x"].copy()
        for lo, hi, arrays in open_store(case.path).iter_blocks()
    }
    for prefetch in (0, 2):
        store = open_store(
            case.path, fault_plan=FaultPlan(persistent_blocks=[2]),
            read_policy=_FAST,
        )
        seen = list(store.iter_blocks(prefetch=prefetch, on_fault="degrade"))
        lo2, _ = store.block_rows(2)
        assert [lo for lo, _, _ in seen] == sorted(set(clean) - {lo2})
        for lo, _, arrays in seen:
            np.testing.assert_array_equal(arrays["x"], clean[lo])
        # default raise mode propagates the typed error
        store2 = open_store(
            case.path, fault_plan=FaultPlan(persistent_blocks=[2]),
            read_policy=_FAST,
        )
        with pytest.raises(BlockUnavailable):
            list(store2.iter_blocks(prefetch=prefetch))


# ---------------------------------------------------------------------------
# Prefetcher: reader-thread restart
# ---------------------------------------------------------------------------

def test_prefetcher_restarts_on_transient_reader_fault():
    calls = []

    def fetch(i):
        calls.append(i)
        if i == 2 and calls.count(2) == 1:
            raise RuntimeError("transient reader fault")
        return i * 10

    with Prefetcher(range(5), fetch, depth=2) as pf:
        got = list(pf)
    assert got == [(i, i * 10) for i in range(5)], "order preserved"
    assert pf.restarts == 1


def test_prefetcher_propagates_typed_verdicts_and_exhausted_budget():
    # BlockError verdicts carry retryable=False: no restart, immediate raise
    def fetch_verdict(i):
        raise BlockUnavailable("p", i, "quarantined")

    with Prefetcher(range(3), fetch_verdict) as pf:
        with pytest.raises(BlockUnavailable):
            list(pf)
    assert pf.restarts == 0

    # a fault on every incarnation exhausts max_restarts, then propagates
    def fetch_always(i):
        raise RuntimeError("reader keeps dying")

    with Prefetcher(range(3), fetch_always, max_restarts=2) as pf:
        with pytest.raises(RuntimeError):
            list(pf)
    assert pf.restarts == 2


# ---------------------------------------------------------------------------
# degraded answers: drop exactly the damage, keep everything else bit-exact
# ---------------------------------------------------------------------------

def test_topk_search_degrade_drops_only_faulted_query_rows(tmp_path):
    case = store_case(tmp_path)
    clean = open_store(case.path)
    d_ref, s_ref = topk_search(case.tree, clean, k=6, beam=3)
    store = open_store(
        case.path, fault_plan=FaultPlan(persistent_blocks=[1]),
        read_policy=_FAST,
    )
    docs, dist, rep = topk_search(case.tree, store, k=6, beam=3,
                                  on_fault="degrade")
    assert isinstance(rep, FaultReport) and rep.degraded
    lo, hi = store.block_rows(1)
    assert set(rep.dropped_query_rows) == set(range(lo, hi))
    assert rep.quarantined_blocks == (1,)
    mask = np.ones(store.n_docs, bool)
    mask[lo:hi] = False
    np.testing.assert_array_equal(docs[mask], d_ref[mask])
    np.testing.assert_array_equal(dist[mask], s_ref[mask])
    assert (docs[~mask] == -1).all() and np.isinf(dist[~mask]).all()

    # fault-free degrade mode: bit-identical + un-degraded report
    docs2, dist2, rep2 = topk_search(case.tree, clean, k=6, beam=3,
                                     on_fault="degrade")
    assert not rep2.degraded
    np.testing.assert_array_equal(docs2, d_ref)
    np.testing.assert_array_equal(dist2, s_ref)


def test_acceptance_chaos_sweep_store_backed(tmp_path):
    """ISSUE acceptance criterion: 10% transient read faults + 1 persistently
    corrupt block → surviving answers bit-identical, damage correctly
    flagged, zero silent wrong answers."""
    case = store_case(tmp_path, seed=9)
    clean = open_store(case.path)
    d_ref, s_ref = topk_search(case.tree, clean, k=6, beam=3)
    bad = clean.n_blocks - 1
    plan = FaultPlan(seed=42, transient_rate=0.10, corrupt_blocks=(bad,))
    store = open_store(case.path, fault_plan=plan, read_policy=_FAST)
    docs, dist, rep = topk_search(case.tree, store, k=6, beam=3,
                                  on_fault="degrade")
    lo, hi = store.block_rows(bad)
    assert rep.degraded
    assert set(rep.dropped_query_rows) == set(range(lo, hi))
    mask = np.ones(clean.n_docs, bool)
    mask[lo:hi] = False
    np.testing.assert_array_equal(docs[mask], d_ref[mask])
    np.testing.assert_array_equal(dist[mask], s_ref[mask])
    assert (docs[~mask] == -1).all()
    cs = store.cache.stats
    assert cs["verify_failures"] > 0, "corruption must be caught by digest"
    assert cs["quarantined"] == 1
    assert plan.stats["corruptions_injected"] > 0
    # the transient layer actually fired and was outlasted by retries
    assert plan.stats["transient_injected"] > 0
    assert cs["read_retries"] >= plan.stats["transient_injected"] - 4


# ---------------------------------------------------------------------------
# fsck: detect, repair, lineage
# ---------------------------------------------------------------------------

def test_fsck_detect_repair_idempotent_lineage(tmp_path):
    case = store_case(tmp_path, seed=5)
    clean = open_store(case.path)
    h0 = clean.manifest_hash
    rows = np.arange(clean.n_docs)
    ref = clean.take_rows(rows)
    d_ref, s_ref = topk_search(case.tree, clean, k=6, beam=3)
    assert fsck_store(case.path).clean

    damaged_file = _damage_block_file(clean, 1)
    rep = fsck_store(case.path)
    assert not rep.clean
    assert [i for i, _ in rep.damaged] == [1]
    assert "digest mismatch" in rep.damaged[0][1]
    assert any("DAMAGED" in line for line in rep.lines())
    # scan-only: nothing moved, nothing rewritten
    assert os.path.exists(damaged_file)
    assert rep.manifest_hash_before == rep.manifest_hash_after == h0

    rep2 = repair_store(case.path)
    assert rep2.repaired == (1,)
    assert rep2.manifest_hash_before == h0
    assert rep2.manifest_hash_after != h0
    assert not os.path.exists(damaged_file), "damaged file moved aside"
    assert os.path.exists(damaged_file + ".damaged"), "evidence kept"

    # repaired store: verify=True passes (tombstones carry no files),
    # excised block pre-quarantined, lineage names the pre-repair hash
    post = open_store(case.path, verify=True)
    assert post.manifest["fsck_lineage"] == [h0]
    assert post.manifest_hash == rep2.manifest_hash_after
    assert 1 in post.quarantined and "excised by store_fsck" in post.quarantined[1]
    with pytest.raises(BlockUnavailable):
        post.read_block(1)
    assert fsck_store(case.path).clean

    # idempotent: a second repair pass finds nothing to do
    rep3 = repair_store(case.path)
    assert rep3.clean and rep3.repaired == ()
    assert rep3.manifest_hash_before == rep3.manifest_hash_after

    # degraded serving off the repaired store: survivors bit-identical
    docs, dist, drep = topk_search(case.tree, post, k=6, beam=3,
                                   on_fault="degrade")
    lo, hi = post.block_rows(1)
    assert set(drep.dropped_query_rows) == set(range(lo, hi))
    mask = np.ones(post.n_docs, bool)
    mask[lo:hi] = False
    np.testing.assert_array_equal(docs[mask], d_ref[mask])
    np.testing.assert_array_equal(dist[mask], s_ref[mask])
    got, ok = post.take_rows_masked(rows)
    np.testing.assert_array_equal(got["x"][ok], ref["x"][ok])


def test_fsck_detects_missing_file(tmp_path):
    case = store_case(tmp_path)
    store = open_store(case.path)
    fname = sorted(store.manifest["blocks"][0]["files"].values())[0]
    os.remove(os.path.join(case.path, fname))
    rep = fsck_store(case.path)
    assert [i for i, _ in rep.damaged] == [0]
    assert "missing file" in rep.damaged[0][1]
    assert repair_store(case.path).repaired == (0,)
    assert fsck_store(case.path).clean


def test_restore_index_accepts_repaired_refuses_regenerated(tmp_path):
    from repro.ckpt.checkpoint import restore_index, save_index

    case = store_case(tmp_path, seed=6)
    store = open_store(case.path)
    ck = str(tmp_path / "idx")
    save_index(ck, case.tree, store)

    _damage_block_file(store, 0)
    repair_store(case.path)
    tree2, store2 = restore_index(ck)  # lineage: repaired != regenerated
    assert_trees_equal(case.tree, tree2)
    assert 0 in store2.quarantined

    # a store regenerated in place shares no lineage — still refused
    save_store(case.path,
               corpus_data(random_corpus(np.random.default_rng(99)), False))
    with pytest.raises(ValueError, match="rewritten in place"):
        restore_index(ck)


# ---------------------------------------------------------------------------
# typed manifest/sidecar errors — corrupt metadata always names its file
# ---------------------------------------------------------------------------

def test_corrupt_store_manifest_is_typed(tmp_path):
    case = store_case(tmp_path)
    mpath = os.path.join(case.path, MANIFEST_NAME)
    with open(mpath, "w") as f:
        f.write('{"format": "ktree-store-v1", "n_docs": ')  # truncated
    for op in (open_store, fsck_store):
        with pytest.raises(ManifestError) as ei:
            op(case.path)
        assert ei.value.path == mpath
        assert MANIFEST_NAME in str(ei.value)
    # a parseable manifest of the wrong format is typed too
    with open(mpath, "w") as f:
        f.write('{"format": "something-else"}')
    with pytest.raises(ManifestError, match="unknown store format"):
        open_store(case.path)


def test_corrupt_index_json_is_typed(tmp_path):
    from repro.ckpt.checkpoint import INDEX_META_NAME, restore_index, save_index

    case = store_case(tmp_path)
    ck = str(tmp_path / "idx")
    save_index(ck, case.tree, open_store(case.path))
    meta = os.path.join(ck, INDEX_META_NAME)
    with open(meta, "w") as f:
        f.write("{broken")
    with pytest.raises(ManifestError) as ei:
        restore_index(ck)
    assert ei.value.path == meta
    # parseable but missing required fields is typed as well
    with open(meta, "w") as f:
        f.write('{"store_path": "somewhere"}')
    with pytest.raises(ManifestError):
        restore_index(ck)


def test_corrupt_ckpt_msgpack_is_typed(tmp_path):
    from repro.ckpt import checkpoint as ckpt

    state = {"w": np.arange(6, dtype=np.float32)}
    d = str(tmp_path / "ck")
    ckpt.save(d, state, step=0)
    mpath = os.path.join(d, "step_000000000", "MANIFEST.msgpack")
    with open(mpath, "wb") as f:
        f.write(b"\xc1\xc1\xc1")  # 0xc1 is never valid msgpack
    with pytest.raises(ManifestError) as ei:
        ckpt.restore(d, state)
    assert ei.value.path == mpath


def test_corpus_store_sidecar_lineage_and_typed_error(tmp_path):
    from repro.data.pipeline import corpus_store
    from repro.data.synth_corpus import INEX_LIKE, scaled

    spec = scaled(INEX_LIKE, n_docs=80, culled=40)
    path = str(tmp_path / "corpus")
    corpus_store(spec, path, representation="dense", block_docs=32)
    # clean reuse
    assert corpus_store(spec, path, representation="dense",
                        block_docs=32) == path
    # fsck-repaired store is the same corpus minus damage: reuse via lineage
    store = open_store(path)
    _damage_block_file(store, 0)
    repair_store(path)
    assert corpus_store(spec, path, representation="dense",
                        block_docs=32) == path
    # corrupt sidecar → typed error naming PIPELINE.json, not a JSONDecodeError
    sidecar = os.path.join(path, "PIPELINE.json")
    with open(sidecar, "w") as f:
        f.write("{truncated")
    with pytest.raises(ManifestError) as ei:
        corpus_store(spec, path, representation="dense", block_docs=32)
    assert ei.value.path == sidecar


# ---------------------------------------------------------------------------
# crash-safety: exhaustive kill-point sweep over append / insert_into_store
# ---------------------------------------------------------------------------

def _grow(store, case, sparse, op):
    new_rows = corpus_data(case.x[:30], sparse)  # layout-compatible rows
    if op == "append":
        store.append(new_rows)
    else:
        kt.insert_into_store(case.tree, store, new_rows)


@pytest.mark.parametrize("sparse,op", [
    (False, "append"), (True, "append"), (False, "insert"),
])
def test_kill_point_sweep_append_and_insert(tmp_path, sparse, op):
    """Crash the writer before *every* write step: the pre-growth store must
    stay openable, verifiable, fsck-clean, and bit-identical over the old
    rows — the atomic-commit contract of DESIGN.md §9/§10."""
    case = store_case(tmp_path, sparse=sparse, seed=3 if sparse else 4)
    n0 = open_store(case.path).n_docs
    pristine = open_store(case.path).take_rows(np.arange(n0))

    # probe run: count the write steps + build the completed-growth reference
    probe = str(tmp_path / "probe")
    shutil.copytree(case.path, probe)
    probe_plan = FaultPlan()
    _grow(open_store(probe, fault_plan=probe_plan), case, sparse, op)
    n_steps = probe_plan.stats["writes_seen"]
    assert n_steps >= 4, "expect tail merge + manifest tmp/replace + commit"
    ref_store = open_store(probe)
    n1 = ref_store.n_docs
    assert n1 == n0 + 30
    ref_rows = ref_store.take_rows(np.arange(n1))

    for kill in range(n_steps):
        work = str(tmp_path / f"kill{kill}")
        shutil.copytree(case.path, work)
        store = open_store(work, fault_plan=FaultPlan(kill_after_writes=kill))
        with pytest.raises(InjectedCrash):
            _grow(store, case, sparse, op)
        post = open_store(work, verify=True)  # every surviving block verifies
        assert post.n_docs in (n0, n1), \
            f"kill point {kill} left a half-committed doc count"
        assert fsck_store(work).clean
        old = post.take_rows(np.arange(n0))
        for name in pristine:
            np.testing.assert_array_equal(
                old[name], pristine[name],
                err_msg=f"kill point {kill} corrupted pre-growth rows",
            )
        if post.n_docs == n1:  # crash after commit: full growth visible
            grown = post.take_rows(np.arange(n1))
            for name in ref_rows:
                np.testing.assert_array_equal(grown[name], ref_rows[name])
    # and with no kill point the same plan machinery stays out of the way
    final = str(tmp_path / "nokill")
    shutil.copytree(case.path, final)
    _grow(open_store(final, fault_plan=FaultPlan(kill_after_writes=n_steps)),
          case, sparse, op)
    assert open_store(final).n_docs == n1


@pytest.mark.skipif(
    not _HAVE_HYPOTHESIS,
    reason="hypothesis not installed; the exhaustive sweep above covers "
           "every kill point deterministically",
)
def test_kill_point_property_randomised():
    import tempfile

    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(kill=st.integers(min_value=0, max_value=40),
           sparse=st.booleans())
    def run(kill, sparse):
        with tempfile.TemporaryDirectory() as td:
            case = store_case(td, sparse=sparse, seed=3 if sparse else 4)
            n0 = open_store(case.path).n_docs
            pristine = open_store(case.path).take_rows(np.arange(n0))
            store = open_store(
                case.path, fault_plan=FaultPlan(kill_after_writes=kill)
            )
            try:
                _grow(store, case, sparse, "append")
            except InjectedCrash:
                pass
            post = open_store(case.path, verify=True)
            assert post.n_docs in (n0, n0 + 30)
            assert fsck_store(case.path).clean
            old = post.take_rows(np.arange(n0))
            for name in pristine:
                np.testing.assert_array_equal(old[name], pristine[name])

    run()


# ---------------------------------------------------------------------------
# serving engine: timeouts, watchdog, closed-engine semantics
# ---------------------------------------------------------------------------

def _fake_answer(x, k):
    return (np.zeros((x.shape[0], k), np.int32),
            np.zeros((x.shape[0], k), np.float32))


def test_result_timeout_is_typed_and_non_destructive():
    release = threading.Event()

    def wedged(x, k, beam):
        release.wait(30)
        return _fake_answer(x, k)

    eng = ServingEngine(wedged, row_budget=4, max_queue=8, max_wait_s=0.0)
    try:
        h = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1)
        with pytest.raises(EngineTimeout):
            h.result(timeout=0.05)
        assert isinstance(EngineTimeout("x"), TimeoutError)
        # the caller-side timeout did not consume the request
        release.set()
        docs, dist = h.result(timeout=10)
        assert docs.shape == (1, 2)
        st = eng.stats()
        assert st["completed"] == 1 and st["timeouts"] == 0
    finally:
        release.set()
        eng.close()


def test_watchdog_expires_wedged_inflight_request():
    release = threading.Event()

    def wedged(x, k, beam):
        release.wait(30)
        return _fake_answer(x, k)

    eng = ServingEngine(wedged, row_budget=4, max_queue=8, max_wait_s=0.0,
                        request_timeout_s=0.05)
    try:
        h = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1)
        with pytest.raises(EngineTimeout, match="watchdog"):
            h.result(timeout=5)
        st = eng.stats()
        assert st["timeouts"] == 1 and st["failed"] == 1
        # set-once resolution: the late answer after release is discarded
        release.set()
        time.sleep(0.1)
        assert eng.stats()["completed"] == 0
    finally:
        release.set()
        eng.close()


def test_close_drain_false_fails_queued_and_inflight():
    release = threading.Event()

    def wedged(x, k, beam):
        release.wait(30)
        return _fake_answer(x, k)

    eng = ServingEngine(wedged, row_budget=1, max_queue=8, max_wait_s=0.0)
    h1 = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1)
    time.sleep(0.05)  # let h1 become the in-flight batch
    h2 = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1)
    eng.close(drain=False)
    for h in (h1, h2):
        with pytest.raises(EngineClosed):
            h.result(timeout=5)
    assert eng.stats()["failed"] == 2
    with pytest.raises(EngineClosed):
        eng.submit(np.zeros((1, 3), np.float32))
    release.set()


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning"
)  # the injected SystemExit below is the point of the test
def test_watchdog_restarts_dead_dispatcher():
    def ok_fn(x, k, beam):
        return _fake_answer(x, k)

    eng = ServingEngine(ok_fn, row_budget=4, max_queue=8, max_wait_s=0.0)
    # _execute survives any search-fn exception (it resolves the batch with
    # the typed error), so a dispatcher *death* has to be injected above it
    orig = eng._execute
    armed = [True]

    def dying_execute(batch):
        if armed[0]:
            armed[0] = False
            raise SystemExit("injected dispatcher death")
        return orig(batch)

    eng._execute = dying_execute
    try:
        h = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1)
        with pytest.raises(EngineFault, match="dispatcher thread died"):
            h.result(timeout=5)
        # the replacement dispatcher keeps serving
        h2 = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1)
        docs, dist = h2.result(timeout=5)
        assert docs.shape == (1, 2)
        st = eng.stats()
        assert st["watchdog_restarts"] == 1
        assert st["failed"] == 1 and st["completed"] == 1
    finally:
        eng.close()


def test_search_fn_exception_fails_batch_without_killing_dispatcher(tmp_path):
    case = store_case(tmp_path)
    store = open_store(
        case.path, fault_plan=FaultPlan(persistent_blocks=[0]),
        read_policy=_FAST,
    )

    def faulting_fn(x, k, beam):
        store.read_block(0)  # typed BlockUnavailable after retries
        return _fake_answer(x, k)

    eng = ServingEngine(faulting_fn, row_budget=4, max_queue=8, max_wait_s=0.0)
    try:
        h = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1)
        with pytest.raises(BlockUnavailable):
            h.result(timeout=5)
        st = eng.stats()
        assert st["failed"] == 1 and st["watchdog_restarts"] == 0
    finally:
        eng.close()


def test_degraded_answers_flagged_on_handle():
    rep = FaultReport(degraded=True, quarantined_blocks=(2,))

    def degfn(x, k, beam):
        return _fake_answer(x, k) + (rep,)

    degfn.on_fault = "degrade"
    with ServingEngine(degfn, row_budget=4, max_queue=8,
                       max_wait_s=0.0) as eng:
        h = eng.submit(np.zeros((2, 3), np.float32), k=3, beam=1)
        docs, dist = h.result(timeout=5)
        assert docs.shape == (2, 3)
        assert h.degraded and h.report is rep
        assert eng.stats()["degraded"] == 1


# ---------------------------------------------------------------------------
# sharded corpus degrade (forced multi-device subprocess)
# ---------------------------------------------------------------------------

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
_TESTS = os.path.dirname(os.path.abspath(__file__))

_SHARDED_DEGRADE_SCRIPT = textwrap.dedent("""
    import json, os, shutil, sys, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {tests!r})
    import numpy as np
    import jax
    from fixtures import store_case
    from repro.core.faults import FaultPlan
    from repro.core.fsck import repair_store
    from repro.core.query import topk_search_sharded
    from repro.core.store import ReadPolicy, open_store

    out = {{}}
    mesh = jax.make_mesh((8,), ("data",))
    fast = ReadPolicy(backoff_s=1e-4, backoff_cap_s=1e-3)
    with tempfile.TemporaryDirectory() as td:
        case = store_case(td)
        q = case.x[32:96].astype(np.float32)  # rows spanning blocks 0 and 1
        clean = open_store(case.path)
        d_ref, s_ref = topk_search_sharded(
            mesh, case.tree, q, corpus=clean, k=6, beam=3)
        lo, hi = clean.block_rows(1)

        # leg A: block 1 quarantined at runtime by injected persistent faults
        fa = open_store(case.path, fault_plan=FaultPlan(persistent_blocks=[1]),
                        read_policy=fast)
        d_a, s_a, rep_a = topk_search_sharded(
            mesh, case.tree, q, corpus=fa, k=6, beam=3, on_fault="degrade")

        # leg B: the same block excised on disk by store_fsck
        dst = os.path.join(td, "copy")
        shutil.copytree(case.path, dst)
        fname = sorted(clean.manifest["blocks"][1]["files"].values())[0]
        full = os.path.join(dst, fname)
        raw = bytearray(open(full, "rb").read())
        raw[200] ^= 0xFF
        open(full, "wb").write(bytes(raw))
        repair_store(dst)
        d_b, s_b, rep_b = topk_search_sharded(
            mesh, case.tree, q, corpus=open_store(dst, read_policy=fast),
            k=6, beam=3, on_fault="degrade")

        out["degraded"] = bool(rep_a.degraded and rep_b.degraded)
        out["quarantined"] = [sorted(rep_a.quarantined_blocks),
                              sorted(rep_b.quarantined_blocks)]
        out["cross_pin"] = bool((d_a == d_b).all()
                                and (np.asarray(s_a) == np.asarray(s_b)).all())
        out["no_quarantined_ids"] = bool(not ((d_a >= lo) & (d_a < hi)).any())
        out["answers_differ_from_clean"] = bool((d_a != d_ref).any())
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_degrade_cross_pins_quarantine_and_fsck_excision():
    """A runtime-quarantined block and the same block fsck-excised on disk
    must produce bit-identical degraded sharded answers (same surviving
    subset → same reference search)."""
    import json

    script = _SHARDED_DEGRADE_SCRIPT.format(src=_SRC, tests=_TESTS)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["degraded"], "block 1 candidates must have been dropped"
    assert out["quarantined"] == [[1], [1]]
    assert out["cross_pin"], "quarantine vs fsck excision must answer alike"
    assert out["no_quarantined_ids"]
    assert out["answers_differ_from_clean"], (
        "queries from block 1 must lose their exact-match doc"
    )
