import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import adamw, adafactor, make_train_step
from repro.train.loop import TrainState, init_state, train_loop
from repro import ckpt as ckpt_lib


def toy_loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def toy_data(n=256, d=10, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(0, 1, (d, 1)).astype(np.float32)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    y = x @ w_true + 0.01 * rng.normal(0, 1, (n, 1)).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (10, 1)) * 0.1, "b": jnp.zeros((1,))}


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizers_reduce_loss(opt_name):
    opt = adamw(lr=3e-2, weight_decay=0.0) if opt_name == "adamw" else adafactor(lr=3e-1)
    step = make_train_step(toy_loss, opt)
    state = init_state(jax.random.PRNGKey(0), toy_params, opt)
    batch = toy_data()
    first = last = None
    for i in range(60):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < 0.15 * first, (first, last)


def test_adafactor_factored_state_is_small():
    opt = adafactor()
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 4))}
    st = opt.init(params)
    assert set(st["big"].keys()) == {"vr", "vc"}
    assert st["big"]["vr"].shape == (256,) and st["big"]["vc"].shape == (512,)
    assert set(st["small"].keys()) == {"v"}


def test_adafactor_state_axes_match_state():
    opt = adafactor()
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 4))}
    axes = {"big": ("fsdp", "tensor"), "small": (None, None)}
    st = opt.init(params)
    sx = opt.state_logical_axes(axes, params)
    assert jax.tree.structure(jax.tree.map(lambda _: 0, st)) == \
           jax.tree.structure(jax.tree.map(lambda _: 0, sx, is_leaf=lambda x: isinstance(x, tuple)))
    assert sx["big"]["vr"] == ("fsdp",) and sx["big"]["vc"] == ("tensor",)


def test_microbatch_accumulation_matches_full_batch():
    opt = adamw(lr=1e-2)
    step1 = make_train_step(toy_loss, opt, n_microbatches=1)
    step4 = make_train_step(toy_loss, opt, n_microbatches=4)
    batch = toy_data()
    s1 = init_state(jax.random.PRNGKey(0), toy_params, opt)
    s4 = init_state(jax.random.PRNGKey(0), toy_params, opt)
    s1, m1 = step1(s1, batch)
    s4, m4 = step4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)


def test_grad_compression_still_learns():
    opt = adamw(lr=3e-2, weight_decay=0.0)
    step = make_train_step(toy_loss, opt, compress_grads=True)
    state = init_state(jax.random.PRNGKey(0), toy_params, opt)
    batch = toy_data()
    first = last = None
    for _ in range(60):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < 0.3 * first


def test_checkpoint_roundtrip(tmp_path):
    opt = adamw()
    state = init_state(jax.random.PRNGKey(0), toy_params, opt)
    d = str(tmp_path / "ckpt")
    ckpt_lib.save(d, state.as_dict(), 7)
    assert ckpt_lib.latest_step(d) == 7
    like = jax.tree.map(lambda x: x, state.as_dict())
    restored = ckpt_lib.restore(d, like)
    for a, b in zip(jax.tree.leaves(state.as_dict()), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    opt = adamw()
    state = init_state(jax.random.PRNGKey(0), toy_params, opt)
    d = str(tmp_path / "ckpt")
    for s in [1, 2, 3, 4, 5]:
        ckpt_lib.save(d, state.as_dict(), s)
    assert ckpt_lib.latest_step(d) == 5
    kept = sorted(os.listdir(d))
    assert len(kept) == 3  # gc keep=3


def test_train_loop_resume_is_deterministic(tmp_path):
    """Fault tolerance: crash after step 5, resume from checkpoint, final
    params identical to an uninterrupted run."""
    opt = adamw(lr=1e-2)
    step = make_train_step(toy_loss, opt)
    data = toy_data()
    batch_fn = lambda s: data

    # uninterrupted 10 steps
    s_full = init_state(jax.random.PRNGKey(0), toy_params, opt)
    s_full, _ = train_loop(s_full, step, batch_fn, n_steps=10)

    # interrupted at 5 + resume
    d = str(tmp_path / "ck")
    s_a = init_state(jax.random.PRNGKey(0), toy_params, opt)
    s_a, _ = train_loop(s_a, step, batch_fn, n_steps=5, ckpt_dir=d, ckpt_every=5)
    like = init_state(jax.random.PRNGKey(0), toy_params, opt).as_dict()
    restored = ckpt_lib.restore(d, like)
    s_b = TrainState(restored["params"], restored["opt"], jnp.asarray(restored["step"]))
    assert int(s_b.step) == 5
    s_b, _ = train_loop(s_b, step, batch_fn, n_steps=10)
    for a, b in zip(jax.tree.leaves(s_full.params), jax.tree.leaves(s_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_elastic_restore_replaces_sharding(tmp_path):
    """Elastic scaling: restore onto a (different) mesh via explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    opt = adamw()
    state = init_state(jax.random.PRNGKey(0), toy_params, opt)
    d = str(tmp_path / "ck")
    ckpt_lib.save(d, state.as_dict(), 1)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), state.as_dict()
    )
    restored = ckpt_lib.restore(d, state.as_dict(), shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 1}
