"""Shard-parallel query serving (DESIGN.md §8): `topk_search_sharded` must
return the single-device `topk_search` answers — same docs, dists within float
noise — on an 8-virtual-device CPU mesh, for dense and ELL-sparse corpora,
uneven shard remainders, and k > docs-per-shard; the merge collective must
stay O(B·k·n_shards). Runs in a subprocess so the main pytest process keeps
its single-device jax config. Also: serve paper mode end-to-end with
--mesh/--cache."""
import json
import os
import re
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json, re
    sys.path.insert(0, {src!r})
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import ktree as kt
    from repro.core.query import (
        topk_search, topk_search_sharded, _SHARDED_FN_CACHE, make_backend,
    )
    from repro.sparse.csr import csr_from_dense, csr_slice_rows

    out = {{}}
    rng = np.random.default_rng(0)
    means = rng.normal(0, 5, (5, 8))
    # 300 docs over 8 shards: uneven remainder (300 = 8*37 + 4 -> zero-pad)
    x = np.concatenate(
        [rng.normal(means[i], 1.0, (60, 8)) for i in range(5)]
    ).astype(np.float32)
    tree = kt.build(jnp.asarray(x), order=8, batch_size=32)
    q = jnp.asarray(x[:80] + 0.05 * rng.normal(0, 1, (80, 8)).astype(np.float32))
    mesh = jax.make_mesh((8,), ("data",))

    def compare(single, sharded):
        d1, s1 = single
        d2, s2 = sharded
        fin = np.isfinite(s1)
        return dict(
            docs_match=bool((d1 == d2).all()),
            finite_match=bool((fin == np.isfinite(s2)).all()),
            dist_err=float(np.abs(s1[fin] - s2[fin]).max()) if fin.any() else 0.0,
        )

    # 1. dense corpus, uneven remainder, explicit corpus arg
    single = topk_search(tree, q, k=10, beam=4)
    out["dense"] = compare(single, topk_search_sharded(mesh, tree, q, corpus=x,
                                                       k=10, beam=4))
    # 2. default corpus (recovered from the tree's own leaves)
    out["default_corpus"] = compare(
        single, topk_search_sharded(mesh, tree, q, k=10, beam=4))
    # 3. chunked sharded == unchunked sharded
    out["chunked"] = compare(
        topk_search_sharded(mesh, tree, q, corpus=x, k=10, beam=4, chunk=17),
        topk_search_sharded(mesh, tree, q, corpus=x, k=10, beam=4, chunk=512))

    # 4. k > docs-per-shard: 40 docs over 8 shards (5 each), k=12
    xs = x[:40]
    tree_s = kt.build(jnp.asarray(xs), order=4, batch_size=16)
    out["k_exceeds_shard"] = compare(
        topk_search(tree_s, jnp.asarray(xs[:10]), k=12, beam=3),
        topk_search_sharded(mesh, tree_s, jnp.asarray(xs[:10]), corpus=xs,
                            k=12, beam=3))

    # 5. ELL-sparse corpus + sparse queries (the nnz-bounded sharded scorer)
    xsp = (x * (rng.random(x.shape) < 0.5)).astype(np.float32)
    xsp[np.arange(xsp.shape[0]), rng.integers(0, 8, xsp.shape[0])] += 1.0
    m = csr_from_dense(xsp)
    tree_sp = kt.build(m, order=8, medoid=True, batch_size=32)
    qs = csr_slice_rows(m, 0, 50)
    out["sparse"] = compare(
        topk_search(tree_sp, qs, k=5, beam=4),
        topk_search_sharded(mesh, tree_sp, qs, corpus=m, k=5, beam=4))

    # 6. multi-axis mesh: docs shard over data only, model axis idle
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    out["mesh2d"] = compare(
        single, topk_search_sharded(mesh2, tree, q, corpus=x, k=10, beam=4))

    # 7. merge collective is O(B*k*S), never O(B*n): every all-gather in the
    # compiled sharded fn moves at most S*B*k elements per operand
    fn = next(f for kk, f in _SHARDED_FN_CACHE.items()
              if kk[0] is mesh or kk[0] == mesh)
    qbe = make_backend(q)
    from repro.core.ktree import chunked_query_rows, _levels_bucket
    rows_np, rows = next(chunked_query_rows(qbe.n_docs, 512))
    levels = int(tree.depth) - 1
    shards = make_backend(x).shard(mesh)
    try:
        txt = fn.lower(tree, qbe, rows, jnp.int32(levels), shards
                       ).compile().as_text()
        gathers = re.findall(r"all-gather[^=]*=?\\s*\\S*\\s*(\\w+)\\[([\\d,]+)\\]",
                             txt)
        if not gathers:
            gathers = re.findall(r"(\\w+)\\[([\\d,]+)\\][^\\n]*all-gather", txt)
        sizes = [int(np.prod([int(d) for d in dims.split(",")]))
                 for _, dims in gathers]
        b = rows.shape[0]
        out["collective"] = dict(
            found=len(sizes),
            max_elems=max(sizes) if sizes else 0,
            bound=8 * b * 10 * 2,      # S * B * k * (ids + dists)
            corpus_scale=b * x.shape[0],
        )
    except Exception as e:  # lowering text is version-dependent; report only
        out["collective"] = dict(found=-1, error=str(e)[:200])

    print("RESULT:" + json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def sharded_results():
    script = _SCRIPT.format(src=_SRC)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def _assert_equiv(r, tol=1e-4):
    assert r["docs_match"], r
    assert r["finite_match"], r
    assert r["dist_err"] <= tol, r


def test_sharded_matches_single_device_dense(sharded_results):
    # dense path shares every expression with _score_entries → bit-identical
    assert sharded_results["dense"]["dist_err"] == 0.0
    _assert_equiv(sharded_results["dense"])


def test_sharded_default_corpus_from_tree(sharded_results):
    _assert_equiv(sharded_results["default_corpus"])


def test_sharded_chunking_invariant(sharded_results):
    _assert_equiv(sharded_results["chunked"])


def test_sharded_k_exceeds_docs_per_shard(sharded_results):
    _assert_equiv(sharded_results["k_exceeds_shard"])


def test_sharded_matches_single_device_sparse(sharded_results):
    # sparse scorer sums in nnz order vs the dense-d order → float noise only
    _assert_equiv(sharded_results["sparse"], tol=1e-4)


def test_sharded_multi_axis_mesh(sharded_results):
    _assert_equiv(sharded_results["mesh2d"])


def test_merge_collective_is_bk_shards(sharded_results):
    c = sharded_results["collective"]
    if c["found"] <= 0:
        pytest.skip(f"no all-gather visible in compiled text: {c}")
    # every gathered operand stays ≤ S·B·k·2 elements — far below the B·n a
    # corpus gather would move
    assert c["max_elems"] <= c["bound"], c
    assert c["max_elems"] < c["corpus_scale"], c


def test_serve_paper_sharded_with_cache():
    """serve paper mode end-to-end: --mesh 8 --cache — sharded answers feed
    the recall report and the cache stats line shows the replayed stream
    hitting."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "ktree-inex",
         "--n-docs", "250", "--culled", "200", "--order", "10",
         "--queries", "48", "--beam", "2", "--mesh", "8", "--cache", "64"],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "sharded×8" in proc.stdout
    # capacity 64 ≥ 48 distinct queries → the replay pass hits every row
    m = re.search(r"hits=(\d+) misses=(\d+) hit_rate=([\d.]+)", proc.stdout)
    assert m, proc.stdout
    assert int(m.group(1)) == 48 and int(m.group(2)) == 48, proc.stdout
