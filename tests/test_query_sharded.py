"""Shard-parallel query serving (DESIGN.md §8): `topk_search_sharded` must
return the single-device `topk_search` answers — same docs, dists within float
noise — on an 8-virtual-device CPU mesh, for dense and ELL-sparse corpora,
uneven shard remainders, and k > docs-per-shard; the merge collective must
stay O(B·k·n_shards). Store-backed sharding (DESIGN.md §9) must additionally
be bit-identical to the in-memory sharded path with per-shard residency
bounded by the partition budgets. Runs in a subprocess so the main pytest
process keeps its single-device jax config. Also: serve paper mode end-to-end
with --mesh/--cache and --store --mesh."""
import json
import os
import re
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
_TESTS = os.path.abspath(os.path.dirname(__file__))

_SCRIPT = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json, re
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {tests!r})
    import numpy as np, jax, jax.numpy as jnp
    from fixtures import clustered_corpus, sparsify
    from repro.core import ktree as kt
    from repro.core.backend import shard_from_store
    from repro.core.query import (
        topk_search, topk_search_sharded, _SHARDED_FN_CACHE, make_backend,
    )
    from repro.core.store import open_store, save_store
    from repro.sparse.csr import csr_from_dense, csr_slice_rows

    out = {{}}
    rng = np.random.default_rng(0)
    # 300 docs over 8 shards: uneven remainder (300 = 8*37 + 4 -> zero-pad)
    x = clustered_corpus(rng, n_clusters=5, per_cluster=60, d=8)
    tree = kt.build(jnp.asarray(x), order=8, batch_size=32)
    q = jnp.asarray(x[:80] + 0.05 * rng.normal(0, 1, (80, 8)).astype(np.float32))
    mesh = jax.make_mesh((8,), ("data",))

    def compare(single, sharded):
        d1, s1 = single
        d2, s2 = sharded
        fin = np.isfinite(s1)
        return dict(
            docs_match=bool((d1 == d2).all()),
            finite_match=bool((fin == np.isfinite(s2)).all()),
            dist_err=float(np.abs(s1[fin] - s2[fin]).max()) if fin.any() else 0.0,
        )

    # 1. dense corpus, uneven remainder, explicit corpus arg
    single = topk_search(tree, q, k=10, beam=4)
    out["dense"] = compare(single, topk_search_sharded(mesh, tree, q, corpus=x,
                                                       k=10, beam=4))
    # 2. default corpus (recovered from the tree's own leaves)
    out["default_corpus"] = compare(
        single, topk_search_sharded(mesh, tree, q, k=10, beam=4))
    # 3. chunked sharded == unchunked sharded
    out["chunked"] = compare(
        topk_search_sharded(mesh, tree, q, corpus=x, k=10, beam=4, chunk=17),
        topk_search_sharded(mesh, tree, q, corpus=x, k=10, beam=4, chunk=512))

    # 4. k > docs-per-shard: 40 docs over 8 shards (5 each), k=12
    xs = x[:40]
    tree_s = kt.build(jnp.asarray(xs), order=4, batch_size=16)
    out["k_exceeds_shard"] = compare(
        topk_search(tree_s, jnp.asarray(xs[:10]), k=12, beam=3),
        topk_search_sharded(mesh, tree_s, jnp.asarray(xs[:10]), corpus=xs,
                            k=12, beam=3))

    # 5. ELL-sparse corpus + sparse queries (the nnz-bounded sharded scorer)
    xsp = sparsify(rng, x, density=0.5)
    m = csr_from_dense(xsp)
    tree_sp = kt.build(m, order=8, medoid=True, batch_size=32)
    qs = csr_slice_rows(m, 0, 50)
    out["sparse"] = compare(
        topk_search(tree_sp, qs, k=5, beam=4),
        topk_search_sharded(mesh, tree_sp, qs, corpus=m, k=5, beam=4))

    # 6. multi-axis mesh: docs shard over data only, model axis idle
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    out["mesh2d"] = compare(
        single, topk_search_sharded(mesh2, tree, q, corpus=x, k=10, beam=4))

    # 8. store-backed sharded serving (DESIGN.md §9): corpus on disk behind
    # per-shard block caches must bit-match the in-memory sharded path —
    # uneven last block (300 over block 64), 1-byte budgets (one-block floor)
    tmp = tempfile.mkdtemp(prefix="sharded-store")
    save_store(os.path.join(tmp, "dense"), x, block_docs=64)
    st_d = open_store(os.path.join(tmp, "dense"), budget_bytes=1)
    sharded_mem = topk_search_sharded(mesh, tree, q, corpus=x, k=10, beam=4)
    ss = shard_from_store(mesh, st_d, budget_bytes=1)
    out["store_dense"] = compare(
        sharded_mem,
        topk_search_sharded(mesh, tree, q, corpus=ss, k=10, beam=4))
    block_bytes = 64 * 8 * 4
    out["store_resident"] = dict(
        peak=ss.peak_resident_bytes, bound=8 * block_bytes,
        per_shard_blocks=[s["resident_blocks"] for s in ss.cache_stats])
    # store as the *query* source over the store-backed corpus
    save_store(os.path.join(tmp, "queries"), np.asarray(q), block_docs=32)
    st_q = open_store(os.path.join(tmp, "queries"), budget_bytes=1)
    out["store_query_source"] = compare(
        sharded_mem,
        topk_search_sharded(mesh, tree, st_q, corpus=ss, k=10, beam=4))

    # 9. store-backed sharded, k > docs-per-shard (40 docs over 8 shards)
    save_store(os.path.join(tmp, "small"), xs, block_docs=8)
    st_s = open_store(os.path.join(tmp, "small"), budget_bytes=1)
    out["store_k_exceeds_shard"] = compare(
        topk_search_sharded(mesh, tree_s, jnp.asarray(xs[:10]), corpus=xs,
                            k=12, beam=3),
        topk_search_sharded(mesh, tree_s, jnp.asarray(xs[:10]), corpus=st_s,
                            k=12, beam=3))

    # 10. store-backed sharded over the ELL corpus (pool scorer stays sparse)
    save_store(os.path.join(tmp, "ell"), m, block_docs=64)
    st_e = open_store(os.path.join(tmp, "ell"), budget_bytes=1)
    out["store_sparse"] = compare(
        topk_search_sharded(mesh, tree_sp, qs, corpus=m, k=5, beam=4),
        topk_search_sharded(mesh, tree_sp, qs,
                            corpus=shard_from_store(mesh, st_e, budget_bytes=1),
                            k=5, beam=4))

    # 7. merge collective is O(B*k*S), never O(B*n): every all-gather in the
    # compiled sharded fn moves at most S*B*k elements per operand
    fn = next(f for kk, f in _SHARDED_FN_CACHE.items()
              if kk[0] is mesh or kk[0] == mesh)
    qbe = make_backend(q)
    from repro.core.ktree import chunked_query_rows, _levels_bucket
    rows_np, rows = next(chunked_query_rows(qbe.n_docs, 512))
    levels = int(tree.depth) - 1
    shards = make_backend(x).shard(mesh)
    try:
        txt = fn.lower(tree, qbe, rows, jnp.int32(levels), shards
                       ).compile().as_text()
        gathers = re.findall(r"all-gather[^=]*=?\\s*\\S*\\s*(\\w+)\\[([\\d,]+)\\]",
                             txt)
        if not gathers:
            gathers = re.findall(r"(\\w+)\\[([\\d,]+)\\][^\\n]*all-gather", txt)
        sizes = [int(np.prod([int(d) for d in dims.split(",")]))
                 for _, dims in gathers]
        b = rows.shape[0]
        out["collective"] = dict(
            found=len(sizes),
            max_elems=max(sizes) if sizes else 0,
            bound=8 * b * 10 * 2,      # S * B * k * (ids + dists)
            corpus_scale=b * x.shape[0],
        )
    except Exception as e:  # lowering text is version-dependent; report only
        out["collective"] = dict(found=-1, error=str(e)[:200])

    print("RESULT:" + json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def sharded_results():
    script = _SCRIPT.format(src=_SRC, tests=_TESTS)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def _assert_equiv(r, tol=1e-4):
    assert r["docs_match"], r
    assert r["finite_match"], r
    assert r["dist_err"] <= tol, r


def test_sharded_matches_single_device_dense(sharded_results):
    # dense path shares every expression with _score_entries → bit-identical
    assert sharded_results["dense"]["dist_err"] == 0.0
    _assert_equiv(sharded_results["dense"])


def test_sharded_default_corpus_from_tree(sharded_results):
    _assert_equiv(sharded_results["default_corpus"])


def test_sharded_chunking_invariant(sharded_results):
    _assert_equiv(sharded_results["chunked"])


def test_sharded_k_exceeds_docs_per_shard(sharded_results):
    _assert_equiv(sharded_results["k_exceeds_shard"])


def test_sharded_matches_single_device_sparse(sharded_results):
    # sparse scorer sums in nnz order vs the dense-d order → float noise only
    _assert_equiv(sharded_results["sparse"], tol=1e-4)


def test_sharded_multi_axis_mesh(sharded_results):
    _assert_equiv(sharded_results["mesh2d"])


def test_merge_collective_is_bk_shards(sharded_results):
    c = sharded_results["collective"]
    if c["found"] <= 0:
        pytest.skip(f"no all-gather visible in compiled text: {c}")
    # every gathered operand stays ≤ S·B·k·2 elements — far below the B·n a
    # corpus gather would move
    assert c["max_elems"] <= c["bound"], c
    assert c["max_elems"] < c["corpus_scale"], c


def test_store_backed_sharded_bit_identical_dense(sharded_results):
    # §9 contract: disk-backed sharded answers == in-memory sharded answers,
    # bit for bit (pool rows are the same bytes, scorer is the same exprs)
    r = sharded_results["store_dense"]
    assert r["docs_match"] and r["finite_match"] and r["dist_err"] == 0.0, r


def test_store_backed_sharded_bit_identical_sparse(sharded_results):
    r = sharded_results["store_sparse"]
    assert r["docs_match"] and r["finite_match"] and r["dist_err"] == 0.0, r


def test_store_backed_sharded_k_exceeds_docs_per_shard(sharded_results):
    r = sharded_results["store_k_exceeds_shard"]
    assert r["docs_match"] and r["finite_match"] and r["dist_err"] == 0.0, r


def test_store_backed_sharded_query_source(sharded_results):
    r = sharded_results["store_query_source"]
    assert r["docs_match"] and r["finite_match"] and r["dist_err"] == 0.0, r


def test_store_backed_sharded_residency_bound(sharded_results):
    """Peak resident store bytes across all shard caches stays within
    n_shards × per-shard budget — here 1-byte budgets, so the one-block
    floor: at most one resident block per shard at any time."""
    r = sharded_results["store_resident"]
    assert 0 < r["peak"] <= r["bound"], r
    assert all(b <= 1 for b in r["per_shard_blocks"]), r


def test_serve_paper_store_sharded():
    """serve paper mode end-to-end: --store --mesh 4 — streaming build, then
    store-backed sharded queries with per-shard cache stats and the residency
    report."""
    import tempfile

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    store_dir = os.path.join(tempfile.mkdtemp(prefix="serve-store"), "blocks")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "ktree-inex",
         "--n-docs", "250", "--culled", "200", "--order", "10",
         "--queries", "48", "--beam", "2", "--mesh", "4",
         "--store", store_dir, "--budget-mb", "1", "--block-docs", "64",
         "--prefetch", "1"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "streaming-built K-tree" in proc.stdout
    assert "sharded×4" in proc.stdout
    assert "shard 3 cache:" in proc.stdout
    assert "peak store residency" in proc.stdout
    assert "out-of-core" in proc.stdout


def test_serve_paper_sharded_with_cache():
    """serve paper mode end-to-end: --mesh 8 --cache — sharded answers feed
    the recall report and the cache stats line shows the replayed stream
    hitting."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "ktree-inex",
         "--n-docs", "250", "--culled", "200", "--order", "10",
         "--queries", "48", "--beam", "2", "--mesh", "8", "--cache", "64"],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "sharded×8" in proc.stdout
    # capacity 64 ≥ 48 distinct queries → the replay pass hits every row
    m = re.search(r"hits=(\d+) misses=(\d+) hit_rate=([\d.]+)", proc.stdout)
    assert m, proc.stdout
    assert int(m.group(1)) == 48 and int(m.group(2)) == 48, proc.stdout
