"""Shared corpus / tree / store factories for the test suite.

Before this module, ``test_store.py`` / ``test_query_sharded.py`` /
``test_invariants.py`` each hand-rolled near-identical corpus builders; these
helpers are the single copy. Plain functions, not pytest fixtures, so they
import both from test modules (the tests directory is on ``sys.path`` via
``conftest.py``) and from the forced-multi-device *subprocess* scripts in
``test_query_sharded.py`` (which cannot share the main process's jax config).

The random patterns reproduce the old hand-rolled builders exactly (same rng
consumption order), so retrofitted tests see byte-identical corpora.
"""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp


def random_corpus(rng, n=210, d=12, sparse=False):
    """Seeded N(0, 1) corpus ``f32[n, d]``. ``sparse=True`` zeroes ~60% of
    the entries and plants one anchor term per row (no all-zero rows, so unit
    norms stay defined) — the pattern the store/invariant suites share."""
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    if sparse:
        x = (x * (rng.random((n, d)) < 0.4)).astype(np.float32)
        x[np.arange(n), rng.integers(0, d, n)] += 1.0
    return x


def sparsify(rng, x, density=0.5):
    """Sparse view of a dense corpus: keep each entry with ``density``, then
    plant one anchor term per row (the sharded-serving suite's pattern)."""
    n, d = x.shape
    xs = (x * (rng.random(x.shape) < density)).astype(np.float32)
    xs[np.arange(n), rng.integers(0, d, n)] += 1.0
    return xs


def clustered_corpus(rng, n_clusters=5, per_cluster=60, d=8, spread=5.0):
    """Gaussian blobs around ``n_clusters`` means — queries routed through a
    tree over this corpus have non-trivial beam behaviour (the sharded suite's
    corpus)."""
    means = rng.normal(0, spread, (n_clusters, d))
    return np.concatenate(
        [rng.normal(means[i], 1.0, (per_cluster, d)) for i in range(n_clusters)]
    ).astype(np.float32)


def corpus_data(x, sparse):
    """The corpus as what ``build`` consumes: a Csr matrix (sparse) or a
    device array (dense)."""
    from repro.sparse.csr import csr_from_dense

    return csr_from_dense(x) if sparse else jnp.asarray(x)


def build_tree(data, order, medoid=False, batch_size=32, seed=1):
    """Deterministically built K-tree over ``data`` (key = PRNGKey(seed))."""
    from repro.core import ktree as kt

    return kt.build(data, order=order, batch_size=batch_size, medoid=medoid,
                    key=jax.random.PRNGKey(seed))


@dataclasses.dataclass
class StoreCase:
    """One store-backed test case: the corpus in every view a test wants.

    ``x``: dense host rows; ``data``: what ``build`` consumed (Csr for
    sparse, device array for dense); ``path``: the on-disk block store;
    ``tree``: the in-memory-built reference tree (streaming builds must
    bit-match it)."""

    x: np.ndarray
    data: object
    path: str
    tree: object


def store_case(dir_path, sparse=False, seed=0, n=210, d=12, block_docs=64,
               order=6, batch_size=32, tree_seed=1):
    """Build the canonical store-backed case: seeded corpus → on-disk block
    store at ``dir_path/store`` (uneven last block for the defaults) + an
    in-memory reference tree. Defaults reproduce the old ``dense_case``
    fixture; ``sparse=True`` with (seed=2, n=170, d=20, tree_seed=3)
    reproduces ``ell_case``."""
    from repro.core.store import save_store

    rng = np.random.default_rng(seed)
    x = random_corpus(rng, n=n, d=d, sparse=sparse)
    data = corpus_data(x, sparse)
    path = os.path.join(str(dir_path), "store")
    save_store(path, data, block_docs=block_docs)
    tree = build_tree(data, order=order, medoid=sparse,
                      batch_size=batch_size, seed=tree_seed)
    return StoreCase(x=x, data=data, path=path, tree=tree)


def assert_trees_equal(a, b):
    """Every non-static KTree field of ``a`` and ``b`` is bit-identical."""
    assert a.order == b.order and a.medoid == b.medoid
    for f in dataclasses.fields(a):
        if f.metadata.get("static"):
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name)),
            err_msg=f.name,
        )
