import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import ShardedBatcher, shard_bounds
from repro.data.synth_corpus import make_corpus, prepared_corpus, scaled, INEX_LIKE, RCV1_LIKE


def test_batcher_determinism():
    b1 = ShardedBatcher(n_examples=1000, global_batch=64, seed=3)
    b2 = ShardedBatcher(n_examples=1000, global_batch=64, seed=3)
    for step in [0, 5, 17]:
        np.testing.assert_array_equal(b1.batch_indices(step), b2.batch_indices(step))


def test_batcher_shards_disjoint_and_cover():
    shards = [
        ShardedBatcher(n_examples=512, global_batch=64, shard_id=i, n_shards=4, seed=0)
        for i in range(4)
    ]
    parts = [s.batch_indices(3) for s in shards]
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == 64  # disjoint union = the global batch
    for p in parts:
        assert p.size == 16


def test_batcher_epoch_coverage():
    b = ShardedBatcher(n_examples=256, global_batch=64, seed=1)
    seen = np.concatenate([b.batch_indices(s) for s in range(4)])
    assert len(np.unique(seen)) == 256  # one epoch covers every example


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 1000), st.integers(1, 16))
def test_shard_bounds_partition(n, k):
    spans = [shard_bounds(n, i, k) for i in range(k)]
    assert spans[0][0] == 0 and spans[-1][1] == n
    for (a1, b1), (a2, b2) in zip(spans, spans[1:]):
        assert b1 == a2
        assert 0 <= (b1 - a1) - (b2 - a2) <= 1  # balanced


def test_corpus_statistics():
    spec = scaled(INEX_LIKE, n_docs=500, culled=300)
    counts, labels = make_corpus(spec, seed=0)
    assert counts.n_rows == 500 and labels.shape[0] == 500
    assert len(np.unique(labels)) == spec.n_labels
    nnz_per_doc = np.diff(np.asarray(counts.indptr))
    assert nnz_per_doc.min() >= 1
    assert 10 < nnz_per_doc.mean() < 400


def test_prepared_corpus_unit_rows_and_culling():
    spec = scaled(RCV1_LIKE, n_docs=300, culled=200)
    m, labels = prepared_corpus(spec, seed=1)
    assert m.n_cols == 200
    from repro.sparse.csr import csr_row_norms
    norms = np.asarray(csr_row_norms(m))
    nz = np.diff(np.asarray(m.indptr)) > 0
    np.testing.assert_allclose(norms[nz], 1.0, rtol=1e-3)


def test_labels_give_signal():
    """Docs of the same label must be measurably closer (the planted topics
    are real signal, so purity/entropy curves mean something)."""
    spec = scaled(INEX_LIKE, n_docs=400, culled=250)
    m, labels = prepared_corpus(spec, seed=2)
    from repro.sparse.csr import csr_to_dense
    x = np.asarray(csr_to_dense(m))
    lab = labels
    same = x[lab == lab[0]][:20]
    other = x[lab != lab[0]][:20]
    d_same = ((same[:10, None] - same[None, 10:20]) ** 2).sum(-1).mean()
    d_other = ((same[:10, None] - other[None, :10]) ** 2).sum(-1).mean()
    assert d_same < d_other


def test_corpus_store_reuse_refuses_grown_store(tmp_path):
    """corpus_store reuse must refuse a store whose content changed since
    generation — a matching request sidecar is not enough once
    CorpusStore.append can grow the store in place (DESIGN.md §9)."""
    from repro.core.store import open_store
    from repro.data.pipeline import corpus_store

    spec = scaled(INEX_LIKE, n_docs=120, culled=80)
    path = str(tmp_path / "store")
    corpus_store(spec, path, representation="dense", block_docs=32)
    # identical request → reuse is silent
    corpus_store(spec, path, representation="dense", block_docs=32)
    # a different request still refuses
    with pytest.raises(ValueError, match="different"):
        corpus_store(spec, path, representation="dense", block_docs=64)
    # grow the store in place: same request, different content → refuse
    store = open_store(path)
    store.append(np.ones((5, store.dim), np.float32))
    with pytest.raises(ValueError, match="content changed"):
        corpus_store(spec, path, representation="dense", block_docs=32)
