"""Doc-consistency gate (the docs CI job runs the same checks via
tools/check_docs.py): the covered public API stays fully docstringed and the
top-level markdown docs stay link-clean."""
import os
import sys

_TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
if _TOOLS not in sys.path:
    sys.path.insert(0, _TOOLS)

import check_docs  # noqa: E402


def test_public_api_fully_docstringed():
    gaps = check_docs.docstring_gaps()
    assert gaps == [], (
        "public names missing docstrings (add args/returns/shape docs): "
        f"{gaps}"
    )


def test_markdown_docs_have_no_dead_links():
    bad = check_docs.broken_links()
    assert bad == [], f"dead relative links in docs: {bad}"


def test_readme_exists_and_covers_the_map():
    root = os.path.join(os.path.dirname(__file__), "..")
    with open(os.path.join(root, "README.md")) as f:
        text = f.read()
    for anchor in ("DESIGN.md", "ROADMAP.md", "BENCH_query.json",
                   "BENCH_oocore.json", "pytest"):
        assert anchor in text, f"README.md lost its pointer to {anchor}"
