"""End-to-end behaviour tests: the paper's full pipeline on a scaled corpus —
generate → TF-IDF → cull → unit rows → cluster with every algorithm → score
with the paper's metrics, asserting the paper's qualitative claims."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ktree as kt
from repro.core.kmeans import kmeans_fixed_iters, bisecting_kmeans
from repro.core.metrics import micro_purity, micro_entropy
from repro.core.sampling import sampled_ktree_clustering
from repro.data.synth_corpus import prepared_corpus, scaled, INEX_LIKE
from repro.sparse.csr import csr_to_dense


@pytest.fixture(scope="module")
def corpus():
    spec = scaled(INEX_LIKE, n_docs=600, culled=400)
    m, labels = prepared_corpus(spec, seed=0)
    return np.asarray(csr_to_dense(m)), labels, spec


def test_paper_pipeline_ktree(corpus):
    x, labels, spec = corpus
    xj = jnp.asarray(x)
    tree = kt.build(xj, order=16, batch_size=128)
    kt.check_invariants(tree, n_docs=x.shape[0])
    assign, nc = kt.extract_assignment(tree, x.shape[0])
    p = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), nc, spec.n_labels))
    h = float(micro_entropy(jnp.asarray(assign), jnp.asarray(labels), nc, spec.n_labels))
    # the synthetic topics are separable: K-tree must find real structure
    assert p > 0.6 and h < 0.7, (p, h, nc)


def test_paper_claim_medoid_faster_lower_quality(corpus):
    """Paper §2/§4: medoid K-tree trades quality for speed (no mean updates).
    We assert the quality side (speed is asserted in benchmarks)."""
    x, labels, spec = corpus
    xj = jnp.asarray(x)
    key = jax.random.PRNGKey(0)
    t_dense = kt.build(xj, order=16, batch_size=128, key=key)
    t_medoid = kt.build(xj, order=16, batch_size=128, key=key, medoid=True)
    a_d, nc_d = kt.extract_assignment(t_dense, x.shape[0])
    a_m, nc_m = kt.extract_assignment(t_medoid, x.shape[0])
    p_d = float(micro_purity(jnp.asarray(a_d), jnp.asarray(labels), nc_d, spec.n_labels))
    p_m = float(micro_purity(jnp.asarray(a_m), jnp.asarray(labels), nc_m, spec.n_labels))
    # medoid must still work, but not beat the weighted-mean tree decisively
    assert p_m > 0.45
    assert p_d >= p_m - 0.05, (p_d, p_m)


def test_paper_claim_ktree_vs_cluto_styles(corpus):
    """K-tree produces many clusters with quality in the same band as the
    k-means baselines at matched cluster count (Fig 1/2 shape)."""
    x, labels, spec = corpus
    xj = jnp.asarray(x)
    tree = kt.build(xj, order=16, batch_size=128)
    assign, nc = kt.extract_assignment(tree, x.shape[0])
    res = kmeans_fixed_iters(jax.random.PRNGKey(0), xj, nc, iters=10)
    p_tree = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), nc, spec.n_labels))
    p_km = float(micro_purity(res.assign, jnp.asarray(labels), nc, spec.n_labels))
    assert p_tree > 0.75 * p_km, (p_tree, p_km)


def test_sampled_ktree_end_to_end(corpus):
    x, labels, spec = corpus
    assign, nc, _ = sampled_ktree_clustering(
        jnp.asarray(x), order=16, fraction=0.1, batch_size=128
    )
    assert (assign >= 0).all()
    p = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), nc, spec.n_labels))
    assert p > 0.5


def test_bisecting_baseline(corpus):
    x, labels, spec = corpus
    res = bisecting_kmeans(jax.random.PRNGKey(1), jnp.asarray(x), 12, inner_iters=15)
    p = float(micro_purity(res.assign, jnp.asarray(labels), 12, spec.n_labels))
    assert p > 0.5


def test_sparse_dense_root_observation(corpus):
    """Paper §1: upper-level K-tree centres are dense (union of subtree terms)
    even though documents are sparse — verify on the built tree."""
    x, labels, spec = corpus
    xj = jnp.asarray(x)
    tree = kt.build(xj, order=16, batch_size=128)
    if int(tree.depth) < 2:
        pytest.skip("tree too shallow")
    root = int(tree.root)
    ne = int(tree.n_entries[root])
    root_centers = np.asarray(tree.centers[root, :ne])
    doc_density = (x != 0).mean()
    root_density = (np.abs(root_centers) > 1e-7).mean()
    assert root_density > 3 * doc_density, (root_density, doc_density)
