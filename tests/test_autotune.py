"""Measured-overlap auto-tuner (DESIGN.md §11): knob resolution precedence,
the ``choose_knobs`` decision rule, byte-deterministic ``TUNE.json`` sidecars
keyed by ``manifest_hash`` (rotation invalidates), tuned-path bit-identity on
a real store, and the headline prefetch regression: ``topk_search_sharded``'s
RP branch must actually honour ``prefetch=`` (it used to hardcode 0)."""
import os

import numpy as np
import pytest
import jax

from fixtures import store_case
from repro.core import ktree as kt
from repro.core.autotune import (
    DEFAULT_CHUNK, DEFAULT_PIPELINE, DEFAULT_PREFETCH, TunedKnobs,
    autotune_store_search, choose_knobs, load_tuned, resolve_knobs,
    save_tuned, sidecar_path, tune_key,
)
from repro.core.backend import make_projection
from repro.core.engine import make_search_fn
from repro.core.query import topk_search, topk_search_sharded
from repro.core.store import open_store


@pytest.fixture(scope="module")
def case(tmp_path_factory):
    return store_case(tmp_path_factory.mktemp("autotune"), sparse=False,
                      seed=0)


def _fresh_store(case, tmp_path, budget_bytes=None):
    """Reopen the case's block dir with its own handle (sidecar tests write
    TUNE.json next to the blocks, so give each test a private copy)."""
    import shutil

    path = os.path.join(str(tmp_path), "store")
    shutil.copytree(case.path, path)
    kw = {} if budget_bytes is None else {"budget_bytes": budget_bytes}
    return open_store(path, **kw)


# ------------------------------------------------------------ resolution

def test_resolve_knobs_precedence():
    tuned = TunedKnobs(pipeline=4, prefetch=2, chunk=128)
    # explicit always wins
    assert resolve_knobs(tuned, chunk=64, pipeline=1, prefetch=0) == (64, 1, 0)
    # None falls to tuned
    assert resolve_knobs(tuned) == (128, 4, 2)
    # mixed: each knob resolves independently
    assert resolve_knobs(tuned, pipeline=8) == (128, 8, 2)
    # no tuner decision → the repo defaults the untuned signatures used
    assert resolve_knobs(None) == (
        DEFAULT_CHUNK, DEFAULT_PIPELINE, DEFAULT_PREFETCH,
    )
    # explicit 0 is a value, not "unset"
    assert resolve_knobs(tuned, prefetch=0)[2] == 0


# -------------------------------------------------------------- decision

def test_choose_knobs_highest_qps_wins():
    cells = {(1, 0, 512): (2.0, 0.0), (2, 2, 256): (1.0, 0.4)}
    t = choose_knobs(cells, (1, 0, 512), n_queries=100)
    assert (t.pipeline, t.prefetch, t.chunk) == (2, 2, 256)
    assert t.qps == pytest.approx(100.0)
    assert t.baseline_qps == pytest.approx(50.0)
    assert t.overlap_frac == pytest.approx(0.4)


def test_choose_knobs_tie_breaks_overlap_then_shallow():
    # equal wall: more measured overlap wins
    cells = {(1, 0, 512): (1.0, 0.0), (2, 2, 512): (1.0, 0.5)}
    t = choose_knobs(cells, (1, 0, 512), n_queries=10)
    assert (t.pipeline, t.prefetch) == (2, 2)
    # equal wall and overlap: shallower depths win (never pay for nothing)
    cells = {(1, 0, 512): (1.0, 0.0), (4, 2, 512): (1.0, 0.0)}
    t = choose_knobs(cells, (1, 0, 512), n_queries=10)
    assert (t.pipeline, t.prefetch, t.chunk) == (1, 0, 512)


def test_choose_knobs_degrades_to_baseline():
    cells = {(1, 0, 512): (1.0, 0.0), (4, 2, 256): (3.0, 0.9)}
    t = choose_knobs(cells, (1, 0, 512), n_queries=10)
    assert (t.pipeline, t.prefetch, t.chunk) == (1, 0, 512)


def test_choose_knobs_requires_baseline():
    with pytest.raises(ValueError, match="baseline"):
        choose_knobs({(2, 0, 512): (1.0, 0.0)}, (1, 0, 512), 10)


# --------------------------------------------------------------- sidecar

def _synthetic_runner(pipeline, prefetch, chunk):
    """Deterministic fake measurements: deeper pipelines are faster, chunk
    256 beats 512, prefetch buys measured overlap."""
    wall = 1.0 / (1.0 + pipeline + prefetch) + (chunk / 512.0) * 0.01
    return wall, 0.2 * prefetch


def test_autotune_sidecar_byte_deterministic(case, tmp_path):
    """Same store + same synthetic timings → byte-identical TUNE.json, both
    across force-resweeps and across handle reopens (no timestamps, no host
    state)."""
    store = _fresh_store(case, tmp_path)
    tuned = autotune_store_search(
        case.tree, store, runner=_synthetic_runner, force=True,
    )
    path = sidecar_path(store)
    with open(path, "rb") as f:
        first = f.read()
    # resweep with the same timings: same decision, same bytes
    again = autotune_store_search(
        case.tree, store, runner=_synthetic_runner, force=True,
    )
    with open(path, "rb") as f:
        assert f.read() == first
    assert again == tuned
    # a fresh handle over the same blocks consults the cache (no runner
    # needed) and the sidecar is untouched
    store2 = open_store(store.path)
    cached = autotune_store_search(
        case.tree, store2,
        runner=lambda *a: (_ for _ in ()).throw(AssertionError("resweep")),
    )
    assert (cached.pipeline, cached.prefetch, cached.chunk) == (
        tuned.pipeline, tuned.prefetch, tuned.chunk,
    )
    with open(path, "rb") as f:
        assert f.read() == first


def test_sidecar_entries_merge_per_key(case, tmp_path):
    """Distinct (budget, backend) keys coexist in one sidecar; each loads
    back independently."""
    store = _fresh_store(case, tmp_path)
    a = TunedKnobs(pipeline=2, prefetch=0, chunk=256, qps=10.0)
    b = TunedKnobs(pipeline=4, prefetch=2, chunk=512, qps=20.0)
    save_tuned(store, a, budget_bytes=1000)
    save_tuned(store, b, budget_bytes=2000, backend="rp8")
    got_a = load_tuned(store, budget_bytes=1000)
    got_b = load_tuned(store, budget_bytes=2000, backend="rp8")
    assert (got_a.pipeline, got_a.chunk) == (2, 256)
    assert (got_b.pipeline, got_b.chunk) == (4, 512)
    # unknown key → None (never a wrong-budget decision)
    assert load_tuned(store, budget_bytes=3000) is None
    assert tune_key(store, 1000) != tune_key(store, 2000)


def test_sidecar_invalidated_by_manifest_rotation(case, tmp_path):
    """Appending rows rotates ``manifest_hash`` — the whole sidecar goes
    stale (measurements were taken over different blocks)."""
    store = _fresh_store(case, tmp_path)
    save_tuned(store, TunedKnobs(pipeline=4, prefetch=2, chunk=256))
    assert load_tuned(store) is not None
    old_hash = store.manifest_hash
    store.append(case.x[:5])
    assert store.manifest_hash != old_hash
    assert load_tuned(store) is None
    # the next save starts a fresh sidecar under the new hash
    save_tuned(store, TunedKnobs(pipeline=1, prefetch=0, chunk=512))
    assert load_tuned(store).pipeline == 1


def test_load_tuned_missing_or_garbage(case, tmp_path):
    store = _fresh_store(case, tmp_path)
    assert load_tuned(store) is None
    with open(sidecar_path(store), "w") as f:
        f.write("{not json")
    assert load_tuned(store) is None


# ---------------------------------------------- tuned paths stay exact

def test_tuned_search_bit_identical(case, tmp_path):
    """A tuner decision only reschedules work: tuned ``topk_search`` and a
    tuned ``make_search_fn`` answer bit-identically to the depth-1
    synchronous baseline."""
    store = _fresh_store(case, tmp_path, budget_bytes=1)
    tree = kt.build_from_store(store, order=6, batch_size=32,
                               key=jax.random.PRNGKey(1))
    q = store.view(0, 40)
    ref_d, ref_s = topk_search(tree, q, k=5, beam=4, chunk=512,
                               pipeline=1, prefetch=0)
    tuned = TunedKnobs(pipeline=4, prefetch=2, chunk=64)
    d, s = topk_search(tree, q, k=5, beam=4, tuned=tuned)
    np.testing.assert_array_equal(np.asarray(ref_d), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(ref_s), np.asarray(s))
    fn = make_search_fn(tree, tuned=tuned)
    assert (fn.chunk, fn.pipeline, fn.prefetch) == (64, 4, 2)
    d2, s2 = fn(q, 5, 4)
    np.testing.assert_array_equal(np.asarray(ref_d), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(ref_s), np.asarray(s2))
    # explicit knobs shadow the tuned ones
    fn_explicit = make_search_fn(tree, tuned=tuned, prefetch=0)
    assert fn_explicit.prefetch == 0


def test_autotune_end_to_end_real_measurements(case, tmp_path):
    """A real (tiny-grid) sweep over the store picks valid knobs, persists
    them, and the tuned replay reproduces the baseline answers."""
    store = _fresh_store(case, tmp_path, budget_bytes=1)
    tree = kt.build_from_store(store, order=6, batch_size=32,
                               key=jax.random.PRNGKey(1))
    tuned = autotune_store_search(
        tree, store, k=5, beam=4, pipelines=(1, 2), prefetches=(0, 1),
        chunks=(64,), n_queries=32, repeats=1, force=True,
    )
    assert tuned.pipeline >= 1 and tuned.prefetch >= 0 and tuned.chunk >= 1
    assert tuned.qps > 0 and tuned.baseline_qps > 0
    cached = load_tuned(store)
    assert (cached.pipeline, cached.prefetch, cached.chunk) == (
        tuned.pipeline, tuned.prefetch, tuned.chunk,
    )
    q = store.view(0, 32)
    ref = topk_search(tree, q, k=5, beam=4, chunk=512, pipeline=1, prefetch=0)
    got = topk_search(tree, q, k=5, beam=4, tuned=tuned)
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(got[1]))


# ------------------------------------- headline regression: rp prefetch

def test_sharded_rp_prefetch_actually_prefetches(case, tmp_path, monkeypatch):
    """Regression for the headline bug: ``topk_search_sharded``'s RP branch
    hardcoded ``prefetch=0`` into ``_topk_search_rp``, so a caller's
    ``prefetch=2`` silently ran fully synchronous. Spy on ``Prefetcher`` to
    prove the reader thread is actually engaged at the requested depth, and
    pin bit-identity against the synchronous run."""
    import repro.core.store as store_mod

    store = _fresh_store(case, tmp_path, budget_bytes=1)
    proj = make_projection(store.dim, 8, seed=3)
    tree = kt.build_from_store(store, order=6, batch_size=32,
                               key=jax.random.PRNGKey(1), projection=proj)
    q = store.view(0, 40)
    ref_d, ref_s = topk_search_sharded(
        None, tree, q, k=5, beam=4, chunk=16, prefetch=0,
        rp=proj, rp_corpus=store,
    )

    depths = []
    real = store_mod.Prefetcher

    class SpyPrefetcher(real):
        def __init__(self, requests, fetch, depth=1, **kw):
            depths.append(depth)
            super().__init__(requests, fetch, depth=depth, **kw)

    monkeypatch.setattr(store_mod, "Prefetcher", SpyPrefetcher)
    d, s = topk_search_sharded(
        None, tree, q, k=5, beam=4, chunk=16, prefetch=2,
        rp=proj, rp_corpus=store,
    )
    # before the fix: depths == [] — the reader thread never existed
    assert depths == [2]
    np.testing.assert_array_equal(np.asarray(ref_d), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(ref_s), np.asarray(s))


def test_single_device_rp_prefetch_bit_identical(case, tmp_path):
    """The RP route's rescore read-ahead (single-worker executor) keeps
    answers bit-identical across prefetch depths on ``topk_search`` too."""
    store = _fresh_store(case, tmp_path, budget_bytes=1)
    proj = make_projection(store.dim, 8, seed=3)
    tree = kt.build_from_store(store, order=6, batch_size=32,
                               key=jax.random.PRNGKey(1), projection=proj)
    q = store.view(0, 40)
    ref = topk_search(tree, q, k=5, beam=4, chunk=16, prefetch=0,
                      rp=proj, rp_corpus=store)
    for depth in (1, 2):
        got = topk_search(tree, q, k=5, beam=4, chunk=16, prefetch=depth,
                          rp=proj, rp_corpus=store)
        np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(got[0]))
        np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(got[1]))
