"""Continuous-batching serving engine (DESIGN.md §8): every answer the engine
hands back must be bit-identical to the offline engine on the same rows —
across mixed per-request (k, beam) settings, dense and ELL corpora, and (in a
forced-8-device subprocess) the sharded and store-backed paths. Overload must
shed at a bounded queue, never queue unboundedly; the deadline forcing point
must dispatch an underfull batch early; the latency recorder's arithmetic is
pinned through a fake clock; close() drains every admitted request."""
import json
import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest
import jax.numpy as jnp

from fixtures import build_tree, clustered_corpus, random_corpus, sparsify, corpus_data

from repro.core.engine import (
    EngineClosed,
    EngineSaturated,
    LatencyRecorder,
    ServingEngine,
    make_search_fn,
)
from repro.core.query import AnswerCache, topk_search
from repro.launch.engine import (
    open_loop_arrivals,
    report_lines,
    request_pool,
    run_load,
    submit_all,
)

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
_TESTS = os.path.abspath(os.path.dirname(__file__))


class FakeClock:
    """Deterministic monotonic clock: returns a scripted value, advanced by
    the test."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------- recorder

def test_latency_recorder_fake_clock_exact():
    # the monotonic-clock regression seam: scripted clock, exact arithmetic
    clk = FakeClock()
    rec = LatencyRecorder(clock=clk)
    t0 = rec.now()
    clk.advance(0.010)
    assert rec.record(t0) == pytest.approx(0.010)
    t1 = rec.now()
    clk.advance(0.030)
    rec.record(t1)
    clk.advance(0.5)
    t2 = rec.now()
    clk.advance(0.020)
    rec.record(t2)
    assert len(rec) == 3
    p = rec.percentiles((50, 95, 99))
    # samples (ms): 10, 30, 20 -> p50 exactly the median
    assert p["p50"] == pytest.approx(20.0)
    assert p["p95"] == pytest.approx(np.percentile([10.0, 30.0, 20.0], 95))
    assert p["p99"] <= 30.0 + 1e-9
    # span = first admit (0.0) .. last completion (0.56)
    assert rec.throughput() == pytest.approx(3 / 0.56)


def test_latency_recorder_empty():
    rec = LatencyRecorder()
    assert len(rec) == 0
    assert rec.percentiles() == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert rec.throughput() == 0.0


def test_latency_recorder_immune_to_wall_clock_steps():
    # an NTP-style wall-clock step must not corrupt samples: the recorder
    # only ever differences its injected clock, which is monotonic here
    clk = FakeClock(1000.0)
    rec = LatencyRecorder(clock=clk)
    t0 = rec.now()
    clk.advance(0.005)  # a wall clock could jump backwards; perf_counter not
    rec.record(t0)
    assert rec.percentiles()["p50"] == pytest.approx(5.0)


# ---------------------------------------------------------------- helpers

def _mini_case(sparse=False):
    rng = np.random.default_rng(3 if sparse else 2)
    x = clustered_corpus(rng, n_clusters=4, per_cluster=40, d=8)
    if sparse:
        x = sparsify(rng, x, density=0.5)
    data = corpus_data(x, sparse)
    tree = build_tree(data, order=6, medoid=sparse, batch_size=32, seed=1)
    q = x[:40] + 0.05 * rng.normal(0, 1, (40, 8)).astype(np.float32)
    return tree, q.astype(np.float32)


def _offline(tree, rows, k, beam):
    d, s = topk_search(tree, jnp.asarray(rows), k=k, beam=beam)
    return np.asarray(d), np.asarray(s)


def _assert_bit_identical(got, want):
    d1, s1 = got
    d2, s2 = want
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


# ------------------------------------------------------------ bit-identity

@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "ell"])
def test_engine_answers_bit_identical_to_offline(sparse):
    tree, q = _mini_case(sparse)
    fn = make_search_fn(tree)
    for b in (1, 2, 4, 8):  # warm the chunk-aligned buckets outside the engine
        fn(q[:b], 5, 3, chunk_rows=b)
    reqs = [q[0:1], q[1:4], q[4:6], q[6:13], q[13:14]]
    with ServingEngine(fn, row_budget=8, max_queue=32, max_wait_s=5e-3) as eng:
        handles = [eng.submit(r, k=5, beam=3) for r in reqs]
        results = [h.result(timeout=120) for h in handles]
    for r, got in zip(reqs, results):
        _assert_bit_identical(got, _offline(tree, r, 5, 3))
    st = eng.stats()
    assert st["completed"] == len(reqs) and st["failed"] == 0


def test_engine_mixed_k_beam_bucketing_bit_identical():
    # the satellite: mixed (k, beam) requests in one dispatched batch must
    # each match a standalone offline call with the same settings
    tree, q = _mini_case()
    fn = make_search_fn(tree)
    settings = [(5, 2), (7, 3), (5, 2), (3, 1), (7, 3)]
    for kk, bb in set(settings):  # warm each setting's chunk-aligned shapes
        for s in (4, 8):
            fn(q[:s], kk, bb, chunk_rows=4)
    reqs = [(q[i * 3:(i + 1) * 3], kk, bb)
            for i, (kk, bb) in enumerate(settings)]
    with ServingEngine(fn, row_budget=64, max_queue=32,
                       max_wait_s=0.25) as eng:
        handles = [eng.submit(r, k=kk, beam=bb) for r, kk, bb in reqs]
        results = [h.result(timeout=120) for h in handles]
    for (r, kk, bb), got in zip(reqs, results):
        _assert_bit_identical(got, _offline(tree, r, kk, bb))
    st = eng.stats()
    # 15 rows over budget 64 with a generous max_wait: one batch, one
    # fragment per distinct (k, beam)
    assert st["n_fragments"] >= len(set(settings))


def test_engine_oversized_request_still_served():
    # a single request larger than row_budget dispatches alone
    tree, q = _mini_case()
    fn = make_search_fn(tree)
    fn(q[:1], 4, 2)
    with ServingEngine(fn, row_budget=4, max_queue=8) as eng:
        got = eng.submit(q[:11], k=4, beam=2).result(timeout=120)
    _assert_bit_identical(got, _offline(tree, q[:11], 4, 2))


def test_engine_two_oversized_same_setting_requests_both_served():
    # regression: two bucket-None requests (rows > the search fn's chunk)
    # sharing (k, beam) land in ONE fragment; each must get its own offline
    # call — the engine once answered only the first and left every later
    # handle in the group unset (its caller blocked forever)
    tree, q = _mini_case()
    fn = make_search_fn(tree, chunk=8)
    reqs = [q[:11], q[3:13]]
    for r in reqs:  # warm the offline shapes outside the engine
        fn(r, 4, 2)
    with ServingEngine(fn, row_budget=64, max_queue=8,
                       max_wait_s=0.25) as eng:
        handles = [eng.submit(r, k=4, beam=2) for r in reqs]
        results = [h.result(timeout=120) for h in handles]
    for r, got in zip(reqs, results):
        _assert_bit_identical(got, fn(r, 4, 2))
    st = eng.stats()
    assert st["completed"] == len(reqs) and st["failed"] == 0


# ---------------------------------------------------------------- overload

def test_engine_overload_sheds_at_bounded_queue():
    release = threading.Event()

    def slow_fn(x, k, beam):
        release.wait(30)
        n = x.shape[0]
        return (np.zeros((n, k), np.int32), np.zeros((n, k), np.float32))

    rows = np.zeros((1, 4), np.float32)
    eng = ServingEngine(slow_fn, row_budget=1, max_queue=4, max_wait_s=0.0)
    try:
        handles, sheds = [], 0
        # first submit occupies the dispatcher; queue then fills to max_queue
        for _ in range(12):
            try:
                handles.append(eng.submit(rows, k=3, beam=1))
            except EngineSaturated:
                sheds += 1
            time.sleep(0.01)
        st = eng.stats()
        assert sheds > 0 and st["shed"] == sheds
        assert st["max_queue_depth"] <= 4  # the bound held
        assert st["queue_depth"] <= 4
    finally:
        release.set()
        eng.close()
    # every admitted request still completes (close() drains)
    for h in handles:
        assert h.done()
        d, _ = h.result(timeout=1)
        assert d.shape == (1, 3)
    assert eng.stats()["completed"] == len(handles)


def test_engine_failure_propagates_to_handles():
    def bad_fn(x, k, beam):
        raise RuntimeError("engine exploded")

    with ServingEngine(bad_fn, row_budget=4, max_queue=8) as eng:
        h = eng.submit(np.zeros((2, 3), np.float32), k=2, beam=1)
        with pytest.raises(RuntimeError, match="engine exploded"):
            h.result(timeout=60)
    assert eng.stats()["failed"] == 1


# ---------------------------------------------------------------- deadlines

def test_engine_deadline_forces_early_dispatch():
    # max_wait is an eternity; a request deadline must force dispatch anyway
    tree, q = _mini_case()
    fn = make_search_fn(tree)
    fn(q[:1], 4, 2, chunk_rows=1)
    with ServingEngine(fn, row_budget=64, max_queue=8,
                       max_wait_s=30.0) as eng:
        t0 = time.perf_counter()
        h = eng.submit(q[:1], k=4, beam=2, deadline_s=0.05)
        got = h.result(timeout=10)
        waited = time.perf_counter() - t0
    assert waited < 5.0  # nowhere near max_wait_s
    _assert_bit_identical(got, _offline(tree, q[:1], 4, 2))


def test_engine_deadline_miss_flagged_answer_still_delivered():
    def slow_fn(x, k, beam):
        time.sleep(0.08)
        n = x.shape[0]
        return (np.zeros((n, k), np.int32), np.zeros((n, k), np.float32))

    with ServingEngine(slow_fn, row_budget=4, max_queue=8,
                       max_wait_s=0.0) as eng:
        h = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1,
                       deadline_s=0.001)
        d, s = h.result(timeout=60)
    assert h.deadline_missed
    assert d.shape == (1, 2)
    assert eng.stats()["deadline_misses"] == 1


def test_engine_waits_to_fill_until_forcing_point():
    # two staggered requests within max_wait coalesce into one batch
    tree, q = _mini_case()
    fn = make_search_fn(tree)
    fn(q[:1], 4, 2, chunk_rows=1)
    fn(q[:2], 4, 2, chunk_rows=1)
    with ServingEngine(fn, row_budget=64, max_queue=8,
                       max_wait_s=0.3) as eng:
        h1 = eng.submit(q[0:1], k=4, beam=2)
        time.sleep(0.02)
        h2 = eng.submit(q[1:2], k=4, beam=2)
        r1, r2 = h1.result(timeout=120), h2.result(timeout=120)
    st = eng.stats()
    assert st["n_batches"] == 1 and st["completed"] == 2
    _assert_bit_identical(r1, _offline(tree, q[0:1], 4, 2))
    _assert_bit_identical(r2, _offline(tree, q[1:2], 4, 2))


# ------------------------------------------------------------ cache staging

def test_engine_cache_stage_hits_and_bit_identity():
    tree, q = _mini_case()
    fn = make_search_fn(tree)
    for m in (1, 2):  # cache misses run at single-row chunking
        fn(q[:m], 5, 2, chunk_rows=1)
    cache = AnswerCache(32)
    with ServingEngine(fn, row_budget=8, max_queue=32, cache=cache,
                       tree=tree) as eng:
        first = eng.submit(q[0:1], k=5, beam=2).result(timeout=120)
        again = eng.submit(q[0:1], k=5, beam=2).result(timeout=120)
        # duplicate rows inside one request dedup to one engine row
        dup = eng.submit(np.concatenate([q[0:1], q[0:1]]), k=5,
                         beam=2).result(timeout=120)
    _assert_bit_identical(first, _offline(tree, q[0:1], 5, 2))
    _assert_bit_identical(again, first)
    # cache entries are per-row answers, so the reference for the dup
    # request is the single-row offline answer scattered to both rows
    d1, s1 = _offline(tree, q[0:1], 5, 2)
    _assert_bit_identical(
        dup, (np.concatenate([d1, d1]), np.concatenate([s1, s1])))
    st = eng.stats()
    assert st["cache"]["hits"] >= 2  # the repeat + both dup rows
    assert cache.stats["misses"] >= 1


def test_engine_cache_requires_tree():
    with pytest.raises(ValueError, match="tree"):
        ServingEngine(lambda x, k, b: None, cache=AnswerCache(4))


# ---------------------------------------------------------------- lifecycle

def test_engine_submit_after_close_raises():
    fn = lambda x, k, b: (np.zeros((x.shape[0], k), np.int32),
                          np.zeros((x.shape[0], k), np.float32))
    eng = ServingEngine(fn, row_budget=4, max_queue=4)
    eng.close()
    eng.close()  # idempotent
    with pytest.raises(EngineClosed):
        eng.submit(np.zeros((1, 3), np.float32))


def test_engine_submit_validation():
    fn = lambda x, k, b: (np.zeros((x.shape[0], k), np.int32),
                          np.zeros((x.shape[0], k), np.float32))
    with ServingEngine(fn, row_budget=4, max_queue=4) as eng:
        with pytest.raises(ValueError):
            eng.submit(np.zeros((3,), np.float32))  # not [r, d]
        with pytest.raises(ValueError):
            eng.submit(np.zeros((0, 3), np.float32))  # r = 0
        with pytest.raises(ValueError):
            eng.submit(np.zeros((1, 3), np.float32), k=0)
        with pytest.raises(ValueError):
            eng.submit(np.zeros((1, 3), np.float32), beam=0)


def test_engine_ctor_validation():
    fn = lambda x, k, b: None
    with pytest.raises(ValueError):
        ServingEngine(fn, row_budget=0)
    with pytest.raises(ValueError):
        ServingEngine(fn, max_queue=0)
    with pytest.raises(ValueError):
        ServingEngine(fn, max_wait_s=-1.0)


def test_result_handle_timeout():
    release = threading.Event()

    def slow_fn(x, k, beam):
        release.wait(30)
        return (np.zeros((x.shape[0], k), np.int32),
                np.zeros((x.shape[0], k), np.float32))

    eng = ServingEngine(slow_fn, row_budget=4, max_queue=4, max_wait_s=0.0)
    try:
        h = eng.submit(np.zeros((1, 3), np.float32), k=2, beam=1)
        with pytest.raises(TimeoutError):
            h.result(timeout=0.05)
    finally:
        release.set()
        eng.close()
    assert h.result(timeout=1)[0].shape == (1, 2)


# --------------------------------------------------------------- load side

def test_open_loop_arrivals_poisson_seeded():
    a = open_loop_arrivals(100.0, 50, seed=7)
    b = open_loop_arrivals(100.0, 50, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a[0] == 0.0 and (np.diff(a) >= 0).all()
    # mean gap ~ 1/rate
    assert np.mean(np.diff(a)) == pytest.approx(0.01, rel=0.6)
    with pytest.raises(ValueError):
        open_loop_arrivals(0.0, 5)
    with pytest.raises(ValueError):
        open_loop_arrivals(10.0, 0)


def test_run_load_end_to_end_and_report_lines():
    tree, q = _mini_case()
    fn = make_search_fn(tree)
    for s in (1, 2, 4, 8):
        fn(q[:s], 5, 2, chunk_rows=1)
    pool = request_pool(q, n_requests=24, rows_per_request=1, k=5, beam=2,
                        seed=1)
    with ServingEngine(fn, row_budget=8, max_queue=64,
                       max_wait_s=2e-3) as eng:
        stats = run_load(eng, pool, rate_qps=400.0, seed=2)
    assert stats["completed"] == stats["admitted"] == 24
    assert stats["shed"] == 0
    assert stats["target_qps"] == 400.0 and stats["offered_qps"] > 0
    assert stats["latency_ms"]["p50"] > 0 and stats["qps"] > 0
    lines = report_lines(stats, label="t")
    joined = "\n".join(lines)
    assert "t latency: p50=" in joined and "qps=" in joined
    assert "t batching:" in joined and "max_queue_depth=" in joined


def test_submit_all_counts_sheds_as_none():
    release = threading.Event()

    def slow_fn(x, k, beam):
        release.wait(30)
        return (np.zeros((x.shape[0], k), np.int32),
                np.zeros((x.shape[0], k), np.float32))

    pool = [(np.zeros((1, 3), np.float32), 2, 1) for _ in range(10)]
    eng = ServingEngine(slow_fn, row_budget=1, max_queue=2, max_wait_s=0.0)
    try:
        handles, stats = submit_all(eng, pool, rate_qps=1e6, seed=0)
    finally:
        release.set()
        eng.close()
    assert len(handles) == 10
    assert any(h is None for h in handles)  # sheds surfaced as None
    assert stats["target_qps"] == 1e6


def test_request_pool_shapes_and_validation():
    x = np.arange(40, dtype=np.float32).reshape(10, 4)
    pool = request_pool(x, n_requests=6, rows_per_request=3, k=4, beam=2,
                        seed=0)
    assert len(pool) == 6
    for rows, k, beam in pool:
        assert rows.shape == (3, 4) and (k, beam) == (4, 2)
    with pytest.raises(ValueError):
        request_pool(x, 3, rows_per_request=0)


# -------------------------------------------- sharded + store-backed paths

_SHARDED_SCRIPT = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {tests!r})
    import numpy as np, jax, jax.numpy as jnp
    from fixtures import clustered_corpus, store_case
    from repro.core import ktree as kt
    from repro.core.backend import shard_from_store
    from repro.core.engine import ServingEngine, make_search_fn
    from repro.core.query import topk_search_sharded
    from repro.core.store import open_store
    from repro.launch.engine import request_pool, run_load

    out = {{}}
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)

    def serve_and_compare(fn, q, tag, **eng_kw):
        for s in (2, 4, 8, 16):  # warm the chunk-aligned batch shapes
            fn(np.ascontiguousarray(q[:s]), 6, 3, chunk_rows=2)
        pool = request_pool(q, n_requests=20, rows_per_request=2, k=6,
                            beam=3, seed=5)
        with ServingEngine(fn, row_budget=16, max_queue=64,
                           max_wait_s=2e-3, **eng_kw) as eng:
            handles = [eng.submit(r, k=k, beam=b) for r, k, b in pool]
            res = [h.result(timeout=600) for h in handles]
        ok = True
        for (r, k, b), (d_e, s_e) in zip(pool, res):
            d_o, s_o = fn(r, k, b)
            ok = ok and bool((np.asarray(d_e) == np.asarray(d_o)).all())
            ok = ok and bool((np.asarray(s_e) == np.asarray(s_o)).all())
        st = eng.stats()
        out[tag] = dict(bit_identical=ok, completed=st["completed"],
                        failed=st["failed"],
                        peak_store=st["peak_batch_store_bytes"])

    # in-memory sharded corpus (uneven remainder over 8 shards)
    x = clustered_corpus(rng, n_clusters=5, per_cluster=60, d=8)
    tree = kt.build(jnp.asarray(x), order=8, batch_size=32)
    q = (x[:64] + 0.05 * rng.normal(0, 1, (64, 8))).astype(np.float32)
    serve_and_compare(make_search_fn(tree, mesh=mesh, corpus=x), q,
                      "sharded_mem")

    # store-backed sharded corpus: block caches report per-batch residency
    with tempfile.TemporaryDirectory() as td:
        case = store_case(td, sparse=False)
        store = open_store(case.path)
        sshards = shard_from_store(mesh, store, budget_bytes=1 << 16)
        fn = make_search_fn(case.tree, mesh=mesh, corpus=sshards)
        qs = case.x[:32].astype(np.float32)
        serve_and_compare(
            fn, qs, "sharded_store",
            block_caches=[p.store.cache for p in sshards.parts])
        out["budget_bound"] = dict(
            peak=out["sharded_store"]["peak_store"],
            bound=8 * (1 << 16),
        )
    print(json.dumps(out))
    """
)


@pytest.mark.slow
def test_engine_sharded_and_store_backed_bit_identity():
    script = _SHARDED_SCRIPT.format(src=_SRC, tests=_TESTS)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=1200,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    for tag in ("sharded_mem", "sharded_store"):
        assert out[tag]["bit_identical"], out[tag]
        assert out[tag]["completed"] == 20 and out[tag]["failed"] == 0
    # a store-backed batch touched disk and stayed within the budget bound
    assert out["sharded_store"]["peak_store"] > 0
    assert out["budget_bound"]["peak"] <= out["budget_bound"]["bound"]
