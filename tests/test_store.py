"""Out-of-core corpus store (DESIGN.md §9): block round-trips, LRU residency
budget, store-backed vs in-memory bit-identical build + top-k for both
backends (uneven last block, k > docs-per-block), async block prefetch
(reader thread, exact cache stats), store growth (append /
insert_into_store, manifest rotation), manifest-reference checkpoints, and
the regenerated-in-place staleness guards (restore_index + answer-cache
corpus token)."""
import os
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from fixtures import assert_trees_equal, random_corpus, store_case
from repro.ckpt import restore_index, save_index
from repro.core import ktree as kt
from repro.core.backend import backend_from_store, make_backend
from repro.core.query import AnswerCache, topk_search, topk_search_cached
from repro.core.store import (
    BlockCache, Prefetcher, StoreSlice, open_store, save_store,
)
from repro.sparse.csr import csr_from_dense


def planted(rng, n=210, d=12, sparse=False):
    """Shared seeded corpus (tests/fixtures.py) — kept as a local alias for
    the cases below that draw several corpora from one rng."""
    return random_corpus(rng, n=n, d=d, sparse=sparse)


@pytest.fixture(scope="module")
def dense_case(tmp_path_factory):
    # 210 docs, block 64 → uneven last block (18 rows)
    c = store_case(tmp_path_factory.mktemp("dense"), sparse=False, seed=0)
    return c.x, c.path, c.tree


@pytest.fixture(scope="module")
def ell_case(tmp_path_factory):
    c = store_case(tmp_path_factory.mktemp("ell"), sparse=True, seed=2,
                   n=170, d=20, tree_seed=3)
    return c.data, c.path, c.tree


# --- round trips ------------------------------------------------------------

def test_dense_roundtrip_uneven_last_block(dense_case):
    x, path, _ = dense_case
    store = open_store(path)
    assert store.kind == "dense" and store.n_docs == 210
    assert store.n_blocks == 4 and store.block_docs == 64
    np.testing.assert_array_equal(store.take_rows(np.arange(210))["x"], x)
    # scrambled + repeated rows across block boundaries
    rows = np.array([209, 0, 63, 64, 127, 128, 0, 209])
    np.testing.assert_array_equal(store.take_rows(rows)["x"], x[rows])
    # last block is padded on disk but padding rows are unaddressable
    with pytest.raises(IndexError):
        store.take_rows(np.array([210]))
    with pytest.raises(IndexError):
        store.read_block(4)


def test_ell_roundtrip_matches_inmemory_backend(ell_case):
    m, path, _ = ell_case
    be_mem = make_backend(m)
    be_st = backend_from_store(open_store(path))
    for field in ("values", "cols", "sq", "csr_indptr"):
        np.testing.assert_array_equal(
            np.asarray(getattr(be_mem, field)),
            np.asarray(getattr(be_st, field)), err_msg=field,
        )
    # chunk backends pad the CSR arrays to the static B·nnz_max capacity
    # (compile-cache stability); the valid prefix must match the in-memory
    # CSR and the padding must be inert zeros past indptr[-1]
    nnz = int(np.asarray(be_mem.csr_indptr)[-1])
    for field in ("csr_data", "csr_indices"):
        got = np.asarray(getattr(be_st, field))
        np.testing.assert_array_equal(
            np.asarray(getattr(be_mem, field)), got[:nnz], err_msg=field)
        assert (got[nnz:] == 0).all(), f"{field} padding not zero"
    assert be_mem.n_cols == be_st.n_cols
    # and the densify path (the only CSR consumer) agrees exactly
    rows = jnp.arange(be_mem.n_docs, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(be_mem.take(rows)), np.asarray(be_st.take(rows)))


def test_ell_store_verify_passes_and_catches_corruption(ell_case, tmp_path):
    """verify=True must accept an intact ELL store (digest concatenation
    order has to survive the manifest's sorted-JSON round trip) and reject a
    tampered block."""
    import shutil

    _, path, _ = ell_case
    open_store(path, verify=True)
    bad = str(tmp_path / "bad-ell")
    shutil.copytree(path, bad)
    victim = os.path.join(bad, sorted(
        f for f in os.listdir(bad) if f.startswith("ell_values"))[0])
    blk = np.load(victim).copy()
    blk.flat[0] += 1.0
    np.save(victim, blk)
    with pytest.raises(ValueError, match="digest"):
        open_store(bad, verify=True)


def test_open_store_verify_and_format_guard(dense_case, tmp_path):
    _, path, _ = dense_case
    open_store(path, verify=True)  # digests match what was written
    # corrupt one block file → verify must refuse
    import shutil

    bad = str(tmp_path / "bad")
    shutil.copytree(path, bad)
    victim = os.path.join(bad, sorted(
        f for f in os.listdir(bad) if f.endswith(".npy"))[0])
    blk = np.load(victim)
    blk = blk.copy()
    blk.flat[0] += 1.0
    np.save(victim, blk)
    open_store(bad)  # lazy open still fine
    with pytest.raises(ValueError, match="digest"):
        open_store(bad, verify=True)
    with pytest.raises(FileNotFoundError):
        open_store(str(tmp_path / "nowhere"))
    # unknown format tag refuses outright
    import json

    from repro.core.store import MANIFEST_NAME

    mpath = os.path.join(bad, MANIFEST_NAME)
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format"] = "not-a-ktree-store"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="format"):
        open_store(bad)


# --- residency budget -------------------------------------------------------

def test_block_cache_budget_and_eviction():
    loads = []

    def loader(i):
        loads.append(i)
        return {"x": np.zeros((4, 2), np.float32)}  # 32 bytes/block

    cache = BlockCache(budget_bytes=64, loader=loader)  # 2 blocks fit
    cache.get(0); cache.get(1); cache.get(0)
    assert cache.stats["hits"] == 1 and loads == [0, 1]
    cache.get(2)  # evicts 1 (LRU; 0 was refreshed)
    assert cache.stats["evictions"] == 1
    cache.get(0)
    assert loads == [0, 1, 2]  # 0 stayed resident
    cache.get(1)
    assert loads == [0, 1, 2, 1]  # 1 was the eviction victim
    assert cache.resident_bytes <= 64

    # a single block above budget is still admitted (one-block floor)
    cache = BlockCache(budget_bytes=1, loader=loader)
    cache.get(0); cache.get(1)
    assert cache.stats["resident_blocks"] == 1
    with pytest.raises(ValueError):
        BlockCache(budget_bytes=0, loader=loader)


def test_store_under_budget_evicts_and_still_exact(dense_case):
    x, path, tree = dense_case
    store = open_store(path, budget_bytes=1)  # one-block floor
    d_mem, s_mem = topk_search(tree, jnp.asarray(x), k=5, beam=3, chunk=64)
    d_st, s_st = topk_search(tree, store, k=5, beam=3, chunk=64)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)
    stats = store.cache.stats
    assert stats["evictions"] > 0 and stats["resident_blocks"] == 1


# --- store-backed vs in-memory equivalence ----------------------------------

@pytest.mark.parametrize("chunk", [32, 50, 512])
def test_dense_store_query_bit_identical(dense_case, chunk):
    """Chunk 50 exercises non-pow2 bucketing mid-stream; 512 > n runs one
    chunk; k=7 > last block's 18 valid docs is irrelevant to correctness but
    k spans blocks regardless."""
    x, path, tree = dense_case
    store = open_store(path)
    d_mem, s_mem = topk_search(tree, jnp.asarray(x), k=7, beam=3, chunk=chunk)
    d_st, s_st = topk_search(tree, store, k=7, beam=3, chunk=chunk)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)


def test_dense_store_k_exceeds_block_docs(tmp_path):
    """k larger than docs-per-block: answers must still merge across blocks
    bit-identically (block granularity is invisible to the engine)."""
    rng = np.random.default_rng(7)
    x = planted(rng, n=90, d=8)
    path = str(tmp_path / "tiny-blocks")
    save_store(path, x, block_docs=8)  # k=20 > 8 docs per block
    store = open_store(path, budget_bytes=1)
    tree = kt.build(jnp.asarray(x), order=5, batch_size=16,
                    key=jax.random.PRNGKey(8))
    d_mem, s_mem = topk_search(tree, jnp.asarray(x), k=20, beam=4)
    d_st, s_st = topk_search(tree, store, k=20, beam=4)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)


def test_ell_store_query_bit_identical(ell_case):
    m, path, tree = ell_case
    store = open_store(path, budget_bytes=1)
    d_mem, s_mem = topk_search(tree, m, k=6, beam=3, chunk=48)
    d_st, s_st = topk_search(tree, store, k=6, beam=3, chunk=48)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)


def test_ell_chunk_backends_share_one_compile(ell_case):
    """ELL chunk backends must not retrace per chunk: a chunk's true nnz
    varies, so the CSR side is padded to the static B·nnz_max capacity —
    without it every chunk misses the jit cache (regression for the
    per-chunk recompile bug)."""
    from repro.core import query as q_mod

    m, path, tree = ell_case
    store = open_store(path)
    topk_search(tree, store, k=3, beam=2, chunk=32)  # warm all buckets
    before = q_mod._beam_search._cache_size()
    topk_search(tree, store, k=3, beam=2, chunk=32)  # 6 chunks over 170 docs
    assert q_mod._beam_search._cache_size() == before


def test_store_slice_matches_row_range(dense_case):
    x, path, tree = dense_case
    store = open_store(path)
    full, fulld = topk_search(tree, jnp.asarray(x), k=4, beam=2, chunk=40)
    sl = store.view(30, 110)
    assert isinstance(sl, StoreSlice) and sl.n_docs == 80
    part, partd = topk_search(tree, sl, k=4, beam=2, chunk=40)
    np.testing.assert_array_equal(full[30:110], part)
    np.testing.assert_array_equal(fulld[30:110], partd)
    with pytest.raises(ValueError):
        store.view(5, 1000)
    # slice-local bounds: ids past the view (or negative) must raise, not
    # silently resolve to other parent rows after the +lo offset
    with pytest.raises(IndexError):
        sl.take_rows(np.array([80]))
    with pytest.raises(IndexError):
        sl.take_rows(np.array([-1]))


def test_streaming_build_bit_identical_both_backends(dense_case, ell_case):
    x, dpath, dtree = dense_case
    m, epath, etree = ell_case
    st_d = open_store(dpath, budget_bytes=1)
    assert_trees_equal(
        dtree, kt.build_from_store(st_d, order=6, batch_size=32,
                                   key=jax.random.PRNGKey(1)))
    st_e = open_store(epath, budget_bytes=1)
    grown = kt.build_from_store(st_e, order=6, medoid=True, batch_size=32,
                                key=jax.random.PRNGKey(3))
    assert_trees_equal(etree, grown)
    kt.check_invariants(grown, n_docs=170)


def test_dim_mismatch_guard(dense_case, tmp_path):
    _, _, tree = dense_case
    path = str(tmp_path / "wrong-dim")
    save_store(path, planted(np.random.default_rng(9), n=40, d=5),
               block_docs=16)
    with pytest.raises(ValueError, match="query dim"):
        topk_search(tree, open_store(path), k=3)


# --- manifest-reference checkpoints -----------------------------------------

def test_save_restore_index_by_manifest_reference(dense_case, tmp_path):
    x, path, tree = dense_case
    store = open_store(path)
    idx = str(tmp_path / "idx")
    out = save_index(idx, tree, store)
    assert out == idx
    # the checkpoint holds the tree + a reference, never the corpus blocks
    assert sorted(os.listdir(idx)) == ["INDEX.json", "tree.npz"]
    tree2, store2 = restore_index(idx, budget_bytes=1)
    assert_trees_equal(tree, tree2)
    assert store2.manifest_hash == store.manifest_hash
    d1, _ = topk_search(tree, jnp.asarray(x), k=4, beam=2)
    d2, _ = topk_search(tree2, store2, k=4, beam=2)
    np.testing.assert_array_equal(d1, d2)


def test_restore_index_refuses_regenerated_store(tmp_path):
    rng = np.random.default_rng(11)
    x = planted(rng, n=80, d=6)
    spath = str(tmp_path / "store")
    save_store(spath, x, block_docs=32)
    store = open_store(spath)
    tree = kt.build(jnp.asarray(x), order=5, batch_size=16,
                    key=jax.random.PRNGKey(12))
    idx = str(tmp_path / "idx")
    save_index(idx, tree, store)
    # regenerate the corpus in place: same path, different content
    save_store(spath, planted(rng, n=80, d=6), block_docs=32)
    with pytest.raises(ValueError, match="rewritten in place"):
        restore_index(idx)
    tree3, store3 = restore_index(idx, check=False)  # explicit override
    assert_trees_equal(tree, tree3)


# --- answer-cache staleness regression (the PR's bugfix) --------------------

def test_cache_corpus_token_invalidates_on_store_regeneration(tmp_path):
    """A store regenerated in place under an unchanged tree object must not
    serve stale cached answers: keying on the manifest content hash flushes
    the cache when the corpus identity changes."""
    rng = np.random.default_rng(13)
    x = planted(rng, n=100, d=8)
    spath = str(tmp_path / "store")
    save_store(spath, x, block_docs=32)
    store = open_store(spath)
    tree = kt.build(jnp.asarray(x), order=5, batch_size=16,
                    key=jax.random.PRNGKey(14))
    cache = AnswerCache(64)
    q = x[:10]
    topk_search_cached(tree, q, cache, k=3, beam=2,
                       corpus_token=store.manifest_hash)
    assert cache.misses == 10 and len(cache) == 10
    topk_search_cached(tree, q, cache, k=3, beam=2,
                       corpus_token=store.manifest_hash)
    assert cache.hits == 10  # same corpus → replay from cache

    # regenerate in place: same path + same tree object, different content
    save_store(spath, planted(rng, n=100, d=8), block_docs=32)
    new_store = open_store(spath)
    assert new_store.manifest_hash != store.manifest_hash
    topk_search_cached(tree, q, cache, k=3, beam=2,
                       corpus_token=new_store.manifest_hash)
    # without the token fix these 10 would all be (stale) hits
    assert cache.hits == 10 and cache.misses == 20

    # the pre-fix behaviour (no token) is the hole: same tree object hits
    legacy = AnswerCache(64)
    topk_search_cached(tree, q, legacy, k=3, beam=2)
    topk_search_cached(tree, q, legacy, k=3, beam=2)
    assert legacy.hits == 10


def test_answer_cache_rebind_same_pair_is_noop():
    """Rebinding the cache to the *same* (index object, corpus token) pair —
    what every topk_search_cached call does — must keep entries and counters;
    only a different index or token flushes."""
    cache = AnswerCache(8)
    tree_token = object()
    cache.bind(tree_token, "hash-a")
    key = AnswerCache.make_key(np.ones(4, np.float32), 3, 2)
    cache.put(key, (np.zeros(3, np.int32), np.zeros(3, np.float32)))
    assert cache.get(key) is not None and cache.hits == 1
    cache.bind(tree_token, "hash-a")  # rebind: must be a no-op
    assert len(cache) == 1
    assert cache.get(key) is not None
    assert cache.hits == 2 and cache.misses == 0
    cache.bind(tree_token, "hash-b")  # changed token: flush (entries only)
    assert len(cache) == 0 and cache.hits == 2 and cache.misses == 0


# --- async block prefetch (DESIGN.md §9) ------------------------------------

def test_prefetcher_order_errors_and_close():
    fetched = []

    def fetch(i):
        fetched.append(i)
        return i * 10

    assert list(Prefetcher(range(6), fetch, depth=2)) == [
        (i, i * 10) for i in range(6)
    ]
    assert fetched == list(range(6))

    with pytest.raises(ValueError):
        Prefetcher(range(3), fetch, depth=0)

    def boom(i):
        if i == 2:
            raise RuntimeError("disk gone")
        return i

    got = []
    with pytest.raises(RuntimeError, match="disk gone"):
        for req, res in Prefetcher(range(5), boom, depth=1):
            got.append(req)
    assert got == [0, 1]

    # early close stops the worker without draining the request stream
    pf = Prefetcher(range(10**6), lambda i: i, depth=1)
    it = iter(pf)
    assert next(it) == (0, 0)
    pf.close()
    assert not pf._thread.is_alive()


@pytest.mark.parametrize("depth", [1, 2])
def test_prefetch_query_bit_identical(dense_case, ell_case, depth):
    """topk_search with an async reader thread must answer exactly like the
    synchronous store path (which itself bit-matches in-memory)."""
    x, dpath, dtree = dense_case
    d_sync, s_sync = topk_search(dtree, open_store(dpath, budget_bytes=1),
                                 k=7, beam=3, chunk=50)
    d_pf, s_pf = topk_search(dtree, open_store(dpath, budget_bytes=1),
                             k=7, beam=3, chunk=50, prefetch=depth)
    np.testing.assert_array_equal(d_sync, d_pf)
    np.testing.assert_array_equal(s_sync, s_pf)
    m, epath, etree = ell_case
    d_sync, s_sync = topk_search(etree, open_store(epath, budget_bytes=1),
                                 k=6, beam=3, chunk=48)
    d_pf, s_pf = topk_search(etree, open_store(epath, budget_bytes=1),
                             k=6, beam=3, chunk=48, prefetch=depth)
    np.testing.assert_array_equal(d_sync, d_pf)
    np.testing.assert_array_equal(s_sync, s_pf)


def test_prefetch_build_and_stream_bit_identical(dense_case):
    """Streaming build and the streamed ground truth must be invariant to the
    reader thread (depth 1 and 2)."""
    from repro.core.query import brute_force_topk_stream

    x, path, tree = dense_case
    for depth in (1, 2):
        st = open_store(path, budget_bytes=1)
        assert_trees_equal(
            tree, kt.build_from_store(st, order=6, batch_size=32,
                                      key=jax.random.PRNGKey(1),
                                      prefetch=depth))
    # ground truth: block scan through a reader thread == synchronous scan
    def blocks(prefetch):
        st = open_store(path, budget_bytes=1)
        for lo, hi, arrays in st.iter_blocks(prefetch=prefetch):
            yield lo, arrays["x"][: hi - lo]

    x_q = np.asarray(x[:20])
    np.testing.assert_array_equal(
        brute_force_topk_stream(x_q, blocks(0), 9),
        brute_force_topk_stream(x_q, blocks(2), 9),
    )


def test_block_cache_stats_exact_under_racing_reader(dense_case):
    """A reader thread racing the consumer loop on one cache: every get lands
    exactly one hit-or-miss, eviction accounting matches, and the one-block
    floor holds at budget=1 byte throughout."""
    _, path, _ = dense_case
    store = open_store(path, budget_bytes=1)
    n_iters, errs = 6, []
    rows = np.arange(store.n_docs)

    def hammer():
        try:
            for _ in range(n_iters):
                store.take_rows(rows)  # touches every block, in order
        except Exception as e:  # pragma: no cover - surfaced via errs
            errs.append(e)

    threads = [threading.Thread(target=hammer) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    cache = store.cache
    total_gets = 2 * n_iters * store.n_blocks
    assert cache.hits + cache.misses == total_gets
    # every loaded block except the one still resident was evicted
    assert cache.evictions == cache.misses - 1
    assert cache.stats["resident_blocks"] == 1
    one_block = cache._block_bytes(store._load_block(0))
    assert cache.resident_bytes == one_block


# --- store growth: append + insert_into_store (DESIGN.md §9) ----------------

def test_append_fills_tail_and_extends_manifest(tmp_path):
    rng = np.random.default_rng(21)
    x = planted(rng, n=100, d=6)
    path = str(tmp_path / "grow")
    save_store(path, x, block_docs=32)  # 4 blocks, last holds 4 valid rows
    store = open_store(path)
    h0 = store.manifest_hash
    stale = open_store(path)  # opened before the append: keeps its manifest
    x2 = planted(rng, n=70, d=6)
    h1 = store.append(x2)
    assert h1 == store.manifest_hash != h0
    assert store.n_docs == 170 and store.n_blocks == 6
    full = np.concatenate([x, x2])
    np.testing.assert_array_equal(store.take_rows(np.arange(170))["x"], full)
    # all digests (incl. the rewritten tail block) match the new manifest
    open_store(path, verify=True)
    re = open_store(path)
    assert re.n_docs == 170 and re.manifest_hash == h1
    # pre-append handles keep their old view of the old rows
    assert stale.n_docs == 100 and stale.manifest_hash == h0
    np.testing.assert_array_equal(stale.take_rows(np.arange(100))["x"], x)
    with pytest.raises(IndexError):
        stale.take_rows(np.array([150]))
    # appending the empty batch is a no-op on the manifest
    assert store.append(np.zeros((0, 6), np.float32)) == h1
    # layout guards: wrong dim refuses, store sources refuse
    with pytest.raises(ValueError, match="dim"):
        store.append(np.zeros((3, 9), np.float32))
    with pytest.raises(TypeError):
        store.append(store)


def test_append_crash_window_keeps_old_manifest_verifiable(tmp_path):
    """The append crash contract: every file append writes (incl. the merged
    tail block, which lands under a fresh generation name) is unreferenced by
    the old manifest — so a crash after the file writes but before the
    manifest replace leaves the previous store fully *verifiable*, not just
    readable."""
    from repro.core.store import MANIFEST_NAME

    rng = np.random.default_rng(24)
    x = planted(rng, n=100, d=6)
    path = str(tmp_path / "crash")
    save_store(path, x, block_docs=32)  # tail block holds 4 valid rows
    mpath = os.path.join(path, MANIFEST_NAME)
    with open(mpath) as f:
        old_manifest = f.read()
    store = open_store(path)
    store.append(planted(rng, n=40, d=6))
    open_store(path, verify=True)  # grown state verifies
    # simulate the crash window: all block files on disk, manifest replace
    # never happened → restore the old manifest and verify against it
    with open(mpath, "w") as f:
        f.write(old_manifest)
    st = open_store(path, verify=True)
    assert st.n_docs == 100
    np.testing.assert_array_equal(st.take_rows(np.arange(100))["x"], x)


def test_append_exact_block_boundary(tmp_path):
    """Appending to a store whose last block is exactly full must start a
    fresh block (no tail rewrite)."""
    rng = np.random.default_rng(22)
    x = planted(rng, n=64, d=5)
    path = str(tmp_path / "full")
    save_store(path, x, block_docs=32)
    store = open_store(path)
    digests0 = [e["digest"] for e in store.manifest["blocks"]]
    x2 = planted(rng, n=10, d=5)
    store.append(x2)
    assert store.n_docs == 74 and store.n_blocks == 3
    # the two original block files were not touched
    assert [e["digest"] for e in store.manifest["blocks"][:2]] == digests0
    np.testing.assert_array_equal(
        store.take_rows(np.arange(74))["x"], np.concatenate([x, x2]))
    open_store(path, verify=True)


def test_ell_append_relayouts_at_store_width(ell_case, tmp_path):
    """ELL append re-lays new rows at the store's recorded nnz_max width and
    the grown store still round-trips through chunk backends."""
    import shutil

    m, path, tree = ell_case
    grow = str(tmp_path / "ell-grow")
    shutil.copytree(path, grow)
    store = open_store(grow)
    rng = np.random.default_rng(23)
    x2 = planted(rng, n=25, d=20, sparse=True)
    m2 = csr_from_dense(x2)
    store.append(m2)
    assert store.n_docs == 195
    open_store(grow, verify=True)
    be = backend_from_store(open_store(grow))
    assert be.nnz_max == store.nnz_max
    got = np.asarray(be.take(jnp.arange(170, 195, dtype=jnp.int32)))
    np.testing.assert_array_equal(got, x2)


def test_insert_into_store_matches_shadow_and_roundtrips_ckpt(tmp_path):
    """Store-backed insert: tree bit-matches the in-memory shadow insert, the
    rotated manifest_hash invalidates the pre-insert index checkpoint, and a
    fresh save_index/restore_index round-trips the grown index."""
    rng = np.random.default_rng(31)
    x = planted(rng, n=120, d=8)
    path = str(tmp_path / "store")
    save_store(path, x, block_docs=32)
    store = open_store(path)
    tree = kt.build(jnp.asarray(x), order=6, batch_size=32,
                    key=jax.random.PRNGKey(7))
    idx_old = str(tmp_path / "idx-old")
    save_index(idx_old, tree, store)

    x2 = planted(rng, n=50, d=8)
    h0 = store.manifest_hash
    tree2 = kt.insert_into_store(tree, store, x2, key=jax.random.PRNGKey(8))
    assert store.n_docs == 170 and store.manifest_hash != h0
    kt.check_invariants(tree2, n_docs=170)
    shadow = kt.insert(tree, jnp.asarray(x2), np.arange(120, 170),
                       key=jax.random.PRNGKey(8))
    assert_trees_equal(tree2, shadow)

    # the pre-insert checkpoint now references a rotated corpus: refuse
    with pytest.raises(ValueError, match="rewritten in place"):
        restore_index(idx_old)
    # a fresh checkpoint of the grown index round-trips
    idx_new = str(tmp_path / "idx-new")
    save_index(idx_new, tree2, store)
    tree3, store3 = restore_index(idx_new, budget_bytes=1)
    assert_trees_equal(tree2, tree3)
    assert store3.n_docs == 170 and store3.manifest_hash == store.manifest_hash
    full = np.concatenate([x, x2])
    d_st, s_st = topk_search(tree3, store3, k=5, beam=3)
    d_mem, s_mem = topk_search(tree2, jnp.asarray(full), k=5, beam=3)
    np.testing.assert_array_equal(d_st, d_mem)
    np.testing.assert_array_equal(s_st, s_mem)


def test_insert_into_store_flushes_stale_answer_cache(tmp_path):
    """Answers cached against the pre-insert corpus token must miss after
    insert_into_store rotates the manifest hash (same tree object would
    otherwise serve doc ids over a changed corpus)."""
    rng = np.random.default_rng(33)
    x = planted(rng, n=80, d=6)
    path = str(tmp_path / "store")
    save_store(path, x, block_docs=32)
    store = open_store(path)
    tree = kt.build(jnp.asarray(x), order=5, batch_size=16,
                    key=jax.random.PRNGKey(9))
    cache = AnswerCache(64)
    q = x[:8]
    topk_search_cached(tree, q, cache, k=3, beam=2,
                       corpus_token=store.manifest_hash)
    topk_search_cached(tree, q, cache, k=3, beam=2,
                       corpus_token=store.manifest_hash)
    assert cache.hits == 8 and cache.misses == 8

    tree2 = kt.insert_into_store(tree, store, planted(rng, n=20, d=6),
                                 key=jax.random.PRNGKey(10))
    # new tree object AND new token — either alone must flush; together they
    # must too (the regression: stale answers after in-place growth)
    topk_search_cached(tree2, q, cache, k=3, beam=2,
                       corpus_token=store.manifest_hash)
    assert cache.hits == 8 and cache.misses == 16


# --- partitions (store side of sharded serving) -----------------------------

def test_partition_ownership_and_isolated_caches(dense_case):
    x, path, _ = dense_case
    store = open_store(path, budget_bytes=1)
    parts = store.partition(4, budget_bytes=1)
    # contiguous cover of [0, n) at the shard_rows extent (ceil(210/4)=53)
    bounds = [(p.lo, p.hi) for p in parts]
    assert bounds == [(0, 53), (53, 106), (106, 159), (159, 210)]
    for s, p in enumerate(parts):
        lo, hi = bounds[s]
        np.testing.assert_array_equal(
            p.take_rows(np.arange(hi - lo))["x"], x[lo:hi])
    # partition reads never touch the parent handle's cache, or each other's
    assert store.cache.stats["misses"] == 0
    miss_counts = [p.store.cache.misses for p in parts]
    assert all(m >= 1 for m in miss_counts)
    assert all(p.store.cache.stats["resident_blocks"] == 1 for p in parts)
    with pytest.raises(ValueError):
        store.partition(0)
