"""Out-of-core corpus store (DESIGN.md §9): block round-trips, LRU residency
budget, store-backed vs in-memory bit-identical build + top-k for both
backends (uneven last block, k > docs-per-block), manifest-reference
checkpoints, and the regenerated-in-place staleness guards (restore_index +
answer-cache corpus token)."""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import restore_index, save_index
from repro.core import ktree as kt
from repro.core.backend import backend_from_store, make_backend
from repro.core.query import AnswerCache, topk_search, topk_search_cached
from repro.core.store import (
    BlockCache, StoreSlice, open_store, save_store,
)
from repro.sparse.csr import csr_from_dense


def planted(rng, n=210, d=12, sparse=False):
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    if sparse:
        x = (x * (rng.random((n, d)) < 0.4)).astype(np.float32)
        x[np.arange(n), rng.integers(0, d, n)] += 1.0
    return x


def assert_trees_equal(a, b):
    assert a.order == b.order and a.medoid == b.medoid
    for f in dataclasses.fields(a):
        if f.metadata.get("static"):
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name)),
            err_msg=f.name,
        )


@pytest.fixture(scope="module")
def dense_case(tmp_path_factory):
    rng = np.random.default_rng(0)
    x = planted(rng)  # 210 docs, block 64 → uneven last block (18 rows)
    path = str(tmp_path_factory.mktemp("dense") / "store")
    save_store(path, x, block_docs=64)
    tree = kt.build(jnp.asarray(x), order=6, batch_size=32,
                    key=jax.random.PRNGKey(1))
    return x, path, tree


@pytest.fixture(scope="module")
def ell_case(tmp_path_factory):
    rng = np.random.default_rng(2)
    x = planted(rng, n=170, d=20, sparse=True)
    m = csr_from_dense(x)
    path = str(tmp_path_factory.mktemp("ell") / "store")
    save_store(path, m, block_docs=64)
    tree = kt.build(m, order=6, medoid=True, batch_size=32,
                    key=jax.random.PRNGKey(3))
    return m, path, tree


# --- round trips ------------------------------------------------------------

def test_dense_roundtrip_uneven_last_block(dense_case):
    x, path, _ = dense_case
    store = open_store(path)
    assert store.kind == "dense" and store.n_docs == 210
    assert store.n_blocks == 4 and store.block_docs == 64
    np.testing.assert_array_equal(store.take_rows(np.arange(210))["x"], x)
    # scrambled + repeated rows across block boundaries
    rows = np.array([209, 0, 63, 64, 127, 128, 0, 209])
    np.testing.assert_array_equal(store.take_rows(rows)["x"], x[rows])
    # last block is padded on disk but padding rows are unaddressable
    with pytest.raises(IndexError):
        store.take_rows(np.array([210]))
    with pytest.raises(IndexError):
        store.read_block(4)


def test_ell_roundtrip_matches_inmemory_backend(ell_case):
    m, path, _ = ell_case
    be_mem = make_backend(m)
    be_st = backend_from_store(open_store(path))
    for field in ("values", "cols", "sq", "csr_indptr"):
        np.testing.assert_array_equal(
            np.asarray(getattr(be_mem, field)),
            np.asarray(getattr(be_st, field)), err_msg=field,
        )
    # chunk backends pad the CSR arrays to the static B·nnz_max capacity
    # (compile-cache stability); the valid prefix must match the in-memory
    # CSR and the padding must be inert zeros past indptr[-1]
    nnz = int(np.asarray(be_mem.csr_indptr)[-1])
    for field in ("csr_data", "csr_indices"):
        got = np.asarray(getattr(be_st, field))
        np.testing.assert_array_equal(
            np.asarray(getattr(be_mem, field)), got[:nnz], err_msg=field)
        assert (got[nnz:] == 0).all(), f"{field} padding not zero"
    assert be_mem.n_cols == be_st.n_cols
    # and the densify path (the only CSR consumer) agrees exactly
    rows = jnp.arange(be_mem.n_docs, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(be_mem.take(rows)), np.asarray(be_st.take(rows)))


def test_ell_store_verify_passes_and_catches_corruption(ell_case, tmp_path):
    """verify=True must accept an intact ELL store (digest concatenation
    order has to survive the manifest's sorted-JSON round trip) and reject a
    tampered block."""
    import shutil

    _, path, _ = ell_case
    open_store(path, verify=True)
    bad = str(tmp_path / "bad-ell")
    shutil.copytree(path, bad)
    victim = os.path.join(bad, sorted(
        f for f in os.listdir(bad) if f.startswith("ell_values"))[0])
    blk = np.load(victim).copy()
    blk.flat[0] += 1.0
    np.save(victim, blk)
    with pytest.raises(ValueError, match="digest"):
        open_store(bad, verify=True)


def test_open_store_verify_and_format_guard(dense_case, tmp_path):
    _, path, _ = dense_case
    open_store(path, verify=True)  # digests match what was written
    # corrupt one block file → verify must refuse
    import shutil

    bad = str(tmp_path / "bad")
    shutil.copytree(path, bad)
    victim = os.path.join(bad, sorted(
        f for f in os.listdir(bad) if f.endswith(".npy"))[0])
    blk = np.load(victim)
    blk = blk.copy()
    blk.flat[0] += 1.0
    np.save(victim, blk)
    open_store(bad)  # lazy open still fine
    with pytest.raises(ValueError, match="digest"):
        open_store(bad, verify=True)
    with pytest.raises(FileNotFoundError):
        open_store(str(tmp_path / "nowhere"))
    # unknown format tag refuses outright
    import json

    from repro.core.store import MANIFEST_NAME

    mpath = os.path.join(bad, MANIFEST_NAME)
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format"] = "not-a-ktree-store"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="format"):
        open_store(bad)


# --- residency budget -------------------------------------------------------

def test_block_cache_budget_and_eviction():
    loads = []

    def loader(i):
        loads.append(i)
        return {"x": np.zeros((4, 2), np.float32)}  # 32 bytes/block

    cache = BlockCache(budget_bytes=64, loader=loader)  # 2 blocks fit
    cache.get(0); cache.get(1); cache.get(0)
    assert cache.stats["hits"] == 1 and loads == [0, 1]
    cache.get(2)  # evicts 1 (LRU; 0 was refreshed)
    assert cache.stats["evictions"] == 1
    cache.get(0)
    assert loads == [0, 1, 2]  # 0 stayed resident
    cache.get(1)
    assert loads == [0, 1, 2, 1]  # 1 was the eviction victim
    assert cache.resident_bytes <= 64

    # a single block above budget is still admitted (one-block floor)
    cache = BlockCache(budget_bytes=1, loader=loader)
    cache.get(0); cache.get(1)
    assert cache.stats["resident_blocks"] == 1
    with pytest.raises(ValueError):
        BlockCache(budget_bytes=0, loader=loader)


def test_store_under_budget_evicts_and_still_exact(dense_case):
    x, path, tree = dense_case
    store = open_store(path, budget_bytes=1)  # one-block floor
    d_mem, s_mem = topk_search(tree, jnp.asarray(x), k=5, beam=3, chunk=64)
    d_st, s_st = topk_search(tree, store, k=5, beam=3, chunk=64)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)
    stats = store.cache.stats
    assert stats["evictions"] > 0 and stats["resident_blocks"] == 1


# --- store-backed vs in-memory equivalence ----------------------------------

@pytest.mark.parametrize("chunk", [32, 50, 512])
def test_dense_store_query_bit_identical(dense_case, chunk):
    """Chunk 50 exercises non-pow2 bucketing mid-stream; 512 > n runs one
    chunk; k=7 > last block's 18 valid docs is irrelevant to correctness but
    k spans blocks regardless."""
    x, path, tree = dense_case
    store = open_store(path)
    d_mem, s_mem = topk_search(tree, jnp.asarray(x), k=7, beam=3, chunk=chunk)
    d_st, s_st = topk_search(tree, store, k=7, beam=3, chunk=chunk)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)


def test_dense_store_k_exceeds_block_docs(tmp_path):
    """k larger than docs-per-block: answers must still merge across blocks
    bit-identically (block granularity is invisible to the engine)."""
    rng = np.random.default_rng(7)
    x = planted(rng, n=90, d=8)
    path = str(tmp_path / "tiny-blocks")
    save_store(path, x, block_docs=8)  # k=20 > 8 docs per block
    store = open_store(path, budget_bytes=1)
    tree = kt.build(jnp.asarray(x), order=5, batch_size=16,
                    key=jax.random.PRNGKey(8))
    d_mem, s_mem = topk_search(tree, jnp.asarray(x), k=20, beam=4)
    d_st, s_st = topk_search(tree, store, k=20, beam=4)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)


def test_ell_store_query_bit_identical(ell_case):
    m, path, tree = ell_case
    store = open_store(path, budget_bytes=1)
    d_mem, s_mem = topk_search(tree, m, k=6, beam=3, chunk=48)
    d_st, s_st = topk_search(tree, store, k=6, beam=3, chunk=48)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)


def test_ell_chunk_backends_share_one_compile(ell_case):
    """ELL chunk backends must not retrace per chunk: a chunk's true nnz
    varies, so the CSR side is padded to the static B·nnz_max capacity —
    without it every chunk misses the jit cache (regression for the
    per-chunk recompile bug)."""
    from repro.core import query as q_mod

    m, path, tree = ell_case
    store = open_store(path)
    topk_search(tree, store, k=3, beam=2, chunk=32)  # warm all buckets
    before = q_mod._beam_search._cache_size()
    topk_search(tree, store, k=3, beam=2, chunk=32)  # 6 chunks over 170 docs
    assert q_mod._beam_search._cache_size() == before


def test_store_slice_matches_row_range(dense_case):
    x, path, tree = dense_case
    store = open_store(path)
    full, fulld = topk_search(tree, jnp.asarray(x), k=4, beam=2, chunk=40)
    sl = store.view(30, 110)
    assert isinstance(sl, StoreSlice) and sl.n_docs == 80
    part, partd = topk_search(tree, sl, k=4, beam=2, chunk=40)
    np.testing.assert_array_equal(full[30:110], part)
    np.testing.assert_array_equal(fulld[30:110], partd)
    with pytest.raises(ValueError):
        store.view(5, 1000)
    # slice-local bounds: ids past the view (or negative) must raise, not
    # silently resolve to other parent rows after the +lo offset
    with pytest.raises(IndexError):
        sl.take_rows(np.array([80]))
    with pytest.raises(IndexError):
        sl.take_rows(np.array([-1]))


def test_streaming_build_bit_identical_both_backends(dense_case, ell_case):
    x, dpath, dtree = dense_case
    m, epath, etree = ell_case
    st_d = open_store(dpath, budget_bytes=1)
    assert_trees_equal(
        dtree, kt.build_from_store(st_d, order=6, batch_size=32,
                                   key=jax.random.PRNGKey(1)))
    st_e = open_store(epath, budget_bytes=1)
    grown = kt.build_from_store(st_e, order=6, medoid=True, batch_size=32,
                                key=jax.random.PRNGKey(3))
    assert_trees_equal(etree, grown)
    kt.check_invariants(grown, n_docs=170)


def test_dim_mismatch_guard(dense_case, tmp_path):
    _, _, tree = dense_case
    path = str(tmp_path / "wrong-dim")
    save_store(path, planted(np.random.default_rng(9), n=40, d=5),
               block_docs=16)
    with pytest.raises(ValueError, match="query dim"):
        topk_search(tree, open_store(path), k=3)


# --- manifest-reference checkpoints -----------------------------------------

def test_save_restore_index_by_manifest_reference(dense_case, tmp_path):
    x, path, tree = dense_case
    store = open_store(path)
    idx = str(tmp_path / "idx")
    out = save_index(idx, tree, store)
    assert out == idx
    # the checkpoint holds the tree + a reference, never the corpus blocks
    assert sorted(os.listdir(idx)) == ["INDEX.json", "tree.npz"]
    tree2, store2 = restore_index(idx, budget_bytes=1)
    assert_trees_equal(tree, tree2)
    assert store2.manifest_hash == store.manifest_hash
    d1, _ = topk_search(tree, jnp.asarray(x), k=4, beam=2)
    d2, _ = topk_search(tree2, store2, k=4, beam=2)
    np.testing.assert_array_equal(d1, d2)


def test_restore_index_refuses_regenerated_store(tmp_path):
    rng = np.random.default_rng(11)
    x = planted(rng, n=80, d=6)
    spath = str(tmp_path / "store")
    save_store(spath, x, block_docs=32)
    store = open_store(spath)
    tree = kt.build(jnp.asarray(x), order=5, batch_size=16,
                    key=jax.random.PRNGKey(12))
    idx = str(tmp_path / "idx")
    save_index(idx, tree, store)
    # regenerate the corpus in place: same path, different content
    save_store(spath, planted(rng, n=80, d=6), block_docs=32)
    with pytest.raises(ValueError, match="rewritten in place"):
        restore_index(idx)
    tree3, store3 = restore_index(idx, check=False)  # explicit override
    assert_trees_equal(tree, tree3)


# --- answer-cache staleness regression (the PR's bugfix) --------------------

def test_cache_corpus_token_invalidates_on_store_regeneration(tmp_path):
    """A store regenerated in place under an unchanged tree object must not
    serve stale cached answers: keying on the manifest content hash flushes
    the cache when the corpus identity changes."""
    rng = np.random.default_rng(13)
    x = planted(rng, n=100, d=8)
    spath = str(tmp_path / "store")
    save_store(spath, x, block_docs=32)
    store = open_store(spath)
    tree = kt.build(jnp.asarray(x), order=5, batch_size=16,
                    key=jax.random.PRNGKey(14))
    cache = AnswerCache(64)
    q = x[:10]
    topk_search_cached(tree, q, cache, k=3, beam=2,
                       corpus_token=store.manifest_hash)
    assert cache.misses == 10 and len(cache) == 10
    topk_search_cached(tree, q, cache, k=3, beam=2,
                       corpus_token=store.manifest_hash)
    assert cache.hits == 10  # same corpus → replay from cache

    # regenerate in place: same path + same tree object, different content
    save_store(spath, planted(rng, n=100, d=8), block_docs=32)
    new_store = open_store(spath)
    assert new_store.manifest_hash != store.manifest_hash
    topk_search_cached(tree, q, cache, k=3, beam=2,
                       corpus_token=new_store.manifest_hash)
    # without the token fix these 10 would all be (stale) hits
    assert cache.hits == 10 and cache.misses == 20

    # the pre-fix behaviour (no token) is the hole: same tree object hits
    legacy = AnswerCache(64)
    topk_search_cached(tree, q, legacy, k=3, beam=2)
    topk_search_cached(tree, q, legacy, k=3, beam=2)
    assert legacy.hits == 10
