import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ktree as kt
from repro.core.metrics import micro_purity
from repro.core.sampling import sampled_ktree_clustering, select_sample_medoid


def planted(rng, k=6, per=50, d=10):
    means = rng.normal(0, 5, (k, d))
    x = np.concatenate([rng.normal(means[i], 1.0, (per, d)) for i in range(k)])
    return jnp.asarray(x.astype(np.float32)), np.repeat(np.arange(k), per)


@pytest.mark.parametrize("order,batch_size", [(4, 16), (8, 32), (16, 64)])
def test_build_invariants(order, batch_size):
    rng = np.random.default_rng(order)
    x, _ = planted(rng, k=4, per=40)
    tree = kt.build(x, order=order, batch_size=batch_size)
    kt.check_invariants(tree, n_docs=x.shape[0])


def test_sequential_build_matches_paper_semantics():
    """batch_size=1 is the exact one-vector-at-a-time algorithm."""
    rng = np.random.default_rng(0)
    x, _ = planted(rng, k=3, per=12, d=6)   # 36 docs
    tree = kt.build(x, order=4, batch_size=1)
    kt.check_invariants(tree, n_docs=x.shape[0])
    assert int(tree.depth) >= 2


def test_medoid_build_invariants_and_quality():
    rng = np.random.default_rng(1)
    x, labels = planted(rng)
    tree = kt.build(x, order=10, batch_size=32, medoid=True)
    kt.check_invariants(tree, n_docs=x.shape[0])
    assign, nc = kt.extract_assignment(tree, x.shape[0])
    p = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), nc, 6))
    assert p > 0.85


def test_assignment_covers_all_docs_once():
    rng = np.random.default_rng(2)
    x, _ = planted(rng, k=4, per=30)
    tree = kt.build(x, order=6, batch_size=16)
    assign, nc = kt.extract_assignment(tree, x.shape[0])
    assert (assign >= 0).all() and assign.max() < nc


def test_incremental_insert():
    rng = np.random.default_rng(3)
    x, _ = planted(rng, k=4, per=40)
    tree = kt.build(x[:100], order=8, batch_size=32)
    tree = kt.insert(tree, x[100:132], jnp.arange(100, 132))
    kt.check_invariants(tree, n_docs=132)


def test_nn_search_quality():
    rng = np.random.default_rng(4)
    x, _ = planted(rng, k=5, per=40, d=8)
    tree = kt.build(x, order=10, batch_size=32)
    doc, dist = kt.nn_search(tree, x[:60])
    # approximate search: the returned doc must be close (within 2x the true NN
    # dist on average) and often exact
    exact = (doc == np.arange(60)).mean()
    assert exact > 0.5
    assert (dist >= -1e-5).all()


def test_cluster_quality_beats_random():
    rng = np.random.default_rng(5)
    x, labels = planted(rng)
    tree = kt.build(x, order=12, batch_size=64)
    assign, nc = kt.extract_assignment(tree, x.shape[0])
    p = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), nc, 6))
    rand_assign = jnp.asarray(np.random.default_rng(0).integers(0, nc, x.shape[0]))
    pr = float(micro_purity(rand_assign, jnp.asarray(labels), nc, 6))
    assert p > pr + 0.2


def test_sampled_pipeline():
    rng = np.random.default_rng(6)
    x, labels = planted(rng, per=40)
    assign, nc, tree = sampled_ktree_clustering(x, order=8, fraction=0.2, batch_size=64)
    assert assign.shape[0] == x.shape[0] and (assign >= 0).all()
    p = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), nc, 6))
    assert p > 0.7


def test_medoid_sample_selection_size():
    rng = np.random.default_rng(7)
    x, _ = planted(rng, k=3, per=40, d=6)
    ids = select_sample_medoid(x, fraction=0.15, batch_size=32)
    frac = ids.size / x.shape[0]
    assert 0.03 < frac < 0.6
    assert len(np.unique(ids)) == ids.size


def test_level_centers_shrink_up_the_tree():
    rng = np.random.default_rng(8)
    x, _ = planted(rng, k=4, per=50)
    tree = kt.build(x, order=6, batch_size=32)
    if int(tree.depth) >= 3:
        c0 = kt.level_centers(tree, 0)
        c1 = kt.level_centers(tree, 1)
        assert c0.shape[0] <= c1.shape[0]


def test_ktree_checkpoint_roundtrip(tmp_path):
    from repro.ckpt import save_ktree, restore_ktree

    rng = np.random.default_rng(9)
    x, _ = planted(rng, k=3, per=20, d=5)
    tree = kt.build(x, order=5, batch_size=16)
    path = str(tmp_path / "tree.npz")
    save_ktree(path, tree)
    tree2 = restore_ktree(path)
    assert tree2.order == tree.order and tree2.medoid == tree.medoid
    np.testing.assert_array_equal(np.asarray(tree.child), np.asarray(tree2.child))
    a1, _ = kt.extract_assignment(tree, x.shape[0])
    a2, _ = kt.extract_assignment(tree2, x.shape[0])
    np.testing.assert_array_equal(a1, a2)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(20, 120), st.integers(3, 10), st.integers(2, 8), st.integers(0, 9999)
)
def test_property_doc_conservation(n, order, d, seed):
    """Every inserted vector lives in exactly one leaf, for arbitrary data."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    tree = kt.build(x, order=order, batch_size=16, key=jax.random.PRNGKey(seed))
    kt.check_invariants(tree, n_docs=n)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 9999))
def test_property_duplicate_vectors(seed):
    """Degenerate inputs (many identical vectors) must still build a legal tree."""
    rng = np.random.default_rng(seed)
    base = rng.normal(0, 1, (4, 6)).astype(np.float32)
    x = jnp.asarray(np.repeat(base, 15, axis=0))
    tree = kt.build(x, order=5, batch_size=16, key=jax.random.PRNGKey(seed))
    kt.check_invariants(tree, n_docs=60)
