"""Top-k beam-search query engine (DESIGN.md §7): golden equivalence with the
greedy descent, recall regression vs brute force, both vector backends, and
query-after-restore identity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ktree as kt
from repro.core.query import topk_search
from repro.sparse.csr import csr_from_dense, csr_to_dense


def planted(rng, k=6, per=50, d=10):
    means = rng.normal(0, 5, (k, d))
    x = np.concatenate([rng.normal(means[i], 1.0, (per, d)) for i in range(k)])
    return x.astype(np.float32)


def brute_topk(x_q, x_all, k):
    d = (
        (x_q ** 2).sum(1)[:, None] - 2.0 * x_q @ x_all.T
        + (x_all ** 2).sum(1)[None, :]
    )
    return np.argsort(d, axis=1, kind="stable")[:, :k]


@pytest.fixture(scope="module")
def dense_setup():
    rng = np.random.default_rng(0)
    x = planted(rng, k=5, per=60, d=8)
    tree = kt.build(jnp.asarray(x), order=8, batch_size=32)
    q = jnp.asarray(x[:80] + 0.05 * rng.normal(0, 1, (80, 8)).astype(np.float32))
    return tree, x, q


@pytest.fixture(scope="module")
def sparse_setup():
    rng = np.random.default_rng(1)
    x = (planted(rng, k=4, per=50, d=24) * (rng.random((200, 24)) < 0.4)).astype(
        np.float32
    )
    m = csr_from_dense(x)
    tree = kt.build(m, order=8, medoid=True, batch_size=32)
    return tree, x, m


def test_topk_shapes_and_ordering(dense_setup):
    tree, x, q = dense_setup
    docs, dist = topk_search(tree, q, k=10, beam=4)
    assert docs.shape == (80, 10) and dist.shape == (80, 10)
    assert docs.dtype == np.int32
    finite = np.isfinite(dist)
    assert finite[:, 0].all()  # every query reaches at least one document
    capped = np.where(finite, dist, np.float32(np.finfo(np.float32).max))
    assert (np.diff(capped, axis=1) >= -1e-5).all(), "distances not ascending"
    # finite results carry valid, per-query-distinct doc ids; padding is −1
    assert ((docs >= 0) == finite).all()
    assert (docs < x.shape[0]).all()
    for i in range(docs.shape[0]):
        real = docs[i][finite[i]].tolist()
        assert len(set(real)) == len(real)


def test_golden_beam1_k1_matches_greedy_dense(dense_setup):
    tree, _, q = dense_setup
    gd, gdist = kt.nn_search_greedy(tree, q)
    docs, dist = topk_search(tree, q, k=1, beam=1)
    np.testing.assert_array_equal(gd, docs[:, 0])
    np.testing.assert_array_equal(gdist, dist[:, 0])
    # the public nn_search is the same wrapper
    nd, ndist = kt.nn_search(tree, q)
    np.testing.assert_array_equal(gd, nd)
    np.testing.assert_array_equal(gdist, ndist)


def test_golden_beam1_k1_matches_greedy_sparse(sparse_setup):
    tree, _, m = sparse_setup
    gd, gdist = kt.nn_search_greedy(tree, m)
    docs, dist = topk_search(tree, m, k=1, beam=1)
    np.testing.assert_array_equal(gd, docs[:, 0])
    np.testing.assert_array_equal(gdist, dist[:, 0])


def test_recall_regression_beam_ge_greedy(dense_setup):
    """Recall@10: beam search ≥ greedy, and wider beams don't regress."""
    tree, x, q = dense_setup
    true10 = brute_topk(np.asarray(q), x, 10)
    greedy = topk_search(tree, q, k=10, beam=1)[0]
    wide = topk_search(tree, q, k=10, beam=4)[0]

    def recall(docs):
        return np.mean([
            len(set(docs[i].tolist()) & set(true10[i].tolist())) / 10
            for i in range(true10.shape[0])
        ])

    r1, r4 = recall(greedy), recall(wide)
    assert r4 >= r1, f"beam=4 recall {r4:.3f} < beam=1 {r1:.3f}"
    assert r4 > 0.5  # wide beam must be genuinely useful on planted clusters


def test_sparse_topk_and_recall(sparse_setup):
    tree, x, m = sparse_setup
    docs, dist = topk_search(tree, m, k=5, beam=4)
    assert docs.shape == (200, 5)
    assert (np.diff(np.where(np.isfinite(dist), dist, 1e30), axis=1) >= -1e-5).all()
    true5 = brute_topk(x, x, 5)
    rec = np.mean([
        len(set(docs[i].tolist()) & set(true5[i].tolist())) / 5 for i in range(200)
    ])
    rec1 = np.mean([
        len(set(r.tolist()) & set(t.tolist())) / 5
        for r, t in zip(topk_search(tree, m, k=5, beam=1)[0], true5)
    ])
    assert rec >= rec1
    # self-query: the document itself must be found by a modest beam
    assert (docs[:, 0] == np.arange(200)).mean() > 0.7


def test_k_exceeds_corpus_pads(dense_setup):
    """k beyond beam·(m+1) candidates pads with (−1, +inf)."""
    tree, _, q = dense_setup
    docs, dist = topk_search(tree, q[:4], k=40, beam=1)  # 1 leaf ≤ 9 docs
    assert (docs[:, -1] == -1).all() and np.isinf(dist[:, -1]).all()
    first_pad = np.argmax(docs < 0, axis=1)
    assert (first_pad >= 1).all()  # at least the leaf's own docs come back


def test_beam_one_deep_tree_bucketing():
    """Low order → deep tree: beam search crosses compile buckets correctly."""
    rng = np.random.default_rng(2)
    x = rng.normal(0, 1, (300, 6)).astype(np.float32)
    tree = kt.build(jnp.asarray(x), order=3, batch_size=32)
    assert int(tree.depth) >= 5
    gd, gdist = kt.nn_search_greedy(tree, jnp.asarray(x[:40]))
    docs, dist = topk_search(tree, jnp.asarray(x[:40]), k=1, beam=1)
    np.testing.assert_array_equal(gd, docs[:, 0])
    np.testing.assert_array_equal(gdist, dist[:, 0])
    # wider than any node's entry count still legal
    docs8, _ = topk_search(tree, jnp.asarray(x[:10]), k=3, beam=8)
    assert ((docs8 >= -1) & (docs8 < 300)).all()


def test_chunked_queries_match_single_batch(dense_setup):
    tree, _, q = dense_setup
    a = topk_search(tree, q, k=5, beam=2, chunk=512)
    b = topk_search(tree, q, k=5, beam=2, chunk=17)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_invalid_args_raise(dense_setup):
    tree, _, q = dense_setup
    with pytest.raises(ValueError):
        topk_search(tree, q, k=0)
    with pytest.raises(ValueError):
        topk_search(tree, q, beam=0)


def test_query_identity_after_restore(tmp_path, dense_setup):
    from repro.ckpt import save_ktree, restore_ktree

    tree, _, q = dense_setup
    save_ktree(str(tmp_path / "tree"), tree)
    tree2 = restore_ktree(str(tmp_path / "tree"))
    d1, s1 = topk_search(tree, q, k=10, beam=4)
    d2, s2 = topk_search(tree2, q, k=10, beam=4)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(s1, s2)
