"""Top-k beam-search query engine (DESIGN.md §7): golden equivalence with the
greedy descent, recall regression vs brute force, both vector backends, and
query-after-restore identity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ktree as kt
from repro.core.query import topk_search
from repro.sparse.csr import csr_from_dense, csr_to_dense


def planted(rng, k=6, per=50, d=10):
    means = rng.normal(0, 5, (k, d))
    x = np.concatenate([rng.normal(means[i], 1.0, (per, d)) for i in range(k)])
    return x.astype(np.float32)


def brute_topk(x_q, x_all, k):
    d = (
        (x_q ** 2).sum(1)[:, None] - 2.0 * x_q @ x_all.T
        + (x_all ** 2).sum(1)[None, :]
    )
    return np.argsort(d, axis=1, kind="stable")[:, :k]


@pytest.fixture(scope="module")
def dense_setup():
    rng = np.random.default_rng(0)
    x = planted(rng, k=5, per=60, d=8)
    tree = kt.build(jnp.asarray(x), order=8, batch_size=32)
    q = jnp.asarray(x[:80] + 0.05 * rng.normal(0, 1, (80, 8)).astype(np.float32))
    return tree, x, q


@pytest.fixture(scope="module")
def sparse_setup():
    rng = np.random.default_rng(1)
    x = (planted(rng, k=4, per=50, d=24) * (rng.random((200, 24)) < 0.4)).astype(
        np.float32
    )
    m = csr_from_dense(x)
    tree = kt.build(m, order=8, medoid=True, batch_size=32)
    return tree, x, m


def test_topk_shapes_and_ordering(dense_setup):
    tree, x, q = dense_setup
    docs, dist = topk_search(tree, q, k=10, beam=4)
    assert docs.shape == (80, 10) and dist.shape == (80, 10)
    assert docs.dtype == np.int32
    finite = np.isfinite(dist)
    assert finite[:, 0].all()  # every query reaches at least one document
    capped = np.where(finite, dist, np.float32(np.finfo(np.float32).max))
    assert (np.diff(capped, axis=1) >= -1e-5).all(), "distances not ascending"
    # finite results carry valid, per-query-distinct doc ids; padding is −1
    assert ((docs >= 0) == finite).all()
    assert (docs < x.shape[0]).all()
    for i in range(docs.shape[0]):
        real = docs[i][finite[i]].tolist()
        assert len(set(real)) == len(real)


def test_golden_beam1_k1_matches_greedy_dense(dense_setup):
    tree, _, q = dense_setup
    gd, gdist = kt.nn_search_greedy(tree, q)
    docs, dist = topk_search(tree, q, k=1, beam=1)
    np.testing.assert_array_equal(gd, docs[:, 0])
    np.testing.assert_array_equal(gdist, dist[:, 0])
    # the public nn_search is the same wrapper
    nd, ndist = kt.nn_search(tree, q)
    np.testing.assert_array_equal(gd, nd)
    np.testing.assert_array_equal(gdist, ndist)


def test_golden_beam1_k1_matches_greedy_sparse(sparse_setup):
    tree, _, m = sparse_setup
    gd, gdist = kt.nn_search_greedy(tree, m)
    docs, dist = topk_search(tree, m, k=1, beam=1)
    np.testing.assert_array_equal(gd, docs[:, 0])
    np.testing.assert_array_equal(gdist, dist[:, 0])


def test_recall_regression_beam_ge_greedy(dense_setup):
    """Recall@10: beam search ≥ greedy, and wider beams don't regress."""
    tree, x, q = dense_setup
    true10 = brute_topk(np.asarray(q), x, 10)
    greedy = topk_search(tree, q, k=10, beam=1)[0]
    wide = topk_search(tree, q, k=10, beam=4)[0]

    def recall(docs):
        return np.mean([
            len(set(docs[i].tolist()) & set(true10[i].tolist())) / 10
            for i in range(true10.shape[0])
        ])

    r1, r4 = recall(greedy), recall(wide)
    assert r4 >= r1, f"beam=4 recall {r4:.3f} < beam=1 {r1:.3f}"
    assert r4 > 0.5  # wide beam must be genuinely useful on planted clusters


def test_sparse_topk_and_recall(sparse_setup):
    tree, x, m = sparse_setup
    docs, dist = topk_search(tree, m, k=5, beam=4)
    assert docs.shape == (200, 5)
    assert (np.diff(np.where(np.isfinite(dist), dist, 1e30), axis=1) >= -1e-5).all()
    true5 = brute_topk(x, x, 5)
    rec = np.mean([
        len(set(docs[i].tolist()) & set(true5[i].tolist())) / 5 for i in range(200)
    ])
    rec1 = np.mean([
        len(set(r.tolist()) & set(t.tolist())) / 5
        for r, t in zip(topk_search(tree, m, k=5, beam=1)[0], true5)
    ])
    assert rec >= rec1
    # self-query: the document itself must be found by a modest beam
    assert (docs[:, 0] == np.arange(200)).mean() > 0.7


def test_k_exceeds_corpus_pads(dense_setup):
    """k beyond beam·(m+1) candidates pads with (−1, +inf)."""
    tree, _, q = dense_setup
    docs, dist = topk_search(tree, q[:4], k=40, beam=1)  # 1 leaf ≤ 9 docs
    assert (docs[:, -1] == -1).all() and np.isinf(dist[:, -1]).all()
    first_pad = np.argmax(docs < 0, axis=1)
    assert (first_pad >= 1).all()  # at least the leaf's own docs come back


def test_beam_one_deep_tree_bucketing():
    """Low order → deep tree: beam search crosses compile buckets correctly."""
    rng = np.random.default_rng(2)
    x = rng.normal(0, 1, (300, 6)).astype(np.float32)
    tree = kt.build(jnp.asarray(x), order=3, batch_size=32)
    assert int(tree.depth) >= 5
    gd, gdist = kt.nn_search_greedy(tree, jnp.asarray(x[:40]))
    docs, dist = topk_search(tree, jnp.asarray(x[:40]), k=1, beam=1)
    np.testing.assert_array_equal(gd, docs[:, 0])
    np.testing.assert_array_equal(gdist, dist[:, 0])
    # wider than any node's entry count still legal
    docs8, _ = topk_search(tree, jnp.asarray(x[:10]), k=3, beam=8)
    assert ((docs8 >= -1) & (docs8 < 300)).all()


def test_chunked_queries_match_single_batch(dense_setup):
    tree, _, q = dense_setup
    a = topk_search(tree, q, k=5, beam=2, chunk=512)
    b = topk_search(tree, q, k=5, beam=2, chunk=17)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_invalid_args_raise(dense_setup):
    tree, _, q = dense_setup
    with pytest.raises(ValueError):
        topk_search(tree, q, k=0)
    with pytest.raises(ValueError):
        topk_search(tree, q, beam=0)


def test_pipelined_chunks_match_sync_loop(dense_setup):
    """Dispatch-ahead pipeline (DESIGN.md §8) is a pure scheduling change:
    depth 1 (the old synchronous loop), 2, and deeper all agree."""
    tree, _, q = dense_setup
    ref = topk_search(tree, q, k=5, beam=2, chunk=17, pipeline=1)
    for depth in (2, 4):
        got = topk_search(tree, q, k=5, beam=2, chunk=17, pipeline=depth)
        np.testing.assert_array_equal(ref[0], got[0])
        np.testing.assert_array_equal(ref[1], got[1])


def test_answer_cache_hits_identical_eviction_and_stats(dense_setup):
    from repro.core.query import AnswerCache, topk_search_cached

    tree, _, q = dense_setup
    x_q = np.asarray(q)[:8]
    cache = AnswerCache(capacity=4)
    d0, s0 = topk_search(tree, x_q, k=5, beam=2)
    d1, s1 = topk_search_cached(tree, x_q, cache, k=5, beam=2)
    np.testing.assert_array_equal(d0, d1)
    np.testing.assert_array_equal(s0, s1)
    assert cache.stats["misses"] == 8 and cache.stats["hits"] == 0
    assert len(cache) == 4  # eviction at capacity: only the last 4 remain

    # rows 4..7 are resident → hits, identical answers; rows 0..3 evicted
    d2, s2 = topk_search_cached(tree, x_q[4:], cache, k=5, beam=2)
    np.testing.assert_array_equal(d0[4:], d2)
    np.testing.assert_array_equal(s0[4:], s2)
    assert cache.stats["hits"] == 4 and cache.stats["misses"] == 8
    d3, _ = topk_search_cached(tree, x_q[:4], cache, k=5, beam=2)
    np.testing.assert_array_equal(d0[:4], d3)
    assert cache.stats["misses"] == 12
    assert cache.stats["hit_rate"] == 4 / 16
    assert cache.stats["size"] == 4 and cache.stats["capacity"] == 4


def test_answer_cache_dedups_misses_within_batch(dense_setup):
    from repro.core.query import AnswerCache, topk_search_cached

    tree, _, q = dense_setup
    x_q = np.repeat(np.asarray(q)[:1], 5, axis=0)
    cache = AnswerCache(capacity=8)
    calls = []

    def spy(xq):
        calls.append(xq.shape[0])
        return topk_search(tree, xq, k=3, beam=2)

    docs, dist = topk_search_cached(tree, x_q, cache, k=3, beam=2, search_fn=spy)
    assert calls == [1]  # five identical rows → one engine row
    assert (docs == docs[0]).all() and (dist == dist[0]).all()


def test_answer_cache_key_separates_k_and_beam(dense_setup):
    from repro.core.query import AnswerCache

    row = np.asarray(dense_setup[2])[0]
    k1 = AnswerCache.make_key(row, 5, 2)
    assert k1 == AnswerCache.make_key(row.copy(), 5, 2)
    assert k1 != AnswerCache.make_key(row, 6, 2)
    assert k1 != AnswerCache.make_key(row, 5, 3)
    assert k1 != AnswerCache.make_key(row + 1e-3, 5, 2)


def test_answer_cache_invalidates_on_new_index(dense_setup):
    """The cache binds to the index object: inserting into the tree yields a
    new KTree, and cached answers for the old one must not survive."""
    from repro.core.query import AnswerCache, topk_search_cached

    tree, x, q = dense_setup
    x_q = np.asarray(q)[:4]
    cache = AnswerCache(capacity=16)
    topk_search_cached(tree, x_q, cache, k=3, beam=2)
    assert len(cache) == 4
    n = x.shape[0]
    tree2 = kt.insert(tree, jnp.asarray(x_q), np.arange(n, n + 4))
    d_fresh, s_fresh = topk_search(tree2, x_q, k=3, beam=2)
    d_cached, s_cached = topk_search_cached(tree2, x_q, cache, k=3, beam=2)
    np.testing.assert_array_equal(d_fresh, d_cached)
    np.testing.assert_array_equal(s_fresh, s_cached)
    # the inserted queries are now their own nearest documents
    assert (d_cached[:, 0] == np.arange(n, n + 4)).all()


def test_sharded_single_shard_mesh_and_wrong_corpus_guard(dense_setup):
    """A 1-shard mesh runs the sharded path in-process: answers must equal
    topk_search, and a corpus too short for the tree's doc ids must raise."""
    from repro.core.query import topk_search_sharded

    tree, x, q = dense_setup
    mesh = jax.make_mesh((1,), ("data",))
    ref = topk_search(tree, q, k=5, beam=2)
    got = topk_search_sharded(mesh, tree, q, corpus=x, k=5, beam=2)
    np.testing.assert_array_equal(ref[0], got[0])
    np.testing.assert_array_equal(ref[1], got[1])
    with pytest.raises(ValueError, match="different corpus"):
        topk_search_sharded(mesh, tree, q, corpus=x[:-10], k=5, beam=2)


def test_recall_at_k_matches_set_loop():
    """The broadcast recall reduction pins the old per-query set-loop
    semantics, −1 padding included."""
    from repro.core.query import recall_at_k

    rng = np.random.default_rng(3)
    for _ in range(5):
        nq, k = int(rng.integers(1, 40)), int(rng.integers(1, 8))
        true_k = np.stack([
            rng.choice(50, size=k, replace=False) for _ in range(nq)
        ])
        docs = rng.integers(0, 50, (nq, k))
        docs[rng.random((nq, k)) < 0.3] = -1  # padding never matches
        old = float(np.mean([
            len(set(docs[i].tolist()) & set(true_k[i].tolist())) / k
            for i in range(nq)
        ]))
        assert recall_at_k(docs, true_k) == old


def test_brute_force_topk_blocked_bit_identical():
    """Tiled brute force (running top-k merge) reproduces the full-matrix
    stable argsort exactly — including duplicate-distance tie order."""
    from repro.core.query import brute_force_topk

    rng = np.random.default_rng(4)
    x_all = rng.normal(0, 1, (157, 12)).astype(np.float32)
    x_all[40] = x_all[7]      # planted duplicates → exact distance ties
    x_all[93] = x_all[7]
    x_q = np.concatenate([x_all[:20], x_all[7:8]])
    d_full = (
        (x_q ** 2).sum(1)[:, None] - 2.0 * x_q @ x_all.T
        + (x_all ** 2).sum(1)[None, :]
    )
    ref = np.argsort(d_full, axis=1, kind="stable")[:, :9]
    got = brute_force_topk(x_q, x_all, 9, doc_block=13, q_block=6)
    np.testing.assert_array_equal(ref, got)
    # k beyond the corpus: width clamps to n_docs like the argsort slice did
    assert brute_force_topk(x_q[:2], x_all[:5], 9).shape == (2, 5)


def test_query_identity_after_restore(tmp_path, dense_setup):
    from repro.ckpt import save_ktree, restore_ktree

    tree, _, q = dense_setup
    save_ktree(str(tmp_path / "tree"), tree)
    tree2 = restore_ktree(str(tmp_path / "tree"))
    d1, s1 = topk_search(tree, q, k=10, beam=4)
    d2, s2 = topk_search(tree2, q, k=10, beam=4)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(s1, s2)


# ---------------------------------------------------------------------------
# serving-engine seams (DESIGN.md §8): batch scatter/gather helpers, cache
# staging, empty-batch edges, and answer-cache thread safety
# ---------------------------------------------------------------------------

def test_topk_search_empty_query_batch(dense_setup):
    tree, _, _ = dense_setup
    docs, dist = topk_search(tree, np.zeros((0, 8), np.float32), k=5, beam=2)
    assert docs.shape == (0, 5) and dist.shape == (0, 5)
    assert docs.dtype == np.int32 and dist.dtype == np.float32


def test_topk_search_cached_empty_batch_and_all_hits(dense_setup):
    from repro.core.query import AnswerCache, topk_search_cached

    tree, x, _ = dense_setup
    cache = AnswerCache(capacity=8)
    # nq = 0: no probes, no engine call, well-formed empty answers
    d0, s0 = topk_search_cached(
        tree, np.zeros((0, 8), np.float32), cache, k=5, beam=2)
    assert d0.shape == (0, 5) and s0.shape == (0, 5)
    assert cache.stats["hits"] == 0 and cache.stats["misses"] == 0
    # all-hit batch: the miss branch (engine call) must not run at all
    q = x[:3]
    d1, s1 = topk_search_cached(tree, q, cache, k=5, beam=2)
    def boom(_):
        raise AssertionError("engine called on an empty miss batch")
    d2, s2 = topk_search_cached(tree, q, cache, k=5, beam=2, search_fn=boom)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(s1, s2)
    assert cache.stats["hits"] == 3


def test_concat_split_round_trip():
    from repro.core.query import concat_request_rows, split_batch_answers

    rng = np.random.default_rng(0)
    frags = [rng.normal(0, 1, (r, 4)).astype(np.float32) for r in (1, 3, 2)]
    x, bounds = concat_request_rows(frags)
    assert x.shape == (6, 4) and bounds == [0, 1, 4, 6]
    docs = np.arange(6 * 2, dtype=np.int32).reshape(6, 2)
    dist = docs.astype(np.float32)
    parts = split_batch_answers(docs, dist, bounds)
    assert len(parts) == 3
    for (d, s), (lo, hi) in zip(parts, zip(bounds[:-1], bounds[1:])):
        np.testing.assert_array_equal(d, docs[lo:hi])
        np.testing.assert_array_equal(s, dist[lo:hi])
        d[:] = -7  # split copies: mutating a part must not alias the batch
    assert (docs >= 0).all()
    with pytest.raises(ValueError):
        concat_request_rows([])


def test_cache_stage_and_fill_accounting(dense_setup):
    from repro.core.query import AnswerCache, cache_fill, cache_stage

    tree, x, _ = dense_setup
    cache = AnswerCache(capacity=8)
    cache.bind(tree)
    # rows 0 and 2 identical -> one dedup'd miss; row 1 distinct
    q = np.stack([x[0], x[1], x[0]])
    docs, dist, miss = cache_stage(cache, q, 4, 2)
    assert (docs == -1).all() and np.isinf(dist).all()
    assert len(miss) == 2  # dedup within the batch
    assert list(miss.values())[0] == [0, 2]
    d_new = np.arange(2 * 4, dtype=np.int32).reshape(2, 4)
    s_new = d_new.astype(np.float32)
    cache_fill(cache, miss, d_new, s_new, docs, dist)
    np.testing.assert_array_equal(docs[0], d_new[0])
    np.testing.assert_array_equal(docs[2], d_new[0])
    np.testing.assert_array_equal(docs[1], d_new[1])
    assert len(cache) == 2
    # a second stage over the same rows is all hits
    docs2, dist2, miss2 = cache_stage(cache, q, 4, 2)
    assert not miss2
    np.testing.assert_array_equal(docs2, docs)
    np.testing.assert_array_equal(dist2, dist)


def test_answer_cache_thread_safety_racing_threads():
    """Two threads hammering get/put on a capacity-1 cache: counters stay
    exact (every get is a hit or a miss), size bounded, no corruption — the
    serving engine consults the cache from its dispatcher thread while other
    threads admit requests."""
    import threading
    from repro.core.query import AnswerCache

    cache = AnswerCache(capacity=1)
    keys = [AnswerCache.make_key(np.float32([i, i]), 3, 1) for i in range(4)]
    val = (np.zeros((3,), np.int32), np.zeros((3,), np.float32))
    n_iter = 400
    results = {}

    def worker(tag, order):
        local = 0
        for i in range(n_iter):
            key = keys[order[i % len(order)]]
            if cache.get(key) is None:
                cache.put(key, val)
            else:
                local += 1
        results[tag] = local

    t1 = threading.Thread(target=worker, args=("a", [0, 1, 2, 3]))
    t2 = threading.Thread(target=worker, args=("b", [3, 2, 1, 0]))
    t1.start(); t2.start(); t1.join(); t2.join()
    st = cache.stats
    assert st["hits"] + st["misses"] == 2 * n_iter  # every get counted once
    assert st["hits"] == results["a"] + results["b"]
    assert len(cache) == 1  # capacity bound held under the race
    # the surviving entry is intact
    k_live = [k for k in keys if cache.get(k) is not None]
    assert len(k_live) == 1
