"""Dry-run machinery tests: registry completeness + an end-to-end compile of a
small-but-real cell on an 8-device mesh in a subprocess (the full 512-device
sweep runs via ``python -m repro.launch.dryrun --all``)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import registry

ASSIGNED = [
    "qwen2.5-14b", "granite-20b", "phi3-mini-3.8b", "grok-1-314b", "dbrx-132b",
    "dimenet", "dlrm-mlperf", "wide-deep", "bst", "dien",
]


def test_all_assigned_archs_registered():
    archs = registry.list_archs()
    for a in ASSIGNED:
        assert a in archs


def test_40_cells_defined():
    cells = [
        (a, s) for a in ASSIGNED for s in registry.get(a).shapes
    ]
    assert len(cells) == 40


def test_exact_published_dims():
    q = registry.get("qwen2.5-14b").cfg
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff, q.vocab) == \
           (48, 5120, 40, 8, 13824, 152064) and q.qkv_bias
    g = registry.get("granite-20b").cfg
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff, g.vocab) == \
           (52, 6144, 48, 1, 24576, 49152)
    p = registry.get("phi3-mini-3.8b").cfg
    assert (p.n_layers, p.d_model, p.n_heads, p.n_kv_heads, p.d_ff, p.vocab) == \
           (32, 3072, 32, 32, 8192, 32064)
    gk = registry.get("grok-1-314b").cfg
    assert (gk.n_layers, gk.d_model, gk.n_experts, gk.top_k) == (64, 6144, 8, 2)
    db = registry.get("dbrx-132b").cfg
    assert (db.n_layers, db.d_ff, db.n_experts, db.top_k) == (40, 10752, 16, 4)
    dn = registry.get("dimenet").cfg
    assert (dn.n_blocks, dn.d_hidden, dn.n_bilinear, dn.n_spherical, dn.n_radial) == \
           (6, 128, 8, 7, 6)
    dl = registry.get("dlrm-mlperf").cfg
    assert dl.n_dense == 13 and dl.n_sparse == 26 and dl.embed_dim == 128


def test_abstract_specs_build_for_every_cell():
    for a in ASSIGNED:
        spec = registry.get(a)
        for s in spec.shapes:
            ins, axes = registry.abstract_inputs(spec, s)
            st, sax = registry.abstract_state(spec, s)
            assert ins and st is not None
            fn = registry.step_fn(spec, s)
            assert callable(fn)


def test_param_counts_match_published_sizes():
    # n_params within 10% of the advertised model size
    import math
    for arch, target in [("qwen2.5-14b", 14e9), ("grok-1-314b", 314e9),
                         ("dbrx-132b", 132e9), ("phi3-mini-3.8b", 3.8e9)]:
        n = registry.get(arch).cfg.n_params()
        assert abs(n - target) / target < 0.12, (arch, n)


_COMPILE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax
    from repro.launch.dryrun import compile_cell
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    compiled, rec = compile_cell("dien", "serve_p99", multi_pod=False, mesh=mesh)
    assert rec["cost"]["flops"] > 0
    assert rec["memory"].get("temp_size_in_bytes", 0) >= 0
    print("COMPILE_OK", rec["cost"]["flops"])
    """
)


def test_compile_cell_small_mesh():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run(
        [sys.executable, "-c", _COMPILE_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=420,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "COMPILE_OK" in proc.stdout


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes, _shape_bytes

    hlo = """
      %ag = bf16[32,1024,8,128] all-gather(%x), replica_groups={}
      %ar.1 = f32[256,128] all-reduce-start(%y)
      %ard = f32[256,128] all-reduce-done(%ar.1)
      %a2a = (f32[16,64], f32[16,64]) all-to-all(%a, %b)
      %cp = u32[8] collective-permute(%c)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 32 * 1024 * 8 * 128 * 2
    assert out["all-reduce"] == 256 * 128 * 4
    assert out["all-to-all"] == 2 * 16 * 64 * 4
    assert out["collective-permute"] == 8 * 4
