"""K-tree checkpoint round-trips: dtype/static-field preservation for dense
and medoid trees (incl. the extended-dtype .npy descr bug), suffix handling,
atomicity, and restored trees staying fully live (further inserts + identical
query answers)."""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import restore_ktree, save_ktree
from repro.core import ktree as kt
from repro.core.query import topk_search
from repro.sparse.csr import csr_from_dense


def planted(rng, n=90, d=8):
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    return jnp.asarray(x)


def assert_trees_equal(a, b):
    assert a.order == b.order and a.medoid == b.medoid
    assert isinstance(b.order, int) and isinstance(b.medoid, bool)
    for f in dataclasses.fields(a):
        if f.metadata.get("static"):
            continue
        fa, fb = getattr(a, f.name), getattr(b, f.name)
        assert fa.dtype == fb.dtype, f"{f.name}: {fa.dtype} != {fb.dtype}"
        assert fa.shape == fb.shape, f"{f.name}: {fa.shape} != {fb.shape}"
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb), err_msg=f.name)


@pytest.mark.parametrize("medoid", [False, True])
def test_roundtrip_preserves_everything(tmp_path, medoid):
    rng = np.random.default_rng(0 if medoid else 1)
    x = planted(rng)
    tree = kt.build(x, order=6, batch_size=16, medoid=medoid)
    path = str(tmp_path / "tree")
    out = save_ktree(path, tree)
    assert out.endswith(".npz") and os.path.exists(out)
    assert_trees_equal(tree, restore_ktree(path))


def test_roundtrip_sparse_medoid_tree(tmp_path):
    rng = np.random.default_rng(2)
    x = (rng.normal(0, 1, (80, 20)) * (rng.random((80, 20)) < 0.4)).astype(np.float32)
    m = csr_from_dense(x)
    tree = kt.build(m, order=7, medoid=True, batch_size=16)
    save_ktree(str(tmp_path / "t"), tree)
    tree2 = restore_ktree(str(tmp_path / "t"))
    assert_trees_equal(tree, tree2)
    # identical answers to sparse queries
    d1, s1 = topk_search(tree, m, k=5, beam=2)
    d2, s2 = topk_search(tree2, m, k=5, beam=2)
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(s1, s2)


def test_npz_suffix_and_bare_path_agree(tmp_path):
    rng = np.random.default_rng(3)
    tree = kt.build(planted(rng, n=40), order=5, batch_size=16)
    p_bare = str(tmp_path / "a")
    p_npz = str(tmp_path / "b.npz")
    save_ktree(p_bare, tree)
    save_ktree(p_npz, tree)
    assert os.path.exists(p_bare + ".npz") and os.path.exists(p_npz)
    assert not os.path.exists(p_npz + ".npz")  # no double suffix
    assert_trees_equal(restore_ktree(p_bare), restore_ktree(p_npz))
    assert_trees_equal(restore_ktree(p_bare + ".npz"), restore_ktree(p_npz))


def test_extended_dtype_roundtrip(tmp_path):
    """bfloat16 tree pages survive the .npy descr limitation (stored upcast,
    restored to the recorded dtype)."""
    tree = kt.ktree_init(16, 4, 8, dtype=jnp.bfloat16)
    tree = dataclasses.replace(
        tree,
        centers=tree.centers.at[0, 0].set(jnp.asarray(0.25, jnp.bfloat16)),
        n_entries=tree.n_entries.at[0].set(1),
        child=tree.child.at[0, 0].set(0),
    )
    save_ktree(str(tmp_path / "bf16"), tree)
    tree2 = restore_ktree(str(tmp_path / "bf16"))
    assert tree2.centers.dtype == jnp.bfloat16
    assert tree2.counts.dtype == jnp.bfloat16
    assert_trees_equal(tree, tree2)


def test_no_tmp_residue(tmp_path):
    rng = np.random.default_rng(4)
    tree = kt.build(planted(rng, n=30), order=4, batch_size=8)
    save_ktree(str(tmp_path / "t"), tree)
    assert [f for f in os.listdir(tmp_path) if "tmp" in f] == []


@pytest.mark.parametrize("medoid", [False, True])
def test_restored_tree_accepts_insert_and_queries(tmp_path, medoid):
    rng = np.random.default_rng(5 if medoid else 6)
    x = np.asarray(planted(rng, n=120))
    tree = kt.build(jnp.asarray(x[:90]), order=6, batch_size=16, medoid=medoid)
    save_ktree(str(tmp_path / "t"), tree)
    tree2 = restore_ktree(str(tmp_path / "t"))

    # identical query answers before any mutation
    q = jnp.asarray(x[:25])
    np.testing.assert_array_equal(
        topk_search(tree, q, k=3, beam=2)[0], topk_search(tree2, q, k=3, beam=2)[0]
    )
    # a restored tree is fully live: insert more docs, invariants hold, and
    # the same growth applied to the original gives the identical tree
    key = jax.random.PRNGKey(7)
    grown = kt.insert(tree, jnp.asarray(x[90:]), np.arange(90, 120), key=key)
    grown2 = kt.insert(tree2, jnp.asarray(x[90:]), np.arange(90, 120), key=key)
    kt.check_invariants(grown2, n_docs=120)
    assert_trees_equal(grown, grown2)
