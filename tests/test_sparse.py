import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.sparse import (
    Csr, csr_from_dense, csr_to_dense, csr_matmat, csr_row_norms,
    csr_row_gather_dense, Ell, ell_from_csr, ell_to_dense, ell_dot_dense,
    tfidf_weight, cull_terms,
)
from repro.sparse.tfidf import unit_normalize_rows, term_ranks


def rand_sparse(rng, n, d, density=0.2):
    x = rng.normal(0, 1, (n, d)) * (rng.random((n, d)) < density)
    return x.astype(np.float32)


def test_csr_roundtrip():
    rng = np.random.default_rng(0)
    x = rand_sparse(rng, 13, 7)
    m = csr_from_dense(x)
    np.testing.assert_allclose(np.asarray(csr_to_dense(m)), x, rtol=1e-6)


def test_csr_matmat_matches_dense():
    rng = np.random.default_rng(1)
    x = rand_sparse(rng, 11, 9)
    w = rng.normal(0, 1, (9, 5)).astype(np.float32)
    m = csr_from_dense(x)
    np.testing.assert_allclose(np.asarray(csr_matmat(m, jnp.asarray(w))), x @ w, rtol=1e-4, atol=1e-5)


def test_csr_row_norms():
    rng = np.random.default_rng(2)
    x = rand_sparse(rng, 10, 20)
    m = csr_from_dense(x)
    np.testing.assert_allclose(np.asarray(csr_row_norms(m)), (x * x).sum(1), rtol=1e-5)


def test_csr_row_gather_dense():
    rng = np.random.default_rng(3)
    x = rand_sparse(rng, 10, 15)
    m = csr_from_dense(x)
    rows = jnp.asarray([0, 3, 7])
    out = csr_row_gather_dense(m, rows, max_nnz_row=15)
    np.testing.assert_allclose(np.asarray(out), x[[0, 3, 7]], rtol=1e-6)


def test_ell_roundtrip_and_dot():
    rng = np.random.default_rng(4)
    x = rand_sparse(rng, 12, 18)
    m = csr_from_dense(x)
    e = ell_from_csr(m)
    np.testing.assert_allclose(np.asarray(ell_to_dense(e)), x, rtol=1e-6)
    c = rng.normal(0, 1, (6, 18)).astype(np.float32)
    s = ell_dot_dense(e, jnp.asarray(c.T))
    np.testing.assert_allclose(np.asarray(s), x @ c.T, rtol=1e-4, atol=1e-5)


def test_tfidf_culling_keeps_top_ranked():
    rng = np.random.default_rng(5)
    x = np.abs(rand_sparse(rng, 40, 30))
    m = csr_from_dense(x)
    w = tfidf_weight(m)
    ranks = term_ranks(w)
    culled, keep = cull_terms(w, 10)
    assert culled.n_cols == 10
    worst_kept = ranks[keep].min()
    dropped = np.setdiff1d(np.arange(30), keep)
    assert (ranks[dropped] <= worst_kept + 1e-9).all()


def test_unit_normalize_rows():
    rng = np.random.default_rng(6)
    x = np.abs(rand_sparse(rng, 15, 12)) + 0.0
    m = csr_from_dense(x)
    n = unit_normalize_rows(m)
    norms = np.asarray(csr_row_norms(n))
    nonzero = np.asarray(m.indptr[1:]) > np.asarray(m.indptr[:-1])
    np.testing.assert_allclose(norms[nonzero], 1.0, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(2, 25), st.integers(0, 10_000))
def test_csr_matmat_property(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rand_sparse(rng, n, d, density=0.3)
    w = rng.normal(0, 1, (d, 3)).astype(np.float32)
    m = csr_from_dense(x)
    np.testing.assert_allclose(
        np.asarray(csr_matmat(m, jnp.asarray(w))), x @ w, rtol=2e-4, atol=1e-4
    )
