"""Random Indexing backend (DESIGN.md §5.1, arxiv 1001.0833): golden
equivalence + replay battery.

The RP pipeline is approximate-route, exact-rescore, so the suite pins
exactly which stages are bit-exact:

- the projection matrix replays bit-identically from its (seed, dims, kind)
  spec — the whole index is reconstructible from the checkpointed spec;
- an RP tree bit-matches the shadow dense tree built from the same projected
  rows (build, streaming build, and insert);
- the rescore stage IS ``brute_force_topk_dist`` restricted to each query's
  leaf candidate pool — bit-exact, over dense and ELL bases, on the
  single-device, store-backed, sharded, and cached serving paths;
- the identity-kind projection at rp_dim = d recovers the exact path's
  answers (the equivalence anchor);
- only pool *membership* is approximate, and its recall@10 on the clustered
  fixture corpus beats documented floors that grow with rp_dim.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from fixtures import assert_trees_equal, clustered_corpus, random_corpus
from repro.core import ktree as kt
from repro.core.backend import (
    DenseBackend,
    ProjectionMismatch,
    RandomProjBackend,
    RandomProjection,
    make_backend,
    make_projection,
    project_corpus,
    projection_from_spec,
)
from repro.core.query import (
    AnswerCache,
    brute_force_topk,
    brute_force_topk_dist,
    recall_at_k,
    rp_candidate_pools,
    topk_search,
    topk_search_cached,
)
from repro.sparse.csr import csr_from_dense

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
_TESTS = os.path.abspath(os.path.dirname(__file__))


@pytest.fixture(scope="module", autouse=True)
def _drop_jit_caches_after_module():
    """This file compiles many one-off shapes (d=512 builds, per-dim RP
    trees); drop them from the process-wide executable cache afterwards so
    the rest of the suite runs against the same compiler state as before
    this file existed."""
    yield
    jax.clear_caches()


def _corpus(sparse, n=180, d=24, seed=0):
    rng = np.random.default_rng(seed)
    x = random_corpus(rng, n=n, d=d, sparse=sparse)
    return x, (csr_from_dense(x) if sparse else jnp.asarray(x))


def _rp_case(sparse, rp_dim=8, seed=3, n=180, d=24, order=6):
    x, data = _corpus(sparse, n=n, d=d)
    proj = make_projection(d, rp_dim, seed=seed)
    rpb = RandomProjBackend.wrap(data, proj)
    tree = kt.build(rpb, order=order, batch_size=32, key=jax.random.PRNGKey(1))
    return x, data, proj, rpb, tree


# --------------------------------------------------------------- projection

def test_projection_replays_bit_exact_from_spec():
    """Same spec → bit-identical matrix, for every projection kind — the
    property that lets checkpoints persist the spec instead of the matrix."""
    for kind, out_dim in [("gaussian", 8), ("ternary", 16), ("identity", 24)]:
        proj = make_projection(24, out_dim, seed=11, kind=kind)
        re = projection_from_spec(proj.spec())
        assert re.spec() == proj.spec()
        np.testing.assert_array_equal(
            np.asarray(proj.matrix), np.asarray(re.matrix), err_msg=kind
        )


def test_projection_typed_errors():
    with pytest.raises(ValueError, match="identity"):
        make_projection(24, 8, kind="identity")
    with pytest.raises(ValueError):
        make_projection(24, 8, kind="banana")
    with pytest.raises(ValueError):
        make_projection(0, 8)
    spec = make_projection(24, 8).spec()
    with pytest.raises(ProjectionMismatch):
        projection_from_spec({k: v for k, v in spec.items() if k != "seed"})
    with pytest.raises(ProjectionMismatch):
        projection_from_spec({**spec, "dtype": "float64"})


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "ell"])
def test_rp_tree_bit_matches_shadow_dense_tree(sparse):
    """Build over a RandomProjBackend ≡ build over a plain dense backend of
    the same projected rows — the RP tree is exactly the dense K-tree in
    projected space."""
    x, data, proj, rpb, tree = _rp_case(sparse)
    z = np.asarray(rpb.proj.x)
    shadow = kt.build(jnp.asarray(z), order=6, batch_size=32,
                      key=jax.random.PRNGKey(1))
    assert tree.dim == proj.out_dim
    assert_trees_equal(tree, shadow)
    kt.check_invariants(tree, n_docs=x.shape[0])


# ------------------------------------------------- golden pool equivalence

def _pool_reference(x_q, cand, valid, x_all, k):
    """Brute force restricted to each query's candidate pool — the reference
    the rescore stage must match bit-for-bit."""
    n = x_q.shape[0]
    docs = np.full((n, k), -1, np.int32)
    dist = np.full((n, k), np.inf, np.float32)
    for i in range(n):
        ids = np.unique(cand[i][valid[i]]).astype(np.int64)
        if not ids.size:
            continue
        sel, d = brute_force_topk_dist(x_q[i : i + 1], x_all[ids], k)
        kk = sel.shape[1]
        docs[i, :kk] = ids[sel[0]]
        dist[i, :kk] = np.maximum(d[0], 0.0).astype(np.float32)
    return docs, dist


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "ell"])
@pytest.mark.parametrize("chunk", [16, 512], ids=["multichunk", "onechunk"])
def test_golden_rescore_equals_pool_restricted_brute_force(sparse, chunk):
    """The tentpole claim: ``topk_search(..., rp=...)`` ≡ brute force over
    each query's leaf candidate pool, bit-exact (ids AND distances), however
    the queries are chunked."""
    x, data, proj, rpb, tree = _rp_case(sparse)
    q = x[:70]
    docs, dist = topk_search(tree, q, k=5, beam=4, chunk=chunk, rp=rpb)
    cand, valid, x_q = rp_candidate_pools(tree, q, rpb, beam=4, chunk=chunk)
    np.testing.assert_array_equal(x_q, q.astype(np.float32))
    ref_docs, ref_dist = _pool_reference(x_q, cand, valid, x, k=5)
    np.testing.assert_array_equal(np.asarray(docs), ref_docs)
    np.testing.assert_array_equal(np.asarray(dist), ref_dist)


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "ell"])
def test_rp_store_backed_matches_in_memory(sparse, tmp_path):
    """Store-backed RP (projection streamed off disk, rescore through
    ``CorpusStore.take_rows``) is bit-identical to the in-memory pipeline:
    same projected rows, same tree, same answers — for dense and ELL
    stores, dense and store-view queries."""
    from repro.core.store import open_store, save_store

    x, data, proj, rpb, tree = _rp_case(sparse)
    path = os.path.join(str(tmp_path), "store")
    save_store(path, data, block_docs=64)
    store = open_store(path, budget_bytes=1)  # one-block budget → evictions

    rpb_st = RandomProjBackend.from_store(store, proj)
    np.testing.assert_array_equal(
        np.asarray(rpb.proj.x), np.asarray(rpb_st.proj.x)
    )
    tree_st = kt.build_from_store(store, order=6, batch_size=32,
                                  key=jax.random.PRNGKey(1), projection=proj)
    assert_trees_equal(tree, tree_st)

    q = x[:40]
    d_mem, s_mem = topk_search(tree, q, k=5, beam=4, chunk=16, rp=rpb)
    d_st, s_st = topk_search(tree_st, q, k=5, beam=4, chunk=16,
                             rp=proj, rp_corpus=store)
    np.testing.assert_array_equal(d_mem, d_st)
    np.testing.assert_array_equal(s_mem, s_st)
    # store-view queries (out-of-core q) answer identically too
    d_sv, s_sv = topk_search(tree_st, store.view(0, 40), k=5, beam=4,
                             chunk=16, rp=proj, rp_corpus=store)
    np.testing.assert_array_equal(d_mem, d_sv)
    np.testing.assert_array_equal(s_mem, s_sv)


def test_rp_cached_path_bit_identical_and_hits():
    """``topk_search_cached(..., rp=...)``: the miss path computes through
    the RP engine, the second pass serves from the cache — both bit-equal
    the uncached call."""
    x, data, proj, rpb, tree = _rp_case(False)
    q = x[:30]
    ref_d, ref_s = topk_search(tree, q, k=5, beam=4, rp=rpb)
    cache = AnswerCache(64)
    for _ in range(2):
        d, s = topk_search_cached(tree, q, cache, k=5, beam=4, rp=rpb)
        np.testing.assert_array_equal(d, np.asarray(ref_d))
        np.testing.assert_array_equal(s, np.asarray(ref_s))
    assert cache.stats["hits"] >= 30


def test_rp_degrade_mode_refused():
    x, data, proj, rpb, tree = _rp_case(False)
    with pytest.raises(ValueError, match="degrade"):
        topk_search(tree, x[:4], k=3, rp=rpb, on_fault="degrade")


def test_rp_typed_resolution_errors():
    x, data, proj, rpb, tree = _rp_case(False)
    with pytest.raises(TypeError, match="rp must be"):
        topk_search(tree, x[:4], k=3, rp="nope")
    with pytest.raises(ValueError, match="rp_corpus"):
        # a store-projected backend has no in-memory base to rescore from
        bare = RandomProjBackend(proj=rpb.proj, projection=proj, base=None)
        topk_search(tree, x[:4], k=3, rp=bare)
    with pytest.raises(ProjectionMismatch, match="in_dim"):
        topk_search(tree, x[:4, :10], k=3, rp=rpb)
    wrong_tree = kt.build(jnp.asarray(x), order=6, batch_size=32,
                          key=jax.random.PRNGKey(1))
    with pytest.raises(ProjectionMismatch, match="tree dim"):
        topk_search(wrong_tree, x[:4], k=3, rp=rpb)


# ------------------------------------------------------------ sharded path

_SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json, tempfile
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {tests!r})
    import numpy as np, jax, jax.numpy as jnp
    from fixtures import clustered_corpus, sparsify
    from repro.core import ktree as kt
    from repro.core.backend import (
        RandomProjBackend, make_backend, make_projection, shard_from_store,
    )
    from repro.core.query import topk_search, topk_search_sharded
    from repro.core.store import open_store, save_store
    from repro.sparse.csr import csr_from_dense

    out = {{}}
    rng = np.random.default_rng(0)
    x = clustered_corpus(rng, n_clusters=5, per_cluster=60, d=24)
    q = (x[:70] + 0.05 * rng.normal(0, 1, (70, 24))).astype(np.float32)
    mesh = jax.make_mesh((8,), ("data",))
    proj = make_projection(24, 8, seed=3)

    def bitmatch(a, b):
        return dict(docs=bool((np.asarray(a[0]) == np.asarray(b[0])).all()),
                    dists=bool((np.asarray(a[1]) == np.asarray(b[1])).all()))

    # dense base: in-memory shards
    rpb = RandomProjBackend.wrap(x, proj)
    tree = kt.build(rpb, order=8, batch_size=32, key=jax.random.PRNGKey(1))
    single = topk_search(tree, q, k=10, beam=4, chunk=32, rp=rpb)
    out["dense"] = bitmatch(single, topk_search_sharded(
        mesh, tree, q, corpus=x, k=10, beam=4, chunk=32, rp=proj))

    # ELL base: in-memory sparse shards
    xs = sparsify(rng, x)
    rpb_s = RandomProjBackend.wrap(csr_from_dense(xs), proj)
    tree_s = kt.build(rpb_s, order=8, batch_size=32, key=jax.random.PRNGKey(1))
    single_s = topk_search(tree_s, q, k=10, beam=4, chunk=32, rp=rpb_s)
    out["ell"] = bitmatch(single_s, topk_search_sharded(
        mesh, tree_s, q, corpus=csr_from_dense(xs), k=10, beam=4, chunk=32,
        rp=proj))

    # store-backed shards: rescore rows fetched through per-shard partition
    # caches must still bit-match the in-memory answers
    path = os.path.join(tempfile.mkdtemp(prefix="rp-shard"), "store")
    save_store(path, csr_from_dense(xs), block_docs=64)
    store = open_store(path, budget_bytes=1)
    sshards = shard_from_store(mesh, store, budget_bytes=1)
    out["store"] = bitmatch(single_s, topk_search_sharded(
        mesh, tree_s, q, corpus=sshards, k=10, beam=4, chunk=32, rp=proj))
    print("RESULT:" + json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def rp_sharded_results():
    script = _SHARDED_SCRIPT.format(src=_SRC, tests=_TESTS)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.mark.parametrize("case", ["dense", "ell", "store"])
def test_rp_sharded_bit_identical_to_single_device(rp_sharded_results, case):
    """Sharded RP answers are bit-identical to single-device RP — candidate
    pools come from the same jitted descent and the rescore is the same
    per-query brute-force call, wherever the rows are fetched from."""
    r = rp_sharded_results[case]
    assert r["docs"] and r["dists"], r


# ------------------------------------------------------------- checkpoints

def test_save_ktree_carries_projection(tmp_path):
    from repro.ckpt import load_ktree_projection, restore_ktree, save_ktree

    x, data, proj, rpb, tree = _rp_case(False)
    path = os.path.join(str(tmp_path), "tree")
    save_ktree(path, tree, projection=proj)
    re_tree = restore_ktree(path)
    re_proj = load_ktree_projection(path)
    assert_trees_equal(tree, re_tree)
    assert re_proj.spec() == proj.spec()
    np.testing.assert_array_equal(
        np.asarray(re_proj.matrix), np.asarray(proj.matrix)
    )
    # a snapshot without a projection reports none
    plain = os.path.join(str(tmp_path), "plain")
    save_ktree(plain, tree)
    assert load_ktree_projection(plain) is None


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "ell"])
def test_index_checkpoint_replay_cycle(sparse, tmp_path):
    """The acceptance-criteria cycle: build → save_index → restore_index →
    query replays bit-identically from the stored projection seed — the
    restored projection is rebuilt from the spec, never copied."""
    from repro.ckpt import restore_index, save_index
    from repro.core.store import open_store, save_store

    x, data, proj, rpb, tree0 = _rp_case(sparse)
    spath = os.path.join(str(tmp_path), "store")
    save_store(spath, data, block_docs=64)
    store = open_store(spath)
    tree = kt.build_from_store(store, order=6, batch_size=32,
                               key=jax.random.PRNGKey(1), projection=proj)
    q = x[:40]
    ref = topk_search(tree, q, k=5, beam=4, chunk=16, rp=proj, rp_corpus=store)

    ipath = os.path.join(str(tmp_path), "index")
    save_index(ipath, tree, store, projection=proj)
    re_tree, re_store, re_proj = restore_index(ipath, budget_bytes=1 << 20)
    assert_trees_equal(tree, re_tree)
    assert re_proj.spec() == proj.spec()
    got = topk_search(re_tree, q, k=5, beam=4, chunk=16,
                      rp=re_proj, rp_corpus=re_store)
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(got[1]))
    # stating the matching expectation also restores
    out = restore_index(ipath, projection=proj)
    assert len(out) == 3 and out[2].spec() == proj.spec()


def test_index_checkpoint_projection_mismatch_refused(tmp_path):
    """Restoring against a different seed/dim — or expecting a projection a
    plain checkpoint never recorded — raises the typed ``ProjectionMismatch``
    instead of silently serving through the wrong matrix."""
    from repro.ckpt import restore_index, save_index
    from repro.core.store import open_store, save_store

    x, data, proj, rpb, _ = _rp_case(False)
    spath = os.path.join(str(tmp_path), "store")
    save_store(spath, data, block_docs=64)
    store = open_store(spath)
    tree = kt.build_from_store(store, order=6, batch_size=32,
                               key=jax.random.PRNGKey(1), projection=proj)
    ipath = os.path.join(str(tmp_path), "index")
    save_index(ipath, tree, store, projection=proj)

    other_seed = make_projection(proj.in_dim, proj.out_dim, seed=proj.seed + 1)
    with pytest.raises(ProjectionMismatch, match="expects"):
        restore_index(ipath, projection=other_seed)
    other_dim = make_projection(proj.in_dim, proj.out_dim * 2, seed=proj.seed)
    with pytest.raises(ProjectionMismatch, match="expects"):
        restore_index(ipath, projection=other_dim)

    # exact-path checkpoint + RP expectation → refused, and vice versa the
    # RP checkpoint restores only as a 3-tuple (never silently exact)
    plain_tree = kt.build_from_store(store, order=6, batch_size=32,
                                     key=jax.random.PRNGKey(1))
    ppath = os.path.join(str(tmp_path), "plain_index")
    save_index(ppath, plain_tree, store)
    with pytest.raises(ProjectionMismatch, match="records no"):
        restore_index(ppath, projection=proj)
    assert len(restore_index(ppath)) == 2


# ------------------------------------------------------ recall acceptance

def test_recall_floors_and_identity_anchor():
    """Documented recall floors on the clustered fixture corpus (d=512,
    normalised rows, 64 perturbed queries, k=10, beam=4, seeds pinned —
    deterministic on CPU):

    - rp_dim=64  → recall@10 ≥ 0.40   (measured 0.50)
    - rp_dim=256 → recall@10 ≥ 0.50   (measured 0.62)
    - the exact dense path measures 0.64 here, so rp_dim=256 routes within
      ~0.03 of exact while descending 2× narrower vectors;
    - rp_dim=d with kind="identity" recovers the exact path: the tree
      bit-matches the plain dense build and the answer ids are equal."""
    rng = np.random.default_rng(0)
    x = clustered_corpus(rng, n_clusters=6, per_cluster=50, d=512, spread=5.0)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    q = (x[:64] + 0.02 * rng.normal(0, 1, (64, 512))).astype(np.float32)
    true = brute_force_topk(q, x, 10)

    recalls = {}
    for rd in (64, 256):
        proj = make_projection(512, rd, seed=0)
        rpb = RandomProjBackend.wrap(x, proj)
        tree = kt.build(rpb, order=8, batch_size=32, key=jax.random.PRNGKey(1))
        docs, _ = topk_search(tree, q, k=10, beam=4, rp=rpb)
        recalls[rd] = recall_at_k(docs, true)
    assert recalls[64] >= 0.40, recalls
    assert recalls[256] >= 0.50, recalls
    assert recalls[256] >= recalls[64], recalls

    tree_exact = kt.build(jnp.asarray(x), order=8, batch_size=32,
                          key=jax.random.PRNGKey(1))
    docs_exact, _ = topk_search(tree_exact, jnp.asarray(q), k=10, beam=4)
    ident = make_projection(512, 512, kind="identity")
    rpb_i = RandomProjBackend.wrap(x, ident)
    tree_i = kt.build(rpb_i, order=8, batch_size=32, key=jax.random.PRNGKey(1))
    assert_trees_equal(tree_exact, tree_i)
    docs_i, _ = topk_search(tree_i, q, k=10, beam=4, rp=rpb_i)
    np.testing.assert_array_equal(np.asarray(docs_i), np.asarray(docs_exact))


def test_project_corpus_streaming_matches_in_memory(tmp_path):
    """The fixed PROJECT_CHUNK granularity makes the streamed (store) and
    in-memory projections bit-identical — the invariant behind
    ``from_store ≡ wrap``."""
    from repro.core.store import open_store, save_store

    x, data = _corpus(True, n=150, d=20)
    proj = make_projection(20, 6, seed=9)
    z_mem = project_corpus(proj, make_backend(data))
    path = os.path.join(str(tmp_path), "store")
    save_store(path, data, block_docs=32)
    z_st = project_corpus(proj, open_store(path, budget_bytes=1), prefetch=2)
    np.testing.assert_array_equal(z_mem, z_st)
