"""Phase-span profiler (DESIGN.md §11): span exactness and nesting on an
injected fake clock, cross-thread interval merging + read∩compute overlap,
and the disabled-mode contract (``NULL_PROFILER`` hands out one shared no-op
span and records nothing — the hot paths rely on that being free)."""
import threading

import pytest

from repro.core.profile import (
    NULL_PROFILER, NullProfiler, Profiler, SpanRecord,
)


class FakeClock:
    """Deterministic monotonic clock: every read advances by ``step``."""

    def __init__(self, step=1.0, t=0.0):
        self.t = t
        self.step = step

    def __call__(self):
        now = self.t
        self.t += self.step
        return now


# ----------------------------------------------------------------- spans

def test_span_exact_on_fake_clock():
    prof = Profiler(clock=FakeClock())
    with prof.span("read"):
        pass
    (r,) = prof.records
    assert r == SpanRecord("read", 0.0, 1.0, 0)
    assert r.seconds == 1.0


def test_span_nesting_depths_and_order():
    """Nested spans carry depth = outer + 1 and close inner-first; sibling
    spans after the nest return to the outer depth."""
    prof = Profiler(clock=FakeClock())
    with prof.span("outer"):
        with prof.span("inner"):
            pass
        with prof.span("inner2"):
            pass
    with prof.span("top"):
        pass
    names = [(r.name, r.depth) for r in prof.records]
    assert names == [
        ("inner", 1), ("inner2", 1), ("outer", 0), ("top", 0),
    ]
    inner, inner2, outer, top = prof.records
    # clock reads: outer.t0=0, inner=(1,2), inner2=(3,4), outer.t1=5, top=(6,7)
    assert (outer.t0, outer.t1) == (0.0, 5.0)
    assert (inner.t0, inner.t1) == (1.0, 2.0)
    assert (inner2.t0, inner2.t1) == (3.0, 4.0)
    assert (top.t0, top.t1) == (6.0, 7.0)


def test_span_records_on_exception_and_restores_depth():
    prof = Profiler(clock=FakeClock())
    with pytest.raises(RuntimeError):
        with prof.span("boom"):
            raise RuntimeError("x")
    (r,) = prof.records
    assert r.name == "boom" and r.seconds == 1.0
    # depth must be back at 0: a new span records at depth 0
    with prof.span("after"):
        pass
    assert prof.records[-1].depth == 0


def test_depth_is_per_thread():
    """A span open on the main thread does not deepen a worker's spans —
    the Prefetcher-reader-thread sharing contract."""
    prof = Profiler(clock=FakeClock())
    done = threading.Event()

    def worker():
        with prof.span("read"):
            pass
        done.set()

    with prof.span("compute"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert done.is_set()
    depths = {r.name: r.depth for r in prof.records}
    assert depths == {"read": 0, "compute": 0}


# ------------------------------------------------- totals / intervals

def test_totals_and_reset():
    prof = Profiler()
    prof.add("read", 0.0, 2.0)
    prof.add("read", 5.0, 6.0)
    prof.add("compute", 1.0, 4.0)
    t = prof.totals()
    assert t["read"] == {"seconds": 3.0, "count": 2}
    assert t["compute"] == {"seconds": 3.0, "count": 1}
    prof.reset()
    assert prof.records == () and prof.totals() == {}


def test_intervals_merge_overlapping_and_adjacent():
    prof = Profiler()
    prof.add("read", 0.0, 2.0)
    prof.add("read", 1.5, 3.0)   # overlaps the first
    prof.add("read", 3.0, 4.0)   # touches → merges
    prof.add("read", 10.0, 11.0)
    assert prof.intervals("read") == [(0.0, 4.0), (10.0, 11.0)]
    assert prof.intervals("nope") == []


def test_overlap_seconds_exact():
    """read∩compute over hand-built intervals: the tuner's primitive."""
    prof = Profiler()
    prof.add("read", 0.0, 4.0)
    prof.add("read", 8.0, 10.0)
    prof.add("compute", 2.0, 9.0)
    # [0,4]∩[2,9] = 2, [8,10]∩[2,9] = 1
    assert prof.overlap_seconds("read", "compute") == pytest.approx(3.0)
    assert prof.overlap_seconds("compute", "read") == pytest.approx(3.0)
    assert prof.overlap_seconds("read", "nope") == 0.0


def test_overlap_zero_when_serialised():
    """Phases that never coexist on the wall clock — the prefetch=0 story —
    measure exactly zero overlap."""
    prof = Profiler()
    for i in range(4):
        prof.add("read", 2 * i, 2 * i + 1)
        prof.add("compute", 2 * i + 1, 2 * i + 2)
    assert prof.overlap_seconds("read", "compute") == 0.0


def test_phase_report_mentions_phases_and_overlap():
    prof = Profiler()
    prof.add("read", 0.0, 1.0)
    prof.add("compute", 0.5, 1.5)
    rep = prof.phase_report()
    assert "read=" in rep and "compute=" in rep and "read∩compute=" in rep


# --------------------------------------------------------- disabled mode

def test_null_profiler_records_nothing():
    with NULL_PROFILER.span("read"):
        with NULL_PROFILER.span("disk_read"):
            pass
    NULL_PROFILER.add("read", 0.0, 1.0)
    assert NULL_PROFILER.records == ()
    assert not NULL_PROFILER.enabled and Profiler.enabled


def test_null_profiler_span_is_shared_singleton():
    """``span()`` hands back the *same* object every call — the
    zero-allocation contract the hot-path defaults rely on."""
    a = NULL_PROFILER.span("a")
    b = NULL_PROFILER.span("b")
    assert a is b
    assert a is NullProfiler().span("c")
