"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


SHAPES = [
    (1, 1, 1), (7, 5, 3), (128, 128, 128), (130, 257, 64),
    (64, 1000, 96), (200, 300, 1000), (33, 129, 2048),
]


@pytest.mark.parametrize("b,k,d", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nn_assign_sweep(b, k, d, dtype):
    rng = np.random.default_rng(b * 1000 + k + d)
    x = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32)).astype(dtype)
    c = jnp.asarray(rng.normal(0, 1, (k, d)).astype(np.float32)).astype(dtype)
    idx, dist = ops.nn_assign(x, c)
    ridx, rdist = ref.nn_assign_ref(x, c)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    # discrete boundary: accept either equal idx or equal distance within tol
    same = np.asarray(idx) == np.asarray(ridx)
    close = np.abs(np.asarray(dist) - np.asarray(rdist)) <= tol * (1 + np.abs(np.asarray(rdist)))
    assert (same | close).all()
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist), rtol=tol, atol=tol)


def test_nn_assign_valid_mask():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (50, 64)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (300, 64)).astype(np.float32))
    valid = jnp.asarray(rng.random(300) > 0.7)
    idx, dist = ops.nn_assign(x, c, valid=valid)
    ridx, rdist = ref.nn_assign_ref(x, c, valid=valid)
    assert (np.asarray(idx) == np.asarray(ridx)).all()
    assert np.asarray(valid)[np.asarray(idx)].all()


def test_nn_assign_block_sizes():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (100, 70)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (190, 70)).astype(np.float32))
    ridx, _ = ref.nn_assign_ref(x, c)
    for bm, bk in [(32, 64), (128, 128), (256, 128)]:
        idx, _ = ops.nn_assign(x, c, bm=bm, bk=bk)
        assert (np.asarray(idx) == np.asarray(ridx)).all(), (bm, bk)


ELL_SHAPES = [(1, 8, 1, 16), (8, 16, 9, 40), (130, 32, 120, 256), (64, 64, 200, 1000)]


@pytest.mark.parametrize("b,nz,k,d", ELL_SHAPES)
def test_ell_spmm_sweep(b, nz, k, d):
    rng = np.random.default_rng(b + nz + k)
    vals = rng.normal(0, 1, (b, nz)).astype(np.float32)
    vals[:, nz // 2:] *= rng.random((b, nz - nz // 2)) > 0.4  # padding pattern
    cols = rng.integers(0, d, (b, nz)).astype(np.int32)
    c = rng.normal(0, 1, (k, d)).astype(np.float32)
    s = ops.ell_spmm(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(c))
    rs = ref.ell_spmm_ref(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=3e-5, atol=3e-5)


def test_ell_spmm_duplicate_columns():
    # repeated column ids within a row must accumulate
    vals = jnp.asarray([[1.0, 2.0, 3.0, 0.0]])
    cols = jnp.asarray([[2, 2, 5, 0]], dtype=jnp.int32)
    c = jnp.asarray(np.eye(8, dtype=np.float32))
    s = ops.ell_spmm(vals, cols, c)
    assert float(s[0, 2]) == pytest.approx(3.0)
    assert float(s[0, 5]) == pytest.approx(3.0)


def test_medoid_assign_sparse_matches_dense():
    rng = np.random.default_rng(2)
    from repro.sparse import csr_from_dense, ell_from_csr
    x = (rng.normal(0, 1, (40, 64)) * (rng.random((40, 64)) < 0.3)).astype(np.float32)
    m = csr_from_dense(x)
    e = ell_from_csr(m)
    centers = jnp.asarray(rng.normal(0, 1, (17, 64)).astype(np.float32))
    row_sq = jnp.asarray((x * x).sum(1))
    idx, dist = ops.medoid_assign_sparse(e.values, e.cols, row_sq, centers)
    ridx, rdist = ref.nn_assign_ref(jnp.asarray(x), centers)
    assert (np.asarray(idx) == np.asarray(ridx)).all()
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.integers(1, 150), st.integers(1, 128), st.integers(0, 9999))
def test_nn_assign_property(b, k, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (k, d)).astype(np.float32))
    idx, dist = ops.nn_assign(x, c)
    ridx, rdist = ref.nn_assign_ref(x, c)
    assert (np.asarray(idx) == np.asarray(ridx)).all()


# ---------------------------------------------------------------------------
# nn_topk — masked top-k accumulator (query engine, DESIGN.md §7)
# ---------------------------------------------------------------------------

TOPK_SHAPES = [
    (1, 1, 1, 1), (7, 5, 3, 3), (128, 128, 128, 8), (130, 257, 64, 10),
    (33, 129, 200, 17), (64, 300, 96, 32),
]


@pytest.mark.parametrize("b,k,d,kq", TOPK_SHAPES)
def test_nn_topk_sweep(b, k, d, kq):
    """Kernel (interpret mode) vs oracle on non-multiple-of-tile shapes:
    distances must match at every rank, and each returned id must be
    consistent with its rank's distance (robust to argmin boundary ulps)."""
    rng = np.random.default_rng(b * 1000 + k + d + kq)
    x = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (k, d)).astype(np.float32))
    idx, dist = ops.nn_topk(x, c, kq)
    ridx, rdist = ref.nn_topk_ref(x, c, kq)
    np.testing.assert_allclose(
        np.asarray(dist), np.asarray(rdist), rtol=3e-5, atol=3e-5
    )
    # verify ids against the true distance matrix at the claimed ranks
    d_true = np.asarray(ref._full_sqdist(x, c))
    ii = np.asarray(idx)
    got = np.where(ii >= 0, d_true[np.arange(b)[:, None], np.maximum(ii, 0)], np.inf)
    np.testing.assert_allclose(
        got, np.asarray(rdist), rtol=3e-5, atol=3e-5
    )
    assert (np.sort(np.asarray(dist), axis=1) == np.asarray(dist)).all()


def test_nn_topk_k_exceeds_centres():
    """k > centre count (k > docs-in-leaf in the query engine): the tail pads
    with (−1, +inf) in both kernel and oracle."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (9, 16)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (5, 16)).astype(np.float32))
    idx, dist = ops.nn_topk(x, c, 12)
    ridx, rdist = ref.nn_topk_ref(x, c, 12)
    assert (np.asarray(idx)[:, 5:] == -1).all()
    assert np.isinf(np.asarray(dist)[:, 5:]).all()
    assert (np.asarray(idx)[:, :5] == np.asarray(ridx)[:, :5]).all()
    np.testing.assert_allclose(
        np.asarray(dist)[:, :5], np.asarray(rdist)[:, :5], rtol=3e-5, atol=3e-5
    )


def test_nn_topk_all_masked():
    """Every centre masked out → all results are (−1, +inf)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (17, 8)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (140, 8)).astype(np.float32))
    valid = jnp.zeros((140,), bool)
    idx, dist = ops.nn_topk(x, c, 4, valid=valid)
    ridx, rdist = ref.nn_topk_ref(x, c, 4, valid=valid)
    assert (np.asarray(idx) == -1).all() and (np.asarray(ridx) == -1).all()
    assert np.isinf(np.asarray(dist)).all() and np.isinf(np.asarray(rdist)).all()


def test_nn_topk_partial_mask_agrees():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 1, (40, 32)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (260, 32)).astype(np.float32))
    valid = jnp.asarray(rng.random(260) > 0.5)
    idx, dist = ops.nn_topk(x, c, 9, valid=valid)
    ridx, rdist = ref.nn_topk_ref(x, c, 9, valid=valid)
    assert (np.asarray(idx) == np.asarray(ridx)).all()
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist), rtol=3e-5, atol=3e-5)
    assert np.asarray(valid)[np.asarray(idx)].all()


def test_nn_topk_ties_lowest_index_first():
    """Exact-arithmetic ties (duplicate centres) resolve to ascending centre
    id — ``lax.top_k`` stability, which the online merge must reproduce even
    across tile boundaries."""
    x = jnp.zeros((3, 8), jnp.float32)
    c = jnp.zeros((260, 8), jnp.float32)  # 260 > bk: ties span two tiles
    idx, dist = ops.nn_topk(x, c, 6)
    ridx, rdist = ref.nn_topk_ref(x, c, 6)
    expect = np.broadcast_to(np.arange(6, dtype=np.int32), (3, 6))
    np.testing.assert_array_equal(np.asarray(idx), expect)
    np.testing.assert_array_equal(np.asarray(ridx), expect)
    assert (np.asarray(dist) == 0).all()
    # two-level ties: duplicates at integer distances across tiles
    base = np.zeros((300, 4), np.float32)
    base[150:, 0] = 1.0     # second tile rows at distance 1
    base[:150, 0] = 2.0     # first tile rows at distance 4
    base[7, 0] = 1.0        # one first-tile row joins the distance-1 group
    xq = jnp.zeros((2, 4), jnp.float32)
    cq = jnp.asarray(base)
    idx, dist = ops.nn_topk(xq, cq, 4)
    ridx, _ = ref.nn_topk_ref(xq, cq, 4)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    assert np.asarray(idx)[0, 0] == 7  # lowest id of the nearest tie group


def test_nn_topk_top1_matches_nn_assign():
    """The kernel family is internally consistent: top-1 of nn_topk equals
    nn_assign on the same inputs (both stable-tie argmin semantics)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (70, 48)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (210, 48)).astype(np.float32))
    i1, d1 = ops.nn_assign(x, c)
    it, dt = ops.nn_topk(x, c, 3)
    assert (np.asarray(i1) == np.asarray(it)[:, 0]).all()
    np.testing.assert_allclose(np.asarray(d1), np.asarray(dt)[:, 0], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(1, 150), st.integers(1, 64),
       st.integers(1, 20), st.integers(0, 9999))
def test_nn_topk_property(b, k, d, kq, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (k, d)).astype(np.float32))
    idx, dist = ops.nn_topk(x, c, kq)
    ridx, rdist = ref.nn_topk_ref(x, c, kq)
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist),
                               rtol=3e-5, atol=3e-5)
    # padding iff rank beyond the centre count
    assert ((np.asarray(idx) == -1) == ~np.isfinite(np.asarray(dist))).all()


def test_kernel_flag_in_kmeans():
    """assign(use_kernel=True) plugs into the clustering stack."""
    from repro.core.kmeans import assign as km_assign
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (60, 32)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (9, 32)).astype(np.float32))
    i1, d1 = km_assign(x, c, use_kernel=False)
    i2, d2 = km_assign(x, c, use_kernel=True)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4, atol=1e-4)


def test_topk_merge_ref_exact_union():
    """The serving merge oracle: merging per-shard top-k lists over disjoint id
    subsets equals a global top-k over the union; (−1, +inf) padding is
    ignored; ties resolve shard-major."""
    rng = np.random.default_rng(11)
    b, s, kq, n = 7, 4, 5, 64
    dist_full = rng.random((b, n)).astype(np.float32)
    dist_full[0, 3] = dist_full[0, 19]  # cross-shard exact tie
    ids_full = np.arange(n)
    # per-shard lists: shard j owns the contiguous id range [j*16, (j+1)*16)
    shard_ids = np.full((b, s, kq), -1, np.int32)
    shard_d = np.full((b, s, kq), np.inf, np.float32)
    for j in range(s):
        seg = dist_full[:, j * 16:(j + 1) * 16]
        order = np.argsort(seg, axis=1, kind="stable")[:, :kq]
        shard_ids[:, j] = order + j * 16
        shard_d[:, j] = np.take_along_axis(seg, order, 1)
    got_ids, got_d = ref.topk_merge_ref(
        jnp.asarray(shard_ids), jnp.asarray(shard_d), kq)
    want = np.argsort(dist_full, axis=1, kind="stable")[:, :kq]
    np.testing.assert_array_equal(np.asarray(got_ids), ids_full[want])
    np.testing.assert_allclose(
        np.asarray(got_d), np.take_along_axis(dist_full, want, 1), rtol=1e-6)


def test_topk_merge_ref_padding_and_k_growth():
    """Shards with fewer than k finite candidates pad; a merge wider than the
    finite union pads with (−1, +inf)."""
    ids = jnp.asarray([[[0, 1, -1], [17, -1, -1]]], jnp.int32)
    d = jnp.asarray([[[0.5, 2.0, np.inf], [1.0, np.inf, np.inf]]], jnp.float32)
    got_ids, got_d = ref.topk_merge_ref(ids, d, 5)
    np.testing.assert_array_equal(np.asarray(got_ids[0]), [0, 17, 1, -1, -1])
    assert np.isinf(np.asarray(got_d[0][3:])).all()
