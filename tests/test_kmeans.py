import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.kmeans import (
    kmeans, kmeans_fixed_iters, bisecting_kmeans, minibatch_kmeans,
    assign, pairwise_sqdist, lloyd_step, kmeans_pp_init,
)


def planted(rng, k=5, per=60, d=8, spread=6.0):
    means = rng.normal(0, spread, (k, d))
    x = np.concatenate([rng.normal(means[i], 1.0, (per, d)) for i in range(k)])
    return jnp.asarray(x.astype(np.float32)), np.repeat(np.arange(k), per)


def test_pairwise_sqdist_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (20, 6)).astype(np.float32)
    c = rng.normal(0, 1, (7, 6)).astype(np.float32)
    d = np.asarray(pairwise_sqdist(jnp.asarray(x), jnp.asarray(c)))
    ref = ((x[:, None] - c[None]) ** 2).sum(-1)
    np.testing.assert_allclose(d, ref, rtol=1e-4, atol=1e-4)


def test_kmeans_recovers_planted_clusters():
    rng = np.random.default_rng(1)
    x, labels = planted(rng)
    res = kmeans(jax.random.PRNGKey(0), x, 5)
    from repro.core.metrics import micro_purity
    p = float(micro_purity(res.assign, jnp.asarray(labels), 5, 5))
    assert p > 0.95


def test_lloyd_sse_non_increasing():
    rng = np.random.default_rng(2)
    x, _ = planted(rng, k=4, per=40)
    key = jax.random.PRNGKey(3)
    centers = kmeans_pp_init(key, x, 4)
    prev = np.inf
    for _ in range(8):
        centers, idx, counts, sse = lloyd_step(x, centers)
        assert float(sse) <= prev + 1e-3
        prev = float(sse)


def test_weighted_equals_duplicated():
    rng = np.random.default_rng(3)
    x, _ = planted(rng, k=3, per=20, d=4)
    w = jnp.ones(x.shape[0]).at[5].set(3.0)
    x_dup = jnp.concatenate([x, x[5:6], x[5:6]])
    c0 = x[:3]
    c_w, *_ = lloyd_step(x, c0, w=w)
    c_d, *_ = lloyd_step(x_dup, c0)
    np.testing.assert_allclose(np.asarray(c_w), np.asarray(c_d), rtol=1e-4, atol=1e-5)


def test_fixed_iters_runs_exact_count():
    rng = np.random.default_rng(4)
    x, _ = planted(rng, k=3, per=30)
    res = kmeans_fixed_iters(jax.random.PRNGKey(0), x, 3, iters=4)
    assert int(res.iters) == 4 and np.isfinite(float(res.sse))


def test_bisecting_produces_k_clusters():
    rng = np.random.default_rng(5)
    x, labels = planted(rng, k=6, per=30)
    res = bisecting_kmeans(jax.random.PRNGKey(1), x, 6)
    sizes = np.bincount(np.asarray(res.assign), minlength=6)
    assert (sizes > 0).all()
    from repro.core.metrics import micro_purity
    assert float(micro_purity(res.assign, jnp.asarray(labels), 6, 6)) > 0.8


def test_minibatch_kmeans_reasonable():
    rng = np.random.default_rng(6)
    x, labels = planted(rng, k=4, per=80)
    res = minibatch_kmeans(jax.random.PRNGKey(2), x, 4, batch=64, steps=100)
    full = kmeans(jax.random.PRNGKey(2), x, 4)
    assert float(res.sse) < 3.0 * float(full.sse) + 1e-3


def test_assign_respects_valid_mask():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1, (10, 4)).astype(np.float32))
    c = jnp.asarray(rng.normal(0, 1, (5, 4)).astype(np.float32))
    valid = jnp.asarray([True, False, True, False, True])
    idx, _ = assign(x, c, valid=valid)
    assert set(np.asarray(idx).tolist()) <= {0, 2, 4}


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(0, 99999))
def test_kmeans_sse_beats_random_centers(k, seed):
    rng = np.random.default_rng(seed)
    x, _ = planted(rng, k=k, per=25, d=5)
    res = kmeans(jax.random.PRNGKey(seed), x, k)
    rand_c = x[: k]
    _, d_rand = assign(x, rand_c)
    assert float(res.sse) <= float(d_rand.sum()) + 1e-3
