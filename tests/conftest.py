import os
import sys

# src layout import without install; tests run single-device (the 512-device
# override belongs ONLY to the dry-run entry point)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
