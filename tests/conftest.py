import os
import sys
import types

# src layout import without install; tests run single-device (the 512-device
# override belongs ONLY to the dry-run entry point)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute tests (subprocess re-runs under forced device "
        "counts); deselect with -m 'not slow'",
    )

# ---------------------------------------------------------------------------
# hypothesis guard: the property tests import `hypothesis` at module scope, so
# a missing install used to kill collection of six whole modules. When the
# package is absent, install a shim that (a) lets the modules import, and
# (b) turns every @given test into a clean pytest skip — the non-property
# tests in those modules still run. `pip install -r requirements-dev.txt`
# restores the real thing.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            def _strategy(*_args, **_kwargs):
                return None

            _strategy.__name__ = name
            return _strategy

    _st = _Strategies("hypothesis.strategies")
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.assume = lambda *_a, **_k: True
    _hyp.note = lambda *_a, **_k: None
    _hyp.example = _settings
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
