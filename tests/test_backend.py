"""Vector-backend seam: dense vs ELL-sparse documents through the same K-tree
(route → insert → split → read APIs), medoid mode, and incremental insertion
on a second shard — the paper's §2 sparse extension."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ktree as kt
from repro.core.backend import DenseBackend, EllSparseBackend, make_backend
from repro.core.metrics import micro_purity
from repro.data.pipeline import corpus_backend, shard_bounds
from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
from repro.sparse.csr import csr_from_dense, csr_slice_rows, csr_to_dense


def small_corpus(n_docs=300, culled=200, seed=0):
    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, labels = prepared_corpus(spec, seed=seed)
    return spec, m, labels


def test_make_backend_dispatch():
    rng = np.random.default_rng(0)
    x = (rng.normal(0, 1, (20, 12)) * (rng.random((20, 12)) < 0.4)).astype(np.float32)
    m = csr_from_dense(x)
    assert isinstance(make_backend(jnp.asarray(x)), DenseBackend)
    assert isinstance(make_backend(m), EllSparseBackend)
    assert isinstance(make_backend(m, "dense"), DenseBackend)
    sp = make_backend(jnp.asarray(x), "sparse")
    assert isinstance(sp, EllSparseBackend)
    # idempotent on backend instances
    assert make_backend(sp) is sp


def test_backend_primitives_agree():
    """take / row_sq / cross_nodes / cross_flat / nn_flat match dense math."""
    rng = np.random.default_rng(1)
    x = (rng.normal(0, 1, (30, 24)) * (rng.random((30, 24)) < 0.3)).astype(np.float32)
    dense = make_backend(jnp.asarray(x))
    sparse = make_backend(csr_from_dense(x))
    rows = jnp.asarray([0, 3, 7, 29], jnp.int32)
    np.testing.assert_allclose(np.asarray(sparse.take(rows)), x[[0, 3, 7, 29]], rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(sparse.row_sq(rows)), np.asarray(dense.row_sq(rows)), rtol=1e-4
    )
    c_flat = jnp.asarray(rng.normal(0, 1, (9, 24)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(sparse.cross_flat(rows, c_flat)),
        np.asarray(dense.cross_flat(rows, c_flat)),
        rtol=1e-4, atol=1e-5,
    )
    c_nodes = jnp.asarray(rng.normal(0, 1, (4, 5, 24)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(sparse.cross_nodes(rows, c_nodes)),
        np.asarray(dense.cross_nodes(rows, c_nodes)),
        rtol=1e-4, atol=1e-5,
    )
    valid = jnp.asarray([True, True, False, True, True, True, True, True, True])
    i_s, d_s = sparse.nn_flat(rows, c_flat, valid)
    i_d, d_d = dense.nn_flat(rows, c_flat, valid)
    assert (np.asarray(i_s) == np.asarray(i_d)).all()
    np.testing.assert_allclose(np.asarray(d_s), np.asarray(d_d), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("representation", ["dense", "sparse_medoid"])
def test_medoid_build_invariants_both_backends(representation):
    spec, m, labels = small_corpus()
    backend, labels = corpus_backend(spec, representation=representation)
    tree = kt.build(backend, order=10, medoid=True, batch_size=64)
    kt.check_invariants(tree, n_docs=spec.n_docs)
    assign, nc = kt.extract_assignment(tree, spec.n_docs)
    assert (assign >= 0).all()
    p = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), nc, spec.n_labels))
    assert p > 0.3  # far above the ~1/n_labels random floor


def test_sparse_and_dense_backends_build_identical_trees():
    """Same corpus, same key → the backend seam must not change the tree."""
    _, m, _ = small_corpus(n_docs=200, culled=150)
    key = jax.random.PRNGKey(3)
    t_sparse = kt.build(m, order=8, medoid=True, batch_size=64, key=key)
    t_dense = kt.build(m, order=8, medoid=True, batch_size=64, key=key, backend="dense")
    assert int(t_sparse.depth) == int(t_dense.depth)
    n = int(t_sparse.n_nodes)
    assert n == int(t_dense.n_nodes)
    np.testing.assert_array_equal(
        np.asarray(t_sparse.child[:n]), np.asarray(t_dense.child[:n])
    )
    np.testing.assert_allclose(
        np.asarray(t_sparse.centers[:n]), np.asarray(t_dense.centers[:n]),
        rtol=1e-4, atol=1e-5,
    )


@pytest.mark.parametrize("representation", ["dense", "sparse_medoid"])
def test_incremental_insert_second_shard(representation):
    """Build on shard 0, insert() shard 1 — invariants + doc conservation
    must hold for both backends (medoid mode)."""
    spec, m, _ = small_corpus(n_docs=260, culled=150, seed=4)
    lo, hi = shard_bounds(spec.n_docs, 0, 2)
    lo2, hi2 = shard_bounds(spec.n_docs, 1, 2)
    if representation == "dense":
        x = jnp.asarray(np.asarray(csr_to_dense(m)))
        first, second = x[lo:hi], x[lo2:hi2]
    else:
        first, second = csr_slice_rows(m, lo, hi), csr_slice_rows(m, lo2, hi2)
    tree = kt.build(first, order=9, medoid=True, batch_size=64,
                    max_nodes=kt.suggested_max_nodes(spec.n_docs, 9))
    kt.check_invariants(tree, n_docs=hi)
    tree = kt.insert(tree, second, np.arange(lo2, hi2))
    kt.check_invariants(tree, n_docs=spec.n_docs)


def test_incremental_insert_non_medoid_dense_mode():
    """Weighted-mean path updates stay consistent through insert() too."""
    _, m, _ = small_corpus(n_docs=200, culled=120, seed=5)
    x = jnp.asarray(np.asarray(csr_to_dense(m)))
    tree = kt.build(x[:150], order=8, batch_size=64,
                    max_nodes=kt.suggested_max_nodes(200, 8))
    tree = kt.insert(tree, x[150:], np.arange(150, 200))
    kt.check_invariants(tree, n_docs=200)


def test_sparse_queries_route_and_search():
    """assign_via_tree / nn_search accept sparse inputs."""
    spec, m, _ = small_corpus(n_docs=200, culled=150, seed=6)
    tree = kt.build(m, order=12, medoid=True, batch_size=64)
    assign = kt.assign_via_tree(tree, m, chunk=64)
    assert assign.shape == (spec.n_docs,) and (assign >= 0).all()
    # routing the corpus must land every doc in the leaf that holds it or a
    # nearby one; at minimum the API contract (shapes, non-negative dists)
    doc, dist = kt.nn_search(tree, m)
    assert doc.shape == (spec.n_docs,)
    assert (dist >= -1e-5).all()
    # sparse and dense query paths agree on the routed leaf
    x = jnp.asarray(np.asarray(csr_to_dense(m)))
    assign_d = kt.assign_via_tree(tree, x, chunk=64)
    assert (assign == assign_d).mean() > 0.99


def test_route_level_bucketing_deep_tree():
    """Order-3 tree is many levels deep — bucketed route must still reach
    the true leaf level and keep the tree legal."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1, (300, 6)).astype(np.float32))
    tree = kt.build(x, order=3, batch_size=32)
    kt.check_invariants(tree, n_docs=300)
    assert int(tree.depth) >= 5  # exercises >1 compile bucket
    leaf_ids, pn, ps = kt.route(tree, x[:10], int(tree.depth) - 1)
    assert pn.shape[0] == int(tree.depth) - 1
    assert np.asarray(tree.is_leaf)[np.asarray(leaf_ids)].all()
