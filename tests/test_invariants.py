"""Property-based structural-invariant hardening (hypothesis): build +
incremental insert under random orders / batch sizes / insertion splits, for
both vector backends — including the out-of-core store paths (streaming
build, insert-into-store interleaved with queries). ``check_invariants``
asserts the full battery — entry-count bounds, height balance,
parent/child/slot agreement, subtree weight & mean consistency,
allocated-node reachability, cleared stale slots, and exactly-once doc
conservation."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from fixtures import assert_trees_equal, random_corpus
from repro.core import ktree as kt
from repro.sparse.csr import csr_from_dense, csr_slice_rows


def _random_docs(rng, n, d, sparse):
    # shared factory (tests/fixtures.py); the rng consumption — and hence
    # every example this suite has ever minimised — is unchanged
    return random_corpus(rng, n=n, d=d, sparse=sparse)


@settings(max_examples=8, deadline=None)
@given(
    st.integers(30, 140),    # corpus size
    st.integers(3, 12),      # order m
    st.sampled_from([1, 7, 16, 64]),   # build batch size
    st.booleans(),           # sparse backend?
    st.integers(0, 9999),
)
def test_property_build_invariants(n, order, batch_size, sparse, seed):
    rng = np.random.default_rng(seed)
    x = _random_docs(rng, n, 10, sparse)
    data = csr_from_dense(x) if sparse else jnp.asarray(x)
    tree = kt.build(
        data, order=order, batch_size=batch_size, medoid=sparse,
        key=jax.random.PRNGKey(seed),
    )
    kt.check_invariants(tree, n_docs=n)


@settings(max_examples=8, deadline=None)
@given(
    st.integers(40, 120),    # total corpus
    st.integers(4, 10),      # order
    st.integers(1, 3),       # number of incremental insert waves
    st.booleans(),           # sparse backend?
    st.integers(0, 9999),
)
def test_property_insert_invariants(n, order, waves, sparse, seed):
    """Random split into build-prefix + insert waves of random sizes: the
    invariants must hold after every wave, and doc conservation over the
    union at the end."""
    rng = np.random.default_rng(seed)
    x = _random_docs(rng, n, 8, sparse)
    cuts = np.sort(rng.choice(np.arange(8, n - 1), size=waves, replace=False))
    bounds = [0, *cuts.tolist(), n]
    data = csr_from_dense(x) if sparse else jnp.asarray(x)

    def rows(lo, hi):
        if sparse:
            return csr_slice_rows(data, lo, hi)
        return data[lo:hi]

    tree = kt.build(
        rows(0, bounds[1]), order=order, batch_size=16, medoid=sparse,
        key=jax.random.PRNGKey(seed),
        max_nodes=kt.suggested_max_nodes(n, order),
    )
    kt.check_invariants(tree, n_docs=bounds[1])
    for lo, hi in zip(bounds[1:], bounds[2:]):
        tree = kt.insert(tree, rows(lo, hi), np.arange(lo, hi),
                         key=jax.random.PRNGKey(seed + hi))
        kt.check_invariants(tree, n_docs=hi)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(16, 220),    # corpus size
    st.integers(3, 12),      # order m
    st.booleans(),           # sparse backend?
    st.integers(0, 9999),
)
def test_property_suggested_capacity_never_overflows(n, order, sparse, seed):
    """Building at exactly suggested_max_nodes capacity (CAPACITY_HEADROOM
    over the worst-case leaf count) never exhausts the node pool — overflow
    would silently drop scatters and break the invariants."""
    rng = np.random.default_rng(seed)
    x = _random_docs(rng, n, 7, sparse)
    cap = kt.suggested_max_nodes(n, order)
    data = csr_from_dense(x) if sparse else jnp.asarray(x)
    tree = kt.build(data, order=order, batch_size=32, medoid=sparse,
                    max_nodes=cap, key=jax.random.PRNGKey(seed))
    assert int(tree.n_nodes) <= cap
    kt.check_invariants(tree, n_docs=n)


@settings(max_examples=6, deadline=None)
@given(
    st.integers(30, 120),    # corpus size
    st.integers(3, 10),      # order m
    st.sampled_from([16, 33, 64]),  # store block_docs (incl. non-pow2)
    st.booleans(),           # sparse backend?
    st.integers(0, 9999),
)
def test_property_streaming_build_invariants(n, order, block_docs, sparse,
                                             seed):
    """Out-of-core streaming build (DESIGN.md §9) under random corpus sizes,
    orders, and block granularities: the full invariant battery must hold, and
    the tree must be bit-identical to the in-memory build with the same key
    (the §9 equivalence contract)."""
    import os
    import tempfile

    from repro.core.store import open_store, save_store

    rng = np.random.default_rng(seed)
    x = _random_docs(rng, n, 9, sparse)
    data = csr_from_dense(x) if sparse else jnp.asarray(x)
    path = os.path.join(tempfile.mkdtemp(prefix="ktree-store-prop"), "corpus")
    save_store(path, data, block_docs=block_docs)
    # a one-block budget forces eviction traffic on every multi-block corpus
    store = open_store(path, budget_bytes=1)
    tree = kt.build_from_store(
        store, order=order, batch_size=32, medoid=sparse,
        key=jax.random.PRNGKey(seed),
    )
    kt.check_invariants(tree, n_docs=n)
    ref = kt.build(data, order=order, batch_size=32, medoid=sparse,
                   key=jax.random.PRNGKey(seed))
    assert_trees_equal(ref, tree)


@settings(max_examples=6, deadline=None)
@given(
    st.integers(50, 110),    # initial corpus
    st.integers(4, 9),       # order m
    st.integers(1, 3),       # number of insert-into-store waves
    st.booleans(),           # sparse backend?
    st.integers(0, 9999),
)
def test_property_insert_into_store_interleaved_with_queries(
        n, order, waves, sparse, seed):
    """Random interleavings of insert-into-store and store-backed top-k
    (DESIGN.md §9): after every wave the invariants must hold, the tree must
    bit-match an in-memory shadow tree fed the identical normalised rows, and
    store-backed answers must bit-match the materialised-corpus answers over
    the grown store — for both block layouts."""
    import os
    import tempfile

    from repro.core.backend import backend_for_store_layout, backend_from_store
    from repro.core.query import topk_search
    from repro.core.store import open_store, save_store

    rng = np.random.default_rng(seed)
    x0 = _random_docs(rng, n, 7, sparse)
    data = csr_from_dense(x0) if sparse else jnp.asarray(x0)
    path = os.path.join(tempfile.mkdtemp(prefix="ktree-grow-prop"), "corpus")
    save_store(path, data, block_docs=32)
    store = open_store(path, budget_bytes=1)
    tree = kt.build_from_store(store, order=order, batch_size=32,
                               medoid=sparse, key=jax.random.PRNGKey(seed),
                               max_nodes=kt.suggested_max_nodes(n * 3, order))
    shadow = tree
    total = n
    for w in range(waves):
        b = int(rng.integers(5, 40))
        xw = _random_docs(rng, b, 7, sparse)
        new = csr_from_dense(xw) if sparse else jnp.asarray(xw)
        # normalise once (the exact rows both trees must see)
        be = backend_for_store_layout(store, new)
        key = jax.random.PRNGKey(seed + 100 + w)
        tree = kt.insert_into_store(tree, store, new, key=key)
        shadow = kt.insert(shadow, be, np.arange(total, total + b), key=key)
        total += b
        kt.check_invariants(tree, n_docs=total)
        assert_trees_equal(tree, shadow)
        assert store.n_docs == total
        # store-backed query over the grown corpus == the same rows served
        # from an in-memory backend of the identical layout
        nq = min(16, total)
        d_st, s_st = topk_search(tree, store.view(0, nq), k=3, beam=2)
        d_mem, s_mem = topk_search(
            shadow, backend_from_store(store, np.arange(nq)), k=3, beam=2)
        np.testing.assert_array_equal(d_st, d_mem)
        np.testing.assert_array_equal(s_st, s_mem)
    # the on-disk result is durable: a fresh handle verifies + agrees
    re = open_store(path, verify=True)
    assert re.n_docs == total and re.manifest_hash == store.manifest_hash


@settings(max_examples=6, deadline=None)
@given(st.integers(3, 8), st.integers(0, 9999))
def test_property_insertion_order_independence_of_legality(order, seed):
    """Any permutation of the same corpus builds a legal tree holding the
    same document set (the tree itself is order-dependent; legality is not)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (60, 6)).astype(np.float32)
    perm = rng.permutation(60)
    tree = kt.build(jnp.asarray(x[perm]), order=order, batch_size=16,
                    key=jax.random.PRNGKey(seed))
    kt.check_invariants(tree, n_docs=60)
    assign, nc = kt.extract_assignment(tree, 60)
    assert (assign >= 0).all() and nc >= 1


@settings(max_examples=5, deadline=None)
@given(
    st.integers(50, 110),    # initial corpus
    st.integers(4, 9),       # order m
    st.integers(1, 3),       # number of insert-into-store waves
    st.booleans(),           # sparse (ELL) base?
    st.sampled_from([3, 4, 6]),   # projection out_dim
    st.integers(0, 9999),
)
def test_property_rp_store_interleavings_bitmatch_projected_shadow(
        n, order, waves, sparse, rp_dim, seed):
    """Random build/insert-into-store/query interleavings under a random
    projection (DESIGN.md §5.1): after every wave the invariants must hold
    over the RP tree, the tree must bit-match a shadow dense tree fed the
    *projected rows themselves* (the RP tree is exactly the dense K-tree in
    projected space — for streaming builds and inserts alike), and the
    store-rescored answers must bit-match rescoring from an in-memory
    materialisation of the identical store layout."""
    import os
    import tempfile

    from repro.core.backend import (
        backend_for_store_layout, backend_from_store, make_projection,
        project_corpus,
    )
    from repro.core.query import topk_search
    from repro.core.store import open_store, save_store

    rng = np.random.default_rng(seed)
    d = 7
    x0 = _random_docs(rng, n, d, sparse)
    data = csr_from_dense(x0) if sparse else jnp.asarray(x0)
    path = os.path.join(tempfile.mkdtemp(prefix="ktree-rp-prop"), "corpus")
    save_store(path, data, block_docs=32)
    store = open_store(path, budget_bytes=1)
    proj = make_projection(d, rp_dim, seed=seed % 100)
    tree = kt.build_from_store(store, order=order, batch_size=32,
                               key=jax.random.PRNGKey(seed),
                               max_nodes=kt.suggested_max_nodes(n * 3, order),
                               projection=proj)
    shadow = kt.build(jnp.asarray(project_corpus(proj, store)),
                      order=order, batch_size=32, key=jax.random.PRNGKey(seed),
                      max_nodes=kt.suggested_max_nodes(n * 3, order))
    assert tree.dim == rp_dim
    assert_trees_equal(tree, shadow)
    total = n
    for w in range(waves):
        b = int(rng.integers(5, 40))
        xw = _random_docs(rng, b, d, sparse)
        new = csr_from_dense(xw) if sparse else jnp.asarray(xw)
        # normalise once into the store layout, then project — the exact
        # projected rows both trees must see
        be = backend_for_store_layout(store, new)
        zw = jnp.asarray(project_corpus(proj, be))
        key = jax.random.PRNGKey(seed + 100 + w)
        tree = kt.insert_into_store(tree, store, new, key=key, projection=proj)
        shadow = kt.insert(shadow, zw, np.arange(total, total + b), key=key)
        total += b
        kt.check_invariants(tree, n_docs=total)
        assert_trees_equal(tree, shadow)
        assert store.n_docs == total
        # RP query rescored through the store == the same queries rescored
        # from an in-memory backend of the identical grown layout
        nq = min(16, total)
        d_st, s_st = topk_search(tree, store.view(0, nq), k=3, beam=2,
                                 rp=proj, rp_corpus=store)
        mem = backend_from_store(store, np.arange(total))
        d_mem, s_mem = topk_search(
            shadow, backend_from_store(store, np.arange(nq)), k=3, beam=2,
            rp=proj, rp_corpus=mem)
        np.testing.assert_array_equal(d_st, d_mem)
        np.testing.assert_array_equal(s_st, s_mem)
