import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.metrics import micro_purity, micro_entropy, nmi, contingency


def test_perfect_clustering():
    labels = jnp.asarray([0, 0, 1, 1, 2, 2])
    assign = jnp.asarray([2, 2, 0, 0, 1, 1])  # permuted but pure
    assert float(micro_purity(assign, labels, 3, 3)) == 1.0
    assert float(micro_entropy(assign, labels, 3, 3)) == 0.0
    assert float(nmi(assign, labels, 3, 3)) > 0.99


def test_single_cluster_worst_entropy():
    labels = jnp.asarray([0, 1] * 8)
    assign = jnp.zeros(16, jnp.int32)
    # uniform 2-label mix in one cluster: entropy (normalised) = 1
    assert abs(float(micro_entropy(assign, labels, 1, 2)) - 1.0) < 1e-5
    assert abs(float(micro_purity(assign, labels, 1, 2)) - 0.5) < 1e-5


def test_contingency_counts():
    labels = jnp.asarray([0, 1, 1, 0])
    assign = jnp.asarray([0, 0, 1, 1])
    n = np.asarray(contingency(assign, labels, 2, 2))
    assert n.tolist() == [[1, 1], [1, 1]]


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(2, 6), st.integers(10, 60), st.integers(0, 10_000))
def test_metric_bounds(n_clusters, n_labels, n, seed):
    rng = np.random.default_rng(seed)
    assign = jnp.asarray(rng.integers(0, n_clusters, n))
    labels = jnp.asarray(rng.integers(0, n_labels, n))
    p = float(micro_purity(assign, labels, n_clusters, n_labels))
    h = float(micro_entropy(assign, labels, n_clusters, n_labels))
    m = float(nmi(assign, labels, n_clusters, n_labels))
    assert 0.0 <= p <= 1.0 and 0.0 <= h <= 1.0 + 1e-6 and -1e-6 <= m <= 1.0 + 1e-6
    # purity at least the share of the globally most common label
    top = max(np.bincount(np.asarray(labels), minlength=n_labels)) / n
    assert p >= top - 1e-6
