"""Distributed-layer tests: run in a subprocess with 8 fake CPU devices so the
main pytest process keeps its single-device jax config."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, {src!r})
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.distributed import (
        distributed_kmeans, distributed_assign_sharded_centers, distributed_lloyd_step,
    )
    from repro.core.kmeans import kmeans, assign

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    K, per, d = 8, 128, 32
    means = rng.normal(0, 5, (K, d))
    x = jnp.asarray(np.concatenate(
        [rng.normal(means[i], 1.0, (per, d)) for i in range(K)]).astype(np.float32))

    out = {{}}

    # 1. one distributed lloyd step == one single-device lloyd step
    from repro.core.kmeans import lloyd_step
    c0 = x[::128][:8]
    step = distributed_lloyd_step(mesh)
    xs = jax.device_put(x, NamedSharding(mesh, P(("data",), None)))
    cs = jax.device_put(c0, NamedSharding(mesh, P(None, None)))
    c_dist, idx_dist, sse_dist = step(xs, cs)
    c_ref, idx_ref, counts_ref, sse_ref = lloyd_step(x, c0)
    out["lloyd_center_err"] = float(jnp.abs(c_dist - c_ref).max())
    out["lloyd_idx_match"] = bool((np.asarray(idx_dist) == np.asarray(idx_ref)).all())
    out["lloyd_sse_err"] = abs(float(sse_dist) - float(sse_ref)) / float(sse_ref)

    # 2. full distributed kmeans converges to good sse (fixed 40 iters vs the
    # single-device run-to-convergence reference: same ballpark, not equality)
    # n_init=1 pins the reference to a single to-convergence run — the
    # quantity this ratio was calibrated against (the multi-restart default
    # would compare a one-shot pipeline to a best-of-N reference)
    centers, idx, sse = distributed_kmeans(mesh, x, 8, iters=40)
    res = kmeans(jax.random.PRNGKey(0), x, 8, n_init=1)
    out["dist_sse_ratio"] = float(sse) / float(res.sse)

    # 3. sharded-centers assignment exact
    cglob = jnp.asarray(rng.normal(0, 1, (64, d)).astype(np.float32))
    fn = distributed_assign_sharded_centers(mesh, 64)
    cs2 = jax.device_put(cglob, NamedSharding(mesh, P("model", None)))
    gidx, gdist = fn(xs, cs2)
    ridx, rdist = assign(x, cglob)
    out["sharded_idx_match"] = bool((np.asarray(gidx) == np.asarray(ridx)).all())
    out["sharded_dist_err"] = float(np.abs(np.asarray(gdist) - np.asarray(rdist)).max())

    print("RESULT:" + json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def dist_results():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(src=os.path.abspath(src))
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=420
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_distributed_lloyd_matches_single_device(dist_results):
    assert dist_results["lloyd_idx_match"]
    assert dist_results["lloyd_center_err"] < 1e-3
    assert dist_results["lloyd_sse_err"] < 1e-4


def test_distributed_kmeans_quality(dist_results):
    # fixed-iteration + sampled seeding can land on a worse local optimum than
    # the to-convergence reference; the bound guards order-of-magnitude sanity
    assert dist_results["dist_sse_ratio"] < 3.5


def test_sharded_centers_assign_exact(dist_results):
    assert dist_results["sharded_idx_match"]
    assert dist_results["sharded_dist_err"] < 1e-3
