"""Offline corpus-store verify/repair CLI (DESIGN.md §10).

Usage::

    python tools/store_fsck.py PATH             # scan-only: verify digests
    python tools/store_fsck.py PATH --repair    # excise damaged blocks
    python tools/store_fsck.py PATH --json      # machine-readable report

Exit status: 0 when the store is clean (or a repair left it clean), 1 when
damage was found and ``--repair`` was not given. The heavy lifting lives in
:mod:`repro.core.fsck` (importable for tests and ``serve.py --fsck``); this
file is the thin argv wrapper.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(_ROOT, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.core.fsck import fsck_store, repair_store  # noqa: E402


def main(argv=None) -> int:
    """Parse args, run the fsck pass, print the report; returns exit status."""
    ap = argparse.ArgumentParser(
        description="Verify (and optionally repair) an on-disk corpus store."
    )
    ap.add_argument("path", help="store directory (contains manifest.json)")
    ap.add_argument(
        "--repair", action="store_true",
        help="excise damaged blocks (tombstone manifest entries, move the "
             "files aside as <name>.damaged) and rewrite the manifest",
    )
    ap.add_argument(
        "--json", action="store_true",
        help="print the report as one JSON object instead of text lines",
    )
    args = ap.parse_args(argv)
    report = repair_store(args.path) if args.repair else fsck_store(args.path)
    if args.json:
        print(json.dumps(dataclasses.asdict(report)))
    else:
        for line in report.lines():
            print(line)
    return 0 if (report.clean or report.repaired) else 1


if __name__ == "__main__":
    raise SystemExit(main())
