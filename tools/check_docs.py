"""Doc-consistency checks (CI docs job + tests/test_docs.py).

Two checks, both importable and runnable as a script:

1. :func:`docstring_gaps` — every public function/class (and public
   method/property of a public class) in the covered modules must carry a
   docstring. Covered modules: ``repro.core.query``, ``repro.core.backend``,
   ``repro.ckpt.checkpoint`` (the public query/persistence API surface),
   ``repro.core.store`` (out-of-core PR), ``repro.core.engine`` and
   ``repro.launch.engine`` (serving-engine PR), ``repro.core.faults``
   and ``repro.core.fsck`` (fault-injection/robustness PR), plus
   ``repro.core.profile`` and ``repro.core.autotune`` (measured-overlap
   profiling/auto-tuner PR).
2. :func:`broken_links` — every relative markdown link/image in the repo's
   top-level docs must point at an existing file (http(s)/mailto links and
   pure #anchors are skipped).

Exit status 0 = clean; 1 = findings (printed one per line).
"""
from __future__ import annotations

import inspect
import os
import re
import sys
from typing import List

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(_ROOT, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_ROOT, "src"))

COVERED_MODULES = (
    "repro.core.query",
    "repro.core.backend",
    "repro.core.store",
    "repro.core.engine",
    "repro.core.faults",
    "repro.core.fsck",
    "repro.core.profile",
    "repro.core.autotune",
    "repro.launch.engine",
    "repro.ckpt.checkpoint",
    "repro.data.pipeline",
)

DOC_FILES = ("README.md", "DESIGN.md", "ROADMAP.md")

_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def _has_doc(obj) -> bool:
    doc = inspect.getdoc(obj)
    return bool(doc and doc.strip())


def docstring_gaps(module_names=COVERED_MODULES) -> List[str]:
    """Public names missing docstrings, as ``module.qualname`` strings.

    Public = module-level functions/classes defined in the module itself
    (not re-exports) whose name has no leading underscore, plus the public
    methods and properties those classes define."""
    import importlib

    gaps = []
    for mod_name in module_names:
        mod = importlib.import_module(mod_name)
        for name, obj in vars(mod).items():
            if name.startswith("_"):
                continue
            if not (inspect.isfunction(obj) or inspect.isclass(obj)):
                continue
            if getattr(obj, "__module__", None) != mod_name:
                continue  # re-export; owned (and checked) elsewhere
            if not _has_doc(obj):
                gaps.append(f"{mod_name}.{name}")
            if inspect.isclass(obj):
                for mname, member in vars(obj).items():
                    if mname.startswith("_"):
                        continue
                    target = None
                    if inspect.isfunction(member):
                        target = member
                    elif isinstance(member, (classmethod, staticmethod)):
                        target = member.__func__
                    elif isinstance(member, property):
                        target = member.fget
                    if target is not None and not _has_doc(target):
                        gaps.append(f"{mod_name}.{name}.{mname}")
    return gaps


def broken_links(doc_files=DOC_FILES, root=_ROOT) -> List[str]:
    """Relative markdown links whose target file does not exist, as
    ``file: target`` strings."""
    bad = []
    for fname in doc_files:
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            bad.append(f"{fname}: (file itself is missing)")
            continue
        with open(path) as f:
            text = f.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if rel and not os.path.exists(os.path.join(root, rel)):
                bad.append(f"{fname}: {target}")
    return bad


def main() -> int:
    """Run both checks; print findings; return a shell exit status."""
    findings = [f"undocumented: {g}" for g in docstring_gaps()]
    findings += [f"broken link: {b}" for b in broken_links()]
    for f in findings:
        print(f)
    if not findings:
        n_mods = len(COVERED_MODULES)
        print(f"docs OK: {n_mods} modules fully docstringed, "
              f"{len(DOC_FILES)} doc files link-clean")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
