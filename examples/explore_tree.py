"""Paper §5: interactive collection exploration — "a user can start browsing
from any point in the tree and generalise or specialise what they are viewing
by traversing up or down the tree".

Builds a K-tree over an INEX-like corpus and walks root→leaf along the most
populated branch, printing per-level cluster summaries (size, label histogram,
top terms of the centre) — the ranked-list view the paper describes.

Run:  PYTHONPATH=src python examples/explore_tree.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import ktree as kt
from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
from repro.sparse.csr import csr_to_dense

spec = scaled(INEX_LIKE, n_docs=1500, culled=600)
matrix, labels = prepared_corpus(spec, seed=0)
x = jnp.asarray(np.asarray(csr_to_dense(matrix)))
tree = kt.build(x, order=10, batch_size=256)

child = np.asarray(tree.child)
counts = np.asarray(tree.counts)
centers = np.asarray(tree.centers)
ne = np.asarray(tree.n_entries)
is_leaf = np.asarray(tree.is_leaf)


def subtree_docs(node):
    if is_leaf[node]:
        return list(child[node, : ne[node]])
    out = []
    for s in range(ne[node]):
        out += subtree_docs(int(child[node, s]))
    return out


node = int(tree.root)
level = 0
while True:
    print(f"\n=== level {level} — node {node} ({'leaf' if is_leaf[node] else 'internal'}, "
          f"{ne[node]} entries) ===")
    weights = counts[node, : ne[node]]
    for s in range(ne[node]):
        docs = [int(child[node, s])] if is_leaf[node] else subtree_docs(int(child[node, s]))
        hist = np.bincount(labels[docs], minlength=spec.n_labels)
        top_lab = hist.argmax()
        top_terms = np.argsort(-centers[node, s])[:5]
        print(f"  entry {s}: {len(docs):4d} docs | dominant label {top_lab} "
              f"({hist[top_lab]/max(len(docs),1):.0%}) | top terms {top_terms.tolist()}")
    if is_leaf[node]:
        break
    # specialise: descend into the largest entry (the paper's "specialise")
    node = int(child[node, int(np.argmax(weights))])
    level += 1
print("\n(ascending back up = 'generalise'; each entry above is a browsable cluster)")
