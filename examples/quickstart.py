"""Quickstart: the paper's pipeline in ~40 lines.

Generate an INEX-like labelled corpus, preprocess exactly as the paper
(TF-IDF → top-term culling → unit rows), build a K-tree, read out the
leaf-level clustering, and score it with micro purity / entropy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import ktree as kt
from repro.core.metrics import micro_purity, micro_entropy
from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
from repro.sparse.csr import csr_to_dense

# 1. corpus (scaled-down INEX 2008: 15 labels, culled vocabulary)
spec = scaled(INEX_LIKE, n_docs=2000, culled=800)
matrix, labels = prepared_corpus(spec, seed=0)
x = jnp.asarray(np.asarray(csr_to_dense(matrix)))
print(f"corpus: {matrix.n_rows} docs x {matrix.n_cols} terms, "
      f"{matrix.nnz} nnz, {spec.n_labels} labels")

# 2. K-tree (order m controls the leaf-level cluster count)
tree = kt.build(x, order=24, batch_size=256)
kt.check_invariants(tree, n_docs=x.shape[0])
print(f"K-tree: depth={int(tree.depth)}, nodes={int(tree.n_nodes)}")

# 3. leaf-level clustering solution
assign, n_clusters = kt.extract_assignment(tree, x.shape[0])
p = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), n_clusters, spec.n_labels))
h = float(micro_entropy(jnp.asarray(assign), jnp.asarray(labels), n_clusters, spec.n_labels))
print(f"clusters={n_clusters}  micro-purity={p:.3f}  micro-entropy={h:.3f}")

# 4. the tree is also a nearest-neighbour search structure (unlike BIRCH)
doc_ids, dists = kt.nn_search(tree, x[:5])
print("NN of docs 0..4:", doc_ids, "(self-recall expected high)")
