"""K-tree as an ANN index for recsys candidate retrieval (the paper's
nearest-neighbour-search-tree application meeting the ``retrieval_cand``
serving shape).

Scores queries against item embeddings (a) brute force and (b) via the K-tree,
reporting recall@10 and the search-cost ratio (distances computed).

Run:  PYTHONPATH=src python examples/retrieval_ann.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ktree as kt

N_ITEMS, DIM, N_QUERIES = 50_000, 64, 32
ORDER = 64

rng = np.random.default_rng(0)
# clustered item space (realistic embedding geometry)
centers = rng.normal(0, 1, (100, DIM))
items = centers[rng.integers(0, 100, N_ITEMS)] + 0.3 * rng.normal(0, 1, (N_ITEMS, DIM))
items /= np.linalg.norm(items, axis=1, keepdims=True)
queries = items[rng.choice(N_ITEMS, N_QUERIES, replace=False)] + 0.05 * rng.normal(0, 1, (N_QUERIES, DIM))
queries /= np.linalg.norm(queries, axis=1, keepdims=True)
xi, xq = jnp.asarray(items.astype(np.float32)), jnp.asarray(queries.astype(np.float32))

# brute force ground truth
t0 = time.time()
scores = xq @ xi.T
true_top = np.asarray(jax.lax.top_k(scores, 10)[1])
t_brute = time.time() - t0

# K-tree index
t0 = time.time()
tree = kt.build(xi, order=ORDER, batch_size=1024)
t_build = time.time() - t0

t0 = time.time()
doc, dist = kt.nn_search(tree, xq)
t_query = time.time() - t0

recall1 = float(np.mean([doc[i] in true_top[i, :10] for i in range(N_QUERIES)]))
# search cost: brute = N_ITEMS distances/query; tree = m * depth + leaf size
depth = int(tree.depth)
tree_cost = ORDER * depth
print(f"items={N_ITEMS} order={ORDER} depth={depth}")
print(f"brute: {t_brute*1e3:.0f}ms; tree build {t_build:.1f}s, query {t_query*1e3:.0f}ms")
print(f"ANN recall@10 (top-1 hit) = {recall1:.2f}")
print(f"distances/query: brute={N_ITEMS}, ktree≈{tree_cost} "
      f"({N_ITEMS/tree_cost:.0f}x fewer)")
