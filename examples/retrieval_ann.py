"""K-tree as an ANN index for recsys candidate retrieval (the paper's
nearest-neighbour-search-tree application meeting the ``retrieval_cand``
serving shape).

Scores queries against item embeddings (a) brute force and (b) through the
top-k beam-search query engine (DESIGN.md §7), sweeping the beam width —
the serving-side recall/latency dial — and reporting recall@10 plus the
search-cost ratio (distances computed).

Run:  PYTHONPATH=src python examples/retrieval_ann.py
(size via env: RETRIEVAL_N_ITEMS / RETRIEVAL_N_QUERIES, for CI smoke)
"""
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ktree as kt
from repro.core.query import recall_at_k, topk_search

N_ITEMS = int(os.environ.get("RETRIEVAL_N_ITEMS", 50_000))
N_QUERIES = int(os.environ.get("RETRIEVAL_N_QUERIES", 32))
DIM = 64
ORDER = 64

rng = np.random.default_rng(0)
# clustered item space (realistic embedding geometry)
centers = rng.normal(0, 1, (100, DIM))
items = centers[rng.integers(0, 100, N_ITEMS)] + 0.3 * rng.normal(0, 1, (N_ITEMS, DIM))
items /= np.linalg.norm(items, axis=1, keepdims=True)
queries = items[rng.choice(N_ITEMS, N_QUERIES, replace=False)] + 0.05 * rng.normal(0, 1, (N_QUERIES, DIM))
queries /= np.linalg.norm(queries, axis=1, keepdims=True)
xi, xq = jnp.asarray(items.astype(np.float32)), jnp.asarray(queries.astype(np.float32))

# brute force ground truth
t0 = time.time()
scores = xq @ xi.T
true_top = np.asarray(jax.lax.top_k(scores, 10)[1])
t_brute = time.time() - t0

# K-tree index
t0 = time.time()
tree = kt.build(xi, order=ORDER, batch_size=1024)
t_build = time.time() - t0
depth = int(tree.depth)
print(f"items={N_ITEMS} order={ORDER} depth={depth}")
print(f"brute: {t_brute*1e3:.0f}ms over {N_ITEMS} candidates; build {t_build:.1f}s")

# beam sweep: recall@10 vs search cost (distances/query ≈ beam · m · depth)
for beam in (1, 2, 4):
    t0 = time.time()
    docs, _ = topk_search(tree, xq, k=10, beam=beam)
    t_query = time.time() - t0
    recall10 = recall_at_k(docs, true_top)
    tree_cost = beam * ORDER * depth
    print(f"beam={beam}: recall@10={recall10:.2f} query {t_query*1e3:.0f}ms "
          f"distances/query≈{tree_cost} ({N_ITEMS/tree_cost:.0f}x fewer than brute)")
