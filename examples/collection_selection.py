"""Paper §5: collection selection — "many clusters are useful ... when deciding
how to spread a collection across many machines".

Build a K-tree with a small order (many leaf clusters), then greedily pack the
leaf clusters onto machines balancing document counts, keeping semantically
related documents co-located. Reports balance + intra-machine coherence vs a
random split.

Run:  PYTHONPATH=src python examples/collection_selection.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import ktree as kt
from repro.data.synth_corpus import RCV1_LIKE, scaled, prepared_corpus
from repro.sparse.csr import csr_to_dense

N_MACHINES = 8

spec = scaled(RCV1_LIKE, n_docs=3000, culled=800)
matrix, labels = prepared_corpus(spec, seed=0)
x = jnp.asarray(np.asarray(csr_to_dense(matrix)))

tree = kt.build(x, order=16, batch_size=256)
assign, n_clusters = kt.extract_assignment(tree, x.shape[0])
sizes = np.bincount(assign, minlength=n_clusters)
print(f"{n_clusters} clusters from K-tree (order 16), sizes: "
      f"min={sizes.min()} mean={sizes.mean():.1f} max={sizes.max()}")

# greedy bin packing: largest cluster -> least-loaded machine
machine_of = np.zeros(n_clusters, np.int32)
load = np.zeros(N_MACHINES, np.int64)
for c in np.argsort(-sizes):
    m = int(np.argmin(load))
    machine_of[c] = m
    load[m] += sizes[c]
doc_machine = machine_of[assign]
print("machine loads:", load.tolist(), f"(imbalance {load.max()/load.mean():.2f}x)")


def coherence(split):
    """mean pairwise cosine within machines (docs are unit rows)."""
    tot, cnt = 0.0, 0
    xs = np.asarray(x)
    for m in range(N_MACHINES):
        docs = xs[split == m]
        if len(docs) < 2:
            continue
        sub = docs[np.random.default_rng(m).choice(len(docs), min(200, len(docs)), replace=False)]
        sims = sub @ sub.T
        tot += (sims.sum() - np.trace(sims)) / (len(sub) ** 2 - len(sub))
        cnt += 1
    return tot / cnt


rand_split = np.random.default_rng(0).integers(0, N_MACHINES, x.shape[0])
print(f"intra-machine coherence: ktree={coherence(doc_machine):.4f} "
      f"random={coherence(rand_split):.4f}")
