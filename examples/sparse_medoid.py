"""Sparse medoid K-tree: the paper's §2 workflow end-to-end.

"K-tree has been extended to address issues with sparse representations" —
documents never densify wholesale: the TF-IDF'd corpus stays in ELL(+CSR)
layout inside an :class:`~repro.core.backend.EllSparseBackend`, routing
scores go through the ``ell_spmm`` path, node centres are document
*exemplars* (medoids), and only one routed wave is densified at a time.

Run:  PYTHONPATH=src python examples/sparse_medoid.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import ktree as kt
from repro.core.metrics import micro_purity, micro_entropy
from repro.data.pipeline import corpus_backend
from repro.data.synth_corpus import INEX_LIKE, scaled

# 1. corpus → sparse backend (TF-IDF → cull → unit rows → ELL layout)
spec = scaled(INEX_LIKE, n_docs=2000, culled=800)
backend, labels = corpus_backend(spec, representation="sparse_medoid", seed=0)
dense_mb = backend.n_docs * backend.dim * 4 / 1e6
sparse_mb = (backend.values.size + backend.cols.size) * 4 / 1e6
print(f"corpus: {backend.n_docs} docs x {backend.dim} terms in ELL "
      f"(nnz_max={backend.nnz_max}); {sparse_mb:.1f}MB sparse vs "
      f"{dense_mb:.1f}MB dense")

# 2. medoid K-tree over the sparse corpus — ``backend`` drops straight into
#    build(); centres are exemplar documents, never updated on insert
tree = kt.build(backend, order=24, medoid=True, batch_size=256)
kt.check_invariants(tree, n_docs=backend.n_docs)
print(f"medoid K-tree: depth={int(tree.depth)}, nodes={int(tree.n_nodes)}")

# 3. leaf-level clustering solution, scored against the planted labels
assign, n_clusters = kt.extract_assignment(tree, backend.n_docs)
p = float(micro_purity(jnp.asarray(assign), jnp.asarray(labels), n_clusters, spec.n_labels))
h = float(micro_entropy(jnp.asarray(assign), jnp.asarray(labels), n_clusters, spec.n_labels))
print(f"clusters={n_clusters}  micro-purity={p:.3f}  micro-entropy={h:.3f}")

# 4. sparse queries route through the same tree (approximate NN search)
doc_ids, dists = kt.nn_search(tree, backend)
self_hit = float((doc_ids == np.arange(backend.n_docs)).mean())
print(f"NN self-recall over the corpus: {self_hit:.2f}")

# 5. incremental arrival (paper §5): new documents insert without a rebuild
from repro.sparse.csr import csr_from_dense
rng = np.random.default_rng(1)
new_docs = rng.random((32, backend.dim)).astype(np.float32)
new_docs *= rng.random((32, backend.dim)) < 0.02             # keep them sparse
tree = kt.insert(tree, csr_from_dense(new_docs), np.arange(backend.n_docs, backend.n_docs + 32))
kt.check_invariants(tree, n_docs=backend.n_docs + 32)
print(f"after insert: depth={int(tree.depth)}, nodes={int(tree.n_nodes)} — invariants hold")
