"""Continuous-batching serving bench (DESIGN.md §8): open-loop arrival-rate
sweep through ``core/engine.py``.

Measures the engine as a *service*, not a batch job: requests arrive
open-loop (Poisson gaps, seeded) at a sweep of rates anchored to the
measured offline capacity — below it, at it, and past it — and the report is
the latency *distribution* (p50/p95/p99), completed QPS, shed count, batch
occupancy, and queue depth per rate. Past saturation the bounded admission
queue must shed rather than let latency grow without bound; the sweep shows
exactly that knee. One served request per rate is asserted bit-identical to
the offline engine.

All timing is monotonic (``time.perf_counter`` via the engine's
``LatencyRecorder``). Results land in ``BENCH_serving.json`` (``--json``) so
CI archives the latency trajectory per commit.

Run:  PYTHONPATH=src python benchmarks/serving.py [--smoke] \
          [--json BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax
import jax.numpy as jnp


def main(
    n_docs: int = 4000,
    culled: int = 800,
    order: int = 16,
    k: int = 10,
    beam: int = 4,
    rows_per_request: int = 1,
    n_requests: int = 512,
    rate_fractions=(0.5, 1.0, 2.0),
    row_budget: int = 64,
    max_queue: int = 256,
    max_wait_ms: float = 2.0,
    cache_capacity: int = 0,
    seed: int = 0,
    json_path: str | None = None,
):
    """Run the sweep; returns ``(name, us_per_call, derived)`` CSV rows."""
    from repro.core import ktree as kt
    from repro.core.engine import ServingEngine, make_search_fn, pow2_bucket
    from repro.core.query import AnswerCache
    from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
    from repro.launch.engine import request_pool, run_load
    from repro.sparse.csr import csr_to_dense

    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, _ = prepared_corpus(spec, seed=seed)
    x_all = np.asarray(csr_to_dense(m))
    tree = kt.build(jnp.asarray(x_all), order=order, batch_size=256,
                    key=jax.random.PRNGKey(seed))
    nq = min(1024, n_docs)
    x_q = x_all[:nq]
    search_fn = make_search_fn(tree)

    # warm the chunk-aligned shapes dynamic batches hit (request bucket ×
    # pow2 chunk counts — the engine's compile ladder)
    bucket = pow2_bucket(rows_per_request)
    cap = pow2_bucket(row_budget)

    def _warm(s, chunk_rows):
        reps = -(-s // nq)
        search_fn(np.tile(x_q, (reps, 1))[:s], k, beam, chunk_rows=chunk_rows)

    s = bucket
    while True:
        _warm(s, bucket)
        if s >= 2 * cap:
            break
        s *= 2
    if cache_capacity:
        miss_rows = 1
        while miss_rows <= cap:
            _warm(miss_rows, 1)
            miss_rows *= 2
    # capacity anchor: flood a fresh engine (open loop at an absurd rate) and
    # take its achieved completion rate — this includes dispatch, demux, and
    # Python-threading overhead, so the 0.5x leg of the sweep really is
    # underloaded and the knee past 1x is visible (timing the offline engine
    # alone overstates serving capacity by the per-dispatch overhead)
    n_cal = min(128, n_requests)
    cal_pool = request_pool(x_q, n_requests=n_cal,
                            rows_per_request=rows_per_request, k=k, beam=beam,
                            seed=seed + 3)
    with ServingEngine(search_fn, row_budget=row_budget,
                       max_queue=n_cal) as eng:
        cal = run_load(eng, cal_pool, rate_qps=1e6, seed=seed + 4)
    capacity_req_s = max(cal["qps"], 1.0)
    capacity_rows_s = capacity_req_s * rows_per_request

    rows, blob = [], {
        "n_docs": n_docs, "k": k, "beam": beam,
        "rows_per_request": rows_per_request, "n_requests": n_requests,
        "row_budget": row_budget, "max_queue": max_queue,
        "max_wait_ms": max_wait_ms,
        "engine_capacity_qps": capacity_req_s, "rates": {},
    }
    rows.append(("serving_engine_capacity", 1e6 / max(capacity_req_s, 1e-9),
                 f"capacity={capacity_req_s:.0f} req/s "
                 f"({capacity_rows_s:.0f} rows/s, flood-calibrated)"))

    pool = request_pool(x_q, n_requests=n_requests,
                        rows_per_request=rows_per_request, k=k, beam=beam,
                        seed=seed + 1)
    for frac in rate_fractions:
        rate = max(frac * capacity_req_s, 1.0)
        cache = AnswerCache(cache_capacity) if cache_capacity else None
        with ServingEngine(
            search_fn, row_budget=row_budget, max_queue=max_queue,
            max_wait_s=max_wait_ms / 1e3, cache=cache, tree=tree,
        ) as eng:
            stats = run_load(eng, pool, rate_qps=rate, seed=seed + 2)
            # engine answers must be bit-identical to the offline engine
            r0, k0, b0 = pool[0]
            d_eng, s_eng = eng.submit(r0, k=k0, beam=b0).result(timeout=300)
        if cache is None:
            d_off, s_off = search_fn(r0, k0, b0)
        else:  # cache entries are per-row answers — compare per-row calls
            parts = [search_fn(r0[i:i + 1], k0, b0)
                     for i in range(r0.shape[0])]
            d_off = np.concatenate([np.asarray(p[0]) for p in parts])
            s_off = np.concatenate([np.asarray(p[1]) for p in parts])
        assert (np.asarray(d_eng) == np.asarray(d_off)).all() and (
            np.asarray(s_eng) == np.asarray(s_off)).all(), (
            f"engine answers diverged from offline at rate {rate:.0f}/s"
        )
        lat_ms = stats["latency_ms"]
        name = f"serving_rate_{frac:g}x"
        rows.append((
            name, 1e6 / max(stats["qps"], 1e-9),
            f"target={rate:.0f}/s qps={stats['qps']:.0f} "
            f"p50={lat_ms['p50']:.1f}ms p95={lat_ms['p95']:.1f}ms "
            f"p99={lat_ms['p99']:.1f}ms shed={stats['shed']} "
            f"occ={stats['batch_occupancy']:.2f} "
            f"maxq={stats['max_queue_depth']}",
        ))
        blob["rates"][f"{frac:g}x"] = {
            "target_qps": rate,
            "offered_qps": stats["offered_qps"],
            "qps": stats["qps"],
            "latency_ms": lat_ms,
            "admitted": stats["admitted"],
            "completed": stats["completed"],
            "shed": stats["shed"],
            "deadline_misses": stats["deadline_misses"],
            "n_batches": stats["n_batches"],
            "batch_occupancy": stats["batch_occupancy"],
            "max_queue_depth": stats["max_queue_depth"],
        }

    if json_path:
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        rows.append(("serving_bench_json", 0.0, f"wrote {json_path}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--rows-per-req", type=int, default=1)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--fractions", type=float, nargs="+",
                    default=[0.5, 1.0, 2.0],
                    help="arrival rates as fractions of measured capacity "
                    "(≥ 3 values keeps the latency knee visible)")
    ap.add_argument("--row-budget", type=int, default=64)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--cache", type=int, default=0,
                    help="answer-cache capacity staged before batching "
                    "(0 = off)")
    ap.add_argument("--json", default="", help="write BENCH_serving.json here")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run: tiny corpus, short request stream",
    )
    args = ap.parse_args()
    if args.smoke:
        # max_queue below the 2x-rate backlog so the overload leg of the
        # sweep actually sheds (bounded queue, never unbounded latency)
        args.docs, args.culled, args.order = 600, 250, 10
        args.requests, args.row_budget, args.max_queue = 160, 32, 48
    for name, us, extra in main(
        n_docs=args.docs, culled=args.culled, order=args.order, k=args.k,
        beam=args.beam, rows_per_request=args.rows_per_req,
        n_requests=args.requests, rate_fractions=tuple(args.fractions),
        row_budget=args.row_budget, max_queue=args.max_queue,
        max_wait_ms=args.max_wait_ms, cache_capacity=args.cache,
        json_path=args.json or None,
    ):
        print(f"{name},{us:.1f},{extra}")
