"""Benchmark entry point: ``python -m benchmarks.run`` (or
``python benchmarks/run.py``).

One section per paper table/figure + the system benches:
  paper_quality — Figures 1 & 2 (quality + runtime vs cluster count)
  sparse_dense  — §1 storage/speed observation
  scaling       — complexity claim (build time vs n)
  query_recall  — beam-search recall@k vs brute force + QPS (DESIGN.md §7)
  ri_recall     — Random Indexing routing: recall@k vs projection dim (§5.1)
  query_throughput — serving QPS/latency: chunk × pipeline × shards + cache
  serving       — continuous-batching engine: open-loop arrival-rate sweep
  oocore        — out-of-core store: build/query under a residency budget
  autotune      — measured-overlap knob tuner vs the depth-1 sync baseline
  chaos         — availability/latency under injected store + engine faults
  kernel_bench  — kernel micro-benches + oracle agreement
  roofline      — §Roofline terms from the dry-run artifacts (if present)

Output: ``name,us_per_call,derived`` CSV blocks.  Every leg also leaves a
``BENCH_<leg>.json`` artifact (stamped ``{"leg", "smoke"}``) so the perf
trajectory is populated even under ``--smoke``; ``BlockCache`` stats are
reset between legs so residency/hit-rate numbers don't bleed across sweeps.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:  # allow `python benchmarks/run.py` from anywhere
    sys.path.insert(0, _ROOT)
if os.path.join(_ROOT, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_ROOT, "src"))


def _finish_leg(leg: str, smoke: bool, rows=None, json_path=None) -> None:
    """Close out one bench leg: make sure its ``BENCH_*.json`` exists and is
    stamped with ``{"leg", "smoke"}``, then reset every live ``BlockCache``'s
    counters so the next leg's residency/hit-rate numbers start clean.

    Legs with a native JSON writer pass the path they already wrote
    (``json_path``); the blob is stamped in place.  The rest pass their CSV
    ``rows`` and get a generic ``{"leg", "smoke", "rows"}`` blob.
    """
    if json_path is not None and os.path.exists(json_path):
        with open(json_path) as f:
            blob = json.load(f)
        blob["leg"], blob["smoke"] = leg, bool(smoke)
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
    else:
        path = json_path or f"BENCH_{leg}.json"
        blob = {"leg": leg, "smoke": bool(smoke),
                "rows": [list(r) if isinstance(r, tuple) else r
                         for r in (rows or [])]}
        with open(path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
    from repro.core.store import BlockCache
    BlockCache.reset_all_stats()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=2000, help="paper-bench corpus size")
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--orders", type=int, nargs="+", default=[16, 32])
    ap.add_argument("--skip", nargs="*", default=[])
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run: tiny corpora, one order, small scaling sweep "
             "(CPU-friendly; Pallas kernels run via kernels/ref.py fallback / "
             "interpret mode)",
    )
    args = ap.parse_args()
    if args.smoke:
        args.docs, args.culled, args.orders = 400, 200, [8]

    t_all = time.perf_counter()

    if "paper" not in args.skip:
        print("== paper_quality (Figures 1 & 2) ==", flush=True)
        from benchmarks import paper_quality
        rows = paper_quality.main(args.docs, args.culled, tuple(args.orders))
        _finish_leg("paper", args.smoke, rows=rows)

    if "sparse" not in args.skip:
        print("\n== sparse_dense (paper §1) ==", flush=True)
        from benchmarks import sparse_dense
        sd_args = (400, 200) if args.smoke else ()
        rows = sparse_dense.main(*sd_args)
        for name, us, extra in rows:
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("sparse_dense", args.smoke, rows=rows)

    if "scaling" not in args.skip:
        print("\n== scaling (complexity claim) ==", flush=True)
        from benchmarks import scaling
        sizes = (300, 600) if args.smoke else (1000, 2000, 4000)
        rows = scaling.main(sizes=sizes)
        for name, us, extra in rows:
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("scaling", args.smoke, rows=rows)

    if "query" not in args.skip:
        print("\n== query_recall (beam-search engine, DESIGN.md §7) ==", flush=True)
        from benchmarks import query_recall
        qr_kwargs = (
            dict(n_docs=500, culled=250, order=10, beams=(1, 2, 4), n_queries=96)
            if args.smoke else {}
        )
        rows = query_recall.main(**qr_kwargs)
        for name, us, extra in rows:
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("query_recall", args.smoke, rows=rows)

    if "ri" not in args.skip:
        print("\n== ri_recall (Random Indexing routing, DESIGN.md §5.1) ==", flush=True)
        from benchmarks import ri_recall
        ri_kwargs = (
            dict(n_docs=400, culled=200, order=8, rp_dims=(16, 64), n_queries=96)
            if args.smoke else {}
        )
        for name, us, extra in ri_recall.main(json_path="BENCH_ri.json", **ri_kwargs):
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("ri", args.smoke, json_path="BENCH_ri.json")

    if "throughput" not in args.skip:
        print("\n== query_throughput (serving plane, DESIGN.md §8) ==", flush=True)
        from benchmarks import query_throughput
        qt_kwargs = (
            dict(n_docs=600, culled=250, order=10, chunks=(64, 128),
                 n_queries=512, repeats=3)
            if args.smoke else {}
        )
        for name, us, extra in query_throughput.main(
                json_path="BENCH_query.json", **qt_kwargs):
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("throughput", args.smoke, json_path="BENCH_query.json")

    if "serving" not in args.skip:
        print("\n== serving (continuous-batching engine, DESIGN.md §8) ==", flush=True)
        from benchmarks import serving
        sv_kwargs = (
            dict(n_docs=600, culled=250, order=10, n_requests=160,
                 row_budget=32, max_queue=48)
            if args.smoke else {}
        )
        for name, us, extra in serving.main(
                json_path="BENCH_serving.json", **sv_kwargs):
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("serving", args.smoke, json_path="BENCH_serving.json")

    if "oocore" not in args.skip:
        print("\n== oocore (out-of-core store, DESIGN.md §9) ==", flush=True)
        from benchmarks import oocore
        oo_kwargs = (
            dict(n_docs=600, culled=250, order=10, chunk=128,
                 block_sizes=(64, 256), budget_fractions=(0.05, 0.5),
                 n_queries=256, repeats=2)
            if args.smoke else {}
        )
        for name, us, extra in oocore.main(
                json_path="BENCH_oocore.json", **oo_kwargs):
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("oocore", args.smoke, json_path="BENCH_oocore.json")

    if "autotune" not in args.skip:
        print("\n== autotune (measured-overlap knob tuner, DESIGN.md §11) ==",
              flush=True)
        from benchmarks import autotune
        at_kwargs = (
            dict(n_docs=600, culled=250, order=10, block_sizes=(64, 256),
                 budget_fractions=(0.05, 0.5), pipelines=(1, 2),
                 prefetches=(0, 2), chunks=(128, 512), n_queries=256,
                 repeats=2)
            if args.smoke else {}
        )
        for name, us, extra in autotune.main(
                json_path="BENCH_autotune.json", **at_kwargs):
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("autotune", args.smoke, json_path="BENCH_autotune.json")

    if "chaos" not in args.skip:
        print("\n== chaos (fault injection, DESIGN.md §10) ==", flush=True)
        from benchmarks import chaos
        ch_kwargs = (
            dict(n_docs=600, culled=250, order=10, block_docs=64,
                 engine_requests=96)
            if args.smoke else {}
        )
        for name, us, extra in chaos.main(
                json_path="BENCH_chaos.json", **ch_kwargs):
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("chaos", args.smoke, json_path="BENCH_chaos.json")

    if "kernels" not in args.skip:
        print("\n== kernel_bench ==", flush=True)
        from benchmarks import kernel_bench
        rows = kernel_bench.main()
        for name, us, extra in rows:
            print(f"{name},{us:.1f},{extra}", flush=True)
        _finish_leg("kernels", args.smoke, rows=rows)

    if "roofline" not in args.skip and os.path.isdir("experiments/dryrun"):
        print("\n== roofline (from dry-run artifacts) ==", flush=True)
        from benchmarks import roofline
        roofline.main()

    print(f"\nTOTAL_BENCH_SECONDS,{time.perf_counter()-t_all:.1f},", flush=True)


if __name__ == "__main__":
    main()
