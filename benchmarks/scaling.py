"""K-tree's complexity claim: build time vs collection size.

The paper: "The K-tree has a low time complexity that is suitable for large
document collections" — insertion is O(m·log_m n) per vector, so the build is
~linear in n at fixed order. We sweep n and report seconds + clusters, and
compare against k-means at the K-tree's leaf count (which is O(n·k) per
iteration and blows up as k grows with n)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ktree as kt
from repro.core.kmeans import kmeans_fixed_iters


def main(sizes=(1000, 2000, 4000, 8000), d: int = 256, order: int = 16):
    rows = []
    rng = np.random.default_rng(0)
    means = rng.normal(0, 4, (20, d)).astype(np.float32)
    for n in sizes:
        lab = rng.integers(0, 20, n)
        x = jnp.asarray((means[lab] + rng.normal(0, 1, (n, d))).astype(np.float32))
        t0 = time.perf_counter()
        tree = kt.build(x, order=order, batch_size=256)
        dt = time.perf_counter() - t0
        _, nc = kt.extract_assignment(tree, n)
        rows.append((f"ktree_build_n{n}", dt * 1e6, f"clusters={nc}"))
        t0 = time.perf_counter()
        kmeans_fixed_iters(jax.random.PRNGKey(0), x, nc, iters=10)
        dtk = time.perf_counter() - t0
        rows.append((f"kmeans_match_n{n}", dtk * 1e6, f"k={nc} ratio={dtk/dt:.2f}"))
    return rows


if __name__ == "__main__":
    for name, us, extra in main():
        print(f"{name},{us:.1f},{extra}")
