"""Inject generated tables into EXPERIMENTS.md:
- <!-- ROOFLINE_TABLES --> ← benchmarks.roofline over experiments/dryrun
- <!-- PAPER_TABLE -->     ← paper_quality CSV (path via --paper-csv)
Idempotent: tables are wrapped in begin/end markers and replaced in place.
"""
from __future__ import annotations

import argparse
import io
import os
import re


def paper_markdown(csv_path: str) -> str:
    if not (csv_path and os.path.exists(csv_path)):
        return "_(run `python -m benchmarks.paper_quality` to populate)_"
    rows = [l.strip() for l in open(csv_path) if "," in l and not l.startswith("==")]
    hdr = [r for r in rows if r.startswith("corpus,")]
    data = [r for r in rows if not r.startswith("corpus,") and len(r.split(",")) == 7
            and "%" not in r and r.split(",")[0] in ("inex", "rcv1")]
    if not data:
        return "_(no rows)_"
    out = ["| corpus | algorithm | order | clusters | purity ↑ | entropy ↓ | seconds |",
           "|---|---|---|---|---|---|---|"]
    for r in data:
        out.append("| " + " | ".join(r.split(",")) + " |")
    return "\n".join(out)


def roofline_markdown() -> str:
    from benchmarks.roofline import load_all, markdown_table

    rows = load_all()
    parts = []
    for mesh in ("16x16", "2x16x16"):
        parts.append(f"\n### mesh {mesh}\n")
        parts.append(markdown_table(rows, mesh))
    return "\n".join(parts)


def inject(text: str, marker: str, payload: str) -> str:
    begin = f"<!-- {marker} -->"
    end = f"<!-- /{marker} -->"
    block = f"{begin}\n{payload}\n{end}"
    if end in text:
        return re.sub(
            re.escape(begin) + r".*?" + re.escape(end), lambda _: block, text, flags=re.S
        )
    return text.replace(begin, block)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-csv", default="/tmp/paper_quality.csv")
    ap.add_argument("--file", default="EXPERIMENTS.md")
    args = ap.parse_args()
    text = open(args.file).read()
    text = inject(text, "ROOFLINE_TABLES", roofline_markdown())
    text = inject(text, "PAPER_TABLE", paper_markdown(args.paper_csv))
    open(args.file, "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
