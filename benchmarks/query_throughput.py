"""Serving-plane throughput bench (DESIGN.md §8): QPS / latency for the
query engine across chunk size × pipeline depth, the shard-parallel path
(when more than one device is visible), and the LRU answer cache on a
repeating query stream.

The pipelined `topk_search` (dispatch-ahead, depth 2) is measured against the
old synchronous loop (`pipeline=1`) at every chunk size — the serving-path
perf trajectory lands in ``BENCH_query.json`` (``--json``) so CI can archive
QPS, p50/p95 latency, and cache hit rate per commit.

Run:  PYTHONPATH=src python benchmarks/query_throughput.py [--smoke] \
          [--json BENCH_query.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp


def _percentiles(samples):
    return (
        float(np.percentile(samples, 50) * 1e3),
        float(np.percentile(samples, 95) * 1e3),
    )


def main(
    n_docs: int = 4000,
    culled: int = 800,
    order: int = 16,
    k: int = 10,
    beam: int = 4,
    chunks=(128, 512),
    n_queries: int = 2048,
    repeats: int = 5,
    seed: int = 0,
    json_path: str | None = None,
):
    from repro.core import ktree as kt
    from repro.core.query import (
        AnswerCache, topk_search, topk_search_cached, topk_search_sharded,
    )
    from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
    from repro.sparse.csr import csr_to_dense

    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, _ = prepared_corpus(spec, seed=seed)
    x_all = np.asarray(csr_to_dense(m))
    nq = min(n_queries, n_docs)
    x_q = jnp.asarray(x_all[:nq])
    tree = kt.build(jnp.asarray(x_all), order=order, batch_size=256,
                    key=jax.random.PRNGKey(seed))

    rows, blob = [], {
        "n_docs": n_docs, "n_queries": nq, "k": k, "beam": beam,
        "qps": {}, "latency_ms": {}, "cache": {}, "sharded": {},
    }

    # --- chunk × pipeline sweep: sync loop vs dispatch-ahead ----------------
    speedup_at = {}
    for chunk in chunks:
        qps_by_depth = {}
        for depth in (1, 2):
            topk_search(tree, x_q, k=k, beam=beam, chunk=chunk, pipeline=depth)
            lat = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                topk_search(tree, x_q, k=k, beam=beam, chunk=chunk, pipeline=depth)
                lat.append(time.perf_counter() - t0)
            med = float(np.median(lat))
            qps = nq / max(med, 1e-9)
            qps_by_depth[depth] = qps
            p50, p95 = _percentiles(lat)
            name = f"query_chunk{chunk}_pipe{depth}"
            rows.append((name, med / nq * 1e6,
                         f"qps={qps:.0f} p50={p50:.1f}ms p95={p95:.1f}ms"))
            blob["qps"][name] = qps
            blob["latency_ms"][name] = {"p50": p50, "p95": p95}
        speedup_at[chunk] = qps_by_depth[2] / max(qps_by_depth[1], 1e-9)
        rows.append((f"query_pipeline_speedup_chunk{chunk}", 0.0,
                     f"pipelined/sync={speedup_at[chunk]:.3f}x"))
    blob["pipeline_speedup"] = speedup_at

    # --- answer cache on a repeating stream ---------------------------------
    # zipf-ish serving mix: 60% of requests replay the hottest 10% of queries
    rng = np.random.default_rng(seed + 1)
    hot = max(nq // 10, 1)
    stream_len = 4 * nq
    hot_draw = rng.integers(0, hot, stream_len)
    cold_draw = rng.integers(0, nq, stream_len)
    stream = np.where(rng.random(stream_len) < 0.6, hot_draw, cold_draw)
    x_stream = x_all[:nq][stream]
    batch = 64  # requests arrive in serving batches; hits accrue across them
    # warm pass (throwaway cache): miss batches hit every power-of-two chunk
    # bucket, so the compiles land here, not in the timed steady state
    warm = AnswerCache(capacity=nq)
    for s0 in range(0, stream_len, batch):
        topk_search_cached(tree, x_stream[s0:s0 + batch], warm, k=k, beam=beam)
    cache = AnswerCache(capacity=nq)
    t0 = time.perf_counter()
    for s0 in range(0, stream_len, batch):
        topk_search_cached(tree, x_stream[s0:s0 + batch], cache, k=k, beam=beam)
    dt_cache = time.perf_counter() - t0
    t0 = time.perf_counter()
    for s0 in range(0, stream_len, batch):
        topk_search(tree, jnp.asarray(x_stream[s0:s0 + batch]), k=k, beam=beam)
    dt_plain = time.perf_counter() - t0
    s = cache.stats
    rows.append((
        "query_cache_stream", dt_cache / stream_len * 1e6,
        f"hit_rate={s['hit_rate']:.2f} qps={stream_len/max(dt_cache,1e-9):.0f} "
        f"uncached_qps={stream_len/max(dt_plain,1e-9):.0f}",
    ))
    blob["cache"] = {
        "hit_rate": s["hit_rate"], "hits": s["hits"], "misses": s["misses"],
        "qps": stream_len / max(dt_cache, 1e-9),
        "uncached_qps": stream_len / max(dt_plain, 1e-9),
        "stream_len": stream_len,
    }

    # --- shard-parallel path (needs >1 device, e.g. forced-host CPU mesh) ---
    n_dev = len(jax.devices())
    if n_dev > 1:
        n_shards = min(n_dev, 8)
        mesh = jax.make_mesh((n_shards,), ("data",))
        from repro.core.backend import DenseBackend

        shards = DenseBackend(jnp.asarray(x_all)).shard(mesh)
        chunk = chunks[min(1, len(chunks) - 1)]
        topk_search_sharded(mesh, tree, x_q, corpus=shards, k=k, beam=beam,
                            chunk=chunk)
        lat = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            topk_search_sharded(mesh, tree, x_q, corpus=shards, k=k, beam=beam,
                                chunk=chunk)
            lat.append(time.perf_counter() - t0)
        med = float(np.median(lat))
        qps = nq / max(med, 1e-9)
        # the merge all-gathers one k-wide (id, dist) list per shard per query
        merge_bytes = min(chunk, nq) * k * n_shards * (4 + 4)
        rows.append((
            f"query_sharded_x{n_shards}", med / nq * 1e6,
            f"qps={qps:.0f} merge_collective={merge_bytes}B/chunk "
            f"(O(B·k·S), corpus rows never gathered)",
        ))
        blob["sharded"] = {
            "n_shards": n_shards, "qps": qps, "chunk": chunk,
            "merge_collective_bytes_per_chunk": merge_bytes,
        }

    if json_path:
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        rows.append(("query_bench_json", 0.0, f"wrote {json_path}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--chunks", type=int, nargs="+", default=[128, 512])
    ap.add_argument("--queries", type=int, default=2048)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--json", default="", help="write BENCH_query.json here")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run: tiny corpus, short sweep",
    )
    args = ap.parse_args()
    if args.smoke:
        # chunk sizes stay well below the query count so every setting spans
        # several chunks — pipelining is unobservable on a single chunk
        args.docs, args.culled, args.order = 600, 250, 10
        args.chunks, args.queries, args.repeats = [64, 128], 512, 3
    for name, us, extra in main(
        n_docs=args.docs, culled=args.culled, order=args.order, k=args.k,
        beam=args.beam, chunks=tuple(args.chunks), n_queries=args.queries,
        repeats=args.repeats, json_path=args.json or None,
    ):
        print(f"{name},{us:.1f},{extra}")
