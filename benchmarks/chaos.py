"""Chaos bench (DESIGN.md §10): availability + latency under injected faults.

Measures the hardened serving path as a *fault-tolerance contract*, not a
throughput number: a seeded :class:`repro.core.faults.FaultPlan` injects
transient read errors (a sweep of rates) and in-flight bit-flip corruption
behind the store, and every leg reports

- **availability** — fraction of query rows answered (degrade mode drops
  only rows whose block is quarantined; everything else must answer);
- **strict_ok** — fraction of *answered* rows bit-identical to a fault-free
  reference run (the zero-silent-wrong-answers criterion: this must be 1.0
  on every leg, and the bench asserts it);
- **p50/p99 per-call latency** — what retry/backoff costs the tail;
- the store's hardened-read counters (retries, verify failures,
  quarantines).

Three sections:

1. **store legs** — store-backed ``topk_search`` over the whole corpus at
   transient fault rates 0 / 0.05 / 0.10, plus one leg with a persistently
   corrupt block (digest verification catches the flip, the block
   quarantines, exactly its rows drop).
2. **engine leg** — a :class:`repro.core.engine.ServingEngine` with
   ``request_timeout_s`` driven through a search fn that stalls on a seeded
   subset of calls: the watchdog expires the stalled requests with
   ``EngineTimeout`` and the bench asserts every admitted request resolved
   (completed + failed == admitted — the no-hang guarantee).
3. **fsck leg** — flip a byte of one block file on disk, time
   ``fsck_store`` (detect) and ``repair_store`` (excise + manifest rewrite),
   then check a degraded query over the repaired store answers the
   surviving rows bit-identically to the fault-free reference.

Results land in ``BENCH_chaos.json`` (``--json``) so the CI chaos job
archives the availability/latency trajectory per commit.

Run:  PYTHONPATH=src python benchmarks/chaos.py [--smoke] \
          [--json BENCH_chaos.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp


def _percentiles(lat_ms):
    lat = np.asarray(lat_ms, np.float64)
    return {
        "p50": float(np.percentile(lat, 50)),
        "p99": float(np.percentile(lat, 99)),
    }


def _run_leg(tree, store, k, beam, query_batch, on_fault="degrade"):
    """Query the full corpus back against the index in ``query_batch``-row
    calls; returns (docs, dist, per-call latencies ms, dropped row ids)."""
    n = store.n_docs
    docs = np.full((n, k), -1, np.int32)
    dist = np.full((n, k), np.inf, np.float32)
    lat_ms, dropped = [], []
    from repro.core.query import topk_search

    for lo in range(0, n, query_batch):
        hi = min(lo + query_batch, n)
        t0 = time.perf_counter()
        out = topk_search(tree, store.view(lo, hi), k=k, beam=beam,
                          on_fault=on_fault)
        lat_ms.append((time.perf_counter() - t0) * 1e3)
        docs[lo:hi], dist[lo:hi] = out[0], out[1]
        if len(out) == 3:
            dropped.extend(lo + r for r in out[2].dropped_query_rows)
    return docs, dist, lat_ms, sorted(dropped)


def main(
    n_docs: int = 4000,
    culled: int = 800,
    order: int = 16,
    k: int = 10,
    beam: int = 4,
    block_docs: int = 256,
    query_batch: int = 64,
    fault_rates=(0.0, 0.05, 0.10),
    engine_requests: int = 256,
    engine_stall_rate: float = 0.1,
    seed: int = 0,
    store_dir: str | None = None,
    json_path: str | None = None,
):
    """Run the chaos sweep; returns ``(name, us_per_call, derived)`` rows."""
    from repro.core import ktree as kt
    from repro.core.engine import ServingEngine, make_search_fn, pow2_bucket
    from repro.core.faults import FaultPlan, _coin
    from repro.core.fsck import fsck_store, repair_store
    from repro.core.store import open_store, save_store
    from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
    from repro.launch.engine import request_pool, run_load
    from repro.sparse.csr import csr_to_dense

    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, _ = prepared_corpus(spec, seed=seed)
    x_all = np.asarray(csr_to_dense(m))
    tree = kt.build(jnp.asarray(x_all), order=order, batch_size=256,
                    key=jax.random.PRNGKey(seed))

    tmp = None
    if store_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="chaos_store_")
        store_dir = tmp.name
    path = os.path.join(store_dir, "store")
    save_store(path, x_all, block_docs=block_docs)

    rows, blob = [], {
        "n_docs": n_docs, "k": k, "beam": beam, "block_docs": block_docs,
        "query_batch": query_batch, "seed": seed, "legs": {},
    }

    # fault-free reference: same call pattern as every leg, so bit-identity
    # comparisons are apples-to-apples
    ref_store = open_store(path)
    d_ref, s_ref, _, _ = _run_leg(tree, ref_store, k, beam, query_batch,
                                  on_fault="raise")

    corrupt_block = ref_store.n_blocks - 1
    legs = [(f"rate_{r:g}", FaultPlan(seed=seed + 1, transient_rate=r), ())
            for r in fault_rates]
    legs.append((
        "corrupt_1block",
        FaultPlan(seed=seed + 1, transient_rate=fault_rates[-1],
                  corrupt_blocks=(corrupt_block,)),
        tuple(range(*ref_store.block_rows(corrupt_block))),
    ))
    for name, plan, expect_dropped in legs:
        store = open_store(path, fault_plan=plan)
        t0 = time.perf_counter()
        docs, dist, lat_ms, dropped = _run_leg(
            tree, store, k, beam, query_batch
        )
        span = time.perf_counter() - t0
        answered = np.setdiff1d(np.arange(n_docs), np.asarray(dropped, int))
        availability = answered.size / n_docs
        strict_ok = float(
            np.mean((docs[answered] == d_ref[answered]).all(1)
                    & (dist[answered] == s_ref[answered]).all(1))
        ) if answered.size else 1.0
        assert strict_ok == 1.0, (
            f"chaos leg {name}: answered rows diverged from the fault-free "
            f"reference (strict_ok={strict_ok}) — silent wrong answers"
        )
        assert tuple(dropped) == expect_dropped, (
            f"chaos leg {name}: dropped rows {dropped[:8]}... != expected "
            f"{expect_dropped[:8]}..."
        )
        cs = store.cache.stats
        pct = _percentiles(lat_ms)
        rows.append((
            f"chaos_{name}", 1e6 * span / max(len(lat_ms), 1),
            f"availability={availability:.3f} strict_ok={strict_ok:.3f} "
            f"p50={pct['p50']:.1f}ms p99={pct['p99']:.1f}ms "
            f"retries={cs['read_retries']} verify_fail={cs['verify_failures']} "
            f"quarantined={cs['quarantined']}",
        ))
        blob["legs"][name] = {
            "transient_rate": plan.transient_rate,
            "corrupt_blocks": sorted(plan.corrupt_blocks),
            "availability": availability, "strict_ok": strict_ok,
            "latency_ms": pct, "qps": n_docs / max(span, 1e-9),
            "dropped_rows": len(dropped),
            "read_retries": cs["read_retries"],
            "read_errors": cs["read_errors"],
            "verify_failures": cs["verify_failures"],
            "quarantined": cs["quarantined"],
            "injected": plan.stats,
        }

    # --- engine leg: stalls vs the watchdog (no request may hang) ----------
    base_fn = make_search_fn(tree)
    nq = min(1024, n_docs)
    x_q = x_all[:nq]
    # a stall blocks the dispatcher, so requests arriving during it age in
    # the queue — the arrival rate is kept moderate so a stall expires its
    # own victims (watchdog timeouts > 0) without starving the whole stream
    stall_s, timeout_s, rate_qps = 0.08, 0.05, 50.0
    calls = [0]

    def flaky_fn(x, k_, beam_, chunk_rows=None):
        i = calls[0]
        calls[0] += 1
        if _coin(seed + 2, "stall", i) < engine_stall_rate:
            time.sleep(stall_s)
        return base_fn(x, k_, beam_, chunk_rows=chunk_rows)

    flaky_fn.chunk = base_fn.chunk
    flaky_fn.on_fault = None
    bucket, cap = pow2_bucket(1), pow2_bucket(32)
    s = bucket
    while True:  # warm the engine's compile ladder outside the timed run
        reps = -(-s // nq)
        base_fn(np.tile(x_q, (reps, 1))[:s], k, beam, chunk_rows=bucket)
        if s >= 2 * cap:
            break
        s *= 2
    pool = request_pool(x_q, n_requests=engine_requests, k=k, beam=beam,
                        seed=seed + 3)
    with ServingEngine(flaky_fn, row_budget=32, max_queue=engine_requests,
                       request_timeout_s=timeout_s) as eng:
        stats = run_load(eng, pool, rate_qps=rate_qps, seed=seed + 4)
    resolved = stats["completed"] + stats["failed"]
    assert resolved == stats["admitted"], (
        f"engine chaos leg: {stats['admitted'] - resolved} requests never "
        f"resolved — a hang the watchdog should have expired"
    )
    lat = stats["latency_ms"]
    rows.append((
        "chaos_engine_stalls", 1e6 / max(stats["qps"], 1e-9),
        f"admitted={stats['admitted']} completed={stats['completed']} "
        f"timeouts={stats['timeouts']} "
        f"watchdog_restarts={stats['watchdog_restarts']} "
        f"availability={stats['completed'] / max(stats['admitted'], 1):.3f} "
        f"p50={lat['p50']:.1f}ms p99={lat['p99']:.1f}ms",
    ))
    blob["engine"] = {
        "stall_rate": engine_stall_rate, "stall_s": stall_s,
        "request_timeout_s": timeout_s,
        "admitted": stats["admitted"], "completed": stats["completed"],
        "failed": stats["failed"], "timeouts": stats["timeouts"],
        "watchdog_restarts": stats["watchdog_restarts"],
        "availability": stats["completed"] / max(stats["admitted"], 1),
        "latency_ms": {"p50": lat["p50"], "p99": lat["p99"]},
    }

    # --- fsck leg: on-disk damage → detect → repair → degraded serve -------
    victim = sorted(glob.glob(os.path.join(path, "*_00000.npy")))[0]
    raw = bytearray(open(victim, "rb").read())
    raw[200] ^= 0xFF  # past the .npy header: only the digest can catch it
    open(victim, "wb").write(bytes(raw))
    t0 = time.perf_counter()
    detect = fsck_store(path)
    t_detect = time.perf_counter() - t0
    assert not detect.clean and [i for i, _ in detect.damaged] == [0], (
        f"fsck missed the damaged block: {detect.lines()}"
    )
    t0 = time.perf_counter()
    repair = repair_store(path)
    t_repair = time.perf_counter() - t0
    assert repair.repaired == (0,) and fsck_store(path).clean
    post = open_store(path)
    docs, dist, _, dropped = _run_leg(tree, post, k, beam, query_batch)
    lost = set(range(*post.block_rows(0)))
    survivors = np.asarray(sorted(set(range(n_docs)) - lost), int)
    assert set(dropped) == lost and (
        (docs[survivors] == d_ref[survivors]).all()
        and (dist[survivors] == s_ref[survivors]).all()
    ), "post-repair degraded answers diverged on surviving rows"
    rows.append((
        "chaos_fsck", 1e6 * (t_detect + t_repair),
        f"detect={t_detect * 1e3:.1f}ms repair={t_repair * 1e3:.1f}ms "
        f"excised={list(repair.repaired)} "
        f"post_repair_availability={survivors.size / n_docs:.3f} "
        f"survivors_bit_identical=True",
    ))
    blob["fsck"] = {
        "detect_s": t_detect, "repair_s": t_repair,
        "excised": list(repair.repaired),
        "post_repair_availability": survivors.size / n_docs,
        "survivors_bit_identical": True,
    }

    if json_path:
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        rows.append(("chaos_bench_json", 0.0, f"wrote {json_path}"))
    if tmp is not None:
        tmp.cleanup()
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--block-docs", type=int, default=256)
    ap.add_argument("--query-batch", type=int, default=64)
    ap.add_argument("--rates", type=float, nargs="+", default=[0.0, 0.05, 0.10],
                    help="transient read-fault rates to sweep")
    ap.add_argument("--requests", type=int, default=256,
                    help="engine-leg request count")
    ap.add_argument("--json", default="", help="write BENCH_chaos.json here")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run: tiny corpus, short sweeps",
    )
    args = ap.parse_args()
    if args.smoke:
        args.docs, args.culled, args.order = 600, 250, 10
        args.block_docs, args.query_batch, args.requests = 64, 64, 96
    for name, us, extra in main(
        n_docs=args.docs, culled=args.culled, order=args.order, k=args.k,
        beam=args.beam, block_docs=args.block_docs,
        query_batch=args.query_batch, fault_rates=tuple(args.rates),
        engine_requests=args.requests,
        json_path=args.json or None,
    ):
        print(f"{name},{us:.1f},{extra}")
