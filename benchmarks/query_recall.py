"""Query-engine bench: beam-search recall@k vs brute force, and QPS.

Sweeps beam width × k for both vector backends (dense and ELL-sparse medoid)
over one synthetic TF-IDF corpus (DESIGN.md §7): recall@k must grow
(monotonically, within noise) with beam width, with beam=1 equal to the greedy
single-path descent — the recall/latency dial the serving path exposes.

Run:  PYTHONPATH=src python benchmarks/query_recall.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
from repro.sparse.csr import csr_slice_rows, csr_to_dense


def main(
    n_docs: int = 3000,
    culled: int = 800,
    order: int = 16,
    k: int = 10,
    beams=(1, 2, 4, 8),
    n_queries: int = 256,
    seed: int = 0,
):
    from repro.core import ktree as kt
    from repro.core.backend import make_backend
    from repro.core.query import brute_force_topk, recall_at_k, topk_search

    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, _ = prepared_corpus(spec, seed=seed)
    x_all = np.asarray(csr_to_dense(m))
    nq = min(n_queries, n_docs)
    true_k = brute_force_topk(x_all[:nq], x_all, k)

    rows = []
    for name, be, medoid in [
        ("dense", make_backend(m, "dense"), False),
        ("sparse", make_backend(m, "sparse"), True),
    ]:
        # queries travel in the backend's own layout, so the sparse rows
        # benchmark the actual ELL query path (topk_flat via ell_spmm +
        # nnz-bounded cross_nodes), not the dense einsum path
        x_q = jnp.asarray(x_all[:nq]) if name == "dense" else csr_slice_rows(m, 0, nq)
        tree = kt.build(be, order=order, medoid=medoid,
                        key=jax.random.PRNGKey(seed))
        greedy_doc, _ = kt.nn_search_greedy(tree, x_q)
        recall_greedy = float(np.mean([
            greedy_doc[i] in true_k[i] for i in range(nq)
        ]))
        rows.append((
            f"query_greedy_{name}", 0.0,
            f"docs={n_docs} order={order} greedy 1NN-in-top{k}={recall_greedy:.3f}",
        ))
        prev = -1.0
        for beam in beams:
            topk_search(tree, x_q, k=k, beam=beam)  # warm the jit cache
            t0 = time.perf_counter()
            docs, _ = topk_search(tree, x_q, k=k, beam=beam)
            dt = time.perf_counter() - t0
            rec = recall_at_k(docs, true_k)
            trend = "+" if rec >= prev - 0.02 else "REGRESSION"
            prev = rec
            rows.append((
                f"query_beam{beam}_{name}",
                dt / nq * 1e6,
                f"recall@{k}={rec:.3f} qps={nq/max(dt,1e-9):.0f} trend={trend}",
            ))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=3000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beams", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run: tiny corpus, short beam sweep",
    )
    args = ap.parse_args()
    if args.smoke:
        args.docs, args.culled, args.order = 500, 250, 10
        args.beams, args.queries = [1, 2, 4], 96
    for name, us, extra in main(
        n_docs=args.docs, culled=args.culled, order=args.order, k=args.k,
        beams=tuple(args.beams), n_queries=args.queries,
    ):
        print(f"{name},{us:.1f},{extra}")
