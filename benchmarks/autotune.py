"""Measured-overlap auto-tuner bench (DESIGN.md §11): sweep the three
pipeline knobs per (block size, residency budget) cell and report what the
tuner picks against the depth-1 synchronous baseline.

For each (block_docs, budget fraction) cell the sweep:

- writes the corpus to an on-disk block store and opens it under the budget;
- runs ``core.autotune.autotune_store_search`` (``force=True`` — real
  measurements, sidecar rewritten) over a small (pipeline, prefetch, chunk)
  grid that always includes the synchronous baseline ``(1, 0, 512)``;
- re-runs the probe queries under the **chosen** knobs with a
  ``core.profile.Profiler`` attached and records the phase totals
  (read / dispatch / compute seconds) plus the measured read∩compute
  overlap fraction;
- asserts the tuned answers are **bit-identical** to the in-memory answers
  (the §9/§11 contract: knobs only reschedule work).

The JSON blob (``--json BENCH_autotune.json``, archived by the ``autotune``
CI job) carries per-cell ``{pipeline, prefetch, chunk, qps, baseline_qps,
speedup, overlap_frac, phases}`` — the acceptance check is that at least one
cell's chosen knobs beat the depth-1 sync baseline QPS.

Run:  PYTHONPATH=src python benchmarks/autotune.py [--smoke] \
          [--json BENCH_autotune.json]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp


def main(
    n_docs: int = 4000,
    culled: int = 800,
    order: int = 16,
    k: int = 10,
    beam: int = 4,
    block_sizes=(256, 1024),
    budget_fractions=(0.1, 0.5),
    pipelines=(1, 2, 4),
    prefetches=(0, 2),
    chunks=(256, 512),
    n_queries: int = 512,
    repeats: int = 2,
    seed: int = 0,
    store_dir: str | None = None,
    json_path: str | None = None,
):
    """Run the sweep; returns ``(name, us_per_query, extra)`` CSV rows."""
    from repro.core import ktree as kt
    from repro.core.autotune import autotune_store_search, load_tuned
    from repro.core.profile import Profiler
    from repro.core.query import topk_search
    from repro.core.store import open_store, save_store
    from repro.data.synth_corpus import INEX_LIKE, scaled, prepared_corpus
    from repro.sparse.csr import csr_to_dense

    spec = scaled(INEX_LIKE, n_docs=n_docs, culled=culled)
    m, _ = prepared_corpus(spec, seed=seed)
    x_all = np.asarray(csr_to_dense(m))
    nq = min(n_queries, n_docs)
    base_dir = store_dir or tempfile.mkdtemp(prefix="autotune_")

    key = jax.random.PRNGKey(seed)
    tree = kt.build(jnp.asarray(x_all), order=order, batch_size=256, key=key)
    x_q = jnp.asarray(x_all[:nq])
    # in-memory reference answers: every tuned cell must reproduce them
    d_ref, s_ref = topk_search(tree, x_q, k=k, beam=beam)

    rows, blob = [], {
        "n_docs": n_docs, "dim": x_all.shape[1], "k": k, "beam": beam,
        "n_queries": nq, "grid": {
            "pipelines": list(pipelines), "prefetches": list(prefetches),
            "chunks": list(chunks),
        },
        "cells": {},
    }

    for block_docs in block_sizes:
        path = os.path.join(base_dir, f"blk{block_docs}")
        save_store(path, x_all, block_docs=block_docs)
        corpus_bytes = open_store(path).nbytes

        for frac in budget_fractions:
            budget = max(int(corpus_bytes * frac), 1)
            tag = f"blk{block_docs}_budget{int(frac * 100)}pct"
            store = open_store(path, budget_bytes=budget)

            t0 = time.perf_counter()
            tuned = autotune_store_search(
                tree, store, k=k, beam=beam, budget_bytes=budget,
                pipelines=pipelines, prefetches=prefetches, chunks=chunks,
                n_queries=nq, repeats=repeats, force=True,
            )
            sweep_s = time.perf_counter() - t0
            # the decision round-trips through the sidecar it just wrote
            # (float fields are rounded on disk; the knobs must be exact)
            cached = load_tuned(store, budget_bytes=budget)
            assert (cached.pipeline, cached.prefetch, cached.chunk) == (
                tuned.pipeline, tuned.prefetch, tuned.chunk
            )

            # replay the probe under the chosen knobs with a profiler on:
            # phase totals + the §9 bit-identity contract on real answers
            store = open_store(path, budget_bytes=budget)
            prof = Profiler()
            store.cache.profiler = prof
            q_view = store.view(0, nq)
            t0 = time.perf_counter()
            d_t, s_t = topk_search(
                tree, q_view, k=k, beam=beam, tuned=tuned, profiler=prof,
            )
            tuned_wall = time.perf_counter() - t0
            np.testing.assert_array_equal(np.asarray(d_ref), d_t)
            np.testing.assert_array_equal(np.asarray(s_ref), s_t)

            totals = prof.totals()
            phases = {
                name: round(agg["seconds"], 6)
                for name, agg in sorted(totals.items())
            }
            speedup = tuned.qps / max(tuned.baseline_qps, 1e-9)
            rows.append((
                f"autotune_{tag}", tuned_wall / nq * 1e6,
                f"pipeline={tuned.pipeline} prefetch={tuned.prefetch} "
                f"chunk={tuned.chunk} qps={tuned.qps:.0f} "
                f"vs_sync={speedup:.2f}x "
                f"overlap={tuned.overlap_frac:.2f} exact=yes",
            ))
            blob["cells"][tag] = {
                "pipeline": tuned.pipeline, "prefetch": tuned.prefetch,
                "chunk": tuned.chunk, "qps": tuned.qps,
                "baseline_qps": tuned.baseline_qps, "speedup": speedup,
                "overlap_frac": tuned.overlap_frac,
                "budget_bytes": budget, "corpus_bytes": corpus_bytes,
                "sweep_seconds": sweep_s, "phases": phases,
            }

    beats = [t for t, c in blob["cells"].items() if c["speedup"] > 1.0]
    rows.append((
        "autotune_cells_beating_sync", float(len(beats)),
        f"{len(beats)}/{len(blob['cells'])} cells beat the depth-1 "
        f"sync baseline",
    ))
    blob["cells_beating_sync"] = beats

    if json_path:
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2, sort_keys=True)
        rows.append(("autotune_bench_json", 0.0, f"wrote {json_path}"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--culled", type=int, default=800)
    ap.add_argument("--order", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--blocks", type=int, nargs="+", default=[256, 1024])
    ap.add_argument("--budgets", type=float, nargs="+", default=[0.1, 0.5])
    ap.add_argument("--pipelines", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--prefetches", type=int, nargs="+", default=[0, 2])
    ap.add_argument("--chunks", type=int, nargs="+", default=[256, 512])
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--store-dir", default="", help="keep stores here "
                    "(default: a fresh temp dir)")
    ap.add_argument("--json", default="", help="write BENCH_autotune.json here")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run: tiny corpus, tight budgets, short grid",
    )
    args = ap.parse_args()
    if args.smoke:
        args.docs, args.culled, args.order = 600, 250, 10
        args.blocks, args.budgets = [64, 256], [0.05, 0.5]
        args.pipelines, args.prefetches = [1, 2], [0, 2]
        args.chunks = [128, 512]
        args.queries, args.repeats = 256, 2
    for name, us, extra in main(
        n_docs=args.docs, culled=args.culled, order=args.order, k=args.k,
        beam=args.beam, block_sizes=tuple(args.blocks),
        budget_fractions=tuple(args.budgets),
        pipelines=tuple(args.pipelines), prefetches=tuple(args.prefetches),
        chunks=tuple(args.chunks), n_queries=args.queries,
        repeats=args.repeats, store_dir=args.store_dir or None,
        json_path=args.json or None,
    ):
        print(f"{name},{us:.1f},{extra}")
