"""Paper Figures 1 & 2: clustering quality (micro purity / micro entropy) and
wall-clock runtime vs number of clusters, on INEX-2008-like and RCV1-like
corpora, for:

  - K-tree (dense, k-means-to-convergence node splits)   [paper]
  - Medoid K-tree (sparse exemplars, no updates)          [paper §2]
  - Sampled (10%) K-tree + NN assignment                  [paper §3]
  - k-means, fixed iterations (CLUTO-style)               [baseline]
  - repeated bisecting k-means (CLUTO rbr-style)          [baseline]

Corpora are scaled by --scale for CPU budgets; full-size uses the published
document counts. The cluster-count axis is swept via K-tree order (paper §3:
"the K-tree order was adjusted to alter the number of clusters at the leaf
level. CLUTO was then run to match the number of clusters produced").
"""
from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ktree as kt
from repro.core.kmeans import kmeans_fixed_iters, bisecting_kmeans
from repro.core.metrics import micro_purity, micro_entropy
from repro.core.sampling import sampled_ktree_clustering
from repro.data.synth_corpus import INEX_LIKE, RCV1_LIKE, prepared_corpus, scaled
from repro.sparse.csr import csr_to_dense

HEADER = "corpus,algorithm,order,n_clusters,purity,entropy,seconds"


def _score(assign, labels, nc, n_labels):
    a = jnp.asarray(assign)
    l = jnp.asarray(labels)
    return (
        float(micro_purity(a, l, nc, n_labels)),
        float(micro_entropy(a, l, nc, n_labels)),
    )


def run_corpus(name: str, spec, orders: List[int], seed: int = 0,
               batch_size: int = 256, bisect_cap: int = 128) -> List[str]:
    rows = []
    m, labels = prepared_corpus(spec, seed=seed)
    x = jnp.asarray(np.asarray(csr_to_dense(m)))
    n_labels = spec.n_labels
    key = jax.random.PRNGKey(seed)

    for order in orders:
        # --- K-tree (dense)
        t0 = time.perf_counter()
        tree = kt.build(x, order=order, batch_size=batch_size, key=key)
        a, nc = kt.extract_assignment(tree, x.shape[0])
        dt = time.perf_counter() - t0
        p, h = _score(a, labels, nc, n_labels)
        rows.append(f"{name},ktree,{order},{nc},{p:.4f},{h:.4f},{dt:.2f}")

        # --- Medoid K-tree
        t0 = time.perf_counter()
        mtree = kt.build(x, order=order, batch_size=batch_size, key=key, medoid=True)
        am, ncm = kt.extract_assignment(mtree, x.shape[0])
        dtm = time.perf_counter() - t0
        p, h = _score(am, labels, ncm, n_labels)
        rows.append(f"{name},medoid_ktree,{order},{ncm},{p:.4f},{h:.4f},{dtm:.2f}")

        # --- Sampled (10%) K-tree
        t0 = time.perf_counter()
        asamp, ncs, _ = sampled_ktree_clustering(
            x, order=order, fraction=0.1, batch_size=batch_size,
            key=jax.random.split(key)[0], sample_mode="random",
        )
        dts = time.perf_counter() - t0
        p, h = _score(asamp, labels, ncs, n_labels)
        rows.append(f"{name},sampled_ktree,{order},{ncs},{p:.4f},{h:.4f},{dts:.2f}")

        # --- CLUTO-style k-means at matched k
        t0 = time.perf_counter()
        res = kmeans_fixed_iters(key, x, nc, iters=10)
        dtk = time.perf_counter() - t0
        p, h = _score(np.asarray(res.assign), labels, nc, n_labels)
        rows.append(f"{name},kmeans_cluto,{order},{nc},{p:.4f},{h:.4f},{dtk:.2f}")

        # --- repeated bisecting k-means (host loop is O(k): cap for budget)
        if nc <= bisect_cap:
            t0 = time.perf_counter()
            res = bisecting_kmeans(key, x, nc, inner_iters=10)
            dtb = time.perf_counter() - t0
            p, h = _score(np.asarray(res.assign), labels, nc, n_labels)
            rows.append(f"{name},bisecting,{order},{nc},{p:.4f},{h:.4f},{dtb:.2f}")
    return rows


def main(scale_docs: int = 4000, culled: int = 1000, orders=(8, 16, 32, 64)):
    print(HEADER)
    out = [HEADER]
    for name, base in [("inex", INEX_LIKE), ("rcv1", RCV1_LIKE)]:
        spec = scaled(base, n_docs=scale_docs, culled=culled)
        for row in run_corpus(name, spec, list(orders)):
            print(row, flush=True)
            out.append(row)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--culled", type=int, default=1000)
    ap.add_argument("--orders", type=int, nargs="+", default=[8, 16, 32, 64])
    args = ap.parse_args()
    main(args.docs, args.culled, tuple(args.orders))
