"""Roofline analysis (deliverable g): combine the dry-run artifacts into the
three-term roofline per (arch × shape × mesh).

Hardware constants (TPU v5e):
  peak  = 197 TFLOP/s bf16 per chip
  HBM   = 819 GB/s per chip
  ICI   ≈ 50 GB/s per link

Terms (all in seconds *per device*, which equals global/chips under SPMD):
  compute    = HLO_flops_per_device / peak
  memory     = HLO_bytes_per_device / HBM
  collective = collective_bytes_per_device / ICI

Scanned LM cells are cost-combined from the full compile + single-layer /
boundary probes (XLA costs a while body once):
  total = full + (n_mb − 1)·boundary + (n_mb·L − 1)·layer
(n_mb = gradient-accumulation depth; n_mb=1 for serving cells; the formula
degenerates to full + (L−1)·layer.)

MODEL_FLOPS (the "useful flops" yardstick):
  LM train   : 6·N_active·tokens        (Kaplan convention)
  LM prefill : 2·N_active·tokens
  LM decode  : 2·N_active·batch
  GNN        : 2·(edge+triplet+node work)·d_hidden terms (formula below)
  recsys     : (3 if train else 1)·2·dense_param_flops·batch
  paper cell : 2·n_docs·k·d_terms (the distance matmul itself)
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def _dien_correction(rec: Dict) -> Dict[str, float]:
    """DIEN's two GRUs are lax.scans over seq_len=100 (full unroll stalls
    XLA:CPU at the big batches); cost_analysis counts the body once. Add the
    missing (seq_len−1) steps analytically: per step per example the GRU pair
    costs ≈ 2·(2d·3h + h·3h + h·h) + attention ≈ 2.2e5 flops and touches
    ≈ 3·h·4 bytes of state."""
    from repro.configs import registry

    spec = registry.get("dien")
    cfg = spec.cfg
    sh = spec.shapes[rec["shape"]]
    b = sh.get("batch", 1)
    d2, h = 2 * cfg.embed_dim, cfg.gru_dim
    per_step = 2.0 * (d2 * 3 * h + h * 3 * h + h * h) * 2   # two GRUs
    mult = 3.0 if rec["kind"] == "train" else 1.0
    extra_flops = mult * (cfg.seq_len - 1) * per_step * b / rec["n_devices"]
    extra_bytes = mult * (cfg.seq_len - 1) * (3 * h * 4 + d2 * 4) * b / rec["n_devices"]
    return {"flops": extra_flops, "bytes_accessed": extra_bytes}


def combined_cost(rec: Dict) -> Dict[str, float]:
    """Per-device totals with the scan-probe correction."""
    cost = dict(rec["cost"])
    coll = dict(rec.get("collectives_per_device_bytes", {}))
    if rec["arch"] == "dien" and rec["shape"] != "retrieval_cand":
        corr = _dien_correction(rec)
        cost["flops"] = cost.get("flops", 0.0) + corr["flops"]
        cost["bytes_accessed"] = cost.get("bytes_accessed", 0.0) + corr["bytes_accessed"]
    probe = rec.get("layer_probe")
    if probe:
        n_layers = rec["n_layers"]
        n_mb = 1
        if rec["kind"] == "train":
            from repro.configs import registry

            n_mb = registry.get(rec["arch"]).shapes[rec["shape"]].get("n_microbatches", 1)
        lay = probe["cost"]
        bnd = probe.get("boundary", {}).get("cost", {"flops": 0, "bytes_accessed": 0})
        for k in ("flops", "bytes_accessed", "transcendentals"):
            cost[k] = (
                cost.get(k, 0.0)
                + (n_mb - 1) * bnd.get(k, 0.0)
                + (n_mb * n_layers - 1) * lay.get(k, 0.0)
            )
        for cname, v in probe.get("collectives_per_device_bytes", {}).items():
            coll[cname] = coll.get(cname, 0.0) + (n_mb * n_layers - 1) * v
        for cname, v in probe.get("boundary", {}).get("collectives_per_device_bytes", {}).items():
            coll[cname] = coll.get(cname, 0.0) + (n_mb - 1) * v
    return {"cost": cost, "collectives": coll}


def model_flops(arch: str, shape: str, n_devices: int) -> Optional[float]:
    """Analytic useful-flops per device."""
    from repro.configs import registry

    spec = registry.get(arch)
    sh = spec.shapes[shape]
    if spec.family == "lm":
        n_act = spec.cfg.n_active_params()
        if sh["kind"] == "train":
            tokens = sh["batch"] * sh["seq"]
            total = 6.0 * n_act * tokens
        elif sh["kind"] == "prefill":
            total = 2.0 * n_act * sh["batch"] * sh["seq"]
        else:
            total = 2.0 * n_act * sh["batch"]
        return total / n_devices
    if spec.family == "gnn":
        cfg = registry.cfg_for_shape(spec, shape)
        h = cfg.d_hidden
        e, t, n = sh["n_edges"], sh["n_triplets"], sh["n_nodes"]
        per_block = 2.0 * (e * h * h * 2 + t * (h * cfg.n_bilinear * 2) + e * h * h)
        total = cfg.n_blocks * per_block + 2.0 * n * h * max(cfg.d_feat, h)
        if sh["kind"] == "train":
            total *= 3.0
        return total / n_devices
    if spec.family == "recsys":
        cfg = spec.cfg
        import numpy as np
        import jax

        params = jax.eval_shape(
            lambda k: __import__("repro.models.recsys", fromlist=["init_params"]).init_params(k, cfg),
            jax.random.PRNGKey(0),
        )
        dense_params = sum(
            int(np.prod(p.shape)) for path, p in jax.tree_util.tree_flatten_with_path(params)[0]
            if "tables" not in str(path) and "wide" not in str(path)
        )
        b = sh.get("batch", 1)
        if sh["kind"] == "retrieval":
            total = 2.0 * sh["n_candidates"] * cfg.embed_dim
        else:
            mult = 3.0 if sh["kind"] == "train" else 1.0
            if cfg.kind == "dien":
                # recurrent params run seq times; the head MLP runs once
                gru = 2 * (2 * cfg.embed_dim * 3 * cfg.gru_dim + cfg.gru_dim * 3 * cfg.gru_dim) \
                      + 2 * (cfg.gru_dim * 3 * cfg.gru_dim * 2)
                total = mult * b * (gru * cfg.seq_len + 2.0 * dense_params)
            else:
                total = mult * 2.0 * dense_params * b
        return total / n_devices
    if spec.family == "paper":
        total = 2.0 * sh["n_docs"] * sh["k"] * sh["n_terms"]
        return total / n_devices
    return None


def analyse(rec: Dict) -> Dict:
    cc = combined_cost(rec)
    flops = cc["cost"]["flops"]
    bytes_acc = cc["cost"]["bytes_accessed"]
    coll_bytes = sum(cc["collectives"].values())
    t_compute = flops / PEAK
    t_memory = bytes_acc / HBM
    t_coll = coll_bytes / ICI
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"], rec["n_devices"])
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": (mf / flops) if (mf and flops) else None,
        "roofline_fraction": (mf / PEAK) / bound if (mf and bound > 0) else None,
        "collectives": cc["collectives"],
        "hbm_gib": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30,
    }


def load_all(dryrun_dir: str = "experiments/dryrun"):
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec.get("mesh"), "error": rec.get("error")})
            continue
        out.append(analyse(rec))
    return out


def markdown_table(rows, mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful/HLO | roofline frac | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("mesh") != mesh or "error" in r:
            continue
        ur = f"{r['useful_ratio']:.2f}" if r.get("useful_ratio") else "-"
        rf = f"{r['roofline_fraction']:.3f}" if r.get("roofline_fraction") else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | {r['dominant']} | "
            f"{ur} | {rf} | {r['hbm_gib']:.1f} |"
        )
    return "\n".join(lines)


def main():
    rows = load_all()
    for mesh in ("16x16", "2x16x16"):
        print(f"\n## Roofline — mesh {mesh}\n")
        print(markdown_table(rows, mesh))


if __name__ == "__main__":
    main()
